"""Train the arcade-embedder LM trunk for a few hundred steps on the
synthetic Markov stream, with fault-tolerant checkpointing (kill it
mid-run and restart: it resumes from the latest step and the data cursor).

  PYTHONPATH=src python examples/train_embedder.py --steps 200
  (use --preset full to train the full 6L/512d config)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.train import checkpoint as ckpt
from repro.train import data as data_lib
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", choices=["reduced", "full"],
                    default="reduced")
    ap.add_argument("--ckpt-dir", default="/tmp/arcade_embedder_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config("arcade-embedder", reduced=(args.preset == "reduced"))
    opt_cfg = opt_lib.OptConfig(lr=3e-3, warmup_steps=20,
                                decay_steps=args.steps)
    dcfg = data_lib.DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch, seed=0)
    ds = data_lib.SyntheticLM(dcfg)

    state, _ = ts.make_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    start = 0
    latest = ckpt.latest_step(args.ckpt_dir)
    if latest is not None:
        like = ts.train_state_shapes(cfg, opt_cfg)
        state, extra = ckpt.restore(args.ckpt_dir, like)
        ds.load_state_dict(extra["data"])
        start = latest
        print(f"restored checkpoint at step {start} (elastic restart)")

    step_fn = jax.jit(lambda s, b: ts.train_step(s, b, cfg, opt_cfg))
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        if step % 20 == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({dt:.1f}s)", flush=True)
        if (step + 1) % args.ckpt_every == 0:
            ds.step = step + 1
            ckpt.save(args.ckpt_dir, step + 1, state,
                      extra={"data": ds.state_dict()})
    print("done; final checkpoint:",
          ckpt.save(args.ckpt_dir, args.steps, state,
                    extra={"data": {"step": args.steps}}))


if __name__ == "__main__":
    main()
