"""End-to-end serving driver (the paper's kind: a real-time data system).

Serves the arcade-embedder model with batched requests: incoming documents
are embedded by `serve_step.embed_step` and ingested into the ARCADE
store; incoming queries are embedded the same way, then answered with a
hybrid NN query through the ``Database`` facade. This is the
LLM(@query_text) -> L2_Distance(...) pipeline of the paper's §2.2
examples, with the model and the data system in one process.

  PYTHONPATH=src python examples/serve_hybrid.py [--requests 64]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import query as q
from repro.core.api import (Column, ColumnType, Database, IndexKind,
                            LSMConfig, Range, Schema, VectorRank)
from repro.models import model
from repro.train import data as data_lib
from repro.train import serve_step

DOCS = [
    "breaking sports news about the championship game",
    "new restaurant opens downtown with great food",
    "stock market rallies on tech earnings",
    "concert tonight live music in the park",
    "heavy rain expected this weekend weather alert",
    "machine learning conference announces keynote",
    "local team wins the derby in extra time",
    "recipe for the perfect pasta dinner",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    # --- the embedding model (paper-native arcade-embedder config) -----
    cfg = get_config("arcade-embedder", reduced=True)
    params, _ = model.init_params(jax.random.PRNGKey(0), cfg)
    embed = jax.jit(lambda p, t: serve_step.embed_step(p, cfg, t))
    seq = 16

    def embed_texts(texts):
        toks = np.stack([data_lib.text_to_tokens(t, cfg.vocab_size, seq)
                         for t in texts])
        return np.asarray(embed(params, jnp.asarray(toks)), np.float32)

    # --- the ARCADE database ---------------------------------------------
    schema = Schema([
        Column("embedding", ColumnType.VECTOR, dim=128, index=IndexKind.IVF),
        Column("coordinate", ColumnType.SPATIAL, index=IndexKind.ZORDER),
        Column("content", ColumnType.TEXT, index=IndexKind.INVERTED),
        Column("time", ColumnType.SCALAR, index=IndexKind.BTREE),
    ])
    db = Database(schema, LSMConfig(flush_rows=256))
    table = db.table()
    rng = np.random.default_rng(0)

    # --- serve batched ingest requests ----------------------------------
    t0 = time.perf_counter()
    pk = 0
    n_ingest = 0
    for r in range(args.requests):
        texts = [DOCS[(r + i) % len(DOCS)] + f" v{r}_{i}"
                 for i in range(args.batch)]
        emb = embed_texts(texts)
        table.put(list(range(pk, pk + args.batch)), {
            "embedding": emb,
            "coordinate": rng.uniform(0, 10,
                                      (args.batch, 2)).astype(np.float32),
            "content": np.asarray(texts, object),
            "time": np.full(args.batch, float(r)),
        })
        pk += args.batch
        n_ingest += args.batch
    table.flush()
    ingest_dt = time.perf_counter() - t0
    print(f"ingested {n_ingest} docs in {ingest_dt:.2f}s "
          f"({n_ingest / ingest_dt:.0f} docs/s incl. embedding)")

    # --- serve hybrid queries (batched: one embed call, one shared scan)
    queries = ["sports championship", "food dinner recipe",
               "tech stock earnings"]
    t0 = time.perf_counter()
    toks = np.stack([data_lib.text_to_tokens(t, cfg.vocab_size, seq)
                     for t in queries])
    answered = serve_step.serve_hybrid_queries(
        params, cfg, jnp.asarray(toks), table.executor,
        lambda qv: q.HybridQuery(
            where=Range("time", 0, args.requests),
            ranks=[VectorRank("embedding", qv, 1.0)], k=3))
    for text, (res, st) in zip(queries, answered):
        top = [(r.values["content"][:40], round(r.score, 3)) for r in res]
        print(f"query {text!r}: plan={st.plan.split('(')[0]}")
        for c, s in top:
            print(f"    {s:6.3f}  {c}")
    q_dt = (time.perf_counter() - t0) / len(queries)
    print(f"avg hybrid query latency (incl. query embedding, batched "
          f"execute_many): {q_dt * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
