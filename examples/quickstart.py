"""Quickstart: the ARCADE public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.api import (Column, ColumnType, Database, GeoWithin,
                            IndexKind, LSMConfig, Not, Or, Range, Schema,
                            SpatialRank, TextContains, VectorRank)

# 1. declare a multimodal schema (paper §2.1): vector + spatial + text +
#    scalar columns, each with its secondary index
schema = Schema([
    Column("embedding", ColumnType.VECTOR, dim=32, index=IndexKind.IVF),
    Column("coordinate", ColumnType.SPATIAL, index=IndexKind.ZORDER),
    Column("content", ColumnType.TEXT, index=IndexKind.INVERTED),
    Column("time", ColumnType.SCALAR, index=IndexKind.BTREE),
])
db = Database(schema, LSMConfig(flush_rows=1024),
              view_budget_bytes=4 * 2**20)
t = db.table()

# 2. high-throughput ingest — indexes are built at flush time, never on
#    the write path
rng = np.random.default_rng(0)
for start in range(0, 4096, 512):
    n = 512
    t.put(list(range(start, start + n)), {
        "embedding": rng.normal(size=(n, 32)).astype(np.float32),
        "coordinate": rng.uniform(0, 10, (n, 2)).astype(np.float32),
        "content": np.asarray([f"tweet about topic{i % 7}"
                               for i in range(start, start + n)], object),
        "time": rng.uniform(0, 100, n),
    })
t.flush()
print(f"ingested {t.n_rows} rows, {len(t.store.segments)} segments, "
      f"{t.store.metrics['compactions']} compactions")

# 3. hybrid search (Type 1): filters across three modalities; the
#    cost-based optimizer picks the index combination
res, stats = (t.query()
              .where(Range("time", 20, 40),
                     TextContains("content", "topic3"),
                     GeoWithin("coordinate", (2, 2, 8, 8)))
              .execute())
print(f"hybrid search: {len(res)} rows, plan={stats.plan}")

# 3b. boolean expressions: OR/NOT normalize to DNF; per-conjunct index
#     plans are OR-merged by the BitmapUnion operator
disj = (t.query()
        .where(Or(Range("time", 0, 5),
                  Not(TextContains("content", "topic3")))))
print(f"disjunctive search: {len(disj.all())} rows")
print("EXPLAIN:\n" + disj.explain())

# 4. hybrid NN (Type 2): joint vector+spatial ranking via NRA (Alg. 1)
qv = rng.normal(size=32).astype(np.float32)
res, stats = (t.query()
              .rank(VectorRank("embedding", qv, 0.5),
                    SpatialRank("coordinate", (5.0, 5.0), 1.5))
              .limit(5)
              .execute())
print(f"hybrid NN top-5: {[(r.pk, round(r.score, 3)) for r in res]}")
print(f"  plan={stats.plan}")

# 5. continuous queries (Types 3-4) over incremental materialized views
sub = (t.query()
       .rank(VectorRank("embedding", qv, 1.0))
       .limit(5)
       .subscribe(interval_s=60.0))
out = sub.poll(now=0.0)
print(f"continuous SYNC first tick: {[r.pk for r in out]} "
      f"(view_hits={t.engine.metrics['view_hits']})")

# writes are visible at the next tick — freshness
t.put([99999], {
    "embedding": qv[None, :], "coordinate": np.asarray([[5.0, 5.0]],
                                                       np.float32),
    "content": np.asarray(["exact match"], object),
    "time": np.asarray([50.0])})
out = sub.poll(now=60.0)
assert out[0].pk == 99999
print(f"after ingest, new top-1: {out[0].pk} (score={out[0].score:.4f})")
