"""Quickstart: the ARCADE public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import query as q
from repro.core.continuous import ContinuousEngine
from repro.core.executor import Executor
from repro.core.lsm import LSMConfig, LSMStore
from repro.core.types import Column, ColumnType, IndexKind, Schema

# 1. declare a multimodal schema (paper §2.1): vector + spatial + text +
#    scalar columns, each with its secondary index
schema = Schema([
    Column("embedding", ColumnType.VECTOR, dim=32, index=IndexKind.IVF),
    Column("coordinate", ColumnType.SPATIAL, index=IndexKind.ZORDER),
    Column("content", ColumnType.TEXT, index=IndexKind.INVERTED),
    Column("time", ColumnType.SCALAR, index=IndexKind.BTREE),
])
store = LSMStore(schema, LSMConfig(flush_rows=1024))

# 2. high-throughput ingest — indexes are built at flush time, never on
#    the write path
rng = np.random.default_rng(0)
for start in range(0, 4096, 512):
    n = 512
    store.put(list(range(start, start + n)), {
        "embedding": rng.normal(size=(n, 32)).astype(np.float32),
        "coordinate": rng.uniform(0, 10, (n, 2)).astype(np.float32),
        "content": np.asarray([f"tweet about topic{i % 7}"
                               for i in range(start, start + n)], object),
        "time": rng.uniform(0, 100, n),
    })
store.flush()
print(f"ingested {store.n_rows} rows, {len(store.segments)} segments, "
      f"{store.metrics['compactions']} compactions")

# 3. hybrid search (Type 1): filters across three modalities; the
#    cost-based optimizer picks the index combination
ex = Executor(store)
res, stats = ex.execute(q.HybridQuery(filters=[
    q.Range("time", 20, 40),
    q.TextContains("content", "topic3"),
    q.GeoWithin("coordinate", (2, 2, 8, 8)),
]))
print(f"hybrid search: {len(res)} rows, plan={stats.plan}")

# 4. hybrid NN (Type 2): joint vector+spatial ranking via NRA (Alg. 1)
qv = rng.normal(size=32).astype(np.float32)
res, stats = ex.execute(q.HybridQuery(
    ranks=[q.VectorRank("embedding", qv, 0.5),
           q.SpatialRank("coordinate", (5.0, 5.0), 1.5)], k=5))
print(f"hybrid NN top-5: {[(r.pk, round(r.score, 3)) for r in res]}")
print(f"  plan={stats.plan}")

# 5. continuous queries (Types 3-4) over incremental materialized views
eng = ContinuousEngine(store, mode="views", view_budget_bytes=4 * 2**20)
rid = eng.register(q.SyncQuery(q.HybridQuery(
    ranks=[q.VectorRank("embedding", qv, 1.0)], k=5), interval_s=60.0))
out = eng.advance(now=0.0)
print(f"continuous SYNC first tick: {[r.pk for r in out[rid]]} "
      f"(view_hits={eng.metrics['view_hits']})")

# writes are visible at the next tick — freshness
store.put([99999], {
    "embedding": qv[None, :], "coordinate": np.asarray([[5.0, 5.0]],
                                                       np.float32),
    "content": np.asarray(["exact match"], object),
    "time": np.asarray([50.0])})
out = eng.advance(now=60.0)
assert out[rid][0].pk == 99999
print(f"after ingest, new top-1: {out[rid][0].pk} "
      f"(score={out[rid][0].score:.4f})")
