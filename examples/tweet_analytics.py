"""Tweet analytics scenario — the paper's running example (§2.2).

Reproduces all four query types over the TRACY-style workload through the
``Database`` facade:
  Type 1  hybrid search   (semantic + keyword + region)
  Type 2  hybrid NN       (weighted spatial proximity + vector similarity)
  Type 3  continuous SYNC (campaign monitoring at fixed interval)
  Type 4  continuous ASYNC(investment research, re-run on data change)

  PYTHONPATH=src:. python examples/tweet_analytics.py
"""
import sys

import numpy as np

sys.path.insert(0, ".")
from benchmarks import tracy  # noqa: E402
from repro.core.api import (And, GeoWithin, Range,  # noqa: E402
                            SpatialRank, TextContains, VectorRange,
                            VectorRank)
from repro.core.api import Database  # noqa: E402

cfg = tracy.TracyConfig(n_rows=4000, dim=64, seed=3)
store, data = tracy.build_store(cfg)
db = Database(view_budget_bytes=8 * 2**20)
t = db.adopt_store("tweets", store)
print(f"TRACY store: {t.n_rows} tweets, {len(store.segments)} segments")

# -- Type 1: semantically relevant tweets mentioning a keyword in a region
qv = data.query_vec()
res, st = (t.query()
           .where(VectorRange("embedding", qv, 8.0),
                  TextContains("content", "sports"),
                  GeoWithin("coordinate", (10, 10, 60, 60)))
           .execute())
print(f"\n[Type 1] {len(res)} tweets match; plan={st.plan}")

# -- Type 2: weighted sum of spatial proximity and vector similarity
res, st = (t.query()
           .where(Range("time", 100, 600))
           .rank(VectorRank("embedding", qv, 0.6),
                 SpatialRank("coordinate", (50.0, 50.0), 0.3))
           .limit(5)
           .execute())
print(f"[Type 2] top-5 scores: {[round(r.score, 3) for r in res]}; "
      f"plan={st.plan.split('(')[0]}")

# -- Type 3: SYNC 60 seconds — advertising campaign monitoring
sync_sub = (t.query()
            .rank(VectorRank("embedding", qv, 1.0))
            .limit(10)
            .subscribe(interval_s=60.0, name="campaign_monitor"))

# -- Type 4: ASYNC — re-execute when new tweets arrive
async_sub = (t.query()
             .where(Range("time", 900, 1000))
             .subscribe(on_change=True, name="investment_research"))

clock = 0.0
for tick in range(4):
    out = t.advance(clock)
    ran = sorted(out.keys())
    print(f"[t={clock:5.0f}s] ran queries {ran}; "
          f"view_hits={t.engine.metrics['view_hits']}")
    # a burst of fresh tweets lands between ticks 1 and 2
    if tick == 1:
        pks, batch = data.batch(128)
        batch["time"] = np.full(128, 950.0)
        t.put(pks, batch)
        print("         ingested 128 fresh tweets (time=950)")
    clock += 60.0

final = async_sub.latest
print(f"[Type 4] final async result rows: {len(final)} "
      f"(includes fresh tweets: "
      f"{sum(1 for r in final if r.values['time'] == 950.0)})")
