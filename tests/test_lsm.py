"""LSM store behaviour: write path, flush, compaction, MVCC."""
import numpy as np

from conftest import make_batch, tweet_schema
from repro.core.lsm import LSMConfig, LSMStore
from repro.core.segment import merge_segments


def test_put_get_roundtrip():
    rng = np.random.default_rng(0)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=100))
    pks, batch = make_batch(rng, 250)
    store.put(pks, batch)
    # memtable + flushed segments both readable
    for i in (0, 120, 249):
        row = store.get(i)
        assert row is not None
        np.testing.assert_allclose(row["embedding"], batch["embedding"][i],
                                   rtol=1e-6)
        assert row["time"] == batch["time"][i]


def test_flush_threshold_and_background_index_build():
    rng = np.random.default_rng(1)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=128))
    for i in range(4):
        pks, batch = make_batch(rng, 128, pk_start=i * 128)
        store.put(pks, batch)
    assert store.metrics["flushes"] >= 3
    for seg in store.segments:
        # every declared index was built with the segment (paper §4)
        assert set(seg.indexes) == {"embedding", "coordinate", "content",
                                    "time"}


def test_update_shadows_old_version():
    rng = np.random.default_rng(2)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=64))
    pks, batch = make_batch(rng, 64)
    store.put(pks, batch)
    store.flush()
    _, batch2 = make_batch(rng, 1)
    store.put([10], batch2)
    row = store.get(10)
    np.testing.assert_allclose(row["embedding"], batch2["embedding"][0])
    store.flush()   # still newest after flush
    row = store.get(10)
    np.testing.assert_allclose(row["embedding"], batch2["embedding"][0])


def test_delete_tombstone():
    rng = np.random.default_rng(3)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=64))
    pks, batch = make_batch(rng, 64)
    store.put(pks, batch)
    store.delete([5, 6])
    assert store.get(5) is None and store.get(6) is None
    store.flush()
    assert store.get(5) is None
    assert store.get(7) is not None


def test_compaction_preserves_visible_rows():
    rng = np.random.default_rng(4)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=100, fanout=3))
    expect = {}
    for i in range(0, 900, 100):
        pks, batch = make_batch(rng, 100, pk_start=i)
        store.put(pks, batch)
        for j, pk in enumerate(pks):
            expect[pk] = batch["time"][j]
    store.flush()
    assert store.metrics["compactions"] >= 1
    levels = {s.level for s in store.segments}
    assert max(levels) >= 1
    for pk, t in list(expect.items())[::37]:
        assert store.get(pk)["time"] == t
    assert store.n_rows == len(expect)


def test_merge_segments_keeps_newest_seqno():
    rng = np.random.default_rng(5)
    schema = tweet_schema()
    store = LSMStore(schema, LSMConfig(flush_rows=10**9))
    pks, b1 = make_batch(rng, 50)
    store.put(pks, b1)
    s1 = store.flush()
    _, b2 = make_batch(rng, 50)
    store.put(pks, b2)     # same keys, newer seqnos
    s2 = store.flush()
    merged = merge_segments(schema, [s1, s2], level=1, drop_tombstones=True)
    assert merged.n_rows == 50
    i = merged.get(25)
    np.testing.assert_allclose(merged.columns["embedding"][i],
                               b2["embedding"][25])


def test_segment_block_reads():
    rng = np.random.default_rng(6)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=300))
    pks, batch = make_batch(rng, 300)
    store.put(pks, batch)
    seg = store.segments[0]
    assert seg.n_blocks == (seg.n_rows + 127) // 128
    blk = seg.read_block("embedding", 0)
    assert blk.shape[0] <= 128


def test_pack_cache_sees_late_quantized_codes():
    """The pack LRU keys on (seg_id, content_gen): packing a segment's
    codes, then re-assigning codes for the same seg_id (what a deferred
    or repeated encode does), must NOT serve the stale cached entry."""
    from repro.core import segment as seg_lib
    from repro.core.lsm import LSMConfig, LSMStore

    rng = np.random.default_rng(11)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=10**9,
                                               quantize_vectors=True))
    pks, batch = make_batch(rng, 200)
    store.put(pks, batch)
    seg = store.flush()
    assert seg.quantized.get("embedding") is not None
    gen0 = seg.content_gen
    assert gen0 >= 1               # the flush encode bumped it

    first = seg_lib.pack_quantized([seg], "embedding")
    assert first is not None

    # re-encode in place (same seg_id): a stale cache would return the
    # old codes object
    store._encode_quantized(seg, "embedding")
    assert seg.content_gen > gen0
    second = seg_lib.pack_quantized([seg], "embedding")
    assert second is not first
    np.testing.assert_array_equal(second.codes,
                                  seg.quantized["embedding"].codes)

    # fp32 pack keys the same way
    p1 = seg_lib.pack_segments([seg], "embedding")
    p2 = seg_lib.pack_segments([seg], "embedding")
    assert p1 is p2                # unchanged generation still caches
