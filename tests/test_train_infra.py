"""Training infrastructure: loss decreases, microbatch equivalence,
optimizers, gradient compression, checkpoint fault tolerance, data
pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.train import checkpoint as ckpt
from repro.train import data as data_lib
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts


def _tiny_cfg():
    return get_config("smollm-135m", reduced=True)


def test_loss_decreases_over_steps():
    cfg = _tiny_cfg()
    opt_cfg = opt_lib.OptConfig(lr=3e-3, warmup_steps=5, decay_steps=100)
    state, _ = ts.make_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    dcfg = data_lib.DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                               global_batch=8, seed=1)
    ds = data_lib.SyntheticLM(dcfg)
    step = jax.jit(lambda s, b: ts.train_step(s, b, cfg, opt_cfg))
    losses = []
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_microbatch_equivalence():
    """grad accumulation over 4 microbatches == single big batch."""
    cfg = _tiny_cfg()
    opt_cfg = opt_lib.OptConfig(lr=1e-3)
    state, _ = ts.make_train_state(jax.random.PRNGKey(1), cfg, opt_cfg)
    dcfg = data_lib.DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                               global_batch=8, seed=2)
    batch = {k: jnp.asarray(v)
             for k, v in data_lib.SyntheticLM(dcfg).batch_at(0).items()}
    s1, m1 = ts.train_step(state, batch, cfg, opt_cfg, num_microbatches=1)
    s4, m4 = ts.train_step(state, batch, cfg, opt_cfg, num_microbatches=4)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-2)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1["params"], s4["params"])
    assert max(jax.tree.leaves(d)) < 5e-2   # bf16 param grid tolerance


def test_adafactor_reduces_loss():
    cfg = _tiny_cfg().replace(optimizer="adafactor")
    opt_cfg = opt_lib.OptConfig(name="adafactor", lr=1e-2, warmup_steps=2,
                                decay_steps=100)
    state, _ = ts.make_train_state(jax.random.PRNGKey(2), cfg, opt_cfg)
    # factored second moment: no full-size mu/nu
    n_state = sum(x.size for x in jax.tree.leaves(state["opt"]))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    assert n_state < 0.5 * n_params
    dcfg = data_lib.DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                               global_batch=8, seed=3)
    ds = data_lib.SyntheticLM(dcfg)
    step = jax.jit(lambda s, b: ts.train_step(s, b, cfg, opt_cfg))
    losses = []
    for i in range(12):
        state, m = step(state, {k: jnp.asarray(v)
                                for k, v in ds.batch_at(i).items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_gradient_compression_error_feedback():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                    jnp.float32)
    err = jnp.zeros_like(g)
    qd, scale, err2 = opt_lib.compress_int8(g, err)
    deq = qd.astype(jnp.float32) * scale
    # quantization error bounded by scale/2, and carried into feedback
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 0.51
    np.testing.assert_allclose(np.asarray(err2), np.asarray(g - deq),
                               rtol=1e-6)
    # with compress_grads the optimizer still trains
    cfg = _tiny_cfg()
    opt_cfg = opt_lib.OptConfig(lr=3e-3, compress_grads=True)
    state, _ = ts.make_train_state(jax.random.PRNGKey(3), cfg, opt_cfg)
    dcfg = data_lib.DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                               global_batch=8, seed=4)
    ds = data_lib.SyntheticLM(dcfg)
    step = jax.jit(lambda s, b: ts.train_step(s, b, cfg, opt_cfg))
    losses = []
    for i in range(10):
        state, m = step(state, {k: jnp.asarray(v)
                                for k, v in ds.batch_at(i).items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_checkpoint_roundtrip_and_retention(tmp_path):
    cfg = _tiny_cfg()
    opt_cfg = opt_lib.OptConfig()
    state, _ = ts.make_train_state(jax.random.PRNGKey(4), cfg, opt_cfg)
    d = str(tmp_path / "ckpts")
    for step in (1, 2, 3, 4):
        ckpt.save(d, step, state, extra={"data_step": step * 10}, keep=2)
    assert ckpt.latest_step(d) == 4
    # retention kept only last 2
    assert len([x for x in os.listdir(d) if x.startswith("step_")]) == 2
    like = ts.train_state_shapes(cfg, opt_cfg)
    restored, extra = ckpt.restore(d, like)
    assert extra["data_step"] == 40
    a = jax.tree.leaves(state["params"])[0]
    b = jax.tree.leaves(restored["params"])[0]
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    cfg = _tiny_cfg()
    opt_cfg = opt_lib.OptConfig()
    state, _ = ts.make_train_state(jax.random.PRNGKey(5), cfg, opt_cfg)
    d = str(tmp_path / "ckpts")
    ckpt.save(d, 7, state)
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    cfg = _tiny_cfg()
    opt_cfg = opt_lib.OptConfig()
    state, _ = ts.make_train_state(jax.random.PRNGKey(6), cfg, opt_cfg)
    d = str(tmp_path / "ckpts")
    ckpt.save(d, 1, state)
    other = get_config("qwen3-4b", reduced=True)
    like = ts.train_state_shapes(other, opt_cfg)
    with pytest.raises((ValueError, KeyError)):
        ckpt.restore(d, like)


def test_data_pipeline_deterministic_and_sharded():
    base = dict(vocab_size=512, seq_len=16, global_batch=8, seed=9)
    a = data_lib.SyntheticLM(data_lib.DataConfig(**base))
    b = data_lib.SyntheticLM(data_lib.DataConfig(**base))
    np.testing.assert_array_equal(a.batch_at(3)["tokens"],
                                  b.batch_at(3)["tokens"])
    # two hosts partition the global batch without overlap
    h0 = data_lib.SyntheticLM(data_lib.DataConfig(**base, num_hosts=2,
                                                  host_id=0))
    h1 = data_lib.SyntheticLM(data_lib.DataConfig(**base, num_hosts=2,
                                                  host_id=1))
    t0, t1 = h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"]
    assert t0.shape[0] == 4 and t1.shape[0] == 4
    assert not np.array_equal(t0, t1)
    # cursor checkpointable
    a.step = 5
    st = a.state_dict()
    c = data_lib.SyntheticLM(data_lib.DataConfig(**base))
    c.load_state_dict(st)
    assert c.step == 5
