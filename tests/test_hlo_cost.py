"""The trip-count-aware HLO cost walker (roofline methodology)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def test_single_matmul_flops():
    n = 256
    w = jnp.zeros((n, n), jnp.float32)
    x = jnp.zeros((n, n), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(x, w).compile()
    hc = hlo_cost.analyze_hlo(c.as_text())
    assert hc.flops == pytest.approx(2 * n**3, rel=0.05)


def test_scan_multiplies_by_trip_count():
    n, trips = 128, 7
    w = jnp.zeros((n, n), jnp.float32)
    x = jnp.zeros((n, n), jnp.float32)

    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=trips)
        return y

    c = jax.jit(f).lower(w, x).compile()
    hc = hlo_cost.analyze_hlo(c.as_text())
    assert hc.flops == pytest.approx(trips * 2 * n**3, rel=0.15)


def test_nested_scans_multiply():
    n, outer, inner = 64, 3, 4
    w = jnp.zeros((n, n), jnp.float32)
    x = jnp.zeros((n, n), jnp.float32)

    def f(w, x):
        def obody(c, _):
            def ibody(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(ibody, c, None, length=inner)
            return ci, None
        y, _ = jax.lax.scan(obody, x, None, length=outer)
        return y

    c = jax.jit(f).lower(w, x).compile()
    hc = hlo_cost.analyze_hlo(c.as_text())
    assert hc.flops == pytest.approx(outer * inner * 2 * n**3, rel=0.15)


def test_bytes_scale_with_tensor_size():
    small = jax.jit(lambda x: jnp.tanh(x) * 2).lower(
        jnp.zeros((128, 128))).compile()
    big = jax.jit(lambda x: jnp.tanh(x) * 2).lower(
        jnp.zeros((512, 512))).compile()
    hs = hlo_cost.analyze_hlo(small.as_text())
    hb = hlo_cost.analyze_hlo(big.as_text())
    assert hb.hbm_bytes > 8 * hs.hbm_bytes


def test_collectives_counted(tmp_path):
    # hand-built HLO exercising the parser (no multi-device needed)
    hlo = """
HloModule test, entry_computation_layout={()->f32[]}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main () -> f32[] {
  %c = f32[128,128]{1,0} constant(0)
  %ar = f32[128,128]{1,0} all-reduce(%c), replica_groups={}, to_apply=%add
  %ag = f32[256,128]{1,0} all-gather(%ar), dimensions={0}
  ROOT %s = f32[] constant(0)
}
"""
    hc = hlo_cost.analyze_hlo(hlo)
    ar = 128 * 128 * 4
    ag = 256 * 128 * 4
    assert hc.coll_bytes_by_kind["all-reduce"] == pytest.approx(2 * ar)
    assert hc.coll_bytes_by_kind["all-gather"] == pytest.approx(ag)


def test_roofline_model_flops():
    from repro.configs import get_config, get_shape
    from repro.launch import roofline as rl
    cfg = get_config("qwen3-4b")
    n = rl.count_params(cfg)
    assert 3.5e9 < n < 5.5e9            # ~4B params
    mf = rl.model_flops_for(cfg, get_shape("train_4k"))
    assert mf == pytest.approx(6 * n * 4096 * 256, rel=1e-6)
    # MoE uses active params only
    ds = get_config("deepseek-moe-16b")
    n_all = rl.count_params(ds)
    n_act = rl.count_params(ds, active_only=True)
    assert n_act < 0.5 * n_all
