"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes (assignment requirement)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bitmap_filter as bf
from repro.kernels import ivf_scan as ivf
from repro.kernels import ops, pq_adc, ref, topk_merge as tkm


@pytest.mark.parametrize("nq,n,d", [(8, 512, 16), (8, 1024, 128),
                                    (16, 512, 64), (32, 2048, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ivf_scan_kernel_matches_ref(nq, n, d, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(nq, d)), dtype)
    v = jnp.asarray(rng.normal(size=(n, d)), dtype)
    out = ivf.ivf_scan(q, v, interpret=True)
    want = ref.ivf_scan_ref(q, v)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol * d)


@pytest.mark.parametrize("n,m", [(512, 4), (1024, 8), (512, 16)])
def test_pq_adc_kernel_matches_ref(n, m):
    rng = np.random.default_rng(1)
    codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.int32)
    lut = jnp.asarray(rng.normal(size=(m, 256)) ** 2, jnp.float32)
    out = pq_adc.pq_adc(codes, lut, interpret=True)
    want = ref.pq_adc_ref(codes, lut)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5)


@pytest.mark.parametrize("n,c", [(1024, 1), (2048, 3), (1024, 6)])
def test_bitmap_filter_kernel_matches_ref(n, c):
    rng = np.random.default_rng(2)
    cols = jnp.asarray(rng.uniform(0, 1, (n, c)), jnp.float32)
    bounds = np.sort(rng.uniform(0, 1, (c, 2)), axis=1)
    out = bf.bitmap_filter(cols, jnp.asarray(bounds, jnp.float32),
                           interpret=True)
    want = ref.bitmap_filter_ref(cols, jnp.asarray(bounds, jnp.float32))
    np.testing.assert_array_equal(np.asarray(out).astype(bool),
                                  np.asarray(want))


@pytest.mark.parametrize("s,kk,k", [(4, 16, 8), (8, 32, 10), (16, 8, 16)])
def test_topk_merge_kernel_matches_ref(s, kk, k):
    rng = np.random.default_rng(3)
    d = jnp.asarray(np.sort(rng.normal(size=(s, kk)) ** 2, axis=1),
                    jnp.float32)
    ids = jnp.asarray(rng.integers(0, 10**6, (s, kk)), jnp.int64)
    od, oi = tkm.topk_merge(d, ids, k, interpret=True)
    wd, wi = ref.topk_merge_ref(d, ids, k)
    np.testing.assert_allclose(np.asarray(od), np.asarray(wd), rtol=1e-6)
    # ids may differ on exact ties; distances define correctness
    assert set(np.asarray(oi).tolist()) == set(np.asarray(wi).tolist())


@pytest.mark.parametrize("nq,s,kk,k", [(4, 3, 8, 5), (8, 5, 16, 10),
                                       (2, 2, 4, 8)])
def test_batched_topk_merge_matches_ref(nq, s, kk, k):
    rng = np.random.default_rng(9)
    d = np.sort(rng.normal(size=(nq, s, kk)) ** 2, axis=2)
    ids = rng.integers(0, 10**6, (nq, s, kk))
    # duplicate scores across shards so the (score, id) tie-break matters,
    # and pad one shard tail with the sentinel slot encoding
    d[:, 1, :] = d[:, 0, :]
    sent = np.iinfo(np.int32).max
    d[:, -1, kk // 2:] = np.inf
    ids[:, -1, kk // 2:] = sent
    d = jnp.asarray(d, jnp.float32)
    ids = jnp.asarray(ids, jnp.int32)
    od, oi = tkm.batched_topk_merge(d, ids, k, interpret=True)
    wd, wi = ref.batched_topk_merge_ref(d, ids, k)
    np.testing.assert_allclose(np.asarray(od), np.asarray(wd), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(oi), np.asarray(wi))


def test_ops_backends_agree():
    """ops.py with use_pallas=True must equal the ref backend, including
    padding edge cases (non-multiple shapes)."""
    rng = np.random.default_rng(4)
    q = rng.normal(size=(3, 24)).astype(np.float32)      # nq not /8, d odd-ish
    x = rng.normal(size=(700, 24)).astype(np.float32)    # n not /512
    a = ops.l2_distances(q, x, use_pallas=True)
    b = ops.l2_distances(q, x, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)

    cols = rng.uniform(0, 1, (1000, 2)).astype(np.float32)
    bounds = np.sort(rng.uniform(0, 1, (2, 2)), axis=1).astype(np.float32)
    np.testing.assert_array_equal(
        ops.range_bitmap(cols, bounds, use_pallas=True),
        ops.range_bitmap(cols, bounds, use_pallas=False))

    codes = rng.integers(0, 256, (700, 8)).astype(np.uint8)
    books = rng.normal(size=(8, 256, 3)).astype(np.float32)
    qv = rng.normal(size=24).astype(np.float32)
    np.testing.assert_allclose(
        ops.pq_adc_distances(qv, codes, books, use_pallas=True),
        ops.pq_adc_distances(qv, codes, books, use_pallas=False),
        rtol=1e-4, atol=1e-4)

    d = np.sort(rng.normal(size=(5, 9)) ** 2, axis=1).astype(np.float32)
    ids = rng.integers(0, 10**6, (5, 9))
    d1, i1 = ops.merge_topk(d, ids, 7, use_pallas=True)
    d2, i2 = ops.merge_topk(d, ids, 7, use_pallas=False)
    np.testing.assert_allclose(d1, d2, rtol=1e-6)
