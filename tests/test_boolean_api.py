"""Boolean filter expressions (And/Or/Not), DNF planning, and the
Database facade: results must match a brute-force numpy reference across
every plan kind, normalization must be idempotent, batching must group
mixed expression shapes, and the legacy ``filters=[...]`` shim must warn.
"""
import warnings

import numpy as np
import pytest

from conftest import make_batch, tweet_schema
from repro.core import query as q
from repro.core.api import Database, LSMConfig
from repro.core.continuous import ContinuousEngine
from repro.core.index.text import tokenize
from repro.core.lsm import LSMStore
from repro.core.optimizer import planner as pl


@pytest.fixture(scope="module")
def db_ref():
    rng = np.random.default_rng(21)
    db = Database(tweet_schema(), LSMConfig(flush_rows=512))
    t = db.table()
    ref = {}
    for i in range(0, 3000, 500):
        pks, batch = make_batch(rng, 500, pk_start=i)
        t.put(pks, batch)
        for j, pk in enumerate(pks):
            ref[pk] = {c: batch[c][j] for c in batch}
    t.flush()
    return db, ref


def brute_expr(ref, expr):
    """Row-at-a-time reference evaluation of a filter expression."""
    def one(row, e):
        if e is None:
            return True
        if isinstance(e, q.And):
            return all(one(row, c) for c in e.children)
        if isinstance(e, q.Or):
            return any(one(row, c) for c in e.children)
        if isinstance(e, q.Not):
            return not one(row, e.child)
        if isinstance(e, q.Range):
            return e.lo <= row[e.col] <= e.hi
        if isinstance(e, q.GeoWithin):
            x, y = row[e.col]
            return (e.rect[0] <= x <= e.rect[2]
                    and e.rect[1] <= y <= e.rect[3])
        if isinstance(e, q.TextContains):
            return e.term in tokenize(row[e.col])
        if isinstance(e, q.VectorRange):
            return float(np.sqrt(((row[e.col] - e.q) ** 2).sum())) < e.thresh
        raise TypeError(e)
    return {pk for pk, row in ref.items() if one(row, expr)}


EXPRS = [
    q.Or(q.Range("time", 0, 25), q.Range("time", 75, 100)),
    q.Or(q.Range("time", 10, 40), q.TextContains("content", "banana")),
    q.Not(q.TextContains("content", "apple")),
    q.And(q.Range("time", 5, 80),
          q.Not(q.GeoWithin("coordinate", (0, 0, 5, 5)))),
    q.Or(q.And(q.Range("time", 0, 30),
               q.GeoWithin("coordinate", (2, 2, 8, 8))),
         q.And(q.TextContains("content", "cherry"),
               q.Not(q.Range("time", 50, 100)))),
]


@pytest.mark.parametrize("expr", EXPRS)
def test_boolean_search_matches_brute(db_ref, expr):
    db, ref = db_ref
    got = {r.pk for r in db.table().query().where(expr).all()}
    assert got == brute_expr(ref, expr)


@pytest.mark.parametrize("expr", EXPRS)
def test_boolean_nn_matches_brute(db_ref, expr):
    db, ref = db_ref
    qv = np.random.default_rng(3).normal(size=16).astype(np.float32)
    k = 12
    res = (db.table().query().where(expr)
           .rank(q.VectorRank("embedding", qv, 1.0)).limit(k).all())
    want = brute_expr(ref, expr)
    score = {pk: float(np.sqrt(((ref[pk]["embedding"] - qv) ** 2).sum()))
             for pk in want}
    top = sorted(want, key=lambda pk: (score[pk], pk))[:k]
    assert [r.pk for r in res] == top


def test_or_query_correct_through_forced_full_scan(db_ref):
    """The degenerate plan (full scan, whole expression as residual)
    agrees with the planner-chosen BitmapUnion plan."""
    db, ref = db_ref
    t = db.table()
    expr = EXPRS[1]
    want = brute_expr(ref, expr)
    forced = pl.Plan(kind="full_scan", residual=[expr])
    res, _ = t.executor.execute(q.HybridQuery(where=expr), plan=forced)
    assert {r.pk for r in res} == want
    chosen = pl.plan(t.executor.catalog, q.HybridQuery(where=expr))
    assert chosen.kind == "union"
    res2, _ = t.executor.execute(q.HybridQuery(where=expr), plan=chosen)
    assert {r.pk for r in res2} == want


# ---------------------------------------------------------------------------
# DNF normalization
# ---------------------------------------------------------------------------

def test_dnf_idempotent():
    for expr in EXPRS:
        d1 = q.to_dnf(expr)
        d2 = q.to_dnf(q.from_dnf(d1))
        assert d1 == d2


def test_dnf_de_morgan_and_double_negation():
    a, b = q.Range("time", 0, 1), q.TextContains("content", "x")
    assert q.to_dnf(q.Not(q.Not(a))) == [(a,)]
    # NOT(a AND b) == NOT a OR NOT b
    assert q.to_dnf(q.Not(q.And(a, b))) == [(q.Not(a),), (q.Not(b),)]
    # NOT(a OR b) == NOT a AND NOT b
    assert q.to_dnf(q.Not(q.Or(a, b))) == [(q.Not(a), q.Not(b))]


def test_dnf_simplifications():
    a, b = q.Range("time", 0, 1), q.TextContains("content", "x")
    # contradiction dropped
    assert q.to_dnf(q.And(a, q.Not(a))) == []
    # duplicate literal deduped
    assert q.to_dnf(q.And(a, a)) == [(a,)]
    # absorption: a OR (a AND b) == a
    assert q.to_dnf(q.Or(a, q.And(a, b))) == [(a,)]
    # duplicate conjuncts deduped
    assert q.to_dnf(q.Or(a, a)) == [(a,)]


def test_unsatisfiable_where_returns_no_rows(db_ref):
    """DNF=false must stay distinct from DNF=no-filter: a contradictory
    WHERE returns zero rows, not every row."""
    db, _ = db_ref
    t = db.table()
    a = q.Range("time", 0, 10)
    contradiction = q.And(a, q.Not(a))
    assert t.query().where(contradiction).all() == []
    plan = pl.plan(t.executor.catalog, q.HybridQuery(where=contradiction))
    assert plan.kind == "empty"
    assert "EmptyResult" in plan.describe()
    # NN shape and batched execution agree
    res = t.executor.execute_many([
        q.HybridQuery(where=contradiction,
                      ranks=[q.VectorRank("embedding",
                                          np.zeros(16, np.float32), 1.0)],
                      k=5),
        q.HybridQuery(where=q.Range("time", 0, 100)),
    ])
    assert res[0][0] == [] and len(res[1][0]) > 0
    # degenerate DNF values: TRUE is [()], FALSE is []
    assert q.to_dnf(None) == [()]
    assert q.to_dnf(contradiction) == []
    with pytest.raises(ValueError):
        q.from_dnf([])


def test_not_vector_range_exact_under_index_paths(db_ref):
    """Complementing an approximate IVF bitmap must not re-admit rows
    inside the excluded distance ball (the NRA filter-mask path probes
    indexes; negated vector leaves must take the exact kernel path)."""
    db, ref = db_ref
    t = db.table()
    qv = np.random.default_rng(7).normal(size=16).astype(np.float32)
    dists = {pk: float(np.sqrt(((row["embedding"] - qv) ** 2).sum()))
             for pk, row in ref.items()}
    ordered = sorted(dists.values())
    thresh = (ordered[29] + ordered[30]) / 2   # margin from any boundary
    expr = q.Not(q.VectorRange("embedding", qv, thresh))
    ranks = [q.VectorRank("embedding", qv, 1.0)]
    k = 10
    plan = pl.Plan(kind="nra", residual=[expr], ranks=ranks, k=k)
    res, _ = t.executor.execute(
        q.HybridQuery(where=expr, ranks=ranks, k=k), plan=plan)
    want = brute_expr(ref, expr)
    top = sorted(want, key=lambda pk: (dists[pk], pk))[:k]
    assert [r.pk for r in res] == top


def test_fcache_invalidates_when_update_leaves_result():
    """An update that moves a row OUT of a cached multi-predicate result
    must invalidate the cache entry (leaf-level delta test)."""
    from repro.core.lsm import LSMConfig as _Cfg
    rng = np.random.default_rng(0)
    store = LSMStore(tweet_schema(), _Cfg(flush_rows=10_000))
    pks, batch = make_batch(rng, 50)
    batch["time"] = np.linspace(0, 100, 50)
    batch["content"] = np.asarray(["apple pie"] * 50, object)
    store.put(pks, batch)
    eng = ContinuousEngine(store, mode="fcache")
    rid = eng.register(q.SyncQuery(q.HybridQuery(
        where=q.And(q.Range("time", 0, 10),
                    q.TextContains("content", "apple"))), interval_s=1.0))
    first = eng.advance(0.0)[rid]
    assert first
    victim = first[0].pk
    update = {c: np.asarray([batch[c][victim]]) for c in batch}
    update["time"] = np.asarray([50.0])    # fails Range, still has "apple"
    store.put([victim], update)
    second = eng.advance(1.0)[rid]
    assert victim not in {r.pk for r in second}


def test_predicates_hashable():
    v1 = q.VectorRange("embedding", np.ones(4), 2.0)
    v2 = q.VectorRange("embedding", np.ones(4), 2.0)
    assert v1 == v2 and hash(v1) == hash(v2)
    r1 = q.VectorRank("embedding", np.zeros(4), 0.5)
    r2 = q.VectorRank("embedding", np.zeros(4), 0.5)
    assert r1 == r2 and len({r1, r2}) == 1
    # whole expression trees are hashable (DNF dedup relies on it)
    assert len({q.And(v1, q.Not(v1)), q.And(v2, q.Not(v2))}) == 1


# ---------------------------------------------------------------------------
# batching / EXPLAIN / shim
# ---------------------------------------------------------------------------

def test_execute_many_mixed_expression_shapes(db_ref):
    db, ref = db_ref
    t = db.table()
    rng = np.random.default_rng(9)
    queries = []
    for i, expr in enumerate(EXPRS):
        if i % 2:
            queries.append(q.HybridQuery(where=expr))
        else:
            queries.append(q.HybridQuery(
                where=expr,
                ranks=[q.VectorRank("embedding",
                                    rng.normal(size=16).astype(np.float32),
                                    1.0)], k=8))
    queries.append(q.HybridQuery(where=q.Range("time", 0, 50)))
    single = [t.executor.execute(qq)[0] for qq in queries]
    batched = [r for r, _ in t.executor.execute_many(queries)]
    for a, b in zip(single, batched):
        assert [r.pk for r in a] == [r.pk for r in b]
        assert [r.score for r in a] == pytest.approx(
            [r.score for r in b], rel=1e-4)


def test_union_explain_has_per_conjunct_costs(db_ref):
    db, _ = db_ref
    text = (db.table().query()
            .where(q.Or(q.Range("time", 0, 10),
                        q.TextContains("content", "echo")))
            .rank(q.VectorRank("embedding", np.zeros(16, np.float32), 1.0))
            .explain())
    assert text.startswith("union_nn(")
    assert "BitmapUnion" in text and "2 conjuncts" in text
    # the ranking node is RankScore (staged) or FusedScanTopK (fused
    # packed dispatch) depending on the planner's dispatch choice
    assert "RankScore" in text or "FusedScanTopK" in text
    assert "TopKMerge" in text
    # per-conjunct children carry their own non-zero cost estimates
    costs = [float(tok.split("=")[1].rstrip(")"))
             for tok in text.split() if tok.startswith("cost=")]
    assert sum(c > 0 for c in costs) >= 3


def test_filters_kwarg_shim_warns_and_matches(db_ref):
    db, ref = db_ref
    preds = [q.Range("time", 10, 60), q.TextContains("content", "delta")]
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = q.HybridQuery(filters=list(preds))
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert legacy.where == q.And(tuple(preds))
    res_a, _ = db.table().executor.execute(legacy)
    res_b, _ = db.table().executor.execute(q.HybridQuery(where=preds))
    assert [r.pk for r in res_a] == [r.pk for r in res_b]
    # flat-conjunction view still exposed for conjunctive queries...
    assert legacy.filters == list(preds)
    # ...but refuses to flatten a disjunction
    with pytest.raises(ValueError):
        q.HybridQuery(where=q.Or(*preds)).filters


# ---------------------------------------------------------------------------
# facade: subscriptions match the hand-wired ContinuousEngine
# ---------------------------------------------------------------------------

def test_subscribe_matches_continuous_engine():
    from benchmarks import tracy

    cfg = tracy.TracyConfig(n_rows=2000, dim=32, seed=5, flush_rows=512)

    # hand-wired: store + engine + register (the pre-facade three-object
    # setup on the tweet_analytics workload)
    store_a, data_a = tracy.build_store(cfg)
    eng = ContinuousEngine(store_a, mode="views",
                           view_budget_bytes=8 * 2**20)
    qv = data_a.query_vec()
    sync_id = eng.register(q.SyncQuery(q.HybridQuery(
        ranks=[q.VectorRank("embedding", qv, 1.0)], k=10), interval_s=60.0))
    async_id = eng.register(q.AsyncQuery(q.HybridQuery(
        where=q.Range("time", 900, 1000))))

    # facade: identical workload through Database/Table.subscribe
    store_b, data_b = tracy.build_store(cfg)
    db = Database(view_budget_bytes=8 * 2**20)
    t = db.adopt_store("tweets", store_b)
    sync_sub = (t.query().rank(q.VectorRank("embedding", qv, 1.0))
                .limit(10).subscribe(interval_s=60.0))
    async_sub = (t.query().where(q.Range("time", 900, 1000))
                 .subscribe(on_change=True))

    clock = 0.0
    for tick in range(3):
        out_a = eng.advance(clock)
        out_b = t.advance(clock)
        assert (sync_id in out_a) == (sync_sub.rid in out_b)
        if sync_id in out_a:
            assert [r.pk for r in out_a[sync_id]] == \
                [r.pk for r in out_b[sync_sub.rid]]
        if tick == 0:
            pks, batch = data_a.batch(64)
            batch["time"] = np.full(64, 950.0)
            store_a.put(pks, batch)
            pks_b, batch_b = data_b.batch(64)
            batch_b["time"] = batch["time"]
            batch_b["embedding"] = batch["embedding"]
            t.put(pks_b, batch_b)
        clock += 60.0

    fin_a = eng.registered[async_id].last_result
    fin_b = async_sub.latest
    assert sorted(r.pk for r in fin_a) == sorted(r.pk for r in fin_b)
    sync_sub.cancel()
    assert sync_sub.rid not in t.engine.registered


def test_database_multiple_tables(db_ref):
    db2 = Database(tweet_schema())
    t2 = db2.create_table("other", tweet_schema())
    rng = np.random.default_rng(1)
    pks, batch = make_batch(rng, 100)
    db2.table().put(pks, batch)
    t2.put(pks, batch)
    out = db2.execute_many([
        db2.table().query().where(q.Range("time", 0, 50)),
        t2.query().where(q.Range("time", 0, 50)),
    ])
    assert {r.pk for r in out[0][0]} == {r.pk for r in out[1][0]}
    with pytest.raises(KeyError):
        db2.table("missing")
