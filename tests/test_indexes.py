"""Unified secondary index framework: bitmaps, sorted access, pruning."""
import numpy as np
import pytest

from repro.core import query as q
from repro.core.index.base import MergedSortedAccess
from repro.core.index.spatial import morton_codes
from repro.core.index.text import tokenize


def _seg(small_store):
    store, _ = small_store
    return store.segments[0]


def test_scalar_bitmap_matches_column(small_store):
    seg = _seg(small_store)
    idx = seg.indexes["time"]
    pred = q.Range("time", 20.0, 40.0)
    mask = idx.bitmap(seg, pred)
    truth = (seg.columns["time"] >= 20.0) & (seg.columns["time"] <= 40.0)
    np.testing.assert_array_equal(mask, truth)
    sel = idx.selectivity(seg, pred)
    assert abs(sel - truth.mean()) < 1e-9


def test_spatial_bitmap_matches_column(small_store):
    seg = _seg(small_store)
    idx = seg.indexes["coordinate"]
    pred = q.GeoWithin("coordinate", (2.0, 3.0, 6.0, 7.0))
    mask = idx.bitmap(seg, pred)
    pts = seg.columns["coordinate"]
    truth = ((pts[:, 0] >= 2) & (pts[:, 0] <= 6)
             & (pts[:, 1] >= 3) & (pts[:, 1] <= 7))
    np.testing.assert_array_equal(mask, truth)


def test_text_bitmap_and_selectivity(small_store):
    seg = _seg(small_store)
    idx = seg.indexes["content"]
    pred = q.TextContains("content", "apple")
    mask = idx.bitmap(seg, pred)
    truth = np.asarray(["apple" in tokenize(t)
                        for t in seg.columns["content"]])
    np.testing.assert_array_equal(mask, truth)
    assert idx.selectivity(seg, pred) == pytest.approx(truth.mean())


def test_ivf_bitmap_high_recall(small_store):
    seg = _seg(small_store)
    idx = seg.indexes["embedding"]
    qv = np.asarray(seg.columns["embedding"][3], np.float32)
    d = np.sqrt(((seg.columns["embedding"] - qv) ** 2).sum(1))
    thresh = np.percentile(d, 2.0)
    pred = q.VectorRange("embedding", qv, float(thresh))
    mask = idx.bitmap(seg, pred)
    truth = d < thresh
    # IVF probes half the lists: recall high, precision exact
    assert (mask & ~truth).sum() == 0
    assert mask.sum() >= 0.6 * truth.sum()


def test_ivf_search_recall(small_store):
    seg = _seg(small_store)
    idx = seg.indexes["embedding"]
    qv = np.random.default_rng(0).normal(size=16).astype(np.float32)
    d, rows, blocks = idx.search(qv, 10)
    assert len(rows) == 10 and blocks > 0
    assert np.all(np.diff(d) >= -1e-6)
    exact = np.argsort(((seg.columns["embedding"] - qv) ** 2).sum(1))[:10]
    assert len(set(rows.tolist()) & set(exact.tolist())) >= 5


def test_ivf_sorted_access_is_globally_sorted(small_store):
    seg = _seg(small_store)
    idx = seg.indexes["embedding"]
    qv = np.random.default_rng(1).normal(size=16).astype(np.float32)
    it = idx.iterator(seg, qv)
    prev = -1.0
    seen = 0
    for d, rows in it:
        assert d[0] >= prev - 1e-5
        assert np.all(np.diff(d) >= -1e-5)
        prev = d[-1]
        seen += len(d)
    assert seen == seg.n_rows


def test_spatial_sorted_access_exact(small_store):
    seg = _seg(small_store)
    idx = seg.indexes["coordinate"]
    p = np.asarray([5.0, 5.0], np.float32)
    it = idx.iterator(seg, p)
    d_all, r_all = [], []
    for d, rows in it:
        d_all += d.tolist()
        r_all += rows.tolist()
    assert np.all(np.diff(d_all) >= -1e-6)
    exact = np.sqrt(((seg.columns["coordinate"] - p) ** 2).sum(1))
    np.testing.assert_allclose(sorted(d_all)[:20], np.sort(exact)[:20],
                               rtol=1e-5)


def test_merged_sorted_access_globally_sorted(small_store):
    store, _ = small_store
    qv = np.random.default_rng(2).normal(size=16).astype(np.float32)
    streams = [(s.seg_id, s.indexes["embedding"].iterator(s, qv))
               for s in store.segments]
    merged = MergedSortedAccess(streams)
    prev = -1.0
    total = 0
    for d, _ in merged:
        assert d[0] >= prev - 1e-5
        prev = d[-1]
        total += len(d)
    assert total == sum(s.n_rows for s in store.segments)


def test_global_index_prunes_segments(small_store):
    store, _ = small_store
    # a range outside every segment's zone map must prune everything
    pred = q.Range("time", 1e6, 2e6)
    pruned = store.global_index.prune(store.segments, pred)
    assert pruned == []
    pred2 = q.Range("time", 0.0, 100.0)
    assert len(store.global_index.prune(store.segments, pred2)) == \
        len(store.segments)


def test_morton_locality():
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 1, (512, 2)).astype(np.float32)
    z = morton_codes(pts, (0, 0, 1, 1))
    order = np.argsort(z)
    # consecutive points in z order are spatially close on average
    d = np.sqrt(((pts[order][1:] - pts[order][:-1]) ** 2).sum(1))
    rand_d = np.sqrt(((pts[1:] - pts[:-1]) ** 2).sum(1))
    assert d.mean() < 0.5 * rand_d.mean()


def test_ivf_probe_cost_prices_skewed_lists():
    """probe_cost_blocks must use the TRAINED list sizes: on a 90/10-
    skewed clustering the heaviest lists hold most rows, so the probe
    estimate has to exceed the balanced n_rows/n_lists guess."""
    from repro.core.index.ivf import IVFIndex
    from repro.core.types import BLOCK_ROWS

    rng = np.random.default_rng(0)
    dim = 8
    # one dominant mode with 90% of rows, the rest spread thin: k-means
    # leaves a handful of giant posting lists
    hot = rng.normal(0, 0.05, size=(2700, dim))
    cold = rng.normal(0, 8.0, size=(300, dim))
    vecs = np.concatenate([hot, cold]).astype(np.float32)

    class Seg:
        columns = {"embedding": vecs}
        n_rows = len(vecs)

    class Col:
        name = "embedding"
        dim = 8

    idx = IVFIndex(n_probe=4)
    idx.build(Seg(), Col())
    sizes = np.diff(idx.post_offsets)
    assert sizes.max() > 2 * sizes.mean()        # the skew took

    cost = idx.probe_cost_blocks(Seg(), None)
    balanced = 1.0 + idx.n_probe * max(
        1.0, len(vecs) / len(idx.centroids) / BLOCK_ROWS)
    top = np.sort(sizes)[::-1][:idx.n_probe]
    expected = 1.0 + float(np.maximum(top / BLOCK_ROWS, 1.0).sum())
    assert cost == pytest.approx(expected)
    assert cost > balanced
