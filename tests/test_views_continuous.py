"""Materialized views + continuous engines (paper §6, Fig. 5 semantics)."""
import numpy as np

from conftest import make_batch, tweet_schema
from repro.core import query as q
from repro.core.continuous import ContinuousEngine
from repro.core.executor import Executor
from repro.core.lsm import LSMConfig, LSMStore
from repro.core.views.selection import (build_candidates, cluster_spatial,
                                        knapsack_select)
from repro.core.views.view import SpatialRangeView, VectorNNView


def _store(rng, n=2000):
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=512))
    for i in range(0, n, 500):
        pks, batch = make_batch(rng, 500, pk_start=i)
        store.put(pks, batch)
    store.flush()
    return store


def test_spatial_view_incremental_equals_rebuild():
    rng = np.random.default_rng(0)
    store = _store(rng)
    eng = ContinuousEngine(store, mode="views", view_budget_bytes=2**22)
    decl = q.SyncQuery(q.HybridQuery(
        where=[q.GeoWithin("coordinate", (2, 2, 5, 5))]), 1.0)
    eng.register(decl)
    views = [v for v in eng.maintainer.views
             if isinstance(v, SpatialRangeView)]
    assert views
    v = views[0]
    before = set(v.rows)
    # incremental insert: a point inside and one outside
    pks, batch = make_batch(rng, 2, pk_start=50_000)
    batch["coordinate"] = np.asarray([[3.0, 3.0], [9.9, 9.9]], np.float32)
    store.put(pks, batch)
    assert 50_000 in v.rows and 50_001 not in v.rows
    # delete removes
    store.delete([50_000])
    assert 50_000 not in v.rows
    assert set(v.rows) == before


def test_vector_view_contains_true_topxk():
    rng = np.random.default_rng(1)
    store = _store(rng)
    qv = rng.normal(size=16).astype(np.float32)
    eng = ContinuousEngine(store, mode="views", view_budget_bytes=2**22)
    eng.register(q.SyncQuery(q.HybridQuery(
        ranks=[q.VectorRank("embedding", qv, 1.0)], k=10), 1.0))
    v = [v for v in eng.maintainer.views if isinstance(v, VectorNNView)][0]
    vecs = np.concatenate([s.columns["embedding"] for s in store.segments])
    pks = np.concatenate([s.pk for s in store.segments])
    d = np.sqrt(((vecs - v.center) ** 2).sum(1))
    want = set(pks[np.argsort(d)[:v.xk]].tolist())
    got = set(pk for _, pk, _ in v.cand)
    assert len(got & want) == v.xk


def test_view_results_match_exact_executor():
    rng = np.random.default_rng(2)
    store = _store(rng)
    qv = rng.normal(size=16).astype(np.float32)
    decl = q.SyncQuery(q.HybridQuery(
        ranks=[q.VectorRank("embedding", qv, 1.0)], k=10), 1.0)
    eng = ContinuousEngine(store, mode="views", view_budget_bytes=2**22)
    rid = eng.register(decl)
    res = eng.advance(0.0)[rid]
    exact, _ = Executor(store).execute(decl.query)
    assert [r.pk for r in res] == [r.pk for r in exact]


def test_view_freshness_after_writes():
    """Continuous queries must reflect new data immediately (the paper's
    data-freshness claim vs Napa-style deferred views)."""
    rng = np.random.default_rng(3)
    store = _store(rng)
    qv = rng.normal(size=16).astype(np.float32)
    decl = q.SyncQuery(q.HybridQuery(
        ranks=[q.VectorRank("embedding", qv, 1.0)], k=5), 1.0)
    eng = ContinuousEngine(store, mode="views", view_budget_bytes=2**22)
    rid = eng.register(decl)
    eng.advance(0.0)
    # insert an exact-match row: must become the new top-1 next tick
    pks, batch = make_batch(rng, 1, pk_start=77_777)
    batch["embedding"] = qv[None, :].copy()
    store.put(pks, batch)
    res = eng.advance(1.0)[rid]
    assert res[0].pk == 77_777 and res[0].score < 1e-3


def test_async_query_triggers_on_write_only():
    rng = np.random.default_rng(4)
    store = _store(rng)
    decl = q.AsyncQuery(q.HybridQuery(
        where=[q.Range("time", 0, 100)]))
    eng = ContinuousEngine(store, mode="none")
    rid = eng.register(decl)
    out = eng.advance(0.0)
    assert rid in out                      # initial run (dirty at reg)
    out = eng.advance(1.0)
    assert rid not in out                  # no data change -> no rerun
    pks, batch = make_batch(rng, 1, pk_start=88_888)
    store.put(pks, batch)
    out = eng.advance(2.0)
    assert rid in out                      # write -> rerun


def test_sync_interval_schedule():
    rng = np.random.default_rng(5)
    store = _store(rng, n=500)
    decl = q.SyncQuery(q.HybridQuery(where=[q.Range("time", 0, 10)]),
                       interval_s=10.0)
    eng = ContinuousEngine(store, mode="none")
    rid = eng.register(decl)
    runs = 0
    for t in range(0, 35, 5):
        if rid in eng.advance(float(t)):
            runs += 1
    assert runs == 4   # t=0,10,20,30


def test_knapsack_respects_budget():
    rng = np.random.default_rng(6)
    store = _store(rng)
    # disjoint rects -> one view candidate per query cluster
    queries = [q.HybridQuery(where=[q.GeoWithin(
        "coordinate", (3 * i, 3 * i, 3 * i + 2, 3 * i + 2))])
        for i in range(3)]
    cands = build_candidates(store, queries)
    assert len(cands) >= 2
    budget = sum(c.bytes_est for c in cands) / 2
    chosen = knapsack_select(cands, budget)
    assert sum(c.bytes_est for c in chosen) <= budget
    assert chosen   # picks something


def test_cluster_spatial_unions_overlaps():
    rects = [(0, 0, 2, 2), (1, 1, 3, 3), (8, 8, 9, 9)]
    clusters = cluster_spatial(rects)
    assert len(clusters) == 2
    big = max(clusters, key=lambda c: c[1])
    assert big[0] == (0, 0, 3, 3) and big[1] == 2


def test_engine_modes_speed_ordering():
    """views (ARCADE+S) <= fcache (ARCADE+F) <= none — Fig. 5's ordering."""
    import time
    rng = np.random.default_rng(7)
    qv = rng.normal(size=16).astype(np.float32)
    decls = [q.SyncQuery(q.HybridQuery(
        ranks=[q.VectorRank("embedding",
                            qv + rng.normal(size=16).astype(np.float32) * .05,
                            1.0)], k=10), 1.0) for _ in range(5)]
    times = {}
    for mode in ("none", "views"):
        # best-of-3: scheduler noise on a loaded machine dwarfs the
        # single-digit-ms advance loop; min is the robust statistic
        best = float("inf")
        for _ in range(3):
            store = _store(np.random.default_rng(7))
            eng = ContinuousEngine(store, mode=mode,
                                   view_budget_bytes=2**23)
            for d in decls:
                eng.register(d)
            t0 = time.perf_counter()
            for t in range(4):
                eng.advance(float(t))
            best = min(best, time.perf_counter() - t0)
        times[mode] = best
    assert times["views"] < times["none"]
