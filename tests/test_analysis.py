"""Tests for ``repro.analysis``: the fixture corpus (each rule fires
exactly once on its known-bad mini-root), the clean-tree gate (HEAD has
zero unbaselined findings), and the runtime plan validator (every TRACY
template validates; hand-broken plans raise).
"""
from pathlib import Path

import numpy as np
import pytest

from benchmarks import tracy
from repro.analysis import RepoModel, all_rules, run_rules
from repro.analysis.findings import load_baseline, split_baselined
from repro.analysis.plan_validator import (
    PlanContractError, maybe_validate, validate_plan)
from repro.core import query as q
from repro.core.executor import Executor
from repro.core.optimizer import planner as planner_lib
from repro.kernels import fused_scan as fs_kernel

REPO_ROOT = Path(__file__).resolve().parents[1]
FIXTURES = REPO_ROOT / "tests" / "fixtures" / "analysis"

# fixture mini-root -> the one rule it must trigger exactly once
CORPUS = [
    ("raw-score-sort", "parity/raw-score-sort"),
    ("sqrt-compare", "parity/sqrt-compare"),
    ("twin-kernel", "parity/twin-kernel"),
    ("pallas-ci-sweep", "parity/pallas-ci-sweep"),
    ("worker-unlocked-write", "locks/worker-unlocked-write"),
    ("global-mutable-cache", "locks/global-mutable-cache"),
    ("tile-constants", "kernel/tile-constants"),
    ("pallas-call-contract", "kernel/pallas-call-contract"),
    ("grid-divisibility-guard", "kernel/grid-divisibility-guard"),
    ("kind-dispatch", "plan/kind-dispatch"),
    ("neighbor-pad-guard", "graph/neighbor-pad-guard"),
    ("fsync-before-publish", "durability/fsync-before-publish"),
    ("obs-span-closed", "obs/span-closed"),
    ("obs-wall-clock-timing", "obs/wall-clock-timing"),
    # one known-bad graph kernel, two existing contracts it breaks
    ("graph-bad-kernel", "parity/twin-kernel"),
    ("graph-bad-kernel", "parity/raw-score-sort"),
]


def test_registry_has_all_families():
    rules = all_rules()
    assert len(rules) >= 10
    families = {r.family for r in rules.values()}
    assert {"parity", "locks", "kernel", "plan", "graph",
            "durability", "obs"} <= families


@pytest.mark.parametrize("fixture,rule_id", CORPUS,
                         ids=[c[0] for c in CORPUS])
def test_fixture_fires_exactly_once(fixture, rule_id):
    root = FIXTURES / fixture
    assert root.is_dir(), f"missing fixture corpus {root}"
    model = RepoModel(root)
    findings = run_rules(model, ids=[rule_id])
    assert [f.rule for f in findings] == [rule_id], (
        f"{fixture}: expected exactly one {rule_id} finding, got "
        f"{[(f.rule, f.path, f.line, f.message) for f in findings]}")


def test_clean_tree_at_head():
    """The gate CI enforces: zero unbaselined findings on the real tree."""
    model = RepoModel(REPO_ROOT)
    findings = run_rules(model)
    baseline = load_baseline(REPO_ROOT / "analysis_baseline.json")
    new = split_baselined(findings, baseline)
    assert not new, "unbaselined findings at HEAD:\n" + "\n".join(
        f"{f.path}:{f.line}: [{f.rule}] {f.message}" for f in new)


def test_allow_comment_suppresses(tmp_path):
    core = tmp_path / "core"
    core.mkdir()
    (core / "ranker.py").write_text(
        "import numpy as np\n\n\n"
        "def rank(dists):\n"
        "    # analysis: allow[parity/raw-score-sort] fixture reason,\n"
        "    # continued over a second comment line\n"
        "    return np.argsort(dists)\n")
    model = RepoModel(tmp_path)
    assert run_rules(model, ids=["parity/raw-score-sort"]) == []


# ---------------------------------------------------------------------------
# runtime plan validation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tracy_ex():
    cfg = tracy.TracyConfig(n_rows=1500, dim=32, seed=11, flush_rows=400)
    store, data = tracy.build_store(cfg)
    pks, batch = data.batch(32)     # live memtable rows on top
    store.put(pks, batch)
    return Executor(store), data


def test_validate_plan_all_tracy_templates(tracy_ex):
    ex, data = tracy_ex
    search, nn = tracy.make_templates(data)
    for ti, tmpl in enumerate(search + nn):
        data.rng = np.random.default_rng(100 + ti)
        for _ in range(3):
            plan = planner_lib.plan(ex.catalog, tmpl())
            validate_plan(plan)    # must not raise
    qq = q.HybridQuery(ranks=[q.VectorRank(
        "embedding", data.query_vec(), 1.0)], k=10)
    validate_plan(planner_lib.plan_shared_scan(ex.catalog, qq))


def _problems(plan):
    with pytest.raises(PlanContractError) as ei:
        validate_plan(plan)
    return "\n".join(ei.value.problems)


def test_validate_plan_rejects_unknown_kind():
    assert "unknown plan kind" in _problems(
        planner_lib.Plan(kind="ghost_kind"))


def test_validate_plan_rejects_fused_over_budget(tracy_ex):
    ex, data = tracy_ex
    kmax = int(fs_kernel.KMAX)
    rank = q.VectorRank("embedding", data.query_vec(), 1.0)
    plan = planner_lib.Plan(kind="full_scan_nn", ranks=[rank],
                            k=kmax + 1, fused=True)
    assert f"outside (0, KMAX={kmax}]" in _problems(plan)


def test_validate_plan_rejects_fused_on_search_kind():
    plan = planner_lib.Plan(kind="full_scan", fused=True)
    assert "no scan->top-k to fuse" in _problems(plan)


def test_validate_plan_rejects_quantized_refine_overflow(tracy_ex):
    ex, data = tracy_ex
    kmax = int(fs_kernel.KMAX)
    rank = q.VectorRank("embedding", data.query_vec(), 1.0)
    plan = planner_lib.Plan(kind="full_scan_nn", ranks=[rank], k=kmax // 2,
                            fused=True, quantized=True, pq_m=8, refine=4)
    assert f"exceeds KMAX={kmax}" in _problems(plan)


def test_validate_plan_rejects_graph_contract_breaks(tracy_ex):
    ex, data = tracy_ex
    kmax = int(fs_kernel.KMAX)
    rank = q.VectorRank("embedding", data.query_vec(), 1.0)
    base = dict(kind="full_scan_nn", ranks=[rank], k=10)
    # beam below k: the survivors cannot cover the result set
    plan = planner_lib.Plan(graph=True, graph_r=16, graph_beam=4,
                            graph_hops=8, **base)
    assert "beam" in _problems(plan)
    # beam above KMAX
    plan = planner_lib.Plan(graph=True, graph_r=16, graph_beam=kmax + 8,
                            graph_hops=8, **base)
    assert "beam" in _problems(plan)
    # zero hops never leaves the entry points
    plan = planner_lib.Plan(graph=True, graph_r=16, graph_beam=40,
                            graph_hops=0, **base)
    assert "entry points" in _problems(plan)
    # graph + quantized are mutually exclusive dispatches
    plan = planner_lib.Plan(graph=True, graph_r=16, graph_beam=40,
                            graph_hops=8, quantized=True, pq_m=8,
                            refine=2, **base)
    assert "graph and quantized" in _problems(plan)


def test_validate_plan_rejects_union_without_subplans():
    assert "no subplans" in _problems(planner_lib.Plan(kind="union"))


def test_validate_plan_rejects_double_charged_predicate():
    pred = q.Range("time", 0.0, 1.0)
    plan = planner_lib.Plan(kind="full_scan", indexed=[pred],
                            residual=[pred])
    assert "both indexed and residual" in _problems(plan)


def test_maybe_validate_env_gated(monkeypatch):
    bad = planner_lib.Plan(kind="ghost_kind")
    monkeypatch.delenv("REPRO_VALIDATE_PLANS", raising=False)
    assert maybe_validate(bad) is bad          # off: pass-through
    monkeypatch.setenv("REPRO_VALIDATE_PLANS", "0")
    assert maybe_validate(bad) is bad
    monkeypatch.setenv("REPRO_VALIDATE_PLANS", "1")
    with pytest.raises(PlanContractError):
        maybe_validate(bad)


def test_planner_validates_under_env(tracy_ex, monkeypatch):
    """End-to-end: the planner hook validates every emitted plan."""
    ex, data = tracy_ex
    monkeypatch.setenv("REPRO_VALIDATE_PLANS", "1")
    search, nn = tracy.make_templates(data)
    data.rng = np.random.default_rng(7)
    for tmpl in (search[0], nn[0]):
        res, stats = ex.execute(tmpl())
        assert stats.plan
