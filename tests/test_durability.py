"""Crash-recovery matrix for the durability subsystem (WAL + manifest +
persistent segments).

Every cell of the matrix kills a store at one named crash point
(``core/faults.CRASH_POINTS``) under one flush mode, recovers from disk,
and asserts three things against an uncrashed in-memory twin fed the
same row prefix:

  * recovery is a *prefix*: the recovered pk set is exactly
    ``arange(n_recovered)`` — no holes, no phantoms;
  * *no acknowledged write is lost*: every seqno the store acknowledged
    (``durable_seqno`` after a successful put) survives recovery;
  * *bitwise result parity*: the TRACY templates (exact, fused,
    quantized and graph dispatches) return identical ``(pk, score)``
    lists on the recovered store and the twin — the difference-form
    scoring + (score, pk) tie-break parity contract holds across the
    divergent segment layouts recovery produces.

Under ``REPRO_USE_PALLAS=1`` (the CI interpret-mode sweep) the matrix is
reduced at collection time to the inline flush mode and a crash-point
subset, because interpreted kernels are ~100x slower.
"""
import os
import tempfile
import time

import numpy as np
import pytest

from benchmarks import tracy
from repro.core import query as q
from repro.core import wal as wal_lib
from repro.core.api import Database, LSMConfig
from repro.core.executor import Executor
from repro.core.faults import CRASH_POINTS, FaultInjector, InjectedCrash
from repro.core.lsm import LSMStore
from repro.core.shards import ShardedExecutor, ShardRouter
from repro.core.types import IndexKind

PALLAS = os.environ.get("REPRO_USE_PALLAS") == "1"
MODES = ("inline",) if PALLAS else ("inline", "pipelined", "background")
MATRIX_POINTS = CRASH_POINTS if not PALLAS else (
    "wal.commit", "flush.before-publish", "manifest.publish",
    "compact.after-publish")

DIM = 16
N_ROWS = 700
BATCH = 70
STORE_KW = dict(flush_rows=150, fanout=3, pq_m=4)
TRACY_CFG = tracy.TracyConfig(n_rows=N_ROWS, dim=DIM, **STORE_KW)

# worker-side points only occur once (or need compaction) in this
# workload; writer-side points get after=1 so the crash lands mid-run
AFTER = {p: (0 if p.startswith("compact.") else 1) for p in CRASH_POINTS}


def _cfg(path=None, mode="inline", quantize=True):
    kw = dict(STORE_KW, quantize_vectors=quantize, path=path)
    if mode == "pipelined":
        kw.update(pipeline=True, max_sealed=2)
    elif mode == "background":
        # huge stall threshold: the writer must never block waiting for
        # a worker the injected crash already killed
        kw.update(pipeline=True, background=True, max_sealed=1000)
    return LSMConfig(**kw)


def _key(rows):
    return [(r.pk, float(r.score)) for r in rows]


def _ingest_until_crash(store, inj, total=N_ROWS, batch=BATCH):
    """Drive TRACY writes until the injector fires (writer-side points
    raise out of ``put``; worker-side points in background mode are
    polled via ``inj.crashed``).  Returns the batches fed and the last
    acknowledgment frontier observed after a *successful* put."""
    data = tracy.TracyData(TRACY_CFG)
    batches, acked, done = [], -1, 0
    try:
        while done < total:
            pks, cols = data.batch(min(batch, total - done))
            batches.append((np.asarray(pks, np.int64), dict(cols)))
            store.put(pks, cols)
            done += len(pks)
            acked = store.durable_seqno
        # pipelined mode defers flush/compaction work: run the queue dry
        # (no seal — the partial memtable must look like the twin's) so
        # worker-side crash points are reached deterministically.  The
        # background worker drains on its own, and waiting on one the
        # crash already killed would hang.
        if not store.cfg.background and not inj.crashed:
            store.drain()
    except InjectedCrash:
        return batches, acked
    deadline = time.time() + 30.0
    while not inj.crashed and time.time() < deadline:
        time.sleep(0.01)
    return batches, acked


def _twin(schema, batches, n_rows, quantize=True):
    """Uncrashed in-memory twin: same rows, same batch boundaries,
    truncated to the recovered prefix."""
    twin = LSMStore(schema, _cfg(quantize=quantize))
    fed = 0
    for pks, cols in batches:
        take = min(len(pks), n_rows - fed)
        if take <= 0:
            break
        twin.put(pks[:take], {k: v[:take] for k, v in cols.items()})
        fed += take
    assert fed == n_rows
    return twin


def _parity_queries(quantized=True):
    """Materialized TRACY query objects (template thunks draw from a
    stateful rng; building them once keeps both sides identical)."""
    d = tracy.TracyData(TRACY_CFG)
    search, nn = tracy.make_templates(d)
    qs = [t() for t in search + nn]
    if quantized:
        # opt into the approximate dispatch so the quantized ADC path
        # (or its exact fallback pricing) runs on both sides
        qs += [q.HybridQuery(
            ranks=[q.VectorRank("embedding", d.query_vec(), 1.0)],
            k=10, recall_target=0.9) for _ in range(3)]
    return qs


def _assert_recovery(schema, path, batches, acked, quantize=True,
                     queries=None):
    rec = LSMStore(schema, _cfg(path=path, quantize=quantize))
    n_rec = rec._seqno
    # no acknowledged write lost
    assert n_rec > acked, f"lost acked rows: recovered {n_rec}, " \
        f"acked through seqno {acked}"
    # recovery is a prefix: every pk < n_rec exactly once
    pks = np.concatenate([rec.memtable_arrays()[0]]
                         + [s.pk for s in rec.segments])
    assert np.array_equal(np.sort(pks), np.arange(n_rec))
    twin = _twin(schema, batches, n_rec, quantize=quantize)
    ex_rec, ex_twin = Executor(rec), Executor(twin)
    for hq in (queries if queries is not None
               else _parity_queries(quantized=quantize)):
        a, _ = ex_rec.execute(hq)
        b, _ = ex_twin.execute(hq)
        assert _key(a) == _key(b), f"parity break on {hq}"
    return rec


# ---------------------------------------------------------------------------
# the matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("point", MATRIX_POINTS)
def test_crash_matrix(point, mode, tmp_path):
    schema = tracy.tweet_schema(DIM)
    store = LSMStore(schema, _cfg(path=str(tmp_path), mode=mode))
    inj = FaultInjector().arm(point, after=AFTER[point])
    store.set_faults(inj)
    batches, acked = _ingest_until_crash(store, inj)
    assert inj.fired == point
    _assert_recovery(schema, str(tmp_path), batches, acked)


@pytest.mark.parametrize("point", (
    "wal.commit", "flush.before-publish") if PALLAS else (
    "wal.append", "wal.commit", "flush.segment-file",
    "flush.before-publish", "manifest.publish", "compact.after-publish"))
def test_crash_matrix_graph(point, tmp_path):
    """Graph-index variant: CSR segment graphs must recover to the same
    beam-search results (quantization off, so the planner prices the
    graph dispatch)."""
    schema = tracy.tweet_schema(DIM, IndexKind.GRAPH)
    store = LSMStore(schema, _cfg(path=str(tmp_path), quantize=False))
    inj = FaultInjector().arm(point, after=AFTER[point])
    store.set_faults(inj)
    batches, acked = _ingest_until_crash(store, inj)
    assert inj.fired == point
    d = tracy.TracyData(TRACY_CFG)
    queries = [t() for _, t in tracy.make_graph_templates(
        d, recall_target=0.9)]
    queries += [t() for _, t in tracy.make_graph_templates(
        d, recall_target=None)]      # exact twins of the same draws
    _assert_recovery(schema, str(tmp_path), batches, acked,
                     quantize=False, queries=queries)


@pytest.mark.parametrize("point", (
    "wal.commit", "manifest.publish") if PALLAS else (
    "wal.append", "wal.commit", "flush.before-publish",
    "manifest.publish"))
def test_crash_matrix_sharded(point, tmp_path):
    """4-shard router with the injector on shard 0 only: the other
    shards keep acknowledging; recovery loses at most shard 0's
    unacknowledged tail and the scatter-gather merge stays bitwise."""
    schema = tracy.tweet_schema(DIM)
    router = ShardRouter(schema, _cfg(path=str(tmp_path)), n_shards=4)
    # shard 0 sees only ~1/4 of the rows: one flush, few commits — arm
    # on the first occurrence (second for appends, so some rows land)
    inj = FaultInjector().arm(point, after=1 if point == "wal.append" else 0)
    router.set_faults(inj, shard=0)
    data = tracy.TracyData(TRACY_CFG)
    batches, acked0 = [], -1
    try:
        for _ in range(N_ROWS // BATCH):
            pks, cols = data.batch(BATCH)
            batches.append((np.asarray(pks, np.int64), dict(cols)))
            router.put(pks, cols)
            acked0 = router.durable_seqnos()[0]
    except InjectedCrash:
        pass
    assert inj.fired == point

    rec = ShardRouter(schema, _cfg(path=str(tmp_path)), n_shards=4)
    assert rec.shards[0]._seqno > acked0
    # global survivor set: shard 0's recovered prefix + everything the
    # healthy shards hold
    alive = set()
    for sh in rec.shards:
        alive.update(int(p) for p in sh.memtable_arrays()[0])
        for s in sh.segments:
            alive.update(int(p) for p in s.pk)
    # twin: an in-memory router fed only the surviving rows, in order
    twin = ShardRouter(schema, _cfg(), n_shards=4)
    for pks, cols in batches:
        mask = np.isin(pks, np.fromiter(alive, np.int64, len(alive)))
        if mask.any():
            twin.put(pks[mask], {k: v[mask] for k, v in cols.items()})
    ex_rec, ex_twin = ShardedExecutor(rec), ShardedExecutor(twin)
    for hq in _parity_queries()[:8]:
        a, _ = ex_rec.execute(hq)
        b, _ = ex_twin.execute(hq)
        assert _key(a) == _key(b)


# ---------------------------------------------------------------------------
# WAL codec robustness (deterministic; the hypothesis fuzz lives in
# test_wal_property.py and skips when hypothesis is absent)
# ---------------------------------------------------------------------------

def _sample_records():
    rng = np.random.default_rng(5)
    recs = []
    for i in range(4):
        n = 3 + i
        recs.append((wal_lib.REC_PUT, 10 * i, np.arange(n, dtype=np.int64),
                     {"embedding": rng.normal(size=(n, 4)).astype(np.float32),
                      "time": rng.uniform(0, 9, n),
                      "content": np.asarray(
                          [f"tok{j} x" for j in range(n)], object)}))
    recs.append((wal_lib.REC_DELETE, 40,
                 np.asarray([1, 3], np.int64), {}))
    return recs


def test_wal_codec_roundtrip():
    recs = _sample_records()
    blob = b"".join(wal_lib.encode_record(*r) for r in recs)
    out, good = wal_lib.read_records(blob)
    assert good == len(blob) and len(out) == len(recs)
    for (rt, s, pks, batch), dec in zip(recs, out):
        assert (dec.rtype, dec.seqno_start) == (rt, s)
        assert np.array_equal(dec.pks, pks)
        assert sorted(dec.batch) == sorted(batch)
        for name in batch:
            assert np.array_equal(dec.batch[name], batch[name])


def test_wal_codec_truncation_always_clean():
    """Cutting the log at ANY byte yields a clean prefix stop — never an
    exception, never a half-applied record."""
    recs = _sample_records()
    encoded = [wal_lib.encode_record(*r) for r in recs]
    blob = b"".join(encoded)
    ends = np.cumsum([len(e) for e in encoded]).tolist()
    for cut in range(len(blob) + 1):
        out, good = wal_lib.read_records(blob[:cut])
        n_complete = sum(1 for e in ends if e <= cut)
        assert len(out) == n_complete
        assert good == (ends[n_complete - 1] if n_complete else 0)


def test_wal_codec_bitflip_stops_at_corruption():
    """Flipping any single byte corrupts exactly one record's crc: every
    record before it still decodes, nothing at or after it does."""
    recs = _sample_records()
    encoded = [wal_lib.encode_record(*r) for r in recs]
    blob = bytearray(b"".join(encoded))
    starts = np.concatenate([[0], np.cumsum([len(e) for e in encoded])])
    for pos in range(0, len(blob), 7):   # stride keeps runtime sane
        corrupt = bytes(blob[:pos]) + bytes([blob[pos] ^ 0xFF]) \
            + bytes(blob[pos + 1:])
        out, good = wal_lib.read_records(corrupt)
        victim = int(np.searchsorted(starts, pos, side="right")) - 1
        assert len(out) <= victim
        assert good <= int(starts[victim])


# ---------------------------------------------------------------------------
# lifecycle: close / context manager / snapshot / restore
# ---------------------------------------------------------------------------

def _small_db(path, shards=1):
    schema = tracy.tweet_schema(DIM)
    db = Database(schema, LSMConfig(**STORE_KW), path=path, shards=shards)
    data = tracy.TracyData(TRACY_CFG)
    for _ in range(4):
        pks, cols = data.batch(100)
        db.table().put(pks, cols)
    return db


def test_close_idempotent_and_context_manager(tmp_path):
    d = str(tmp_path / "db")
    with _small_db(d) as db:
        v = tracy.TracyData(TRACY_CFG).query_vec()
        hq = q.HybridQuery(
            ranks=[q.VectorRank("embedding", v, 1.0)], k=10)
        before = _key(db.table().execute(hq)[0])
    db.close()   # second close after __exit__: must be a no-op
    db.close()
    reopened = Database(path=d)
    assert _key(reopened.table().execute(hq)[0]) == before
    assert reopened.table().n_rows == 400
    reopened.close()


def test_database_reopen_rejects_schema(tmp_path):
    d = str(tmp_path / "db")
    _small_db(d).close()
    with pytest.raises(ValueError):
        Database(tracy.tweet_schema(DIM), path=d)
    with pytest.raises(FileNotFoundError):
        Database.restore(str(tmp_path / "nope"))


def test_snapshot_restore_parity(tmp_path):
    d, s = str(tmp_path / "db"), str(tmp_path / "snap")
    db = _small_db(d, shards=2)
    v = tracy.TracyData(TRACY_CFG).query_vec()
    hq = q.HybridQuery(ranks=[q.VectorRank("embedding", v, 1.0)], k=10)
    before = _key(db.table().execute(hq)[0])
    db.snapshot(s)
    db.close()
    restored = Database.restore(s)
    t = restored.table()
    assert t.n_shards == 2 and t.n_rows == 400
    assert _key(t.execute(hq)[0]) == before
    # the restored database keeps journaling into the snapshot dir
    pks, cols = tracy.TracyData(TRACY_CFG).batch(50)
    t.put(np.asarray(pks, np.int64) + 400, cols)
    restored.close()
    again = Database(path=s)
    assert again.table().n_rows == 450
    again.close()
