"""Sharded serving subsystem tests (core/shards).

The load-bearing claim: a hash-partitioned N-shard database is
OBSERVATIONALLY IDENTICAL to the single-store engine — bitwise-equal
(pk, score) results across plan kinds (fused and staged dispatch,
disjunctions, forced full scans), under interleaved put/update/delete
visibility, with live memtable overlays, and through continuous
subscriptions — while the cross-shard combine handles at most
shards * k rows per query.  Satellite coverage: the generalized batched
top-k merge kernel, the vectorized ``distributed.store_shards`` packing,
and the k > shard-rows clamp in the scatter-gather demo path.
"""
import numpy as np
import pytest

from repro.core import query as q
from repro.core.api import Database
from repro.core.lsm import LSMConfig
from repro.core.optimizer import planner as planner_lib
from repro.core.shards import ShardRouter, hash_pks
from repro.kernels import ops as kops
from tests.conftest import make_batch, tweet_schema

N_SHARDS = 4
DIM = 16


def _pair(seed=0, n=900, chunk=150, shards=N_SHARDS, **db_kw):
    """(single-store table, sharded table) fed the exact same batches."""
    tables = []
    for n_shards in (1, shards):
        rng = np.random.default_rng(seed)
        db = Database(tweet_schema(DIM), LSMConfig(flush_rows=chunk),
                      shards=n_shards, **db_kw)
        t = db.table()
        for start in range(0, n, chunk):
            pks, batch = make_batch(rng, chunk, dim=DIM, pk_start=start)
            t.put(pks, batch)
        tables.append(t)
    return tables[0], tables[1]


@pytest.fixture(scope="module")
def pair():
    t1, tn = _pair()
    t1.flush()
    tn.flush()
    return t1, tn


def _res(rows):
    return [(r.pk, r.score) for r in rows]


def _queries(rng):
    qv = rng.normal(size=DIM).astype(np.float32)
    qv2 = rng.normal(size=DIM).astype(np.float32)
    return [
        # filter-only: scalar + spatial, text, disjunction, negation
        q.HybridQuery(where=q.And(q.Range("time", 10, 55),
                                  q.GeoWithin("coordinate",
                                              (1.0, 1.0, 7.0, 7.0)))),
        q.HybridQuery(where=q.TextContains("content", "banana")),
        q.HybridQuery(where=q.Or(q.Range("time", 0, 15),
                                 q.TextContains("content", "cherry"))),
        q.HybridQuery(where=q.And(q.Range("time", 5, 80),
                                  q.Not(q.TextContains("content",
                                                       "apple")))),
        # NN: pure vector, vector+spatial, filtered, text-ranked,
        # disjunctive-filtered
        q.HybridQuery(ranks=[q.VectorRank("embedding", qv, 1.0)], k=10),
        q.HybridQuery(ranks=[q.VectorRank("embedding", qv, 0.6),
                             q.SpatialRank("coordinate", (5.0, 5.0), 0.4)],
                      k=10),
        q.HybridQuery(where=q.Range("time", 10, 70),
                      ranks=[q.VectorRank("embedding", qv2, 1.0)], k=10),
        q.HybridQuery(ranks=[q.VectorRank("embedding", qv, 1.0),
                             q.TextRank("content", ("banana", "echo"),
                                        0.5)], k=10),
        q.HybridQuery(where=q.Or(q.Range("time", 0, 30),
                                 q.TextContains("content", "golf")),
                      ranks=[q.VectorRank("embedding", qv, 1.0)], k=10),
    ]


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def test_routing_deterministic_and_complete(pair):
    t1, tn = pair
    router = tn.store
    assert isinstance(router, ShardRouter)
    pks = np.arange(900)
    sid = router.shard_of(pks)
    assert np.array_equal(sid, router.shard_of(pks))       # stable
    assert set(np.unique(sid)) <= set(range(N_SHARDS))
    # hash-balanced: every shard owns a non-trivial slice
    counts = np.bincount(sid, minlength=N_SHARDS)
    assert counts.min() > 900 // N_SHARDS // 2
    assert sum(router.shard_rows()) == t1.store.n_rows == 900
    # a pk's row lives on exactly its hash shard and get() finds it
    for pk in (0, 17, 501, 899):
        row1, rown = t1.get(pk), tn.get(pk)
        assert rown is not None
        assert row1["time"] == rown["time"]


def test_hash_decorrelates_sequential_pks():
    h = hash_pks(np.arange(1024))
    assert len(np.unique(h)) == 1024
    counts = np.bincount((h % np.uint64(8)).astype(int), minlength=8)
    assert counts.min() > 1024 // 8 // 2


# ---------------------------------------------------------------------------
# parity across plan kinds
# ---------------------------------------------------------------------------

def test_parity_planner_chosen(pair):
    t1, tn = pair
    for qq in _queries(np.random.default_rng(1)):
        r1, _ = t1.executor.execute(qq)
        rn, st = tn.executor.execute(qq)
        assert _res(r1) == _res(rn), st.plan.splitlines()[0]
        assert st.shards == N_SHARDS


def test_parity_fused_vs_staged(pair):
    t1, tn = pair
    rng = np.random.default_rng(2)
    nn = [qq for qq in _queries(rng) if qq.is_nn]
    prev = planner_lib.FUSED_ENABLED
    try:
        out = {}
        for mode in (True, False):
            planner_lib.FUSED_ENABLED = mode
            # batches of identical structure reach the fused/shared path
            out[mode] = [
                [_res(r) for r, _ in t.executor.execute_many([qq] * 6)]
                for t in (t1, tn) for qq in nn]
        assert out[True] == out[False]
    finally:
        planner_lib.FUSED_ENABLED = prev


def test_parity_forced_full_scan(pair):
    t1, tn = pair
    rng = np.random.default_rng(3)
    for qq in _queries(rng):
        kind = "full_scan_nn" if qq.is_nn else "full_scan"
        mk = lambda: planner_lib.Plan(          # noqa: E731
            kind=kind, residual=[qq.where] if qq.where else [],
            ranks=list(qq.ranks), k=qq.k)
        r1, _ = t1.executor.execute(qq, plan=mk())
        rn, _ = tn.executor.execute(qq, plan=mk())
        assert _res(r1) == _res(rn)


def test_parity_execute_many_mixed(pair):
    t1, tn = pair
    batch = _queries(np.random.default_rng(4))
    res1 = t1.executor.execute_many(batch)
    resn = tn.executor.execute_many(batch)
    for (r1, _), (rn, _) in zip(res1, resn):
        assert _res(r1) == _res(rn)


def test_parity_shard_counts():
    t1, _ = _pair(seed=5, n=600)
    t1.flush()
    for shards in (2, 8):
        _, tn = _pair(seed=5, n=600, shards=shards)
        tn.flush()
        for qq in _queries(np.random.default_rng(6)):
            r1, _ = t1.executor.execute(qq)
            rn, _ = tn.executor.execute(qq)
            assert _res(r1) == _res(rn), shards


def test_unsatisfiable_and_empty(pair):
    _, tn = pair
    p = q.Range("time", 0, 50)
    rows, st = tn.executor.execute(
        q.HybridQuery(where=q.And(p, q.Not(p))))
    assert rows == [] and "empty" in st.plan
    # empty sharded table
    t_empty = Database(tweet_schema(DIM), shards=3).table()
    rows, _ = t_empty.executor.execute(
        q.HybridQuery(ranks=[q.VectorRank(
            "embedding", np.zeros(DIM, np.float32), 1.0)], k=5))
    assert rows == []


def test_k_exceeds_total_rows():
    t1, tn = _pair(seed=7, n=60, chunk=30)
    t1.flush()
    tn.flush()
    qq = q.HybridQuery(ranks=[q.VectorRank(
        "embedding", np.ones(DIM, np.float32), 1.0)], k=200)
    r1, _ = t1.executor.execute(qq)
    rn, _ = tn.executor.execute(qq)
    assert len(rn) == 60 and _res(r1) == _res(rn)


# ---------------------------------------------------------------------------
# MVCC visibility across shards, live memtable overlay
# ---------------------------------------------------------------------------

def test_interleaved_put_update_delete_parity():
    t1, tn = _pair(seed=8, n=600)
    rng1 = np.random.default_rng(99)
    rng2 = np.random.default_rng(99)
    for t, rng in ((t1, rng1), (tn, rng2)):
        # update a slice (new versions), delete another, add fresh rows
        upd_pks, upd = make_batch(rng, 80, dim=DIM, pk_start=100)
        t.put(upd_pks, upd)
        t.delete(list(range(300, 340)))
        new_pks, new = make_batch(rng, 50, dim=DIM, pk_start=600)
        t.put(new_pks, new)
    for label, drain in (("live memtable", False), ("after drain", True)):
        if drain:
            t1.drain()
            t1.flush()
            tn.drain()
            tn.flush()
        for qq in _queries(np.random.default_rng(9)):
            r1, _ = t1.executor.execute(qq)
            rn, _ = tn.executor.execute(qq)
            assert _res(r1) == _res(rn), (label, qq)
        # deleted pks are gone everywhere, updated pks resolve newest
        assert tn.get(310) is None and t1.get(310) is None
        assert tn.get(120)["time"] == t1.get(120)["time"]


# ---------------------------------------------------------------------------
# continuous subscriptions
# ---------------------------------------------------------------------------

def test_subscribe_equivalence_vs_single_store():
    # "none" mode on the single store = plain re-execution, the exact
    # semantics the sharded engine implements (views don't span shards)
    t1, tn = _pair(seed=10, n=450, continuous_mode="none")
    rng = np.random.default_rng(11)
    qv = rng.normal(size=DIM).astype(np.float32)
    sync_q = q.HybridQuery(where=q.Range("time", 0, 50),
                           ranks=[q.VectorRank("embedding", qv, 1.0)], k=8)
    async_q = q.HybridQuery(where=q.TextContains("content", "delta"))
    subs = {}
    for name, t in (("single", t1), ("sharded", tn)):
        subs[name] = (t.subscribe(sync_q, interval_s=60.0),
                      t.subscribe(async_q, on_change=True))
    for t in (t1, tn):
        t.advance(0.0)
    for a, b in zip(subs["single"], subs["sharded"]):
        assert _res(a.latest) == _res(b.latest)
    # a delta dirties the ASYNC query on both engines; SYNC not yet due
    rng_d = np.random.default_rng(12)
    pks, batch = make_batch(rng_d, 40, dim=DIM, pk_start=450)
    t1.put(pks, batch)
    rng_d = np.random.default_rng(12)
    pks, batch = make_batch(rng_d, 40, dim=DIM, pk_start=450)
    tn.put(pks, batch)
    out1 = t1.advance(30.0)
    outn = tn.advance(30.0)
    assert set(out1) == {subs["single"][1].rid}
    assert set(outn) == {subs["sharded"][1].rid}
    # SYNC re-runs at its interval with the new rows on both sides
    t1.advance(60.0)
    tn.advance(60.0)
    for a, b in zip(subs["single"], subs["sharded"]):
        assert _res(a.latest) == _res(b.latest)
        assert a.latest is not None and len(a.latest) > 0


# ---------------------------------------------------------------------------
# merge payload + stats aggregation + EXPLAIN
# ---------------------------------------------------------------------------

def test_merge_payload_bounded_and_stats_aggregated(pair):
    t1, tn = pair
    qv = np.random.default_rng(13).normal(size=DIM).astype(np.float32)
    qq = q.HybridQuery(ranks=[q.VectorRank("embedding", qv, 1.0)], k=10)
    rows, st = tn.executor.execute(qq)
    assert st.shards == N_SHARDS
    assert 0 < st.merge_rows <= N_SHARDS * qq.k
    assert st.kernel_launches > 0 and st.bytes_to_host > 0
    assert 0 < st.shard_rows_max <= st.rows_scanned
    _, st1 = t1.executor.execute(qq)
    assert st1.shards == 0 and st1.merge_rows == 0   # unsharded defaults
    # filter queries concatenate — no top-k merge payload
    _, stf = tn.executor.execute(
        q.HybridQuery(where=q.Range("time", 0, 40)))
    assert stf.merge_rows == 0 and stf.shards == N_SHARDS


def test_explain_shard_fanout(pair):
    _, tn = pair
    qv = np.zeros(DIM, np.float32)
    txt = (tn.query()
             .rank(q.VectorRank("embedding", qv, 1.0))
             .limit(7).explain())
    assert txt.startswith("sharded:")
    assert f"ShardFanout [n={N_SHARDS} hash(pk)]" in txt
    assert "CrossShardTopKMerge" in txt and "k=7" in txt
    assert txt.count("-> Shard [") == N_SHARDS     # per-shard subtrees
    ftxt = tn.explain(q.HybridQuery(where=q.Range("time", 0, 10)))
    assert "ShardConcat" in ftxt and "ShardFanout" in ftxt
    # executed stats carry the sharded EXPLAIN
    _, st = tn.executor.execute(
        q.HybridQuery(ranks=[q.VectorRank("embedding", qv, 1.0)], k=7))
    assert "ShardFanout" in st.plan


# ---------------------------------------------------------------------------
# batched cross-shard merge kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_merge_topk_batch_orders_by_score_then_pk(use_pallas):
    rng = np.random.default_rng(14)
    nq, s, kk, k = 4, 3, 6, 5
    d = rng.uniform(0, 10, (nq, s, kk)).astype(np.float32)
    ids = rng.permutation(nq * s * kk).reshape(nq, s, kk).astype(np.int64)
    d[0, 0, 0] = d[0, 1, 3] = d[0, 2, 2] = 1.25        # 3-way tie
    d[3, :, 2:] = np.inf                               # short lists
    md, mi = kops.merge_topk_batch(d, ids, k, use_pallas=use_pallas)
    for qi in range(nq):
        flat = [(float(dv), int(iv))
                for dv, iv in zip(d[qi].ravel(), ids[qi].ravel())
                if np.isfinite(dv)]
        want = sorted(flat)[:k]
        got = [(float(a), int(b)) for a, b in zip(md[qi], mi[qi])
               if b >= 0]
        assert got == want
    assert (mi[3][np.isinf(md[3])] == -1).all()


def test_merge_topk_batch_large_pks_fall_back_exactly():
    # ids beyond the int32 tie-break range must not truncate on the
    # pallas path — the wrapper falls back to the exact host merge
    d = np.asarray([[[1.0, 2.0, np.inf]]], np.float32)
    ids = np.asarray([[[2**31, 7, 0]]], np.int64)
    md, mi = kops.merge_topk_batch(d, ids, 2, use_pallas=True)
    assert mi[0].tolist() == [2**31, 7]
    assert md[0].tolist() == [1.0, 2.0]


def test_merge_topk_batch_pallas_matches_host():
    rng = np.random.default_rng(15)
    d = rng.uniform(0, 5, (6, 4, 8)).astype(np.float32)
    ids = rng.integers(0, 2**20, (6, 4, 8)).astype(np.int64)
    d[1, 2, :] = d[1, 0, :]                            # cross-shard ties
    ids[1, 2, :] = ids[1, 0, ::-1]
    a = kops.merge_topk_batch(d, ids, 7, use_pallas=True)
    b = kops.merge_topk_batch(d, ids, 7, use_pallas=False)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


# ---------------------------------------------------------------------------
# distributed.py satellites
# ---------------------------------------------------------------------------

def test_store_shards_vectorized_includes_memtable():
    from repro.core import distributed as dist
    from repro.core.lsm import LSMStore
    rng = np.random.default_rng(16)
    store = LSMStore(tweet_schema(DIM), LSMConfig(flush_rows=128))
    pks, batch = make_batch(rng, 300, dim=DIM)
    store.put(pks, batch)
    store.flush()
    pks2, batch2 = make_batch(rng, 37, dim=DIM, pk_start=300)
    store.put(pks2, batch2)                 # stays in the memtable
    n_shards = 4
    V, Pt, I, M = dist.store_shards(store, n_shards)
    assert int(M.sum()) == 337              # memtable rows not dropped
    assert set(I[M].tolist()) == set(range(337))
    per = len(I) // n_shards
    for s in range(n_shards):
        blk = I[s * per:(s + 1) * per]
        real = blk[blk >= 0]
        assert (real % n_shards == s).all()     # demo routing: pk % n
        # within a shard, rows keep store order (stable packing)
        assert (np.diff(real) > 0).all()
    # vectors land next to their ids
    seg = store.segments[0]
    row = int(np.nonzero(I == 5)[0][0])
    np.testing.assert_array_equal(V[row], seg.columns["embedding"][5])
    # visibility resolves before packing: an update supersedes the
    # flushed version (no duplicate pk), a delete shadows it entirely
    upd_pks, upd = make_batch(rng, 1, dim=DIM, pk_start=5)
    store.put(upd_pks, upd)
    store.delete([6])
    V2, _, I2, M2 = dist.store_shards(store, n_shards)
    live = I2[M2].tolist()
    assert int(M2.sum()) == 336 and live.count(5) == 1 and 6 not in live
    row5 = int(np.nonzero(I2 == 5)[0][0])
    np.testing.assert_array_equal(V2[row5],
                                  np.asarray(upd["embedding"][0],
                                             np.float32))


def test_local_topk_k_exceeds_rows():
    import jax.numpy as jnp
    from repro.core import distributed as dist
    rng = np.random.default_rng(17)
    vecs = rng.normal(size=(5, 8)).astype(np.float32)
    d, idx = dist.local_topk(jnp.ones(8, jnp.float32),
                             jnp.asarray(vecs), 9)
    d, idx = np.asarray(d), np.asarray(idx)
    assert (idx[:5] >= 0).all() and (np.diff(d[:5]) >= 0).all()
    assert (idx[5:] == -1).all() and np.isinf(d[5:]).all()


def test_distributed_topk_k_exceeds_shard_rows():
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import distributed as dist
    rng = np.random.default_rng(18)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    n, d, k = 6, 8, 10                      # k > rows on the shard
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    ids = np.arange(100, 100 + n, dtype=np.int64)
    qv = rng.normal(size=d).astype(np.float32)
    topk = dist.make_distributed_topk(mesh, k)
    out_d, out_i = topk(jnp.asarray(qv), jnp.asarray(vecs),
                        jnp.asarray(ids))
    out_i = np.asarray(out_i)
    real = out_i[out_i >= 0]
    exact = ids[np.argsort(((vecs - qv) ** 2).sum(1))]
    assert sorted(real.tolist()) == sorted(exact.tolist())
    assert (out_i[len(real):] == -1).all()
