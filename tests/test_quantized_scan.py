"""Quantized residence tier: fused ADC scan->top-k' kernel parity vs the
ref oracle, the ops wrapper vs brute force in both backends, shared code
padding accounting, and end-to-end quantized-vs-exact equivalence on the
TRACY workload (single-store and sharded)."""
import numpy as np
import pytest

from benchmarks import tracy
from repro.core import query as q
from repro.core.executor import Executor
from repro.core.optimizer import planner as planner_lib
from repro.core.shards import ShardedExecutor, ShardRouter
from repro.kernels import fused_scan as fs
from repro.kernels import ops as kops
from repro.kernels import quantized_scan as qs
from repro.kernels import ref

import jax.numpy as jnp


def _make_pq(n, d, m, seed=0):
    """Random codes + codebooks shaped like a quantized rank column."""
    rng = np.random.default_rng(seed)
    codebooks = rng.normal(size=(m, 256, d // m)).astype(np.float32)
    codes = rng.integers(0, 256, (n, m), dtype=np.uint8)
    return codes, codebooks


def _brute_adc(Q, codes, codebooks, mask, pks, k):
    """(adc, row) float64 oracle: smallest ADC distance per query over
    admitted rows, ties by (adc, pk)."""
    lut = kops.adc_lut(Q, codebooks).astype(np.float64)
    n, m = codes.shape
    adc = np.zeros((len(Q), n))
    for j in range(m):
        adc += lut[:, j, :][:, codes[:, j].astype(np.int64)]
    out = []
    for qi in range(len(Q)):
        dd = np.where(mask[qi], adc[qi], np.inf)
        order = np.lexsort((pks, dd))[:k]
        out.append(order[np.isfinite(dd[order])])
    return out


# ---------------------------------------------------------------------------
# kernel vs oracle parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nq,n,m", [(8, 512, 8), (8, 1024, 16),
                                    (16, 512, 4)])
@pytest.mark.parametrize("mask_kind", ["full", "partial", "block_holes"])
def test_kernel_matches_ref(nq, n, m, mask_kind):
    rng = np.random.default_rng(0)
    d = 4 * m
    Q = rng.normal(size=(nq, d)).astype(np.float32)
    codes, codebooks = _make_pq(n, d, m, seed=1)
    if mask_kind == "full":
        mask = np.ones((nq, n), np.uint8)
    elif mask_kind == "partial":
        mask = (rng.random((nq, n)) < 0.3).astype(np.uint8)
    else:           # whole tiles masked for every query (occupancy skip)
        mask = np.ones((nq, n), np.uint8)
        mask[:, : fs.BLOCK_N] = 0
        mask[:, -fs.BLOCK_N // 2:] = 0
    pks = (np.arange(n, dtype=np.int32) * 7 + 3)
    occ = mask.reshape(nq // fs.BLOCK_Q, fs.BLOCK_Q,
                       n // fs.BLOCK_N, fs.BLOCK_N) \
        .any(axis=(1, 3)).astype(np.int32)
    lut = kops.adc_lut(Q, codebooks)
    kd, kp, ki = qs.quantized_scan_topk(
        jnp.asarray(lut.reshape(nq, m * 256)),
        jnp.asarray(codes.astype(np.int32)), jnp.asarray(mask),
        jnp.asarray(pks[None, :]), jnp.asarray(occ), interpret=True)
    rd, rp, ri = ref.quantized_topk_ref(
        jnp.asarray(lut), jnp.asarray(codes.astype(np.int32)),
        jnp.asarray(mask), jnp.asarray(pks[None, :]), k=fs.KMAX)
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(rp))


def test_kernel_tie_break_by_pk():
    """Duplicate codes give bitwise-equal ADC distances: within every
    run of equal distances the winners must ascend by pk."""
    rng = np.random.default_rng(1)
    m, n = 8, 512
    codes, codebooks = _make_pq(8, 4 * m, m, seed=2)
    X = np.repeat(codes, n // len(codes), axis=0)     # 512 rows, 8 classes
    X = X[rng.permutation(len(X))]
    pks = rng.permutation(n).astype(np.int32) * 5 + 2
    Q = rng.normal(size=(fs.BLOCK_Q, 4 * m)).astype(np.float32)
    lut = kops.adc_lut(Q, codebooks)
    mask = np.ones((fs.BLOCK_Q, n), np.uint8)
    occ = np.ones((1, 1), np.int32)
    kd, kp, ki = qs.quantized_scan_topk(
        jnp.asarray(lut.reshape(fs.BLOCK_Q, m * 256)),
        jnp.asarray(X.astype(np.int32)), jnp.asarray(mask),
        jnp.asarray(pks[None, :]), jnp.asarray(occ), interpret=True)
    kd, kp = np.asarray(kd)[0], np.asarray(kp)[0]
    for i in range(1, fs.KMAX):
        if kd[i] == kd[i - 1]:
            assert kp[i] > kp[i - 1]


# ---------------------------------------------------------------------------
# ops wrapper: ragged shapes, degenerate bitmaps, both backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 10, 128])
@pytest.mark.parametrize("nq,n,m", [(1, 700, 8), (5, 1400, 16),
                                    (9, 130, 4)])
def test_ops_quantized_matches_bruteforce_ragged(nq, n, m, k):
    rng = np.random.default_rng(2)
    d = 4 * m
    Q = rng.normal(size=(nq, d)).astype(np.float32)
    codes, codebooks = _make_pq(n, d, m, seed=3)
    mask = rng.random((nq, n)) < 0.4
    mask[0, :] = False                                # all-masked query
    if nq > 1:
        mask[1, :] = True                             # full bitmap
    pks = np.arange(n, dtype=np.int64) * 3 + 11
    want = _brute_adc(Q, codes, codebooks, mask, pks, k)
    for up in (True, False):
        adc, rows = kops.quantized_scan_topk(Q, codes, codebooks, mask,
                                             pks, k, use_pallas=up)
        assert adc.shape == (nq, k) and rows.shape == (nq, k)
        for qi in range(nq):
            got = rows[qi][rows[qi] >= 0]
            np.testing.assert_array_equal(got, want[qi],
                                          err_msg=f"q{qi} pallas={up}")
            assert (rows[qi][len(want[qi]):] == -1).all()
            assert np.isinf(adc[qi][len(want[qi]):]).all()


def test_ops_quantized_empty_inputs():
    codes, codebooks = _make_pq(200, 32, 8, seed=4)
    Q = np.zeros((2, 32), np.float32)
    pks = np.arange(200, dtype=np.int64)
    for up in (True, False):
        _, rows = kops.quantized_scan_topk(
            Q, codes, codebooks, np.zeros((2, 200), bool), pks, 5,
            use_pallas=up)
        assert (rows == -1).all()
    _, rows = kops.quantized_scan_topk(
        Q, np.zeros((0, 8), np.uint8), codebooks, np.zeros((2, 0), bool),
        np.zeros(0, np.int64), 5)
    assert rows.shape == (2, 5) and (rows == -1).all()


def test_pq_adc_padding_charged_once(monkeypatch):
    """Satellite: both ``pq_adc_distances`` device backends pad the code
    matrix through the shared ``_pad_codes`` helper, so the dispatch
    accounting (shape key and bytes) is identical whichever ran —
    host-side padding differences can't skew ``stats_snapshot()``."""
    rng = np.random.default_rng(5)
    n, m = 700, 8                                     # odd n: real padding
    codes, codebooks = _make_pq(n, 32, m, seed=5)
    qv = rng.normal(size=32).astype(np.float32)
    monkeypatch.setattr(kops, "HOST_FLOP_CUTOFF", 0)  # force device paths
    before = kops.stats_snapshot()
    d_ref = kops.pq_adc_distances(qv, codes, codebooks, use_pallas=False)
    mid = kops.stats_snapshot()
    d_pal = kops.pq_adc_distances(qv, codes, codebooks, use_pallas=True)
    after = kops.stats_snapshot()
    ref_bytes = mid[1] - before[1]
    pal_bytes = after[1] - mid[1]
    assert ref_bytes == pal_bytes > 0
    np.testing.assert_allclose(d_ref, d_pal, rtol=1e-5, atol=1e-5)
    padded = kops._pad_codes(codes, qs.BLOCK_N)
    assert len(padded) % qs.BLOCK_N == 0 and len(padded) >= n


# ---------------------------------------------------------------------------
# end-to-end: quantized vs exact over the TRACY workload
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tracy_store():
    cfg = tracy.TracyConfig(n_rows=1200, dim=32, seed=7, flush_rows=300,
                            fanout=64, pq_m=16)      # dsub=2 books
    store, data = tracy.build_store(cfg)
    return store, data


def _results(pairs):
    return [[(r.pk, float(r.score)) for r in rows] for rows, _ in pairs]


def test_planner_quantized_dispatch_and_explain(tracy_store):
    store, data = tracy_store
    ex = Executor(store)
    qq = q.HybridQuery(ranks=[q.VectorRank(
        "embedding", data.query_vec(), 1.0)], k=10, recall_target=0.9)
    plan = planner_lib.plan(ex.catalog, qq)
    assert plan.quantized and plan.pq_m == 16 and plan.refine == 4
    text = plan.describe()
    assert "dispatch=quantized(pq m=16, refine=4)" in text
    assert "QuantizedScanTopK" in text
    # no recall target (or target 1.0) keeps the exact read path
    exact = q.HybridQuery(ranks=list(qq.ranks), k=10)
    assert not planner_lib.plan(ex.catalog, exact).quantized
    full = q.HybridQuery(ranks=list(qq.ranks), k=10, recall_target=1.0)
    assert not planner_lib.plan(ex.catalog, full).quantized
    with pytest.raises(ValueError):
        q.HybridQuery(ranks=list(qq.ranks), k=10, recall_target=1.5)


def test_quantized_bitwise_identical_at_high_refine(tracy_store):
    """With refine*k covering enough survivors, the quantized path must
    return bitwise-identical (pk, score) to the exact fused path — in
    both backends (the CI pallas-interpret job re-runs this file with
    REPRO_USE_PALLAS=1)."""
    store, data = tracy_store
    ex = Executor(store)
    for ti in range(3):
        data.rng = np.random.default_rng(60 + ti)
        qa = [q.HybridQuery(ranks=[q.VectorRank(
            "embedding", data.query_vec(), 1.0)], k=10,
            recall_target=0.9) for _ in range(4)]
        data.rng = np.random.default_rng(60 + ti)
        qb = [q.HybridQuery(ranks=[q.VectorRank(
            "embedding", data.query_vec(), 1.0)], k=10)
            for _ in range(4)]
        plans = [planner_lib.plan(ex.catalog, qi) for qi in qa]
        assert all(p.quantized for p in plans)
        for p in plans:
            p.refine = 12                            # k' = 120 <= KMAX
        quant = ex.execute_many(qa, plans=plans)
        exact = ex.execute_many(qb)
        assert _results(quant) == _results(exact)
        for (_, sq), (_, se) in zip(quant, exact):
            assert sq.rerank_rows > 0 and se.rerank_rows == 0
            assert 0 < sq.bytes_scanned < se.bytes_scanned


def test_quantized_stats_bytes_reduction(tracy_store):
    """Default refine: bytes_scanned must shrink by ~4*d/m (scan-phase
    accounting) and recall stays high on the clustered workload."""
    store, data = tracy_store
    ex = Executor(store)
    data.rng = np.random.default_rng(99)
    qa = [q.HybridQuery(ranks=[q.VectorRank(
        "embedding", data.query_vec(), 1.0)], k=10, recall_target=0.9)
        for _ in range(6)]
    data.rng = np.random.default_rng(99)
    qb = [q.HybridQuery(ranks=[q.VectorRank(
        "embedding", data.query_vec(), 1.0)], k=10) for _ in range(6)]
    quant = ex.execute_many(qa)
    exact = ex.execute_many(qb)
    for (rq, sq), (re_, se) in zip(quant, exact):
        assert "dispatch=quantized" in sq.plan
        # dim=32 fp rows are 128 B, m=16 codes: exactly 8x scan bytes
        assert se.bytes_scanned == 8 * sq.bytes_scanned > 0
        assert sq.rerank_rows == 40                  # refine(4) * k(10)
        got = {r.pk for r in rq}
        want = {r.pk for r in re_}
        assert len(got & want) >= 8                  # recall@10 >= 0.8


def test_quantized_filtered_and_fallback(tracy_store):
    """Filtered quantized queries stay correct, and a store without
    codes for the rank column plans exact."""
    store, data = tracy_store
    ex = Executor(store)
    data.rng = np.random.default_rng(123)
    qa = [q.HybridQuery(where=q.Range("time", 100, 600),
                        ranks=[q.VectorRank("embedding", data.query_vec(),
                                            1.0)],
                        k=10, recall_target=0.9) for _ in range(4)]
    data.rng = np.random.default_rng(123)
    qb = [q.HybridQuery(where=q.Range("time", 100, 600),
                        ranks=[q.VectorRank("embedding", data.query_vec(),
                                            1.0)], k=10)
          for _ in range(4)]
    plans = [planner_lib.plan_shared_scan(ex.catalog, qi) for qi in qa]
    assert all(p.quantized for p in plans)
    for p in plans:
        p.refine = 12
    quant = ex.execute_many(qa, plans=plans)
    exact = ex.execute_many(qb)
    assert _results(quant) == _results(exact)
    # a spatial rank column has no PQ codes -> no quantized dispatch
    sq = q.HybridQuery(ranks=[q.SpatialRank("coordinate", (5., 5.), 1.0)],
                       k=5, recall_target=0.9)
    assert not planner_lib.plan(ex.catalog, sq).quantized


def test_sharded_quantized_parity():
    """Sharded scatter-gather threads the quantized choice through and
    matches the sharded exact path at high refine; aggregated stats
    carry the new columns."""
    cfg = tracy.TracyConfig(n_rows=1600, dim=32, seed=11, flush_rows=200,
                            fanout=64, pq_m=16)
    data = tracy.TracyData(cfg)
    router = ShardRouter(tracy.tweet_schema(cfg.dim),
                         tracy.LSMConfig(flush_rows=cfg.flush_rows,
                                         fanout=cfg.fanout,
                                         pq_m=cfg.pq_m),
                         n_shards=2)
    done = 0
    while done < cfg.n_rows:
        n = min(cfg.flush_rows, cfg.n_rows - done)
        pks, batch = data.batch(n)
        router.put(pks, batch)
        done += n
    router.flush()
    ex = ShardedExecutor(router)
    data.rng = np.random.default_rng(77)
    qa = [q.HybridQuery(ranks=[q.VectorRank(
        "embedding", data.query_vec(), 1.0)], k=10, recall_target=0.9)
        for _ in range(4)]
    data.rng = np.random.default_rng(77)
    qb = [q.HybridQuery(ranks=[q.VectorRank(
        "embedding", data.query_vec(), 1.0)], k=10) for _ in range(4)]
    plan = ex.plan(qa[0])
    assert plan.quantized and plan.pq_m == 16
    assert "dispatch=quantized(pq m=16" in plan.describe()
    logical = plan.logical
    logical.refine = 12
    quant = ex.execute_many(qa, plans=[logical] * len(qa))
    exact = ex.execute_many(qb)
    assert _results(quant) == _results(exact)
    for _, st in quant:
        assert st.bytes_scanned > 0 and st.rerank_rows > 0
        assert st.shards == 2
