"""Fused scan->top-k kernel path: parity sweeps vs the ref oracle and
end-to-end fused-vs-staged equivalence on the TRACY workload."""
import numpy as np
import pytest

from benchmarks import tracy
from repro.core import query as q
from repro.core.executor import Executor
from repro.core.optimizer import planner as planner_lib
from repro.kernels import fused_scan as fs
from repro.kernels import ops as kops
from repro.kernels import ref

import jax.numpy as jnp


@pytest.fixture
def fused_toggle():
    prev = planner_lib.FUSED_ENABLED
    yield
    planner_lib.FUSED_ENABLED = prev


def _pad(a, mult, axis, value=0):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return np.pad(a, widths, constant_values=value)


def _brute_topk(Q, X, mask, pks, k):
    """(d2, row) oracle: smallest squared-L2 per query over admitted
    rows, ties by (distance, pk)."""
    d2 = ((Q[:, None, :].astype(np.float64)
           - X[None, :, :].astype(np.float64)) ** 2).sum(-1)
    out = []
    for qi in range(len(Q)):
        dd = np.where(mask[qi], d2[qi], np.inf)
        order = np.lexsort((pks, dd))[:k]
        out.append(order[np.isfinite(dd[order])])
    return out


# ---------------------------------------------------------------------------
# kernel vs oracle parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nq,n,d", [(8, 512, 16), (8, 1024, 64),
                                    (16, 512, 8)])
@pytest.mark.parametrize("mask_kind", ["full", "partial", "block_holes"])
def test_kernel_matches_ref(nq, n, d, mask_kind):
    rng = np.random.default_rng(0)
    Q = rng.normal(size=(nq, d)).astype(np.float32)
    X = rng.normal(size=(n, d)).astype(np.float32)
    if mask_kind == "full":
        mask = np.ones((nq, n), np.uint8)
    elif mask_kind == "partial":
        mask = (rng.random((nq, n)) < 0.3).astype(np.uint8)
    else:           # whole tiles masked for every query (occupancy skip)
        mask = np.ones((nq, n), np.uint8)
        mask[:, : fs.BLOCK_N] = 0
        mask[:, -fs.BLOCK_N // 2:] = 0
    pks = (np.arange(n, dtype=np.int32) * 7 + 3)
    occ = mask.reshape(nq // fs.BLOCK_Q, fs.BLOCK_Q,
                       n // fs.BLOCK_N, fs.BLOCK_N) \
        .any(axis=(1, 3)).astype(np.int32)
    kd, kp, ki = fs.fused_scan_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask),
        jnp.asarray(pks[None, :]), jnp.asarray(occ), interpret=True)
    rd, rp, ri = ref.fused_topk_ref(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask),
        jnp.asarray(pks[None, :]), k=fs.KMAX)
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(rp))


def test_kernel_tie_break_by_pk():
    """Duplicate vectors give bitwise-equal distances: the winner must
    be the smallest pk, in both backends, regardless of row order."""
    rng = np.random.default_rng(1)
    d = 16
    base = rng.normal(size=(8, d)).astype(np.float32)
    X = np.repeat(base, 64, axis=0)                  # 512 rows, 8 classes
    perm = rng.permutation(len(X))
    X = X[perm]
    pks = rng.permutation(len(X)).astype(np.int32) * 5 + 2
    Q = base[:1] + 0.01
    Qp = _pad(Q, fs.BLOCK_Q, 0)
    mask = np.ones((len(Qp), len(X)), np.uint8)
    occ = np.ones((1, 1), np.int32)
    kd, kp, ki = fs.fused_scan_topk(
        jnp.asarray(Qp), jnp.asarray(X), jnp.asarray(mask),
        jnp.asarray(pks[None, :]), jnp.asarray(occ), interpret=True)
    kd, kp, ki = (np.asarray(a)[0] for a in (kd, kp, ki))
    # within every run of equal distances, pks must ascend
    for i in range(1, fs.KMAX):
        if kd[i] == kd[i - 1]:
            assert kp[i] > kp[i - 1]
    rd, rp, ri = ref.fused_topk_ref(
        jnp.asarray(Qp), jnp.asarray(X), jnp.asarray(mask),
        jnp.asarray(pks[None, :]), k=fs.KMAX)
    np.testing.assert_array_equal(ki, np.asarray(ri)[0])


# ---------------------------------------------------------------------------
# ops wrapper: ragged shapes, k sweep, degenerate bitmaps, backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 10, 128])
@pytest.mark.parametrize("nq,n,d", [(1, 700, 24), (5, 1400, 32),
                                    (9, 130, 8)])
def test_ops_fused_matches_bruteforce_ragged(nq, n, d, k):
    rng = np.random.default_rng(2)
    Q = rng.normal(size=(nq, d)).astype(np.float32)
    X = rng.normal(size=(n, d)).astype(np.float32)
    mask = rng.random((nq, n)) < 0.4
    mask[0, :] = False                                # all-masked query
    if nq > 1:
        mask[1, :] = True                             # full bitmap
    pks = np.arange(n, dtype=np.int64) * 3 + 11
    want = _brute_topk(Q, X, mask, pks, k)
    for up in (True, False):
        d2, rows = kops.fused_scan_topk(Q, X, mask, pks, k, use_pallas=up)
        assert d2.shape == (nq, k) and rows.shape == (nq, k)
        for qi in range(nq):
            got = rows[qi][rows[qi] >= 0]
            np.testing.assert_array_equal(got, want[qi],
                                          err_msg=f"q{qi} pallas={up}")
            assert (rows[qi][len(want[qi]):] == -1).all()
            assert np.isinf(d2[qi][len(want[qi]):]).all()


def test_ops_fused_all_masked_segment_and_empty():
    rng = np.random.default_rng(3)
    Q = rng.normal(size=(3, 16)).astype(np.float32)
    X = rng.normal(size=(1100, 16)).astype(np.float32)
    pks = np.arange(1100, dtype=np.int64)
    # a whole "segment" range masked for every query (block compaction)
    mask = np.ones((3, 1100), bool)
    mask[:, 200:900] = False
    want = _brute_topk(Q, X, mask, pks, 10)
    for up in (True, False):
        _, rows = kops.fused_scan_topk(Q, X, mask, pks, 10, use_pallas=up)
        for qi in range(3):
            np.testing.assert_array_equal(rows[qi][rows[qi] >= 0],
                                          want[qi])
    # fully empty bitmap and empty input
    _, rows = kops.fused_scan_topk(Q, X, np.zeros((3, 1100), bool), pks, 4)
    assert (rows == -1).all()
    _, rows = kops.fused_scan_topk(Q, np.zeros((0, 16), np.float32),
                                   np.zeros((3, 0), bool),
                                   np.zeros(0, np.int64), 4)
    assert rows.shape == (3, 4) and (rows == -1).all()


def test_ops_fused_jit_ref_path_matches_host(monkeypatch):
    """Force the jit'd ref backend (cutoff=0) against the host fast
    path: same rows selected on non-tied data."""
    rng = np.random.default_rng(4)
    Q = rng.normal(size=(4, 24)).astype(np.float32)
    X = rng.normal(size=(900, 24)).astype(np.float32)
    mask = rng.random((4, 900)) < 0.5
    pks = np.arange(900, dtype=np.int64) + 5
    d2_host, rows_host = kops.fused_scan_topk(Q, X, mask, pks, 12,
                                              use_pallas=False)
    monkeypatch.setattr(kops, "HOST_FLOP_CUTOFF", 0)
    d2_jit, rows_jit = kops.fused_scan_topk(Q, X, mask, pks, 12,
                                            use_pallas=False)
    np.testing.assert_array_equal(rows_host, rows_jit)
    np.testing.assert_allclose(d2_host, d2_jit, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# end-to-end: fused vs staged over the TRACY workload
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tracy_store():
    cfg = tracy.TracyConfig(n_rows=1200, dim=32, seed=7, flush_rows=300,
                            fanout=64)
    store, data = tracy.build_store(cfg)
    # live memtable rows on top of the segments (overlay must merge)
    pks, batch = data.batch(40)
    store.put(pks, batch)
    return store, data


def _run_both(ex, queries_a, queries_b):
    planner_lib.FUSED_ENABLED = True
    fused = ex.execute_many(queries_a)
    planner_lib.FUSED_ENABLED = False
    staged = ex.execute_many(queries_b)
    return fused, staged


def test_execute_many_fused_vs_staged_tracy(tracy_store, fused_toggle):
    store, data = tracy_store
    assert len(store.segments) >= 4 and store.memtable_rows > 0
    _, nn_t = tracy.make_templates(data)
    ex = Executor(store)
    any_fused = False
    for ti, tmpl in enumerate(nn_t):
        data.rng = np.random.default_rng(50 + ti)
        qa = [tmpl() for _ in range(6)]
        data.rng = np.random.default_rng(50 + ti)
        qb = [tmpl() for _ in range(6)]
        fused, staged = _run_both(ex, qa, qb)
        used = any("dispatch=fused" in st.plan for _, st in fused)
        any_fused |= used
        for (ra, sa), (rb, sb) in zip(fused, staged):
            assert [(r.pk, float(r.score)) for r in ra] == \
                [(r.pk, float(r.score)) for r in rb], f"template {ti}"
            if used:
                assert sa.kernel_launches <= sb.kernel_launches
                assert sa.bytes_to_host < sb.bytes_to_host
    assert any_fused, "no template exercised the fused path"


def test_fused_plan_explain_and_stats(tracy_store, fused_toggle):
    store, data = tracy_store
    ex = Executor(store)
    planner_lib.FUSED_ENABLED = True
    qq = q.HybridQuery(ranks=[q.VectorRank(
        "embedding", data.query_vec(), 1.0)], k=10)
    plan = planner_lib.plan_shared_scan(ex.catalog, qq)
    assert plan.fused
    text = plan.describe()
    assert "dispatch=fused" in text and "FusedScanTopK" in text
    assert "RankScore" not in text
    planner_lib.FUSED_ENABLED = False
    plan2 = planner_lib.plan_shared_scan(ex.catalog, qq)
    assert not plan2.fused and "RankScore" in plan2.describe()
    planner_lib.FUSED_ENABLED = True
    res, st = ex.execute(qq, plan)
    assert len(res) == 10
    assert st.kernel_launches >= 1 and st.bytes_to_host > 0


def test_fused_gate_requires_unique_pks(fused_toggle):
    planner_lib.FUSED_ENABLED = True
    cfg = tracy.TracyConfig(n_rows=600, dim=16, seed=3, flush_rows=200,
                            fanout=64)
    store, data = tracy.build_store(cfg)
    ex = Executor(store)
    qq = q.HybridQuery(ranks=[q.VectorRank(
        "embedding", data.query_vec(), 1.0)], k=5)
    assert planner_lib.plan_shared_scan(ex.catalog, qq).fused
    # overwrite an existing pk: visibility resolution now matters, and
    # the device-side cut would race it -> the planner must fall back
    pks, batch = data.batch(1)
    store.put([0], batch)
    store.flush()
    assert not store.unique_pks
    ex2 = Executor(store)
    plan = planner_lib.plan_shared_scan(ex2.catalog, qq)
    assert not plan.fused
    res, _ = ex2.execute(qq, plan)
    assert len({r.pk for r in res}) == len(res)       # winners, no dupes


def test_fused_gate_rank_shapes(tracy_store, fused_toggle):
    store, data = tracy_store
    ex = Executor(store)
    planner_lib.FUSED_ENABLED = True
    vec = data.query_vec()
    multi = q.HybridQuery(ranks=[q.VectorRank("embedding", vec, 0.5),
                                 q.SpatialRank("coordinate", (1., 2.), 0.2)],
                          k=5)
    assert not planner_lib.plan_shared_scan(ex.catalog, multi).fused
    big_k = q.HybridQuery(ranks=[q.VectorRank("embedding", vec, 1.0)],
                          k=fs.KMAX + 1)
    assert not planner_lib.plan_shared_scan(ex.catalog, big_k).fused
    neg_w = q.HybridQuery(ranks=[q.VectorRank("embedding", vec, -1.0)],
                          k=5)
    assert not planner_lib.plan_shared_scan(ex.catalog, neg_w).fused


def test_vector_range_squared_compare(tracy_store):
    """VectorRange masks compare squared distances (satellite): results
    must equal the sqrt formulation, including thresh <= 0."""
    from repro.core.operators import eval_predicate_rows
    store, data = tracy_store
    seg = store.segments[0]
    vecs = np.asarray(seg.columns["embedding"], np.float32)
    qv = data.query_vec()
    for thresh in (8.0, 0.0, -1.0):
        pred = q.VectorRange("embedding", qv, thresh)
        got = eval_predicate_rows({"embedding": vecs}, pred)
        want = np.sqrt(np.maximum(
            ((vecs - qv[None, :]) ** 2).sum(1), 0)) < thresh
        np.testing.assert_array_equal(got, want)
