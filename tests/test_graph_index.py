"""Graph ANN tier: CSR beam-search kernel parity vs the ref oracle, the
ops wrapper contract on both backends, Vamana build/merge invariants
(donation bound, merged-vs-rebuilt recall parity), and end-to-end
graph-vs-exact equivalence on the TRACY workload (single-store, filtered
and sharded).  The CI pallas-interpret job re-runs this file with
REPRO_USE_PALLAS=1."""
import types

import numpy as np
import pytest

from benchmarks import tracy
from repro.analysis.plan_validator import validate_plan
from repro.core import query as q
from repro.core.executor import Executor
from repro.core.index.graph import GraphIndex
from repro.core.optimizer import planner as planner_lib
from repro.core.shards import ShardedExecutor, ShardRouter
from repro.core.types import IndexKind
from repro.kernels import fused_scan as fs
from repro.kernels import graph_search as gs
from repro.kernels import ops as kops

import jax.numpy as jnp

SENT = int(fs.SENTINEL)


def _jit_ref(args, beam, hops):
    """The oracle the ops layer actually dispatches: the JITTED ref twin
    (eager eval can fuse float ops differently by a ulp)."""
    return kops._jit_graph_ref(beam, hops)(*args)


def _random_csr(n, r_deg, rng):
    """Random adjacency shaped like a packed CSR: int32 (n, R) with a
    sprinkling of -1 out-degree padding."""
    nbr = rng.integers(0, n, (n, r_deg)).astype(np.int32)
    nbr[rng.random((n, r_deg)) < 0.25] = -1
    return nbr


def _seg_col(vecs):
    seg = types.SimpleNamespace(columns={"embedding": vecs},
                                n_rows=len(vecs))
    col = types.SimpleNamespace(name="embedding")
    return seg, col


def _brute_topk(vecs, qv, k):
    d2 = ((vecs - qv) ** 2).sum(axis=1)
    return set(np.argsort(d2)[:k].tolist())


def _clustered(n, dim, n_clusters, rng, spread=0.3):
    centers = rng.normal(size=(n_clusters, dim)).astype(np.float32)
    labels = rng.integers(0, n_clusters, n)
    return (centers[labels]
            + spread * rng.normal(size=(n, dim))).astype(np.float32)


# ---------------------------------------------------------------------------
# kernel vs oracle parity (bitwise: same hop loop, same comparator)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nq,n,r_deg,beam", [(8, 512, 8, 32),
                                             (8, 1024, 16, 64),
                                             (16, 512, 4, 40)])
@pytest.mark.parametrize("mask_kind", ["full", "partial", "one_empty"])
def test_kernel_matches_ref(nq, n, r_deg, beam, mask_kind):
    rng = np.random.default_rng(0)
    d = 16
    Q = rng.normal(size=(nq, d)).astype(np.float32)
    X = rng.normal(size=(n, d)).astype(np.float32)
    nbr = _random_csr(n, r_deg, rng)
    if mask_kind == "full":
        mask = np.ones((nq, n), np.uint8)
    elif mask_kind == "partial":
        mask = (rng.random((nq, n)) < 0.3).astype(np.uint8)
    else:           # one query admits nothing: traversal still runs
        mask = np.ones((nq, n), np.uint8)
        mask[0, :] = 0
    pks = (np.arange(n, dtype=np.int32) * 7 + 3)[None, :]
    ent = np.full((1, 8), SENT, np.int32)
    ent[0, :5] = rng.choice(n, 5, replace=False).astype(np.int32)
    args = (jnp.asarray(Q), jnp.asarray(X), jnp.asarray(nbr),
            jnp.asarray(ent), jnp.asarray(mask), jnp.asarray(pks))
    kd, kp, ki, kv = gs.graph_search_topk(*args, beam=beam, hops=4,
                                          interpret=True)
    rd, rp, ri, rv = _jit_ref(args, beam, 4)
    np.testing.assert_array_equal(np.asarray(kd), np.asarray(rd))
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(rp))
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))
    if mask_kind == "one_empty":
        assert (np.asarray(ki)[0] == SENT).all()


def test_kernel_entries_exceed_beam():
    """E > beam seed sets must still work: the kernel folds ALL entries
    through the same concat+sort merge, keeping the best `beam`."""
    rng = np.random.default_rng(1)
    nq, n, beam = 8, 512, 8
    Q = rng.normal(size=(nq, 12)).astype(np.float32)
    X = rng.normal(size=(n, 12)).astype(np.float32)
    nbr = _random_csr(n, 8, rng)
    mask = np.ones((nq, n), np.uint8)
    pks = np.arange(n, dtype=np.int32)[None, :]
    ent = np.full((1, 24), SENT, np.int32)
    ent[0, :20] = rng.choice(n, 20, replace=False).astype(np.int32)
    args = (jnp.asarray(Q), jnp.asarray(X), jnp.asarray(nbr),
            jnp.asarray(ent), jnp.asarray(mask), jnp.asarray(pks))
    kd, kp, ki, kv = gs.graph_search_topk(*args, beam=beam, hops=3,
                                          interpret=True)
    rd, rp, ri, rv = _jit_ref(args, beam, 3)
    np.testing.assert_array_equal(np.asarray(kd), np.asarray(rd))
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))
    assert (np.asarray(kd) < np.inf).all()


# ---------------------------------------------------------------------------
# ops wrapper contract: both device backends and the host fast path
# ---------------------------------------------------------------------------

def _check_wrapper_contract(Q, X, nbr, ent, mask, pks, beam, hops, up):
    d2, rows, gathered = kops.graph_search_topk(
        Q, X, nbr, ent, mask, pks, beam, hops, use_pallas=up)
    nq = len(Q)
    assert d2.shape == (nq, beam) and rows.shape == (nq, beam)
    assert gathered.shape == (nq,)
    for qi in range(nq):
        got = rows[qi][rows[qi] >= 0]
        # every emitted row passes the predicate and its distance is the
        # exact squared L2 (approximate coverage, exact values)
        assert mask[qi][got].all()
        want = ((X[got] - Q[qi]) ** 2).sum(axis=1).astype(np.float32)
        np.testing.assert_allclose(d2[qi][:len(got)], want,
                                   rtol=1e-5, atol=1e-5)
        assert (np.diff(d2[qi][:len(got)]) >= 0).all()      # ascending
        assert np.isinf(d2[qi][len(got):]).all()
        assert (rows[qi][len(got):] == -1).all()
        assert gathered[qi] >= len(got)


@pytest.mark.parametrize("use_pallas", [True, False])
def test_ops_wrapper_backends(use_pallas, monkeypatch):
    rng = np.random.default_rng(2)
    nq, n, r_deg, beam = 5, 700, 8, 24
    Q = rng.normal(size=(nq, 16)).astype(np.float32)
    X = rng.normal(size=(n, 16)).astype(np.float32)
    nbr = _random_csr(n, r_deg, rng)
    ent = rng.choice(n, 6, replace=False).astype(np.int64)
    mask = rng.random((nq, n)) < 0.5
    mask[0, :] = True
    pks = np.arange(n, dtype=np.int64) * 3 + 11
    monkeypatch.setattr(kops, "HOST_FLOP_CUTOFF", 0)    # force device path
    _check_wrapper_contract(Q, X, nbr, ent, mask, pks, beam, 6, use_pallas)


def test_ops_wrapper_host_fast_path():
    rng = np.random.default_rng(3)
    nq, n = 3, 400
    Q = rng.normal(size=(nq, 8)).astype(np.float32)
    X = rng.normal(size=(n, 8)).astype(np.float32)
    nbr = _random_csr(n, 8, rng)
    ent = rng.choice(n, 4, replace=False).astype(np.int64)
    mask = np.ones((nq, n), bool)
    pks = np.arange(n, dtype=np.int64)
    _check_wrapper_contract(Q, X, nbr, ent, mask, pks, 16, 4, False)


def test_ops_wrapper_degenerate_inputs():
    rng = np.random.default_rng(4)
    X = rng.normal(size=(100, 8)).astype(np.float32)
    Q = np.zeros((2, 8), np.float32)
    nbr = _random_csr(100, 4, rng)
    pks = np.arange(100, dtype=np.int64)
    # all-masked bitmap, out-of-range entries, empty column
    for up in (True, False):
        d2, rows, g = kops.graph_search_topk(
            Q, X, nbr, np.array([0]), np.zeros((2, 100), bool), pks,
            8, 4, use_pallas=up)
        assert (rows == -1).all() and np.isinf(d2).all()
    d2, rows, g = kops.graph_search_topk(
        Q, X, nbr, np.array([-1, 500]), np.ones((2, 100), bool), pks,
        8, 4, use_pallas=False)
    assert (rows == -1).all()
    d2, rows, g = kops.graph_search_topk(
        Q, np.zeros((0, 8), np.float32), np.zeros((0, 4), np.int32),
        np.array([0]), np.ones((2, 0), bool), np.zeros(0, np.int64),
        8, 4)
    assert rows.shape == (2, 8) and (rows == -1).all()


# ---------------------------------------------------------------------------
# index build + donation merge invariants
# ---------------------------------------------------------------------------

def test_build_recall_and_reachability():
    rng = np.random.default_rng(5)
    vecs = _clustered(600, 16, 6, rng)
    seg, col = _seg_col(vecs)
    idx = GraphIndex()
    idx.build(seg, col)
    deg = (idx.neighbors >= 0).sum(axis=1)
    assert deg.mean() >= idx.R / 2          # refinement fills out-degree
    assert idx._reachable().all()           # no stranded rows
    assert len(idx.entries) > 1
    assert ((idx.entries >= 0) & (idx.entries < 600)).all()
    hits = total = 0
    for _ in range(20):
        qv = vecs[rng.integers(0, 600)] + \
            0.1 * rng.normal(size=16).astype(np.float32)
        _, rows, _ = idx.search(qv, 10, beam=64)
        hits += len(set(rows.tolist()) & _brute_topk(vecs, qv, 10))
        total += 10
    assert hits / total >= 0.9


def test_merge_donates_and_matches_rebuild():
    """Compaction merges by donation: inserted_rows counts ONLY foreign
    rows (never the donor's survivors) and recall stays within 1% of a
    from-scratch rebuild."""
    rng = np.random.default_rng(6)
    sizes = [500, 400, 200]
    parts, part_vecs = [], []
    for si, sz in enumerate(sizes):
        vecs = _clustered(sz, 16, 5, np.random.default_rng(40 + si))
        seg, col = _seg_col(vecs)
        gi = GraphIndex(seed=si)
        gi.build(seg, col)
        parts.append(gi)
        part_vecs.append(vecs)
    # compaction row maps: drop ~5% of each part, survivors keep order
    row_maps, surv, off = [], [], 0
    for vecs in part_vecs:
        keep = rng.random(len(vecs)) >= 0.05
        rmap = np.full(len(vecs), -1, np.int64)
        rmap[keep] = off + np.arange(int(keep.sum()))
        off += int(keep.sum())
        row_maps.append(rmap)
        surv.append(vecs[keep])
    merged_vecs = np.concatenate(surv, axis=0)
    mseg, col = _seg_col(merged_vecs)
    gm = GraphIndex()
    gm.merge(parts, mseg, col, row_maps)
    donor_surv = max(int((rm >= 0).sum()) for rm in row_maps)
    assert gm.donated_rows == donor_surv
    assert gm.inserted_rows == len(merged_vecs) - donor_surv
    assert gm._reachable().all()
    rebuilt = GraphIndex()
    rebuilt.build(mseg, col)
    assert rebuilt.inserted_rows == len(merged_vecs)    # no donation
    hits_m = hits_r = total = 0
    for _ in range(30):
        qv = merged_vecs[rng.integers(0, len(merged_vecs))] + \
            0.1 * rng.normal(size=16).astype(np.float32)
        want = _brute_topk(merged_vecs, qv, 10)
        _, rm_, _ = gm.search(qv, 10, beam=64)
        _, rr_, _ = rebuilt.search(qv, 10, beam=64)
        hits_m += len(set(rm_.tolist()) & want)
        hits_r += len(set(rr_.tolist()) & want)
        total += 10
    assert hits_m / total >= hits_r / total - 0.01


# ---------------------------------------------------------------------------
# end-to-end: graph vs exact over the TRACY workload
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def graph_store():
    # dim 128: at TRACY's embedding width the graph walk beats both the
    # exact scan and the NRA index walk on cost, so the planner picks it
    # unprompted (smaller dims make the exact paths too cheap to lose)
    cfg = tracy.TracyConfig(n_rows=2400, dim=128, seed=7, flush_rows=600,
                            fanout=64)
    store, data = tracy.build_store(cfg, vector_index=IndexKind.GRAPH,
                                    quantize=False)
    return store, data


def _results(pairs):
    return [[(r.pk, float(r.score)) for r in rows] for rows, _ in pairs]


def test_planner_graph_dispatch_and_explain(graph_store):
    store, data = graph_store
    ex = Executor(store)
    qq = q.HybridQuery(ranks=[q.VectorRank(
        "embedding", data.query_vec(), 1.0)], k=10, recall_target=0.95)
    plan = planner_lib.plan(ex.catalog, qq)
    assert plan.graph and plan.graph_r == 16
    assert plan.k <= plan.graph_beam <= int(fs.KMAX)
    assert plan.graph_hops > 0 and not plan.quantized
    validate_plan(plan)                     # graph contract holds
    text = plan.describe()
    assert f"dispatch=graph(R=16, beam={plan.graph_beam}" in text
    assert "GraphSearchTopK" in text
    # no recall target (or target 1.0) keeps the exact read path
    exact = q.HybridQuery(ranks=list(qq.ranks), k=10)
    assert not planner_lib.plan(ex.catalog, exact).graph
    full = q.HybridQuery(ranks=list(qq.ranks), k=10, recall_target=1.0)
    assert not planner_lib.plan(ex.catalog, full).graph


def test_graph_bitwise_identical_at_high_beam(graph_store):
    """With the beam covering the true top-k, survivors re-ranked through
    the exact fused kernel must return bitwise-identical (pk, score) to
    the exact dispatch — on both backends (the CI pallas-interpret job
    re-runs this file with REPRO_USE_PALLAS=1)."""
    store, data = graph_store
    ex = Executor(store)
    for ti in range(2):
        data.rng = np.random.default_rng(60 + ti)
        qa = [q.HybridQuery(ranks=[q.VectorRank(
            "embedding", data.query_vec(), 1.0)], k=10,
            recall_target=0.95) for _ in range(4)]
        data.rng = np.random.default_rng(60 + ti)
        qb = [q.HybridQuery(ranks=[q.VectorRank(
            "embedding", data.query_vec(), 1.0)], k=10)
            for _ in range(4)]
        plans = [planner_lib.plan(ex.catalog, qi) for qi in qa]
        assert all(p.graph for p in plans)
        for p in plans:                 # widen until top-k is covered
            p.graph_beam = int(fs.KMAX)
            p.graph_hops = 12
        graph = ex.execute_many(qa, plans=plans)
        exact = ex.execute_many(qb)
        assert _results(graph) == _results(exact)
        for (_, sg), (_, se) in zip(graph, exact):
            assert "dispatch=graph" in sg.plan


def test_graph_filtered_parity(graph_store):
    """Filtered graph queries stay correct: the dual-accumulator kernel
    walks through rejected rows but only admits bitmap-passing ones."""
    store, data = graph_store
    ex = Executor(store)
    data.rng = np.random.default_rng(123)
    qa = [q.HybridQuery(where=q.Range("time", 100, 600),
                        ranks=[q.VectorRank("embedding", data.query_vec(),
                                            1.0)],
                        k=10, recall_target=0.95) for _ in range(4)]
    data.rng = np.random.default_rng(123)
    qb = [q.HybridQuery(where=q.Range("time", 100, 600),
                        ranks=[q.VectorRank("embedding", data.query_vec(),
                                            1.0)], k=10)
          for _ in range(4)]
    plans = [planner_lib.plan_shared_scan(ex.catalog, qi) for qi in qa]
    assert all(p.graph for p in plans)
    for p in plans:
        p.graph_beam = int(fs.KMAX)
        p.graph_hops = 12
    graph = ex.execute_many(qa, plans=plans)
    exact = ex.execute_many(qb)
    assert _results(graph) == _results(exact)


def test_sharded_graph_parity():
    """Sharded scatter-gather threads the graph choice through and
    matches the sharded exact path at high recall target."""
    cfg = tracy.TracyConfig(n_rows=2400, dim=128, seed=11, flush_rows=600,
                            fanout=64)
    data = tracy.TracyData(cfg)
    router = ShardRouter(tracy.tweet_schema(cfg.dim, IndexKind.GRAPH),
                         tracy.LSMConfig(flush_rows=cfg.flush_rows,
                                         fanout=cfg.fanout,
                                         quantize_vectors=False),
                         n_shards=2)
    done = 0
    while done < cfg.n_rows:
        n = min(cfg.flush_rows, cfg.n_rows - done)
        pks, batch = data.batch(n)
        router.put(pks, batch)
        done += n
    router.flush()
    ex = ShardedExecutor(router)
    data.rng = np.random.default_rng(77)
    qa = [q.HybridQuery(ranks=[q.VectorRank(
        "embedding", data.query_vec(), 1.0)], k=10, recall_target=0.95)
        for _ in range(4)]
    data.rng = np.random.default_rng(77)
    qb = [q.HybridQuery(ranks=[q.VectorRank(
        "embedding", data.query_vec(), 1.0)], k=10) for _ in range(4)]
    plan = ex.plan(qa[0])
    assert plan.graph
    assert "dispatch=graph(R=" in plan.describe()
    logical = plan.logical
    logical.graph_beam = int(fs.KMAX)
    logical.graph_hops = 12
    graph = ex.execute_many(qa, plans=[logical] * len(qa))
    exact = ex.execute_many(qb)
    assert _results(graph) == _results(exact)
    for _, st in graph:
        assert st.shards == 2
