"""Columnar write path: vectorized memtable, flush scheduler +
backpressure, mergeable per-segment indexes, and compaction correctness.
"""
import numpy as np
import pytest

from conftest import make_batch, tweet_schema
from repro.core import query as q
from repro.core import visibility as vis_lib
from repro.core.executor import Executor
from repro.core.index import default_index_factory
from repro.core.lsm import LSMConfig, LSMStore
from repro.core.memtable import MemTable
from repro.core.types import Column, ColumnType, IndexKind, Schema
from repro.kernels import ops as kops


# --------------------------------------------------------------- memtable

def test_columnar_memtable_roundtrip():
    rng = np.random.default_rng(0)
    m = MemTable(tweet_schema())
    pks, batch = make_batch(rng, 100)
    nxt = m.put_batch(pks, batch, seqno_start=7)
    assert nxt == 107 and len(m) == 100
    row = m.get(42)
    assert row["_seqno"] == 49 and not row["_tombstone"]
    np.testing.assert_allclose(row["embedding"], batch["embedding"][42])
    pk, seqno, tomb, cols = m.scan_arrays()
    assert pk.dtype == np.int64 and tomb.dtype == bool
    assert cols["embedding"].shape == (100, 16)
    assert cols["time"].dtype == np.float64
    assert cols["content"].dtype == object
    # chunked appends concatenate in order
    pks2, batch2 = make_batch(rng, 50, pk_start=100)
    m.put_batch(pks2, batch2, seqno_start=nxt)
    pk, _, _, cols = m.scan_arrays()
    assert len(pk) == 150
    np.testing.assert_allclose(cols["coordinate"][100:],
                               batch2["coordinate"])


def test_approx_bytes_counts_text_payload():
    rng = np.random.default_rng(1)
    schema = tweet_schema()
    small, big = MemTable(schema), MemTable(schema)
    pks, batch = make_batch(rng, 64)
    small.put_batch(pks, batch, 0)
    batch_big = dict(batch)
    batch_big["content"] = np.asarray(["x" * 10_000] * 64, object)
    big.put_batch(pks, batch_big, 0)
    # the old flat 24-bytes-per-TEXT-cell estimate made these equal
    assert big.approx_bytes > small.approx_bytes + 64 * 9_000


def test_flush_by_bytes_threshold():
    rng = np.random.default_rng(2)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=10**9,
                                               flush_bytes=200_000))
    pks, batch = make_batch(rng, 100)
    batch["content"] = np.asarray(["y" * 4_000] * 100, object)
    store.put(pks, batch)
    assert store.metrics["flushes"] >= 1          # bytes, not rows, tripped


def test_put_empty_batch_is_noop():
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=64))
    calls = []
    store.on_delta(lambda pks, batch, deleted: calls.append(len(pks)))
    before = dict(store.metrics)
    seq = store._seqno
    store.put([], {c.name: [] for c in store.schema.columns})
    assert calls == []
    assert store.metrics == before and store._seqno == seq


def test_delete_of_never_written_pks_keeps_fast_path():
    rng = np.random.default_rng(3)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=64))
    pks, batch = make_batch(rng, 64)
    store.put(pks, batch)
    calls = []
    store.on_delta(lambda p, b, d: calls.append((list(map(int, p)), d)))
    store.delete([500, 600])                      # never written: no-op
    assert store.unique_pks is True
    assert store.metrics["deletes"] == 0
    assert store.metrics["noop_deletes"] == 2
    assert calls == []
    # partial overlap: only the existing pk is tombstoned
    store.delete([6, 700])
    assert store.unique_pks is False
    assert store.get(6) is None and store.get(7) is not None
    assert store.metrics["deletes"] == 1
    assert store.metrics["noop_deletes"] == 3
    assert calls == [([6], True)]


# ------------------------------------------------- scheduler / pipelining

def _fill(store, rng, n, pk_start=0, batch=128):
    done = 0
    while done < n:
        m = min(batch, n - done)
        pks, b = make_batch(rng, m, pk_start=pk_start + done)
        store.put(pks, b)
        done += m


def test_pipelined_reads_see_sealed_memtables():
    rng = np.random.default_rng(4)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=128,
                                               pipeline=True))
    _fill(store, rng, 400)
    assert len(store.sealed) >= 1 and store.metrics["flushes"] == 0
    # point reads and query paths cover sealed + active rows
    assert store.get(5) is not None and store.get(399) is not None
    ex = Executor(store)
    res, _ = ex.execute(q.HybridQuery(where=[q.Range("time", 0, 100)],
                                      k=500))
    assert len(res) == 400


def test_drain_visibility_equivalence():
    rng = np.random.default_rng(5)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=100, fanout=3,
                                               pipeline=True))
    _fill(store, rng, 350)
    _, upd = make_batch(rng, 30, pk_start=40)
    store.put(list(range(40, 70)), upd)           # updates
    store.delete(list(range(10, 20)))             # deletes
    assert len(store.sealed) >= 1
    ex = Executor(store)
    query = q.HybridQuery(where=[q.Range("time", 0, 100)], k=1000)
    before_rows = {r.pk: r.values["time"] for r in ex.execute(query)[0]}
    before_gets = {pk: store.get(pk) and store.get(pk)["time"]
                   for pk in range(0, 350, 7)}
    flushed = store.drain()
    assert flushed and store.metrics["flushes"] >= 3
    after_rows = {r.pk: r.values["time"] for r in ex.execute(query)[0]}
    after_gets = {pk: store.get(pk) and store.get(pk)["time"]
                  for pk in range(0, 350, 7)}
    assert before_rows == after_rows
    assert before_gets == after_gets
    for pk in range(10, 20):
        assert store.get(pk) is None


def test_flush_extends_visibility_cache_incrementally():
    rng = np.random.default_rng(6)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=10**9))
    _fill(store, rng, 200)
    _, upd = make_batch(rng, 10, pk_start=50)
    store.put(list(range(50, 60)), upd)
    vis_before = vis_lib.visibility_index(store)   # build + cache
    store.flush()
    assert store.metrics["vis_extends"] == 1
    vis_after = vis_lib.visibility_index(store)
    assert vis_after is vis_before                 # remapped, not rebuilt
    # equivalence vs a from-scratch rebuild
    fresh = vis_lib.VisibilityIndex(store)
    np.testing.assert_array_equal(vis_after._winners, fresh._winners)
    np.testing.assert_array_equal(vis_after._win_pk, fresh._win_pk)
    np.testing.assert_array_equal(vis_after._win_sid, fresh._win_sid)
    np.testing.assert_array_equal(vis_after._win_row, fresh._win_row)


def test_backpressure_write_stall():
    rng = np.random.default_rng(7)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=64, fanout=4,
                                               pipeline=True,
                                               max_sealed=2))
    _fill(store, rng, 1500, batch=64)
    # the stall threshold bounds queued memtables even with no drain()
    assert len(store.sealed) <= 2
    assert store.metrics["stalls"] > 0
    assert store.metrics["flushes"] > 0            # writer self-drained
    store.drain()
    assert store.n_rows == 1500


def test_background_scheduler_smoke():
    rng = np.random.default_rng(8)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=128,
                                               pipeline=True,
                                               background=True))
    _fill(store, rng, 600)
    store.drain()
    store.scheduler.close()
    assert store.metrics["flushes"] >= 4
    assert store.n_rows == 600
    assert all(store.get(pk) is not None for pk in range(0, 600, 53))


def test_pipelined_store_matches_inline_store():
    def build(pipeline):
        rng = np.random.default_rng(9)
        store = LSMStore(tweet_schema(), LSMConfig(flush_rows=100,
                                                   fanout=3,
                                                   pipeline=pipeline))
        _fill(store, rng, 500)
        _, upd = make_batch(rng, 20, pk_start=100)
        store.put(list(range(100, 120)), upd)
        store.delete(list(range(200, 215)))
        store.flush()
        return store

    a, b = build(False), build(True)
    # physical version counts may differ (compaction timing), the
    # *visible* state may not
    assert {pk for pk in range(520) if a.get(pk) is not None} == \
        {pk for pk in range(520) if b.get(pk) is not None}
    ex_a, ex_b = Executor(a), Executor(b)
    for where in ([q.Range("time", 10, 60)],
                  [q.TextContains("content", "apple")]):
        ra, _ = ex_a.execute(q.HybridQuery(where=where, k=1000))
        rb, _ = ex_b.execute(q.HybridQuery(where=where, k=1000))
        assert {r.pk for r in ra} == {r.pk for r in rb}


# -------------------------------------------------- compaction correctness

def test_interleaved_put_update_delete_across_tiers():
    rng = np.random.default_rng(10)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=80, fanout=3,
                                               max_levels=4))
    model = {}
    for round_ in range(12):
        base = round_ * 60
        pks, batch = make_batch(rng, 60, pk_start=base)
        store.put(pks, batch)
        for i, pk in enumerate(pks):
            model[pk] = batch["time"][i]
        if round_ % 2 == 1:                      # update an older stripe
            upd_pks = list(range(base - 30, base))
            _, upd = make_batch(rng, 30)
            store.put(upd_pks, upd)
            for i, pk in enumerate(upd_pks):
                model[pk] = upd["time"][i]
        if round_ % 3 == 2:                      # delete a scattered set
            dels = list(range(base, base + 10))
            store.delete(dels)
            for pk in dels:
                model.pop(pk, None)
    store.flush()
    assert store.metrics["compactions"] >= 2
    assert {s.level for s in store.segments} != {0}
    for pk in range(0, 720, 3):
        want = model.get(pk)
        got = store.get(pk)
        if want is None:
            assert got is None, pk
        else:
            assert got is not None and got["time"] == want, pk
    assert store.n_rows >= len(model)


def test_tombstones_dropped_only_at_bottom_level():
    rng = np.random.default_rng(11)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=10**9, fanout=3,
                                               max_levels=5))
    # deep tier first: three flushes -> one level-1 segment
    for start in (0, 100, 200):
        pks, batch = make_batch(rng, 100, pk_start=start)
        store.put(pks, batch)
        store.flush()
    assert [s.level for s in store.segments] == [1]
    # tombstones for pks living in the deep tier + two filler flushes
    store.delete(list(range(0, 25)))
    store.flush()
    for start in (300, 400):
        pks, batch = make_batch(rng, 50, pk_start=start)
        store.put(pks, batch)
        store.flush()                   # third L0 -> compact over deep L1
    upper = [s for s in store.segments if s.level == 1 and
             s.tombstone.any()]
    assert upper, "tombstones must survive non-bottom compaction"
    for pk in (0, 10, 24):
        assert store.get(pk) is None
    # force the bottom merge: level-1 tier reaches fanout
    for start in (500, 600, 700):
        pks, batch = make_batch(rng, 50, pk_start=start)
        store.put(pks, batch)
        store.flush()
    assert any(s.level >= 2 for s in store.segments)
    assert all(not s.tombstone.any() for s in store.segments
               if s.level >= 2), "bottom merge must drop tombstones"
    for pk in (0, 10, 24):
        assert store.get(pk) is None
    assert store.get(25) is not None


def _compacted_store(merge_indexes: bool):
    rng = np.random.default_rng(12)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=150, fanout=3,
                                               merge_indexes=merge_indexes))
    _fill(store, rng, 450, batch=150)
    _, upd = make_batch(rng, 40, pk_start=60)
    store.put(list(range(60, 100)), upd)
    store.delete(list(range(20, 35)))
    store.flush()
    return store


def test_merged_indexes_equal_rebuilt_indexes():
    store = _compacted_store(merge_indexes=True)
    assert store.metrics["index_merges"] > 0
    rng = np.random.default_rng(13)
    merged = [s for s in store.segments if s.level >= 1]
    assert merged
    for seg in merged:
        rebuilt = {}
        for col in store.schema.indexed_columns:
            idx = default_index_factory(col)
            idx.build(seg, col)
            rebuilt[col.name] = idx
        # scalar: range bitmaps identical
        for _ in range(5):
            lo = float(rng.uniform(0, 80))
            pred = q.Range("time", lo, lo + 15)
            np.testing.assert_array_equal(
                seg.indexes["time"].bitmap(seg, pred),
                rebuilt["time"].bitmap(seg, pred))
        # text: term bitmaps + BM25 stats identical
        t_merged, t_rebuilt = seg.indexes["content"], rebuilt["content"]
        assert set(t_merged.postings) == set(t_rebuilt.postings)
        assert t_merged.n_docs == t_rebuilt.n_docs
        np.testing.assert_allclose(t_merged.doc_len, t_rebuilt.doc_len)
        for term in ("apple", "golf", "hotel"):
            pred = q.TextContains("content", term)
            np.testing.assert_array_equal(t_merged.bitmap(seg, pred),
                                          t_rebuilt.bitmap(seg, pred))
            sm, rm = t_merged._bm25([term]), t_rebuilt._bm25([term])
            assert dict(zip(sm[1].tolist(), sm[0].tolist())) == \
                pytest.approx(dict(zip(rm[1].tolist(), rm[0].tolist())))
        # spatial: rect bitmaps identical
        for _ in range(5):
            x, y = rng.uniform(0, 8, 2)
            pred = q.GeoWithin("coordinate",
                               (float(x), float(y), float(x + 2),
                                float(y + 2)))
            np.testing.assert_array_equal(
                seg.indexes["coordinate"].bitmap(seg, pred),
                rebuilt["coordinate"].bitmap(seg, pred))
        # vector: full-probe search is exact for both -> identical top-k
        iv_m, iv_r = seg.indexes["embedding"], rebuilt["embedding"]
        assert set(iv_m.post_rows.tolist()) == set(iv_r.post_rows.tolist())
        for _ in range(3):
            qv = rng.normal(size=16).astype(np.float32)
            dm, rm_, _ = iv_m.search(qv, 10, n_probe=len(iv_m.centroids))
            dr, rr, _ = iv_r.search(qv, 10, n_probe=len(iv_r.centroids))
            np.testing.assert_allclose(dm, dr, rtol=1e-5)
            assert rm_.tolist() == rr.tolist()


def test_merge_results_match_rebuild_results_end_to_end():
    a = _compacted_store(merge_indexes=True)
    b = _compacted_store(merge_indexes=False)
    assert a.metrics["index_merges"] > 0 and b.metrics["index_merges"] == 0
    ex_a, ex_b = Executor(a), Executor(b)
    rng = np.random.default_rng(14)
    for _ in range(4):
        lo = float(rng.uniform(0, 70))
        where = [q.Range("time", lo, lo + 20)]
        ra, _ = ex_a.execute(q.HybridQuery(where=where, k=1000))
        rb, _ = ex_b.execute(q.HybridQuery(where=where, k=1000))
        assert {r.pk for r in ra} == {r.pk for r in rb}


# ------------------------------------------- quantized codebook donation

def _pqivf_schema():
    return Schema([
        Column("embedding", ColumnType.VECTOR, dim=16,
               index=IndexKind.PQIVF),
        Column("coordinate", ColumnType.SPATIAL, index=IndexKind.ZORDER),
        Column("content", ColumnType.TEXT, index=IndexKind.INVERTED),
        Column("time", ColumnType.SCALAR, index=IndexKind.BTREE),
    ])


def test_ivf_merge_donates_pq_codebooks():
    rng = np.random.default_rng(21)
    store = LSMStore(_pqivf_schema(), LSMConfig(flush_rows=150, fanout=3))
    _fill(store, rng, 300, batch=150)
    part_books = [s.indexes["embedding"].codebooks.copy()
                  for s in store.segments]
    assert all(b is not None for b in part_books)
    _fill(store, rng, 150, pk_start=300, batch=150)   # trips the fanout
    merged = [s for s in store.segments if s.level >= 1]
    assert len(merged) == 1 and store.metrics["index_merges"] > 0
    idx = merged[0].indexes["embedding"]
    # the merged index keeps a donor part's codebooks bitwise — reuse,
    # never a k-means retrain at compaction
    assert any(np.array_equal(idx.codebooks, b) for b in part_books)
    # and the codes are the nearest-codeword re-encode under the donated
    # books, in posting-list (grouped) order
    vecs = np.asarray(merged[0].columns["embedding"],
                      np.float32)[idx.post_rows]
    m, _, dsub = idx.codebooks.shape
    assert m == idx.pq_m
    expect = np.stack(
        [kops.assign_nearest(vecs[:, j * dsub:(j + 1) * dsub],
                             idx.codebooks[j]) for j in range(m)],
        axis=1).astype(np.uint8)
    np.testing.assert_array_equal(idx.codes, expect)


def test_compaction_donates_quantized_residence_books(monkeypatch):
    from repro.core import quantize as qz
    rng = np.random.default_rng(22)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=150, fanout=3))
    _fill(store, rng, 300, batch=150)
    book_id, books = store._pq_books["embedding"]
    # give one part a foreign book: only its rows may be re-encoded
    seg_f = store.segments[1]
    foreign = qz.quantize_column(
        np.asarray(seg_f.columns["embedding"], np.float32), seed=99)
    assert foreign.book_id != book_id
    seg_f.quantized["embedding"] = foreign
    donor = store.segments[0]
    donor_codes = {int(p): donor.quantized["embedding"].codes[i].copy()
                   for i, p in enumerate(donor.pk)}
    encoded, real_encode = [], qz.encode

    def spy(vecs, codebooks):
        encoded.append(len(vecs))
        return real_encode(vecs, codebooks)

    monkeypatch.setattr(qz, "encode", spy)
    _fill(store, rng, 150, pk_start=300, batch=150)   # trips the fanout
    merged = [s for s in store.segments if s.level >= 1]
    assert len(merged) == 1 and merged[0].n_rows == 450
    qc = merged[0].quantized["embedding"]
    # the donated book survives the whole level drop: same identity,
    # bitwise-equal codebooks
    assert qc.book_id == book_id
    np.testing.assert_array_equal(qc.codebooks, books)
    # donor-part codes rode through the compaction row maps verbatim
    pk_row = {int(p): i for i, p in enumerate(merged[0].pk)}
    for p, c in donor_codes.items():
        np.testing.assert_array_equal(qc.codes[pk_row[p]], c)
    # the encoder ran for the new flush (150 rows) plus the foreign-book
    # part (150 rows) only — donor-book rows were copied, not re-encoded
    assert sum(encoded) == 300
    # and the result is still the faithful nearest-codeword encoding
    np.testing.assert_array_equal(
        qc.codes, real_encode(
            np.asarray(merged[0].columns["embedding"], np.float32), books))
