"""Shared fixtures. NOTE: do NOT set xla_force_host_platform_device_count
here — smoke tests and benches must see 1 device (the dry-run sets its own
flags in its own process)."""
import numpy as np
import pytest

from repro.core.lsm import LSMConfig, LSMStore
from repro.core.types import Column, ColumnType, IndexKind, Schema

WORDS = ["apple", "banana", "cherry", "delta", "echo", "foxtrot",
         "golf", "hotel"]


def tweet_schema(dim: int = 16) -> Schema:
    return Schema([
        Column("embedding", ColumnType.VECTOR, dim=dim, index=IndexKind.IVF),
        Column("coordinate", ColumnType.SPATIAL, index=IndexKind.ZORDER),
        Column("content", ColumnType.TEXT, index=IndexKind.INVERTED),
        Column("time", ColumnType.SCALAR, index=IndexKind.BTREE),
    ])


def make_batch(rng, n, dim=16, pk_start=0):
    return list(range(pk_start, pk_start + n)), {
        "embedding": rng.normal(size=(n, dim)).astype(np.float32),
        "coordinate": rng.uniform(0, 10, (n, 2)).astype(np.float32),
        "content": np.asarray(
            [" ".join(rng.choice(WORDS, 3)) for _ in range(n)], object),
        "time": rng.uniform(0, 100, n),
    }


@pytest.fixture(scope="module")
def small_store():
    rng = np.random.default_rng(7)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=512))
    data = {"embedding": [], "coordinate": [], "content": [], "time": []}
    for i in range(0, 3000, 500):
        pks, batch = make_batch(rng, 500, pk_start=i)
        store.put(pks, batch)
        for k in data:
            data[k].append(batch[k])
    store.flush()
    ref = {k: np.concatenate(v) for k, v in data.items()}
    return store, ref
