"""Tests for the unified observability layer: span tracer, metrics
registry, slow-query log, and EXPLAIN ANALYZE (drift exactness + bitwise
result parity across every dispatch kind, unsharded and sharded).
"""
import json

import numpy as np
import pytest

from benchmarks import tracy
from repro.core import query as q
from repro.core.api import (Column, ColumnType, Database, IndexKind, Range,
                            Schema, VectorRank)
from repro.core.executor import Executor
from repro.core.lsm import LSMConfig
from repro.core.optimizer import planner as planner_lib
from repro.core.shards import ShardedExecutor, ShardRouter
from repro.kernels import ops as kops
from repro.obs import (REGISTRY, SLOW_LOG, TRACER, MetricsRegistry,
                       actuals_from, set_tracing, span)
from repro.obs import trace as obs_trace


@pytest.fixture(autouse=True)
def _obs_isolation():
    """Leave tracing off and the retention/slow-log buffers empty."""
    yield
    set_tracing(False)
    TRACER.clear()
    SLOW_LOG.configure(None)
    SLOW_LOG.clear()


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_tracing_disabled_by_default():
    assert not obs_trace.enabled()
    before = len(TRACER.snapshot())
    sp = span("anything", rows=3)
    assert sp is obs_trace.NULL_SPAN and not sp.live
    with span("outer"):
        with span("inner") as inner:
            inner.set(rows=1)       # discarded, no error
    assert len(TRACER.snapshot()) == before
    assert obs_trace.current_span() is None


def test_span_nesting_and_retention():
    set_tracing(True)
    TRACER.clear()
    with span("flush", rows=10) as outer:
        assert outer.live and obs_trace.current_span() is outer
        with span("operator:X") as inner:
            inner.add("bytes", 64)
            inner.add("bytes", 36)
    roots = TRACER.snapshot()
    assert [r.name for r in roots] == ["flush"]
    (root,) = roots
    assert root.attrs == {"rows": 10} and root.dur >= 0.0
    assert [c.name for c in root.children] == ["operator:X"]
    assert root.children[0].attrs == {"bytes": 100}


def test_force_tracing_restores_prior_state():
    assert not obs_trace.enabled()
    with obs_trace.force_tracing():
        assert obs_trace.enabled()
        with pytest.raises(RuntimeError), obs_trace.force_tracing():
            assert obs_trace.enabled()
            raise RuntimeError("boom")
        assert obs_trace.enabled()
    assert not obs_trace.enabled()


def test_record_span_attaches_to_open_parent():
    set_tracing(True)
    TRACER.clear()
    with span("query") as sp:
        obs_trace.record_span("operator:Scan", 0.002, rows=7)
    assert [c.name for c in sp.children] == ["operator:Scan"]
    child = sp.children[0]
    assert child.attrs["rows"] == 7
    assert child.dur == pytest.approx(0.002)
    # without a parent it lands in the ring buffer
    obs_trace.record_span("flush", 0.001)
    assert [r.name for r in TRACER.snapshot()] == ["query", "flush"]


def test_chrome_trace_export_and_tree():
    set_tracing(True)
    TRACER.clear()
    with span("query", n=2):
        with span("operator:TopKMerge", k=5):
            pass
    doc = json.loads(TRACER.chrome_trace())
    names = [e["name"] for e in doc["traceEvents"]]
    assert sorted(names) == ["operator:TopKMerge", "query"]
    for e in doc["traceEvents"]:
        assert e["ph"] == "X" and e["dur"] >= 0.0
    dump = TRACER.tree()
    assert "query" in dump and "  operator:TopKMerge" in dump


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    reg.inc("x.count")
    reg.inc("x.count", 4)
    reg.set_gauge("x.depth", 3.5)
    for v in (0.001, 0.002, 0.004, 0.2):
        reg.observe("x.latency_s", v)
    snap = reg.snapshot()
    assert snap["x.count"] == {"type": "counter", "value": 5}
    assert snap["x.depth"]["value"] == 3.5
    h = snap["x.latency_s"]
    assert h["count"] == 4 and h["sum"] == pytest.approx(0.207)
    # interpolated percentiles stay inside the observed range
    hist = reg.histogram("x.latency_s")
    for qq_ in (0.5, 0.95, 0.99):
        assert 0.001 <= hist.percentile(qq_) <= 0.2
    assert hist.p50 <= hist.p95 <= hist.p99


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.inc("a.b")
    with pytest.raises(TypeError):
        reg.observe("a.b", 0.1)


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.inc("query.count", 3)
    reg.observe("query.latency_s", 0.004)
    reg.observe("query.latency_s", 0.040)
    text = reg.prometheus_text()
    lines = text.splitlines()
    assert "# TYPE repro_query_count counter" in lines
    assert "repro_query_count 3" in lines
    assert "# TYPE repro_query_latency_s histogram" in lines
    # cumulative bucket counts are monotone and end at the total
    cums = [int(ln.rsplit(" ", 1)[1]) for ln in lines
            if ln.startswith("repro_query_latency_s_bucket")]
    assert cums == sorted(cums) and cums[-1] == 2
    assert "repro_query_latency_s_count 2" in lines
    for quant in ("p50", "p95", "p99"):
        assert any(ln.startswith(f"repro_query_latency_s_{quant} ")
                   for ln in lines)


def test_kernel_counters_survive_registry_reset():
    kops._dispatched(128)
    kops.flush_registry_counters()   # publish the pending delta
    REGISTRY.reset()                 # drops metrics, bumps generation
    kops._dispatched(256)
    kops.flush_registry_counters()   # cached refs must re-resolve
    assert REGISTRY.get("kernels.launches").value == 1
    assert REGISTRY.get("kernels.bytes_to_host").value == 256


def test_kernel_counters_batch_to_registry():
    """The per-dispatch mirror is batched: deltas publish every
    REG_FLUSH_EVERY dispatches without an explicit flush call."""
    REGISTRY.reset()
    kops.flush_registry_counters()   # zero the thread's pending delta
    REGISTRY.reset()
    for _ in range(kops.REG_FLUSH_EVERY):
        kops._dispatched(4)
    assert REGISTRY.get("kernels.launches").value == kops.REG_FLUSH_EVERY
    assert (REGISTRY.get("kernels.bytes_to_host").value
            == 4 * kops.REG_FLUSH_EVERY)


def test_slow_query_log_threshold():
    SLOW_LOG.configure(0.01)
    assert not SLOW_LOG.maybe_record(0.005, "plan-fast")
    assert SLOW_LOG.maybe_record(0.02, "plan-slow", n_queries=3)
    (entry,) = SLOW_LOG.snapshot()
    assert entry["plan"] == "plan-slow" and entry["n_queries"] == 3
    assert entry["latency_s"] == 0.02 and entry["span_tree"] is None


# ---------------------------------------------------------------------------
# EXPLAIN ANALYZE: drift exactness + result parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tracy_ex():
    cfg = tracy.TracyConfig(n_rows=1200, dim=32, seed=7, flush_rows=300,
                            fanout=64, pq_m=16)
    store, data = tracy.build_store(cfg)
    return Executor(store), data


@pytest.fixture(scope="module")
def graph_ex():
    cfg = tracy.TracyConfig(n_rows=1200, dim=32, seed=9, flush_rows=300,
                            fanout=64)
    store, data = tracy.build_store(cfg, vector_index=IndexKind.GRAPH,
                                    quantize=False)
    return Executor(store), data


def _pairs(rows):
    return [(r.pk, float(r.score)) for r in rows]


def test_analyze_drift_exact_on_tracy_templates(tracy_ex):
    """Per-query span-charged rows/bytes must equal ExecStats exactly:
    the analyze annotations are the cost model's ground truth."""
    ex, data = tracy_ex
    search, nn = tracy.make_templates(data)
    data.rng = np.random.default_rng(42)
    checked = 0
    for tmpl in search + nn:
        qq = tmpl()
        plan = planner_lib.plan(ex.catalog, qq)
        if plan.kind in ("nra", "postfilter_nn"):
            # index-walk dispatches do not itemize per-operator charges;
            # the scan shape of the same query must
            plan = planner_lib.plan_shared_scan(ex.catalog, qq)
        an = ex.explain_analyze(qq, plan=plan)
        rows = sum(a["rows"] for a in an.actuals.values())
        byts = sum(a["bytes"] for a in an.actuals.values())
        assert rows == an.stats.rows_scanned, an.text
        assert byts == an.stats.bytes_scanned, an.text
        checked += 1
    assert checked == len(search) + len(nn)


def test_analyze_annotates_every_operator(tracy_ex):
    ex, data = tracy_ex
    data.rng = np.random.default_rng(3)
    qq = q.HybridQuery(
        where=q.Range("time", 100.0, 600.0),
        ranks=[q.VectorRank("embedding", data.query_vec(), 1.0)], k=10)
    an = ex.explain_analyze(qq)
    lines = an.text.splitlines()
    assert lines[0].endswith("(analyzed)")
    ops_lines = [ln for ln in lines[1:] if "-> " in ln]
    assert ops_lines, an.text
    for ln in ops_lines:
        assert "(actual" in ln, ln
    # estimated nodes render estimated-vs-actual drift
    assert any("drift=" in ln and "drift=-" not in ln for ln in ops_lines), \
        an.text


def test_analyze_parity_all_dispatch_kinds(tracy_ex, graph_ex):
    """Analyze-mode results are bitwise-identical to plain execution on
    the exact, fused, quantized, and graph dispatches."""
    ex, data = tracy_ex
    gex, gdata = graph_ex
    data.rng = np.random.default_rng(11)
    gdata.rng = np.random.default_rng(11)
    rank = q.VectorRank("embedding", data.query_vec(), 1.0)
    base = dict(kind="full_scan_nn", ranks=[rank], k=10)
    cases = [
        (ex, planner_lib.Plan(fused=False, **base)),            # exact
        (ex, planner_lib.Plan(fused=True, **base)),             # fused
        (ex, planner_lib.Plan(fused=True, quantized=True,       # quantized
                              pq_m=16, refine=4, **base)),
        (gex, planner_lib.Plan(                                 # graph
            kind="full_scan_nn", k=10, graph=True, graph_r=16,
            graph_beam=40, graph_hops=8,
            ranks=[q.VectorRank("embedding", gdata.query_vec(), 1.0)])),
    ]
    for exec_, plan in cases:
        qq = q.HybridQuery(ranks=list(plan.ranks), k=plan.k)
        plain, _ = exec_.execute(qq, plan)
        an = exec_.explain_analyze(qq, plan=plan)
        assert _pairs(an.results) == _pairs(plain), plan.describe()
        assert "(actual" in an.text


def test_analyze_parity_sharded():
    cfg = tracy.TracyConfig(n_rows=1000, dim=16, seed=5, flush_rows=250)
    data = tracy.TracyData(cfg)
    router = ShardRouter(tracy.tweet_schema(cfg.dim, IndexKind.IVF),
                         LSMConfig(flush_rows=cfg.flush_rows),
                         n_shards=4)
    done = 0
    while done < cfg.n_rows:
        pks, batch = data.batch(250)
        router.put(pks, batch)
        done += 250
    router.flush()
    sex = ShardedExecutor(router)
    qq = q.HybridQuery(
        where=q.Range("time", 0.0, 700.0),
        ranks=[q.VectorRank("embedding", data.query_vec(), 1.0)], k=8)
    plain, _ = sex.execute(qq)
    an = sex.explain_analyze(qq)
    assert _pairs(an.results) == _pairs(plain)
    assert an.per_shard is not None and len(an.per_shard) == 4
    shard_lines = [ln for ln in an.text.splitlines() if "-> Shard [" in ln]
    assert len(shard_lines) == 4
    for ln in shard_lines:
        assert "(actual" in ln, ln


def test_analyze_leaves_tracing_off(tracy_ex):
    ex, data = tracy_ex
    data.rng = np.random.default_rng(23)
    qq = q.HybridQuery(where=q.Range("time", 0.0, 400.0), k=5)
    assert not obs_trace.enabled()
    ex.explain_analyze(qq)
    assert not obs_trace.enabled()
    # and a plain execute under the default records no spans
    before = len(TRACER.snapshot())
    ex.execute(qq)
    assert len(TRACER.snapshot()) == before


# ---------------------------------------------------------------------------
# facade: Database.metrics / metrics_text / slow_queries
# ---------------------------------------------------------------------------

def _mini_db(shards=1):
    sch = Schema([
        Column("emb", ColumnType.VECTOR, dim=8, index=IndexKind.IVF),
        Column("t", ColumnType.SCALAR, index=IndexKind.BTREE)])
    db = Database(sch, shards=shards)
    rng = np.random.default_rng(0)
    n = 600
    db.table().put(np.arange(n), {
        "emb": rng.standard_normal((n, 8)).astype(np.float32),
        "t": np.arange(n, dtype=np.float64)})
    db.table().flush()
    return db, rng


def test_database_metrics_and_prometheus():
    db, rng = _mini_db(shards=2)
    qb = (db.table().query().where(Range("t", 0, 300))
          .rank(VectorRank("emb", rng.standard_normal(8).astype(np.float32)))
          .limit(5))
    assert qb.all()
    m = db.metrics()
    assert "query.latency_s" in m["registry"]
    assert m["registry"]["query.count"]["value"] >= 1
    tbl = m["tables"]["default"]
    assert tbl["store"]["puts"] == 600
    assert sorted(tbl["shards"]) == [0, 1]
    assert sum(s["puts"] for s in tbl["shards"].values()) == 600
    assert tbl["executor"]["queries"] >= 1
    text = db.metrics_text()
    for needle in ("repro_query_latency_s_p50", "repro_query_latency_s_p95",
                   "repro_query_latency_s_p99", "repro_lsm_puts",
                   "repro_kernels_launches"):
        assert needle in text, needle


def test_database_slow_queries_and_builder_analyze():
    db, rng = _mini_db()
    SLOW_LOG.configure(0.0)          # everything is "slow"
    qb = (db.table().query().where(Range("t", 0, 300))
          .rank(VectorRank("emb", rng.standard_normal(8).astype(np.float32)))
          .limit(5))
    plain = qb.all()
    an = qb.explain(analyze=True)
    assert _pairs(an.results) == _pairs(plain)
    assert str(an) == an.text and "(analyzed)" in an.text
    entries = db.slow_queries()
    assert entries and all(e["latency_s"] >= 0.0 for e in entries)
    # the analyze run traced its query, so its entry kept the span tree
    assert any(e["span_tree"] for e in entries)
