"""Property-based fuzz of the WAL record codec (hypothesis).

Two invariants hold for ANY payload and ANY corruption of the log tail:

  * round-trip: encode → decode reproduces every record bitwise
    (dtype, shape, and values — including unicode text and raw bytes);
  * torn-tail safety: truncating the encoded stream at any byte, or
    flipping any byte, makes ``read_records`` stop cleanly at a record
    boundary at or before the damage — it never raises, never returns a
    half-decoded record, and never resynchronizes past corruption.

Deterministic (non-hypothesis) versions of these checks live in
tests/test_durability.py so the guarantee is exercised even where
hypothesis is not installed.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import wal as wal_lib  # noqa: E402

_DTYPES = (np.float32, np.float64, np.int64, np.int32, np.uint8)


@st.composite
def wal_record(draw):
    n = draw(st.integers(min_value=0, max_value=12))
    rtype = draw(st.sampled_from([wal_lib.REC_PUT, wal_lib.REC_DELETE]))
    seqno = draw(st.integers(min_value=0, max_value=2**40))
    pks = np.asarray(
        draw(st.lists(st.integers(min_value=-2**62, max_value=2**62),
                      min_size=n, max_size=n)), np.int64)
    batch = {}
    if rtype == wal_lib.REC_PUT:
        for name in draw(st.lists(
                st.text(min_size=1, max_size=8).filter(
                    lambda s: s != "_pk"),       # reserved by the codec
                max_size=3, unique=True)):
            kind = draw(st.integers(0, 2))
            if kind == 0:
                dt = draw(st.sampled_from(_DTYPES))
                ndim = draw(st.integers(1, 2))
                shape = (n,) if ndim == 1 else (n, draw(st.integers(1, 4)))
                rng = np.random.default_rng(draw(st.integers(0, 2**31)))
                arr = (rng.uniform(-9, 9, shape) * 100).astype(dt)
            elif kind == 1:
                arr = np.asarray(draw(st.lists(
                    st.text(max_size=12), min_size=n, max_size=n)), object)
            else:
                arr = np.asarray(draw(st.lists(
                    st.binary(max_size=12), min_size=n, max_size=n)), object)
            batch[name] = arr
    return wal_lib.WalRecord(rtype, seqno, pks, batch)


@settings(max_examples=60, deadline=None)
@given(st.lists(wal_record(), max_size=4))
def test_roundtrip(records):
    blob = b"".join(
        wal_lib.encode_record(r.rtype, r.seqno_start, r.pks, r.batch)
        for r in records)
    out, good = wal_lib.read_records(blob)
    assert good == len(blob)
    assert len(out) == len(records)
    for orig, dec in zip(records, out):
        assert dec.rtype == orig.rtype
        assert dec.seqno_start == orig.seqno_start
        assert np.array_equal(dec.pks, orig.pks)
        assert sorted(dec.batch) == sorted(orig.batch)
        for name, arr in orig.batch.items():
            got = dec.batch[name]
            if arr.dtype == object:
                assert list(got) == list(arr)
            else:
                assert got.dtype == arr.dtype and got.shape == arr.shape
                assert np.array_equal(got, arr, equal_nan=True)


@settings(max_examples=60, deadline=None)
@given(st.lists(wal_record(), min_size=1, max_size=3), st.data())
def test_any_suffix_damage_stops_at_record_boundary(records, data):
    encoded = [wal_lib.encode_record(r.rtype, r.seqno_start, r.pks, r.batch)
               for r in records]
    blob = b"".join(encoded)
    ends = np.cumsum([len(e) for e in encoded])
    pos = data.draw(st.integers(0, len(blob) - 1), label="damage offset")
    mode = data.draw(st.sampled_from(["truncate", "bitflip"]), label="mode")
    if mode == "truncate":
        damaged = blob[:pos]
    else:
        flip = data.draw(st.integers(1, 255), label="xor")
        damaged = blob[:pos] + bytes([blob[pos] ^ flip]) + blob[pos + 1:]
    out, good = wal_lib.read_records(damaged)
    # never past the damage, always a record boundary at or before it
    intact = int(np.searchsorted(ends, pos, side="right"))
    assert len(out) <= intact
    assert good == (int(ends[len(out) - 1]) if out else 0)
    # everything before the stop still decodes bitwise
    for orig, dec in zip(records, out):
        assert dec.seqno_start == orig.seqno_start
        assert np.array_equal(dec.pks, orig.pks)
