"""Concurrent ingest + query stress test for background mode.

``pipeline=True, background=True`` runs flushes and compactions on a
daemon worker thread while the writer keeps ingesting and query threads
keep reading.  Before this PR the worker published segment lists,
metrics, the visibility cache, and PQ codebooks outside any lock — the
exact findings ``python -m repro.analysis`` (locks family) reports on
the pre-fix tree.  These tests drive all three roles at once and assert
the invariants the locks fixes are supposed to buy:

- queries never raise and never return torn state (duplicate pks,
  unsorted (score, pk) order, rows that were never written);
- after drain, the background store's results and metrics agree exactly
  with an inline twin store fed the same writes (parity);
- the flush worker's metrics writes are not lost (put/flush/seal
  counters add up).
"""
from __future__ import annotations

import threading

import numpy as np

from repro.core import query as q
from repro.core.executor import Executor
from repro.core.lsm import LSMConfig, LSMStore
from repro.core.types import Column, ColumnType, IndexKind, Schema

DIM = 8
N_WRITER_BATCHES = 60
BATCH = 64
FLUSH_ROWS = 128          # small: many flushes + compactions in-flight
N_QUERY_THREADS = 3
QUERIES_PER_THREAD = 40


def make_schema() -> Schema:
    return Schema([
        Column("v", ColumnType.VECTOR, dim=DIM, index=IndexKind.IVF),
        Column("a", ColumnType.SCALAR, index=IndexKind.BTREE),
    ])


def make_store(background: bool) -> LSMStore:
    return LSMStore(make_schema(), LSMConfig(
        flush_rows=FLUSH_ROWS, pipeline=background,
        background=background, max_sealed=2, fanout=3))


def gen_batches(seed: int = 7):
    rng = np.random.default_rng(seed)
    batches = []
    for i in range(N_WRITER_BATCHES):
        pks = np.arange(i * BATCH, (i + 1) * BATCH, dtype=np.int64)
        batches.append((pks, {
            "v": rng.standard_normal((BATCH, DIM)).astype(np.float32),
            "a": rng.uniform(0, 100, BATCH).astype(np.float32),
        }))
    return batches


def nn_query(qv: np.ndarray, k: int = 10) -> q.HybridQuery:
    return q.HybridQuery(where=q.Range("a", 10.0, 90.0),
                         ranks=[q.VectorRank("v", qv)], k=k)


def check_rows(rows, written_pks: set) -> None:
    """Structural invariants every result must satisfy, torn or not."""
    pks = [r.pk for r in rows]
    assert len(pks) == len(set(pks)), f"duplicate pks in result: {pks}"
    key = [(r.score, r.pk) for r in rows]
    assert key == sorted(key), f"result not in (score, pk) order: {key}"
    ghost = [p for p in pks if p not in written_pks]
    assert not ghost, f"result contains never-written pks: {ghost}"


def test_concurrent_ingest_query_no_torn_reads():
    store = make_store(background=True)
    batches = gen_batches()
    all_pks: set = set()
    for pks, _ in batches:
        all_pks.update(pks.tolist())
    ex = Executor(store)
    rng = np.random.default_rng(11)
    qvecs = rng.standard_normal((QUERIES_PER_THREAD, DIM)).astype(
        np.float32)
    errors: list = []
    start = threading.Barrier(N_QUERY_THREADS + 1)

    def writer():
        start.wait()
        for pks, batch in batches:
            store.put(pks, batch)

    def reader():
        start.wait()
        try:
            for qv in qvecs:
                rows, _ = ex.execute(nn_query(qv))
                check_rows(rows, all_pks)
        except Exception as e:  # surfaced on the main thread below
            errors.append(e)

    threads = [threading.Thread(target=writer)] + \
        [threading.Thread(target=reader) for _ in range(N_QUERY_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=90)
        assert not t.is_alive(), "stress thread deadlocked"
    store.scheduler.close()
    if errors:
        raise errors[0]
    # the worker's locked metrics writes must not be lost
    assert store.metrics["puts"] == N_WRITER_BATCHES * BATCH
    assert store.metrics["flushes"] >= 1
    assert store.n_rows == N_WRITER_BATCHES * BATCH


def test_background_matches_inline_after_drain():
    bg = make_store(background=True)
    inline = make_store(background=False)
    batches = gen_batches(seed=23)

    done = threading.Event()

    def hammer():
        # concurrent readers while the writer below ingests: results are
        # checked structurally; exact parity is asserted after drain
        ex = Executor(bg)
        rng = np.random.default_rng(5)
        while not done.is_set():
            qv = rng.standard_normal(DIM).astype(np.float32)
            rows, _ = ex.execute(nn_query(qv))
            check_rows(rows, written)

    written: set = set()
    for pks, _ in batches:
        written.update(pks.tolist())
    t = threading.Thread(target=hammer, daemon=True)
    t.start()
    try:
        for pks, batch in batches:
            bg.put(pks, batch)
            inline.put(pks, batch)
        bg.drain()
        inline.drain()
    finally:
        done.set()
        t.join(timeout=60)
    assert not t.is_alive()
    bg.scheduler.close()

    # exact parity once quiescent: same visible rows, same ranking
    ex_bg, ex_in = Executor(bg), Executor(inline)
    rng = np.random.default_rng(31)
    for _ in range(10):
        qv = rng.standard_normal(DIM).astype(np.float32)
        rows_bg, _ = ex_bg.execute(nn_query(qv, k=15))
        rows_in, _ = ex_in.execute(nn_query(qv, k=15))
        assert [(r.pk, round(r.score, 4)) for r in rows_bg] == \
            [(r.pk, round(r.score, 4)) for r in rows_in]
    assert bg.n_rows == inline.n_rows
    assert bg.metrics["puts"] == inline.metrics["puts"]
    assert bg.metrics["flushes"] == inline.metrics["flushes"]


def test_writer_and_worker_metrics_consistent():
    """Tombstones + duplicate pks force the non-unique visibility path
    while the worker flushes concurrently."""
    store = make_store(background=True)
    rng = np.random.default_rng(3)
    for i in range(30):
        pks = np.arange(i * 50, i * 50 + 50, dtype=np.int64)
        store.put(pks, {
            "v": rng.standard_normal((50, DIM)).astype(np.float32),
            "a": rng.uniform(0, 100, 50).astype(np.float32)})
        if i % 5 == 4:
            store.delete(pks[:10])
    store.drain()
    store.scheduler.close()
    assert store.metrics["puts"] == 30 * 50
    assert store.metrics["deletes"] == 6 * 10
    assert not store.sealed
    # every sealed memtable became a segment or was compacted away
    assert store.metrics["flushes"] == store.metrics["seals"]
    ex = Executor(store)
    rows, _ = ex.execute(nn_query(np.zeros(DIM, np.float32), k=20))
    deleted = {int(p) for i in range(4, 30, 5)
               for p in range(i * 50, i * 50 + 10)}
    assert not [r.pk for r in rows if r.pk in deleted]
