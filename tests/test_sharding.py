"""Sharding rules: safe_spec divisibility/dedup, rule variants, and the
distributed shard_map query path (multi-device via subprocess)."""
import subprocess
import sys

import jax
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import compat_make_mesh
from repro.sharding import partition


def _mesh22():
    return compat_make_mesh((1, 1), ("data", "model"))


def test_safe_spec_drops_indivisible():
    mesh = compat_make_mesh((1,), ("model",))
    # 56 heads on 16-way model: must drop (simulated via mesh dict math)
    spec = partition.safe_spec((56,), ("heads",), mesh, partition.RULES_TRAIN)
    assert spec == P(None) or spec == P("model")   # 1-way always divides


def test_safe_spec_dedups_mesh_axes():
    mesh = _mesh22()
    rules = dict(partition.RULES_TRAIN, kv_seq="model", kv="model")
    spec = partition.safe_spec((4, 32, 8, 16),
                               ("batch", "kv_seq", "kv", None), mesh, rules)
    # "model" may appear at most once
    used = [e for e in spec if e is not None]
    flat = []
    for e in used:
        flat += list(e) if isinstance(e, tuple) else [e]
    assert len(flat) == len(set(flat))


def test_rules_for_variants():
    r = partition.rules_for("train", num_heads=56, tp=16)
    assert r["attn_seq"] == "model"          # yi-34b fallback
    r = partition.rules_for("train", num_heads=32, tp=16)
    assert r["attn_seq"] is None
    r = partition.rules_for("decode", num_heads=56, tp=16)
    assert r["embed"] == "model"             # decode row-parallel fallback
    r = partition.rules_for("decode", num_heads=128, tp=16)
    assert r["embed"] is None
    r = partition.rules_for("long", num_heads=32, tp=16)
    assert r["kv_seq"] == ("pod", "data", "model")


def test_constrain_noop_outside_context():
    import jax.numpy as jnp
    x = jnp.zeros((4, 4))
    y = partition.constrain(x, ("batch", None))
    assert y is x


def test_tree_sharding_matches_structure():
    from repro.configs import get_config
    from repro.models import model
    cfg = get_config("qwen3-4b", reduced=True)
    axes = model.param_axes(cfg)
    shapes = model.param_shapes(cfg)
    mesh = _mesh22()
    sh = partition.tree_sharding(axes, mesh, partition.RULES_TRAIN, shapes)
    assert jax.tree.structure(sh) == jax.tree.structure(shapes)


DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
import sys
sys.path.insert(0, "src")
from repro.core import distributed as dist
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
n, d, k = 1024, 16, 8
vecs = rng.normal(size=(n, d)).astype(np.float32)
ids = np.arange(n, dtype=np.int64)
q = rng.normal(size=d).astype(np.float32)
topk = dist.make_distributed_topk(mesh, k)
dd, ii = topk(jnp.asarray(q), jnp.asarray(vecs), jnp.asarray(ids))
exact = np.argsort(((vecs - q) ** 2).sum(1))[:k]
assert sorted(np.asarray(ii).tolist()) == sorted(exact.tolist())
print("DIST_OK")
"""


def test_distributed_topk_multidevice():
    """shard_map scatter-gather on 4 fake devices (own process so the
    device-count flag doesn't leak into this test session)."""
    out = subprocess.run([sys.executable, "-c", DIST_SCRIPT],
                         capture_output=True, text=True, cwd=".",
                         timeout=300)
    assert "DIST_OK" in out.stdout, out.stderr[-2000:]
