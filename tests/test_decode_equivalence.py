"""Cache-correctness: token-by-token decode must reproduce the full
forward pass (prefill) logits — per family, covering GQA, MLA-absorbed,
Mamba2 chunk-vs-step, mLSTM/sLSTM chunk-vs-step, enc-dec and VLM paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model
from repro.models.model import _run_encoder
from repro.models.transformer import build_stages

B, S = 2, 16

FAMILIES = ["smollm-135m", "qwen3-4b", "deepseek-v3-671b", "zamba2-7b",
            "xlstm-125m", "seamless-m4t-medium", "llama-3.2-vision-90b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(42)
    params, _ = model.init_params(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    memory = None
    if cfg.family == "audio":
        m = int(S * cfg.encdec.frontend_len_ratio)
        memory = jax.random.normal(key, (B, m, cfg.d_model), jnp.bfloat16)
    elif cfg.family == "vlm":
        memory = jax.random.normal(
            key, (B, cfg.vision.num_image_tokens, cfg.d_model), jnp.bfloat16)

    full = model.forward(params, cfg, tokens, memory)
    full = np.asarray(full.astype(jnp.float32))

    dec_memory = memory
    if cfg.family == "audio":
        dec_memory = _run_encoder(params, cfg, build_stages(cfg), memory)
    cache, _ = model.init_cache(cfg, B, S)
    step = jax.jit(lambda p, t, c, i: model.decode_step(
        p, cfg, t, c, i, memory=dec_memory))
    outs = []
    for i in range(S):
        logits, cache = step(params, tokens[:, i:i + 1], cache,
                             jnp.int32(i))
        outs.append(np.asarray(logits.astype(jnp.float32))[:, 0])
    dec = np.stack(outs, axis=1)

    # bf16 forward vs decode: compare argmax agreement + value closeness
    agree = (full.argmax(-1) == dec.argmax(-1)).mean()
    assert agree > 0.9, f"argmax agreement {agree}"
    err = np.abs(full - dec).max() / (np.abs(full).max() + 1e-6)
    assert err < 0.08, f"relative logit error {err}"
