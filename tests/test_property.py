"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from conftest import tweet_schema
from repro.core import query as q
from repro.core.executor import Executor
from repro.core.index.spatial import morton_codes
from repro.core.lsm import LSMConfig, LSMStore
from repro.kernels import ops

SET = settings(max_examples=20, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])


def _mk_store(seed, n, flush_rows):
    rng = np.random.default_rng(seed)
    store = LSMStore(tweet_schema(dim=8), LSMConfig(flush_rows=flush_rows))
    vecs = rng.normal(size=(n, 8)).astype(np.float32)
    pts = rng.uniform(0, 10, (n, 2)).astype(np.float32)
    texts = np.asarray(["w%d w%d" % (i % 5, i % 3) for i in range(n)],
                       object)
    times = rng.uniform(0, 100, n)
    step = max(1, n // 4)
    for i in range(0, n, step):
        j = min(i + step, n)
        store.put(list(range(i, j)), {
            "embedding": vecs[i:j], "coordinate": pts[i:j],
            "content": texts[i:j], "time": times[i:j]})
    store.flush()
    return store, vecs, pts, times


@SET
@given(seed=st.integers(0, 10**6), n=st.integers(50, 400),
       flush=st.sampled_from([32, 64, 128]))
def test_lsm_every_put_visible(seed, n, flush):
    store, vecs, pts, times = _mk_store(seed, n, flush)
    rng = np.random.default_rng(seed + 1)
    for pk in rng.integers(0, n, size=10):
        row = store.get(int(pk))
        assert row is not None
        assert row["time"] == times[pk]


@SET
@given(seed=st.integers(0, 10**6), n=st.integers(100, 300),
       lo=st.floats(0, 50), width=st.floats(0.1, 50))
def test_range_query_matches_brute(seed, n, lo, width):
    store, vecs, pts, times = _mk_store(seed, n, 64)
    ex = Executor(store)
    res, _ = ex.execute(q.HybridQuery(where=[q.Range("time", lo,
                                                       lo + width)]))
    want = set(np.nonzero((times >= lo) & (times <= lo + width))[0].tolist())
    assert set(r.pk for r in res) == want


@SET
@given(seed=st.integers(0, 10**6), n=st.integers(100, 300),
       k=st.integers(1, 15))
def test_nra_matches_brute_force(seed, n, k):
    store, vecs, pts, times = _mk_store(seed, n, 128)
    rng = np.random.default_rng(seed + 2)
    qv = rng.normal(size=8).astype(np.float32)
    p = tuple(rng.uniform(0, 10, 2))
    w1, w2 = float(rng.uniform(0.1, 2)), float(rng.uniform(0.1, 2))
    from repro.core.optimizer import planner as pl
    ranks = [q.VectorRank("embedding", qv, w1),
             q.SpatialRank("coordinate", p, w2)]
    plan = pl.Plan(kind="nra", ranks=ranks, k=k)
    res, _ = Executor(store).execute(q.HybridQuery(ranks=ranks, k=k),
                                     plan=plan)
    score = w1 * np.sqrt(((vecs - qv) ** 2).sum(1)) \
        + w2 * np.sqrt(((pts - np.asarray(p)) ** 2).sum(1))
    want_scores = np.sort(score)[:k]
    got_scores = np.asarray([r.score for r in res])
    np.testing.assert_allclose(got_scores, want_scores, rtol=1e-4,
                               atol=1e-4)


@SET
@given(seed=st.integers(0, 10**6), n=st.integers(1, 600),
       c=st.integers(1, 5))
def test_bitmap_kernel_property(seed, n, c):
    rng = np.random.default_rng(seed)
    cols = rng.uniform(-1, 1, (n, c)).astype(np.float32)
    bounds = np.sort(rng.uniform(-1, 1, (c, 2)), axis=1).astype(np.float32)
    got = ops.range_bitmap(cols, bounds, use_pallas=False)
    want = np.all((cols >= bounds[:, 0]) & (cols <= bounds[:, 1]), axis=1)
    np.testing.assert_array_equal(got, want)


@SET
@given(seed=st.integers(0, 10**6), n=st.integers(2, 256))
def test_morton_codes_bounded(seed, n):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(-5, 5, (n, 2)).astype(np.float32)
    bbox = (float(pts[:, 0].min()), float(pts[:, 1].min()),
            float(pts[:, 0].max()), float(pts[:, 1].max()))
    z = morton_codes(pts, bbox)
    assert z.dtype == np.uint32
    # corner points map to extreme codes
    lo = morton_codes(np.asarray([[bbox[0], bbox[1]]], np.float32), bbox)
    assert lo[0] == 0


@SET
@given(seed=st.integers(0, 10**6),
       nq=st.integers(1, 8), n=st.integers(1, 300), d=st.integers(2, 32))
def test_l2_distance_property(seed, nq, n, d):
    rng = np.random.default_rng(seed)
    qs = rng.normal(size=(nq, d)).astype(np.float32)
    xs = rng.normal(size=(n, d)).astype(np.float32)
    got = ops.l2_distances(qs, xs)
    want = ((qs[:, None, :] - xs[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@SET
@given(seed=st.integers(0, 10**6), n=st.integers(20, 200),
       n_del=st.integers(1, 10))
def test_delete_then_query_never_returns_deleted(seed, n, n_del):
    store, vecs, pts, times = _mk_store(seed, n, 64)
    rng = np.random.default_rng(seed + 3)
    dels = [int(x) for x in rng.integers(0, n, n_del)]
    store.delete(dels)
    res, _ = Executor(store).execute(
        q.HybridQuery(where=[q.Range("time", -1, 101)]))
    got = set(r.pk for r in res)
    assert not (got & set(dels))
    assert got == set(range(n)) - set(dels)
