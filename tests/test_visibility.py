"""Shared MVCC visibility (core/visibility.py): one scenario covering
updates, deletes, memtable shadowing, and tombstones must read back
identically through every read path — the filter pipeline, the batched
NN scan, and NRA — since all three resolve against the same lexsort
winner set.  Plus: ``execute_many`` equivalence (batch of N == N single
executions) and EXPLAIN coverage for every plan kind."""
import numpy as np
import pytest

from conftest import make_batch, tweet_schema
from repro.core import query as q
from repro.core import visibility as vis_lib
from repro.core.executor import Executor
from repro.core.lsm import LSMConfig, LSMStore
from repro.core.optimizer import planner as pl


@pytest.fixture(scope="module")
def mvcc_store():
    """A store exercising every visibility case:

      pks   0-299  base rows (flushed, segment 1)
      pks   0-49   updated, flushed       -> newer segment shadows seg 1
      pks  50-79   deleted, flushed       -> segment tombstones
      pks 100-119  updated, NOT flushed   -> memtable shadows segments
      pks 120-129  deleted, NOT flushed   -> memtable tombstones
      pks 300-319  inserted, NOT flushed  -> memtable-only rows
    """
    rng = np.random.default_rng(42)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=10_000))
    ref = {}

    def apply(pks, batch):
        for j, pk in enumerate(pks):
            ref[pk] = {c: batch[c][j] for c in batch}

    pks, batch = make_batch(rng, 300, pk_start=0)
    store.put(pks, batch)
    apply(pks, batch)
    store.flush()

    pks, batch = make_batch(rng, 50, pk_start=0)          # update 0-49
    store.put(pks, batch)
    apply(pks, batch)
    store.delete(list(range(50, 80)))                     # delete 50-79
    for pk in range(50, 80):
        ref.pop(pk)
    store.flush()

    pks, batch = make_batch(rng, 20, pk_start=100)        # shadow 100-119
    store.put(pks, batch)
    apply(pks, batch)
    store.delete(list(range(120, 130)))                   # tombstone
    for pk in range(120, 130):
        ref.pop(pk)
    pks, batch = make_batch(rng, 20, pk_start=300)        # memtable-only
    store.put(pks, batch)
    apply(pks, batch)

    assert len(store.segments) >= 2 and len(store.memtable) > 0
    cols = {c: np.stack([ref[pk][c] for pk in sorted(ref)])
            for c in ("embedding", "coordinate", "time")}
    return store, np.asarray(sorted(ref), np.int64), cols


def _visible_filter(pks, cols, lo, hi):
    return pks[(cols["time"] >= lo) & (cols["time"] <= hi)]


@pytest.mark.parametrize("path", ["filter", "nn_scan", "nra"])
def test_all_read_paths_agree_on_visibility(mvcc_store, path):
    store, pks, cols = mvcc_store
    ex = Executor(store)
    lo, hi = 10.0, 90.0
    filters = [q.Range("time", lo, hi)]
    mask = (cols["time"] >= lo) & (cols["time"] <= hi)

    if path == "filter":
        plan = pl.Plan(kind="full_scan", residual=filters)
        res, _ = ex.execute(q.HybridQuery(where=filters), plan=plan)
        assert set(r.pk for r in res) == set(pks[mask].tolist())
        return

    qv = np.random.default_rng(1).normal(size=16).astype(np.float32)
    ranks = [q.VectorRank("embedding", qv, 1.0)]
    k = 15
    kind = "full_scan_nn" if path == "nn_scan" else "nra"
    plan = pl.Plan(kind=kind, residual=filters, ranks=ranks, k=k)
    res, _ = ex.execute(
        q.HybridQuery(where=filters, ranks=ranks, k=k), plan=plan)
    score = np.sqrt(((cols["embedding"] - qv) ** 2).sum(1))
    score[~mask] = np.inf
    want = set(pks[np.argsort(score, kind="stable")[:k]].tolist())
    assert set(r.pk for r in res) == want


def test_updated_values_are_served_not_stale(mvcc_store):
    """A shadowed row must never leak: the returned values for updated
    pks are the newest version's, on every path."""
    store, pks, cols = mvcc_store
    ex = Executor(store)
    by_pk = dict(zip(pks.tolist(), cols["time"]))
    for plan in (pl.Plan(kind="full_scan",
                         residual=[q.Range("time", 0, 100)]),):
        res, _ = ex.execute(
            q.HybridQuery(where=[q.Range("time", 0, 100)]), plan=plan)
        assert len(res) == len(pks)
        for r in res:
            assert float(r.values["time"]) == pytest.approx(
                float(by_pk[r.pk]))


def test_memtable_visible_newest_wins():
    pk = np.asarray([1, 2, 1, 3, 2])
    tomb = np.asarray([False, False, False, True, False])
    keep = vis_lib.memtable_visible(pk, tomb)
    # newest version per pk; pk 3's newest is a tombstone
    assert keep.tolist() == [False, False, True, False, True]


def test_resolve_drops_shadowed_rows(mvcc_store):
    store, _, _ = mvcc_store
    seg1 = store.segments[0]
    out = store.resolve_visible(
        {seg1.seg_id: np.arange(seg1.n_rows, dtype=np.int64)})
    vis_pks = set(seg1.pk[out.get(seg1.seg_id, [])].tolist())
    # updated (0-49), deleted (50-79), memtable-shadowed (100-129) rows
    # of the base segment must all be gone
    assert not vis_pks & set(range(0, 80))
    assert not vis_pks & set(range(100, 130))
    assert set(range(80, 100)) <= vis_pks


def test_execute_many_matches_single_executions(mvcc_store):
    store, _, _ = mvcc_store
    ex = Executor(store)
    rng = np.random.default_rng(5)
    queries = [q.HybridQuery(where=[q.Range("time", 0, 60)])]
    for i in range(7):
        queries.append(q.HybridQuery(
            where=[q.Range("time", 5.0 * i, 5.0 * i + 60)],
            ranks=[q.VectorRank(
                "embedding", rng.normal(size=16).astype(np.float32), 1.0)],
            k=10))
    single = [ex.execute(qq)[0] for qq in queries]
    batched = [r for r, _ in ex.execute_many(queries)]
    for a, b in zip(single, batched):
        assert [r.pk for r in a] == [r.pk for r in b]
        assert [r.score for r in a] == pytest.approx(
            [r.score for r in b], rel=1e-4)


EXPLAIN_KINDS = {
    "full_scan": ["SegmentScan", "VisibilityResolve", "MemtableOverlay"],
    "index_intersect": ["IndexProbe", "VisibilityResolve"],
    "prefilter_nn": ["RankScore", "TopKMerge", "VisibilityResolve"],
    "postfilter_nn": ["IndexProbe", "TopKMerge"],
    "nra": ["NRAMerge", "TopKMerge"],
}


@pytest.mark.parametrize("kind", sorted(EXPLAIN_KINDS))
def test_explain_tree_for_every_plan_kind(kind):
    qv = np.zeros(16, np.float32)
    plan = pl.Plan(kind=kind, k=5,
                   indexed=[q.Range("time", 0, 1)]
                   if kind == "index_intersect" else [],
                   residual=[q.Range("time", 0, 1)]
                   if kind != "index_intersect" else [],
                   ranks=[] if kind in ("full_scan", "index_intersect")
                   else [q.VectorRank("embedding", qv, 1.0)])
    text = plan.describe()
    assert text.startswith(kind + "(")
    for node in EXPLAIN_KINDS[kind]:
        assert node in text, f"{node} missing from EXPLAIN:\n{text}"
    assert "cost=" in text


def test_explain_carries_cost_estimates(mvcc_store):
    store, _, _ = mvcc_store
    ex = Executor(store)
    query = q.HybridQuery(where=[q.Range("time", 0, 50)])
    plan = pl.plan(ex.catalog, query)
    text = plan.describe()
    # planner-built trees carry non-zero per-operator block estimates
    assert any(float(tok.split("=")[1].rstrip(")")) > 0
               for tok in text.split() if tok.startswith("cost="))
