"""Fixture: a constructed Plan kind no dispatcher names (fires once);
the dispatched kind is clean."""


class Plan:
    def __init__(self, kind=""):
        self.kind = kind


def make_ghost():
    return Plan(kind="ghost_kind")     # fires: never dispatched


def make_scan():
    return Plan(kind="full_scan")


def dispatch(plan):
    if plan.kind == "full_scan":
        return "scan"
    return None
