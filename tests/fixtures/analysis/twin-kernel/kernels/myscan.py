"""Fixture: a Pallas kernel wrapper with no oracle twin in ref.py
(fires once)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kern(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def my_scan(x, interpret=True):       # fires: no my_scan_ref in ref.py
    return pl.pallas_call(
        _kern,
        grid=(1,),
        in_specs=[pl.BlockSpec(x.shape, lambda i: (0, 0))],
        out_specs=[pl.BlockSpec(x.shape, lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct(x.shape, jnp.float32)],
        interpret=interpret,
    )(x)
