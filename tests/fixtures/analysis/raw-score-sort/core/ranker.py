"""Fixture: one raw argsort over distances (fires), one sanctioned
lexsort and one key= comparator (clean)."""
import numpy as np


def bad_rank(dists):
    return np.argsort(dists)          # fires: no pk tie-break


def good_rank(dists, pks):
    return np.lexsort((pks, dists))   # sanctioned comparator


def good_rows(rows):
    rows.sort(key=lambda r: (r.score, r.pk))   # explicit (score, pk) key
    return rows
