"""Fixture: a graph beam-search kernel breaking two parity contracts —
no oracle twin in ref.py (parity/twin-kernel fires once) and a raw
argsort over distances in the prune (parity/raw-score-sort fires once).
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kern(x_ref, nbr_ref, o_ref):
    x = x_ref[...]
    nbrs = nbr_ref[...]
    safe = jnp.where(nbrs >= 0, nbrs, 0)
    cand = jnp.take(x, safe, axis=0)
    d = jnp.sum(cand * cand, axis=-1)
    order = jnp.argsort(d)          # fires: no (distance, pk) comparator
    o_ref[...] = jnp.take_along_axis(d, order, axis=-1)


def graph_probe(x, nbrs, interpret=True):   # fires: no graph_probe_ref
    return pl.pallas_call(
        _kern,
        grid=(1,),
        in_specs=[pl.BlockSpec(x.shape, lambda i: (0, 0)),
                  pl.BlockSpec(nbrs.shape, lambda i: (0, 0))],
        out_specs=[pl.BlockSpec(nbrs.shape, lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct(nbrs.shape, jnp.float32)],
        interpret=interpret,
    )(x, nbrs)
