"""Fixture ref module: carries an unrelated oracle only."""
import jax.numpy as jnp


def other_ref(x):
    return jnp.asarray(x) * 2.0
