"""Fixture: CSR gather without a -1 padding guard (fires once)."""
import jax.numpy as jnp


def expand_frontier(x, nbrs, frontier):
    cand = jnp.take(nbrs, frontier, axis=0)
    # fires: cand still carries -1 padding lanes, which clamp to row 0
    vals = jnp.take(x, cand, axis=0)
    return vals.sum(axis=-1)
