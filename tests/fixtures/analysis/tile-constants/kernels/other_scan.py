"""Fixture: a kernel module redefining a tile constant with a different
value (fires once)."""

BLOCK_N = 256                          # fires: canon says 512
