"""Fixture canonical kernel module: the contract constants."""
import jax.numpy as jnp

BLOCK_Q = 8
BLOCK_N = 512
KMAX = 128
SENTINEL = jnp.iinfo(jnp.int32).max    # int32 pk tie-break range
