"""Known-bad manifest publish: writes the temp file and renames it into
place without ever fsyncing the content — the rename is atomic but the
bytes it publishes may still be only in the page cache."""
import json
import os


def publish(root, gen, state):
    tmp = os.path.join(root, f"manifest-{gen:08d}.json.tmp")
    final = os.path.join(root, f"manifest-{gen:08d}.json")
    with open(tmp, "w") as f:
        json.dump(state, f)
        f.flush()
    os.replace(tmp, final)
    return final
