"""Fixture sweep test: exercises no kernel module at all."""


def test_nothing():
    assert True
