"""Fixture: a sqrt-derived distance feeding an ordering comparison
(fires once); the squared-form rewrite below is clean."""
import numpy as np


def bad_admit(vecs, q, r):
    d = np.sqrt(((vecs - q[None, :]) ** 2).sum(1))
    return d <= r                     # fires: compare in squared form


def good_admit(vecs, q, r):
    d2 = ((vecs - q[None, :]) ** 2).sum(1)
    return d2 <= r * r
