"""Fixture: a pallas_call whose BlockSpec index map takes one grid
coordinate while the grid has rank 2 (fires once)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kern(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def bad_call(x):
    n, d = x.shape
    assert n % 8 == 0 and d % 8 == 0
    return pl.pallas_call(
        _kern,
        grid=(n // 8, d // 8),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],  # fires
        out_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((n, d), jnp.float32)],
    )(x)
