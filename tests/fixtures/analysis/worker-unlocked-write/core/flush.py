"""Fixture: the flush worker reaches an unlocked store mutation through
``step`` (fires once); the locked sibling is clean."""
import threading


class FlushScheduler:
    def __init__(self, store):
        self.store = store
        self._cv = threading.Condition()

    def _run_worker(self):
        while True:
            self.step()
            self.locked_step()

    def step(self):
        self.store.metrics["flushes"] += 1     # fires: no store lock

    def locked_step(self):
        with self.store._lock:
            self.store.metrics["compactions"] += 1
