"""Known-bad span usage: the flush span is created but never entered —
``span()`` returns a context manager, so without ``with`` the span's
``__exit__`` never runs, its duration is never recorded, and the node
leaks.  The good path below shows the required form."""
from repro.obs import trace as obs_trace


def flush_bad(store):
    sp = obs_trace.span("flush", rows=len(store.sealed))
    seg = store.build_segment()
    sp.set(seg_id=seg.seg_id)
    return seg


def flush_good(store):
    with obs_trace.span("flush", rows=len(store.sealed)) as sp:
        seg = store.build_segment()
        sp.set(seg_id=seg.seg_id)
    return seg
