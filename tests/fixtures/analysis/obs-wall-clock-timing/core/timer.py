"""Known-bad duration measurement: ``time.time()`` is wall-clock (NTP
can step it mid-interval), so the flush duration below can come out
negative or wildly wrong.  Timestamps that are never subtracted (the
log entry) and monotonic ``perf_counter`` intervals are fine."""
import time


def flush_timed(store):
    t0 = store.last_flush_ts
    seg = store.flush()
    store.metrics["flush_s"] += time.time() - t0
    return seg


def log_entry(event):
    # a wall timestamp, never subtracted: legitimate time.time() use
    return {"ts": time.time(), "event": event}


def flush_timed_good(store):
    t0 = time.perf_counter()
    seg = store.flush()
    store.metrics["flush_s"] += time.perf_counter() - t0
    return seg
