"""Fixture: a module-level memo mutated without a lock (fires once);
the guarded writer below is clean."""
import threading

_memo: dict = {}
_lock = threading.Lock()


def bad_put(key, value):
    _memo[key] = value                # fires: unguarded shared cache


def good_put(key, value):
    with _lock:
        _memo[key] = value
