"""Fixture: a tiled wrapper whose grid divides by the tile without an
assert guarding divisibility (fires once)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 8


def _kern(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def ragged_call(x):
    n = x.shape[0]
    return pl.pallas_call(                 # fires: no `assert n % TILE`
        _kern,
        grid=(n // TILE,),
        in_specs=[pl.BlockSpec((TILE,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((TILE,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)],
    )(x)
