"""Hybrid query execution vs brute force, across all physical plans."""
import numpy as np
import pytest

from conftest import make_batch, tweet_schema
from repro.core import query as q
from repro.core.executor import Executor
from repro.core.index.text import tokenize
from repro.core.lsm import LSMConfig, LSMStore
from repro.core.optimizer import planner as pl


@pytest.fixture(scope="module")
def store_ref():
    rng = np.random.default_rng(11)
    store = LSMStore(tweet_schema(), LSMConfig(flush_rows=512))
    data = {"embedding": [], "coordinate": [], "content": [], "time": []}
    for i in range(0, 4000, 500):
        pks, batch = make_batch(rng, 500, pk_start=i)
        store.put(pks, batch)
        for k in data:
            data[k].append(batch[k])
    store.flush()
    return store, {k: np.concatenate(v) for k, v in data.items()}


def brute_filter(ref, filters):
    n = len(ref["time"])
    mask = np.ones(n, bool)
    for f in filters:
        if isinstance(f, q.Range):
            mask &= (ref[f.col] >= f.lo) & (ref[f.col] <= f.hi)
        elif isinstance(f, q.GeoWithin):
            x, y = ref[f.col][:, 0], ref[f.col][:, 1]
            mask &= ((x >= f.rect[0]) & (x <= f.rect[2])
                     & (y >= f.rect[1]) & (y <= f.rect[3]))
        elif isinstance(f, q.TextContains):
            mask &= np.asarray([f.term in tokenize(t) for t in ref[f.col]])
        elif isinstance(f, q.VectorRange):
            d = np.sqrt(((ref[f.col] - f.q) ** 2).sum(1))
            mask &= d < f.thresh
    return mask


def brute_score(ref, ranks):
    n = len(ref["time"])
    s = np.zeros(n)
    for r in ranks:
        if isinstance(r, q.VectorRank):
            s += r.weight * np.sqrt(((ref[r.col] - r.q) ** 2).sum(1))
        elif isinstance(r, q.SpatialRank):
            s += r.weight * np.sqrt(
                ((ref[r.col] - np.asarray(r.point)) ** 2).sum(1))
    return s


def test_hybrid_search_exact(store_ref):
    store, ref = store_ref
    ex = Executor(store)
    filters = [q.Range("time", 10, 30),
               q.TextContains("content", "banana"),
               q.GeoWithin("coordinate", (1, 1, 9, 9))]
    res, st = ex.execute(q.HybridQuery(where=filters))
    want = set(np.nonzero(brute_filter(ref, filters))[0].tolist())
    assert set(r.pk for r in res) == want


def test_hybrid_search_all_plans_agree(store_ref):
    store, ref = store_ref
    ex = Executor(store)
    filters = [q.Range("time", 40, 70), q.TextContains("content", "echo")]
    want = set(np.nonzero(brute_filter(ref, filters))[0].tolist())
    # full scan
    fs = pl.Plan(kind="full_scan", residual=filters)
    res, _ = ex.execute(q.HybridQuery(where=filters), plan=fs)
    assert set(r.pk for r in res) == want
    # every single-index choice
    for probe in filters:
        plan = pl.Plan(kind="index_intersect", indexed=[probe],
                       residual=[p for p in filters if p is not probe])
        res, _ = ex.execute(q.HybridQuery(where=filters), plan=plan)
        assert set(r.pk for r in res) == want
    # both indexes
    plan = pl.Plan(kind="index_intersect", indexed=filters, residual=[])
    res, _ = ex.execute(q.HybridQuery(where=filters), plan=plan)
    assert set(r.pk for r in res) == want


@pytest.mark.parametrize("kind", ["full_scan_nn", "nra", "prefilter_nn"])
def test_hybrid_nn_plans_match_brute(store_ref, kind):
    store, ref = store_ref
    ex = Executor(store)
    rng = np.random.default_rng(0)
    qv = rng.normal(size=16).astype(np.float32)
    ranks = [q.VectorRank("embedding", qv, 0.7),
             q.SpatialRank("coordinate", (4.0, 6.0), 1.3)]
    filters = [q.Range("time", 0, 60)]
    query = q.HybridQuery(where=filters, ranks=ranks, k=10)
    plan = pl.Plan(kind=kind, residual=filters, ranks=ranks, k=10)
    if kind == "prefilter_nn":
        plan.indexed = filters
        plan.residual = []
    res, _ = ex.execute(query, plan=plan)
    mask = brute_filter(ref, filters)
    score = brute_score(ref, ranks)
    score[~mask] = np.inf
    want = set(np.argsort(score, kind="stable")[:10].tolist())
    got = set(r.pk for r in res)
    assert len(got & want) == 10


def test_postfilter_nn_high_recall(store_ref):
    store, ref = store_ref
    ex = Executor(store)
    rng = np.random.default_rng(1)
    qv = rng.normal(size=16).astype(np.float32)
    ranks = [q.VectorRank("embedding", qv, 1.0)]
    filters = [q.Range("time", 0, 80)]     # mild filter
    query = q.HybridQuery(where=filters, ranks=ranks, k=10)
    plan = pl.Plan(kind="postfilter_nn", residual=filters, ranks=ranks, k=10)
    res, _ = ex.execute(query, plan=plan)
    mask = brute_filter(ref, filters)
    score = brute_score(ref, ranks)
    score[~mask] = np.inf
    want = set(np.argsort(score)[:10].tolist())
    assert len(set(r.pk for r in res) & want) >= 7   # IVF probe recall


def test_memtable_rows_visible_in_queries(store_ref):
    store, ref = store_ref
    ex = Executor(store)
    rng = np.random.default_rng(2)
    pks, batch = make_batch(rng, 5, pk_start=10_000)
    batch["time"] = np.full(5, 55.5)
    store.put(pks, batch)       # stays in memtable (below flush threshold)
    res, _ = ex.execute(q.HybridQuery(where=[q.Range("time", 55.4, 55.6)]))
    assert set(r.pk for r in res) >= set(pks)


def test_planner_picks_cheap_plan(store_ref):
    store, _ = store_ref
    ex = Executor(store)
    # highly selective indexed range: planner must not full-scan
    plan = pl.plan(ex.catalog, q.HybridQuery(
        where=[q.Range("time", 50.0, 50.5),
                 q.TextContains("content", "golf")]))
    assert plan.kind == "index_intersect"
    # rank over indexed modalities: NRA or prefilter beats full scan
    qv = np.zeros(16, np.float32)
    plan = pl.plan(ex.catalog, q.HybridQuery(
        ranks=[q.VectorRank("embedding", qv, 1.0),
               q.SpatialRank("coordinate", (5, 5), 1.0)], k=5))
    assert plan.kind in ("nra", "prefilter_nn", "postfilter_nn")
