"""int8 KV-cache quantization (EXPERIMENTS.md §Perf C3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model

B, S = 2, 12


@pytest.mark.parametrize("arch", ["qwen3-4b", "smollm-135m"])
def test_int8_kv_decode_close_to_bf16(arch):
    cfg = get_config(arch, reduced=True).replace(kv_cache_dtype="int8")
    key = jax.random.PRNGKey(3)
    params, _ = model.init_params(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full = np.asarray(model.forward(params, cfg, tokens)
                      .astype(jnp.float32))
    cache, _ = model.init_cache(cfg, B, S)
    outs = []
    for i in range(S):
        lg, cache = model.decode_step(params, cfg, tokens[:, i:i + 1],
                                      cache, jnp.int32(i))
        outs.append(np.asarray(lg.astype(jnp.float32))[:, 0])
    dec = np.stack(outs, 1)
    agree = (full.argmax(-1) == dec.argmax(-1)).mean()
    assert agree > 0.8, agree


def test_int8_cache_half_bytes():
    cfg = get_config("qwen3-4b", reduced=True)
    c_bf, _ = model.init_cache(cfg, B, 512)
    c_i8, _ = model.init_cache(cfg.replace(kv_cache_dtype="int8"), B, 512)

    def nbytes(c):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c))

    ratio = nbytes(c_i8) / nbytes(c_bf)
    assert 0.5 <= ratio <= 0.6   # int8 payload + bf16 scales
