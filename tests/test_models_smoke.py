"""Per-arch smoke tests (assignment requirement): instantiate a REDUCED
config of each family, run forward + one train step on CPU, assert output
shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import layers, model
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts

B, S = 2, 32


def _inputs(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    memory = None
    if cfg.family == "audio":
        m = int(S * cfg.encdec.frontend_len_ratio)
        memory = jax.random.normal(key, (B, m, cfg.d_model), jnp.bfloat16)
    elif cfg.family == "vlm":
        memory = jax.random.normal(
            key, (B, cfg.vision.num_image_tokens, cfg.d_model), jnp.bfloat16)
    return tokens, memory


@pytest.mark.parametrize("arch", list(ASSIGNED_ARCHS) + ["arcade-embedder"])
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params, axes = model.init_params(key, cfg)
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda a: isinstance(a, tuple))
    tokens, memory = _inputs(cfg, key)
    logits = model.forward(params, cfg, tokens, memory)
    assert logits.shape == (B, S, layers.pad_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", list(ASSIGNED_ARCHS))
def test_one_train_step(arch):
    cfg = get_config(arch, reduced=True)
    opt_cfg = opt_lib.OptConfig(name=cfg.optimizer, lr=1e-3)
    key = jax.random.PRNGKey(1)
    state, _ = ts.make_train_state(key, cfg, opt_cfg)
    tokens, memory = _inputs(cfg, key)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if memory is not None:
        batch["memory"] = memory
    new_state, metrics = ts.train_step(state, batch, cfg, opt_cfg)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", list(ASSIGNED_ARCHS))
def test_decode_step_shapes(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(2)
    params, _ = model.init_params(key, cfg)
    tokens, memory = _inputs(cfg, key)
    if cfg.family == "audio":
        # decode uses the precomputed ENCODER OUTPUT as memory
        from repro.models.model import _run_encoder
        from repro.models.transformer import build_stages
        memory = _run_encoder(params, cfg, build_stages(cfg), memory)
    cache, _ = model.init_cache(cfg, B, S)
    logits, cache2 = model.decode_step(params, cfg, tokens[:, :1], cache,
                                       jnp.int32(0), memory=memory)
    assert logits.shape == (B, 1, layers.pad_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
