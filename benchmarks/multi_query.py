"""Batched multi-query execution: ``execute_many`` vs sequential
``execute`` on the same workload (the shared-scan amortization the
vectorized operator pipeline enables).

At each batch size B, the same B hybrid NN queries run (a) sequentially,
one ``execute`` per query, and (b) as one ``execute_many`` batch that
shares per-segment scans, predicate bitmaps, and stacks the B query
vectors into single ``l2_distances(Q, X)`` kernel calls.

Rows: ``mq_batchN,us_per_query_batched,seq_qps=..;batch_qps=..;speedup=..``
"""
from __future__ import annotations

import time
from typing import List


from benchmarks import tracy
from repro.core import query as q
from repro.core.executor import Executor

BATCH_SIZES = (1, 8, 64)


def _make_queries(data: tracy.TracyData, n: int) -> List[q.HybridQuery]:
    """Hybrid NN workload: vector rank + time filter (template t8 shape),
    distinct query vector per request."""
    out = []
    for _ in range(n):
        lo = float(data.rng.uniform(0, 800))
        out.append(q.HybridQuery(
            where=q.Range("time", lo, lo + 200),
            ranks=[q.VectorRank("embedding", data.query_vec(), 1.0)],
            k=10))
    return out


def _time_best(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run_multi_query(n_rows: int = 6000, batch: int = 8, seed: int = 0,
                    repeats: int = 3) -> dict:
    cfg = tracy.TracyConfig(n_rows=n_rows, seed=seed, dim=64)
    store, data = tracy.build_store(cfg)
    ex = Executor(store)
    queries = _make_queries(data, batch)
    plans = [None] * batch

    # warm both paths (plan cache, jit, visibility index)
    ex.execute_many(queries)
    for qq in queries:
        ex.execute(qq)

    seq_s = _time_best(
        lambda: [ex.execute(qq) for qq in queries], repeats)
    bat_s = _time_best(
        lambda: ex.execute_many(queries, plans=list(plans)), repeats)

    # sanity: both paths agree on results
    seq_res = [ex.execute(qq)[0] for qq in queries]
    bat_res = [r for r, _ in ex.execute_many(queries)]
    for a, b in zip(seq_res, bat_res):
        assert [r.pk for r in a] == [r.pk for r in b], \
            "batched results diverge from sequential"

    return {"seq_qps": batch / seq_s, "batch_qps": batch / bat_s,
            "speedup": seq_s / bat_s,
            "us_per_query_batched": bat_s / batch * 1e6}


def bench(scale: float = 1.0) -> List[str]:
    rows = []
    n_rows = int(6000 * scale)
    for batch in BATCH_SIZES:
        r = run_multi_query(n_rows=n_rows, batch=batch)
        rows.append(
            f"mq_batch{batch},{r['us_per_query_batched']:.0f},"
            f"seq_qps={r['seq_qps']:.0f};batch_qps={r['batch_qps']:.0f};"
            f"speedup={r['speedup']:.2f}")
    return rows


if __name__ == "__main__":
    for row in bench():
        print(row)
