"""Fig. 5 reproduction: continuous-query performance.

(a) vary the materialized-view memory budget at fixed workload;
(b) vary the number of registered queries at fixed budget.
Engines: ARCADE (no reuse), ARCADE+F (full-result cache), ARCADE+S (ours).
"""
from __future__ import annotations

import time
from typing import Dict, List


from benchmarks import tracy
from repro.core import query as q
from repro.core.continuous import ContinuousEngine

MODES = {"arcade": "none", "arcade_f": "fcache", "arcade_s": "views"}


def _make_queries(data: tracy.TracyData, n: int) -> List[q.SyncQuery]:
    out = []
    for i in range(n):
        if i % 2 == 0:
            out.append(q.SyncQuery(q.HybridQuery(
                ranks=[q.VectorRank("embedding", data.query_vec(), 1.0)],
                k=10), interval_s=1.0))
        else:
            out.append(q.SyncQuery(q.HybridQuery(
                where=q.GeoWithin("coordinate", data.rect(12))),
                interval_s=1.0))
    return out


def run_continuous(n_rows: int = 5000, n_queries: int = 12,
                   budget_mb: float = 4.0, ticks: int = 4,
                   mode: str = "views", seed: int = 0) -> Dict[str, float]:
    cfg = tracy.TracyConfig(n_rows=n_rows, seed=seed, dim=64)
    store, data = tracy.build_store(cfg)
    eng = ContinuousEngine(store, mode=mode,
                           view_budget_bytes=budget_mb * 2**20)
    for decl in _make_queries(data, n_queries):
        eng.register(decl)
    t0 = time.perf_counter()
    for t in range(ticks):
        eng.advance(float(t))
        pks, batch = data.batch(64)       # interleaved ingest
        store.put(pks, batch)
    dt = time.perf_counter() - t0
    ex = eng.metrics["executions"] or 1
    return {"avg_exec_ms": dt / ex * 1e3,
            "view_hits": eng.metrics["view_hits"],
            "cache_hits": eng.metrics["cache_hits"]}


def bench(scale: float = 1.0) -> List[str]:
    rows = []
    n_rows = int(5000 * scale)
    # (a) budget sweep
    for budget in (0.25, 1.0, 4.0):
        for name, mode in MODES.items():
            r = run_continuous(n_rows=n_rows, budget_mb=budget, mode=mode)
            rows.append(f"fig5a_budget{budget}MB_{name},"
                        f"{r['avg_exec_ms'] * 1e3:.0f},"
                        f"view_hits={r['view_hits']};"
                        f"cache_hits={r['cache_hits']}")
    # (b) #queries sweep at fixed budget
    for nq in (4, 12, 24):
        for name, mode in MODES.items():
            r = run_continuous(n_rows=n_rows, n_queries=nq, budget_mb=1.0,
                               mode=mode)
            rows.append(f"fig5b_q{nq}_{name},{r['avg_exec_ms'] * 1e3:.0f},"
                        f"view_hits={r['view_hits']}")
    return rows
