"""Table 1 reproduction: average hybrid-query latency, ARCADE vs the
baseline strategies (each implementing one competitor's design point)."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks import baselines as bl
from benchmarks import tracy


def run_latency(n_rows: int = 6000, n_queries: int = 30,
                kind: str = "search", engine: str = "arcade",
                seed: int = 0) -> Dict[str, float]:
    cfg = tracy.TracyConfig(n_rows=n_rows, seed=seed, dim=64)
    store, data = tracy.build_store(cfg)
    search_t, nn_t = tracy.make_templates(data)
    templates = search_t if kind == "search" else nn_t
    ex = bl.EXECUTORS[engine](store)
    rng = np.random.default_rng(seed + 2)

    # warm
    ex.execute(templates[0]())
    lat = []
    blocks = 0.0
    for i in range(n_queries):
        tmpl = templates[rng.integers(0, len(templates))]
        query = tmpl()
        t0 = time.perf_counter()
        _, st = ex.execute(query)
        lat.append(time.perf_counter() - t0)
        blocks += st.blocks_read
    return {"avg_ms": float(np.mean(lat) * 1e3),
            "p95_ms": float(np.percentile(lat, 95) * 1e3),
            "blocks_per_q": blocks / n_queries}


def bench(scale: float = 1.0) -> List[str]:
    rows = []
    n_rows = int(6000 * scale)
    nq = max(10, int(25 * scale))
    for kind in ("search", "nn"):
        for engine in ("arcade", "single_index", "segment_full_load",
                       "full_scan"):
            r = run_latency(n_rows=n_rows, n_queries=nq, kind=kind,
                            engine=engine)
            rows.append(
                f"tab1_{kind}_{engine},{r['avg_ms'] * 1e3:.0f},"
                f"p95_ms={r['p95_ms']:.1f};blocks={r['blocks_per_q']:.0f}")
    return rows
