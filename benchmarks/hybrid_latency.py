"""Table 1 reproduction (hybrid-query latency vs baseline strategies)
plus the fused-vs-staged read-path dispatch study.

``run_fused_vs_staged`` executes the TRACY NN templates twice over an
8+-segment store — once with the planner's fused packed kernel path
(``kernels/fused_scan.py``: one dispatch per query batch, ``(nq, k)``
bytes back) and once with the staged per-segment fallback (one dispatch
per segment per batch, full distance rows back) — and checks that both
return IDENTICAL results while counting kernel launches and
device->host bytes via ``kernels.ops.STATS``.

CLI:  python benchmarks/hybrid_latency.py [--smoke] [--json PATH]
                                          [--baseline PATH]
With ``--baseline``, machine-independent ratios are gated against the
committed JSON (CI smoke job): fails if fused stops returning identical
results, launches more kernels than staged, or the launch/bytes
advantage on the NN-heavy (fused-eligible) templates drops below the
floors recorded in the baseline.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

if __package__ in (None, ""):        # `python benchmarks/hybrid_latency.py`
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import baselines as bl
from benchmarks import tracy
from repro.core.executor import Executor
from repro.core.optimizer import planner as planner_lib
from repro.kernels import ops as kops


def run_latency(n_rows: int = 6000, n_queries: int = 30,
                kind: str = "search", engine: str = "arcade",
                seed: int = 0) -> Dict[str, float]:
    cfg = tracy.TracyConfig(n_rows=n_rows, seed=seed, dim=64)
    store, data = tracy.build_store(cfg)
    search_t, nn_t = tracy.make_templates(data)
    templates = search_t if kind == "search" else nn_t
    ex = bl.EXECUTORS[engine](store)
    rng = np.random.default_rng(seed + 2)

    # warm
    ex.execute(templates[0]())
    lat = []
    blocks = 0.0
    for i in range(n_queries):
        tmpl = templates[rng.integers(0, len(templates))]
        query = tmpl()
        t0 = time.perf_counter()
        _, st = ex.execute(query)
        lat.append(time.perf_counter() - t0)
        blocks += st.blocks_read
    return {"avg_ms": float(np.mean(lat) * 1e3),
            "p95_ms": float(np.percentile(lat, 95) * 1e3),
            "blocks_per_q": blocks / n_queries}


# ---------------------------------------------------------------------------
# fused vs staged dispatch study
# ---------------------------------------------------------------------------

NN_TEMPLATE_NAMES = ["t6", "t7", "t8", "t9", "t10", "t11", "t13"]


def run_fused_vs_staged(n_rows: int = 6000, n_segments: int = 8,
                        batch: int = 8, n_batches: int = 2,
                        dim: int = 64, seed: int = 0) -> Dict:
    """Execute every TRACY NN template in both dispatch modes over an
    ``n_segments``-segment store and compare results + kernel traffic.

    Queries run through ``execute_many`` in batches of ``batch``
    structurally-identical instances — the regime the packed fused path
    targets (the batch shares one superbatch scan).  Multi-rank
    templates are not fused-eligible and act as controls (identical
    plans, identical traffic in both modes)."""
    cfg = tracy.TracyConfig(n_rows=n_rows, dim=dim, seed=seed,
                            flush_rows=max(1, n_rows // n_segments),
                            fanout=4 * n_segments)
    store, data = tracy.build_store(cfg)
    _, nn_t = tracy.make_templates(data)
    ex = Executor(store)
    out: Dict = {"config": {"n_rows": n_rows, "dim": dim, "batch": batch,
                            "n_segments": len(store.segments),
                            "n_batches": n_batches},
                 "templates": {}}
    prev = planner_lib.FUSED_ENABLED
    try:
        for name, tmpl in zip(NN_TEMPLATE_NAMES, nn_t):
            rec: Dict = {"identical": True}
            per_mode: Dict[str, Dict] = {}
            results: Dict[str, List] = {}
            for mode in ("staged", "fused"):
                planner_lib.FUSED_ENABLED = mode == "fused"
                res: List = []
                before = kops.stats_snapshot()
                t0 = time.perf_counter()
                for b in range(n_batches):
                    # identical query parameters in both modes
                    data.rng = np.random.default_rng(seed + 1000 + b)
                    res.extend(ex.execute_many([tmpl()
                                                for _ in range(batch)]))
                dt = time.perf_counter() - t0
                after = kops.stats_snapshot()
                per_mode[mode] = {
                    "launches": after[0] - before[0],
                    "bytes_to_host": after[1] - before[1],
                    "jit_shape_misses": after[2] - before[2],
                    "ms": dt * 1e3,
                }
                results[mode] = [[(r.pk, float(r.score)) for r in rows]
                                 for rows, _ in res]
                if mode == "fused":
                    rec["kind"] = res[0][1].plan.splitlines()[0].split(
                        "(")[0]
                    rec["fused_chosen"] = "dispatch=fused" in res[0][1].plan
            rec["identical"] = results["staged"] == results["fused"]
            rec.update(per_mode)
            out["templates"][name] = rec
    finally:
        planner_lib.FUSED_ENABLED = prev
    heavy = [n for n, r in out["templates"].items() if r["fused_chosen"]]
    sl = sum(out["templates"][n]["staged"]["launches"] for n in heavy)
    fl = sum(out["templates"][n]["fused"]["launches"] for n in heavy)
    sb = sum(out["templates"][n]["staged"]["bytes_to_host"] for n in heavy)
    fb = sum(out["templates"][n]["fused"]["bytes_to_host"] for n in heavy)
    out["nn_heavy"] = {
        "templates": heavy,
        "staged_launches": sl, "fused_launches": fl,
        "staged_bytes": sb, "fused_bytes": fb,
        "launch_ratio": sl / max(1, fl),
        "bytes_ratio": sb / max(1, fb),
    }
    out["identical_all"] = all(r["identical"]
                               for r in out["templates"].values())
    return out


# ---------------------------------------------------------------------------
# graph vs IVF vs exact recall-latency study
# ---------------------------------------------------------------------------

GRAPH_GATHER_CEILING = 0.35     # candidate rows gathered / segment rows


def run_graph_vs_ivf_vs_exact(n_rows: int = 8000, n_segments: int = 8,
                              dim: int = 128, n_queries: int = 12,
                              recall_target: float = 0.95,
                              seed: int = 0) -> Dict:
    """Recall-vs-latency study for the graph dispatch: every
    recall-targeted NN template (``tracy.make_graph_templates``) runs on
    three engines over identical data and identical query streams —

      * ``graph``: GRAPH-resident store, per-query ``recall_target`` (the
        planner prices the CSR beam walk against the exact paths);
      * ``ivf``:   IVF-resident store, same targeted queries (no graph
        residence, so the planner falls back to its index-walk/scan
        choices — the probe baseline);
      * ``exact``: the GRAPH store with the targets stripped (default
        exact contract; doubles as recall ground truth).

    Records per-engine p50/p95 latency, the fraction of queries whose
    chosen plan was the graph dispatch, recall@k against the exact run,
    and the traversal's gathered-row fraction (``rows_scanned`` under the
    graph dispatch is the visited-bitmap popcount, not a scan length)."""
    base = dict(n_rows=n_rows, dim=dim, seed=seed,
                flush_rows=max(1, n_rows // n_segments),
                fanout=4 * n_segments)
    g_store, g_data = tracy.build_store(tracy.TracyConfig(**base),
                                        vector_index=tracy.IndexKind.GRAPH,
                                        quantize=False)
    i_store, i_data = tracy.build_store(tracy.TracyConfig(**base),
                                        vector_index=tracy.IndexKind.IVF,
                                        quantize=False)
    total_rows = sum(s.n_rows for s in g_store.segments)
    # identical seeds => identical topic centers => identical query draws
    engines = {"graph": (Executor(g_store), g_data, recall_target),
               "ivf": (Executor(i_store), i_data, recall_target),
               "exact": (Executor(g_store), g_data, None)}
    out: Dict = {"config": {"n_rows": n_rows, "dim": dim,
                            "n_segments": len(g_store.segments),
                            "n_queries": n_queries,
                            "recall_target": recall_target},
                 "templates": {}}
    names = [n for n, _ in tracy.make_graph_templates(g_data)]
    for ti, tname in enumerate(names):
        rec: Dict = {}
        pks_by_engine: Dict[str, List] = {}
        for ename, (ex, data, rt) in engines.items():
            tmpl = dict(tracy.make_graph_templates(data, rt))[tname]
            data.rng = np.random.default_rng(seed + 777)
            ex.execute(tmpl())                       # warm/compile
            data.rng = np.random.default_rng(seed + 1000 + ti)
            lat, pks, chosen, gathered = [], [], 0, []
            for _ in range(n_queries):
                query = tmpl()
                t0 = time.perf_counter()
                rows, st = ex.execute(query)
                lat.append(time.perf_counter() - t0)
                pks.append({r.pk for r in rows})
                if "dispatch=graph" in st.plan:
                    chosen += 1
                    gathered.append(st.rows_scanned / max(1, total_rows))
            pks_by_engine[ename] = pks
            rec[ename] = {
                "p50_ms": float(np.percentile(lat, 50) * 1e3),
                "p95_ms": float(np.percentile(lat, 95) * 1e3),
                "graph_chosen_frac": chosen / n_queries,
                "gathered_frac": float(np.mean(gathered))
                if gathered else 0.0,
            }
        k = 10
        for ename in ("graph", "ivf"):
            hits = sum(len(a & b) for a, b in
                       zip(pks_by_engine[ename], pks_by_engine["exact"]))
            denom = sum(min(k, len(b)) for b in pks_by_engine["exact"])
            rec[ename]["recall_at_k"] = hits / max(1, denom)
        out["templates"][tname] = rec
    g6 = out["templates"]["g6"]
    out["summary"] = {
        "graph_p50_vs_exact": g6["graph"]["p50_ms"] / g6["exact"]["p50_ms"],
        "graph_p50_vs_ivf": g6["graph"]["p50_ms"] / g6["ivf"]["p50_ms"],
        "graph_beats_exact_p50": g6["graph"]["p50_ms"]
        < g6["exact"]["p50_ms"],
        "graph_beats_ivf_p50": g6["graph"]["p50_ms"] < g6["ivf"]["p50_ms"],
    }
    return out


def _check_graph_baseline(result: Dict, baseline: Dict) -> List[str]:
    """Machine-independent gates for the graph-smoke CI job: the planner
    keeps choosing the graph dispatch wherever the committed baseline
    says it did, recall@k holds the target on every template where the
    graph ran, and the traversal stays sub-linear (gathered-row fraction
    under the ceiling).  Latency ratios are recorded, never gated — they
    are machine-dependent."""
    failures = []
    rt = result["config"]["recall_target"]
    for tname, rec in result["templates"].items():
        g = rec["graph"]
        bfrac = baseline.get("templates", {}).get(tname, {}) \
            .get("graph", {}).get("graph_chosen_frac", 0.0)
        if g["graph_chosen_frac"] < bfrac:
            failures.append(
                f"{tname}: graph chosen on {g['graph_chosen_frac']:.2f} "
                f"of queries < baseline {bfrac:.2f}")
        if g["graph_chosen_frac"] > 0 and g["recall_at_k"] < rt:
            failures.append(
                f"{tname}: recall@10 {g['recall_at_k']:.3f} < "
                f"target {rt}")
        if g["graph_chosen_frac"] > 0 and \
                g["gathered_frac"] > GRAPH_GATHER_CEILING:
            failures.append(
                f"{tname}: gathered {g['gathered_frac']:.2f} of rows > "
                f"ceiling {GRAPH_GATHER_CEILING}")
    if result["templates"]["g6"]["graph"]["graph_chosen_frac"] < 1.0:
        failures.append("g6 (pure NN): graph dispatch not chosen on "
                        "every query")
    return failures


# ---------------------------------------------------------------------------
# harness hooks (run.py) and CLI
# ---------------------------------------------------------------------------

def bench(scale: float = 1.0) -> List[str]:
    rows = []
    n_rows = int(6000 * scale)
    nq = max(10, int(25 * scale))
    for kind in ("search", "nn"):
        for engine in ("arcade", "single_index", "segment_full_load",
                       "full_scan"):
            r = run_latency(n_rows=n_rows, n_queries=nq, kind=kind,
                            engine=engine)
            rows.append(
                f"tab1_{kind}_{engine},{r['avg_ms'] * 1e3:.0f},"
                f"p95_ms={r['p95_ms']:.1f};blocks={r['blocks_per_q']:.0f}")
    rows.extend(csv_from_json(
        {"fused_vs_staged": run_fused_vs_staged(n_rows=int(6000 * scale)),
         "graph_study": run_graph_vs_ivf_vs_exact(
             n_rows=int(8000 * scale))}))
    return rows


def bench_json(scale: float = 1.0) -> Dict:
    out: Dict = {"tab1": {}}
    n_rows = int(6000 * scale)
    nq = max(10, int(25 * scale))
    for kind in ("search", "nn"):
        for engine in ("arcade", "single_index", "segment_full_load",
                       "full_scan"):
            out["tab1"][f"{kind}_{engine}"] = run_latency(
                n_rows=n_rows, n_queries=nq, kind=kind, engine=engine)
    out["fused_vs_staged"] = run_fused_vs_staged(n_rows=n_rows)
    out["graph_study"] = run_graph_vs_ivf_vs_exact(
        n_rows=int(8000 * scale))
    return out


def csv_from_json(data: Dict) -> List[str]:
    rows = []
    for key, r in data.get("tab1", {}).items():
        rows.append(f"tab1_{key},{r['avg_ms'] * 1e3:.0f},"
                    f"p95_ms={r['p95_ms']:.1f};"
                    f"blocks={r['blocks_per_q']:.0f}")
    fs = data.get("fused_vs_staged")
    if fs:
        h = fs["nn_heavy"]
        rows.append(
            f"fused_nn_heavy,{h['launch_ratio'] * 1e3:.0f},"
            f"launch_ratio={h['launch_ratio']:.1f};"
            f"bytes_ratio={h['bytes_ratio']:.1f};"
            f"identical={int(fs['identical_all'])}")
        for name, r in fs["templates"].items():
            rows.append(
                f"fused_{name},{r['fused']['ms'] * 1e3:.0f},"
                f"kind={r['kind']};fused={int(r['fused_chosen'])};"
                f"launches={r['fused']['launches']}v"
                f"{r['staged']['launches']};"
                f"bytes={r['fused']['bytes_to_host']}v"
                f"{r['staged']['bytes_to_host']}")
    gs = data.get("graph_study")
    if gs:
        for name, rec in gs["templates"].items():
            g = rec["graph"]
            rows.append(
                f"graph_{name},{g['p50_ms'] * 1e3:.0f},"
                f"chosen={g['graph_chosen_frac']:.2f};"
                f"recall={g['recall_at_k']:.3f};"
                f"gathered={g['gathered_frac']:.2f};"
                f"exact_p50={rec['exact']['p50_ms']:.1f}ms;"
                f"ivf_p50={rec['ivf']['p50_ms']:.1f}ms")
        s = gs["summary"]
        rows.append(
            f"graph_summary,{s['graph_p50_vs_exact'] * 1e3:.0f},"
            f"vs_exact={s['graph_p50_vs_exact']:.2f};"
            f"vs_ivf={s['graph_p50_vs_ivf']:.2f};"
            f"beats_exact={int(s['graph_beats_exact_p50'])};"
            f"beats_ivf={int(s['graph_beats_ivf_p50'])}")
    return rows


def _check_against_baseline(result: Dict, baseline: Dict) -> List[str]:
    """Machine-independent gates: identical results, fused never
    launches more than staged, and the NN-heavy launch/bytes advantage
    holds at no worse than half the committed baseline ratios."""
    failures = []
    if not result["identical_all"]:
        broken = [n for n, r in result["templates"].items()
                  if not r["identical"]]
        failures.append(f"fused != staged results on {broken}")
    h = result["nn_heavy"]
    if h["fused_launches"] > h["staged_launches"]:
        failures.append(
            f"fused launches {h['fused_launches']} > staged "
            f"{h['staged_launches']}")
    base = baseline.get("nn_heavy", {})
    for key, floor in (("launch_ratio", 3.0), ("bytes_ratio", 5.0)):
        want = max(floor, base.get(key, floor) / 2.0)
        if h[key] < want:
            failures.append(
                f"{key} {h[key]:.2f} < required {want:.2f} "
                f"(baseline {base.get(key)})")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload + baseline ratio gates")
    ap.add_argument("--graph-smoke", action="store_true",
                    help="graph study only: small workload + recall/"
                         "dispatch/gather gates vs the committed baseline")
    ap.add_argument("--json", default=None)
    ap.add_argument("--baseline", default=None)
    args = ap.parse_args()
    if args.graph_smoke:
        result = {"graph_study": run_graph_vs_ivf_vs_exact(
            n_rows=3200, n_segments=8, n_queries=6)}
    elif args.smoke:
        result = {"fused_vs_staged": run_fused_vs_staged(
            n_rows=3200, n_segments=8, batch=8, n_batches=1)}
    else:
        result = bench_json()
    for row in csv_from_json(result):
        print(row)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        failures = []
        if "fused_vs_staged" in result:
            failures += _check_against_baseline(
                result["fused_vs_staged"], baseline["fused_vs_staged"])
        if "graph_study" in result:
            failures += _check_graph_baseline(
                result["graph_study"], baseline["graph_study"])
        if failures:
            for msg in failures:
                print(f"SMOKE FAIL: {msg}", file=sys.stderr)
            sys.exit(1)
        print("smoke gates passed", file=sys.stderr)


if __name__ == "__main__":
    main()
