"""Observability overhead study: what the obs layer costs on the
query hot path, measured three ways on the same TRACY store.

  stripped  — the obs hooks are monkeypatched out in-process: the
              ``execute_many`` telemetry wrapper is bypassed and the
              kernel-dispatch registry mirror is replaced with no-op
              counters.  This approximates the pre-obs engine.
  disabled  — the shipped default: tracing off, metrics registry live.
  enabled   — ``set_tracing(True)``: full span trees recorded.

The three modes run back-to-back on identical query chunks with the
order rotating every triple, so clock drift and cache warmth cancel.
Scheduler noise is strictly additive, so each chunk's true per-mode
cost is the MIN over rounds (best-of-N); the gated ratio is the median
across chunks of those paired minima, and the reported p50s are
medians over all samples.  The machine-independent gates are

  disabled_over_stripped <= 1.02   (tracing off must cost <= 2%)
  enabled_over_disabled  <= 1.15   (tracing on must cost <= 15%)

A ``registry`` micro-section reports the raw cost of one counter
``inc`` and one histogram ``observe`` (ns; informational, no gate).

CLI:  python benchmarks/obs_overhead.py [--smoke] [--json PATH]
                                        [--baseline PATH]
With --baseline the ratios above are gated (CI obs-smoke job); the
committed JSON records the reference numbers the gate message cites.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Any, Dict, List, Optional

import numpy as np

if __package__ in (None, ""):    # `python benchmarks/obs_overhead.py`
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import tracy
from repro.core.executor import Executor
from repro.kernels import ops as kops
from repro.obs import REGISTRY
from repro.obs import trace as obs_trace

DIM = 32
BATCH = 8                      # queries per timed execute_many call


class _NoopCounter:
    def inc(self, n: int = 1) -> None:
        pass


def _patch_stripped():
    """Remove the obs hooks from the hot path; returns restore state."""
    saved = (Executor.execute_many, kops._registry_counters)
    Executor.execute_many = Executor._execute_many
    noop = (_NoopCounter(), _NoopCounter(), _NoopCounter())
    kops._registry_counters = lambda: noop
    return saved


def _unpatch(saved) -> None:
    Executor.execute_many, kops._registry_counters = saved


MODES = ("stripped", "disabled", "enabled")


def _run_mode(mode: str, ex: Executor, chunk: List) -> float:
    """Per-query latency for one chunk under one obs mode."""
    if mode == "stripped":
        saved = _patch_stripped()
        try:
            return _run_mode("disabled", ex, chunk)
        finally:
            _unpatch(saved)
    if mode == "enabled":
        obs_trace.set_tracing(True)
        try:
            t = _run_mode("disabled", ex, chunk)
        finally:
            obs_trace.set_tracing(False)
            obs_trace.TRACER.clear()
        return t
    t0 = time.perf_counter()
    ex.execute_many(chunk)
    return (time.perf_counter() - t0) / len(chunk)


def run_query_overhead(n_rows: int = 4000, n_queries: int = 32,
                       rounds: int = 40) -> Dict[str, float]:
    cfg = tracy.TracyConfig(n_rows=n_rows, dim=DIM, seed=5,
                            flush_rows=max(256, n_rows // 8))
    store, data = tracy.build_store(cfg)
    ex = Executor(store)
    search, nn = tracy.make_templates(data)
    templates = search + nn
    data.rng = np.random.default_rng(17)
    queries = [templates[i % len(templates)]() for i in range(n_queries)]
    for _ in range(3):          # warm jit caches + segment readers
        ex.execute_many(queries)
    chunks = [queries[i:i + BATCH]
              for i in range(0, len(queries), BATCH)]
    # times[mode][ci] = per-query latency of chunk ci, one per round
    times: Dict[str, List[List[float]]] = {
        m: [[] for _ in chunks] for m in MODES}
    for r in range(rounds):
        for ci, chunk in enumerate(chunks):
            # the three modes run back-to-back on the SAME chunk so
            # clock drift and query-mix difficulty cancel; the order
            # rotates so position-in-triple effects cancel too
            rot = (r + ci) % len(MODES)
            for mode in MODES[rot:] + MODES[:rot]:
                times[mode][ci].append(_run_mode(mode, ex, chunk))
    # scheduler/GC noise is strictly additive, so the min over rounds
    # is the clean estimate of a chunk's true cost per mode; the gated
    # ratio is the median across chunks of those best-of-N pairs
    ratios_ds = [min(times["disabled"][ci]) / min(times["stripped"][ci])
                 for ci in range(len(chunks))]
    ratios_ed = [min(times["enabled"][ci]) / min(times["disabled"][ci])
                 for ci in range(len(chunks))]
    p50 = {m: float(np.median([t for per in v for t in per]))
           for m, v in times.items()}
    return {
        "p50_stripped_us": p50["stripped"] * 1e6,
        "p50_disabled_us": p50["disabled"] * 1e6,
        "p50_enabled_us": p50["enabled"] * 1e6,
        "disabled_over_stripped": float(np.median(ratios_ds)),
        "enabled_over_disabled": float(np.median(ratios_ed)),
        "rows": float(n_rows),
        "queries_per_round": float(n_queries),
        "rounds": float(rounds),
    }


def run_registry_cost(n: int = 200_000) -> Dict[str, float]:
    """Raw metric-op cost: ns per counter inc / histogram observe."""
    c = REGISTRY.counter("obs_bench.scratch_count")
    t0 = time.perf_counter()
    for _ in range(n):
        c.inc()
    inc_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for i in range(n):
        REGISTRY.observe("obs_bench.scratch_s", i * 1e-6)
    obs_s = time.perf_counter() - t0
    return {"ns_per_inc": inc_s / n * 1e9,
            "ns_per_observe": obs_s / n * 1e9,
            "ops": float(n)}


def bench_json(scale: float = 1.0) -> Dict[str, Any]:
    return {
        "query": run_query_overhead(
            n_rows=max(1200, int(4000 * scale)),
            rounds=max(24, int(40 * scale))),
        "registry": run_registry_cost(n=max(20_000, int(200_000 * scale))),
    }


def csv_from_json(r: Dict[str, Any]) -> List[str]:
    """CSV rows for benchmarks/run.py from a ``bench_json`` result."""
    qr, reg = r["query"], r["registry"]
    return [
        f"obs_query_p50,{qr['p50_disabled_us']:.0f},"
        f"disabled_over_stripped={qr['disabled_over_stripped']:.3f}x;"
        f"enabled_over_disabled={qr['enabled_over_disabled']:.3f}x",
        f"obs_registry_ops,0.0,"
        f"ns_per_inc={reg['ns_per_inc']:.0f};"
        f"ns_per_observe={reg['ns_per_observe']:.0f}",
    ]


def bench(scale: float = 1.0) -> List[str]:
    return csv_from_json(bench_json(scale))


def check_baseline(result: Dict[str, Any], baseline: Dict[str, Any]
                   ) -> List[str]:
    """The obs cost contract (absolute, machine-independent ratios)."""
    errors = []
    qr = result["query"]
    ref = baseline.get("query", {})
    got = qr["disabled_over_stripped"]
    if got > 1.02:
        errors.append(
            f"tracing-off overhead above the 2% budget: {got:.3f}x "
            f"(baseline {ref.get('disabled_over_stripped', 0.0):.3f}x)")
    got = qr["enabled_over_disabled"]
    if got > 1.15:
        errors.append(
            f"tracing-on overhead above the 15% budget: {got:.3f}x "
            f"(baseline {ref.get('enabled_over_disabled', 0.0):.3f}x)")
    return errors


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run (CI)")
    ap.add_argument("--json", default=None,
                    help="write structured results to PATH ('-' = stdout)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON to check ratios against")
    args = ap.parse_args(argv)
    scale = 0.33 if args.smoke else args.scale
    result = bench_json(scale)
    text = json.dumps(result, indent=2, sort_keys=True)
    if args.json == "-":
        print(text)
    elif args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        errors = check_baseline(result, baseline)
        for e in errors:
            print(f"REGRESSION: {e}", file=sys.stderr)
        if errors:
            return 1
        print("baseline check passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
