"""Sharded serving benchmark: read-path scaling + exact parity gates.

Builds the TRACY workload once per shard count (identical ingest stream)
behind ``Database(schema, shards=N)`` and executes every TRACY template
through ``execute_many`` batches at 1/2/4/8 shards, checking three
machine-independent properties against the single-store reference:

  parity    sharded results are bitwise equal (pk AND score) to the
            single-store engine on every template, with live memtable
            overlays included;
  payload   the cross-shard merge hands the host at most ``shards * k``
            candidate rows per query on fused-eligible (NN) templates —
            the device-side merge contract;
  scaling   the read-path critical path (rows scanned on the busiest
            shard, the wall-clock proxy when shards execute in parallel)
            shrinks near-linearly with the shard count.

CLI:  python benchmarks/sharded_bench.py [--smoke] [--json PATH]
                                         [--baseline PATH]
With ``--baseline``, the committed ratios gate CI: parity must hold,
payload must respect the shards*k bound, and the critical-path speedup
at the highest shard count may not drop below half the baseline.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

if __package__ in (None, ""):        # `python benchmarks/sharded_bench.py`
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import tracy
from repro.core.api import Database
from repro.core.lsm import LSMConfig

TEMPLATE_NAMES = ["t1", "t2", "t3", "t4", "t5", "t12",
                  "t6", "t7", "t8", "t9", "t10", "t11", "t13"]
SHARD_COUNTS = (1, 2, 4, 8)


def build_db(cfg: tracy.TracyConfig, n_shards: int):
    """One Database over the TRACY ingest stream; the stream is fully
    determined by ``cfg.seed`` so every shard count sees identical rows.
    The last sub-threshold batch stays in the memtable(s) — parity runs
    with a live overlay, not a fully-flushed store."""
    data = tracy.TracyData(cfg)
    db = Database(tracy.tweet_schema(cfg.dim),
                  LSMConfig(flush_rows=cfg.flush_rows, fanout=cfg.fanout),
                  shards=n_shards)
    t = db.table()
    done = 0
    while done < cfg.n_rows:
        n = min(cfg.flush_rows, 2048, cfg.n_rows - done)
        pks, batch = data.batch(n)
        t.put(pks, batch)
        done += n
    t.flush()
    # a live memtable tail on top of the flushed segments
    pks, batch = data.batch(max(16, cfg.flush_rows // 8))
    t.put(pks, batch)
    return t, data


def run_scaling(n_rows: int = 8000, shard_counts=SHARD_COUNTS,
                batch: int = 8, n_batches: int = 2, dim: int = 48,
                seed: int = 0) -> Dict:
    """Sized to stay inside the host-dispatch regime (every distance
    call below ``kops.HOST_FLOP_CUTOFF``, including the single store's
    packed fused superbatch: batch * bucket(n_rows) * dim < 4M MACs) —
    that is the regime where the engine's bitwise-equality contract
    holds; above it, differently-partitioned layouts land on
    differently-bucketed jit shapes whose rounding may legally differ."""
    cfg = tracy.TracyConfig(n_rows=n_rows, dim=dim, seed=seed,
                            flush_rows=max(64, n_rows // 8), fanout=100)
    out: Dict = {"config": {"n_rows": n_rows, "dim": dim, "batch": batch,
                            "n_batches": n_batches,
                            "shard_counts": list(shard_counts)},
                 "templates": {}, "summary": {}}
    reference: Dict[str, List] = {}
    single_rows: Dict[str, float] = {}
    for n_shards in shard_counts:
        table, data = build_db(cfg, n_shards)
        search_t, nn_t = tracy.make_templates(data)
        for name, tmpl in zip(TEMPLATE_NAMES, search_t + nn_t):
            rec = out["templates"].setdefault(name, {"k": 10})
            res: List = []
            t0 = time.perf_counter()
            for b in range(n_batches):
                # identical query parameters at every shard count
                data.rng = np.random.default_rng(seed + 1000 + b)
                res.extend(table.executor.execute_many(
                    [tmpl() for _ in range(batch)]))
            dt = time.perf_counter() - t0
            pkscores = [[(r.pk, float(r.score)) for r in rows]
                        for rows, _ in res]
            stats = [st for _, st in res]
            entry = {
                "ms": dt * 1e3 / max(1, len(res)),
                "rows_scanned": float(np.mean(
                    [s.rows_scanned for s in stats])),
                "critical_rows": float(np.mean(
                    [s.shard_rows_max if n_shards > 1 else s.rows_scanned
                     for s in stats])),
                "launches": int(sum(s.kernel_launches for s in stats)),
                "merge_rows_max": int(max(s.merge_rows for s in stats)),
                "payload_bound": n_shards * 10,
                "fused_chosen": "dispatch=fused" in stats[0].plan,
            }
            if n_shards == 1:
                reference[name] = pkscores
                single_rows[name] = entry["rows_scanned"]
                entry["parity"] = True
                entry["speedup"] = 1.0
            else:
                entry["parity"] = pkscores == reference[name]
                # selective index probes scan ~no rows; call that 1.0
                # instead of a meaningless 0/eps ratio
                entry["speedup"] = single_rows[name] / \
                    max(1.0, entry["critical_rows"]) \
                    if single_rows[name] >= 1.0 else 1.0
            rec[str(n_shards)] = entry
    # ------------------------------------------------------------ summary
    max_n = max(shard_counts)
    nn_names = [n for n, r in out["templates"].items()
                if r[str(max_n)]["merge_rows_max"] > 0]
    scan_names = [n for n in nn_names
                  if out["templates"][n][str(max_n)]["fused_chosen"]]
    out["summary"] = {
        "parity_all": all(r[str(n)]["parity"]
                          for r in out["templates"].values()
                          for n in shard_counts),
        "payload_ok": all(
            r[str(n)]["merge_rows_max"] <= r[str(n)]["payload_bound"]
            for r in out["templates"].values() for n in shard_counts
            if n > 1),
        "nn_templates": nn_names,
        "fused_templates": scan_names,
        # critical-path speedup over the templates that scan (NN shapes);
        # selective index probes have little to parallelize
        "speedup_at_max": float(np.mean(
            [out["templates"][n][str(max_n)]["speedup"]
             for n in nn_names])) if nn_names else 1.0,
        "max_shards": max_n,
    }
    return out


# ---------------------------------------------------------------------------
# harness hooks (run.py) and CLI
# ---------------------------------------------------------------------------

def bench_json(scale: float = 1.0) -> Dict:
    return run_scaling(n_rows=int(8000 * scale))


def csv_from_json(data: Dict) -> List[str]:
    rows = []
    s = data["summary"]
    rows.append(
        f"sharded_scaling,{s['speedup_at_max'] * 1e3:.0f},"
        f"speedup_at_{s['max_shards']}={s['speedup_at_max']:.2f};"
        f"parity={int(s['parity_all'])};payload_ok={int(s['payload_ok'])}")
    max_n = str(s["max_shards"])
    for name, r in data["templates"].items():
        e = r[max_n]
        rows.append(
            f"sharded_{name},{e['ms'] * 1e3:.0f},"
            f"speedup={e['speedup']:.2f};parity={int(e['parity'])};"
            f"merge_rows={e['merge_rows_max']}/{e['payload_bound']};"
            f"launches={e['launches']}")
    return rows


def bench(scale: float = 1.0) -> List[str]:
    return csv_from_json(bench_json(scale))


def _check_against_baseline(result: Dict, baseline: Dict) -> List[str]:
    failures = []
    s = result["summary"]
    if not s["parity_all"]:
        broken = [n for n, r in result["templates"].items()
                  if not all(e.get("parity", True) for key, e in r.items()
                             if key.isdigit())]
        failures.append(f"sharded != single-store results on {broken}")
    if not s["payload_ok"]:
        failures.append("cross-shard merge payload exceeded shards*k")
    base = baseline.get("summary", {})
    want = max(1.5, base.get("speedup_at_max", 3.0) / 2.0)
    if s["speedup_at_max"] < want:
        failures.append(
            f"critical-path speedup {s['speedup_at_max']:.2f} < "
            f"required {want:.2f} (baseline {base.get('speedup_at_max')})")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload + baseline ratio gates")
    ap.add_argument("--json", default=None)
    ap.add_argument("--baseline", default=None)
    args = ap.parse_args()
    if args.smoke:
        result = run_scaling(n_rows=3200, batch=8, n_batches=1, dim=32)
    else:
        result = run_scaling()
    for row in csv_from_json(result):
        print(row)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        failures = _check_against_baseline(result, baseline)
        if failures:
            for msg in failures:
                print(f"SMOKE FAIL: {msg}", file=sys.stderr)
            sys.exit(1)
        print("smoke gates passed", file=sys.stderr)


if __name__ == "__main__":
    main()
