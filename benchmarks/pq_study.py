"""PQ-IVF study (paper §2.1: VECTOR_INDEX_TYPE 'pqivf'): recall/latency/
memory trade-off of product quantization vs plain IVF on the TRACY
embedding workload. ADC runs through the one-hot-matmul kernel semantics
(kernels/pq_adc.py) with exact re-ranking."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks import tracy
from repro.core.types import IndexKind
from repro.kernels import ops as kops


def run_pq(n_rows: int = 6000, n_queries: int = 25, k: int = 10,
           seed: int = 0):
    out = {}
    for kind, name in ((IndexKind.IVF, "ivf"), (IndexKind.PQIVF, "pqivf")):
        cfg = tracy.TracyConfig(n_rows=n_rows, seed=seed, dim=64)
        store, data = tracy.build_store(cfg, vector_index=kind)
        # exact ground truth over all segments
        vecs = np.concatenate([s.columns["embedding"]
                               for s in store.segments])
        pks = np.concatenate([s.pk for s in store.segments])
        rng = np.random.default_rng(seed + 5)
        lat, recall, idx_bytes = [], [], 0
        for seg in store.segments:
            idx = seg.indexes["embedding"]
            idx_bytes += idx.post_vecs.nbytes + idx.centroids.nbytes
            if idx.codes is not None:
                idx_bytes += idx.codes.nbytes + idx.codebooks.nbytes
        for _ in range(n_queries):
            qv = data.query_vec()
            d = np.sqrt(((vecs - qv) ** 2).sum(1))
            truth = set(pks[np.argsort(d)[:k]].tolist())
            t0 = time.perf_counter()
            got = []
            for seg in store.segments:
                dd, rows, _ = seg.indexes["embedding"].search(qv, k)
                got += [(float(x), int(seg.pk[r]))
                        for x, r in zip(dd, rows)]
            got.sort()
            lat.append(time.perf_counter() - t0)
            recall.append(len(set(p for _, p in got[:k]) & truth) / k)
        out[name] = {
            "avg_ms": float(np.mean(lat) * 1e3),
            "recall": float(np.mean(recall)),
            "index_mb": idx_bytes / 2**20,
        }
    return out


def bench(scale: float = 1.0) -> List[str]:
    r = run_pq(n_rows=int(6000 * scale))
    rows = []
    for name, v in r.items():
        rows.append(f"pq_{name},{v['avg_ms'] * 1e3:.0f},"
                    f"recall@10={v['recall']:.2f};"
                    f"index_mb={v['index_mb']:.1f}")
    return rows
