"""Quantized-residence study: PQ/int8 rank columns streamed through the
fused scan->top-k kernels with exact re-rank, vs the full-precision
fused path.

``run_quantized_study`` drives the quantized-eligible TRACY NN templates
(t6 pure vector NN, t8 NN + time filter, t13 disjunctive NN) through the
``Database`` facade twice with identical query streams — once exact
(no ``recall_target``: full-precision fused scan) and once quantized
(``recall_target=0.9``: PQ-ADC candidate generation + exact re-rank of
the refine*k survivors) — and reports per-template logical bytes
scanned, recall@k against the exact results, re-ranked row counts and
latency.  Bytes are the planner's machine-independent accounting
(``ExecStats.bytes_scanned``: mask-passing rows x bytes-per-row of
whatever column representation the kernel streamed), so the headline
bytes ratio ~ 4*dim/m is stable across hosts.

The legacy PQ-IVF index study (``run_pq``: recall/latency/memory of
IndexKind.PQIVF vs plain IVF probes, paper §2.1) is kept below — it
measures the *index* tier, while the quantized study measures the
*scan* tier.

CLI:  python benchmarks/pq_study.py [--smoke] [--json PATH]
                                    [--baseline PATH]
With ``--baseline``, machine-independent ratios are gated against the
committed JSON (CI quantized-smoke job): fails if the quantized bytes-
scanned reduction on the eligible templates drops below 8x (or half the
committed baseline, whichever is larger), or recall@10 falls under 0.95
at the default refine ladder.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

if __package__ in (None, ""):        # `python benchmarks/pq_study.py`
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import tracy
from repro.core.api import Database
from repro.core.types import IndexKind

# quantized-eligible NN templates: single positive VectorRank (t10 ranks
# by SpatialRank, t7/t9/t11 are multi-rank — controls, not eligible)
QUANT_TEMPLATES = {"t6": 0, "t8": 2, "t13": 6}
RECALL_TARGET = 0.9


def run_quantized_study(n_rows: int = 6000, n_segments: int = 8,
                        batch: int = 8, n_batches: int = 2,
                        dim: int = 64, k: int = 10,
                        seed: int = 0) -> Dict:
    """Exact vs quantized dispatch over the eligible TRACY NN templates
    with identical query streams (the data rng is reseeded per batch, so
    both modes see the same query vectors and filter bounds)."""
    cfg = tracy.TracyConfig(n_rows=n_rows, dim=dim, seed=seed,
                            flush_rows=max(1, n_rows // n_segments),
                            fanout=4 * n_segments,
                            pq_m=max(1, dim // 2))   # dsub=2 codebooks
    store, data = tracy.build_store(cfg)
    db = Database(schema=None)
    table = db.adopt_store("tracy", store)
    _, nn_t = tracy.make_templates(data)
    out: Dict = {"config": {"n_rows": n_rows, "dim": dim, "batch": batch,
                            "n_batches": n_batches, "k": k,
                            "recall_target": RECALL_TARGET,
                            "n_segments": len(store.segments)},
                 "templates": {}}
    for name, ti in QUANT_TEMPLATES.items():
        tmpl = nn_t[ti]
        rec: Dict = {}
        results: Dict[str, List] = {}
        for mode in ("exact", "quantized"):
            res: List = []
            t0 = time.perf_counter()
            for b in range(n_batches):
                # identical query parameters in both modes
                data.rng = np.random.default_rng(seed + 1000 + b)
                queries = [tmpl() for _ in range(batch)]
                if mode == "quantized":
                    for qq in queries:
                        qq.recall_target = RECALL_TARGET
                res.extend(table.execute_many(queries))
            dt = time.perf_counter() - t0
            rec[mode] = {
                "bytes_scanned": sum(st.bytes_scanned for _, st in res),
                "rerank_rows": sum(st.rerank_rows for _, st in res),
                "rows_scanned": sum(st.rows_scanned for _, st in res),
                "ms": dt * 1e3,
            }
            results[mode] = [[r.pk for r in rows] for rows, _ in res]
            if mode == "quantized":
                rec["quantized_chosen"] = \
                    "dispatch=quantized" in res[0][1].plan
        hits = [len(set(e[:k]) & set(g[:k])) / max(1, min(k, len(e)))
                for e, g in zip(results["exact"], results["quantized"])
                if e]
        rec["recall_at_k"] = float(np.mean(hits)) if hits else 1.0
        rec["bytes_ratio"] = rec["exact"]["bytes_scanned"] / \
            max(1, rec["quantized"]["bytes_scanned"])
        out["templates"][name] = rec
    eligible = [n for n, r in out["templates"].items()
                if r["quantized_chosen"]]
    eb = sum(out["templates"][n]["exact"]["bytes_scanned"]
             for n in eligible)
    qb = sum(out["templates"][n]["quantized"]["bytes_scanned"]
             for n in eligible)
    out["summary"] = {
        "templates": eligible,
        "exact_bytes": eb, "quantized_bytes": qb,
        "bytes_ratio": eb / max(1, qb),
        "recall_at_k": float(np.mean(
            [out["templates"][n]["recall_at_k"] for n in eligible]))
        if eligible else 0.0,
        "rerank_rows": sum(out["templates"][n]["quantized"]["rerank_rows"]
                           for n in eligible),
    }
    return out


# ---------------------------------------------------------------------------
# legacy PQ-IVF index study (paper §2.1: VECTOR_INDEX_TYPE 'pqivf')
# ---------------------------------------------------------------------------

def run_pq(n_rows: int = 6000, n_queries: int = 25, k: int = 10,
           seed: int = 0) -> Dict:
    """Recall/latency/memory trade-off of PQ-IVF vs plain IVF *index
    probes* on the TRACY embedding workload."""
    out = {}
    for kind, name in ((IndexKind.IVF, "ivf"), (IndexKind.PQIVF, "pqivf")):
        cfg = tracy.TracyConfig(n_rows=n_rows, seed=seed, dim=64)
        store, data = tracy.build_store(cfg, vector_index=kind)
        # exact ground truth over all segments
        vecs = np.concatenate([s.columns["embedding"]
                               for s in store.segments])
        pks = np.concatenate([s.pk for s in store.segments])
        lat, recall, idx_bytes = [], [], 0
        for seg in store.segments:
            idx = seg.indexes["embedding"]
            idx_bytes += idx.post_vecs.nbytes + idx.centroids.nbytes
            if idx.codes is not None:
                idx_bytes += idx.codes.nbytes + idx.codebooks.nbytes
        for _ in range(n_queries):
            qv = data.query_vec()
            d = np.sqrt(((vecs - qv) ** 2).sum(1))
            truth = set(pks[np.argsort(d)[:k]].tolist())
            t0 = time.perf_counter()
            got = []
            for seg in store.segments:
                dd, rows, _ = seg.indexes["embedding"].search(qv, k)
                got += [(float(x), int(seg.pk[r]))
                        for x, r in zip(dd, rows)]
            got.sort()
            lat.append(time.perf_counter() - t0)
            recall.append(len(set(p for _, p in got[:k]) & truth) / k)
        out[name] = {
            "avg_ms": float(np.mean(lat) * 1e3),
            "recall": float(np.mean(recall)),
            "index_mb": idx_bytes / 2**20,
        }
    return out


# ---------------------------------------------------------------------------
# harness hooks (run.py) and CLI
# ---------------------------------------------------------------------------

def bench(scale: float = 1.0) -> List[str]:
    return csv_from_json(bench_json(scale))


def bench_json(scale: float = 1.0) -> Dict:
    return {"quantized": run_quantized_study(n_rows=int(6000 * scale)),
            "pqivf": run_pq(n_rows=int(6000 * scale))}


def csv_from_json(data: Dict) -> List[str]:
    rows = []
    qs = data.get("quantized")
    if qs:
        s = qs["summary"]
        rows.append(
            f"pq_scan_summary,{s['bytes_ratio'] * 1e3:.0f},"
            f"bytes_ratio={s['bytes_ratio']:.1f};"
            f"recall@k={s['recall_at_k']:.3f};"
            f"rerank_rows={s['rerank_rows']}")
        for name, r in qs["templates"].items():
            rows.append(
                f"pq_scan_{name},{r['quantized']['ms'] * 1e3:.0f},"
                f"bytes={r['quantized']['bytes_scanned']}v"
                f"{r['exact']['bytes_scanned']};"
                f"ratio={r['bytes_ratio']:.1f};"
                f"recall@k={r['recall_at_k']:.3f};"
                f"quantized={int(r['quantized_chosen'])}")
    for name, v in data.get("pqivf", {}).items():
        rows.append(f"pq_{name},{v['avg_ms'] * 1e3:.0f},"
                    f"recall@10={v['recall']:.2f};"
                    f"index_mb={v['index_mb']:.1f}")
    return rows


def _check_against_baseline(result: Dict, baseline: Dict) -> List[str]:
    """Machine-independent gates: the quantized dispatch must actually be
    chosen on every eligible template, the logical bytes-scanned
    reduction must hold at >= 8x (or half the committed baseline ratio,
    whichever is larger), and recall@k must stay >= 0.95 at the default
    refine ladder."""
    failures = []
    not_chosen = [n for n, r in result["templates"].items()
                  if not r["quantized_chosen"]]
    if not_chosen:
        failures.append(f"quantized dispatch not chosen on {not_chosen}")
    s = result["summary"]
    base = baseline.get("summary", {})
    want_ratio = max(8.0, base.get("bytes_ratio", 8.0) / 2.0)
    if s["bytes_ratio"] < want_ratio:
        failures.append(
            f"bytes_ratio {s['bytes_ratio']:.2f} < required "
            f"{want_ratio:.2f} (baseline {base.get('bytes_ratio')})")
    if s["recall_at_k"] < 0.95:
        failures.append(
            f"recall@k {s['recall_at_k']:.3f} < required 0.95 "
            f"(baseline {base.get('recall_at_k')})")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload + baseline ratio gates")
    ap.add_argument("--json", default=None)
    ap.add_argument("--baseline", default=None)
    args = ap.parse_args()
    if args.smoke:
        result = {"quantized": run_quantized_study(
            n_rows=3200, n_segments=8, batch=8, n_batches=1)}
    else:
        result = bench_json()
    for row in csv_from_json(result):
        print(row)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        failures = _check_against_baseline(
            result["quantized"], baseline["quantized"])
        if failures:
            for msg in failures:
                print(f"SMOKE FAIL: {msg}", file=sys.stderr)
            sys.exit(1)
        print("smoke gates passed", file=sys.stderr)


if __name__ == "__main__":
    main()
