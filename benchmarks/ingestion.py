"""Ingestion study (paper §1/§3-§4: secondary-index maintenance never on
the write critical path; global in-memory vector index "caused the
ingestion throughput to drop by as much as 75x").

Four write-path designs over the same TRACY batches:

  per_row       — the pre-refactor memtable: Python per-row/per-column
                  appends on the critical path (kept here as the
                  reference implementation the columnar rewrite is
                  measured against).
  columnar      — chunked columnar memtable, inline flush/compaction.
  pipelined     — columnar + FlushScheduler: puts only append; sealed
                  memtables flush and tiers compact from the work queue
                  (deterministic drain; write stalls on compaction debt).
  global_index  — Milvus/FAISS-style global IVF maintained synchronously
                  with every put (the design the paper measured 75x
                  slower).

Workloads: write-heavy (pure ingest), mixed (interleaved puts + hybrid
queries), and compaction index maintenance (merge vs rebuild).

CLI:  python benchmarks/ingestion.py [--smoke] [--json PATH]
                                     [--baseline PATH]
With --baseline, machine-independent *ratios* are checked against the
committed JSON (CI smoke job): fails if the columnar-vs-per-row put
speedup regressed by more than 2x, or index merge stopped beating
rebuild at compaction.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Any, Dict, List, Optional

import numpy as np

if __package__ in (None, ""):        # `python benchmarks/ingestion.py`
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import baselines as bl
from benchmarks import tracy
from repro.core import query as q
from repro.core.executor import Executor
from repro.core.lsm import LSMConfig, LSMStore
from repro.core.types import ColumnType


class PerRowMemTable:
    """The seed's memtable, verbatim in spirit: Python lists, one loop
    iteration per row *and* per column on the write path.  The benchmark
    baseline — do not 'optimize'."""

    def __init__(self, schema):
        self.schema = schema
        self._pk: List[int] = []
        self._seqno: List[int] = []
        self._tomb: List[bool] = []
        self._cols: Dict[str, List[Any]] = {c.name: [] for c in
                                            schema.columns}
        self._latest: Dict[int, int] = {}
        self._scan_cache = None

    def __len__(self):
        return len(self._pk)

    @property
    def approx_bytes(self):
        n = len(self._pk)
        per_row = 16
        for c in self.schema.columns:
            if c.ctype == ColumnType.VECTOR:
                per_row += 4 * c.dim
            elif c.ctype == ColumnType.SPATIAL:
                per_row += 8
            else:
                per_row += 24
        return n * per_row

    def put_batch(self, pks, batch, seqno_start, tombstone=False):
        self._scan_cache = None
        seq = seqno_start
        for i in range(len(pks)):
            self._latest[int(pks[i])] = len(self._pk)
            self._pk.append(int(pks[i]))
            self._seqno.append(seq)
            self._tomb.append(tombstone)
            for c in self.schema.columns:
                if tombstone:
                    self._cols[c.name].append(
                        np.zeros((c.dim,), np.float32)
                        if c.ctype == ColumnType.VECTOR else
                        np.zeros((2,), np.float32)
                        if c.ctype == ColumnType.SPATIAL else
                        0.0 if c.ctype == ColumnType.SCALAR else "")
                else:
                    self._cols[c.name].append(batch[c.name][i])
            seq += 1
        return seq

    def get(self, key):
        i = self._latest.get(int(key))
        if i is None:
            return None
        row = {"_pk": self._pk[i], "_seqno": self._seqno[i],
               "_tombstone": self._tomb[i]}
        for name, vals in self._cols.items():
            row[name] = vals[i]
        return row

    def scan_arrays(self):
        if self._scan_cache is not None:
            return self._scan_cache
        pk = np.asarray(self._pk, np.int64)
        seqno = np.asarray(self._seqno, np.int64)
        tomb = np.asarray(self._tomb, bool)
        cols = {}
        for c in self.schema.columns:
            vals = self._cols[c.name]
            if c.ctype == ColumnType.VECTOR:
                cols[c.name] = np.asarray(vals, np.float32).reshape(
                    len(vals), c.dim) if vals else np.zeros((0, c.dim),
                                                            np.float32)
            elif c.ctype == ColumnType.SPATIAL:
                cols[c.name] = np.asarray(vals, np.float32).reshape(
                    len(vals), 2) if vals else np.zeros((0, 2), np.float32)
            elif c.ctype == ColumnType.SCALAR:
                cols[c.name] = np.asarray(vals, np.float64)
            else:
                cols[c.name] = np.asarray(vals, object)
        self._scan_cache = (pk, seqno, tomb, cols)
        return self._scan_cache


def _make_store(mode: str, dim: int, flush_rows: int) -> LSMStore:
    schema = tracy.tweet_schema(dim)
    if mode == "per_row":
        return LSMStore(schema, LSMConfig(flush_rows=flush_rows),
                        memtable_factory=PerRowMemTable)
    if mode == "pipelined":
        return LSMStore(schema, LSMConfig(flush_rows=flush_rows,
                                          pipeline=True))
    if mode == "background":
        return LSMStore(schema, LSMConfig(flush_rows=flush_rows,
                                          pipeline=True, background=True))
    return LSMStore(schema, LSMConfig(flush_rows=flush_rows))


def run_ingestion(n_rows: int = 8000, batch: int = 256,
                  mode: str = "columnar", seed: int = 0,
                  flush_rows: int = 2048) -> Dict[str, float]:
    """Write-heavy workload: pure ingest of ``n_rows`` in columnar
    batches.  ``put_rows_per_s`` charges only the write critical path
    (time inside ``put``, including any write stalls); ``rows_per_s`` is
    end-to-end including the final drain/flush."""
    data = tracy.TracyData(tracy.TracyConfig(n_rows=0, seed=seed, dim=64))
    if mode == "global_index":
        store = _make_store("columnar", 64, flush_rows)
        writer = bl.GlobalIndexWriter(store, dim=64, rebuild_every=1024)
    else:
        store = _make_store(mode, 64, flush_rows)
        writer = None
    put_s = 0.0
    t0 = time.perf_counter()
    done = 0
    while done < n_rows:
        pks, b = data.batch(batch)
        t1 = time.perf_counter()
        (writer or store).put(pks, b)
        put_s += time.perf_counter() - t1
        done += batch
    store.flush()
    if mode == "background":
        store.scheduler.close()
    dt = time.perf_counter() - t0
    return {"rows_per_s": n_rows / dt, "wall_s": dt,
            "put_rows_per_s": n_rows / max(put_s, 1e-9), "put_s": put_s,
            "stalls": float(store.metrics["stalls"]),
            "flushes": float(store.metrics["flushes"]),
            "compactions": float(store.metrics["compactions"])}


def run_mixed(n_rows: int = 4000, n_ops: int = 120,
              write_frac: float = 0.5, seed: int = 0) -> Dict[str, float]:
    """Mixed read/write workload over the pipelined store: hybrid
    queries (vector NN + scalar range) interleave with columnar puts;
    reads see sealed-but-unflushed memtables, writes stall only on
    compaction debt."""
    cfg = tracy.TracyConfig(n_rows=n_rows, seed=seed, dim=64,
                            flush_rows=1024)
    data = tracy.TracyData(cfg)
    store = LSMStore(tracy.tweet_schema(64),
                     LSMConfig(flush_rows=1024, pipeline=True))
    done = 0
    while done < n_rows:
        pks, b = data.batch(1024)
        store.put(pks, b)
        done += 1024
    ex = Executor(store)
    rng = np.random.default_rng(seed + 1)
    reads = writes = rows = 0
    t0 = time.perf_counter()
    for _ in range(n_ops):
        if rng.random() < write_frac:
            pks, b = data.batch(128)
            store.put(pks, b)
            writes += 1
            rows += 128
        else:
            lo = float(rng.uniform(0, 800))
            qq = q.HybridQuery(
                where=q.Range("time", lo, lo + 200),
                ranks=[q.VectorRank("embedding", data.query_vec(), 1.0)],
                k=10)
            ex.execute(qq)
            reads += 1
    store.drain()
    dt = time.perf_counter() - t0
    return {"wall_s": dt, "ops_per_s": n_ops / dt,
            "rows_per_s": rows / dt, "queries_per_s": reads / dt,
            "reads": float(reads), "writes": float(writes),
            "stalls": float(store.metrics["stalls"])}


def run_merge_vs_rebuild(n_rows: int = 12000, seed: int = 0,
                         repeats: int = 3) -> Dict[str, float]:
    """Index maintenance at compaction: identical data ingested twice,
    once with mergeable per-segment indexes, once with the pre-refactor
    rebuild-from-scratch path; compares the compaction-time index cost.
    Best-of-``repeats`` per path — single-compaction timings are noisy
    at smoke scale."""
    out: Dict[str, float] = {}
    for label, merge in (("merge", True), ("rebuild", False)):
        best = None
        for rep in range(max(1, repeats)):
            data = tracy.TracyData(tracy.TracyConfig(n_rows=0, seed=seed,
                                                     dim=64))
            store = LSMStore(tracy.tweet_schema(64),
                             LSMConfig(flush_rows=1024, fanout=4,
                                       merge_indexes=merge))
            done = 0
            while done < n_rows:
                pks, b = data.batch(512)
                store.put(pks, b)
                done += 512
            store.flush()
            cost = store.metrics["index_merge_s"] + \
                store.metrics["index_rebuild_s"]
            best = cost if best is None else min(best, cost)
            out[f"{label}_compactions"] = \
                float(store.metrics["compactions"])
        out[f"{label}_s"] = best
    out["merge_speedup"] = out["rebuild_s"] / max(out["merge_s"], 1e-9)
    return out


def _warmup() -> None:
    """Compile/trace the kernels once so the first timed section isn't
    charged for JAX warm-up."""
    run_ingestion(n_rows=1024, batch=256, flush_rows=512, mode="columnar")


def bench_json(scale: float = 1.0) -> Dict[str, Any]:
    """Structured results for --json / the CI smoke check."""
    _warmup()
    n = max(2048, int(8000 * scale))
    wh: Dict[str, Any] = {}
    for mode in ("per_row", "columnar", "pipelined", "global_index"):
        wh[mode] = run_ingestion(n_rows=n, mode=mode)
    wh["put_speedup_vs_per_row"] = (
        wh["pipelined"]["put_rows_per_s"] / wh["per_row"]["put_rows_per_s"])
    wh["e2e_speedup_vs_per_row"] = (
        wh["columnar"]["rows_per_s"] / wh["per_row"]["rows_per_s"])
    return {
        "write_heavy": wh,
        "mixed": run_mixed(n_rows=max(2048, int(4000 * scale)),
                           n_ops=max(40, int(120 * scale))),
        "compaction": run_merge_vs_rebuild(
            n_rows=max(6144, int(12000 * scale))),
    }


def bench(scale: float = 1.0) -> List[str]:
    """CSV rows for benchmarks/run.py."""
    return csv_from_json(bench_json(scale))


def csv_from_json(r: Dict[str, Any]) -> List[str]:
    wh, mixed, comp = r["write_heavy"], r["mixed"], r["compaction"]
    rows = []
    for mode in ("per_row", "columnar", "pipelined", "global_index"):
        m = wh[mode]
        rows.append(
            f"ingest_{mode},{1e6 / m['rows_per_s']:.1f},"
            f"rows_per_s={m['rows_per_s']:.0f};"
            f"put_rows_per_s={m['put_rows_per_s']:.0f}")
    rows.append(f"ingest_put_speedup,0.0,"
                f"{wh['put_speedup_vs_per_row']:.1f}x_vs_per_row")
    rows.append(f"ingest_mixed,{1e6 / mixed['ops_per_s']:.1f},"
                f"rows_per_s={mixed['rows_per_s']:.0f};"
                f"queries_per_s={mixed['queries_per_s']:.1f}")
    rows.append(f"ingest_index_merge,{comp['merge_s'] * 1e6:.0f},"
                f"rebuild_us={comp['rebuild_s'] * 1e6:.0f};"
                f"speedup={comp['merge_speedup']:.1f}x")
    return rows


def check_baseline(result: Dict[str, Any], baseline: Dict[str, Any]
                   ) -> List[str]:
    """Machine-independent regression gate: ratios may not degrade by
    more than 2x vs the committed baseline, and index merge must still
    beat rebuild at compaction."""
    errors = []
    got = result["write_heavy"]["put_speedup_vs_per_row"]
    want = baseline["write_heavy"]["put_speedup_vs_per_row"]
    if got < want / 2.0:
        errors.append(f"put speedup vs per-row regressed >2x: "
                      f"{got:.1f}x (baseline {want:.1f}x)")
    if got < 5.0:
        errors.append(f"put speedup vs per-row below the 5x floor: "
                      f"{got:.1f}x")
    m = result["compaction"]
    if m["merge_s"] >= m["rebuild_s"]:
        errors.append(f"index merge no faster than rebuild: "
                      f"{m['merge_s']:.4f}s vs {m['rebuild_s']:.4f}s")
    base_spd = baseline["compaction"]["merge_speedup"]
    if m["merge_speedup"] < base_spd / 2.0:
        errors.append(f"index merge speedup regressed >2x: "
                      f"{m['merge_speedup']:.1f}x (baseline "
                      f"{base_spd:.1f}x)")
    return errors


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run (CI)")
    ap.add_argument("--json", default=None,
                    help="write structured results to PATH ('-' = stdout)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON to check ratios against")
    args = ap.parse_args(argv)
    scale = 0.33 if args.smoke else args.scale
    result = bench_json(scale)
    text = json.dumps(result, indent=2, sort_keys=True)
    if args.json == "-":
        print(text)
    elif args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        errors = check_baseline(result, baseline)
        for e in errors:
            print(f"REGRESSION: {e}", file=sys.stderr)
        if errors:
            return 1
        print("baseline check passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
