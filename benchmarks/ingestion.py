"""Ingestion-throughput study (paper §1: global in-memory vector index
"caused the ingestion throughput to drop by as much as 75x").

ARCADE's background per-segment index build vs a synchronous global
in-memory IVF on the write path.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks import baselines as bl
from benchmarks import tracy
from repro.core.lsm import LSMConfig, LSMStore


def run_ingestion(n_rows: int = 8000, batch: int = 256, mode: str = "arcade",
                  seed: int = 0) -> Dict[str, float]:
    cfg = tracy.TracyConfig(n_rows=0, seed=seed, dim=64)
    data = tracy.TracyData(cfg)
    store = LSMStore(tracy.tweet_schema(64), LSMConfig(flush_rows=2048))
    writer = bl.GlobalIndexWriter(store, dim=64, rebuild_every=1024) \
        if mode == "global_index" else None
    t0 = time.perf_counter()
    done = 0
    while done < n_rows:
        pks, b = data.batch(batch)
        if writer is not None:
            writer.put(pks, b)
        else:
            store.put(pks, b)
        done += batch
    dt = time.perf_counter() - t0
    return {"rows_per_s": n_rows / dt, "wall_s": dt}


def bench(scale: float = 1.0) -> List[str]:
    n = int(8000 * scale)
    rows = []
    a = run_ingestion(n_rows=n, mode="arcade")
    g = run_ingestion(n_rows=n, mode="global_index")
    rows.append(f"ingest_arcade,{1e6 / a['rows_per_s']:.1f},"
                f"rows_per_s={a['rows_per_s']:.0f}")
    rows.append(f"ingest_global_index,{1e6 / g['rows_per_s']:.1f},"
                f"rows_per_s={g['rows_per_s']:.0f};"
                f"slowdown={a['rows_per_s'] / g['rows_per_s']:.1f}x")
    return rows
