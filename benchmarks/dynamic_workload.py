"""Fig. 4 reproduction: dynamic workloads with interleaved writes+queries.

Write-heavy (1:9 read:write) and read-heavy (9:1) scenarios over the
TRACY workload; hybrid-search, hybrid-NN and mixed query streams; ARCADE
vs in-system baseline strategies. Metric: total wall time (lower is
better), plus block-read counters.

The ARCADE engine runs through the ``Database`` facade (``adopt_store``
+ ``Table.put``/``Table.execute``) — the same surface applications use;
the baseline strategies keep their purpose-built executors from
``benchmarks.baselines``.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks import baselines as bl
from benchmarks import tracy
from repro.core.api import Database


def run_dynamic(n_rows: int = 6000, n_ops: int = 100, read_frac: float = 0.9,
                workload: str = "mixed", engine: str = "arcade",
                seed: int = 0) -> Dict[str, float]:
    cfg = tracy.TracyConfig(n_rows=n_rows, seed=seed, dim=64)
    store, data = tracy.build_store(cfg)
    search_t, nn_t = tracy.make_templates(data)
    templates = {"search": search_t, "nn": nn_t,
                 "mixed": search_t + nn_t}[workload]
    if engine == "arcade":
        sink = Database(schema=None).adopt_store("tracy", store)
    else:
        ex = bl.EXECUTORS[engine](store)

        class _Sink:                       # same put/execute surface
            put = staticmethod(store.put)
            execute = staticmethod(ex.execute)
        sink = _Sink()
    rng = np.random.default_rng(seed + 1)

    t0 = time.perf_counter()
    blocks = 0.0
    reads = writes = 0
    for i in range(n_ops):
        if rng.random() < read_frac:
            tmpl = templates[rng.integers(0, len(templates))]
            _, st = sink.execute(tmpl())
            blocks += st.blocks_read
            reads += 1
        else:
            pks, batch = data.batch(64)
            sink.put(pks, batch)
            writes += 1
    dt = time.perf_counter() - t0
    return {"wall_s": dt, "blocks": blocks, "reads": reads,
            "writes": writes, "us_per_op": dt / n_ops * 1e6}


def bench(scale: float = 1.0) -> List[str]:
    rows = []
    n_rows = int(6000 * scale)
    n_ops = max(20, int(60 * scale))
    for scenario, rf in (("write_heavy", 0.1), ("read_heavy", 0.9)):
        for engine in ("arcade", "single_index", "segment_full_load"):
            r = run_dynamic(n_rows=n_rows, n_ops=n_ops, read_frac=rf,
                            engine=engine)
            rows.append(f"fig4_{scenario}_{engine},{r['us_per_op']:.0f},"
                        f"blocks={r['blocks']:.0f}")
    return rows
