"""Benchmark harness — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (assignment contract).

  fig4_*   — dynamic workloads, write/read-heavy (paper Fig. 4)
  tab1_*   — hybrid query latency vs baseline strategies (paper Table 1)
  fig5a/b_* — continuous queries: budget / #queries sweeps (paper Fig. 5)
  ingest_* — ingestion throughput vs global in-memory index (paper §1)
  mq_*     — batched execute_many vs sequential execute throughput

``--scale`` shrinks/grows the workload (CPU container default 1.0).
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--only", default=None,
                    help="comma list: fig4,tab1,fig5,ingest,mq")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (continuous_bench, dynamic_workload,
                            hybrid_latency, ingestion, multi_query,
                            pq_study)
    sections = [
        ("tab1", hybrid_latency.bench),
        ("fig4", dynamic_workload.bench),
        ("fig5", continuous_bench.bench),
        ("ingest", ingestion.bench),
        ("pq", pq_study.bench),
        ("mq", multi_query.bench),
    ]
    print("name,us_per_call,derived")
    for name, fn in sections:
        if only and name not in only:
            continue
        t0 = time.time()
        for row in fn(scale=args.scale):
            print(row, flush=True)
        print(f"# section {name} took {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
