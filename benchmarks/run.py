"""Benchmark harness — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (assignment contract).

  fig4_*   — dynamic workloads, write/read-heavy (paper Fig. 4)
  tab1_*   — hybrid query latency vs baseline strategies (paper Table 1)
  fig5a/b_* — continuous queries: budget / #queries sweeps (paper Fig. 5)
  ingest_* — ingestion throughput: columnar/pipelined write path vs the
             per-row baseline and the global in-memory index (paper §1),
             mixed read/write, index merge-vs-rebuild at compaction
  mq_*     — batched execute_many vs sequential execute throughput
  durability_* — WAL ingest overhead, recovery replay, snapshot/restore
  obs_*    — observability layer cost: tracing-off/on query overhead

``--scale`` shrinks/grows the workload (CPU container default 1.0).
``--json PATH`` additionally writes structured results for every section
that exposes a ``bench_json(scale)`` hook (ingestion does), plus a
``metrics`` key with the unified registry snapshot (histograms with
p50/p95/p99, counters) accumulated across every section that ran.
"""
import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--only", default=None,
                    help="comma list: fig4,tab1,fig5,ingest,mq,sharded,"
                         "durability,obs")
    ap.add_argument("--json", default=None,
                    help="write structured per-section results to PATH")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (continuous_bench, durability_bench,
                            dynamic_workload, hybrid_latency, ingestion,
                            multi_query, obs_overhead, pq_study,
                            sharded_bench)
    sections = [
        ("tab1", hybrid_latency),
        ("fig4", dynamic_workload),
        ("fig5", continuous_bench),
        ("ingest", ingestion),
        ("pq", pq_study),
        ("mq", multi_query),
        ("sharded", sharded_bench),
        ("durability", durability_bench),
        ("obs", obs_overhead),
    ]
    structured = {}
    print("name,us_per_call,derived")
    for name, mod in sections:
        if only and name not in only:
            continue
        t0 = time.time()
        if args.json and hasattr(mod, "bench_json") and \
                hasattr(mod, "csv_from_json"):
            structured[name] = mod.bench_json(scale=args.scale)
            rows = mod.csv_from_json(structured[name])
        else:
            rows = mod.bench(scale=args.scale)
        for row in rows:
            print(row, flush=True)
        print(f"# section {name} took {time.time() - t0:.1f}s",
              file=sys.stderr)
    if args.json:
        # unified telemetry accumulated across every section that ran:
        # the process-wide registry (latency histograms with
        # p50/p95/p99, engine counters) + this thread's kernel totals
        from repro.kernels import ops as kops
        from repro.obs import REGISTRY
        kops.flush_registry_counters()
        launches, byts, misses = kops.stats_snapshot()
        structured["metrics"] = {
            "registry": REGISTRY.snapshot(),
            "kernels_thread": {"launches": launches,
                               "bytes_to_host": byts,
                               "shape_misses": misses},
        }
        with open(args.json, "w") as f:
            json.dump(structured, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# structured results -> {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
