"""TRACY-like benchmark workload (paper §7.1): Tweet hybRid And Continuous
querY. Synthetic stand-ins for the Tweet/POI/City tables (33M/7M/186K in
the paper; CPU-scaled here) with 128-d embeddings, geo coordinates and
text, plus the paper's 11 parameterized hybrid query templates.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core import query as q
from repro.core.lsm import LSMConfig, LSMStore
from repro.core.types import Column, ColumnType, IndexKind, Schema

TOPICS = ["sports", "music", "food", "travel", "tech", "finance",
          "weather", "movies", "health", "politics"]


def tweet_schema(dim: int = 128, vector_index: IndexKind = IndexKind.IVF
                 ) -> Schema:
    return Schema([
        Column("embedding", ColumnType.VECTOR, dim=dim, index=vector_index),
        Column("coordinate", ColumnType.SPATIAL, index=IndexKind.ZORDER),
        Column("content", ColumnType.TEXT, index=IndexKind.INVERTED),
        Column("time", ColumnType.SCALAR, index=IndexKind.BTREE),
        Column("likes", ColumnType.SCALAR, index=IndexKind.BTREE),
    ])


@dataclasses.dataclass
class TracyConfig:
    n_rows: int = 8000           # pre-loaded tweets (paper: 8M)
    dim: int = 128
    seed: int = 0
    flush_rows: int = 2048
    fanout: int = 4              # LSM tier width (large = no compaction,
    #                              so flush_rows controls segment count)
    # topic centers give embeddings cluster structure (semantic search)
    n_topics: int = 10
    pq_m: int = 8                # PQ subquantizers for the quantized
    #                              residence tier (32 => dsub=2 on the
    #                              64-d study config: near-exact ADC)


class TracyData:
    def __init__(self, cfg: TracyConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.rng = rng
        self.topic_centers = rng.normal(
            size=(cfg.n_topics, cfg.dim)).astype(np.float32)
        self._next_pk = 0

    def batch(self, n: int) -> Tuple[List[int], Dict[str, np.ndarray]]:
        rng = self.rng
        cfg = self.cfg
        topics = rng.integers(0, cfg.n_topics, n)
        emb = (self.topic_centers[topics]
               + 0.4 * rng.normal(size=(n, cfg.dim))).astype(np.float32)
        pts = rng.uniform(0, 100, (n, 2)).astype(np.float32)
        words = [f"{TOPICS[t]} {TOPICS[rng.integers(0, cfg.n_topics)]} "
                 f"w{rng.integers(0, 50)}" for t in topics]
        batch = {
            "embedding": emb,
            "coordinate": pts,
            "content": np.asarray(words, object),
            "time": rng.uniform(0, 1000, n),
            "likes": rng.zipf(2.0, n).astype(np.float64),
        }
        pks = list(range(self._next_pk, self._next_pk + n))
        self._next_pk += n
        return pks, batch

    def query_vec(self) -> np.ndarray:
        t = self.rng.integers(0, self.cfg.n_topics)
        v = self.topic_centers[t] + 0.2 * self.rng.normal(size=self.cfg.dim)
        return v.astype(np.float32)

    def rect(self, side: float = 10.0) -> Tuple[float, float, float, float]:
        x, y = self.rng.uniform(0, 100 - side, 2)
        return (float(x), float(y), float(x + side), float(y + side))


def build_store(cfg: TracyConfig,
                vector_index: IndexKind = IndexKind.IVF,
                quantize: bool = True
                ) -> Tuple[LSMStore, TracyData]:
    """``quantize=False`` skips the PQ residence tier — the graph study
    uses it so the proximity-graph dispatch competes against the exact
    scan and IVF probe alone (a store operator picks ONE approximate
    residence per column; pricing both on one store is a cost-model
    exercise, not the serving configuration)."""
    data = TracyData(cfg)
    store = LSMStore(tweet_schema(cfg.dim, vector_index),
                     LSMConfig(flush_rows=cfg.flush_rows,
                               fanout=cfg.fanout, pq_m=cfg.pq_m,
                               quantize_vectors=quantize))
    done = 0
    while done < cfg.n_rows:
        # never out-batch the flush threshold: small flush_rows configs
        # rely on it to control the resulting segment count
        n = min(cfg.flush_rows, 2048, cfg.n_rows - done)
        pks, batch = data.batch(n)
        store.put(pks, batch)
        done += n
    store.flush()
    return store, data


# ---------------------------------------------------------------------------
# the 11 hybrid query templates (paper: "11 parameterized hybrid query
# templates ... varying combinations of filter predicates and ranking
# conditions over embedding, spatial and text attributes")
# ---------------------------------------------------------------------------

def make_templates(data: TracyData):
    d = data

    def t1():   # vector range + text (Type 1 example in §2.2)
        return q.HybridQuery(where=q.And(
            q.VectorRange("embedding", d.query_vec(), 8.0),
            q.TextContains("content", TOPICS[d.rng.integers(0, 10)])))

    def t2():   # scalar range + spatial region
        lo = float(d.rng.uniform(0, 900))
        return q.HybridQuery(where=q.And(
            q.Range("time", lo, lo + 50),
            q.GeoWithin("coordinate", d.rect(15))))

    def t3():   # triple-modality filter
        lo = float(d.rng.uniform(0, 900))
        return q.HybridQuery(where=q.And(
            q.Range("time", lo, lo + 100),
            q.TextContains("content", TOPICS[d.rng.integers(0, 10)]),
            q.GeoWithin("coordinate", d.rect(25))))

    def t4():   # highly selective scalar
        lo = float(d.rng.uniform(0, 990))
        return q.HybridQuery(where=q.Range("time", lo, lo + 2))

    def t5():   # popularity + region
        return q.HybridQuery(where=q.And(
            q.Range("likes", 5, 1e9),
            q.GeoWithin("coordinate", d.rect(20))))

    def t6():   # pure vector NN
        return q.HybridQuery(ranks=[
            q.VectorRank("embedding", d.query_vec(), 1.0)], k=10)

    def t7():   # vector + spatial joint ranking (Type 2 example in §2.2)
        x, y = d.rng.uniform(10, 90, 2)
        return q.HybridQuery(ranks=[
            q.VectorRank("embedding", d.query_vec(), 0.5),
            q.SpatialRank("coordinate", (float(x), float(y)), 0.2)], k=10)

    def t8():   # vector NN with time filter
        lo = float(d.rng.uniform(0, 800))
        return q.HybridQuery(
            where=q.Range("time", lo, lo + 200),
            ranks=[q.VectorRank("embedding", d.query_vec(), 1.0)], k=10)

    def t9():   # vector + text relevance joint ranking
        return q.HybridQuery(ranks=[
            q.VectorRank("embedding", d.query_vec(), 1.0),
            q.TextRank("content", (TOPICS[d.rng.integers(0, 10)],), 0.5)],
            k=10)

    def t10():  # spatial NN with text filter
        x, y = d.rng.uniform(10, 90, 2)
        return q.HybridQuery(
            where=q.TextContains("content",
                                 TOPICS[d.rng.integers(0, 10)]),
            ranks=[q.SpatialRank("coordinate", (float(x), float(y)), 1.0)],
            k=10)

    def t11():  # 3-way joint ranking with filter
        x, y = d.rng.uniform(10, 90, 2)
        lo = float(d.rng.uniform(0, 800))
        return q.HybridQuery(
            where=q.Range("time", lo, lo + 400),
            ranks=[q.VectorRank("embedding", d.query_vec(), 0.6),
                   q.SpatialRank("coordinate", (float(x), float(y)), 0.2),
                   q.TextRank("content",
                              (TOPICS[d.rng.integers(0, 10)],), 0.3)], k=10)

    def t12():  # disjunctive hybrid search: hot region OR trending topic
        lo = float(d.rng.uniform(0, 900))
        return q.HybridQuery(where=q.Or(
            q.And(q.Range("time", lo, lo + 100),
                  q.GeoWithin("coordinate", d.rect(20))),
            q.TextContains("content", TOPICS[d.rng.integers(0, 10)])))

    def t13():  # disjunctive NN: (recent AND region) OR keyword, ranked
        lo = float(d.rng.uniform(0, 800))
        return q.HybridQuery(
            where=q.Or(q.Range("time", lo, lo + 200),
                       q.TextContains("content",
                                      TOPICS[d.rng.integers(0, 10)])),
            ranks=[q.VectorRank("embedding", d.query_vec(), 1.0)], k=10)

    search = [t1, t2, t3, t4, t5, t12]
    nn = [t6, t7, t8, t9, t10, t11, t13]
    return search, nn


def make_graph_templates(data: TracyData, recall_target=0.95):
    """Recall-targeted analogs of the NN templates a proximity graph can
    serve (single vector rank): t6 pure NN, t8 filtered NN and t13
    disjunctive NN, each with the per-query ``recall_target`` that makes
    the approximate graph dispatch admissible.  ``recall_target=None``
    yields the exact twins (same parameter draws, default contract) for
    ground-truth runs.  Returns ``[(name, template), ...]``."""
    d = data
    rt = recall_target

    def g6():
        return q.HybridQuery(ranks=[
            q.VectorRank("embedding", d.query_vec(), 1.0)], k=10,
            recall_target=rt)

    def g8():
        lo = float(d.rng.uniform(0, 800))
        return q.HybridQuery(
            where=q.Range("time", lo, lo + 200),
            ranks=[q.VectorRank("embedding", d.query_vec(), 1.0)], k=10,
            recall_target=rt)

    def g13():
        lo = float(d.rng.uniform(0, 800))
        return q.HybridQuery(
            where=q.Or(q.Range("time", lo, lo + 200),
                       q.TextContains("content",
                                      TOPICS[d.rng.integers(0, 10)])),
            ranks=[q.VectorRank("embedding", d.query_vec(), 1.0)], k=10,
            recall_target=rt)

    return [("g6", g6), ("g8", g8), ("g13", g13)]
