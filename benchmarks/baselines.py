"""In-system baseline strategies implementing each competitor's design
point (paper §7.2 — external systems can't run here, so their *strategies*
are reproduced inside our engine; EXPERIMENTS.md maps each to its system).

  global_index     — Milvus/FAISS-style global in-memory vector index kept
                     synchronously consistent with writes: every put
                     retrains/rebuilds the global IVF (the paper measured
                     75x ingestion collapse for this design).
  segment_full_load— SingleStore-V-style per-segment index that must be
                     read IN FULL per query (no block-level access): every
                     vector query scans every segment's full vector column.
  single_index     — pre/post-filter only optimizer (no multi-index
                     intersection, no NRA): PostgreSQL/Milvus-style
                     "index isolation".
  full_scan        — MySQL/AsterixDB-style fallback for vector queries.
"""
from __future__ import annotations


import numpy as np

from repro.core import query as q
from repro.core.executor import Executor
from repro.core.index.ivf import kmeans
from repro.core.lsm import LSMStore
from repro.core.optimizer import planner as pl
from repro.kernels import ops as kops


class GlobalIndexWriter:
    """Global in-memory IVF rebuilt synchronously on ingest."""

    def __init__(self, store: LSMStore, dim: int, rebuild_every: int = 2048):
        self.store = store
        self.dim = dim
        self.rebuild_every = rebuild_every
        self.vecs = np.zeros((0, dim), np.float32)
        self.pks = np.zeros((0,), np.int64)
        self.centroids = None
        self.assign = None
        self._since_rebuild = 0

    def put(self, pks, batch) -> None:
        self.store.put(pks, batch)
        # synchronous global-index maintenance on the write path
        self.vecs = np.concatenate([self.vecs, batch["embedding"]])
        self.pks = np.concatenate([self.pks,
                                   np.asarray(pks, np.int64)])
        self._since_rebuild += len(pks)
        if self.centroids is None or \
                self._since_rebuild >= self.rebuild_every:
            k = max(1, int(np.sqrt(len(self.vecs))))
            self.centroids = kmeans(self.vecs, k, iters=4)
            self.assign = kops.assign_nearest(self.vecs, self.centroids)
            self._since_rebuild = 0
        else:
            new = kops.assign_nearest(batch["embedding"], self.centroids)
            self.assign = np.concatenate([self.assign, new])

    def search(self, qv: np.ndarray, k: int, n_probe: int = 4):
        cd = kops.l2_distances(qv[None, :], self.centroids)[0]
        probe = set(np.argsort(cd)[:n_probe].tolist())
        mask = np.isin(self.assign, list(probe))
        cand = np.nonzero(mask)[0]
        if not len(cand):
            return np.zeros(0), np.zeros(0, np.int64)
        d, idx = kops.block_topk(qv, self.vecs[cand], k)
        return np.sqrt(np.maximum(d, 0)), self.pks[cand[idx]]


def _residuals(query) -> list:
    """The query's filter as a residual list: flat literals when the
    expression is a pure conjunction, else the whole expression tree as
    one residual entry (these strategies have no DNF machinery — a
    boolean shape beyond AND degenerates to scan-and-filter)."""
    try:
        return query.filters
    except ValueError:
        return [query.where]


class SegmentFullLoadExecutor(Executor):
    """Vector queries read every segment's vectors in full (per-segment
    index must be memory-resident before use — no block-level reads)."""

    def _exec_nn(self, query, plan, stats):
        forced = pl.Plan(kind="full_scan_nn", residual=_residuals(query),
                         ranks=query.ranks, k=query.k)
        # charge the full per-segment load the design implies
        for seg in self.store.segments:
            stats.blocks_read += seg.n_blocks
        return self._prefilter_nn(query, forced, stats)


class SingleIndexExecutor(Executor):
    """Optimizer restricted to single-index plans (no intersection/NRA):
    best single index probe + residual filters; NN = post-filter if a
    vector index exists else full scan."""

    def execute(self, query, plan=None):
        from repro.core.executor import ExecStats
        try:
            literals = query.filters       # pure conjunction?
        except ValueError:
            literals = None                # disjunctive: scan-and-filter
        if not query.is_nn:
            best = None
            for p in (literals or []):
                col = getattr(p, "col", None)
                if col and self.catalog.has_index(col):
                    cand = pl.Plan(
                        kind="index_intersect", indexed=[p],
                        residual=[r for r in literals if r is not p])
                    from repro.core.optimizer import cost as cost_lib
                    cand.cost = cost_lib.intersect_cost(
                        self.catalog, [p], cand.residual).total
                    if best is None or cand.cost < best.cost:
                        best = cand
            if best is None:
                best = pl.Plan(kind="full_scan", residual=_residuals(query))
            stats = ExecStats(plan="single:" + best.describe())
            return self._exec_filter(query, best, stats), stats
        vec = [r for r in query.ranks if isinstance(r, q.VectorRank)]
        if len(query.ranks) == 1 and vec and literals is not None:
            plan = pl.Plan(kind="postfilter_nn", residual=literals,
                           ranks=query.ranks, k=query.k)
        else:
            plan = pl.Plan(kind="full_scan_nn", residual=_residuals(query),
                           ranks=query.ranks, k=query.k)
        stats = ExecStats(plan="single:" + plan.describe())
        return self._exec_nn(query, plan, stats), stats


class FullScanExecutor(Executor):
    """No secondary indexes consulted at query time."""

    def execute(self, query, plan=None):
        from repro.core.executor import ExecStats
        if query.is_nn:
            plan = pl.Plan(kind="full_scan_nn", residual=_residuals(query),
                           ranks=query.ranks, k=query.k)
            stats = ExecStats(plan="fullscan")
            return self._exec_nn(query, plan, stats), stats
        plan = pl.Plan(kind="full_scan", residual=_residuals(query))
        stats = ExecStats(plan="fullscan")
        return self._exec_filter(query, plan, stats), stats


EXECUTORS = {
    "arcade": Executor,
    "segment_full_load": SegmentFullLoadExecutor,
    "single_index": SingleIndexExecutor,
    "full_scan": FullScanExecutor,
}
