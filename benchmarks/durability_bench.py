"""Durability study: what the WAL + manifest subsystem costs and how
fast recovery runs.

Three sections:

  wal       — identical TRACY ingest with durability off (process-
              resident store) and on (group-committed WAL + persistent
              segments); the machine-independent ``overhead_ratio`` is
              put-throughput(off) / put-throughput(on).
  recovery  — ingest into a WAL-only store (flush threshold above the
              row count), then time a cold open at X and 2X rows:
              replay must stay linear in WAL bytes
              (``linearity`` ~ 1.0 means perfectly proportional).
  snapshot  — ``Database.snapshot`` -> ``Database.restore`` round-trip
              on a sharded TRACY store; result parity is a hard gate,
              timings are reported.

CLI:  python benchmarks/durability_bench.py [--smoke] [--json PATH]
                                            [--baseline PATH]
With --baseline, machine-independent ratios are checked against the
committed JSON (CI smoke job): fails if the WAL overhead ratio
regressed by more than 2x, recovery stopped being linear in WAL bytes,
or the snapshot round-trip loses parity.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time
from typing import Any, Dict, List, Optional

import numpy as np

if __package__ in (None, ""):    # `python benchmarks/durability_bench.py`
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import tracy
from repro.core import query as q
from repro.core.api import Database
from repro.core.lsm import LSMConfig, LSMStore

DIM = 32


def _ingest(store: LSMStore, n_rows: int, batch: int, seed: int = 0
            ) -> Dict[str, float]:
    """Feed TRACY batches until at least ``n_rows``; returns seconds
    spent inside ``put`` and the actual row count (batch-aligned)."""
    data = tracy.TracyData(tracy.TracyConfig(n_rows=0, seed=seed, dim=DIM))
    put_s, done = 0.0, 0
    while done < n_rows:
        pks, b = data.batch(batch)
        t0 = time.perf_counter()
        store.put(pks, b)
        put_s += time.perf_counter() - t0
        done += batch
    return {"put_s": put_s, "rows": float(done)}


def run_wal_overhead(n_rows: int = 8000, batch: int = 256,
                     flush_rows: int = 2048) -> Dict[str, float]:
    schema = tracy.tweet_schema(DIM)
    off = LSMStore(schema, LSMConfig(flush_rows=flush_rows))
    off_r = _ingest(off, n_rows, batch)
    root = tempfile.mkdtemp(prefix="durab-wal-")
    try:
        on = LSMStore(schema, LSMConfig(flush_rows=flush_rows, path=root))
        on_r = _ingest(on, n_rows, batch)
        on.close()
        wal_bytes = sum(
            os.path.getsize(os.path.join(on.storage.wal_dir, f))
            for f in os.listdir(on.storage.wal_dir))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return {"put_rows_per_s_off": off_r["rows"] / max(off_r["put_s"], 1e-9),
            "put_rows_per_s_on": on_r["rows"] / max(on_r["put_s"], 1e-9),
            "overhead_ratio":
                max(on_r["put_s"], 1e-9) / max(off_r["put_s"], 1e-9),
            "wal_bytes": float(wal_bytes)}


def _cold_open_seconds(n_rows: int, batch: int) -> Dict[str, float]:
    """Ingest into a WAL-only store (nothing flushed), close, and time a
    cold open — pure manifest load + WAL replay."""
    schema = tracy.tweet_schema(DIM)
    root = tempfile.mkdtemp(prefix="durab-rec-")
    try:
        cfg = LSMConfig(flush_rows=10 ** 9, path=root)
        st = LSMStore(schema, cfg)
        rows = _ingest(st, n_rows, batch)["rows"]
        st.close()
        wal_bytes = sum(
            os.path.getsize(os.path.join(st.storage.wal_dir, f))
            for f in os.listdir(st.storage.wal_dir))
        t0 = time.perf_counter()
        rec = LSMStore(schema, cfg)
        dt = time.perf_counter() - t0
        assert rec.n_rows == rows
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return {"open_s": dt, "wal_bytes": float(wal_bytes),
            "rows_per_s": rows / max(dt, 1e-9)}


def run_recovery(n_rows: int = 6000, batch: int = 256) -> Dict[str, float]:
    small = _cold_open_seconds(n_rows, batch)
    big = _cold_open_seconds(2 * n_rows, batch)
    # time growth normalized by byte growth: ~1.0 when replay is linear
    linearity = (big["open_s"] / max(small["open_s"], 1e-9)) \
        / (big["wal_bytes"] / max(small["wal_bytes"], 1.0))
    return {"open_s_x": small["open_s"], "open_s_2x": big["open_s"],
            "wal_bytes_x": small["wal_bytes"],
            "wal_bytes_2x": big["wal_bytes"],
            "replay_rows_per_s": big["rows_per_s"],
            "linearity": linearity}


def run_snapshot_restore(n_rows: int = 4000, batch: int = 256
                         ) -> Dict[str, float]:
    schema = tracy.tweet_schema(DIM)
    root = tempfile.mkdtemp(prefix="durab-snap-")
    try:
        db = Database(schema, LSMConfig(flush_rows=1024),
                      path=os.path.join(root, "db"), shards=2)
        data = tracy.TracyData(tracy.TracyConfig(n_rows=0, seed=3, dim=DIM))
        done = 0
        while done < n_rows:
            pks, b = data.batch(batch)
            db.table().put(pks, b)
            done += batch
        rng = np.random.default_rng(9)
        queries = [q.HybridQuery(
            ranks=[q.VectorRank(
                "embedding", rng.normal(size=DIM).astype(np.float32), 1.0)],
            k=10) for _ in range(8)]
        before = [[(r.pk, float(r.score))
                   for r in db.table().execute(hq)[0]] for hq in queries]
        snap = os.path.join(root, "snap")
        t0 = time.perf_counter()
        db.snapshot(snap)
        snapshot_s = time.perf_counter() - t0
        db.close()
        t0 = time.perf_counter()
        restored = Database.restore(snap)
        restore_s = time.perf_counter() - t0
        after = [[(r.pk, float(r.score))
                  for r in restored.table().execute(hq)[0]]
                 for hq in queries]
        restored.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return {"snapshot_s": snapshot_s, "restore_s": restore_s,
            "rows": float(n_rows),
            "parity_ok": float(before == after)}


def bench_json(scale: float = 1.0) -> Dict[str, Any]:
    return {
        "wal": run_wal_overhead(n_rows=max(2048, int(8000 * scale))),
        "recovery": run_recovery(n_rows=max(1536, int(6000 * scale))),
        "snapshot": run_snapshot_restore(n_rows=max(1024,
                                                    int(4000 * scale))),
    }


def csv_from_json(r: Dict[str, Any]) -> List[str]:
    """CSV rows for benchmarks/run.py from a ``bench_json`` result."""
    w, rec, s = r["wal"], r["recovery"], r["snapshot"]
    return [
        f"durability_wal_overhead,0.0,"
        f"ratio={w['overhead_ratio']:.2f}x;"
        f"on_rows_per_s={w['put_rows_per_s_on']:.0f}",
        f"durability_recovery,{rec['open_s_2x'] * 1e6:.0f},"
        f"replay_rows_per_s={rec['replay_rows_per_s']:.0f};"
        f"linearity={rec['linearity']:.2f}",
        f"durability_snapshot,{s['snapshot_s'] * 1e6:.0f},"
        f"restore_us={s['restore_s'] * 1e6:.0f};"
        f"parity={int(s['parity_ok'])}",
    ]


def bench(scale: float = 1.0) -> List[str]:
    return csv_from_json(bench_json(scale))


def check_baseline(result: Dict[str, Any], baseline: Dict[str, Any]
                   ) -> List[str]:
    """Machine-independent regression gate."""
    errors = []
    got = result["wal"]["overhead_ratio"]
    want = baseline["wal"]["overhead_ratio"]
    # floor of 2.0x absorbs noise when the baseline ratio is ~1 (WAL
    # cost hides under flush + index build); the 2x-vs-baseline clause
    # catches regressions once the ratio is genuinely above that
    if got > max(want * 2.0, 2.0):
        errors.append(f"WAL ingest overhead regressed >2x: {got:.2f}x "
                      f"(baseline {want:.2f}x)")
    if got > 10.0:
        errors.append(f"WAL ingest overhead above the 10x ceiling: "
                      f"{got:.2f}x")
    lin = result["recovery"]["linearity"]
    if lin > 2.5:
        errors.append(f"recovery no longer linear in WAL bytes: 2x the "
                      f"bytes took {lin:.2f}x the proportional time")
    if not result["snapshot"]["parity_ok"]:
        errors.append("snapshot/restore round-trip lost result parity")
    return errors


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run (CI)")
    ap.add_argument("--json", default=None,
                    help="write structured results to PATH ('-' = stdout)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON to check ratios against")
    args = ap.parse_args(argv)
    scale = 0.33 if args.smoke else args.scale
    result = bench_json(scale)
    text = json.dumps(result, indent=2, sort_keys=True)
    if args.json == "-":
        print(text)
    elif args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        errors = check_baseline(result, baseline)
        for e in errors:
            print(f"REGRESSION: {e}", file=sys.stderr)
        if errors:
            return 1
        print("baseline check passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
