"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


import numpy as np


def compat_make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` across JAX versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist in newer releases; Auto is the
    default everywhere, so omit the argument when unsupported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, devices=devices,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for {shape} mesh, have {len(devices)}; "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return compat_make_mesh(shape, axes, devices=devices)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU tests/examples."""
    return compat_make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (per chip) for the roofline model
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~ per-direction)
HBM_BYTES = 16 * 1024**3        # 16 GiB
