import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds ShapeDtypeStruct stand-ins for all inputs
(no allocation), jits the appropriate step with explicit in/out shardings,
``.lower().compile()``s it for the production mesh, and records
``memory_analysis`` / ``cost_analysis`` / collective-schedule roofline
terms into a JSON report consumed by EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out reports/dryrun.json
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config, get_shape, SHAPES
from repro.configs.base import cell_supported
from repro.launch import roofline as rl
from repro.launch.mesh import HBM_BYTES, make_production_mesh
from repro.models import model
from repro.sharding import partition
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _shard(mesh, rules, shape, axes):
    spec = partition.safe_spec(shape, axes, mesh, rules)
    return NamedSharding(mesh, spec)


def input_specs(cfg, shape, mesh, rules) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        tok_sh = _shard(mesh, rules, (b, s), ("batch", None))
        specs["tokens"] = _sds((b, s), jnp.int32, tok_sh)
        if shape.kind == "train":
            specs["labels"] = _sds((b, s), jnp.int32, tok_sh)
        mem = memory_spec(cfg, shape, mesh, rules)
        if mem is not None:
            specs["memory"] = mem
    else:  # decode
        tok_sh = _shard(mesh, rules, (b, 1), ("batch", None))
        specs["token"] = _sds((b, 1), jnp.int32, tok_sh)
        specs["pos"] = _sds((), jnp.int32,
                            NamedSharding(mesh, P()))
        mem = memory_spec(cfg, shape, mesh, rules)
        if mem is not None:
            specs["memory"] = mem
    return specs


def memory_spec(cfg, shape, mesh, rules):
    """Modality-frontend stub inputs (precomputed embeddings)."""
    b = shape.global_batch
    if cfg.family == "audio":
        m = int(shape.seq_len * cfg.encdec.frontend_len_ratio)
        return _sds((b, m, cfg.d_model), jnp.bfloat16,
                    _shard(mesh, rules, (b, m, cfg.d_model),
                           ("batch", None, None)))
    if cfg.family == "vlm":
        m = cfg.vision.num_image_tokens
        return _sds((b, m, cfg.d_model), jnp.bfloat16,
                    _shard(mesh, rules, (b, m, cfg.d_model),
                           ("batch", None, None)))
    return None


def _tree_shardings(axes, shapes, mesh, rules):
    return partition.tree_sharding(axes, mesh, rules, shapes)


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str
    compile_s: float = 0.0
    per_device_bytes: float = 0.0
    fits_hbm: Optional[bool] = None
    roofline: Optional[Dict] = None
    error: str = ""


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               compile_only: bool = True) -> Tuple[Any, Any, Any]:
    """Build + lower + compile one cell; returns (compiled, mesh, extras)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"SKIP: {why}")
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = "long" if shape.name == "long_500k" else shape.kind
    tp = mesh.shape["model"]
    eff_heads = cfg.num_heads
    if os.environ.get("DRYRUN_KV_INT8") == "1" and cfg.attn_type == "gqa":
        cfg = cfg.replace(kv_cache_dtype="int8")
    if os.environ.get("DRYRUN_GHOST_HEADS") == "1" and \
            cfg.attn_type == "gqa" and cfg.num_heads % tp != 0:
        from repro.configs.base import ghost_head_layout
        cfg = cfg.replace(pad_heads_to_tp=tp)
        eff_heads = ghost_head_layout(cfg.num_heads, cfg.num_kv_heads,
                                      tp)[0]
    rules = partition.rules_for(kind, num_heads=eff_heads, tp=tp)
    if os.environ.get("DRYRUN_RES_SEQ") == "1" and kind == "train":
        rules["res_seq"] = "model"
    specs = input_specs(cfg, shape, mesh, rules)

    if shape.kind == "train":
        opt_cfg = opt_lib.OptConfig(name=cfg.optimizer)
        st_shapes = ts.train_state_shapes(cfg, opt_cfg)
        st_axes = ts.state_axes(cfg, opt_cfg)
        st_shard = _tree_shardings(st_axes, st_shapes, mesh, rules)
        state_in = jax.tree.map(
            lambda s, sh: _sds(s.shape, s.dtype, sh), st_shapes, st_shard)
        batch = {k: specs[k] for k in ("tokens", "labels")}
        if "memory" in specs:
            batch["memory"] = specs["memory"]
        # microbatching: ~32-sequence microbatches keep the per-layer
        # residual stack + loss temps inside the v5e HBM envelope;
        # widest models (d_model >= 8k, e.g. vision-90b) halve again
        per_micro = 16 if cfg.d_model >= 8000 else 32
        if os.environ.get("DRYRUN_PER_MICRO"):
            per_micro = int(os.environ["DRYRUN_PER_MICRO"])
        n_micro = max(1, shape.global_batch // per_micro)
        g_axes = st_axes["params"] if os.environ.get(
            "DRYRUN_GRAD_CONSTRAIN", "1") == "1" else None

        def step(state, batch):
            with partition.axis_rules(mesh, rules):
                return ts.train_step(state, batch, cfg, opt_cfg,
                                     num_microbatches=n_micro,
                                     grad_axes=g_axes)

        jitted = jax.jit(step, in_shardings=(st_shard, None),
                         out_shardings=(st_shard, None),
                         donate_argnums=(0,))
        lowered = jitted.lower(state_in, batch)

    elif shape.kind == "prefill":
        p_shapes = model.param_shapes(cfg)
        p_axes = model.param_axes(cfg)
        p_shard = _tree_shardings(p_axes, p_shapes, mesh, rules)
        params_in = jax.tree.map(
            lambda s, sh: _sds(s.shape, s.dtype, sh), p_shapes, p_shard)

        n_chunks = int(os.environ.get("DRYRUN_PREFILL_CHUNKS", "1"))

        def step(params, tokens, memory=None):
            with partition.axis_rules(mesh, rules):
                if n_chunks <= 1:
                    return model.forward(params, cfg, tokens, memory)
                # chunked prefill: sequence the batch through the model in
                # B/n_chunks slices (bounds live activations; Perf B1)
                b = tokens.shape[0]
                tok_c = tokens.reshape(n_chunks, b // n_chunks, -1)
                if memory is not None:
                    mem_c = memory.reshape(n_chunks, b // n_chunks,
                                           *memory.shape[1:])
                    return jax.lax.map(
                        lambda args: model.forward(params, cfg, args[0],
                                                   args[1]),
                        (tok_c, mem_c))
                return jax.lax.map(
                    lambda t: model.forward(params, cfg, t), tok_c)

        args = [params_in, specs["tokens"]]
        if "memory" in specs:
            args.append(specs["memory"])
        jitted = jax.jit(step, in_shardings=(p_shard,) + (None,) * (len(args) - 1))
        lowered = jitted.lower(*args)

    else:  # decode
        p_shapes = model.param_shapes(cfg)
        p_axes = model.param_axes(cfg)
        p_shard = _tree_shardings(p_axes, p_shapes, mesh, rules)
        params_in = jax.tree.map(
            lambda s, sh: _sds(s.shape, s.dtype, sh), p_shapes, p_shard)
        c_shapes, c_axes = model.cache_shapes(cfg, shape.global_batch,
                                              shape.seq_len)
        c_shard = _tree_shardings(c_axes, c_shapes, mesh, rules)
        cache_in = jax.tree.map(
            lambda s, sh: _sds(s.shape, s.dtype, sh), c_shapes, c_shard)

        def step(params, token, cache, pos, memory=None):
            with partition.axis_rules(mesh, rules):
                return model.decode_step(params, cfg, token, cache, pos,
                                         memory=memory)

        args = [params_in, specs["token"], cache_in, specs["pos"]]
        in_sh = [p_shard, None, c_shard, None]
        if "memory" in specs:
            args.append(specs["memory"])
            in_sh.append(None)
        jitted = jax.jit(step, in_shardings=tuple(in_sh),
                         out_shardings=(None, c_shard),
                         donate_argnums=(2,))
        lowered = jitted.lower(*args)

    compiled = lowered.compile()
    return compiled, mesh, (cfg, shape)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> CellResult:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.perf_counter()
    try:
        compiled, mesh, (cfg, shape) = lower_cell(arch, shape_name, multi_pod)
    except ValueError as e:
        if str(e).startswith("SKIP"):
            return CellResult(arch, shape_name, mesh_name, "skipped",
                              error=str(e))
        return CellResult(arch, shape_name, mesh_name, "error",
                          error=traceback.format_exc()[-2000:])
    except Exception:
        return CellResult(arch, shape_name, mesh_name, "error",
                          error=traceback.format_exc()[-2000:])
    dt = time.perf_counter() - t0

    ma = compiled.memory_analysis()
    per_dev = 0.0
    if ma is not None:
        per_dev = float(ma.argument_size_in_bytes + ma.output_size_in_bytes
                        - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
    n_dev = mesh.devices.size
    mflops = rl.model_flops_for(cfg, shape)
    roof = rl.analyze(compiled, n_dev, mflops)
    res = CellResult(
        arch=arch, shape=shape_name, mesh=mesh_name, status="ok",
        compile_s=round(dt, 1), per_device_bytes=per_dev,
        fits_hbm=bool(per_dev <= HBM_BYTES),
        roofline={
            "flops_per_dev": roof.flops,
            "hbm_bytes_per_dev": roof.hbm_bytes,
            "coll_bytes_per_dev": roof.coll_bytes,
            "coll_by_kind": roof.coll_by_kind,
            "compute_s": roof.compute_s,
            "memory_s": roof.memory_s,
            "collective_s": roof.collective_s,
            "bottleneck": roof.bottleneck,
            "model_flops": roof.model_flops,
            "useful_ratio": roof.useful_ratio,
            "step_time_s": roof.step_time_s,
        })
    if verbose:
        print(f"[{mesh_name}] {arch} x {shape_name}: OK "
              f"compile={dt:.0f}s bytes/dev={per_dev/2**30:.2f}GiB "
              f"fits={res.fits_hbm} bottleneck={roof.bottleneck} "
              f"(c={roof.compute_s:.4f}s m={roof.memory_s:.4f}s "
              f"k={roof.collective_s:.4f}s)", flush=True)
        print("  memory_analysis:", ma, flush=True)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"], default="no")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.all or not args.arch else [args.arch]
    shapes = [s.name for s in SHAPES] if args.all or not args.shape \
        else [args.shape]
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]

    results = []

    def _save():
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    for mp in pods:
        for arch in archs:
            for shape in shapes:
                res = run_cell(arch, shape, mp)
                if res.status == "error":
                    print(f"[{'2x16x16' if mp else '16x16'}] {arch} x {shape}"
                          f": ERROR\n{res.error}", flush=True)
                elif res.status == "skipped":
                    print(f"[{'2x16x16' if mp else '16x16'}] {arch} x {shape}"
                          f": SKIPPED ({res.error})", flush=True)
                results.append(dataclasses.asdict(res))
                _save()
                jax.clear_caches()
    if args.out:
        print(f"wrote {args.out}")
    n_ok = sum(r["status"] == "ok" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"SUMMARY: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)} cells")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
