import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""ARCADE data-plane dry-run: lower + compile the shard_map scatter-gather
query kernels (core/distributed.py) on the production meshes — the
distribution proof for the paper's own layer (the LM-zoo dry-run is
launch/dryrun.py).

  PYTHONPATH=src python -m repro.launch.dryrun_arcade [--multi-pod]
"""

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed as dist
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh


def run(multi_pod: bool, n_per_shard: int = 1 << 16, dim: int = 128,
        k: int = 100):
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_global = n_per_shard * mesh.devices.size
    name = "2x16x16" if multi_pod else "16x16"

    # shard rows over every axis (segments partitioned store-wide)
    axes_all = P(tuple(mesh.axis_names))
    vec_sh = NamedSharding(mesh, P(tuple(mesh.axis_names), None))
    id_sh = NamedSharding(mesh, axes_all)

    qv = jax.ShapeDtypeStruct((dim,), jnp.float32,
                              sharding=NamedSharding(mesh, P()))
    vecs = jax.ShapeDtypeStruct((n_global, dim), jnp.float32,
                                sharding=vec_sh)
    ids = jax.ShapeDtypeStruct((n_global,), jnp.int64, sharding=id_sh)

    from jax.experimental.shard_map import shard_map

    shard_axes = tuple(mesh.axis_names)

    def _shardfn(q, v, i):
        d, idx = dist.local_topk(q, v, k)
        lids = i[idx]
        all_d = d
        all_i = lids
        for ax in shard_axes:
            all_d = jax.lax.all_gather(all_d, ax).reshape(-1)
            all_i = jax.lax.all_gather(all_i, ax).reshape(-1)
        neg, pos = jax.lax.top_k(-all_d, k)
        return -neg, all_i[pos]

    fn = shard_map(_shardfn, mesh=mesh,
                   in_specs=(P(), P(shard_axes, None), P(shard_axes)),
                   out_specs=(P(), P()), check_rep=False)
    lowered = jax.jit(fn).lower(qv, vecs, ids)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    roof = rl.analyze(compiled, mesh.devices.size)
    per_dev = (ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes)
    print(f"[{name}] ARCADE distributed top-{k} over {n_global:,} vectors: "
          f"OK bytes/dev={per_dev / 2**20:.1f}MiB "
          f"c={roof.compute_s * 1e6:.0f}us m={roof.memory_s * 1e6:.0f}us "
          f"k={roof.collective_s * 1e6:.0f}us "
          f"bottleneck={roof.bottleneck}")
    print("  memory_analysis:", ma)
    print("  collectives:", {kk: f"{v:.2e}"
                             for kk, v in roof.coll_by_kind.items()})
    return compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"],
                    default="both")
    args = ap.parse_args()
    pods = {"no": [False], "yes": [True],
            "both": [False, True]}[args.multi_pod]
    for mp in pods:
        run(mp)


if __name__ == "__main__":
    main()
