"""HLO-walking cost model with while-loop trip-count multiplication.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE
(verified empirically: a 10-iteration scan of a matmul reports the same
flops as a single matmul). All our models lower layers / flash-attention
chunks / microbatches as ``lax.scan`` loops, so the built-in numbers are
useless for a roofline. This module parses the post-SPMD optimized HLO
(per-device module), builds the computation call graph, extracts each
while loop's trip count from its condition, and accumulates:

  * flops  — dot ops exactly (2 * batch * M * N * K from dimension
             numbers), 1 flop/output element for elementwise/fusion ops;
  * bytes  — per top-level op: output + operand bytes (via a per-
             computation symbol table); dynamic-(update-)slice counts the
             slice, not the aliased big buffer; tuples/GTE/bitcast free;
  * collective bytes — per kind, with ring factors (all-reduce 2x).

All numbers are per-device (the module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
# first lowercase identifier followed by '(' in the rhs is the op kind —
# dtype tokens (bf16[..], s32[]) are followed by '[' so they never match
_KIND_RE = re.compile(r"\b([a-z][\w\-]*)\(")
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_FREE_OPS = {"tuple", "get-tuple-element", "parameter", "bitcast",
             "constant", "after-all", "partition-id", "replica-id"}


def _shape_info(text: str) -> Tuple[int, int]:
    """(total elements, total bytes) over all dtype[...] groups in text."""
    elems, bts = 0, 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bts += n * _DTYPE_BYTES[dtype]
    return elems, bts


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    shape_text: str
    line: str
    out_elems: int
    out_bytes: int
    args_text: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_fusion_body: bool = False


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    fusion_bodies = set()
    for line in hlo.splitlines():
        s = line.strip()
        # computation headers may contain nested parens in the param list:
        #   %wide.region_0.1_spmd.clone (arg: (s32[], bf16[...])) -> (...) {
        header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(", s)
        if header and s.endswith("{") and "->" in s and "=" not in \
                s.split("->")[0].split("(")[0]:
            cur = Computation(header.group(2), [])
            comps[cur.name] = cur
            if header.group(1):
                entry = cur.name
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _ASSIGN_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        mk = _KIND_RE.search(rhs)
        if not mk:
            continue
        kind = mk.group(1)
        shape_text = rhs[:mk.start()]
        elems, bts = _shape_info(shape_text)
        cur.ops.append(Op(name, kind, shape_text, s, elems, bts,
                          args_text=rhs[mk.end():]))
        if kind == "fusion":
            for callee in _CALLS_RE.findall(s):
                fusion_bodies.add(callee)
    for fb in fusion_bodies:
        if fb in comps:
            comps[fb].is_fusion_body = True
    return comps, entry


def _dot_flops(op: Op, symtab: Dict[str, Tuple[int, int]]) -> float:
    """2 * prod(lhs elems) * prod(rhs free dims). Using dimension numbers:
    flops = 2 * batch * M * N * K = 2 * lhs_elems * rhs_free_elems."""
    ops = _OPERAND_RE.findall(op.line.split("(", 1)[1])
    lhs = symtab.get(ops[0], (0, 0))[0] if ops else 0
    # rhs free = rhs_elems / (batch * K) = rhs_elems * out_elems-based:
    # out = batch * M * N; lhs = batch * M * K  =>  N = out/(batch*M)
    # flops = 2 * batch * M * N * K = 2 * lhs * (out / (batch * M))
    #       = 2 * lhs * out / (lhs / K) ... avoid dim parsing:
    # use: flops = 2 * sqrt-free relation needs K. Parse contracting dims.
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    lhs_shape = _op_dims(op.line, operand_idx=0, symdims=None)
    if mc is None or lhs_shape is None:
        # fallback: assume K ~ lhs_elems / out_rows — crude: 2*lhs*1
        return 2.0 * lhs
    contracting = [int(x) for x in mc.group(1).split(",") if x]
    k = 1
    for c in contracting:
        if c < len(lhs_shape):
            k *= lhs_shape[c]
    return 2.0 * op.out_elems * k


def _op_dims(line: str, operand_idx: int, symdims) -> Optional[List[int]]:
    """Parse operand shapes from the operand list when annotated inline —
    optimized HLO usually writes `dot(%a, %b)` without shapes, so we carry
    a dims table instead."""
    return None


class CostWalker:
    def __init__(self, comps: Dict[str, Computation]):
        self.comps = comps
        # per-computation symbol tables: op name -> (elems, bytes) and dims
        self.symtab: Dict[str, Dict[str, Tuple[int, int]]] = {}
        self.dims: Dict[str, Dict[str, List[int]]] = {}
        for cname, comp in comps.items():
            tab, dtab = {}, {}
            for op in comp.ops:
                tab[op.name] = (op.out_elems, op.out_bytes)
                m = _SHAPE_RE.search(op.shape_text)
                if m:
                    dtab[op.name] = [int(d) for d in m.group(2).split(",")
                                     if d]
            self.symtab[cname] = tab
            self.dims[cname] = dtab
        self._memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}
        self.bytes_by_kind: Dict[str, float] = {}
        self._kind_memo: Dict[str, Dict[str, float]] = {}
        self._fusion_memo: Dict[str, tuple] = {}

    def _trip_count(self, cond_name: str) -> float:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1.0
        consts = []
        for op in comp.ops:
            consts += [int(x) for x in _CONST_RE.findall(op.line)]
        return float(max(consts)) if consts else 1.0

    def cost(self, cname: str):
        """Returns (flops, bytes, coll_by_kind, bytes_by_op_kind)."""
        if cname in self._memo:
            return self._memo[cname]
        comp = self.comps.get(cname)
        if comp is None:
            return 0.0, 0.0, {}, {}
        flops, bts = 0.0, 0.0
        coll: Dict[str, float] = {}
        kb: Dict[str, float] = {}

        def charge(kind, amount):
            nonlocal bts
            bts += amount
            kb[kind] = kb.get(kind, 0.0) + amount

        tab = self.symtab[cname]
        dtab = self.dims[cname]
        for op in comp.ops:
            if op.kind in _FREE_OPS:
                continue
            # `copy` is an XLA:CPU while-loop aliasing artifact (on the TPU
            # target, loop carries alias in place); charging it would count
            # phantom traffic — see EXPERIMENTS.md §Method.
            if op.kind == "copy":
                continue
            if op.kind == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                mcnd = re.search(r"condition=%?([\w.\-]+)", op.line)
                if mb:
                    body = mb.group(1)
                if mcnd:
                    cond = mcnd.group(1)
                mtc = re.search(r'known_trip_count.*?"n":"(\d+)"', op.line)
                if mtc:
                    trip = float(mtc.group(1))
                else:
                    trip = self._trip_count(cond) if cond else 1.0
                bf, bb, bc, bk = self.cost(body) if body \
                    else (0.0, 0.0, {}, {})
                flops += trip * bf
                bts += trip * bb
                for k, v in bc.items():
                    coll[k] = coll.get(k, 0.0) + trip * v
                for k, v in bk.items():
                    kb[k] = kb.get(k, 0.0) + trip * v
                continue
            if op.kind in ("call", "custom-call", "conditional"):
                for callee in _CALLS_RE.findall(op.line):
                    cf, cb, cc, ck = self.cost(callee)
                    flops += cf
                    bts += cb
                    for k, v in cc.items():
                        coll[k] = coll.get(k, 0.0) + v
                    for k, v in ck.items():
                        kb[k] = kb.get(k, 0.0) + v
                continue
            is_coll = False
            for kind, factor in _COLL_FACTOR.items():
                if re.search(rf"\b{kind}(-start)?\(", op.line) and \
                        f"{kind}-done" not in op.line:
                    payload = op.out_bytes
                    coll[kind] = coll.get(kind, 0.0) + payload * factor
                    charge(kind, payload)
                    is_coll = True
                    break
            if is_coll:
                continue
            if op.kind == "fusion":
                callee = None
                mcal = re.search(r"calls=%?([\w.\-]+)", op.line)
                if mcal:
                    callee = mcal.group(1)
                if callee and callee in self.comps:
                    ff, fb = self._fusion_cost(callee, op.out_bytes)
                    flops += ff
                    charge("fusion", fb)
                else:
                    flops += op.out_elems
                    charge("fusion", 2 * op.out_bytes)
                continue
            if op.kind == "dot":
                flops += self._dot(op, dtab)
                operand_names = _OPERAND_RE.findall(op.args_text)
                charge("dot", op.out_bytes + sum(
                    tab.get(o, (0, 0))[1] for o in operand_names[:2]))
                continue
            if op.kind in ("dynamic-update-slice", "dynamic-slice"):
                if op.kind == "dynamic-slice":
                    charge(op.kind, 2 * op.out_bytes)
                else:
                    operand_names = _OPERAND_RE.findall(op.args_text)
                    upd = tab.get(operand_names[1], (0, 0))[1] \
                        if len(operand_names) > 1 else op.out_bytes
                    charge(op.kind, 2 * upd)
                continue
            operand_names = _OPERAND_RE.findall(op.args_text)
            obytes = sum(tab.get(o, (0, 0))[1] for o in operand_names)
            charge(op.kind, obytes + op.out_bytes)
            flops += op.out_elems
        self._memo[cname] = (flops, bts, coll, kb)
        return self._memo[cname]

    def _fusion_flops(self, cname: str) -> float:
        return self._fusion_cost(cname, 0)[0]

    def _fusion_cost(self, cname: str, out_bytes: int):
        """(flops, hbm_bytes) of one fusion call.

        Fusion internals are streamed (registers), so HBM traffic is only:
          * parameters — charged at *slice* size when the body merely
            dynamic-slices them (loop-carried stacks!), full size otherwise;
          * dynamic-update-slice writes — charged at update size (the big
            target buffer is aliased in place, not rewritten);
          * the fusion output — unless the root is a DUS chain (aliased).
        Flops: exact dots + 1/elem for the rest.
        """
        if cname in self._fusion_memo:
            f, b, root_aliased = self._fusion_memo[cname]
            return f, b + (0 if root_aliased else out_bytes)
        comp = self.comps[cname]
        dtab = self.dims[cname]
        tab = self.symtab[cname]
        params = {o.name: o.out_bytes for o in comp.ops
                  if o.kind == "parameter"}
        sliced: Dict[str, int] = {}
        used_full = set()
        flops, extra = 0.0, 0.0
        dus_names = set()
        for o in comp.ops:
            if o.kind == "parameter":
                continue
            args = _OPERAND_RE.findall(o.args_text)
            if o.kind == "dot":
                flops += self._dot(o, dtab)
            elif o.kind not in _FREE_OPS:
                flops += o.out_elems
            if o.kind in ("dynamic-slice", "slice") and args \
                    and args[0] in params:
                sliced[args[0]] = sliced.get(args[0], 0) + o.out_bytes
                for a in args[1:]:
                    if a in params and params[a] > 64:
                        used_full.add(a)
                continue
            if o.kind == "dynamic-update-slice":
                upd = tab.get(args[1], (0, 0))[1] if len(args) > 1 else 0
                extra += 2 * upd
                dus_names.add(o.name)
                # a param fed to DUS as the big target is aliased: skip it
                for a in args[2:]:
                    if a in params and params[a] > 64:
                        used_full.add(a)
                continue
            if o.kind in ("bitcast", "convert", "copy") and args and \
                    args[0] in dus_names:
                dus_names.add(o.name)   # alias chains keep DUS rooting
            for a in args:
                if a in params:
                    used_full.add(a)
        pbytes = 0.0
        for name, sz in params.items():
            if name in used_full:
                pbytes += sz
            elif name in sliced:
                pbytes += sliced[name]
            # unused params: free
        root = comp.ops[-1] if comp.ops else None
        root_aliased = bool(root and (root.name in dus_names
                                      or root.kind == "dynamic-update-slice"))
        total = pbytes + extra
        self._fusion_memo[cname] = (flops, total, root_aliased)
        return flops, total + (0 if root_aliased else out_bytes)

    def _dot(self, op: Op, dtab: Dict[str, List[int]]) -> float:
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
        operand_names = _OPERAND_RE.findall(op.args_text)
        lhs_dims = dtab.get(operand_names[0]) if operand_names else None
        if mc and lhs_dims:
            k = 1
            for c in [int(x) for x in mc.group(1).split(",") if x]:
                if c < len(lhs_dims):
                    k *= lhs_dims[c]
            return 2.0 * op.out_elems * k
        return 2.0 * op.out_elems   # fallback (K unknown)


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    coll_bytes_by_kind: Dict[str, float]
    bytes_by_op_kind: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll_bytes_by_kind.values())


def analyze_hlo(hlo_text: str) -> HloCost:
    comps, entry = parse_module(hlo_text)
    walker = CostWalker(comps)
    flops, bts, coll, kb = walker.cost(entry)
    return HloCost(flops=flops, hbm_bytes=bts, coll_bytes_by_kind=coll,
                   bytes_by_op_kind=kb)
