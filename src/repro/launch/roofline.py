"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

``cost_analysis`` on the partitioned executable reports the per-device
program, so the per-chip division is already done. Collective bytes are NOT
in cost_analysis: we parse the post-SPMD optimized HLO and sum operand
sizes of every collective op, weighting all-reduce 2x (ring = reduce-
scatter + all-gather).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# result-shape(s) of an op line: one or more `dtype[d0,d1,...]` groups
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# bytes moved per byte of payload (asymptotic ring factors)
_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Weighted bytes moved through ICI per device, by collective kind."""
    per_kind: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, rhs = stripped.split("=", 1)
        rhs = rhs.strip()
        for kind in _COLLECTIVES:
            # match `bf16[...] all-reduce(` / `all-gather-start(` forms;
            # skip `-done` (payload already counted at `-start`).
            m = re.search(rf"\b{kind}(-start)?\(", rhs)
            if not m:
                continue
            if re.search(rf"\b{kind}-done\(", rhs):
                continue
            # result shape: text before the op name
            head = rhs[:m.start()]
            size = _shape_bytes(head)
            per_kind[kind] = per_kind.get(kind, 0.0) + size * _FACTOR[kind]
            break
    return sum(per_kind.values()), per_kind


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device weighted collective bytes
    coll_by_kind: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float = 0.0     # 6*N*D (useful flops, global)
    n_devices: int = 1

    @property
    def useful_ratio(self) -> Optional[float]:
        if not self.model_flops:
            return None
        total = self.flops * self.n_devices
        return self.model_flops / total if total else None

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step that is pure compute at peak — i.e. how
        close the dominant term is to the compute roofline."""
        t = self.step_time_s
        return self.compute_s / t if t else 0.0


def analyze(compiled, n_devices: int, model_flops: float = 0.0) -> Roofline:
    """Roofline terms from the optimized per-device HLO.

    Uses the trip-count-aware HLO walker (launch.hlo_cost) rather than
    ``compiled.cost_analysis()``: XLA's built-in analysis counts while-loop
    bodies once, which under-counts every scanned layer/chunk/microbatch
    loop (verified empirically — see EXPERIMENTS.md §Method).
    """
    from repro.launch import hlo_cost
    hc = hlo_cost.analyze_hlo(compiled.as_text())
    flops = hc.flops
    hbm = hc.hbm_bytes
    coll, by_kind = hc.coll_bytes, dict(hc.coll_bytes_by_kind)
    c_s = flops / PEAK_FLOPS_BF16
    m_s = hbm / HBM_BW
    k_s = coll / ICI_BW
    terms = {"compute": c_s, "memory": m_s, "collective": k_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                    coll_by_kind=by_kind, compute_s=c_s, memory_s=m_s,
                    collective_s=k_s, bottleneck=bottleneck,
                    model_flops=model_flops, n_devices=n_devices)


# ---------------------------------------------------------------------------
# MODEL_FLOPS = 6 * N * D  (dense)  or  6 * N_active * D (MoE); decode uses
# 2 * N * D_new (forward only, one token per step per sequence).
# ---------------------------------------------------------------------------

def count_params(cfg, active_only: bool = False) -> float:
    """Approximate parameter count from the config (embedding included)."""
    d, v, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    hd = cfg.resolved_head_dim
    n = v * d * (1 if cfg.tie_embeddings else 2)

    def attn_params():
        if cfg.attn_type == "mla":
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            return (d * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * qk
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * cfg.num_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + cfg.num_heads * m.v_head_dim * d)
        if cfg.attn_type == "none":
            return 0
        return (d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd
                + cfg.num_heads * hd * d)

    def mlp_params(ff):
        return 3 * d * ff

    if cfg.family == "moe":
        m = cfg.moe
        n += m.first_dense_layers * (attn_params() + mlp_params(m.dense_d_ff))
        moe_layers = L - m.first_dense_layers
        routed = mlp_params(m.expert_d_ff)
        shared = mlp_params(m.expert_d_ff * m.num_shared_experts)
        per_layer_total = attn_params() + m.num_experts * routed + shared
        per_layer_active = attn_params() + m.top_k * routed + shared
        n_total = n + moe_layers * per_layer_total
        n_active = n + moe_layers * per_layer_active \
            + m.first_dense_layers * (attn_params() + mlp_params(m.dense_d_ff))
        return n_active if active_only else n_total

    if cfg.family == "ssm":   # xLSTM
        xl = cfg.xlstm
        di_m = int(xl.mlstm_proj_factor * d)
        ml = d * 2 * di_m + 3 * di_m * di_m // cfg.num_heads * cfg.num_heads \
            + di_m * d
        sl = d * 4 * d + 3 * d * int(xl.slstm_proj_factor * d)
        n += (L // 2) * (ml + sl)
        return n

    if cfg.family == "hybrid":
        s = cfg.ssm
        d_inner = s.expand * d
        mamba = d * (2 * d_inner + 2 * s.d_state + d_inner // s.head_dim) \
            + d_inner * d
        n_mamba = L - (L // s.attn_every)
        n_attn = 1 if s.shared_attn else L // s.attn_every
        n += n_mamba * mamba + n_attn * (attn_params() + mlp_params(cfg.d_ff))
        return n

    if cfg.family == "audio":
        enc = cfg.encdec.encoder_layers * (attn_params() + mlp_params(cfg.d_ff))
        dec = L * (2 * attn_params() + mlp_params(cfg.d_ff))
        return n + enc + dec

    if cfg.family == "vlm":
        per = cfg.vision.cross_attn_every
        n_cross = L // per
        n_self = L - n_cross
        n += n_self * (attn_params() + mlp_params(cfg.d_ff))
        n += n_cross * (attn_params() + mlp_params(cfg.d_ff))
        return n

    return n + L * (attn_params() + mlp_params(cfg.d_ff))


def model_flops_for(cfg, shape) -> float:
    n = count_params(cfg, active_only=(cfg.family == "moe"))
    tokens = shape.seq_len * shape.global_batch
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    # decode: one new token per sequence per step
    return 2.0 * n * shape.global_batch
