"""Pallas TPU kernel: fused filter-aware distance scan → per-query top-k.

The staged read path launches one ``ivf_scan`` per segment per query
batch and ships the full ``(nq, n)`` distance matrix back to the host,
where numpy does the top-k cut.  This kernel fuses all three stages —
predicate masking, distance scan, top-k selection — into ONE launch over
the cross-segment *packed* superbatch (see ``ops.fused_scan_topk`` for
the host-side packing/compaction layer):

  * grid = (nq / BLOCK_Q, n / BLOCK_N); the inner (posting) dimension is
    sequential on TPU, so the output block doubles as the per-query-tile
    running top-k accumulator (the canonical revisited-block pattern);
  * per tile, squared-L2 distances use the MXU via
    ||q - v||^2 = ||q||^2 - 2 q.v + ||v||^2; the predicate bitmap is
    applied INSIDE the scan (masked lanes get +inf) so staged
    filter -> rank round trips disappear;
  * each tile merges its BLOCK_N candidates into the running (BLOCK_Q, K)
    top-k with one ``lax.sort`` over K + BLOCK_N lanes (a sorting network
    on TPU; K <= 128 keeps it a small fraction of the matmul cost).
    Sort keys are (distance, pk) so ties break identically to the host
    merge's ``lexsort((pk, score))``; the packed row id rides along as a
    payload;
  * fully-masked (query-tile, block) pairs are skipped via a per-block
    occupancy grid the host derives from zone maps + bitmaps — the
    compute predicate costs one SMEM scalar read.

Only ``(nq, K)`` distances + row ids + pks leave the device instead of
``(nq, n)`` distances: device->host traffic is k/n of the staged path,
and dispatches drop from O(segments x predicates) to 1 per query batch.

The bitmap is uint8 (0/1) here for interpret-mode simplicity; a
production TPU build would pack it 8 rows/byte and unpack in-register.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 8          # query rows per tile (sublane-aligned)
BLOCK_N = 512        # packed posting vectors per tile (lane-aligned)
KMAX = 128           # top-k capacity: one lane register row per query

# int32 sentinel for "no candidate" slots: +inf distance partners with the
# largest pk/id so sentinels sort after every real candidate
SENTINEL = np.int32(np.iinfo(np.int32).max)


def _fused_scan_topk_kernel(occ_ref, q_ref, x_ref, mask_ref, pk_ref,
                            out_d_ref, out_p_ref, out_i_ref):
    """One (query-tile, posting-block) grid step.

    occ_ref:  (1, 1) SMEM — 0 when every lane of this tile is masked
    q_ref:    (BLOCK_Q, d) queries        (resident across the inner dim)
    x_ref:    (BLOCK_N, d) packed vectors
    mask_ref: (BLOCK_Q, BLOCK_N) uint8 predicate bitmap
    pk_ref:   (1, BLOCK_N) int32 primary keys (tie-break sort key)
    out_*:    (BLOCK_Q, KMAX) running top-k — same block for every j, so
              it accumulates across the sequential inner dimension
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_d_ref[...] = jnp.full((BLOCK_Q, KMAX), jnp.inf, jnp.float32)
        out_p_ref[...] = jnp.full((BLOCK_Q, KMAX), SENTINEL, jnp.int32)
        out_i_ref[...] = jnp.full((BLOCK_Q, KMAX), SENTINEL, jnp.int32)

    @pl.when(occ_ref[0, 0] != 0)
    def _scan_and_merge():
        q = q_ref[...].astype(jnp.float32)
        x = x_ref[...].astype(jnp.float32)
        qn = jnp.sum(q * q, axis=1, keepdims=True)            # (BQ, 1)
        xn = jnp.sum(x * x, axis=1)[None, :]                  # (1, BN)
        # MXU matmul: (BQ, d) x (d, BN)
        dots = jax.lax.dot_general(
            q, x, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        m = mask_ref[...] != 0
        d = jnp.where(m, qn - 2.0 * dots + xn, jnp.inf)
        ids = j * BLOCK_N + jax.lax.broadcasted_iota(
            jnp.int32, (BLOCK_Q, BLOCK_N), 1)
        ids = jnp.where(m, ids, SENTINEL)
        pks = jnp.where(m, pk_ref[...], SENTINEL)             # (BQ, BN)
        # merge the block into the running top-k: lexicographic sort by
        # (distance, pk), packed row id as payload
        cat_d = jnp.concatenate([out_d_ref[...], d], axis=1)
        cat_p = jnp.concatenate([out_p_ref[...], pks], axis=1)
        cat_i = jnp.concatenate([out_i_ref[...], ids], axis=1)
        sd, sp, si = jax.lax.sort((cat_d, cat_p, cat_i), dimension=1,
                                  num_keys=2)
        out_d_ref[...] = sd[:, :KMAX]
        out_p_ref[...] = sp[:, :KMAX]
        out_i_ref[...] = si[:, :KMAX]


def fused_scan_topk(q: jnp.ndarray, x: jnp.ndarray, mask: jnp.ndarray,
                    pks: jnp.ndarray, occ: jnp.ndarray,
                    interpret: bool = True):
    """q (nq, d); x (n, d); mask (nq, n) uint8; pks (1, n) int32;
    occ (nq/BLOCK_Q, n/BLOCK_N) int32.  All padded to tile multiples by
    ``ops.fused_scan_topk``.  Returns ((nq, KMAX) fp32 squared-L2 sorted
    ascending, (nq, KMAX) int32 pks, (nq, KMAX) int32 packed row ids);
    empty slots hold (+inf, SENTINEL, SENTINEL)."""
    nq, d = q.shape
    n, _ = x.shape
    assert nq % BLOCK_Q == 0 and n % BLOCK_N == 0, (nq, n)
    grid = (nq // BLOCK_Q, n // BLOCK_N)
    return pl.pallas_call(
        _fused_scan_topk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((BLOCK_Q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_N, d), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_Q, BLOCK_N), lambda i, j: (i, j)),
            pl.BlockSpec((1, BLOCK_N), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_Q, KMAX), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_Q, KMAX), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_Q, KMAX), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, KMAX), jnp.float32),
            jax.ShapeDtypeStruct((nq, KMAX), jnp.int32),
            jax.ShapeDtypeStruct((nq, KMAX), jnp.int32),
        ],
        interpret=interpret,
    )(occ, q, x, mask, pks)
