"""Pallas kernel: batched beam search over a packed CSR graph index.

One launch answers a whole query batch against the STACKED per-segment
graphs (``core/index/graph.py``): the segments' flat-array CSRs are
concatenated with neighbor ids shifted into packed row space (the -1
out-degree padding survives the shift), and every segment's medoid seeds
the walk, so a single frontier explores all segments at once.

Per BLOCK_Q query tile the kernel runs a fixed number of hops.  Each hop
expands the current beam's neighbor lists (one int32 gather), drops -1
padding and already-visited rows, scores the fresh candidates with the
difference-form squared L2 (per-element rounding independent of the
batch tiling, so the tiled kernel is bitwise equal to the full-batch
oracle in ``ref.py``), and merges them into two fixed-width accumulators
with the fused scan's ``lax.sort`` (distance, pk) comparator:

  * the traversal beam keeps UNfiltered distances — greedy routing must
    walk through rows the predicate rejects or recall collapses under
    selective filters;
  * the result accumulator admits only bitmap-passing rows, masking
    rejected lanes to (+inf, SENTINEL) exactly like ``FusedScanTopK``.

The visited set lives in an int32 bitmask that is also the kernel's
revisited-output block: callers popcount it for the "candidate rows
gathered" statistic the planner's C_GATHER_ROW term models.  Emitted
distances are approximate only in coverage, never in value — survivors
are re-ranked through the exact fused kernel by the operator layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_scan import BLOCK_Q, SENTINEL


def _graph_search_kernel(q_ref, x_ref, nbr_ref, entry_ref, mask_ref, pk_ref,
                         out_d_ref, out_p_ref, out_i_ref, vis_ref,
                         *, beam: int, hops: int):
    q = q_ref[...].astype(jnp.float32)             # (BQ, d)
    x = x_ref[...].astype(jnp.float32)             # (n, d)
    nbrs = nbr_ref[...]                            # (n, R) int32, -1 padded
    mask = mask_ref[...] != 0                      # (BQ, n)
    pks = pk_ref[...][0, :]                        # (n,) int32
    entries = entry_ref[...][0, :]                 # (E,) int32, SENTINEL pad
    bq = q.shape[0]
    n_rows = x.shape[0]
    r_deg = nbrs.shape[1]
    nw = vis_ref.shape[1]                          # visited words = n/32
    n_ent = entries.shape[0]
    iota_w = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nw), 2)

    def dists_to(safe_ids):
        # difference-form squared L2: each output element sums only its
        # own (q_i, x_j) pair over d, so rounding never depends on what
        # the row is batched with (bitwise parity with the ref twin)
        xv = jnp.take(x, safe_ids, axis=0)         # (BQ, C, d)
        diff = xv - q[:, None, :]
        return jnp.sum(diff * diff, axis=2)

    def scatter_bits(safe_ids, live):
        # OR each live id's bit into the per-query visited words.  A SUM
        # implements the OR exactly: callers guarantee live ids are
        # unique within the call and not yet visited, so every (word,
        # bit) position is hit at most once and distinct single-bit
        # patterns add carry-free (int32 wraparound on bit 31 included).
        bit = jnp.where(live, jnp.int32(1) << (safe_ids & 31), 0)
        hit = (safe_ids >> 5)[:, :, None] == iota_w
        return jnp.sum(jnp.where(hit, bit[:, :, None], 0), axis=1)

    def merge_topm(acc, cd, cp, ci):
        md = jnp.concatenate([acc[0], cd], axis=1)
        mp = jnp.concatenate([acc[1], cp], axis=1)
        mi = jnp.concatenate([acc[2], ci], axis=1)
        sd, sp, si = jax.lax.sort((md, mp, mi), dimension=1, num_keys=2)
        return sd[:, :beam], sp[:, :beam], si[:, :beam]

    # ---- seed: every segment medoid, visited from hop 0 -------------------
    ev = jnp.broadcast_to((entries != SENTINEL)[None, :], (bq, n_ent))
    esafe = jnp.broadcast_to(
        jnp.where(entries != SENTINEL, entries, 0)[None, :], (bq, n_ent))
    ed = jnp.where(ev, dists_to(esafe), jnp.inf)
    epk = jnp.where(ev, jnp.take(pks, esafe), SENTINEL)
    eid = jnp.where(ev, esafe, SENTINEL)
    empty = (jnp.full((bq, beam), jnp.inf, jnp.float32),
             jnp.full((bq, beam), SENTINEL, jnp.int32),
             jnp.full((bq, beam), SENTINEL, jnp.int32))
    bd, bp, bi = merge_topm(empty, ed, epk, eid)
    epass = ev & jnp.take_along_axis(mask, esafe, axis=1)
    rd, rp, ri = merge_topm(empty,
                            jnp.where(epass, ed, jnp.inf),
                            jnp.where(epass, epk, SENTINEL),
                            jnp.where(epass, eid, SENTINEL))
    vis = scatter_bits(esafe, ev)

    def hop(_, state):
        bd, bp, bi, rd, rp, ri, vis = state
        fval = bi != SENTINEL
        fsafe = jnp.where(fval, bi, 0)
        cand = jnp.take(nbrs, fsafe, axis=0).reshape(bq, beam * r_deg)
        # guard BEFORE any gather keyed by cand: -1 out-degree padding
        # (and dead frontier lanes) would otherwise clamp to row 0
        cval = (cand >= 0) & jnp.repeat(fval, r_deg, axis=1)
        csafe = jnp.where(cval, cand, 0)
        words = jnp.take_along_axis(vis, csafe >> 5, axis=1)
        seen = ((words >> (csafe & 31)) & 1) != 0
        fresh = cval & ~seen
        cd = jnp.where(fresh, dists_to(csafe), jnp.inf)
        cp = jnp.where(fresh, jnp.take(pks, csafe), SENTINEL)
        ci = jnp.where(fresh, csafe, SENTINEL)
        # in-hop dedup: one row reachable from several frontier lanes.
        # Sort by id; repeated ids are adjacent and carry identical
        # (d, pk) payloads, so invalidating all but the first is exact.
        si_, sd_, sp_ = jax.lax.sort((ci, cd, cp), dimension=1, num_keys=1)
        dup = jnp.concatenate(
            [jnp.zeros((bq, 1), bool), si_[:, 1:] == si_[:, :-1]],
            axis=1) & (si_ != SENTINEL)
        uniq = (si_ != SENTINEL) & ~dup
        usafe = jnp.where(uniq, si_, 0)
        ud = jnp.where(uniq, sd_, jnp.inf)
        up = jnp.where(uniq, sp_, SENTINEL)
        ui = jnp.where(uniq, si_, SENTINEL)
        vis = vis | scatter_bits(usafe, uniq)
        bd, bp, bi = merge_topm((bd, bp, bi), ud, up, ui)
        admit = uniq & jnp.take_along_axis(mask, usafe, axis=1)
        rd, rp, ri = merge_topm((rd, rp, ri),
                                jnp.where(admit, ud, jnp.inf),
                                jnp.where(admit, up, SENTINEL),
                                jnp.where(admit, ui, SENTINEL))
        return bd, bp, bi, rd, rp, ri, vis

    bd, bp, bi, rd, rp, ri, vis = jax.lax.fori_loop(
        0, hops, hop, (bd, bp, bi, rd, rp, ri, vis))
    del bd, bp, bi, n_rows
    out_d_ref[...] = rd
    out_p_ref[...] = rp
    out_i_ref[...] = ri
    vis_ref[...] = vis


def graph_search_topk(q, x, neighbors, entries, mask, pks,
                      beam: int, hops: int, interpret: bool = True):
    """q (nq, d); x (n, d) packed vectors; neighbors (n, R) int32 CSR in
    packed row space, -1 padded; entries (1, E) int32 seed rows, SENTINEL
    padded; mask (nq, n) uint8 predicate bitmap; pks (1, n) int32.

    Returns ((nq, beam) fp32 squared-L2 ascending, (nq, beam) int32 pks,
    (nq, beam) int32 packed row ids, (nq, n/32) int32 visited bitmask);
    empty result slots hold (+inf, SENTINEL, SENTINEL)."""
    nq, d = q.shape
    n, r_deg = neighbors.shape
    n_ent = entries.shape[1]
    assert nq % BLOCK_Q == 0, (nq,)
    assert n % 32 == 0, n            # visited bitmask packs 32 rows/word
    nw = n // 32
    grid = (nq // BLOCK_Q,)
    kernel = functools.partial(_graph_search_kernel, beam=beam, hops=hops)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_Q, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, r_deg), lambda i: (0, 0)),
            pl.BlockSpec((1, n_ent), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_Q, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_Q, beam), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_Q, beam), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_Q, beam), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_Q, nw), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, beam), jnp.float32),
            jax.ShapeDtypeStruct((nq, beam), jnp.int32),
            jax.ShapeDtypeStruct((nq, beam), jnp.int32),
            jax.ShapeDtypeStruct((nq, nw), jnp.int32),
        ],
        interpret=interpret,
    )(q, x, neighbors, entries, mask, pks)
