"""Pure-jnp oracles for every Pallas kernel (the correctness reference).

Each function mirrors one kernel's semantics exactly; kernel tests sweep
shapes/dtypes and assert_allclose kernel(interpret=True) vs these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ivf_scan_ref(q: jnp.ndarray, vecs: jnp.ndarray) -> jnp.ndarray:
    """Squared-L2 distances. q: (nq, d); vecs: (n, d) -> (nq, n) fp32."""
    q = q.astype(jnp.float32)
    v = vecs.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)          # (nq, 1)
    vn = jnp.sum(v * v, axis=-1)[None, :]                # (1, n)
    return qn - 2.0 * (q @ v.T) + vn


def pq_adc_ref(codes: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """ADC: codes (n, m) uint8; lut (m, 256) fp32 -> (n,) summed distances."""
    take = jnp.take_along_axis(lut.T, codes.astype(jnp.int32), axis=0)
    # lut.T: (256, m); gather per column j at codes[:, j]
    return jnp.sum(take.astype(jnp.float32), axis=1)


def bitmap_filter_ref(cols: jnp.ndarray, bounds: jnp.ndarray) -> jnp.ndarray:
    """cols (n, c) fp32; bounds (c, 2) [lo, hi] inclusive -> (n,) bool:
    AND over all per-column range predicates (fused multi-predicate)."""
    lo = bounds[:, 0][None, :]
    hi = bounds[:, 1][None, :]
    ok = (cols >= lo) & (cols <= hi)
    return jnp.all(ok, axis=1)


def topk_merge_ref(dists: jnp.ndarray, ids: jnp.ndarray, k: int):
    """Merge S sorted top-k lists: dists/ids (s, k) -> global (k,), (k,)."""
    flat_d = dists.reshape(-1)
    flat_i = ids.reshape(-1)
    # analysis: allow[parity/raw-score-sort] ties break by flattened
    # position here, matching the kernel's argmin selection order
    order = jnp.argsort(flat_d)[:k]
    return flat_d[order], flat_i[order]


def batched_topk_merge_ref(dists: jnp.ndarray, ids: jnp.ndarray, k: int):
    """Batched cross-shard merge oracle (topk_merge.batched_topk_merge).

    dists (nq, s, kk) fp32; ids (nq, s, kk) int32 -> ((nq, k), (nq, k)):
    per query the k smallest candidates across all shard lists in
    ascending (score, id) lexicographic order; padded slots carry
    (+inf, INT32_MAX) and sort last."""
    nq = dists.shape[0]
    flat_d = dists.reshape(nq, -1).astype(jnp.float32)
    flat_i = ids.reshape(nq, -1)
    sd, si = jax.lax.sort((flat_d, flat_i), dimension=1, num_keys=2)
    return sd[:, :k], si[:, :k]


def fused_topk_ref(q: jnp.ndarray, x: jnp.ndarray, mask: jnp.ndarray,
                   pks: jnp.ndarray, k: int):
    """Fused masked scan -> top-k oracle (kernels/fused_scan.py).

    q (nq, d); x (n, d); mask (nq, n); pks (1, n) int32 -> per query the k
    smallest squared-L2 distances over mask-admitted rows, ties broken by
    pk, then row id.  Returns ((nq, k) fp32, (nq, k) int32 pks, (nq, k)
    int32 row ids); empty slots hold (+inf, INT32_MAX, INT32_MAX)."""
    sentinel = jnp.int32(jnp.iinfo(jnp.int32).max)
    d = ivf_scan_ref(q, x)
    m = mask != 0
    d = jnp.where(m, d, jnp.inf)
    ids = jnp.broadcast_to(
        jax.lax.broadcasted_iota(jnp.int32, d.shape, 1), d.shape)
    ids = jnp.where(m, ids, sentinel)
    pkb = jnp.where(m, pks.astype(jnp.int32), sentinel)
    sd, sp, si = jax.lax.sort((d, pkb, ids), dimension=1, num_keys=2)
    return sd[:, :k], sp[:, :k], si[:, :k]


def quantized_topk_ref(lut: jnp.ndarray, codes: jnp.ndarray,
                       mask: jnp.ndarray, pks: jnp.ndarray, k: int):
    """Fused quantized ADC scan -> top-k' oracle (quantized_scan.py).

    lut (nq, m, 256) fp32 per-query ADC tables; codes (n, m); mask
    (nq, n); pks (1, n) int32 -> per query the k smallest ADC distances
    over mask-admitted rows, ties broken by pk, then row id.  Returns
    ((nq, k) fp32, (nq, k) int32 pks, (nq, k) int32 row ids); empty
    slots hold (+inf, INT32_MAX, INT32_MAX)."""
    sentinel = jnp.int32(jnp.iinfo(jnp.int32).max)
    # gather lut[q, j, codes[i, j]] and sum over j: (nq, m, n) -> (nq, n)
    idx = codes.astype(jnp.int32).T[None, :, :]          # (1, m, n)
    take = jnp.take_along_axis(
        lut.astype(jnp.float32), jnp.broadcast_to(
            idx, (lut.shape[0],) + idx.shape[1:]), axis=2)
    d = jnp.sum(take, axis=1)
    m = mask != 0
    d = jnp.where(m, d, jnp.inf)
    ids = jnp.broadcast_to(
        jax.lax.broadcasted_iota(jnp.int32, d.shape, 1), d.shape)
    ids = jnp.where(m, ids, sentinel)
    pkb = jnp.where(m, pks.astype(jnp.int32), sentinel)
    sd, sp, si = jax.lax.sort((d, pkb, ids), dimension=1, num_keys=2)
    return sd[:, :k], sp[:, :k], si[:, :k]


def graph_search_topk_ref(q: jnp.ndarray, x: jnp.ndarray,
                          neighbors: jnp.ndarray, entries: jnp.ndarray,
                          mask: jnp.ndarray, pks: jnp.ndarray,
                          beam: int, hops: int):
    """Batched CSR beam-search oracle (kernels/graph_search.py).

    q (nq, d); x (n, d); neighbors (n, R) int32 packed CSR, -1 padded;
    entries (1, E) int32 seed rows, SENTINEL padded; mask (nq, n); pks
    (1, n) int32.  Full-batch mirror of the kernel's hop loop: every
    operation is per-query-row independent and distances use the
    difference form, so the BLOCK_Q-tiled kernel must match bitwise.
    Returns ((nq, beam) fp32 squared-L2 ascending, (nq, beam) int32 pks,
    (nq, beam) int32 row ids, (nq, n/32) int32 visited bitmask); empty
    result slots hold (+inf, INT32_MAX, INT32_MAX)."""
    sentinel = jnp.int32(jnp.iinfo(jnp.int32).max)
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    nbrs = neighbors
    m = mask != 0
    pk1 = pks[0, :]
    ent = entries[0, :]
    nq = q.shape[0]
    r_deg = nbrs.shape[1]
    nw = x.shape[0] // 32
    n_ent = ent.shape[0]
    iota_w = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nw), 2)

    def dists_to(safe_ids):
        xv = jnp.take(x, safe_ids, axis=0)
        diff = xv - q[:, None, :]
        return jnp.sum(diff * diff, axis=2)

    def scatter_bits(safe_ids, live):
        bit = jnp.where(live, jnp.int32(1) << (safe_ids & 31), 0)
        hit = (safe_ids >> 5)[:, :, None] == iota_w
        return jnp.sum(jnp.where(hit, bit[:, :, None], 0), axis=1)

    def merge_topm(acc, cd, cp, ci):
        md = jnp.concatenate([acc[0], cd], axis=1)
        mp = jnp.concatenate([acc[1], cp], axis=1)
        mi = jnp.concatenate([acc[2], ci], axis=1)
        sd, sp, si = jax.lax.sort((md, mp, mi), dimension=1, num_keys=2)
        return sd[:, :beam], sp[:, :beam], si[:, :beam]

    ev = jnp.broadcast_to((ent != sentinel)[None, :], (nq, n_ent))
    esafe = jnp.broadcast_to(
        jnp.where(ent != sentinel, ent, 0)[None, :], (nq, n_ent))
    ed = jnp.where(ev, dists_to(esafe), jnp.inf)
    epk = jnp.where(ev, jnp.take(pk1, esafe), sentinel)
    eid = jnp.where(ev, esafe, sentinel)
    empty = (jnp.full((nq, beam), jnp.inf, jnp.float32),
             jnp.full((nq, beam), sentinel, jnp.int32),
             jnp.full((nq, beam), sentinel, jnp.int32))
    bd, bp, bi = merge_topm(empty, ed, epk, eid)
    epass = ev & jnp.take_along_axis(m, esafe, axis=1)
    rd, rp, ri = merge_topm(empty,
                            jnp.where(epass, ed, jnp.inf),
                            jnp.where(epass, epk, sentinel),
                            jnp.where(epass, eid, sentinel))
    vis = scatter_bits(esafe, ev)

    def hop(_, state):
        bd, bp, bi, rd, rp, ri, vis = state
        fval = bi != sentinel
        fsafe = jnp.where(fval, bi, 0)
        cand = jnp.take(nbrs, fsafe, axis=0).reshape(nq, beam * r_deg)
        cval = (cand >= 0) & jnp.repeat(fval, r_deg, axis=1)
        csafe = jnp.where(cval, cand, 0)
        words = jnp.take_along_axis(vis, csafe >> 5, axis=1)
        seen = ((words >> (csafe & 31)) & 1) != 0
        fresh = cval & ~seen
        cd = jnp.where(fresh, dists_to(csafe), jnp.inf)
        cp = jnp.where(fresh, jnp.take(pk1, csafe), sentinel)
        ci = jnp.where(fresh, csafe, sentinel)
        si_, sd_, sp_ = jax.lax.sort((ci, cd, cp), dimension=1, num_keys=1)
        dup = jnp.concatenate(
            [jnp.zeros((nq, 1), bool), si_[:, 1:] == si_[:, :-1]],
            axis=1) & (si_ != sentinel)
        uniq = (si_ != sentinel) & ~dup
        usafe = jnp.where(uniq, si_, 0)
        ud = jnp.where(uniq, sd_, jnp.inf)
        up = jnp.where(uniq, sp_, sentinel)
        ui = jnp.where(uniq, si_, sentinel)
        vis = vis | scatter_bits(usafe, uniq)
        bd, bp, bi = merge_topm((bd, bp, bi), ud, up, ui)
        admit = uniq & jnp.take_along_axis(m, usafe, axis=1)
        rd, rp, ri = merge_topm((rd, rp, ri),
                                jnp.where(admit, ud, jnp.inf),
                                jnp.where(admit, up, sentinel),
                                jnp.where(admit, ui, sentinel))
        return bd, bp, bi, rd, rp, ri, vis

    bd, bp, bi, rd, rp, ri, vis = jax.lax.fori_loop(
        0, hops, hop, (bd, bp, bi, rd, rp, ri, vis))
    return rd, rp, ri, vis


def rect_filter_ref(points: jnp.ndarray, rect: jnp.ndarray) -> jnp.ndarray:
    """points (n, 2); rect (4,) = (xmin, ymin, xmax, ymax) -> (n,) bool."""
    x, y = points[:, 0], points[:, 1]
    return (x >= rect[0]) & (x <= rect[2]) & (y >= rect[1]) & (y <= rect[3])
