"""Pallas TPU kernel: PQ asymmetric-distance computation (ADC).

GPU PQ scan uses shared-memory LUT gathers; TPUs have no fast random
gather, so the idiomatic port is a one-hot matmul: for subquantizer j,
dist_j = onehot(codes[:, j]) @ lut[j] runs on the MXU with the (256,)
LUT row resident in VMEM (DESIGN.md hardware-adaptation table).

Grid tiles the code block dim; the (m, 256) LUT is broadcast to every
tile (tiny: m*256*4 bytes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512        # codes per tile


def _pq_adc_kernel(codes_ref, lut_ref, out_ref):
    """codes: (BLOCK_N, m) int32; lut: (m, 256) fp32; out: (BLOCK_N,)."""
    codes = codes_ref[...]
    lut = lut_ref[...]
    m = codes.shape[1]
    acc = jnp.zeros((codes.shape[0],), jnp.float32)
    for j in range(m):          # static unroll over subquantizers
        onehot = (codes[:, j][:, None] ==
                  jax.lax.broadcasted_iota(jnp.int32, (1, 256), 1))
        acc = acc + jax.lax.dot_general(
            onehot.astype(jnp.float32), lut[j][:, None],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]
    out_ref[...] = acc


def pq_adc(codes: jnp.ndarray, lut: jnp.ndarray,
           interpret: bool = True) -> jnp.ndarray:
    """codes: (n, m) int32 in [0, 256); lut: (m, 256) fp32 -> (n,) fp32."""
    n, m = codes.shape
    assert n % BLOCK_N == 0, n
    grid = (n // BLOCK_N,)
    return pl.pallas_call(
        _pq_adc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, m), lambda i: (i, 0)),
            pl.BlockSpec((m, 256), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(codes, lut)
