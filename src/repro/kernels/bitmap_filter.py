"""Pallas TPU kernel: fused multi-predicate range filter -> bitmap.

ARCADE's hybrid-search plans intersect bitmaps from several secondary
indexes (paper §5); residual predicates over scalar columns are evaluated
with this fused kernel: one pass over the (BLOCK_N, c) column tile
evaluates every range predicate and ANDs them on the VPU — the dense-
bitmap adaptation of the paper's bitmap intersection (DESIGN.md).
Output is int8 (0/1): TPU-friendly mask representation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# analysis: allow[kernel/tile-constants] mask-filter tile, deliberately
# larger than the scan-tile family (int8 rows, VMEM is cheap here)
BLOCK_N = 1024


def _bitmap_kernel(cols_ref, bounds_ref, out_ref):
    """cols: (BLOCK_N, c) fp32; bounds: (c, 2); out: (BLOCK_N,) int8."""
    cols = cols_ref[...]
    bounds = bounds_ref[...]
    lo = bounds[:, 0][None, :]
    hi = bounds[:, 1][None, :]
    ok = jnp.logical_and(cols >= lo, cols <= hi)
    out_ref[...] = jnp.all(ok, axis=1).astype(jnp.int8)


def bitmap_filter(cols: jnp.ndarray, bounds: jnp.ndarray,
                  interpret: bool = True) -> jnp.ndarray:
    """cols: (n, c) fp32; bounds: (c, 2) -> (n,) int8 mask."""
    n, c = cols.shape
    assert n % BLOCK_N == 0, n
    grid = (n // BLOCK_N,)
    return pl.pallas_call(
        _bitmap_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, c), lambda i: (i, 0)),
            pl.BlockSpec((c, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int8),
        interpret=interpret,
    )(cols, bounds)
