"""Jit'd wrappers over the Pallas kernels with numpy-friendly padding.

Every op has two backends selected by ``use_pallas``:
  * pallas  — the TPU-target kernels, executed in interpret mode on CPU
              (correctness path; sweeps validated against ref.py);
  * ref     — jnp oracles from ref.py, jit-compiled (fast on CPU).

The host-side ARCADE engine calls these for all per-segment compute:
distance scans, PQ ADC, predicate bitmaps, top-k merges.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bitmap_filter as bf_kernel
from repro.kernels import fused_scan as fs_kernel
from repro.kernels import graph_search as gs_kernel
from repro.kernels import ivf_scan as ivf_kernel
from repro.kernels import pq_adc as pq_kernel
from repro.kernels import quantized_scan as qs_kernel
from repro.kernels import ref
from repro.kernels import topk_merge as tk_kernel
from repro.obs import REGISTRY

# global backend switch (tests flip it); env override for benchmarks
USE_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"


# ---------------------------------------------------------------------------
# dispatch accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KernelStats:
    """Per-THREAD dispatch counters (monotonic; consumers diff
    ``stats_snapshot()`` values around a region of interest).  Thread-
    local so a background flush/compaction worker's index-build kernel
    dispatches are never attributed to the query thread it races.

    launches       — op dispatches.  The host numpy fast path under
                     ``HOST_FLOP_CUTOFF`` counts too: at production scale
                     the cutoff vanishes and every dispatch is a device
                     launch, so ratios stay machine-independent.
    bytes_to_host  — bytes of results handed back to the host engine
                     (device->host traffic when a device backend is
                     active).  Operand upload is not counted.
    shape_misses   — first sighting of a (op, bucketed shape) pair, i.e.
                     jit compile-cache misses caused by ``_bucket``-padded
                     ragged inputs (the shape-cache itself is process-
                     wide, like jax's jit cache).
    """
    launches: int = 0
    bytes_to_host: int = 0
    shape_misses: int = 0
    # high-water marks already published to the metrics registry; the
    # per-dispatch mirror batches (see flush_registry_counters) so the
    # hot path pays an int compare instead of a Counter lock
    reg_launches: int = 0
    reg_bytes: int = 0
    reg_misses: int = 0


_tls = threading.local()
_seen_shapes: set = set()
# the jit shape cache is process-global while the counters are
# per-thread; guard membership+insert so concurrent first-seens from a
# query thread and the flush worker don't corrupt the set
_seen_lock = threading.Lock()


def thread_stats() -> KernelStats:
    """The calling thread's dispatch counters."""
    stats = getattr(_tls, "stats", None)
    if stats is None:
        stats = _tls.stats = KernelStats()
    return stats


def stats_snapshot() -> Tuple[int, int, int]:
    s = thread_stats()
    return (s.launches, s.bytes_to_host, s.shape_misses)


_reg_counters = None
_reg_generation = -1


def _registry_counters():
    """Process-wide mirrors of the per-thread counters in the metrics
    registry.  Object refs are cached (re-fetched only when
    ``REGISTRY.reset()`` bumps its generation), so the per-dispatch
    cost is an int compare plus three ``Counter.inc`` calls."""
    global _reg_counters, _reg_generation
    if _reg_counters is None or _reg_generation != REGISTRY.generation:
        _reg_generation = REGISTRY.generation
        _reg_counters = (REGISTRY.counter("kernels.launches"),
                         REGISTRY.counter("kernels.bytes_to_host"),
                         REGISTRY.counter("kernels.jit_shape_misses"))
    return _reg_counters


REG_FLUSH_EVERY = 64    # dispatches between registry-mirror flushes


def flush_registry_counters() -> None:
    """Publish the calling thread's pending dispatch deltas to the
    metrics registry.  Runs every ``REG_FLUSH_EVERY`` dispatches and at
    query-batch boundaries (``Executor._observe_query``), keeping the
    registry's Counter lock off the per-dispatch path."""
    s = thread_stats()
    launches, byts, misses = _registry_counters()
    if s.launches != s.reg_launches:
        launches.inc(s.launches - s.reg_launches)
        s.reg_launches = s.launches
    if s.bytes_to_host != s.reg_bytes:
        byts.inc(s.bytes_to_host - s.reg_bytes)
        s.reg_bytes = s.bytes_to_host
    if s.shape_misses != s.reg_misses:
        misses.inc(s.shape_misses - s.reg_misses)
        s.reg_misses = s.shape_misses


def _dispatched(out_bytes: int, tag: str = None, shape: Tuple = ()) -> None:
    """Record one op dispatch; with a ``tag`` also track the jit shape
    cache (host-path calls pass no tag — numpy has no shape cache)."""
    s = thread_stats()
    s.launches += 1
    s.bytes_to_host += int(out_bytes)
    if s.launches - s.reg_launches >= REG_FLUSH_EVERY:
        flush_registry_counters()
    if tag is not None:
        key = (tag,) + tuple(shape)
        with _seen_lock:
            fresh = key not in _seen_shapes
            if fresh:
                _seen_shapes.add(key)
        if fresh:
            s.shape_misses += 1


def _pad_to(x: np.ndarray, mult: int, axis: int, value=0.0) -> np.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=value)


def _bucket(n: int, floor: int = 128) -> int:
    """Round up to the next power-of-two bucket (>= floor): bounds the
    number of distinct jit shapes from ragged posting lists to O(log n)."""
    b = floor
    while b < n:
        b *= 2
    return b


def _pad_bucket(x: np.ndarray, axis: int, value=0.0,
                floor: int = 128) -> np.ndarray:
    n = x.shape[axis]
    b = _bucket(n, floor)
    if b == n:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, b - n)
    return np.pad(x, widths, constant_values=value)


def _pad_codes(codes: np.ndarray, block: int,
               bucket: bool = True) -> np.ndarray:
    """THE one place PQ code matrices get padded for device dispatch:
    int32 cast + row pad to a ``block`` multiple (code 0 in the pad rows
    — masked or sliced off by every consumer), optionally bucket-padded
    to a power of two.  Shared by both ``pq_adc_distances`` backends and
    the fused quantized scan so ``stats_snapshot()`` charges code-block
    padding identically whichever path ran."""
    cp = _pad_to(codes.astype(np.int32), block, 0)
    return _pad_bucket(cp, 0, floor=block) if bucket else cp


@functools.lru_cache(maxsize=None)
def _jit_ivf_ref():
    return jax.jit(ref.ivf_scan_ref)


@functools.lru_cache(maxsize=None)
def _jit_pq_ref():
    return jax.jit(ref.pq_adc_ref)


@functools.lru_cache(maxsize=None)
def _jit_bitmap_ref():
    return jax.jit(ref.bitmap_filter_ref)


# ---------------------------------------------------------------------------
# distance scans
# ---------------------------------------------------------------------------

# Below this many MACs the fixed device-dispatch cost dominates: run the
# op on the host (the TPU-production analog: tiny index probes stay on the
# host CPU; large posting scans go to the accelerator kernels).
HOST_FLOP_CUTOFF = 4_000_000


def _l2_host(q: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Host numpy squared-L2 with BATCH-SHAPE-INDEPENDENT rounding.

    The difference form ``((x - q)**2).sum(-1)`` computes every output
    element from exactly its own (q_i, x_j) pair — numpy pairwise-sums
    the d axis per element — so a row's distance is bitwise identical
    whatever it is batched with.  The BLAS-backed ``qn - 2 q@x.T + xn``
    expansion does NOT have this property: gemm picks differently-rounded
    micro-kernels by operand shape and row position (size-1 operands hit
    a gemv/dot path; larger shapes still disagree at blocking edges), so
    the same row scored in two batch layouts could differ by ~1 ulp.
    That invariant is what makes fused-vs-staged, NRA-refinement-vs-scan
    and sharded-vs-single results bitwise comparable — and the
    difference form also never goes negative (no cancellation).  Only
    used below HOST_FLOP_CUTOFF, so the (nq, n, d) temporary is bounded
    at ~16 MB."""
    diff = x[None, :, :] - q[:, None, :]
    return (diff * diff).sum(axis=-1)


def l2_distances(q: np.ndarray, x: np.ndarray,
                 use_pallas: bool = None) -> np.ndarray:
    """Squared L2: q (nq, d), x (n, d) -> (nq, n) fp32."""
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    q = np.asarray(q, np.float32)
    x = np.asarray(x, np.float32)
    if len(x) == 0:
        return np.zeros((len(q), 0), np.float32)
    if not use_pallas and q.shape[0] * x.shape[0] * x.shape[1] \
            < HOST_FLOP_CUTOFF:
        out = _l2_host(q, x)
        _dispatched(out.nbytes)
        return out
    if use_pallas:
        qp = _pad_to(q, ivf_kernel.BLOCK_Q, 0)
        xp = _pad_bucket(_pad_to(x, ivf_kernel.BLOCK_N, 0, value=1e30),
                         0, value=1e30, floor=ivf_kernel.BLOCK_N)
        out = np.asarray(ivf_kernel.ivf_scan(jnp.asarray(qp),
                                             jnp.asarray(xp)))
        _dispatched(out.nbytes, "ivf_scan.pallas", qp.shape + xp.shape)
        return out[:len(q), :len(x)]
    qp = _pad_bucket(q, 0, floor=8)
    xp = _pad_bucket(x, 0)
    out = np.asarray(_jit_ivf_ref()(jnp.asarray(qp), jnp.asarray(xp)))
    _dispatched(out.nbytes, "ivf_scan.ref", qp.shape + xp.shape)
    return out[:len(q), :len(x)]


def assign_nearest(x: np.ndarray, centroids: np.ndarray,
                   chunk: int = 16384) -> np.ndarray:
    """argmin over centroids per row (chunked for memory)."""
    out = np.empty(len(x), np.int64)
    for i in range(0, len(x), chunk):
        d = l2_distances(x[i:i + chunk], centroids)
        out[i:i + chunk] = np.argmin(d, axis=1)
    return out


def block_topk(q: np.ndarray, vecs: np.ndarray, k: int,
               use_pallas: bool = None) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k nearest of q among vecs -> (dists sorted, indices)."""
    d = l2_distances(q[None, :], vecs, use_pallas=use_pallas)[0]
    k = min(k, len(d))
    idx = np.argpartition(d, k - 1)[:k]
    # (score, row) comparator: ties break by row index, deterministic
    # regardless of argpartition's arbitrary intra-tie order
    order = np.lexsort((idx, d[idx]))
    return d[idx][order], idx[order]


# ---------------------------------------------------------------------------
# PQ ADC
# ---------------------------------------------------------------------------

def pq_adc_distances(q: np.ndarray, codes: np.ndarray,
                     codebooks: np.ndarray,
                     use_pallas: bool = None) -> np.ndarray:
    """q (d,); codes (n, m) uint8; codebooks (m, 256, dsub) -> (n,) fp32."""
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    m, n_codes, dsub = codebooks.shape
    qs = q.reshape(m, dsub)
    # LUT: distance from q's subvector to every codeword
    lut = ((codebooks - qs[:, None, :]) ** 2).sum(axis=2)   # (m, 256)
    if len(codes) == 0:
        return np.zeros((0,), np.float32)
    if not use_pallas and codes.size < HOST_FLOP_CUTOFF:
        out = np.take_along_axis(
            lut.T, codes.astype(np.int64), axis=0).sum(axis=1) \
            .astype(np.float32)
        _dispatched(out.nbytes)
        return out
    cp = _pad_codes(codes, pq_kernel.BLOCK_N)
    if use_pallas:
        out = np.asarray(pq_kernel.pq_adc(jnp.asarray(cp),
                                          jnp.asarray(lut, jnp.float32)))
        _dispatched(out.nbytes, "pq_adc.pallas", cp.shape)
        return out[:len(codes)]
    out = np.asarray(_jit_pq_ref()(jnp.asarray(cp),
                                   jnp.asarray(lut, jnp.float32)))
    _dispatched(out.nbytes, "pq_adc.ref", cp.shape)
    return out[:len(codes)]


# ---------------------------------------------------------------------------
# predicate bitmaps
# ---------------------------------------------------------------------------

def range_bitmap(cols: np.ndarray, bounds: np.ndarray,
                 use_pallas: bool = None) -> np.ndarray:
    """cols (n, c) fp32; bounds (c, 2) -> (n,) bool (AND of range preds)."""
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    cols = np.asarray(cols, np.float32)
    bounds = np.asarray(bounds, np.float32)
    if len(cols) == 0:
        return np.zeros((0,), bool)
    if not use_pallas and cols.size < HOST_FLOP_CUTOFF:
        out = np.all((cols >= bounds[:, 0][None])
                     & (cols <= bounds[:, 1][None]), axis=1)
        _dispatched(out.nbytes)
        return out
    if use_pallas:
        cp = _pad_bucket(_pad_to(cols, bf_kernel.BLOCK_N, 0, value=np.inf),
                         0, value=np.inf, floor=bf_kernel.BLOCK_N)
        out = np.asarray(bf_kernel.bitmap_filter(jnp.asarray(cp),
                                                 jnp.asarray(bounds)))
        _dispatched(out.nbytes, "bitmap.pallas", cp.shape)
        return out[:len(cols)].astype(bool)
    cp = _pad_bucket(cols, 0, value=np.inf)
    out = np.asarray(_jit_bitmap_ref()(jnp.asarray(cp),
                                       jnp.asarray(bounds)))
    _dispatched(out.nbytes, "bitmap.ref", cp.shape)
    return out[:len(cols)]


def rect_filter(points: np.ndarray, rect,
                use_pallas: bool = None) -> np.ndarray:
    """points (n, 2); rect (xmin, ymin, xmax, ymax) -> (n,) bool."""
    r = np.asarray(rect, np.float32)
    bounds = np.stack([[r[0], r[2]], [r[1], r[3]]])       # (2, 2)
    return range_bitmap(np.asarray(points, np.float32), bounds,
                        use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# fused masked scan -> top-k (packed cross-segment path)
# ---------------------------------------------------------------------------

def fused_scan_topk(q: np.ndarray, x: np.ndarray, mask: np.ndarray,
                    pks: np.ndarray, k: int,
                    use_pallas: bool = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Fused filter-aware scan -> per-query top-k over a packed matrix.

    q (nq, d) queries; x (n, d) packed vectors (all visible segments
    concatenated); mask (nq, n) bool predicate bitmap; pks (n,) primary
    keys (< 2^31: the device tie-break key).  Returns (d2 (nq, k) fp32
    squared-L2 ascending, rows (nq, k) int64 row indices into ``x``; -1
    marks slots beyond the query's candidate count).  Ties break by
    (distance, pk) — the host merge's lexsort comparator.  The
    non-pallas backend SIMULATES the fused kernel: it reproduces the
    staged path's distance arithmetic at this size (numpy expansion
    below ``HOST_FLOP_CUTOFF``, the jit'd scan above) and the host
    merge's (sqrt-distance, pk) comparator exactly, so fused and staged
    results are bitwise equal backend-for-backend; the Pallas kernel
    compares squared distances (a monotone transform — same rows except
    where f32 sqrt rounds two distinct squared distances together).

    ONE dispatch for the whole batch, whatever the segment or predicate
    count.  Host-side prep: rows are tiled into BLOCK_N blocks; blocks
    masked out for EVERY query (zone-map/bitmap holes) are compacted away
    before upload, and the kept-block count is bucket-padded to a power
    of two so ragged stores hit O(log n) jit shapes.  A per-(query-tile,
    block) occupancy grid lets the kernel skip tiles that survive
    compaction but are empty for this query tile.
    """
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    q = np.asarray(q, np.float32)
    x = np.asarray(x, np.float32)
    mask = np.asarray(mask, bool)
    nq = len(q)
    k = int(min(k, fs_kernel.KMAX))
    empty = (np.full((nq, k), np.inf, np.float32),
             np.full((nq, k), -1, np.int64))
    if len(x) == 0 or k == 0 or not mask.any():
        return empty
    if not use_pallas:
        # simulated fused kernel: ONE counted dispatch, with the exact
        # arithmetic the staged path uses at this size (numpy expansion
        # below the FLOP cutoff, the same jit'd scan kernel above it)
        # and the host merge's (score, pk) comparator — so fused and
        # staged return bitwise-equal results on matching backends
        if q.shape[0] * x.shape[0] * x.shape[1] < HOST_FLOP_CUTOFF:
            d2 = _l2_host(q, x)
            shape_tag = None
        else:
            qp = _pad_bucket(q, 0, floor=8)
            xp = _pad_bucket(x, 0)
            d2 = np.asarray(_jit_ivf_ref()(jnp.asarray(qp),
                                           jnp.asarray(xp)))[:nq, :len(x)]
            shape_tag = qp.shape + xp.shape
        s = np.where(mask, np.sqrt(np.maximum(d2, 0),
                                   dtype=np.float32), np.inf)
        pks64 = np.asarray(pks, np.int64)
        out_d = np.full((nq, k), np.inf, np.float32)
        out_r = np.full((nq, k), -1, np.int64)
        for qi in range(nq):
            order = np.lexsort((pks64, s[qi]))[:k]
            order = order[np.isfinite(s[qi][order])]
            out_d[qi, :len(order)] = d2[qi][order]
            out_r[qi, :len(order)] = order
        _dispatched(out_d.nbytes + out_r.nbytes,
                    None if shape_tag is None else "fused_scan.ref",
                    shape_tag or ())
        return out_d, out_r
    BQ, BN = fs_kernel.BLOCK_Q, fs_kernel.BLOCK_N
    # pad rows to a block multiple (mask=0 => padding is never selected)
    xp = _pad_to(x, BN, 0)
    mp = _pad_to(mask.astype(np.uint8), BN, 1)
    pkp = _pad_to(np.asarray(pks, np.int64), BN, 0,
                  value=int(fs_kernel.SENTINEL))
    nb = len(xp) // BN
    # host-side occupancy prefix: drop blocks no query can touch
    keep = np.nonzero(mp.reshape(nq, nb, BN).any(axis=(0, 2)))[0]
    if len(keep) == 0:
        return empty
    nb_pad = _bucket(len(keep), floor=1)       # blocks, not rows
    xk = np.zeros((nb_pad * BN, x.shape[1]), np.float32)
    mk = np.zeros((nq, nb_pad * BN), np.uint8)
    pkk = np.full((nb_pad * BN,), int(fs_kernel.SENTINEL), np.int64)
    xk[:len(keep) * BN] = xp.reshape(nb, BN, -1)[keep].reshape(-1,
                                                               x.shape[1])
    mk[:, :len(keep) * BN] = \
        mp.reshape(nq, nb, BN)[:, keep].reshape(nq, -1)
    pkk[:len(keep) * BN] = pkp.reshape(nb, BN)[keep].reshape(-1)
    qp = _pad_to(q, BQ, 0)
    mkq = _pad_to(mk, BQ, 0)
    occ = mkq.reshape(len(qp) // BQ, BQ, nb_pad, BN) \
        .any(axis=(1, 3)).astype(np.int32)
    pk32 = pkk.astype(np.int32)[None, :]
    d2, _, idx = fs_kernel.fused_scan_topk(
        jnp.asarray(qp), jnp.asarray(xk), jnp.asarray(mkq),
        jnp.asarray(pk32), jnp.asarray(occ))
    d2 = np.asarray(d2)
    idx = np.asarray(idx)
    _dispatched(d2.nbytes + 2 * idx.nbytes, "fused_scan.pallas",
                qp.shape + xk.shape)
    d2, idx = d2[:nq, :k], idx[:nq, :k]
    # map packed block-compacted indices back to rows of the caller's x
    safe = np.minimum(idx, len(keep) * BN - 1)
    rows = keep[safe // BN] * BN + safe % BN
    rows = np.where(idx == int(fs_kernel.SENTINEL), -1, rows)
    return d2, rows.astype(np.int64)


# ---------------------------------------------------------------------------
# graph beam search -> top-beam (candidate generation)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jit_graph_ref(beam: int, hops: int):
    return jax.jit(functools.partial(ref.graph_search_topk_ref,
                                     beam=beam, hops=hops))


def _graph_host(q, x, nbr, ent, mask, pks64, beam, hops):
    """Host numpy beam search: same hop/dedup/comparator structure as the
    kernel, per query.  Candidate COVERAGE can differ from the device
    paths by float ulps at the beam margin; the operator layer's exact
    re-rank normalizes scores either way."""
    nq, n = len(q), len(x)
    out_d = np.full((nq, beam), np.inf, np.float32)
    out_r = np.full((nq, beam), -1, np.int64)
    gathered = np.zeros(nq, np.int64)
    for qi in range(nq):
        qv = q[qi]
        visited = np.zeros(n, bool)
        visited[ent] = True
        diff = x[ent] - qv
        bd = (diff * diff).sum(axis=1).astype(np.float32)
        bi = ent.copy()
        adm = mask[qi][bi]
        res_d, res_i = [bd[adm]], [bi[adm]]
        order = np.lexsort((bi, pks64[bi], bd))[:beam]
        bd, bi = bd[order], bi[order]
        for _ in range(hops):
            cand = nbr[bi].ravel()
            cand = np.unique(cand[cand >= 0])
            cand = cand[~visited[cand]]
            if not len(cand):
                break
            visited[cand] = True
            diff = x[cand] - qv
            cd = (diff * diff).sum(axis=1).astype(np.float32)
            adm = mask[qi][cand]
            res_d.append(cd[adm])
            res_i.append(cand[adm])
            md = np.concatenate([bd, cd])
            mi = np.concatenate([bi, cand])
            order = np.lexsort((mi, pks64[mi], md))[:beam]
            bd, bi = md[order], mi[order]
        gathered[qi] = int(visited.sum())
        rd = np.concatenate(res_d)
        ri = np.concatenate(res_i)
        order = np.lexsort((ri, pks64[ri], rd))[:beam]
        out_d[qi, :len(order)] = rd[order]
        out_r[qi, :len(order)] = ri[order]
    _dispatched(out_d.nbytes + out_r.nbytes)
    return out_d, out_r, gathered


def graph_search_topk(q: np.ndarray, x: np.ndarray, neighbors: np.ndarray,
                      entries: np.ndarray, mask: np.ndarray,
                      pks: np.ndarray, beam: int, hops: int,
                      use_pallas: bool = None
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Graph-index candidate generation over a packed CSR superbatch.

    q (nq, d) queries; x (n, d) packed vectors; neighbors (n, R) int32
    CSR adjacency in packed row space (-1 out-degree padding); entries
    (e,) int32 seed rows (the per-segment medoids); mask (nq, n) bool
    predicate bitmap; pks (n,) primary keys.  Returns (d2 (nq, beam)
    fp32 squared-L2 ascending, rows (nq, beam) int64 packed row ids, -1
    beyond a query's candidate count, gathered (nq,) int64 count of rows
    whose vectors the walk touched — the sub-linear-access statistic).
    Ties break by (distance, pk) like every scan kernel.

    Distances are exact but coverage is approximate: callers re-rank the
    survivors through ``fused_scan_topk`` with the survivor mask, so the
    final (score, pk) results match the exact dispatch bit-for-bit
    whenever the beam covered the true top-k.

    Host-side prep pads rows to a bucketed BLOCK_N multiple (padding
    rows are unreachable: their adjacency is all -1 and no real row
    points at them) and queries to BLOCK_Q tiles.
    """
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    q = np.asarray(q, np.float32)
    x = np.asarray(x, np.float32)
    nbr = np.asarray(neighbors, np.int32)
    mask = np.asarray(mask, bool)
    pks64 = np.asarray(pks, np.int64).ravel()
    nq, n = len(q), len(x)
    beam = int(min(beam, fs_kernel.KMAX))
    hops = int(hops)
    empty = (np.full((nq, beam), np.inf, np.float32),
             np.full((nq, beam), -1, np.int64),
             np.zeros(nq, np.int64))
    ent = np.asarray(entries, np.int64).ravel()
    ent = ent[(ent >= 0) & (ent < n)]
    if n == 0 or beam == 0 or len(ent) == 0 or not mask.any():
        return empty
    work = nq * (hops * beam * nbr.shape[1] + len(ent)) * x.shape[1]
    if not use_pallas and work < HOST_FLOP_CUTOFF:
        return _graph_host(q, x, nbr, ent, mask, pks64, beam, hops)
    BQ, BN = fs_kernel.BLOCK_Q, fs_kernel.BLOCK_N
    sent = int(fs_kernel.SENTINEL)
    xp = _pad_bucket(_pad_to(x, BN, 0), 0, floor=BN)
    npad = len(xp)
    nbp = np.full((npad, nbr.shape[1]), -1, np.int32)
    nbp[:n] = nbr
    mp = np.zeros((nq, npad), np.uint8)
    mp[:, :n] = mask
    pkp = np.full(npad, sent, np.int64)
    pkp[:n] = pks64
    ep = np.full((1, _bucket(len(ent), floor=8)), sent, np.int32)
    ep[0, :len(ent)] = ent
    qp = _pad_to(q, BQ, 0)
    mq = _pad_to(mp, BQ, 0)
    pk32 = pkp.astype(np.int32)[None, :]
    if use_pallas:
        d2, _, ids, vis = gs_kernel.graph_search_topk(
            jnp.asarray(qp), jnp.asarray(xp), jnp.asarray(nbp),
            jnp.asarray(ep), jnp.asarray(mq), jnp.asarray(pk32),
            beam, hops)
        tag = "graph_search.pallas"
    else:
        d2, _, ids, vis = _jit_graph_ref(beam, hops)(
            jnp.asarray(qp), jnp.asarray(xp), jnp.asarray(nbp),
            jnp.asarray(ep), jnp.asarray(mq), jnp.asarray(pk32))
        tag = "graph_search.ref"
    d2 = np.asarray(d2)[:nq]
    ids = np.asarray(ids)[:nq]
    vis = np.asarray(vis)[:nq]
    _dispatched(d2.nbytes + ids.nbytes + vis.nbytes, tag,
                qp.shape + xp.shape + (beam, hops))
    rows = np.where(ids == sent, -1, ids).astype(np.int64)
    gathered = np.unpackbits(
        vis.view(np.uint8), axis=1).sum(axis=1).astype(np.int64)
    return d2, rows, gathered


# ---------------------------------------------------------------------------
# fused quantized (PQ ADC) scan -> top-k' (candidate generation)
# ---------------------------------------------------------------------------

def adc_lut(q: np.ndarray, codebooks: np.ndarray) -> np.ndarray:
    """Per-query ADC tables: q (nq, d); codebooks (m, 256, dsub) ->
    (nq, m, 256) fp32 with lut[q, j, c] = ||q_sub_j - codebook_j[c]||^2.
    Computed once per launch on the host (nq*m*256 floats — tiny next to
    the code matrix the device streams)."""
    nq, d = q.shape
    m, _, dsub = codebooks.shape
    qs = np.asarray(q, np.float32).reshape(nq, m, dsub)
    diff = codebooks[None, :, :, :] - qs[:, :, None, :]
    return (diff * diff).sum(axis=3).astype(np.float32)


def quantized_scan_topk(q: np.ndarray, codes: np.ndarray,
                        codebooks: np.ndarray, mask: np.ndarray,
                        pks: np.ndarray, k: int,
                        use_pallas: bool = None
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Fused PQ-ADC candidate generation over a packed code matrix.

    q (nq, d) queries; codes (n, m) uint8 packed PQ codes (row-aligned
    with the fp32 superbatch); codebooks (m, 256, dsub) shared books;
    mask (nq, n) bool; pks (n,) primary keys.  Returns (adc (nq, k) fp32
    ADC distances ascending, rows (nq, k) int64 row indices into the
    packed matrix; -1 beyond a query's candidate count).  Ties break by
    (adc, pk) so survivor sets are deterministic.

    ADC distances are approximate: callers re-rank the survivors exactly
    via ``fused_scan_topk`` with the survivor mask — which reproduces the
    exact path's per-row arithmetic bit-for-bit in both backends, so the
    final (score, pk) results match the exact dispatch whenever the
    survivors cover the true top-k.

    Host-side prep mirrors ``fused_scan_topk`` exactly (pad -> keep-block
    compaction -> power-of-two bucket -> occupancy grid), just over the
    uint8 code matrix instead of the fp32 column: the device streams
    m bytes/row instead of 4*d.
    """
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    q = np.asarray(q, np.float32)
    mask = np.asarray(mask, bool)
    nq = len(q)
    k = int(min(k, fs_kernel.KMAX))
    empty = (np.full((nq, k), np.inf, np.float32),
             np.full((nq, k), -1, np.int64))
    if len(codes) == 0 or k == 0 or not mask.any():
        return empty
    lut = adc_lut(q, codebooks)                     # (nq, m, 256)
    m = codes.shape[1]
    if not use_pallas:
        # simulated fused ADC kernel: ONE counted dispatch; same gather
        # arithmetic and (adc, pk) comparator as the device kernel
        adc = np.zeros((nq, len(codes)), np.float32)
        codes64 = codes.astype(np.int64)
        for j in range(m):
            adc += lut[:, j, :][:, codes64[:, j]]
        s = np.where(mask, adc, np.inf)
        pks64 = np.asarray(pks, np.int64)
        out_d = np.full((nq, k), np.inf, np.float32)
        out_r = np.full((nq, k), -1, np.int64)
        for qi in range(nq):
            order = np.lexsort((pks64, s[qi]))[:k]
            order = order[np.isfinite(s[qi][order])]
            out_d[qi, :len(order)] = s[qi][order]
            out_r[qi, :len(order)] = order
        _dispatched(out_d.nbytes + out_r.nbytes)
        return out_d, out_r
    BQ, BN = fs_kernel.BLOCK_Q, fs_kernel.BLOCK_N
    cp = _pad_codes(codes, BN, bucket=False)
    mp = _pad_to(mask.astype(np.uint8), BN, 1)
    pkp = _pad_to(np.asarray(pks, np.int64), BN, 0,
                  value=int(fs_kernel.SENTINEL))
    nb = len(cp) // BN
    keep = np.nonzero(mp.reshape(nq, nb, BN).any(axis=(0, 2)))[0]
    if len(keep) == 0:
        return empty
    nb_pad = _bucket(len(keep), floor=1)
    ck = np.zeros((nb_pad * BN, m), np.int32)
    mk = np.zeros((nq, nb_pad * BN), np.uint8)
    pkk = np.full((nb_pad * BN,), int(fs_kernel.SENTINEL), np.int64)
    ck[:len(keep) * BN] = cp.reshape(nb, BN, m)[keep].reshape(-1, m)
    mk[:, :len(keep) * BN] = \
        mp.reshape(nq, nb, BN)[:, keep].reshape(nq, -1)
    pkk[:len(keep) * BN] = pkp.reshape(nb, BN)[keep].reshape(-1)
    lutf = _pad_to(lut.reshape(nq, m * 256), BQ, 0)
    mkq = _pad_to(mk, BQ, 0)
    occ = mkq.reshape(len(lutf) // BQ, BQ, nb_pad, BN) \
        .any(axis=(1, 3)).astype(np.int32)
    pk32 = pkk.astype(np.int32)[None, :]
    adc, _, idx = qs_kernel.quantized_scan_topk(
        jnp.asarray(lutf), jnp.asarray(ck), jnp.asarray(mkq),
        jnp.asarray(pk32), jnp.asarray(occ))
    adc = np.asarray(adc)
    idx = np.asarray(idx)
    _dispatched(adc.nbytes + 2 * idx.nbytes, "quantized_scan.pallas",
                lutf.shape + ck.shape)
    adc, idx = adc[:nq, :k], idx[:nq, :k]
    safe = np.minimum(idx, len(keep) * BN - 1)
    rows = keep[safe // BN] * BN + safe % BN
    rows = np.where(idx == int(fs_kernel.SENTINEL), -1, rows)
    return adc, rows.astype(np.int64)


# ---------------------------------------------------------------------------
# top-k merge
# ---------------------------------------------------------------------------

def merge_topk_batch(scores: np.ndarray, ids: np.ndarray, k: int,
                     use_pallas: bool = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Cross-shard top-k merge for a query batch (the sharded read path's
    combine step — kernels/topk_merge.py ``batched_topk_merge``).

    scores (nq, s, kk) fp32 and ids (nq, s, kk) int64 hold each query's s
    per-shard candidate lists; empty slots carry score=+inf (their id is
    ignored).  Returns ((nq, k) fp32, (nq, k) int64) in ascending
    (score, id) order — the host merge's ``lexsort((pk, score))``
    comparator — with id=-1 marking slots beyond a query's candidate
    count.  The device merge tie-breaks in int32 registers (the same
    bound the fused scan's pk registers impose); ids outside [0, 2^31-1)
    automatically fall back to the exact host merge instead of
    truncating.  ONE dispatch for the whole batch; only the (nq, k)
    winners return to the host, never the (nq, s*kk) candidate tensor."""
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    scores = np.asarray(scores, np.float32)
    ids64 = np.asarray(ids, np.int64)
    nq, s, kk = scores.shape
    k = int(min(k, s * kk))
    out_d = np.full((nq, k), np.inf, np.float32)
    out_i = np.full((nq, k), -1, np.int64)
    if k == 0 or nq == 0:
        return out_d, out_i
    if use_pallas:
        sentinel = np.iinfo(np.int32).max
        real = ids64[np.isfinite(scores)]
        if len(real) and (int(real.min()) < 0
                          or int(real.max()) >= sentinel):
            # the device tie-break key is int32; ids outside its range
            # would truncate silently — take the exact host merge instead
            use_pallas = False
    if use_pallas:
        idp = np.where(np.isfinite(scores), ids64, sentinel).astype(np.int32)
        d, i = tk_kernel.batched_topk_merge(jnp.asarray(scores),
                                            jnp.asarray(idp), k)
        d = np.asarray(d)
        i = np.asarray(i, np.int64)
        _dispatched(d.nbytes + i.nbytes, "topk_merge_batch.pallas",
                    scores.shape + (k,))
        return d, np.where(np.isfinite(d), i, -1)
    flat_d = scores.reshape(nq, -1)
    flat_i = ids64.reshape(nq, -1)
    for qi in range(nq):
        order = np.lexsort((flat_i[qi], flat_d[qi]))[:k]
        order = order[np.isfinite(flat_d[qi][order])]
        out_d[qi, :len(order)] = flat_d[qi][order]
        out_i[qi, :len(order)] = flat_i[qi][order]
    _dispatched(out_d.nbytes + out_i.nbytes, "topk_merge_batch.ref",
                scores.shape + (k,))
    return out_d, out_i


def merge_topk(dists: np.ndarray, ids: np.ndarray, k: int,
               use_pallas: bool = None) -> Tuple[np.ndarray, np.ndarray]:
    """Merge S per-segment top-k lists (s, kk) -> global (k,)."""
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    dists = np.asarray(dists, np.float32)
    ids = np.asarray(ids, np.int64)
    k = min(k, dists.size)
    if k == 0:
        return np.zeros((0,), np.float32), np.zeros((0,), np.int64)
    if use_pallas:
        d, i = tk_kernel.topk_merge(jnp.asarray(dists), jnp.asarray(ids), k)
        _dispatched(d.nbytes + i.nbytes, "topk_merge.pallas",
                    dists.shape + (k,))
        return np.asarray(d), np.asarray(i)
    d, i = ref.topk_merge_ref(jnp.asarray(dists), jnp.asarray(ids), k)
    _dispatched(d.nbytes + i.nbytes, "topk_merge.ref", dists.shape + (k,))
    return np.asarray(d), np.asarray(i)
