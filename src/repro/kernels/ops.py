"""Jit'd wrappers over the Pallas kernels with numpy-friendly padding.

Every op has two backends selected by ``use_pallas``:
  * pallas  — the TPU-target kernels, executed in interpret mode on CPU
              (correctness path; sweeps validated against ref.py);
  * ref     — jnp oracles from ref.py, jit-compiled (fast on CPU).

The host-side ARCADE engine calls these for all per-segment compute:
distance scans, PQ ADC, predicate bitmaps, top-k merges.
"""
from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bitmap_filter as bf_kernel
from repro.kernels import ivf_scan as ivf_kernel
from repro.kernels import pq_adc as pq_kernel
from repro.kernels import ref
from repro.kernels import topk_merge as tk_kernel

# global backend switch (tests flip it); env override for benchmarks
USE_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def _pad_to(x: np.ndarray, mult: int, axis: int, value=0.0) -> np.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=value)


def _bucket(n: int, floor: int = 128) -> int:
    """Round up to the next power-of-two bucket (>= floor): bounds the
    number of distinct jit shapes from ragged posting lists to O(log n)."""
    b = floor
    while b < n:
        b *= 2
    return b


def _pad_bucket(x: np.ndarray, axis: int, value=0.0,
                floor: int = 128) -> np.ndarray:
    n = x.shape[axis]
    b = _bucket(n, floor)
    if b == n:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, b - n)
    return np.pad(x, widths, constant_values=value)


@functools.lru_cache(maxsize=None)
def _jit_ivf_ref():
    return jax.jit(ref.ivf_scan_ref)


@functools.lru_cache(maxsize=None)
def _jit_pq_ref():
    return jax.jit(ref.pq_adc_ref)


@functools.lru_cache(maxsize=None)
def _jit_bitmap_ref():
    return jax.jit(ref.bitmap_filter_ref)


# ---------------------------------------------------------------------------
# distance scans
# ---------------------------------------------------------------------------

# Below this many MACs the fixed device-dispatch cost dominates: run the
# op on the host (the TPU-production analog: tiny index probes stay on the
# host CPU; large posting scans go to the accelerator kernels).
HOST_FLOP_CUTOFF = 4_000_000


def l2_distances(q: np.ndarray, x: np.ndarray,
                 use_pallas: bool = None) -> np.ndarray:
    """Squared L2: q (nq, d), x (n, d) -> (nq, n) fp32."""
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    q = np.asarray(q, np.float32)
    x = np.asarray(x, np.float32)
    if len(x) == 0:
        return np.zeros((len(q), 0), np.float32)
    if not use_pallas and q.shape[0] * x.shape[0] * x.shape[1] \
            < HOST_FLOP_CUTOFF:
        qn = (q * q).sum(1)[:, None]
        xn = (x * x).sum(1)[None, :]
        return qn - 2.0 * (q @ x.T) + xn
    if use_pallas:
        qp = _pad_to(q, ivf_kernel.BLOCK_Q, 0)
        xp = _pad_bucket(_pad_to(x, ivf_kernel.BLOCK_N, 0, value=1e30),
                         0, value=1e30, floor=ivf_kernel.BLOCK_N)
        out = np.asarray(ivf_kernel.ivf_scan(jnp.asarray(qp),
                                             jnp.asarray(xp)))
        return out[:len(q), :len(x)]
    qp = _pad_bucket(q, 0, floor=8)
    xp = _pad_bucket(x, 0)
    out = np.asarray(_jit_ivf_ref()(jnp.asarray(qp), jnp.asarray(xp)))
    return out[:len(q), :len(x)]


def assign_nearest(x: np.ndarray, centroids: np.ndarray,
                   chunk: int = 16384) -> np.ndarray:
    """argmin over centroids per row (chunked for memory)."""
    out = np.empty(len(x), np.int64)
    for i in range(0, len(x), chunk):
        d = l2_distances(x[i:i + chunk], centroids)
        out[i:i + chunk] = np.argmin(d, axis=1)
    return out


def block_topk(q: np.ndarray, vecs: np.ndarray, k: int,
               use_pallas: bool = None) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k nearest of q among vecs -> (dists sorted, indices)."""
    d = l2_distances(q[None, :], vecs, use_pallas=use_pallas)[0]
    k = min(k, len(d))
    idx = np.argpartition(d, k - 1)[:k]
    order = np.argsort(d[idx], kind="stable")
    return d[idx][order], idx[order]


# ---------------------------------------------------------------------------
# PQ ADC
# ---------------------------------------------------------------------------

def pq_adc_distances(q: np.ndarray, codes: np.ndarray,
                     codebooks: np.ndarray,
                     use_pallas: bool = None) -> np.ndarray:
    """q (d,); codes (n, m) uint8; codebooks (m, 256, dsub) -> (n,) fp32."""
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    m, n_codes, dsub = codebooks.shape
    qs = q.reshape(m, dsub)
    # LUT: distance from q's subvector to every codeword
    lut = ((codebooks - qs[:, None, :]) ** 2).sum(axis=2)   # (m, 256)
    if len(codes) == 0:
        return np.zeros((0,), np.float32)
    if not use_pallas and codes.size < HOST_FLOP_CUTOFF:
        return np.take_along_axis(
            lut.T, codes.astype(np.int64), axis=0).sum(axis=1) \
            .astype(np.float32)
    if use_pallas:
        cp = _pad_bucket(_pad_to(codes.astype(np.int32),
                                 pq_kernel.BLOCK_N, 0), 0,
                         floor=pq_kernel.BLOCK_N)
        out = np.asarray(pq_kernel.pq_adc(jnp.asarray(cp),
                                          jnp.asarray(lut, jnp.float32)))
        return out[:len(codes)]
    cp = _pad_bucket(codes.astype(np.int32), 0)
    out = np.asarray(_jit_pq_ref()(jnp.asarray(cp),
                                   jnp.asarray(lut, jnp.float32)))
    return out[:len(codes)]


# ---------------------------------------------------------------------------
# predicate bitmaps
# ---------------------------------------------------------------------------

def range_bitmap(cols: np.ndarray, bounds: np.ndarray,
                 use_pallas: bool = None) -> np.ndarray:
    """cols (n, c) fp32; bounds (c, 2) -> (n,) bool (AND of range preds)."""
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    cols = np.asarray(cols, np.float32)
    bounds = np.asarray(bounds, np.float32)
    if len(cols) == 0:
        return np.zeros((0,), bool)
    if not use_pallas and cols.size < HOST_FLOP_CUTOFF:
        return np.all((cols >= bounds[:, 0][None])
                      & (cols <= bounds[:, 1][None]), axis=1)
    if use_pallas:
        cp = _pad_bucket(_pad_to(cols, bf_kernel.BLOCK_N, 0, value=np.inf),
                         0, value=np.inf, floor=bf_kernel.BLOCK_N)
        out = np.asarray(bf_kernel.bitmap_filter(jnp.asarray(cp),
                                                 jnp.asarray(bounds)))
        return out[:len(cols)].astype(bool)
    cp = _pad_bucket(cols, 0, value=np.inf)
    out = np.asarray(_jit_bitmap_ref()(jnp.asarray(cp),
                                       jnp.asarray(bounds)))
    return out[:len(cols)]


def rect_filter(points: np.ndarray, rect,
                use_pallas: bool = None) -> np.ndarray:
    """points (n, 2); rect (xmin, ymin, xmax, ymax) -> (n,) bool."""
    r = np.asarray(rect, np.float32)
    bounds = np.stack([[r[0], r[2]], [r[1], r[3]]])       # (2, 2)
    return range_bitmap(np.asarray(points, np.float32), bounds,
                        use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# top-k merge
# ---------------------------------------------------------------------------

def merge_topk(dists: np.ndarray, ids: np.ndarray, k: int,
               use_pallas: bool = None) -> Tuple[np.ndarray, np.ndarray]:
    """Merge S per-segment top-k lists (s, kk) -> global (k,)."""
    use_pallas = USE_PALLAS if use_pallas is None else use_pallas
    dists = np.asarray(dists, np.float32)
    ids = np.asarray(ids, np.int64)
    k = min(k, dists.size)
    if k == 0:
        return np.zeros((0,), np.float32), np.zeros((0,), np.int64)
    if use_pallas:
        d, i = tk_kernel.topk_merge(jnp.asarray(dists), jnp.asarray(ids), k)
        return np.asarray(d), np.asarray(i)
    d, i = ref.topk_merge_ref(jnp.asarray(dists), jnp.asarray(ids), k)
    return np.asarray(d), np.asarray(i)
