"""Pallas TPU kernel: merge per-segment top-k lists into a global top-k.

ARCADE's "top-level merging iterator" (paper §4) combines per-SST results;
on TPU the scatter-gather query path merges S per-shard top-k lists with
this kernel: iterative masked-argmin selection over the flattened
(S*K,) candidates held in VMEM — k passes of a VPU reduction, no host
heap. k is small (<= a few hundred), so k * S * K ops stay negligible
next to the distance scans.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_merge_kernel(d_ref, i_ref, out_d_ref, out_i_ref, *, k: int):
    d = d_ref[...].reshape(-1).astype(jnp.float32)
    ids = i_ref[...].reshape(-1)

    def body(j, carry):
        d_work, od, oi = carry
        pos = jnp.argmin(d_work)
        od = od.at[j].set(d_work[pos])
        oi = oi.at[j].set(ids[pos])
        d_work = d_work.at[pos].set(jnp.inf)
        return d_work, od, oi

    od0 = jnp.full((k,), jnp.inf, jnp.float32)
    oi0 = jnp.zeros((k,), ids.dtype)
    _, od, oi = jax.lax.fori_loop(0, k, body, (d, od0, oi0))
    out_d_ref[...] = od
    out_i_ref[...] = oi


def topk_merge(dists: jnp.ndarray, ids: jnp.ndarray, k: int,
               interpret: bool = True):
    """dists/ids: (s, kk) -> ((k,), (k,)) globally smallest."""
    s, kk = dists.shape
    kern = functools.partial(_topk_merge_kernel, k=k)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((s, kk), lambda i: (0, 0)),
            pl.BlockSpec((s, kk), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), ids.dtype),
        ],
        interpret=interpret,
    )(dists, ids)
