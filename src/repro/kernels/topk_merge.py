"""Pallas TPU kernel: merge per-segment top-k lists into a global top-k.

ARCADE's "top-level merging iterator" (paper §4) combines per-SST results;
on TPU the scatter-gather query path merges S per-shard top-k lists with
this kernel: iterative masked-argmin selection over the flattened
(S*K,) candidates held in VMEM — k passes of a VPU reduction, no host
heap. k is small (<= a few hundred), so k * S * K ops stay negligible
next to the distance scans.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_merge_kernel(d_ref, i_ref, out_d_ref, out_i_ref, *, k: int):
    d = d_ref[...].reshape(-1).astype(jnp.float32)
    ids = i_ref[...].reshape(-1)

    def body(j, carry):
        d_work, od, oi = carry
        pos = jnp.argmin(d_work)
        od = od.at[j].set(d_work[pos])
        oi = oi.at[j].set(ids[pos])
        d_work = d_work.at[pos].set(jnp.inf)
        return d_work, od, oi

    od0 = jnp.full((k,), jnp.inf, jnp.float32)
    oi0 = jnp.zeros((k,), ids.dtype)
    _, od, oi = jax.lax.fori_loop(0, k, body, (d, od0, oi0))
    out_d_ref[...] = od
    out_i_ref[...] = oi


def topk_merge(dists: jnp.ndarray, ids: jnp.ndarray, k: int,
               interpret: bool = True):
    """dists/ids: (s, kk) -> ((k,), (k,)) globally smallest."""
    s, kk = dists.shape
    kern = functools.partial(_topk_merge_kernel, k=k)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((s, kk), lambda i: (0, 0)),
            pl.BlockSpec((s, kk), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), ids.dtype),
        ],
        interpret=interpret,
    )(dists, ids)


# ---------------------------------------------------------------------------
# generalized cross-shard merge: batched over queries, (score, pk) order
# ---------------------------------------------------------------------------

def _batched_merge_kernel(d_ref, i_ref, out_d_ref, out_i_ref, *, k: int):
    """One query tile: (1, s, kk) candidates -> (1, k) winners selected in
    ascending (score, id) lexicographic order — ties on score break toward
    the smaller id, matching the host merge's ``np.lexsort((pk, score))``
    comparator exactly.  Consumed and padded slots both carry id=SENTINEL
    and score=+inf, so they are only emitted once every real candidate is
    exhausted (the wrapper maps them back to "empty")."""
    d = d_ref[...].reshape(-1).astype(jnp.float32)
    ids = i_ref[...].reshape(-1)
    sentinel = jnp.iinfo(ids.dtype).max

    def body(j, carry):
        d_work, i_work, od, oi = carry
        dmin = jnp.min(d_work)
        tie = d_work == dmin
        sel = jnp.min(jnp.where(tie, i_work, sentinel))
        pos = jnp.argmax(tie & (i_work == sel))
        od = od.at[j].set(d_work[pos])
        oi = oi.at[j].set(i_work[pos])
        d_work = d_work.at[pos].set(jnp.inf)
        i_work = i_work.at[pos].set(sentinel)
        return d_work, i_work, od, oi

    od0 = jnp.full((k,), jnp.inf, jnp.float32)
    oi0 = jnp.full((k,), sentinel, ids.dtype)
    _, _, od, oi = jax.lax.fori_loop(0, k, body, (d, ids, od0, oi0))
    out_d_ref[...] = od.reshape(1, k)
    out_i_ref[...] = oi.reshape(1, k)


def batched_topk_merge(dists: jnp.ndarray, ids: jnp.ndarray, k: int,
                       interpret: bool = True):
    """Cross-shard top-k merge for a whole query batch.

    dists (nq, s, kk) fp32, ids (nq, s, kk) int32 -> ((nq, k), (nq, k)):
    per query the k smallest candidates across all s shard lists, ordered
    by (score, id).  Pad empty slots with score=+inf and id=INT32_MAX —
    padded output slots come back as (+inf, INT32_MAX).  The grid is one
    program per query so shard counts and k stay tiny VMEM residents."""
    nq, s, kk = dists.shape
    kern = functools.partial(_batched_merge_kernel, k=k)
    return pl.pallas_call(
        kern,
        grid=(nq,),
        in_specs=[
            pl.BlockSpec((1, s, kk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, kk), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), ids.dtype),
        ],
        interpret=interpret,
    )(dists, ids)
