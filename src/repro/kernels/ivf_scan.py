"""Pallas TPU kernel: fused batched squared-L2 distance scan over posting
blocks — the compute hot-spot of ARCADE's vector IVF query path (paper §4:
"selectively accessing posting lists ... retrieving records via block
handles").

TPU mapping: the posting list is tiled into (BLOCK_N, d) VMEM blocks; the
query tile (BLOCK_Q, d) stays resident. Distances use the MXU via the
expansion ||q - v||^2 = ||q||^2 - 2 q.v + ||v||^2 where q.v is a
(BLOCK_Q, d) x (d, BLOCK_N) matmul; norms ride the VPU. Accumulation is
fp32. Dims are padded to (8, 128) multiples by the ops.py wrapper so MXU
tiles are hardware-aligned.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 8          # query rows per tile (sublane-aligned)
BLOCK_N = 512        # posting vectors per tile (lane-aligned, fits VMEM)


def _ivf_scan_kernel(q_ref, v_ref, out_ref):
    """q_ref: (BLOCK_Q, d); v_ref: (BLOCK_N, d); out: (BLOCK_Q, BLOCK_N)."""
    q = q_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    qn = jnp.sum(q * q, axis=1, keepdims=True)           # (BQ, 1)
    vn = jnp.sum(v * v, axis=1)[None, :]                 # (1, BN)
    # MXU matmul: (BQ, d) x (d, BN)
    dots = jax.lax.dot_general(
        q, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[...] = qn - 2.0 * dots + vn


def ivf_scan(q: jnp.ndarray, vecs: jnp.ndarray,
             interpret: bool = True) -> jnp.ndarray:
    """q: (nq, d); vecs: (n, d) — both padded to tile multiples by ops.py.
    Returns (nq, n) fp32 squared-L2 distances."""
    nq, d = q.shape
    n, _ = vecs.shape
    assert nq % BLOCK_Q == 0 and n % BLOCK_N == 0, (nq, n)
    grid = (nq // BLOCK_Q, n // BLOCK_N)
    return pl.pallas_call(
        _ivf_scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_Q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_N, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_Q, BLOCK_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, n), jnp.float32),
        interpret=interpret,
    )(q, vecs)
