"""Pallas TPU kernel: fused PQ-ADC scan → per-query running top-k'.

Quantized sibling of ``fused_scan.py`` — same grid, same occupancy-grid
skip, same revisited-output-block running top-k, same (distance, pk)
tie-break sort — but the posting tile streams the uint8 PQ code matrix
(m bytes/row) instead of the fp32 column (4*d bytes/row), so candidate
generation reads ~16-32x fewer bytes at typical (d=128, m=8):

  * the host computes one ADC LUT per query ONCE per launch —
    ``lut[q, j, c] = || q_sub_j - codebook_j[c] ||^2`` flattened to
    (nq, m*256) so a query tile's LUT rows ride in as a single block
    resident across the inner dimension;
  * inside the tile, per-subquantizer distances come from the one-hot
    matmul trick (``pq_adc.py``): expanding codes to a (BLOCK_N, 256)
    one-hot and contracting against the query-tile LUT slice puts the
    gather on the MXU — (BLOCK_Q, 256) x (256, BLOCK_N) per j;
  * the predicate bitmap masks in-kernel and the running top-k' merges
    via one ``lax.sort`` over KMAX + BLOCK_N lanes, keys (adc, pk), so
    survivor sets are deterministic under ties.

ADC distances are approximations: callers keep k' = refine*k survivors
and re-rank them EXACTLY against the fp32 column through the ordinary
fused scan (``ops.fused_scan_topk``), which restores the committed
(score, pk) comparator bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_scan import BLOCK_N, BLOCK_Q, KMAX, SENTINEL


def _quantized_scan_topk_kernel(occ_ref, lut_ref, codes_ref, mask_ref,
                                pk_ref, out_d_ref, out_p_ref, out_i_ref):
    """One (query-tile, posting-block) grid step.

    occ_ref:   (1, 1) SMEM — 0 when every lane of this tile is masked
    lut_ref:   (BLOCK_Q, m*256) fp32 per-query ADC LUTs (resident)
    codes_ref: (BLOCK_N, m) int32 PQ codes
    mask_ref:  (BLOCK_Q, BLOCK_N) uint8 predicate bitmap
    pk_ref:    (1, BLOCK_N) int32 primary keys (tie-break sort key)
    out_*:     (BLOCK_Q, KMAX) running top-k' accumulator
    """
    j = pl.program_id(1)
    m = codes_ref.shape[1]

    @pl.when(j == 0)
    def _init():
        out_d_ref[...] = jnp.full((BLOCK_Q, KMAX), jnp.inf, jnp.float32)
        out_p_ref[...] = jnp.full((BLOCK_Q, KMAX), SENTINEL, jnp.int32)
        out_i_ref[...] = jnp.full((BLOCK_Q, KMAX), SENTINEL, jnp.int32)

    @pl.when(occ_ref[0, 0] != 0)
    def _scan_and_merge():
        codes = codes_ref[...]
        acc = jnp.zeros((BLOCK_Q, BLOCK_N), jnp.float32)
        # static unroll over subquantizers: one one-hot MXU contraction
        # per j sums lut[q, j, codes[i, j]] into the (BQ, BN) tile
        for sub in range(m):
            lutj = lut_ref[:, sub * 256:(sub + 1) * 256]      # (BQ, 256)
            onehot = (codes[:, sub][:, None] ==
                      jax.lax.broadcasted_iota(jnp.int32, (1, 256), 1))
            acc = acc + jax.lax.dot_general(
                lutj, onehot.astype(jnp.float32),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
        mask = mask_ref[...] != 0
        d = jnp.where(mask, acc, jnp.inf)
        ids = j * BLOCK_N + jax.lax.broadcasted_iota(
            jnp.int32, (BLOCK_Q, BLOCK_N), 1)
        ids = jnp.where(mask, ids, SENTINEL)
        pks = jnp.where(mask, pk_ref[...], SENTINEL)
        cat_d = jnp.concatenate([out_d_ref[...], d], axis=1)
        cat_p = jnp.concatenate([out_p_ref[...], pks], axis=1)
        cat_i = jnp.concatenate([out_i_ref[...], ids], axis=1)
        sd, sp, si = jax.lax.sort((cat_d, cat_p, cat_i), dimension=1,
                                  num_keys=2)
        out_d_ref[...] = sd[:, :KMAX]
        out_p_ref[...] = sp[:, :KMAX]
        out_i_ref[...] = si[:, :KMAX]


def quantized_scan_topk(lut: jnp.ndarray, codes: jnp.ndarray,
                        mask: jnp.ndarray, pks: jnp.ndarray,
                        occ: jnp.ndarray, interpret: bool = True):
    """lut (nq, m*256) fp32; codes (n, m) int32; mask (nq, n) uint8;
    pks (1, n) int32; occ (nq/BLOCK_Q, n/BLOCK_N) int32.  All padded to
    tile multiples by ``ops.quantized_scan_topk``.  Returns ((nq, KMAX)
    fp32 ADC distances sorted ascending, (nq, KMAX) int32 pks, (nq, KMAX)
    int32 packed row ids); empty slots hold (+inf, SENTINEL, SENTINEL)."""
    nq, lut_w = lut.shape
    n, m = codes.shape
    assert lut_w == m * 256, (lut_w, m)
    assert nq % BLOCK_Q == 0 and n % BLOCK_N == 0, (nq, n)
    grid = (nq // BLOCK_Q, n // BLOCK_N)
    return pl.pallas_call(
        _quantized_scan_topk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((BLOCK_Q, lut_w), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_N, m), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_Q, BLOCK_N), lambda i, j: (i, j)),
            pl.BlockSpec((1, BLOCK_N), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_Q, KMAX), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_Q, KMAX), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_Q, KMAX), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, KMAX), jnp.float32),
            jax.ShapeDtypeStruct((nq, KMAX), jnp.int32),
            jax.ShapeDtypeStruct((nq, KMAX), jnp.int32),
        ],
        interpret=interpret,
    )(occ, lut, codes, mask, pks)
