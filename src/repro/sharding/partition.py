"""Logical-axis partitioning: maps logical axis names to mesh axes.

Models annotate parameters and activations with *logical* axes
("embed", "heads", "ff", "experts", "batch", "seq", ...). A rule set maps
each logical axis to a mesh axis (or None = replicated). ``axis_rules`` is
a context manager installing (mesh, rules); ``constrain`` applies
``with_sharding_constraint`` when inside a context and is a no-op outside,
so model code runs unmodified on a single CPU device in tests.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# Default production rules (training / prefill).
#   pod+data together form the FSDP/data axis; model is the TP/EP axis.
RULES_TRAIN: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "embed": ("pod", "data"),       # FSDP: big-matrix width dim sharded on data
    "heads": "model",
    "heads_flat": "model",
    "kv": "model",
    "ff": "model",
    "experts": "model",             # activation expert dim: EP on model
    "experts_w": "model",           # weight expert dim
    "vocab": "model",
    "seq": None,
    "attn_seq": None,               # per-arch: "model" when heads % TP != 0
    "res_seq": None,                # layer-carry storage: "model" seq-shards
    #                                 the remat residual stack (Perf A3)
    "kv_seq": None,
    "norm": None,
    "layers": None,
}
RULES_2D = RULES_TRAIN  # alias

# decode (serving): params replicated across data (no FSDP gather per token),
# KV cache sequence dim sharded on model; MoE expert weights sharded over
# (data, model) so 671B fits without FSDP.
RULES_DECODE: Dict[str, Any] = dict(
    RULES_TRAIN,
    embed=None,
    kv_seq="model",
    # expert weights AND expert activations both (data, model)-sharded:
    # mismatched specs would make GSPMD gather a 16x expert-weight slice
    # per step (measured 175 GiB temp on dsv3 decode before this fix)
    experts_w=("data", "model"),
    experts=("data", "model"),
)

# long-context decode (batch=1): shard the KV/state sequence dim over every
# axis (context parallelism for a 524288-deep cache).
RULES_LONG_CONTEXT = dict(
    RULES_DECODE,
    batch=None,
    kv_seq=("pod", "data", "model"),
)


def rules_for(kind: str, num_heads: int = 0, tp: int = 16) -> Dict[str, Any]:
    """Pick the rule set for a shape kind, with the per-arch attention
    fallback: when q heads don't divide the TP width, shard attention
    activations on the sequence dim instead (DESIGN.md §6)."""
    base = {"train": RULES_TRAIN, "prefill": RULES_TRAIN,
            "decode": RULES_DECODE, "long": RULES_LONG_CONTEXT}[kind]
    rules = dict(base)
    if num_heads and num_heads % tp != 0:
        rules["attn_seq"] = "model"
        if kind in ("decode", "long"):
            # decode params are not FSDP-sharded (embed=None), so heads-
            # indivisible archs would replicate all attention weights;
            # shard their contraction dim on model instead (row-parallel,
            # one psum per projection — fine at decode batch sizes)
            rules["embed"] = "model"
    return rules


def _mesh_axes_for(logical: Optional[str], mesh: Mesh, rules: Mapping) -> Any:
    if logical is None:
        return None
    rule = rules.get(logical, None)
    if rule is None:
        return None
    if isinstance(rule, tuple):
        present = tuple(a for a in rule if a in mesh.axis_names)
        if not present:
            return None
        return present if len(present) > 1 else present[0]
    return rule if rule in mesh.axis_names else None


def spec_for(axes: Sequence[Optional[str]], mesh: Mesh,
             rules: Mapping) -> P:
    """Logical axes tuple -> PartitionSpec, dropping shardings that do not
    divide evenly is left to the caller (see ``safe_spec``)."""
    return P(*[_mesh_axes_for(a, mesh, rules) for a in axes])


def _axis_size(entry: Any, mesh: Mesh) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def safe_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
              mesh: Mesh, rules: Mapping) -> P:
    """spec_for, but drops any dim whose size does not divide the mesh
    extent (e.g. batch=1 on a 16-way data axis) and any mesh axis already
    consumed by an earlier dim (e.g. kv_seq and kv both wanting "model")."""
    entries = []
    used = set()
    for dim, a in zip(shape, axes):
        entry = _mesh_axes_for(a, mesh, rules)
        if entry is not None:
            names = entry if isinstance(entry, tuple) else (entry,)
            if any(n in used for n in names):
                names = tuple(n for n in names if n not in used)
                entry = names if len(names) > 1 else (names[0] if names else None)
        if entry is not None and dim % _axis_size(entry, mesh) != 0:
            entry = None
        if entry is not None:
            used.update(entry if isinstance(entry, tuple) else (entry,))
        entries.append(entry)
    return P(*entries)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[Mapping] = None):
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, dict(rules or RULES_2D))
    try:
        yield
    finally:
        _state.ctx = prev


def current_context():
    return getattr(_state, "ctx", None)


def constrain(x: jnp.ndarray, axes: Sequence[Optional[str]]) -> jnp.ndarray:
    """Apply a sharding constraint if inside an ``axis_rules`` context."""
    ctx = current_context()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = safe_spec(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_spec(params_axes, mesh: Mesh, rules: Optional[Mapping] = None,
              shapes=None):
    """Map an axes-pytree (tuples of logical names) to PartitionSpecs.

    ``shapes``: optional matching pytree of shapes for divisibility checks.
    """
    rules = dict(rules or RULES_2D)
    if shapes is None:
        return jax.tree.map(
            lambda a: spec_for(a, mesh, rules), params_axes,
            is_leaf=lambda a: isinstance(a, tuple))
    return jax.tree.map(
        lambda a, s: safe_spec(getattr(s, "shape", s), a, mesh, rules),
        params_axes, shapes,
        is_leaf=lambda a: isinstance(a, tuple))


def tree_sharding(params_axes, mesh: Mesh, rules=None, shapes=None):
    specs = tree_spec(params_axes, mesh, rules, shapes)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
