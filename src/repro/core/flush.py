"""Pipelined flush/compaction scheduling (paper §3-§4: secondary indexes
are maintained at flush/compaction time — never on the write critical
path).

The ``FlushScheduler`` decouples ingest from segment building: puts land
in the *active* memtable; when it reaches the flush threshold it is
*sealed* (an O(1) pointer swap) and queued.  Sealed memtables stay fully
readable (``LSMStore.memtable_arrays`` concatenates sealed + active) until
a worker turns them into level-0 segments and size-tiered compaction runs
— so index construction cost never blocks a ``put``.

Three operating modes:

  inline       (pipeline=False, default) — every write drains the queue
               synchronously; behavior is identical to the classic
               flush-on-put LSM write path (what the tests exercise).
  pipelined    (pipeline=True) — work queues up; tests/drivers call
               ``drain()`` deterministically.  Backpressure: when more
               than ``max_sealed`` memtables are waiting, the writer
               self-drains one work unit per put (a *write stall*,
               counted in ``metrics['stalls']``).
  background   (pipeline=True, background=True) — a daemon worker thread
               drains the queue; the writer blocks on the stall condition
               instead of self-draining.  Benchmark-oriented: concurrent
               reads during background flushing are not synchronized.
"""
from __future__ import annotations

import threading
from typing import List, Optional

from repro.core.faults import InjectedCrash
from repro.obs import REGISTRY


class FlushScheduler:
    def __init__(self, store):
        self.store = store
        cfg = store.cfg
        self.pipeline = bool(cfg.pipeline)
        self.max_sealed = max(1, int(cfg.max_sealed))
        self._cv: Optional[threading.Condition] = None
        self._worker: Optional[threading.Thread] = None
        self._stop = False
        self._busy = False           # worker is mid-step outside the lock
        if self.pipeline and cfg.background:
            self._cv = threading.Condition()
            self._worker = threading.Thread(
                target=self._run_worker, name="lsm-flush", daemon=True)
            self._worker.start()

    # ----------------------------------------------------------- write side
    def on_write(self) -> None:
        """Called by the store after every put/delete lands in the active
        memtable: seal at threshold, then either drain inline (classic
        mode) or apply backpressure (pipelined modes)."""
        store = self.store
        cfg = store.cfg
        mtab = store.memtable
        if len(mtab) >= cfg.flush_rows or (
                cfg.flush_bytes > 0
                and mtab.approx_bytes >= cfg.flush_bytes):
            store.seal()
        if not self.pipeline:
            self.drain()
            return
        if self._cv is not None:
            with self._cv:
                self._cv.notify_all()
                while len(store.sealed) > self.max_sealed:
                    store.metrics["stalls"] += 1
                    REGISTRY.inc("lsm.stalls")
                    self._cv.wait(timeout=0.05)
        else:
            # deterministic backpressure: the writer pays one unit of
            # background work per put while compaction debt is high
            while len(store.sealed) > self.max_sealed:
                store.metrics["stalls"] += 1
                REGISTRY.inc("lsm.stalls")
                if not self.step():
                    break

    # ------------------------------------------------------------ work queue
    def work_available(self) -> bool:
        return bool(self.store.sealed) or \
            self.store._compactable_level() is not None

    def step(self):
        """Process one unit of background work: flush the oldest sealed
        memtable, else merge one full tier.  Returns the new Segment for
        a flush, True for a compaction, False when idle."""
        store = self.store
        if store.sealed:
            return store._flush_sealed()
        level = store._compactable_level()
        if level is not None:
            store._compact_level(level)
            return True
        return False

    def drain(self) -> List:
        """Deterministically run the queue dry; returns the segments
        flushed by this call (in flush order)."""
        if self._cv is not None:
            # background mode: wake the worker and wait for quiescence —
            # including a step in flight (work_available() is briefly
            # false while the worker mutates the store outside the lock)
            with self._cv:
                self._cv.notify_all()
                while self.work_available() or self._busy:
                    self._cv.wait(timeout=0.05)
            return []
        segs = []
        while True:
            r = self.step()
            if r is False:
                return segs
            if r is not True:
                segs.append(r)

    # ------------------------------------------------------------ background
    def _run_worker(self) -> None:
        while True:
            with self._cv:
                while not self.work_available() and not self._stop:
                    self._cv.wait(timeout=0.05)
                if self._stop and not self.work_available():
                    return
                self._busy = True
            try:
                self.step()
            except InjectedCrash:
                # fault-injection harness: the simulated process died at
                # a crash point — the worker thread dies with it (a real
                # kill would take every thread), leaving the on-disk
                # state exactly as the crash left it
                with self._cv:
                    self._busy = False
                    self._cv.notify_all()
                return
            finally:
                with self._cv:
                    self._busy = False
                    self._cv.notify_all()

    def close(self) -> None:
        """Stop the background worker after finishing queued work."""
        if self._cv is None:
            return
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._worker.join(timeout=10.0)
