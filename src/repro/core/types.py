"""Schema and multimodal column types for the ARCADE store.

ARCADE supports relational (SCALAR), VECTOR (with declared dimension),
SPATIAL (2-d points), TEXT, and BLOB columns (paper §2.1). Row batches are
columnar dicts of numpy arrays (TEXT/BLOB as object arrays); the storage
layer is host-orchestrated, per-segment compute runs through the JAX/Pallas
kernels.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence

import numpy as np


class ColumnType(enum.Enum):
    SCALAR = "scalar"       # int/float relational attribute
    VECTOR = "vector"       # embedding, fixed dim
    SPATIAL = "spatial"     # 2-d point (x, y)
    TEXT = "text"           # tokenizable string
    BLOB = "blob"           # opaque bytes (images/videos); not indexed


class IndexKind(enum.Enum):
    NONE = "none"
    BTREE = "btree"         # sorted scalar secondary index
    IVF = "ivf"             # vector inverted-file index
    PQIVF = "pqivf"         # IVF with product quantization
    GRAPH = "graph"         # Vamana-style CSR proximity graph
    ZORDER = "zorder"       # spatial (local per-segment; 'hybrid' adds global)
    INVERTED = "inverted"   # text inverted index


@dataclasses.dataclass(frozen=True)
class Column:
    name: str
    ctype: ColumnType
    dim: int = 0                      # VECTOR_DIMENSION for vector columns
    index: IndexKind = IndexKind.NONE
    spatial_index_type: str = "hybrid"  # 'local' | 'hybrid' (paper §2.1)

    def __post_init__(self):
        if self.ctype == ColumnType.VECTOR and self.dim <= 0:
            raise ValueError(f"vector column {self.name} needs dim > 0")


@dataclasses.dataclass(frozen=True)
class Schema:
    columns: Sequence[Column]

    def __post_init__(self):
        names = [c.name for c in self.columns]
        if len(set(names)) != len(names):
            raise ValueError("duplicate column names")

    def col(self, name: str) -> Column:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(name)

    @property
    def indexed_columns(self) -> List[Column]:
        return [c for c in self.columns if c.index != IndexKind.NONE]


def validate_batch(schema: Schema, batch: Dict[str, np.ndarray],
                   n: Optional[int] = None) -> int:
    """Check a columnar batch against the schema; returns row count."""
    for c in schema.columns:
        if c.name not in batch:
            raise ValueError(f"missing column {c.name}")
        arr = batch[c.name]
        rows = len(arr)
        if n is None:
            n = rows
        elif rows != n:
            raise ValueError(f"column {c.name} has {rows} rows, want {n}")
        if c.ctype == ColumnType.VECTOR:
            arr = np.asarray(arr)
            if arr.ndim != 2 or arr.shape[1] != c.dim:
                raise ValueError(f"vector column {c.name}: shape {arr.shape}"
                                 f" want (*, {c.dim})")
        elif c.ctype == ColumnType.SPATIAL:
            arr = np.asarray(arr)
            if arr.ndim != 2 or arr.shape[1] != 2:
                raise ValueError(f"spatial column {c.name}: shape {arr.shape}")
    return int(n or 0)


BLOCK_ROWS = 128   # rows per block — the read unit (HBM->VMEM tile height)
