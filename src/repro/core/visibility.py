"""Shared vectorized MVCC visibility resolution (newest seqno per pk).

One implementation serves every read path: the filter executor, the NN
candidate finisher, and NRA's streaming candidate check all resolve
visibility against the same ``VisibilityIndex``.  The resolver is
``np.lexsort``-based: concatenate (pk, seqno, tombstone) across all
segments plus the memtable, order by (pk asc, seqno desc), and the first
row of every pk group is the winning version.  A segment row is visible
iff it is its pk's winner and that winner is neither a tombstone nor a
memtable entry (memtable rows are served by the memtable-overlay
operator, never by segment scans).

The index is O(total rows) to build and is cached on the store, keyed by
(write seqno, segment ids) so any put/delete/flush/compaction
invalidates it.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Tuple

import numpy as np


def _store_lock(store):
    """The store's lock, or a no-op context for lock-less store stand-ins
    (unit-test doubles).  LSMStore always carries ``_lock`` (re-entrant),
    so flush-time callers already inside the publish window re-enter."""
    return getattr(store, "_lock", None) or contextlib.nullcontext()


def memtable_visible(pk: np.ndarray, tomb: np.ndarray) -> np.ndarray:
    """Bool mask over memtable rows: newest version per pk, tombstones out.

    Rows are append-ordered, so within a pk group the last occurrence is
    the newest (seqnos increase with position).
    """
    n = len(pk)
    if n == 0:
        return np.zeros(0, bool)
    pk = np.asarray(pk, np.int64)
    # stable sort by pk keeps append order inside each group
    order = np.argsort(pk, kind="stable")
    spk = pk[order]
    last = np.ones(n, bool)
    last[:-1] = spk[1:] != spk[:-1]
    keep = np.zeros(n, bool)
    keep[order[last]] = True
    return keep & ~np.asarray(tomb, bool)


class VisibilityIndex:
    """Global winner set: for every pk in the store, which (seg, row) —
    if any — is the visible version."""

    def __init__(self, store):
        parts_pk, parts_seq, parts_sid, parts_row, parts_tomb = \
            [], [], [], [], []
        for seg in store.segments:
            if seg.n_rows == 0:
                continue
            parts_pk.append(np.asarray(seg.pk, np.int64))
            parts_seq.append(np.asarray(seg.seqno, np.int64))
            parts_sid.append(np.full(seg.n_rows, seg.seg_id, np.int64))
            parts_row.append(np.arange(seg.n_rows, dtype=np.int64))
            parts_tomb.append(np.asarray(seg.tombstone, bool))
        mt_pk, mt_seq, mt_tomb, _ = store.memtable_arrays()
        if len(mt_pk):
            parts_pk.append(mt_pk)
            parts_seq.append(mt_seq)
            parts_sid.append(np.full(len(mt_pk), -1, np.int64))
            parts_row.append(np.arange(len(mt_pk), dtype=np.int64))
            parts_tomb.append(mt_tomb)
        if not parts_pk:
            self._winners = np.zeros(0, np.int64)
            self._win_pk = np.zeros(0, np.int64)
            self._win_sid = np.zeros(0, np.int64)
            self._win_row = np.zeros(0, np.int64)
            return
        pk = np.concatenate(parts_pk)
        seqno = np.concatenate(parts_seq)
        sid = np.concatenate(parts_sid)
        row = np.concatenate(parts_row)
        tomb = np.concatenate(parts_tomb)
        # (pk asc, seqno desc): first row of each pk group is the winner
        order = np.lexsort((-seqno, pk))
        pk, sid, row, tomb = pk[order], sid[order], row[order], tomb[order]
        first = np.ones(len(pk), bool)
        first[1:] = pk[1:] != pk[:-1]
        # full winner set (pk-sorted), memtable winners included: the
        # point-lookup side (lookup_pks) must see memtable versions
        win = first & ~tomb
        self._win_pk = pk[win]
        self._win_sid = sid[win]
        self._win_row = row[win]
        seg_win = win & (sid >= 0)
        self._winners = np.sort(_encode(sid[seg_win], row[seg_win]))

    def extend_on_flush(self, seg, n_flushed: int) -> None:
        """Incremental update when the oldest ``n_flushed`` memtable rows
        become segment ``seg``: a flush moves versions without changing
        any pk's winner, so the winner set is *remapped* instead of
        rebuilt — memtable winners in the flushed prefix point at their
        new segment rows, remaining memtable winners shift down, and the
        new segment winners merge into the sorted membership array.
        O(winners) instead of O(total rows · log)."""
        inv = np.empty(n_flushed, np.int64)
        inv[seg.sort_order] = np.arange(n_flushed, dtype=np.int64)
        mt = self._win_sid == -1
        flushed = mt & (self._win_row < n_flushed)
        later = mt & ~flushed
        new_rows = inv[self._win_row[flushed]]
        self._win_sid[flushed] = seg.seg_id
        self._win_row[flushed] = new_rows
        self._win_row[later] -= n_flushed
        if len(new_rows):
            enc = _encode(np.full(len(new_rows), seg.seg_id, np.int64),
                          new_rows)
            self._winners = np.sort(
                np.concatenate([self._winners, enc]))

    def visible_mask(self, sids: np.ndarray, rows: np.ndarray) -> np.ndarray:
        """Vectorized membership test: is each (seg_id, row) the visible
        version of its pk?"""
        if len(self._winners) == 0:
            return np.zeros(len(sids), bool)
        enc = _encode(np.asarray(sids, np.int64), np.asarray(rows, np.int64))
        pos = np.searchsorted(self._winners, enc)
        pos = np.minimum(pos, len(self._winners) - 1)
        return self._winners[pos] == enc

    def lookup_pks(self, pks: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized point lookup: pk -> its winning (sid, row).

        Returns (sids, rows, found); ``sid == -1`` means the winner lives
        in the memtable, ``found == False`` means the pk has no visible
        version (absent or tombstoned).
        """
        pks = np.asarray(pks, np.int64)
        if len(self._win_pk) == 0:
            z = np.zeros(len(pks), np.int64)
            return z, z, np.zeros(len(pks), bool)
        pos = np.minimum(np.searchsorted(self._win_pk, pks),
                         len(self._win_pk) - 1)
        found = self._win_pk[pos] == pks
        return self._win_sid[pos], self._win_row[pos], found

    def resolve(self, per_segment_rows: Dict[int, np.ndarray]
                ) -> Dict[int, np.ndarray]:
        """{seg_id: row_indices} -> same shape, shadowed rows dropped."""
        out: Dict[int, np.ndarray] = {}
        for sid, rows in per_segment_rows.items():
            rows = np.asarray(rows, np.int64)
            keep = self.visible_mask(np.full(len(rows), sid, np.int64), rows)
            kept = np.sort(rows[keep])
            if len(kept):
                out[sid] = kept
        return out


def _encode(sids: np.ndarray, rows: np.ndarray) -> np.ndarray:
    return (sids << 32) | rows


def visibility_index(store) -> VisibilityIndex:
    """Cached VisibilityIndex for the store's current write state.

    Key computation, index build, and cache publish all happen under the
    store lock: the build walks ``store.segments`` and the memtable, and
    a background flush republishing mid-walk would hand back an index
    keyed for a state it was not built from."""
    with _store_lock(store):
        key = (store._seqno, tuple(s.seg_id for s in store.segments))
        cached = getattr(store, "_vis_cache", None)
        if cached is None or cached[0] != key:
            cached = (key, VisibilityIndex(store))
            store._vis_cache = cached
        return cached[1]


def extend_cache_on_flush(store, pre_key, seg, n_flushed: int) -> bool:
    """Flush-time cache maintenance: if the store's cached index matches
    the pre-flush state, remap it in place (``extend_on_flush``) and
    re-key it for the post-flush state instead of discarding it.  Returns
    whether the incremental path was taken."""
    with _store_lock(store):
        cached = getattr(store, "_vis_cache", None)
        if cached is None or cached[0] != pre_key or n_flushed == 0:
            return False
        vis = cached[1]
        vis.extend_on_flush(seg, n_flushed)
        new_key = (store._seqno, tuple(s.seg_id for s in store.segments))
        store._vis_cache = (new_key, vis)
        return True
