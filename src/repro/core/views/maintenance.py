"""Incremental view maintenance from write deltas (paper §6).

"To identify affected views, we check which selection predicates of views
cover the updated point. For spatial and vector filters, each view defines
a coverage region (e.g., hypersphere), stored in an in-memory spatial
index (e.g., kd-tree). Upon data updates, we query this index to locate
and update all relevant views efficiently."

Coverage index: uniform grid over view rects (spatial) + centroid table
(vector). Backfill on creation scans the current store once.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.views.view import SpatialRangeView, VectorNNView


class CoverageIndex:
    """Locates views affected by an inserted/deleted row."""

    def __init__(self, grid: int = 16):
        self.spatial: List[SpatialRangeView] = []
        self.vector: List[VectorNNView] = []
        self.grid = grid
        self._cells: Dict[tuple, List[int]] = {}
        self._bbox = None
        self._centers: Optional[np.ndarray] = None

    def rebuild(self, views) -> None:
        self.spatial = [v for v in views if isinstance(v, SpatialRangeView)]
        self.vector = [v for v in views if isinstance(v, VectorNNView)]
        self._cells = {}
        if self.spatial:
            xs0 = min(v.rect[0] for v in self.spatial)
            ys0 = min(v.rect[1] for v in self.spatial)
            xs1 = max(v.rect[2] for v in self.spatial)
            ys1 = max(v.rect[3] for v in self.spatial)
            self._bbox = (xs0, ys0, max(xs1, xs0 + 1e-9),
                          max(ys1, ys0 + 1e-9))
            for i, v in enumerate(self.spatial):
                for cell in self._cells_of(v.rect):
                    self._cells.setdefault(cell, []).append(i)
        self._centers = np.stack([v.center for v in self.vector]) \
            if self.vector else None

    def _cells_of(self, rect):
        x0, y0, x1, y1 = self._bbox
        g = self.grid
        cx0 = int((rect[0] - x0) / (x1 - x0) * g)
        cx1 = int((rect[2] - x0) / (x1 - x0) * g)
        cy0 = int((rect[1] - y0) / (y1 - y0) * g)
        cy1 = int((rect[3] - y0) / (y1 - y0) * g)
        for cx in range(max(0, cx0), min(g, cx1 + 1)):
            for cy in range(max(0, cy0), min(g, cy1 + 1)):
                yield (cx, cy)

    def spatial_views_for(self, xy) -> List[SpatialRangeView]:
        if not self.spatial or self._bbox is None:
            return []
        x0, y0, x1, y1 = self._bbox
        g = self.grid
        cx = int((float(xy[0]) - x0) / (x1 - x0) * g)
        cy = int((float(xy[1]) - y0) / (y1 - y0) * g)
        if not (0 <= cx < g and 0 <= cy < g):
            return [v for v in self.spatial if v.covers_point(xy)]
        out = []
        for i in self._cells.get((cx, cy), []):
            v = self.spatial[i]
            if v.covers_point(xy):
                out.append(v)
        return out

    def vector_views_for(self, vec) -> List[VectorNNView]:
        if self._centers is None:
            return []
        d2 = ((self._centers - np.asarray(vec)[None, :]) ** 2).sum(axis=1)
        out = []
        for i, v in enumerate(self.vector):
            r = v.coverage_radius()
            if d2[i] <= r * r:
                out.append(v)
        return out


class ViewMaintainer:
    """Wires the coverage index into the store's delta hook."""

    def __init__(self, store):
        self.store = store
        self.views: List = []
        self.coverage = CoverageIndex()
        self.deltas_applied = 0
        store.on_delta(self._on_delta)

    # ------------------------------------------------------------- admin
    def install(self, views: List) -> None:
        self.views = list(views)
        self.coverage.rebuild(self.views)
        self._backfill()

    def _backfill(self) -> None:
        """Populate new views from current store contents — one columnar
        pass per (source, view) pair, vectorized membership tests."""
        from repro.kernels import ops as kops
        mt_pk, _, mt_tomb, mt_cols = self.store.memtable_arrays()
        sources = [(seg.pk, seg.tombstone, seg.columns)
                   for seg in self.store.segments]
        sources.append((mt_pk, mt_tomb, mt_cols))
        for spk, stomb, scols in sources:
            live = ~np.asarray(stomb, bool)
            if not live.any():
                continue
            lpks = np.asarray(spk, np.int64)[live]
            for v in self.views:
                arr = scols.get(v.col)
                if arr is None:
                    continue
                vals = np.asarray(arr, np.float32)[live]
                if isinstance(v, SpatialRangeView):
                    inside = kops.rect_filter(vals, v.rect)
                    v.insert_many(lpks[inside], vals[inside])
                else:
                    v.insert_many(lpks, vals)

    # ------------------------------------------------------------- delta
    def _on_delta(self, pks, batch, deleted: bool) -> None:
        """Apply one columnar write delta ``(pks, batch, deleted)`` to all
        installed views — one vectorized membership test per view over the
        whole batch, never a per-row Python loop."""
        pks = np.asarray(pks, np.int64)
        if deleted:
            for v in self.views:
                v.remove_many(pks)
            self.deltas_applied += len(pks)
            return
        for v in self.coverage.spatial:
            pts = np.asarray(batch[v.col], np.float32)
            from repro.kernels import ops as kops
            inside = kops.rect_filter(pts, v.rect)
            v.insert_many(pks[inside], pts[inside])
        for v in self.coverage.vector:
            vecs = np.asarray(batch[v.col], np.float32)
            d2 = ((vecs - v.center[None, :]) ** 2).sum(axis=1)
            r = v.coverage_radius()
            m = d2 <= r * r
            # sqrt only for the admitted rows (the view stores euclid)
            v.insert_many(pks[m], vecs[m], np.sqrt(d2[m]))
        self.deltas_applied += len(pks)
