"""View selection: cluster registered queries, then knapsack under the
memory budget (paper §6: "Views are selected from registered continuous
queries using a knapsack-based strategy that balances reuse benefit and
storage overhead").

Spatial queries cluster by rect overlap (union-find on intersecting
rects -> one covering rect per cluster); vector queries cluster by k-means
on their query embeddings (one view per cluster center, sim_radius = max
member distance + slack). Benefit = expected block reads saved * queries
covered; cost = estimated view bytes. Greedy by benefit density — the
classic 1/2-approximation.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core import query as q
from repro.core.index.ivf import kmeans
from repro.core.views.view import SpatialRangeView, VectorNNView


@dataclasses.dataclass
class ViewCandidate:
    view: object
    benefit: float
    bytes_est: float
    members: int


def _rect_union(a, b):
    return (min(a[0], b[0]), min(a[1], b[1]),
            max(a[2], b[2]), max(a[3], b[3]))


def _rects_overlap(a, b) -> bool:
    return not (a[2] < b[0] or b[2] < a[0] or a[3] < b[1] or b[3] < a[1])


def cluster_spatial(rects: List[Tuple]) -> List[Tuple[Tuple, int]]:
    """Union-find on overlapping rects -> [(covering rect, n_members)]."""
    parent = list(range(len(rects)))

    def find(i):
        while parent[i] != i:
            parent[i] = parent[parent[i]]
            i = parent[i]
        return i

    for i in range(len(rects)):
        for j in range(i + 1, len(rects)):
            if _rects_overlap(rects[i], rects[j]):
                parent[find(i)] = find(j)
    groups = {}
    for i, r in enumerate(rects):
        root = find(i)
        if root in groups:
            groups[root] = (_rect_union(groups[root][0], r),
                            groups[root][1] + 1)
        else:
            groups[root] = (r, 1)
    return list(groups.values())


def cluster_vectors(qvecs: np.ndarray, max_clusters: int = 8
                    ) -> List[Tuple[np.ndarray, float, int]]:
    """k-means clusters -> [(center, radius, n_members)]."""
    if len(qvecs) == 0:
        return []
    k = min(max_clusters, len(qvecs))
    cents = kmeans(np.asarray(qvecs, np.float32), k, iters=6)
    d = np.sqrt(((qvecs[:, None, :] - cents[None, :, :]) ** 2).sum(-1))
    assign = d.argmin(axis=1)
    out = []
    for c in range(len(cents)):
        members = np.nonzero(assign == c)[0]
        if not len(members):
            continue
        radius = float(d[members, c].max())
        out.append((cents[c], radius * 1.2 + 1e-3, len(members)))
    return out


def build_candidates(store, queries: List[q.HybridQuery],
                     xk_factor: int = 8) -> List[ViewCandidate]:
    """One candidate view per query cluster."""
    spatial_rects, vector_qs, vec_col, sp_col = [], [], None, None
    ks = []
    for query in queries:
        # clustering hints only: every GeoWithin leaf counts, wherever it
        # sits in the expression tree (matching stays semantics-checked)
        for p in q.leaf_predicates(query.where):
            if isinstance(p, q.GeoWithin):
                spatial_rects.append(p.rect)
                sp_col = p.col
        for r in query.ranks:
            if isinstance(r, q.VectorRank):
                vector_qs.append(r.q)
                vec_col = r.col
                ks.append(query.k)
            elif isinstance(r, q.SpatialRank):
                pass
    cands: List[ViewCandidate] = []
    n_rows = max(store.n_rows, 1)
    total_blocks = sum(s.n_blocks for s in store.segments) or 1

    for rect, members in cluster_spatial(spatial_rects):
        # expected rows in view from area fraction (catalog-style estimate)
        frac = 0.05
        try:
            from repro.core.optimizer.stats import Catalog
            frac = Catalog(store).selectivity(q.GeoWithin(sp_col, rect))
        except Exception:
            pass
        rows = frac * n_rows
        view = SpatialRangeView(sp_col, rect)
        benefit = members * total_blocks * (1 - frac)
        cands.append(ViewCandidate(view, benefit, rows * 24 + 64, members))

    if vector_qs:
        k_avg = int(np.mean(ks)) if ks else 10
        dim = len(vector_qs[0])
        for center, radius, members in cluster_vectors(
                np.stack(vector_qs)):
            xk = k_avg * xk_factor
            view = VectorNNView(vec_col, center, xk, radius)
            benefit = members * total_blocks * 0.5
            cands.append(ViewCandidate(
                view, benefit, xk * (12 + 4 * dim) + 4 * dim, members))
    return cands


def knapsack_select(cands: List[ViewCandidate],
                    budget_bytes: float) -> List[ViewCandidate]:
    """Greedy by benefit/size density (1/2-approx for knapsack)."""
    chosen, used = [], 0.0
    for c in sorted(cands, key=lambda c: -(c.benefit / max(c.bytes_est, 1))):
        if used + c.bytes_est <= budget_bytes:
            chosen.append(c)
            used += c.bytes_est
    return chosen
