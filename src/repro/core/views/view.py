"""Incremental materialized views (paper §6).

Two view types, mirroring the paper's "Materialized View Selection":
  * SpatialRangeView — all rows inside a representative rect; shared by
    every query whose region is contained in it.
  * VectorNNView — top-(x*k) candidates around a representative query
    embedding, sorted by distance; queries with similar embeddings re-rank
    the materialized candidates at runtime to approximate their top-k.

Views hold (pk, key attrs, sort keys) — not full rows — and are maintained
incrementally from write deltas (maintenance.py).
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

_view_ids = itertools.count()


class SpatialRangeView:
    kind = "spatial_range"

    def __init__(self, col: str, rect: Tuple[float, float, float, float]):
        self.view_id = next(_view_ids)
        self.col = col
        self.rect = tuple(rect)
        self.rows: Dict[int, Tuple[float, float]] = {}   # pk -> point
        self.hits = 0

    # coverage -------------------------------------------------------------
    def covers_rect(self, rect) -> bool:
        return (self.rect[0] <= rect[0] and self.rect[1] <= rect[1]
                and self.rect[2] >= rect[2] and self.rect[3] >= rect[3])

    def covers_point(self, xy) -> bool:
        x, y = float(xy[0]), float(xy[1])
        return (self.rect[0] <= x <= self.rect[2]
                and self.rect[1] <= y <= self.rect[3])

    # maintenance ------------------------------------------------------------
    def insert(self, pk: int, xy) -> None:
        self.rows[int(pk)] = (float(xy[0]), float(xy[1]))

    def insert_many(self, pks: np.ndarray, xys: np.ndarray) -> None:
        """Columnar delta application: one C-level dict update."""
        if len(pks):
            self.rows.update(zip(np.asarray(pks, np.int64).tolist(),
                                 map(tuple, np.asarray(xys).tolist())))

    def remove(self, pk: int) -> None:
        self.rows.pop(int(pk), None)

    def remove_many(self, pks: np.ndarray) -> None:
        for pk in np.asarray(pks, np.int64).tolist():
            self.rows.pop(pk, None)

    # read --------------------------------------------------------------
    def pks_in(self, rect) -> List[int]:
        x0, y0, x1, y1 = rect
        return [pk for pk, (x, y) in self.rows.items()
                if x0 <= x <= x1 and y0 <= y <= y1]

    @property
    def size_bytes(self) -> int:
        return 24 * len(self.rows) + 64


class VectorNNView:
    kind = "vector_nn"

    def __init__(self, col: str, center: np.ndarray, xk: int,
                 sim_radius: float):
        self.view_id = next(_view_ids)
        self.col = col
        self.center = np.asarray(center, np.float32)
        self.xk = xk                      # materialize top-(x*k)
        self.sim_radius = float(sim_radius)  # query-match radius
        # sorted candidate list: (dist_to_center, pk, vector)
        self.cand: List[Tuple[float, int, np.ndarray]] = []
        self.hits = 0
        self._arrays_cache = None      # stacked (vecs, pks), read path

    # coverage ---------------------------------------------------------
    def matches_query(self, qvec: np.ndarray) -> bool:
        return float(np.linalg.norm(self.center - qvec)) <= self.sim_radius

    def coverage_radius(self) -> float:
        """A new point closer to center than the current worst candidate
        may belong in the view."""
        if len(self.cand) < self.xk:
            return float("inf")
        return self.cand[-1][0]

    # maintenance --------------------------------------------------------
    def insert(self, pk: int, vec: np.ndarray) -> None:
        d = float(np.linalg.norm(self.center - vec))
        if len(self.cand) >= self.xk and d >= self.cand[-1][0]:
            return
        import bisect
        keys = [c[0] for c in self.cand]
        i = bisect.bisect_left(keys, d)
        self.cand.insert(i, (d, int(pk), np.asarray(vec, np.float32)))
        if len(self.cand) > self.xk:
            self.cand.pop()
        self._arrays_cache = None

    def insert_many(self, pks: np.ndarray, vecs: np.ndarray,
                    dists: Optional[np.ndarray] = None) -> None:
        """Columnar delta application: merge a whole batch into the sorted
        candidate list with one argsort instead of per-row bisects."""
        if not len(pks):
            return
        vecs = np.asarray(vecs, np.float32)
        if dists is None:
            dists = np.sqrt(((vecs - self.center[None, :]) ** 2).sum(axis=1))
        # (score, pk) comparator keeps the candidate list's tie order
        # identical to the query-path ranking
        cut = np.lexsort((np.asarray(pks, np.int64), dists))
        if len(cut) > self.xk:
            cut = cut[:self.xk]
        new = [(float(dists[i]), int(pks[i]), vecs[i]) for i in cut]
        import heapq
        merged = list(heapq.merge(self.cand, new, key=lambda c: c[0]))
        self.cand = merged[:self.xk]
        self._arrays_cache = None

    def remove(self, pk: int) -> None:
        self.cand = [c for c in self.cand if c[1] != pk]
        self._arrays_cache = None

    def remove_many(self, pks: np.ndarray) -> None:
        gone = set(np.asarray(pks, np.int64).tolist())
        self.cand = [c for c in self.cand if c[1] not in gone]
        self._arrays_cache = None

    # read ----------------------------------------------------------------
    def _arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """Candidates as stacked arrays, cached between maintenance ops."""
        if self._arrays_cache is None:
            if self.cand:
                vecs = np.stack([c[2] for c in self.cand])
                pks = np.asarray([c[1] for c in self.cand], np.int64)
            else:
                vecs = np.zeros((0, len(self.center)), np.float32)
                pks = np.zeros(0, np.int64)
            self._arrays_cache = (vecs, pks)
        return self._arrays_cache

    def topk_for(self, qvec: np.ndarray, k: int) -> List[Tuple[float, int]]:
        """Re-rank materialized candidates for the actual query vector."""
        vecs, pks = self._arrays()
        if not len(pks):
            return []
        d = np.sqrt(((vecs - qvec[None, :]) ** 2).sum(axis=1))
        if k < len(d):
            idx = np.argpartition(d, k)[:k]
            idx = idx[np.lexsort((pks[idx], d[idx]))]
        else:
            idx = np.lexsort((pks, d))
        return [(float(d[i]), int(pks[i])) for i in idx]

    @property
    def size_bytes(self) -> int:
        dim = len(self.center)
        return len(self.cand) * (8 + 4 + 4 * dim) + 4 * dim + 64
