"""Query rewriting over materialized views (paper §6).

Continuous queries: views matched and *statically* bound at registration
(reused every execution). Snapshot queries: matched at runtime with
rule-based heuristics — region containment for spatial filters, embedding
similarity for vector ranks — and rewritten per execution (greedy: the
first/highest-hit matching view wins).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core import query as q
from repro.core.views.view import SpatialRangeView, VectorNNView


@dataclasses.dataclass
class Rewrite:
    """A bound (view, query-part) substitution."""
    spatial_view: Optional[SpatialRangeView] = None
    spatial_pred: Optional[q.GeoWithin] = None
    vector_view: Optional[VectorNNView] = None
    vector_rank: Optional[q.VectorRank] = None

    @property
    def any(self) -> bool:
        return self.spatial_view is not None or self.vector_view is not None


def match(views: List, query: q.HybridQuery) -> Rewrite:
    """Greedy rule-based matching (used at registration for continuous
    queries, at runtime for snapshot queries)."""
    rw = Rewrite()
    for p in query.filters:
        if isinstance(p, q.GeoWithin) and rw.spatial_view is None:
            best = None
            for v in views:
                if isinstance(v, SpatialRangeView) and v.col == p.col \
                        and v.covers_rect(p.rect):
                    if best is None or v.hits > best.hits:
                        best = v
            if best is not None:
                rw.spatial_view, rw.spatial_pred = best, p
    for r in query.ranks:
        if isinstance(r, q.VectorRank) and rw.vector_view is None:
            best = None
            for v in views:
                if isinstance(v, VectorNNView) and v.col == r.col \
                        and v.matches_query(r.q):
                    if best is None or v.hits > best.hits:
                        best = v
            if best is not None:
                rw.vector_view, rw.vector_rank = best, r
    return rw


def execute_with_views(executor, query: q.HybridQuery, rw: Rewrite):
    """Execute using the bound views; residual parts go to the base
    executor. Returns (results, stats, used_view: bool)."""
    from repro.core import executor as ex

    if not rw.any:
        res, st = executor.execute(query)
        return res, st, False

    stats = ex.ExecStats(plan="view_rewrite")
    store = executor.store

    # Vector-NN rewrite: re-rank materialized candidates, then apply
    # filters; fall back if the view can't fill k after filtering.
    if rw.vector_view is not None and query.is_nn:
        rw.vector_view.hits += 1
        cand = rw.vector_view.topk_for(rw.vector_rank.q,
                                       max(query.k * 4, query.k))
        rows = []
        for dist, pk in cand:
            row = store.get(pk)
            if row is None:
                continue
            ok = True
            for pred in query.filters:
                vals = {c: np.asarray([row[c]]) for c in row
                        if not c.startswith("_")}
                if not ex.eval_predicate_rows(vals, pred)[0]:
                    ok = False
                    break
            if not ok:
                continue
            # full weighted score (other rank terms exact from the row)
            score = 0.0
            for r in query.ranks:
                vals = {r.col: np.asarray([row[r.col]])}
                score += r.weight * float(
                    ex.rank_distances(vals, r)[0])
            rows.append(ex.ResultRow(pk=pk, score=score, values={
                c: v for c, v in row.items() if not c.startswith("_")}))
            stats.rows_scanned += 1
        rows.sort(key=lambda r: (r.score, r.pk))
        if len(rows) >= query.k:
            return rows[:query.k], stats, True
        res, st = executor.execute(query)   # underfilled: fall back
        return res, st, False

    # Spatial-range rewrite: pks from the view replace the GeoWithin scan.
    if rw.spatial_view is not None:
        rw.spatial_view.hits += 1
        pks = rw.spatial_view.pks_in(rw.spatial_pred.rect)
        rows = []
        residual = [p for p in query.filters if p is not rw.spatial_pred]
        for pk in pks:
            row = store.get(pk)
            if row is None:
                continue
            ok = True
            for pred in residual:
                vals = {c: np.asarray([row[c]]) for c in row
                        if not c.startswith("_")}
                if not ex.eval_predicate_rows(vals, pred)[0]:
                    ok = False
                    break
            if not ok:
                continue
            score = 0.0
            for r in query.ranks:
                vals = {r.col: np.asarray([row[r.col]])}
                score += r.weight * float(ex.rank_distances(vals, r)[0])
            rows.append(ex.ResultRow(pk=pk, score=score, values={
                c: v for c, v in row.items() if not c.startswith("_")}))
            stats.rows_scanned += 1
        rows.sort(key=lambda r: (r.score, r.pk))
        if query.is_nn:
            rows = rows[:query.k]
        return rows, stats, True

    res, st = executor.execute(query)
    return res, st, False
