"""Query rewriting over materialized views (paper §6).

Continuous queries: views matched and *statically* bound at registration
(reused every execution). Snapshot queries: matched at runtime with
rule-based heuristics — region containment for spatial filters, embedding
similarity for vector ranks — and rewritten per execution (greedy: the
first/highest-hit matching view wins).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core import query as q
from repro.core.views.view import SpatialRangeView, VectorNNView


@dataclasses.dataclass
class Rewrite:
    """A bound (view, query-part) substitution."""
    spatial_view: Optional[SpatialRangeView] = None
    spatial_pred: Optional[q.GeoWithin] = None
    vector_view: Optional[VectorNNView] = None
    vector_rank: Optional[q.VectorRank] = None

    @property
    def any(self) -> bool:
        return self.spatial_view is not None or self.vector_view is not None


def match(views: List, query: q.HybridQuery) -> Rewrite:
    """Greedy rule-based matching (used at registration for continuous
    queries, at runtime for snapshot queries).

    Spatial substitution requires the matched ``GeoWithin`` to be a
    top-level conjunct (replacing a predicate nested under ``Or``/``Not``
    with a view scan would change semantics), so it is attempted only for
    pure-conjunction queries.  Vector-NN matching post-filters candidates
    through the full expression tree and works for any ``where`` shape."""
    rw = Rewrite()
    try:
        top_literals = q.conjunction_literals(query.where)
    except ValueError:
        top_literals = []              # disjunctive: no spatial rewrite
    for p in top_literals:
        if isinstance(p, q.GeoWithin) and rw.spatial_view is None:
            best = None
            for v in views:
                if isinstance(v, SpatialRangeView) and v.col == p.col \
                        and v.covers_rect(p.rect):
                    if best is None or v.hits > best.hits:
                        best = v
            if best is not None:
                rw.spatial_view, rw.spatial_pred = best, p
    for r in query.ranks:
        if isinstance(r, q.VectorRank) and rw.vector_view is None:
            best = None
            for v in views:
                if isinstance(v, VectorNNView) and v.col == r.col \
                        and v.matches_query(r.q):
                    if best is None or v.hits > best.hits:
                        best = v
            if best is not None:
                rw.vector_view, rw.vector_rank = best, r
    return rw


def _lookup_visible(store, pks: np.ndarray):
    """Vectorized point lookup through the shared visibility index: pk ->
    winning (segment, row), memtable included; absent/tombstoned pks are
    dropped.  Returns (pks, sids, rows) in input order."""
    from repro.core import visibility as vis_lib

    pks = np.asarray(pks, np.int64)
    if len(pks):
        sids, rows, found = vis_lib.visibility_index(store).lookup_pks(pks)
        return pks[found], sids[found], rows[found]
    z = np.zeros(0, np.int64)
    return z, z, z


def _gather(store, sids: np.ndarray, rows: np.ndarray, cols) -> dict:
    """Columnar gather of (segment|memtable, row) pairs, input order."""
    if not len(sids):
        return {c: np.zeros(0) for c in cols}
    seg_by_id = {s.seg_id: s for s in store.segments}
    idx_parts: List[np.ndarray] = []
    val_parts = {c: [] for c in cols}
    for sid in np.unique(sids):
        sel = np.nonzero(sids == sid)[0]
        src = store.memtable_arrays()[3] if sid < 0 \
            else seg_by_id[int(sid)].columns
        idx_parts.append(sel)
        for c in cols:
            val_parts[c].append(np.asarray(src[c])[rows[sel]])
    idx = np.concatenate(idx_parts)
    inv = np.empty(len(idx), np.int64)
    inv[idx] = np.arange(len(idx))
    return {c: np.concatenate(val_parts[c])[inv] for c in cols}


def _finish(store, query: q.HybridQuery, pks, sids, rows, where, stats,
            k=None):
    """Shared tail of both rewrite paths: the residual filter expression
    and rank scores evaluated columnar over only the needed columns, then
    the (score, pk) sort/cut; full rows are materialized only for the ≤ k
    returned results.  ``where`` is a filter expression tree or a list of
    literals (implicit conjunction).  Returns (result_rows, n_survivors)."""
    from repro.core import executor as ex

    if isinstance(where, (list, tuple)):
        where = None if not where else \
            where[0] if len(where) == 1 else q.And(tuple(where))
    if len(pks):
        need = sorted(set(q.expr_cols(where)) |
                      {r.col for r in query.ranks})
        vals = _gather(store, sids, rows, need)
        keep = ex.eval_expr_rows(vals, where) if need else \
            np.ones(len(pks), bool)
        pks, sids, rows = pks[keep], sids[keep], rows[keep]
        vals = {c: v[keep] for c, v in vals.items()}
    if not len(pks):
        return [], 0
    stats.rows_scanned += int(len(pks))
    scores = ex.combined_scores(vals, query.ranks) if query.ranks \
        else np.zeros(len(pks), np.float32)
    order = np.lexsort((pks, scores))
    if k is not None:
        order = order[:k]
    out_cols = [c.name for c in store.schema.columns]
    final = _gather(store, sids[order], rows[order], out_cols)
    return ([ex.ResultRow(pk=int(pks[t]), score=float(scores[t]),
                          values={c: final[c][j] for c in out_cols})
             for j, t in enumerate(order)], int(len(pks)))


def execute_with_views(executor, query: q.HybridQuery, rw: Rewrite):
    """Execute using the bound views; residual parts go to the base
    executor. Returns (results, stats, used_view: bool)."""
    from repro.core import executor as ex

    if not rw.any:
        res, st = executor.execute(query)
        return res, st, False

    stats = ex.ExecStats(plan="view_rewrite")
    store = executor.store

    # Vector-NN rewrite: re-rank materialized candidates, then apply
    # filters; fall back if the view can't fill k after filtering.
    if rw.vector_view is not None and query.is_nn:
        rw.vector_view.hits += 1
        cand = rw.vector_view.topk_for(rw.vector_rank.q,
                                       max(query.k * 4, query.k))
        pks, sids, seg_rows = _lookup_visible(
            store, np.asarray([pk for _, pk in cand], np.int64))
        res, n = _finish(store, query, pks, sids, seg_rows,
                         query.where, stats, k=query.k)
        if n >= query.k:
            return res, stats, True
        res, st = executor.execute(query)   # underfilled: fall back
        return res, st, False

    # Spatial-range rewrite: pks from the view replace the GeoWithin scan.
    if rw.spatial_view is not None:
        rw.spatial_view.hits += 1
        pks, sids, seg_rows = _lookup_visible(
            store, np.asarray(list(rw.spatial_view.pks_in(
                rw.spatial_pred.rect)), np.int64))
        residual = [p for p in query.filters if p is not rw.spatial_pred]
        res, _ = _finish(store, query, pks, sids, seg_rows, residual,
                         stats, k=query.k if query.is_nn else None)
        return res, stats, True

    res, st = executor.execute(query)
    return res, st, False
