"""Per-shard write-ahead log with batched group commit (Arc's
per-worker-WAL + fdatasync design, SNIPPETS.md).

The log records the ingest path's *columnar* ``put_batch`` payloads
as-is: one record per batch holding the pk array, every column's
canonical numpy array (or just the pks for tombstone batches), and the
batch's starting seqno — so replay is a handful of vectorized
``put_batch`` calls, never a per-row loop.

Record codec (all little-endian)::

    | magic "AWR1" | crc32 u32 | body_len u32 | body ... |
    body = type u8 | seqno_start i64 | n_rows u32 | arrays ...
    array = name_len u16 | name utf8 | kind u8 | payload
      kind 0 (numeric): dtype_len u8 | dtype str | ndim u8 |
                        dims i64*ndim | raw C-order bytes
      kind 1 (str) / 2 (bytes): offsets i64*(n+1) | utf8/raw blob

The crc32 covers the body; a short header, short body, or crc mismatch
is a *torn tail*: ``read_records`` stops cleanly at the last good record
and reports the good byte offset so recovery can truncate the file.  No
record is ever half-applied and nothing after a torn record is trusted.

Durability contract: ``append`` buffers through the OS file; a *group
commit* (``flush`` + ``fdatasync``) runs every ``group_records`` records
or ``group_bytes`` bytes, and always on ``sync()`` (seal/flush/close).
``durable_seqno`` is the highest seqno covered by a completed commit —
the store's acknowledgment frontier for the no-acknowledged-write-lost
guarantee.

The log is a directory of files ``wal-<start_seqno>.log``; ``rotate``
opens a fresh file at each memtable seal so ``gc(frontier)`` can drop
whole files once a manifest publish covers their seqno range.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import time
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.faults import NO_FAULTS, FaultInjector
from repro.core.types import ColumnType, Schema
from repro.obs import REGISTRY

MAGIC = b"AWR1"
_HEADER = struct.Struct("<4sII")          # magic, crc32, body_len
_BODY_HEAD = struct.Struct("<BqI")        # type, seqno_start, n_rows
REC_PUT = 1
REC_DELETE = 2

_KIND_NUMERIC = 0
_KIND_STR = 1
_KIND_BYTES = 2


# ---------------------------------------------------------------------------
# array (de)serialization — shared with the segment save/load format
# ---------------------------------------------------------------------------

def pack_object_array(arr: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Flatten a TEXT/BLOB object array into (offsets i64 (n+1,),
    blob uint8) — the pickle-free on-disk form."""
    parts = [v.encode("utf-8") if isinstance(v, str)
             else bytes(v) for v in arr]
    offsets = np.zeros(len(parts) + 1, np.int64)
    np.cumsum([len(p) for p in parts], out=offsets[1:])
    blob = np.frombuffer(b"".join(parts), np.uint8).copy() if parts \
        else np.zeros(0, np.uint8)
    return offsets, blob


def unpack_object_array(offsets: np.ndarray, blob: np.ndarray,
                        as_str: bool) -> np.ndarray:
    raw = blob.tobytes()
    out = np.empty(len(offsets) - 1, object)
    for i in range(len(out)):
        piece = raw[int(offsets[i]):int(offsets[i + 1])]
        out[i] = piece.decode("utf-8") if as_str else piece
    return out


def _pack_array(name: str, arr: np.ndarray) -> bytes:
    nm = name.encode("utf-8")
    parts = [struct.pack("<H", len(nm)), nm]
    if arr.dtype == object:
        kind = _KIND_STR if (len(arr) and isinstance(arr[0], str)) or \
            not len(arr) else _KIND_BYTES
        offsets, blob = pack_object_array(arr)
        parts.append(struct.pack("<BQ", kind, len(arr)))
        parts.append(offsets.tobytes())
        parts.append(blob.tobytes())
    else:
        arr = np.ascontiguousarray(arr)
        dt = arr.dtype.str.encode()
        parts.append(struct.pack("<BB", _KIND_NUMERIC, len(dt)))
        parts.append(dt)
        parts.append(struct.pack("<B", arr.ndim))
        parts.append(struct.pack(f"<{arr.ndim}q", *arr.shape))
        parts.append(arr.tobytes())
    return b"".join(parts)


def _unpack_array(buf: memoryview, off: int
                  ) -> Tuple[str, np.ndarray, int]:
    (nlen,) = struct.unpack_from("<H", buf, off)
    off += 2
    name = bytes(buf[off:off + nlen]).decode("utf-8")
    off += nlen
    (kind,) = struct.unpack_from("<B", buf, off)
    off += 1
    if kind == _KIND_NUMERIC:
        (dlen,) = struct.unpack_from("<B", buf, off)
        off += 1
        dtype = np.dtype(bytes(buf[off:off + dlen]).decode())
        off += dlen
        (ndim,) = struct.unpack_from("<B", buf, off)
        off += 1
        shape = struct.unpack_from(f"<{ndim}q", buf, off)
        off += 8 * ndim
        nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        arr = np.frombuffer(buf[off:off + nbytes], dtype).reshape(shape)
        return name, arr.copy(), off + nbytes
    (n,) = struct.unpack_from("<Q", buf, off)
    off += 8
    offsets = np.frombuffer(buf[off:off + 8 * (n + 1)], np.int64).copy()
    off += 8 * (n + 1)
    blob_len = int(offsets[-1]) if n else 0
    blob = np.frombuffer(buf[off:off + blob_len], np.uint8)
    return name, unpack_object_array(offsets, blob, kind == _KIND_STR), \
        off + blob_len


# ---------------------------------------------------------------------------
# record codec
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WalRecord:
    rtype: int                       # REC_PUT / REC_DELETE
    seqno_start: int
    pks: np.ndarray                  # (n,) int64
    batch: Dict[str, np.ndarray]     # empty for deletes

    @property
    def n_rows(self) -> int:
        return len(self.pks)


def encode_record(rtype: int, seqno_start: int, pks: np.ndarray,
                  batch: Dict[str, np.ndarray]) -> bytes:
    pks = np.asarray(pks, np.int64)
    body = [_BODY_HEAD.pack(rtype, int(seqno_start), len(pks)),
            _pack_array("_pk", pks)]
    for name in sorted(batch):
        body.append(_pack_array(name, np.asarray(batch[name])))
    blob = b"".join(body)
    return _HEADER.pack(MAGIC, zlib.crc32(blob), len(blob)) + blob


def decode_record(buf: memoryview, off: int
                  ) -> Optional[Tuple[WalRecord, int]]:
    """Decode one record at ``off``; None on any torn/corrupt tail."""
    if off + _HEADER.size > len(buf):
        return None
    magic, crc, blen = _HEADER.unpack_from(buf, off)
    if magic != MAGIC or off + _HEADER.size + blen > len(buf):
        return None
    body = buf[off + _HEADER.size:off + _HEADER.size + blen]
    if zlib.crc32(body) != crc:
        return None
    try:
        rtype, seqno_start, n_rows = _BODY_HEAD.unpack_from(body, 0)
        pos = _BODY_HEAD.size
        arrays: Dict[str, np.ndarray] = {}
        while pos < len(body):
            name, arr, pos = _unpack_array(body, pos)
            arrays[name] = arr
        pks = arrays.pop("_pk")
        if len(pks) != n_rows:
            return None
    except (struct.error, ValueError, KeyError, TypeError):
        return None
    rec = WalRecord(rtype, seqno_start, pks, arrays)
    return rec, off + _HEADER.size + blen


def read_records(data: bytes) -> Tuple[List[WalRecord], int]:
    """Decode a whole log image; returns (records, good_bytes) where
    ``good_bytes`` is the offset of the first torn/corrupt record (==
    len(data) when the tail is clean)."""
    buf = memoryview(data)
    out: List[WalRecord] = []
    off = 0
    while off < len(buf):
        dec = decode_record(buf, off)
        if dec is None:
            break
        rec, off = dec
        out.append(rec)
    return out, off


# ---------------------------------------------------------------------------
# the log itself
# ---------------------------------------------------------------------------

def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class WriteAheadLog:
    """Group-committed per-store log over a directory of rotated files.

    All mutating calls run under the owning store's lock (put/seal are
    locked; flush-worker GC happens inside the locked manifest publish
    window), so the log needs no lock of its own."""

    def __init__(self, root: str, group_records: int = 8,
                 group_bytes: int = 1 << 20,
                 faults: FaultInjector = NO_FAULTS):
        self.root = root
        self.group_records = max(1, int(group_records))
        self.group_bytes = max(1, int(group_bytes))
        self.faults = faults
        os.makedirs(root, exist_ok=True)
        self._f = None                    # active file object
        self._active_start = 0            # first seqno the active file holds
        self._pending = 0                 # records since last commit
        self._pending_seqno = -1          # highest seqno written, unsynced
        self.durable_seqno = -1           # highest seqno covered by a commit
        self._closed = False

    # ------------------------------------------------------------ files
    def _path(self, start_seqno: int) -> str:
        return os.path.join(self.root, f"wal-{start_seqno:012d}.log")

    def _file_starts(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("wal-") and name.endswith(".log"):
                out.append(int(name[4:-4]))
        return sorted(out)

    def _open_active(self, start_seqno: int) -> None:
        self._active_start = start_seqno
        self._f = open(self._path(start_seqno), "ab")

    # ----------------------------------------------------------- append
    def append(self, pks: np.ndarray, batch: Dict[str, np.ndarray],
               seqno_start: int, tombstone: bool = False) -> None:
        """Log one columnar batch ahead of the memtable apply.  The
        group-commit policy decides whether this batch's fdatasync runs
        now or is amortized into a later append/sync."""
        if self._f is None:
            self._open_active(seqno_start)
        rtype = REC_DELETE if tombstone else REC_PUT
        data = encode_record(rtype, seqno_start, pks,
                             {} if tombstone else batch)
        if self.faults.should_crash("wal.append"):
            # simulate the process dying mid-write: half a record lands
            self._f.write(data[:max(1, len(data) // 2)])
            self._f.flush()
            self.faults.crash("wal.append")
        self._f.write(data)
        self._pending += 1
        self._pending_seqno = int(seqno_start) + len(pks) - 1
        if (self._pending >= self.group_records
                or len(data) >= self.group_bytes):
            self._commit()

    def _commit(self) -> None:
        """Group commit: push the OS buffer to stable storage and
        advance the acknowledgment frontier."""
        if self._f is None or self._pending == 0:
            return
        t0 = time.perf_counter()
        self._f.flush()
        self.faults.crash("wal.commit")
        os.fdatasync(self._f.fileno())
        self.durable_seqno = max(self.durable_seqno, self._pending_seqno)
        self._pending = 0
        REGISTRY.observe("wal.fsync_s", time.perf_counter() - t0)
        REGISTRY.inc("wal.commits")

    def sync(self) -> None:
        """Force a commit (seal/flush/close call this: everything
        appended so far becomes acknowledged)."""
        self._commit()

    # --------------------------------------------------------- rotation
    def rotate(self, next_seqno: int) -> None:
        """Seal the active file (sync) and start a new one whose name
        records the first seqno it can contain — called at memtable
        seal so file ranges align with flush units."""
        self.sync()
        if self._f is not None:
            self._f.close()
        self._open_active(int(next_seqno))

    def gc(self, frontier: int) -> None:
        """Delete non-active files whose entire seqno range is covered
        by durable segments (every seqno < the next file's start is <=
        ``frontier``)."""
        starts = self._file_starts()
        for i, start in enumerate(starts):
            if start == self._active_start:
                continue
            nxt = starts[i + 1] if i + 1 < len(starts) else None
            if nxt is not None and nxt - 1 <= frontier:
                try:
                    os.remove(self._path(start))
                except OSError:
                    pass

    # --------------------------------------------------------- recovery
    def replay(self) -> Iterator[WalRecord]:
        """Yield every intact record across all files in seqno order,
        truncating the first torn tail in place (later bytes/files are
        never trusted — a record is only as durable as everything
        logged before it)."""
        starts = self._file_starts()
        for i, start in enumerate(starts):
            path = self._path(start)
            with open(path, "rb") as f:
                data = f.read()
            recs, good = read_records(data)
            yield from recs
            if good < len(data):
                with open(path, "r+b") as f:
                    f.truncate(good)
                # drop anything logged after the torn record
                for later in starts[i + 1:]:
                    try:
                        os.remove(self._path(later))
                    except OSError:
                        pass
                break
        # reopen for appends at the tail file
        if starts:
            self._active_start = starts[-1]
            self._f = open(self._path(starts[-1]), "ab")

    def close(self) -> None:
        """Seal the log: final group commit, then release the handle.
        Idempotent."""
        if self._closed:
            return
        self._closed = True
        if self._f is not None:
            self._commit()
            os.fsync(self._f.fileno())
            self._f.close()
            self._f = None
            _fsync_dir(self.root)
