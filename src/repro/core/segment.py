"""Immutable columnar segments — the SST-file analog (DESIGN.md §2).

A segment stores rows sorted by primary key in fixed-height blocks of
``BLOCK_ROWS`` (the read unit: one HBM->VMEM tile). Block handles are
(segment_id, block_id) pairs; the per-segment secondary indexes map
attribute values / centroids to block handles + in-block offsets, mirroring
the paper's "(vector, block handle) pairs" posting lists.
"""
from __future__ import annotations

import dataclasses
import io
import itertools
import os
import threading
from collections import OrderedDict
from typing import Any, Dict, Optional, Sequence

import numpy as np

from repro.core.faults import NO_FAULTS, FaultInjector
from repro.core.quantize import QuantizedColumn
from repro.core.types import BLOCK_ROWS, ColumnType, Schema
from repro.core.wal import pack_object_array, unpack_object_array

_seg_counter = itertools.count()


def bump_seg_counter(n: int) -> None:
    """Advance the module seg-id counter to at least ``n``: freshly
    flushed segments must never collide with loaded ones, because the
    pack caches and the global index key on ``seg_id``."""
    global _seg_counter
    cur = next(_seg_counter)
    _seg_counter = itertools.count(max(cur + 1, int(n)))


@dataclasses.dataclass(frozen=True)
class BlockHandle:
    seg_id: int
    block_id: int

    def __repr__(self):
        return f"BH({self.seg_id}:{self.block_id})"


class Segment:
    """Immutable sorted run. ``indexes`` is populated by the index builders
    at flush/compaction time (the paper: vector index built in the
    background along with SST construction)."""

    def __init__(self, schema: Schema, pk: np.ndarray, seqno: np.ndarray,
                 tombstone: np.ndarray, columns: Dict[str, np.ndarray],
                 level: int = 0, seg_id: Optional[int] = None):
        order = np.argsort(pk, kind="stable")
        self.schema = schema
        self.seg_id = next(_seg_counter) if seg_id is None else seg_id
        self.level = level
        # input-row -> segment-row permutation; consumed exactly once (the
        # flush path extends the visibility index with it) then released
        self.sort_order: Optional[np.ndarray] = order
        self.pk = np.asarray(pk)[order]
        self.seqno = np.asarray(seqno)[order]
        self.tombstone = np.asarray(tombstone)[order]
        self.columns: Dict[str, np.ndarray] = {}
        for name, arr in columns.items():
            arr = np.asarray(arr)
            self.columns[name] = arr[order]
        self.n_rows = len(self.pk)
        self.indexes: Dict[str, Any] = {}
        # quantized residence tier: col name -> quantize.QuantizedColumn
        # (PQ codes in segment row order), populated at flush/compaction
        self.quantized: Dict[str, Any] = {}
        # bumped whenever derived per-segment content (quantized codes)
        # is assigned after construction: pack caches key on it, because
        # seg_id alone cannot distinguish a segment packed before its
        # codes arrived from the same segment packed after
        self.content_gen = 0
        # per-segment zone map (fence pointers) for the global index
        self.pk_min = int(self.pk[0]) if self.n_rows else 0
        self.pk_max = int(self.pk[-1]) if self.n_rows else 0

    # ---- blocks ----------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return (self.n_rows + BLOCK_ROWS - 1) // BLOCK_ROWS

    def block_rows(self, block_id: int) -> slice:
        lo = block_id * BLOCK_ROWS
        return slice(lo, min(lo + BLOCK_ROWS, self.n_rows))

    def read_block(self, col: str, block_id: int) -> np.ndarray:
        """Block-granular read — the unit the cost model charges for."""
        return self.columns[col][self.block_rows(block_id)]

    # ---- point lookups ----------------------------------------------------
    def get(self, key: int) -> Optional[int]:
        """Row index of the NEWEST version of ``key`` or None (binary
        search over sorted pk).  A segment can legally hold several
        versions of one pk — original + update ingested into the same
        memtable flush side by side — so the equal-pk run is resolved by
        max seqno, never by position."""
        i = int(np.searchsorted(self.pk, key))
        if i >= self.n_rows or self.pk[i] != key:
            return None
        j = int(np.searchsorted(self.pk, key, side="right"))
        if j - i == 1:
            return i
        return i + int(np.argmax(self.seqno[i:j]))

    def may_contain(self, key: int) -> bool:
        return self.n_rows > 0 and self.pk_min <= key <= self.pk_max

    def row(self, i: int) -> Dict[str, Any]:
        out = {"_pk": int(self.pk[i]), "_seqno": int(self.seqno[i]),
               "_tombstone": bool(self.tombstone[i])}
        for name, arr in self.columns.items():
            out[name] = arr[i]
        return out


@dataclasses.dataclass
class PackedColumn:
    """Cross-segment superbatch: one column of every visible segment
    stacked into a single matrix, with parallel row-provenance arrays —
    the unit the fused scan->top-k kernel consumes (one launch per query
    batch instead of one per segment)."""
    x: np.ndarray            # (N, d) fp32 stacked column values
    pks: np.ndarray          # (N,) int64 primary keys
    sids: np.ndarray         # (N,) int64 owning segment id per row
    rows: np.ndarray         # (N,) int64 row index inside the segment
    offsets: np.ndarray      # (n_segs + 1,) int64 segment start offsets


# segments are immutable, so a packed column is valid for as long as its
# exact (col, seg_id...) combination is queried; a small LRU bounds the
# memory pinned by superbatches that outlive compaction (each entry is a
# full fp32 copy of the packed column, so the cap is deliberately tight)
_pack_cache: "OrderedDict[Tuple, PackedColumn]" = OrderedDict()
_PACK_CACHE_CAP = 4
# query threads and the background flush worker share the LRU: an
# unguarded move_to_end/popitem pair from two threads corrupts the
# OrderedDict's internal links
_pack_lock = threading.Lock()


def pack_segments(segments: Sequence[Segment], col: str) -> PackedColumn:
    """Concatenate ``col`` across ``segments`` into one superbatch."""
    key = (col,) + tuple((s.seg_id, s.content_gen) for s in segments)
    with _pack_lock:
        hit = _pack_cache.get(key)
        if hit is not None:
            _pack_cache.move_to_end(key)
            return hit
    xs = [np.asarray(s.columns[col], np.float32) for s in segments]
    ns = [s.n_rows for s in segments]
    packed = PackedColumn(
        x=np.concatenate(xs) if xs else np.zeros((0, 0), np.float32),
        pks=np.concatenate([s.pk for s in segments]),
        sids=np.concatenate([np.full(n, s.seg_id, np.int64)
                             for s, n in zip(segments, ns)]),
        rows=np.concatenate([np.arange(n, dtype=np.int64) for n in ns]),
        offsets=np.cumsum([0] + ns).astype(np.int64))
    with _pack_lock:
        while len(_pack_cache) >= _PACK_CACHE_CAP:
            _pack_cache.popitem(last=False)       # evict least-recent
        _pack_cache[key] = packed
    return packed


@dataclasses.dataclass
class PackedCodes:
    """Quantized sibling of ``PackedColumn``: the PQ code matrices of the
    same segments stacked in the SAME row order as ``pack_segments``, so
    a packed row id indexes both the fp32 superbatch and the code
    superbatch.  Only well-defined when every segment carries codes from
    one shared codebook set (one ``book_id``)."""
    codes: np.ndarray        # (N, m) uint8 PQ codes
    codebooks: np.ndarray    # (m, 256, dsub) fp32 shared codebooks
    book_id: int


def pack_quantized(segments: Sequence[Segment],
                   col: str) -> Optional[PackedCodes]:
    """Stack ``col``'s PQ codes across ``segments`` (row-aligned with
    ``pack_segments``).  Returns None when any segment lacks codes or the
    segments' codebooks differ — callers fall back to the exact path."""
    qcols = [s.quantized.get(col) for s in segments]
    if not qcols or any(qc is None for qc in qcols):
        return None
    book_id = qcols[0].book_id
    if any(qc.book_id != book_id for qc in qcols[1:]):
        return None
    key = ("#codes", col) + tuple((s.seg_id, s.content_gen)
                                  for s in segments)
    with _pack_lock:
        hit = _pack_cache.get(key)
        if hit is not None:
            _pack_cache.move_to_end(key)
            return hit
    packed = PackedCodes(
        codes=np.concatenate([qc.codes for qc in qcols]),
        codebooks=qcols[0].codebooks,
        book_id=book_id)
    with _pack_lock:
        while len(_pack_cache) >= _PACK_CACHE_CAP:
            _pack_cache.popitem(last=False)
        _pack_cache[key] = packed
    return packed


def merge_segments(schema: Schema, segments: Sequence[Segment],
                   level: int, drop_tombstones: bool,
                   return_maps: bool = False):
    """K-way merge by primary key keeping the newest seqno per key
    (size-tiered compaction). Tombstones are dropped only when compacting
    into the bottom tier (no older data can be shadowed).

    With ``return_maps`` also returns, per input segment, an int64 array
    mapping each source row to its row in the merged segment (-1 when the
    row was shadowed or tombstone-dropped) — the plumbing mergeable
    per-segment indexes need to remap their entries without a rebuild.
    """
    if not segments:
        raise ValueError("nothing to merge")
    pk = np.concatenate([s.pk for s in segments])
    seqno = np.concatenate([s.seqno for s in segments])
    tomb = np.concatenate([s.tombstone for s in segments])
    cols = {c.name: np.concatenate([s.columns[c.name] for s in segments])
            for c in schema.columns}
    # newest version per key: sort by (pk, -seqno), keep first
    order = np.lexsort((-seqno, pk))
    spk, sseq, stomb = pk[order], seqno[order], tomb[order]
    keep = np.ones(len(spk), bool)
    keep[1:] = spk[1:] != spk[:-1]
    if drop_tombstones:
        keep &= ~stomb
    cols = {k: v[order][keep] for k, v in cols.items()}
    merged = Segment(schema, spk[keep], sseq[keep], stomb[keep], cols,
                     level=level)
    if not return_maps:
        return merged
    # surviving rows are already pk-sorted (strictly increasing after the
    # dedup), so Segment's stable argsort is the identity and the merged
    # row of the i-th kept sorted position is simply its rank
    concat_to_new = np.full(len(pk), -1, np.int64)
    concat_to_new[order[keep]] = np.arange(int(keep.sum()), dtype=np.int64)
    maps, lo = [], 0
    for s in segments:
        maps.append(concat_to_new[lo:lo + s.n_rows])
        lo += s.n_rows
    return merged, maps


# ---------------------------------------------------------------------------
# persistence: one npz-style file per segment (core/manifest.py publishes
# the file names; a segment file is durable only once a manifest names it)
# ---------------------------------------------------------------------------

# loaded segments reuse their saved seg_id (the manifest references files
# by it) but must not collide in the pack caches with any same-id segment
# object from before a crash/restore in this process, so each load stamps
# a content_gen from a range live stores never use (they count from 0)
_load_gens = itertools.count(1_000_000)


def _segment_arrays(seg: Segment) -> Dict[str, np.ndarray]:
    """Flatten a segment to named arrays — no pickle anywhere: object
    columns (TEXT/BLOB) become offsets + byte blobs, indexes serialize
    through the ``to_arrays`` contract, PQ codes/codebooks go as-is."""
    arrays: Dict[str, np.ndarray] = {
        "pk": np.asarray(seg.pk, np.int64),
        "seqno": np.asarray(seg.seqno, np.int64),
        "tombstone": np.asarray(seg.tombstone, bool),
        "meta": np.asarray([seg.level, seg.seg_id], np.int64)}
    for c in seg.schema.columns:
        arr = seg.columns[c.name]
        if arr.dtype == object:
            offsets, blob = pack_object_array(arr)
            arrays[f"col.{c.name}.offsets"] = offsets
            arrays[f"col.{c.name}.blob"] = blob
        else:
            arrays[f"col.{c.name}"] = arr
    for name, qc in seg.quantized.items():
        arrays[f"pq.{name}.codes"] = qc.codes
        arrays[f"pq.{name}.codebooks"] = qc.codebooks
    for name, idx in seg.indexes.items():
        for key, val in idx.to_arrays().items():
            arrays[f"idx.{name}.{key}"] = val
    return arrays


def save_segment(seg: Segment, path: str,
                 faults: FaultInjector = NO_FAULTS) -> None:
    """Write a segment durably: serialize in memory, write temp, fsync,
    atomic rename. The file is invisible to recovery until a manifest
    publish references it, so a crash here leaves only an orphan."""
    buf = io.BytesIO()
    np.savez(buf, **_segment_arrays(seg))
    data = buf.getvalue()
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        if faults.should_crash("flush.segment-file"):
            # simulate dying mid-write: a torn temp file lands on disk
            f.write(data[:max(1, len(data) // 2)])
            f.flush()
            faults.crash("flush.segment-file")
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def load_segment(schema: Schema, path: str,
                 index_factory=None) -> Segment:
    """Rebuild a segment (columns, PQ codes, all index kinds) from its
    file. Loaded PQ columns carry ``book_id=0``; the owning store remaps
    them to a fresh shared id per column so ``pack_quantized``'s
    same-book gate keeps working across loaded + new segments."""
    with np.load(path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    level, seg_id = (int(v) for v in arrays["meta"])
    cols: Dict[str, np.ndarray] = {}
    for c in schema.columns:
        key = f"col.{c.name}"
        if key in arrays:
            cols[c.name] = arrays[key]
        else:
            cols[c.name] = unpack_object_array(
                arrays[f"{key}.offsets"], arrays[f"{key}.blob"],
                as_str=(c.ctype == ColumnType.TEXT))
    seg = Segment(schema, arrays["pk"], arrays["seqno"],
                  arrays["tombstone"].astype(bool), cols,
                  level=level, seg_id=seg_id)
    seg.sort_order = None            # visibility rebuilds from scratch
    seg.content_gen = next(_load_gens)
    for c in schema.columns:
        ck = f"pq.{c.name}.codes"
        if ck in arrays:
            seg.quantized[c.name] = QuantizedColumn(
                arrays[ck], arrays[f"pq.{c.name}.codebooks"], 0)
    if index_factory is not None:
        for c in schema.indexed_columns:
            prefix = f"idx.{c.name}."
            sub = {k[len(prefix):]: v for k, v in arrays.items()
                   if k.startswith(prefix)}
            if not sub:
                continue
            idx = index_factory(c)
            if idx is not None:
                idx.from_arrays(sub, seg, c)
                seg.indexes[c.name] = idx
    bump_seg_counter(seg_id + 1)
    return seg
