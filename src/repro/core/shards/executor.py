"""Scatter-gather query execution across hash-partitioned shards.

``ShardedExecutor`` is the read-path half of the sharded serving
subsystem: a ``HybridQuery`` (or an ``execute_many`` batch) is planned
ONCE against merged shard statistics (a ``Catalog`` over a store view
that concatenates per-shard segments and sums row counts), then every
shard executes the same logical plan through its complete single-store
pipeline — index probes, ``BitmapUnion``, the fused packed scan->top-k
kernel, visibility resolution and the memtable overlay all run per shard
unchanged.  Combination is shape-aware:

  NN      per-shard top-k candidate lists merge ON DEVICE via the
          generalized batched top-k merge kernel (kernels/topk_merge.py)
          in (score, pk) order — shards partition pks, so the merge of
          per-shard top-ks is the exact global top-k and the host never
          handles more than shards * k rows per query;
  filter  shard-wise concatenation re-sorted by the single-store result
          comparator (pk-disjoint, so concat IS the union).

Per-shard ``kops.stats_snapshot()`` dispatch deltas are aggregated into
one ``ExecStats`` per query (plus ``shards`` / ``merge_rows`` /
``shard_rows_max`` fan-out accounting), and EXPLAIN grows a
``ShardFanout(n=N)`` node whose children are the per-shard operator
subtrees costed against each shard's own catalog.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import operators as ops
from repro.core import query as q
from repro.core.executor import MIN_SHARED_SCAN_BATCH, Executor
from repro.core.operators import ExecStats, ResultRow
from repro.core.optimizer import planner as planner_lib
from repro.core.optimizer.stats import Catalog
from repro.kernels import ops as kops
from repro.obs import REGISTRY, SLOW_LOG
from repro.obs import analyze as obs_analyze
from repro.obs import trace as obs_trace


class _MergedGlobalIndex:
    """Segment pruning over the union of the shards' global indexes —
    serves the merged catalog's cost estimates only; execution prunes
    per shard through each shard's own ``GlobalIndexSet``."""

    def __init__(self, shards):
        self.shards = shards

    def prune(self, segments, predicate) -> List:
        allowed = set()
        for sh in self.shards:
            allowed.update(id(s) for s in
                           sh.global_index.prune(sh.segments, predicate))
        return [s for s in segments if id(s) in allowed]


class _MergedStoreView:
    """Store-shaped facade over all shards for the planner's ``Catalog``:
    concatenated segment list, summed row counts, and the conjunction of
    per-shard ``unique_pks`` flags (routing keeps shard pk sets disjoint,
    so every-shard-unique implies globally unique — the fused dispatch
    gate stays sound)."""

    def __init__(self, router):
        self._router = router
        self.global_index = _MergedGlobalIndex(router.shards)

    @property
    def schema(self):
        return self._router.schema

    @property
    def segments(self) -> List:
        return self._router.all_segments()

    @property
    def n_rows(self) -> int:
        return self._router.n_rows

    @property
    def memtable_rows(self) -> int:
        return self._router.memtable_rows

    @property
    def unique_pks(self) -> bool:
        return self._router.unique_pks


class _ShardSubplan(ops.PhysicalOp):
    """EXPLAIN wrapper for one shard's operator subtree."""
    name = "Shard"


def _tree_cost(node: ops.PhysicalOp) -> float:
    return node.est_cost + sum(_tree_cost(c) for c in node.children)


class ShardedPlan:
    """One logical ``Plan`` chosen on merged shard statistics, plus the
    fan-out EXPLAIN structure.  Duck-types the parts of ``Plan`` the
    facade and benchmarks read (``kind``/``fused``/``cost``/``k``)."""

    def __init__(self, logical: planner_lib.Plan,
                 executor: "ShardedExecutor"):
        self.logical = logical
        self._executor = executor

    @property
    def kind(self) -> str:
        return self.logical.kind

    @property
    def fused(self) -> bool:
        return self.logical.fused

    @property
    def quantized(self) -> bool:
        return self.logical.quantized

    @property
    def pq_m(self) -> int:
        return self.logical.pq_m

    @property
    def refine(self) -> int:
        return self.logical.refine

    @property
    def graph(self) -> bool:
        return self.logical.graph

    @property
    def graph_r(self) -> int:
        return self.logical.graph_r

    @property
    def graph_beam(self) -> int:
        return self.logical.graph_beam

    @property
    def graph_hops(self) -> int:
        return self.logical.graph_hops

    @property
    def cost(self) -> float:
        return self.logical.cost

    @property
    def k(self) -> int:
        return self.logical.k

    @property
    def ranks(self) -> List:
        return self.logical.ranks

    def describe(self) -> str:
        return self._executor.describe(self.logical)


class ShardedExecutor:
    """Executor-shaped driver over N shard ``Executor``s (see module
    docstring for the dataflow)."""

    def __init__(self, store):
        self.store = store                       # ShardRouter
        self.executors = [Executor(sh) for sh in store.shards]
        self.catalog = Catalog(_MergedStoreView(store))
        # facade-visible read-path counters (Database.metrics())
        self.metrics = {"queries": 0, "batches": 0, "merges": 0,
                        "merge_rows": 0, "exec_time_s": 0.0}

    @property
    def n_shards(self) -> int:
        return self.store.n_shards

    # ----------------------------------------------------------- planning
    def plan(self, query: q.HybridQuery) -> ShardedPlan:
        return ShardedPlan(self._plan_logical(query), self)

    def _plan_logical(self, query: q.HybridQuery) -> planner_lib.Plan:
        plan = planner_lib.plan(self.catalog, query)
        if plan.kind == "postfilter_nn":
            # the IVF probe is approximate AND shard-layout-sensitive
            # (per-segment centroid sets differ between shardings), so a
            # post-filter probe would break sharded==single parity;
            # demote to the exact shared-scan shape
            plan = planner_lib.plan_shared_scan(self.catalog, query)
            plan.note = (plan.note + "; " if plan.note else "") + \
                "postfilter demoted under sharding"
            plan.operator_tree(self.catalog)
        return plan

    def _fanout_tree(self, plan: planner_lib.Plan) -> ops.PhysicalOp:
        """The sharded EXPLAIN structure: the combine operator (device
        top-k merge / pk-disjoint concat) over a ``ShardFanout(n=N)``
        node holding per-shard operator subtrees costed against each
        shard's own catalog.  Shared by ``describe`` and EXPLAIN
        ANALYZE's annotated rendering."""
        kids = []
        for i, (sh, ex) in enumerate(zip(self.store.shards,
                                         self.executors)):
            clone = dataclasses.replace(plan, root=None)
            tree = clone.operator_tree(ex.catalog)
            kids.append(_ShardSubplan(
                [tree],
                detail=(f"{i}: {sh.n_rows} rows, "
                        f"{len(sh.segments)} segments"),
                est_cost=_tree_cost(tree)))
        n = self.n_shards
        fan = ops.ShardFanout(kids, detail=f"n={n} hash(pk)",
                              est_cost=max(c.est_cost for c in kids)
                              if kids else 0.0)
        if plan.kind == "empty":
            root: ops.PhysicalOp = plan.operator_tree()
        elif plan.ranks:
            root = ops.CrossShardTopKMerge(
                [fan], detail=(f"k={plan.k} device merge, "
                               f"<={n}*{plan.k} rows to host"),
                est_cost=float(n * max(1, plan.k)),
                est_rows=float(n * max(1, plan.k)))
        else:
            root = ops.ShardConcat([fan], detail="pk-disjoint concat")
        return root

    def describe(self, plan: planner_lib.Plan) -> str:
        """EXPLAIN with the sharded dataflow (see ``_fanout_tree``).
        Rendered once per plan object (plans are immutable after
        planning), so executing a query doesn't rebuild N subtrees on
        every call."""
        cached = getattr(plan, "_sharded_describe", None)
        if cached is not None:
            return cached
        n = self.n_shards
        root = self._fanout_tree(plan)
        if plan.graph:
            disp = (f" dispatch=graph(R={plan.graph_r}, "
                    f"beam={plan.graph_beam}, hops={plan.graph_hops})")
        elif plan.quantized:
            disp = (f" dispatch=quantized(pq m={plan.pq_m}, "
                    f"refine={plan.refine})")
        elif plan.fused:
            disp = " dispatch=fused"
        else:
            disp = ""
        head = (f"sharded:{plan.kind}(shards={n} "
                f"ranks={len(plan.ranks)} cost={plan.cost:.1f}{disp})")
        plan._sharded_describe = head + "\n" + root.explain(1)
        return plan._sharded_describe

    # ---------------------------------------------------------- execution
    def execute(self, query: q.HybridQuery, plan=None
                ) -> Tuple[List[ResultRow], ExecStats]:
        return self.execute_many([query], plans=[plan])[0]

    def explain_analyze(self, query: q.HybridQuery, plan=None
                        ) -> obs_analyze.Analyzed:
        """EXPLAIN ANALYZE across the fan-out: executes under forced
        tracing, then annotates the combine/fanout tree — each ``Shard``
        subtree reads the actuals captured under that shard's ``shard``
        span, so per-shard drift is visible node by node."""
        if isinstance(plan, ShardedPlan):
            logical = plan.logical
        elif plan is not None:
            logical = plan
        else:
            logical = self._plan_logical(query)
        with obs_trace.force_tracing():
            with obs_trace.span("analyze") as root:
                ((results, stats),) = self.execute_many([query],
                                                        plans=[logical])
        actuals = obs_analyze.actuals_from(root)
        per_shard = obs_analyze.shard_actuals(root)
        head = self.describe(logical).splitlines()[0]
        tree = self._fanout_tree(logical)
        text = head + " (analyzed)\n" + tree.explain(
            1, annotate=obs_analyze.make_annotator(actuals, per_shard))
        return obs_analyze.Analyzed(text=text, results=results,
                                    stats=stats, span=root,
                                    actuals=actuals, per_shard=per_shard)

    def execute_many(self, queries: Sequence[q.HybridQuery],
                     plans: Optional[Sequence] = None
                     ) -> List[Tuple[List[ResultRow], ExecStats]]:
        t0 = time.perf_counter()
        with obs_trace.span("query", n=len(queries),
                            shards=self.n_shards) as sp:
            out = self._execute_many(queries, plans)
        elapsed = time.perf_counter() - t0
        self.metrics["queries"] += len(queries)
        self.metrics["batches"] += 1
        self.metrics["exec_time_s"] += elapsed
        REGISTRY.observe("query.latency_s", elapsed)
        REGISTRY.inc("query.count", len(queries))
        if SLOW_LOG.threshold_s is not None and out:
            SLOW_LOG.maybe_record(
                elapsed, out[0][1].plan,
                span=sp if getattr(sp, "live", False) else None,
                n_queries=len(queries), shards=self.n_shards)
        return out

    def _execute_many(self, queries: Sequence[q.HybridQuery],
                      plans: Optional[Sequence] = None
                      ) -> List[Tuple[List[ResultRow], ExecStats]]:
        queries = list(queries)
        given = list(plans) if plans is not None else [None] * len(queries)
        logical: List[planner_lib.Plan] = []
        for qq, p in zip(queries, given):
            if isinstance(p, ShardedPlan):
                p = p.logical
            logical.append(p if p is not None else self._plan_logical(qq))

        # batch-aware re-planning, mirroring Executor.execute_many:
        # enough structurally-identical exact-NN queries make one shared
        # scan per shard cheaper than per-query NRA walks — and unlock
        # the fused packed dispatch the cross-shard merge feeds on
        nra_groups: Dict[tuple, List[int]] = {}
        for i, (qq, p, g) in enumerate(zip(queries, logical, given)):
            if g is None and p.kind == "nra":
                nra_groups.setdefault(
                    ops.rank_signature(qq.ranks), []).append(i)
        for idxs in nra_groups.values():
            if len(idxs) >= MIN_SHARED_SCAN_BATCH:
                for i in idxs:
                    logical[i] = planner_lib.plan_shared_scan(
                        self.catalog, queries[i])
                    logical[i].operator_tree(self.catalog)

        # scatter: every shard executes the whole batch under the SAME
        # logical plans (per-shard executors share this thread, so each
        # shard's kernel-dispatch delta lands in its own ExecStats).
        # Calling the shard executors' inner entry point keeps shard
        # sub-batches out of the facade's query-latency histogram; the
        # per-shard spans scope EXPLAIN ANALYZE's per-shard actuals.
        with obs_trace.span("operator:ShardFanout",
                            n=len(self.executors)):
            per_shard = []
            for i, ex in enumerate(self.executors):
                with obs_trace.span("shard", shard=i):
                    per_shard.append(
                        ex._execute_many(queries, plans=list(logical)))

        # gather: aggregate per-shard ExecStats into one per query
        n = self.n_shards
        described: Dict[int, str] = {}
        stats_all: List[ExecStats] = []
        for i, plan in enumerate(logical):
            if id(plan) not in described:
                described[id(plan)] = self.describe(plan)
            agg = ExecStats(plan=described[id(plan)], shards=n)
            for s in range(n):
                st = per_shard[s][i][1]
                agg.blocks_read += st.blocks_read
                agg.rows_scanned += st.rows_scanned
                agg.kernel_launches += st.kernel_launches
                agg.bytes_to_host += st.bytes_to_host
                agg.bytes_scanned += st.bytes_scanned
                agg.rerank_rows += st.rerank_rows
                agg.jit_shape_misses += st.jit_shape_misses
                agg.shard_rows_max = max(agg.shard_rows_max,
                                         st.rows_scanned)
            stats_all.append(agg)

        # combine: NN queries through the device merge (grouped by k so
        # one batched kernel call serves each group), filter queries by
        # pk-disjoint concatenation
        results: List[Optional[List[ResultRow]]] = [None] * len(queries)
        nn_groups: Dict[int, List[int]] = {}
        for i, (qq, plan) in enumerate(zip(queries, logical)):
            if qq.is_nn and plan.kind != "empty":
                nn_groups.setdefault(qq.k, []).append(i)
            else:
                with obs_trace.span("operator:ShardConcat") as csp:
                    results[i] = self._concat_filter(
                        [per_shard[s][i][0] for s in range(n)])
                    if csp.live:
                        csp.set(out_rows=len(results[i]))
        for k, idxs in nn_groups.items():
            before = kops.stats_snapshot()
            with obs_trace.span("operator:CrossShardTopKMerge",
                                k=k) as msp:
                merged = self._merge_topk(
                    [[per_shard[s][i][0] for s in range(n)]
                     for i in idxs], k)
                if msp.live:
                    msp.set(out_rows=sum(len(m) for m in merged))
            launches, byts, misses = kops.stats_snapshot()
            self.metrics["merges"] += 1
            for i, rows in zip(idxs, merged):
                results[i] = rows
                st = stats_all[i]
                st.kernel_launches += launches - before[0]
                st.bytes_to_host += byts - before[1]
                st.jit_shape_misses += misses - before[2]
                st.merge_rows = sum(len(per_shard[s][i][0])
                                    for s in range(n))
                self.metrics["merge_rows"] += st.merge_rows
        return list(zip(results, stats_all))

    # ------------------------------------------------------------ combine
    @staticmethod
    def _concat_filter(shard_lists: List[List[ResultRow]]
                       ) -> List[ResultRow]:
        rows = [r for rows in shard_lists for r in rows]
        rows.sort(key=lambda r: (r.score, r.pk))
        return rows

    def _merge_topk(self, groups: List[List[List[ResultRow]]], k: int
                    ) -> List[List[ResultRow]]:
        """Merge each query's per-shard top-k lists (already cut to <= k
        and (score, pk)-sorted by the per-shard pipeline) into the global
        top-k via ONE batched device merge; winning pks map back to their
        per-shard ``ResultRow``s, so scores and materialized values are
        byte-identical to the shard pipeline's output."""
        nq, n = len(groups), self.n_shards
        if nq == 0:
            return []
        d = np.full((nq, n, max(1, k)), np.inf, np.float32)
        ids = np.zeros((nq, n, max(1, k)), np.int64)
        lookups: List[Dict[int, ResultRow]] = []
        for qi, shard_lists in enumerate(groups):
            lookup: Dict[int, ResultRow] = {}
            for s, rows in enumerate(shard_lists):
                for j, r in enumerate(rows):
                    d[qi, s, j] = np.float32(r.score)
                    ids[qi, s, j] = r.pk
                    lookup[int(r.pk)] = r
            lookups.append(lookup)
        _, mi = kops.merge_topk_batch(d, ids, k)
        return [[lookups[qi][int(pk)] for pk in mi[qi] if pk >= 0]
                for qi in range(nq)]
