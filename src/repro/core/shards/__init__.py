"""Sharded serving subsystem: hash-partitioned multi-shard LSM with
scatter-gather execution and device-side cross-shard top-k merge.

The single ``LSMStore`` data plane scales out here: ``ShardRouter``
hash-partitions ingest by pk across N independent LSM shards (each with
its own memtable, flush scheduler, compaction tiers and per-segment
secondary indexes); ``ShardedExecutor`` fans hybrid queries out to every
shard's full pipeline — fused kernels, bitmap operators, visibility,
memtable overlay — and combines per-shard top-ks on device so the host
only ever sees O(shards * k) rows; ``ShardedContinuousEngine`` aggregates
per-shard write deltas for Type 3/4 subscriptions.  The facade entry
point is ``Database(schema, shards=N)`` (core/api.py).
"""
from repro.core.shards.continuous import ShardedContinuousEngine  # noqa: F401
from repro.core.shards.executor import (ShardedExecutor,  # noqa: F401
                                        ShardedPlan)
from repro.core.shards.router import ShardRouter, hash_pks  # noqa: F401

__all__ = ["ShardRouter", "ShardedExecutor", "ShardedPlan",
           "ShardedContinuousEngine", "hash_pks"]
