"""Continuous queries over a sharded table (paper Types 3/4, scaled out).

Aggregates per-shard write deltas into one scheduling state: every
shard's ``on_delta`` hook feeds the same engine, so an ASYNC subscription
goes dirty when ANY shard ingests, and due queries re-execute through the
scatter-gather ``ShardedExecutor`` in a single ``execute_many`` batch
(amortizing each shard's segment sweep across all due queries, exactly
like the single-store engine).

Semantics match ``ContinuousEngine(mode="none")``: full re-execution per
due tick.  Incremental materialized views do not span shards yet —
per-shard view maintenance with cross-shard rewrite is a future PR; the
registration/advance surface is identical so the facade's
``Subscription`` handles work unchanged.
"""
from __future__ import annotations

import time as _time
from typing import Dict, List, Tuple

from repro.core import query as q
from repro.core.continuous import Registered
from repro.obs import REGISTRY
from repro.obs import trace as obs_trace


class ShardedContinuousEngine:
    mode = "none"

    def __init__(self, store, executor=None):
        from repro.core.shards.executor import ShardedExecutor
        self.store = store                       # ShardRouter
        self.executor = executor if executor is not None \
            else ShardedExecutor(store)
        self.registered: Dict[int, Registered] = {}
        self._next_id = 0
        self.metrics = {"executions": 0, "exec_time_s": 0.0,
                        "delta_batches": 0}
        store.on_delta(self._on_delta)           # hooked on EVERY shard

    # --------------------------------------------------------- registration
    def register(self, decl) -> int:
        rid = self._next_id
        self._next_id += 1
        reg = Registered(decl=decl)
        if isinstance(reg.decl, q.SyncQuery):
            reg.next_due = 0.0
        self.registered[rid] = reg
        return rid

    # --------------------------------------------------------------- deltas
    def _on_delta(self, pks, batch, deleted) -> None:
        """One call per shard sub-batch; any shard's write dirties every
        ASYNC subscription (the query may match rows on that shard)."""
        self.metrics["delta_batches"] += 1
        for reg in self.registered.values():
            if isinstance(reg.decl, q.AsyncQuery):
                reg.dirty = True

    # ------------------------------------------------------------ execution
    def advance(self, now: float) -> Dict[int, List]:
        """Run everything due at virtual time ``now``: SYNC queries by
        interval, ASYNC queries when any shard changed since their last
        run.  All due queries share one scatter-gather batch."""
        due: List[Tuple[int, Registered]] = []
        for rid, reg in self.registered.items():
            if isinstance(reg.decl, q.SyncQuery):
                if now >= reg.next_due:
                    due.append((rid, reg))
                    reg.next_due = now + reg.decl.interval_s
            else:
                if reg.dirty:
                    due.append((rid, reg))
                    reg.dirty = False
        out: Dict[int, List] = {}
        if not due:
            return out
        adv0 = _time.perf_counter()
        with obs_trace.span("advance", due=len(due)):
            t0 = _time.perf_counter()
            many = self.executor.execute_many(
                [reg.decl.query for _, reg in due])
            for (rid, reg), (res, _) in zip(due, many):
                out[rid] = res
                reg.runs += 1
                reg.last_result = res
                self.metrics["executions"] += 1
                self.metrics["exec_time_s"] += _time.perf_counter() - t0
                t0 = _time.perf_counter()
        REGISTRY.observe("continuous.advance_s",
                         _time.perf_counter() - adv0)
        REGISTRY.inc("continuous.advances")
        return out

    def snapshot_query(self, query: q.HybridQuery) -> Tuple[List, bool]:
        """One-shot scatter-gather execution (no view rewriting)."""
        res, _ = self.executor.execute(query)
        return res, False
