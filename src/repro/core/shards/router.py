"""Hash-partitioned write/read routing across N independent LSM shards.

``ShardRouter`` is the scale-out data plane's store surface: it owns N
``LSMStore`` shards and presents the same columnar API (`put`/`delete`/
`get`/`flush`/`drain`/`on_delta`, aggregated `metrics` and row counts),
so the facade's ``Table`` swaps it in transparently.  Rows are routed by
a SplitMix64 hash of the pk — every version of a pk (puts, updates,
tombstones) lands on the same shard, which makes per-shard MVCC
visibility resolution globally correct and keeps shard pk sets disjoint
(the property the exact cross-shard top-k merge relies on).

Routing is fully vectorized: one hash + stable argsort per batch, then
sliced per-shard sub-batches in original relative order (so per-shard
seqno order preserves the caller's write order and the ``unique_pks``
fast path survives monotonic ingest).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import memtable as mt
from repro.core.lsm import LSMConfig, LSMStore
from repro.core.types import Schema

_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def hash_pks(pks: Sequence[int]) -> np.ndarray:
    """SplitMix64 finalizer, vectorized over an int64 pk array.

    Decorrelates the key pattern from the shard choice so partitioning
    stays balanced for sequential, strided, or clustered pks alike; the
    wrap-around uint64 arithmetic is numpy's native behavior."""
    x = np.asarray(pks, np.int64).astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * _MIX1
    x = (x ^ (x >> np.uint64(27))) * _MIX2
    return x ^ (x >> np.uint64(31))


class ShardRouter:
    """N independent ``LSMStore`` shards behind one store-shaped surface.

    Each shard runs the complete single-store write path — its own
    memtable, flush scheduler, size-tiered compaction and per-segment
    secondary indexes — so ingest work parallelizes shard-wise without
    any cross-shard coordination.  Reads go through ``ShardedExecutor``
    (core/shards/executor.py), which fans queries out and merges."""

    def __init__(self, schema: Schema, cfg: Optional[LSMConfig] = None,
                 n_shards: int = 2,
                 index_factory: Optional[Callable] = None):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.schema = schema
        self.cfg = cfg or LSMConfig()
        self.n_shards = int(n_shards)
        # durable routers give every shard its own subdirectory — each
        # shard is a complete single-store durability domain (own WAL,
        # own manifest), so shard recoveries are independent
        shard_cfgs = [
            dataclasses.replace(
                self.cfg, path=os.path.join(self.cfg.path, f"shard-{i:04d}"))
            if self.cfg.path else self.cfg
            for i in range(self.n_shards)]
        self.shards: List[LSMStore] = [
            LSMStore(schema, shard_cfgs[i], index_factory)
            for i in range(self.n_shards)]
        self._cols = {c.name: c for c in schema.columns}

    # ------------------------------------------------------------ routing
    def shard_of(self, pks: Sequence[int]) -> np.ndarray:
        """Owning shard id per pk (deterministic, version-stable)."""
        return (hash_pks(pks) % np.uint64(self.n_shards)).astype(np.int64)

    def _split(self, pks: np.ndarray
               ) -> Iterator[Tuple[int, np.ndarray]]:
        """Yield ``(shard_id, positions)`` for each non-empty shard's
        slice of the batch; positions preserve original relative order
        (stable argsort), so per-shard write order mirrors the caller's."""
        sid = self.shard_of(pks)
        order = np.argsort(sid, kind="stable")
        bounds = np.searchsorted(sid[order], np.arange(self.n_shards + 1))
        for s in range(self.n_shards):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            if lo < hi:
                yield s, order[lo:hi]

    # -------------------------------------------------------------- write
    def put(self, pks: Sequence[int], batch: Dict[str, Any]) -> None:
        """Partition one columnar batch by pk hash and forward each
        shard's sub-batch whole — O(#columns) canonical conversions plus
        one fancy-index slice per shard, never a per-row loop."""
        pks = np.asarray(pks, np.int64)
        if len(pks) == 0:
            return
        if self.n_shards == 1:
            self.shards[0].put(pks, batch)
            return
        cols = {name: mt.as_column_array(self._cols[name], vals, len(pks))
                if name in self._cols else np.asarray(vals)
                for name, vals in batch.items()}
        for s, idx in self._split(pks):
            self.shards[s].put(pks[idx],
                               {name: arr[idx] for name, arr in cols.items()})

    insert = put

    def delete(self, pks: Sequence[int]) -> None:
        """Tombstones go to each pk's owning shard only; a shard that
        never saw the pk is never touched (its ``unique_pks`` fast path
        survives)."""
        pks = np.asarray(pks, np.int64)
        if len(pks) == 0:
            return
        for s, idx in self._split(pks):
            self.shards[s].delete(pks[idx])

    def on_delta(self, fn: Callable) -> None:
        """Register a write hook on EVERY shard; callers receive the
        per-shard sub-batches (columnar, same signature as the
        single-store hook) — the continuous engine aggregates them."""
        for sh in self.shards:
            sh.on_delta(fn)

    # ------------------------------------------------- flush / compaction
    def seal(self) -> bool:
        return any([sh.seal() for sh in self.shards])

    def flush(self) -> List:
        """Seal + drain every shard; returns the flushed segments."""
        out = []
        for sh in self.shards:
            seg = sh.flush()
            if seg is not None:
                out.append(seg)
        return out

    def drain(self) -> List:
        """Deterministically finish every shard's queued flush/compaction
        work (pipelined configs); returns all segments flushed."""
        out = []
        for sh in self.shards:
            out.extend(sh.drain())
        return out

    # --------------------------------------------------------- durability
    def set_faults(self, faults, shard: int = 0) -> None:
        """Arm a fault injector on ONE shard (crash-matrix tests kill a
        single shard; the others keep running, as independent processes
        would)."""
        self.shards[int(shard)].set_faults(faults)

    def durable_seqnos(self) -> List[int]:
        """Per-shard acknowledgement frontiers (seqnos are per-shard
        counters, so there is no meaningful global aggregate)."""
        return [sh.durable_seqno for sh in self.shards]

    def close(self) -> None:
        """Close every shard (idempotent): stop background workers, seal
        and fsync each WAL."""
        for sh in self.shards:
            sh.close()

    def snapshot(self, path: str) -> None:
        """Flush and copy every shard into ``path/shard-%04d`` — opening
        a router with ``cfg.path`` pointing at the snapshot root (same
        ``n_shards``) restores it."""
        for i, sh in enumerate(self.shards):
            sh.snapshot(os.path.join(path, f"shard-{i:04d}"))

    # --------------------------------------------------------------- read
    def get(self, key: int) -> Optional[Dict[str, Any]]:
        return self.shards[int(self.shard_of([key])[0])].get(int(key))

    def all_segments(self) -> List:
        return [s for sh in self.shards for s in sh.segments]

    @property
    def segments(self) -> List:
        """Merged per-shard segment lists (stats / EXPLAIN; execution
        always iterates each shard's own list)."""
        return self.all_segments()

    @property
    def n_rows(self) -> int:
        return sum(sh.n_rows for sh in self.shards)

    @property
    def memtable_rows(self) -> int:
        return sum(sh.memtable_rows for sh in self.shards)

    @property
    def unique_pks(self) -> bool:
        """Global uniqueness: shards hold disjoint pk sets by routing, so
        every-shard-unique implies globally unique."""
        return all(sh.unique_pks for sh in self.shards)

    @property
    def metrics(self) -> Dict[str, float]:
        """Element-wise sum of the per-shard metrics dicts."""
        out: Dict[str, float] = {}
        for sh in self.shards:
            for key, val in sh.metrics.items():
                out[key] = out.get(key, 0) + val
        return out

    def shard_rows(self) -> List[int]:
        """Per-shard row counts (balance diagnostics / benchmarks)."""
        return [sh.n_rows for sh in self.shards]
