"""Composable physical operators over columnar batches (paper §5).

The read path is a pipeline of physical operators that pass columnar
batches — never per-row Python loops:

  SegmentScan / IndexProbe   leaf sources: per-segment candidate bitmaps
  FilterBitmap               residual predicates ANDed into the bitmaps
  RankScore                  batched distance kernels over the bitmap union
  VisibilityResolve          shared lexsort-based MVCC winner filtering
  MemtableOverlay            brute-force scan of the RAM write buffer
  TopKMerge                  per-query (score, pk) merge and cut

Every operator doubles as an EXPLAIN node (``explain()`` renders the tree
with per-operator cost estimates) and as an execution unit.  Execution is
*multi-query*: a ``PipelineContext`` carries a batch of queries, leaf
scans are shared across the batch (each predicate bitmap is computed once
per segment, whatever the batch size), and ``RankScore`` stacks the batch
query vectors into single ``l2_distances(Q, X)`` kernel calls — N
sequential segment sweeps become one.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import query as q
from repro.obs import trace as obs_trace
from repro.core import visibility as vis_lib
from repro.core.index.text import tokenize
from repro.core.optimizer.cost import (C_FILTER_BLOCK, C_MERGE,
                                       C_RERANK_ROW, C_ROW_RESIDUAL,
                                       C_VECTOR_BLOCK, conjunct_passing)
from repro.core.types import BLOCK_ROWS
from repro.kernels import ops as kops


@dataclasses.dataclass
class ExecStats:
    blocks_read: float = 0.0
    rows_scanned: int = 0
    plan: str = ""
    # kernel-dispatch accounting (deltas of kernels.ops.stats_snapshot()
    # around this query's execution; batched queries in one scan group
    # share kernel calls, so — like blocks_read for shared bitmaps — each
    # member is charged the group's full delta to stay comparable with
    # sequential execution; benchmarks measuring fleet totals diff
    # stats_snapshot() themselves)
    kernel_launches: int = 0
    bytes_to_host: int = 0
    jit_shape_misses: int = 0
    # sharded scatter-gather accounting (core/shards ShardedExecutor):
    # fan-out width (0 = unsharded execution), candidate rows entering the
    # cross-shard device merge (bounded by shards * k), and the critical
    # path — rows scanned on the busiest shard (the wall-clock proxy when
    # shards execute in parallel)
    shards: int = 0
    merge_rows: int = 0
    shard_rows_max: int = 0
    # read-path bandwidth accounting (logical bytes, machine-independent):
    # rank-column bytes streamed for this query's candidate generation —
    # the quantized dispatch reads m code bytes/row instead of 4*d fp32
    # bytes, plus 4*d for each of the rerank_rows it re-scores exactly
    bytes_scanned: int = 0
    rerank_rows: int = 0


@dataclasses.dataclass
class ResultRow:
    pk: int
    score: float
    values: Dict[str, Any]


# ---------------------------------------------------------------------------
# predicate evaluation (segment bitmaps + materialized rows)
# ---------------------------------------------------------------------------

def vrange_mask(d2: np.ndarray, thresh: float) -> np.ndarray:
    """VectorRange admission from SQUARED distances: d < r compared as
    d2 < r*r — same rows, no full-matrix sqrt pass.  (sqrt is monotone
    and d2 is clamped >= 0 by construction; r <= 0 admits nothing, as
    sqrt(d2) >= 0 > r did before.)"""
    if thresh <= 0:
        return np.zeros(d2.shape, bool)
    return d2 < float(thresh) * float(thresh)


def eval_predicate_seg(seg, pred, stats: ExecStats,
                       use_index: bool = True) -> np.ndarray:
    """Bool mask over segment rows for one predicate.  Accepts any filter
    expression — And/Or recurse over their children's masks — so a
    ``residual`` slot can hold a whole sub-expression (the degenerate
    full-scan fallback for arbitrary boolean shapes)."""
    if isinstance(pred, q.Not):
        # complementing an APPROXIMATE bitmap (IVF probes a subset of
        # lists) would re-admit rows the user excluded; the vector leaf
        # must take the exact kernel path under negation
        exact_needed = isinstance(pred.child, q.VectorRange)
        return ~eval_predicate_seg(seg, pred.child, stats,
                                   use_index=use_index and not exact_needed)
    if isinstance(pred, q.And):
        m = np.ones(seg.n_rows, bool)
        for c in pred.children:
            m &= eval_predicate_seg(seg, c, stats, use_index=use_index)
        return m
    if isinstance(pred, q.Or):
        m = np.zeros(seg.n_rows, bool)
        for c in pred.children:
            m |= eval_predicate_seg(seg, c, stats, use_index=use_index)
        return m
    idx = seg.indexes.get(getattr(pred, "col", None)) if use_index else None
    if idx is not None:
        try:
            mask = idx.bitmap(seg, pred)
            stats.blocks_read += idx.probe_cost_blocks(seg, pred)
            return mask
        except NotImplementedError:
            pass
    # kernel fallback (full column scan)
    stats.blocks_read += seg.n_blocks
    if isinstance(pred, q.Range):
        col = np.asarray(seg.columns[pred.col], np.float32)[:, None]
        return kops.range_bitmap(col, np.asarray([[pred.lo, pred.hi]]))
    if isinstance(pred, q.GeoWithin):
        return kops.rect_filter(np.asarray(seg.columns[pred.col],
                                           np.float32), pred.rect)
    if isinstance(pred, q.TextContains):
        term = pred.term.lower()
        return np.asarray([term in tokenize(t)
                           for t in seg.columns[pred.col]], bool)
    if isinstance(pred, q.VectorRange):
        d2 = kops.l2_distances(
            pred.q[None, :], np.asarray(seg.columns[pred.col],
                                        np.float32))[0]
        return vrange_mask(d2, pred.thresh)
    raise TypeError(f"unknown predicate {pred!r}")


def eval_predicate_rows(row_values: Dict[str, np.ndarray], pred) -> np.ndarray:
    """Predicate over materialized rows (memtable / residual eval).
    Accepts any filter expression — And/Or recurse."""
    if isinstance(pred, q.Not):
        return ~eval_predicate_rows(row_values, pred.child)
    if isinstance(pred, (q.And, q.Or)):
        return eval_expr_rows(row_values, pred)
    if isinstance(pred, q.Range):
        v = np.asarray(row_values[pred.col], np.float64)
        return (v >= pred.lo) & (v <= pred.hi)
    if isinstance(pred, q.GeoWithin):
        return kops.rect_filter(np.asarray(row_values[pred.col],
                                           np.float32), pred.rect)
    if isinstance(pred, q.TextContains):
        term = pred.term.lower()
        return np.asarray([term in tokenize(t)
                           for t in row_values[pred.col]], bool)
    if isinstance(pred, q.VectorRange):
        vecs = np.asarray(row_values[pred.col], np.float32)
        if len(vecs) == 0:
            return np.zeros((0,), bool)
        return vrange_mask(kops.l2_distances(pred.q[None, :], vecs)[0],
                           pred.thresh)
    raise TypeError(f"unknown predicate {pred!r}")


def eval_expr_rows(row_values: Dict[str, np.ndarray], expr) -> np.ndarray:
    """Boolean filter expression tree over materialized rows.

    ``row_values`` must contain every column the expression references
    (``q.expr_cols``).  ``None`` means "no filter" (all rows pass)."""
    n = len(next(iter(row_values.values()))) if row_values else 0
    if expr is None:
        return np.ones(n, bool)
    if isinstance(expr, q.And):
        out = np.ones(n, bool)
        for c in expr.children:
            out &= eval_expr_rows(row_values, c)
            if not out.any():
                break
        return out
    if isinstance(expr, q.Or):
        out = np.zeros(n, bool)
        for c in expr.children:
            out |= eval_expr_rows(row_values, c)
            if out.all():
                break
        return out
    if isinstance(expr, q.Not):
        return ~eval_expr_rows(row_values, expr.child)
    return eval_predicate_rows(row_values, expr)


def pred_cache_key(pred) -> Tuple:
    """Hashable identity for a predicate (VectorRange holds an ndarray)."""
    if isinstance(pred, q.Not):
        return ("not",) + pred_cache_key(pred.child)
    if isinstance(pred, (q.And, q.Or)):
        return (type(pred).__name__.lower(),) + tuple(
            pred_cache_key(c) for c in pred.children)
    if isinstance(pred, q.Range):
        return ("range", pred.col, pred.lo, pred.hi)
    if isinstance(pred, q.GeoWithin):
        return ("geo", pred.col, tuple(pred.rect))
    if isinstance(pred, q.TextContains):
        return ("text", pred.col, pred.term)
    if isinstance(pred, q.VectorRange):
        return ("vrange", pred.col, pred.q.tobytes(), pred.thresh)
    return ("id", id(pred))


# ---------------------------------------------------------------------------
# rank-distance evaluation (exact; single-query and batched)
# ---------------------------------------------------------------------------

def rank_distances(values: Dict[str, np.ndarray], rank, seg=None,
                   rows: Optional[np.ndarray] = None) -> np.ndarray:
    if isinstance(rank, q.VectorRank):
        vecs = np.asarray(values[rank.col], np.float32)
        if len(vecs) == 0:
            return np.zeros((0,), np.float32)
        return np.sqrt(np.maximum(
            kops.l2_distances(rank.q[None, :], vecs)[0], 0))
    if isinstance(rank, q.SpatialRank):
        pts = np.asarray(values[rank.col], np.float32)
        p = np.asarray(rank.point, np.float32)
        if len(pts) == 0:
            return np.zeros((0,), np.float32)
        return np.sqrt(((pts - p) ** 2).sum(axis=1))
    if isinstance(rank, q.TextRank):
        out = np.empty(len(values[rank.col]), np.float32)
        qterms = [t.lower() for t in rank.terms]
        for i, text in enumerate(values[rank.col]):
            toks = tokenize(text)
            score = sum(toks.count(t) for t in qterms) / (len(toks) + 1.0)
            out[i] = 1.0 / (1.0 + score * 10.0)
        return out
    raise TypeError(f"unknown rank {rank!r}")


def combined_scores(values: Dict[str, np.ndarray], ranks) -> np.ndarray:
    n = len(next(iter(values.values()))) if values else 0
    total = np.zeros(n, np.float32)
    for r in ranks:
        total += r.weight * rank_distances(values, r)
    return total


def rank_signature(ranks) -> Tuple:
    """Queries with equal signatures can share one batched kernel call."""
    return tuple((type(r).__name__, r.col) for r in ranks)


def batched_rank_scores(values: Dict[str, np.ndarray],
                        rank_lists: Sequence[Sequence]) -> np.ndarray:
    """Weighted-sum scores for a batch of structurally-identical rank
    lists -> (nq, n).  Vector and spatial modalities stack the batch's
    query points into one ``l2_distances(Q, X)`` kernel call."""
    nq = len(rank_lists)
    n = len(next(iter(values.values()))) if values else 0
    total = np.zeros((nq, n), np.float32)
    for j in range(len(rank_lists[0])):
        terms = [rl[j] for rl in rank_lists]
        r0 = terms[0]
        w = np.asarray([t.weight for t in terms], np.float32)[:, None]
        if isinstance(r0, (q.VectorRank, q.SpatialRank)):
            pts = np.asarray(values[r0.col], np.float32)
            Q = np.stack([np.asarray(
                t.q if isinstance(t, q.VectorRank) else t.point, np.float32)
                for t in terms])
            D = np.sqrt(np.maximum(kops.l2_distances(Q, pts), 0))
        else:
            D = np.stack([rank_distances(values, t) for t in terms])
        total += w * D
    return total


# ---------------------------------------------------------------------------
# execution context: one per query batch
# ---------------------------------------------------------------------------

class PipelineContext:
    """Shared state for executing a batch of queries in one pipeline pass:
    per-(segment, predicate) bitmap cache, global-index pruning sets, the
    shared visibility index, and memtable arrays."""

    def __init__(self, store, catalog, queries, plans,
                 stats: List[ExecStats],
                 pred_cache: Optional[Dict] = None):
        self.store = store
        self.catalog = catalog
        self.queries = list(queries)
        self.plans = list(plans)
        self.stats = list(stats)
        self.nq = len(self.queries)
        self._pred_cache: Dict = pred_cache if pred_cache is not None else {}
        self._mt_pred: Dict = {}
        # snapshot the store's shared state under its lock: every operator
        # in this pass reads ctx.segments / ctx.memtable_arrays() so the
        # whole batch executes against ONE consistent store state even
        # while a background flush republishes mid-pass
        lock = getattr(store, "_lock", None)
        with lock if lock is not None else contextlib.nullcontext():
            self.segments: List = list(store.segments)
            self._mt = store.memtable_arrays()
            if not store.unique_pks:
                # eagerly pin the matching visibility index; resolving it
                # lazily could pick up a post-flush index whose winner
                # rows don't exist in the snapshotted segment list
                self._vis = vis_lib.visibility_index(store)
            else:
                self._vis = None
            # zone-map pruning per query (filter plans only, matching the
            # sequential executor: NN scans visit every segment)
            self._allowed: List[Optional[set]] = []
            for qq, plan in zip(self.queries, self.plans):
                if plan.kind in ("full_scan", "index_intersect"):
                    preds = plan.indexed or plan.residual
                    segs = self.segments
                    for p in preds:
                        segs = store.global_index.prune(segs, p)
                    self._allowed.append({s.seg_id for s in segs})
                elif plan.kind == "union":
                    # a segment is needed if ANY conjunct may match in it
                    allowed: set = set()
                    for sub in plan.subplans:
                        segs = self.segments
                        for p in list(sub.indexed) + list(sub.residual):
                            segs = store.global_index.prune(segs, p)
                        allowed |= {s.seg_id for s in segs}
                    self._allowed.append(allowed)
                else:
                    self._allowed.append(None)

    # ------------------------------------------------------------- caches
    @property
    def visibility(self):
        return self._vis

    def allowed(self, qi: int, seg) -> bool:
        a = self._allowed[qi]
        return a is None or seg.seg_id in a

    def pred_mask(self, seg, pred, use_index: bool
                  ) -> Tuple[np.ndarray, float]:
        """(bool mask over segment rows, block cost) — computed once per
        (segment, predicate) whatever the batch size; the block cost is
        charged to every query that uses the mask so per-query stats stay
        comparable with sequential execution."""
        key = (seg.seg_id, use_index, pred_cache_key(pred))
        hit = self._pred_cache.get(key)
        if hit is None:
            s = ExecStats()
            mask = eval_predicate_seg(seg, pred, s, use_index=use_index)
            hit = (mask, s.blocks_read)
            self._pred_cache[key] = hit
        return hit

    def memtable_arrays(self):
        # sealed-aware (includes memtables queued for flush), captured at
        # snapshot time in __init__
        return self._mt

    def memtable_pred_mask(self, pred) -> np.ndarray:
        key = pred_cache_key(pred)
        hit = self._mt_pred.get(key)
        if hit is None:
            _, _, _, cols = self.memtable_arrays()
            hit = eval_predicate_rows(cols, pred)
            self._mt_pred[key] = hit
        return hit

    def memtable_expr_mask(self, expr) -> np.ndarray:
        """Filter expression tree over the memtable, with per-literal
        mask caching shared across the batch."""
        pk, _, _, _ = self.memtable_arrays()
        if expr is None:
            return np.ones(len(pk), bool)
        if q.is_literal(expr):
            return self.memtable_pred_mask(expr)
        if isinstance(expr, q.And):
            out = np.ones(len(pk), bool)
            for c in expr.children:
                out &= self.memtable_expr_mask(c)
            return out
        if isinstance(expr, q.Or):
            out = np.zeros(len(pk), bool)
            for c in expr.children:
                out |= self.memtable_expr_mask(c)
            return out
        if isinstance(expr, q.Not):
            return ~self.memtable_expr_mask(expr.child)
        raise TypeError(f"unknown filter expression {expr!r}")


@dataclasses.dataclass
class Candidates:
    """Per-query columnar candidate set: parallel arrays of (segment id,
    row index, score).  ``sid == -1`` denotes a memtable row."""
    sids: np.ndarray
    rows: np.ndarray
    scores: np.ndarray

    @staticmethod
    def empty() -> "Candidates":
        return Candidates(np.zeros(0, np.int64), np.zeros(0, np.int64),
                          np.zeros(0, np.float32))

    @staticmethod
    def concat(parts: List["Candidates"]) -> "Candidates":
        if not parts:
            return Candidates.empty()
        return Candidates(np.concatenate([p.sids for p in parts]),
                          np.concatenate([p.rows for p in parts]),
                          np.concatenate([p.scores for p in parts]))


# ---------------------------------------------------------------------------
# physical operators
# ---------------------------------------------------------------------------

class PhysicalOp:
    name = "Op"

    def __init__(self, children: Sequence["PhysicalOp"] = (),
                 detail: str = "", est_cost: float = 0.0,
                 est_rows: float = 0.0):
        self.children = list(children)
        self.detail = detail
        self.est_cost = est_cost
        self.est_rows = est_rows

    def explain(self, indent: int = 0, annotate=None) -> str:
        """EXPLAIN rendering; ``annotate`` is an optional callback
        ``node -> suffix`` used by EXPLAIN ANALYZE to append actuals —
        the cached plain rendering never passes one."""
        pad = "  " * indent
        head = f"{pad}-> {self.name}"
        if self.detail:
            head += f" [{self.detail}]"
        head += f" cost={self.est_cost:.1f}"
        if annotate is not None:
            head += annotate(self)
        lines = [head]
        for c in self.children:
            lines.append(c.explain(indent + 1, annotate))
        return "\n".join(lines)

    # -- execution interface (leaf sources / transforms override) --------
    def batches(self, ctx: PipelineContext
                ) -> Iterator[Tuple[Any, np.ndarray]]:
        """Yield (segment, mask (nq, n_rows) bool) columnar batches.
        When tracing is on the drain is wrapped so each source records
        one ``operator:<Name>`` span; the disabled path returns the raw
        generator (zero per-batch overhead)."""
        if not obs_trace.enabled():
            return self._batches(ctx)
        return _traced_batches(self, ctx)

    def _batches(self, ctx: PipelineContext
                 ) -> Iterator[Tuple[Any, np.ndarray]]:
        raise NotImplementedError(self.name)


def _stat_sums(stats: List[ExecStats]) -> Tuple[float, int, int]:
    blocks = 0.0
    rows = nbytes = 0
    for s in stats:
        blocks += s.blocks_read
        rows += s.rows_scanned
        nbytes += s.bytes_scanned
    return blocks, rows, nbytes


def _traced_batches(op: PhysicalOp, ctx: PipelineContext
                    ) -> Iterator[Tuple[Any, np.ndarray]]:
    """Timed drain of a source generator: each ``next()`` window runs
    only the source's own code (consumers work between yields), so the
    ``ExecStats`` delta across the windows is exactly what this operator
    charged.  Nested sources (FilterBitmap over IndexProbe) attribute
    exclusively via a ctx-level accumulator of inner-drain charges; one
    completed span is recorded at exhaustion."""
    acc = getattr(ctx, "_drain_acc", None)
    if acc is None:
        acc = ctx._drain_acc = [0.0, 0, 0]
    gen = op._batches(ctx)
    total = 0.0
    blocks = 0.0
    rows = nbytes = out_rows = 0
    while True:
        pre = _stat_sums(ctx.stats)
        in0 = (acc[0], acc[1], acc[2])
        t0 = time.perf_counter()
        try:
            item = next(gen)
        except StopIteration:
            item = None
        total += time.perf_counter() - t0
        post = _stat_sums(ctx.stats)
        blocks += (post[0] - pre[0]) - (acc[0] - in0[0])
        rows += (post[1] - pre[1]) - (acc[1] - in0[1])
        nbytes += (post[2] - pre[2]) - (acc[2] - in0[2])
        if item is None:
            break
        out_rows += int(item[1].sum())
        yield item
    acc[0] += blocks
    acc[1] += rows
    acc[2] += nbytes
    obs_trace.record_span("operator:" + op.name, total, rows=rows,
                          bytes=nbytes, blocks=blocks, out_rows=out_rows)


class SegmentScan(PhysicalOp):
    """Leaf: every row of every (unpruned) segment."""
    name = "SegmentScan"

    def _batches(self, ctx):
        for seg in ctx.segments:
            if seg.n_rows == 0:
                continue
            mask = np.zeros((ctx.nq, seg.n_rows), bool)
            for qi in range(ctx.nq):
                if ctx.allowed(qi, seg):
                    mask[qi, :] = True
            if mask.any():
                yield seg, mask


class IndexProbe(PhysicalOp):
    """Leaf: per-segment index bitmaps for each query's probe predicates,
    intersected.  Falls back to a kernel column scan where a segment lacks
    the index."""
    name = "IndexProbe"

    def _batches(self, ctx):
        for seg in ctx.segments:
            if seg.n_rows == 0:
                continue
            mask = np.zeros((ctx.nq, seg.n_rows), bool)
            for qi, plan in enumerate(ctx.plans):
                if not ctx.allowed(qi, seg):
                    continue
                m = np.ones(seg.n_rows, bool)
                for pred in plan.indexed:
                    pm, blocks = ctx.pred_mask(seg, pred, use_index=True)
                    ctx.stats[qi].blocks_read += blocks
                    m &= pm
                    if not m.any():
                        break
                mask[qi] = m
            if mask.any():
                yield seg, mask


class FilterBitmap(PhysicalOp):
    """Residual predicates ANDed into the candidate bitmaps.  Each
    predicate is evaluated once per segment per batch, row-wise over the
    UNION of the batch's surviving candidate rows — N queries sharing a
    filter pay for one evaluation, and a selective index probe upstream
    keeps residual work O(survivors), never O(segment)."""
    name = "FilterBitmap"

    def _batches(self, ctx):
        for seg, mask in self.children[0].batches(ctx):
            rows = np.nonzero(mask.any(axis=0))[0]
            evaluated: Dict[Tuple, np.ndarray] = {}

            def residual_mask(pred) -> np.ndarray:
                key = pred_cache_key(pred)
                hit = evaluated.get(key)
                if hit is None:
                    vals = {c: seg.columns[c][rows]
                            for c in q.expr_cols(pred)}
                    hit = np.zeros(seg.n_rows, bool)
                    hit[rows[eval_predicate_rows(vals, pred)]] = True
                    evaluated[key] = hit
                return hit

            for qi, plan in enumerate(ctx.plans):
                if not plan.residual or not mask[qi].any():
                    continue
                ctx.stats[qi].rows_scanned += int(mask[qi].sum())
                for pred in plan.residual:
                    mask[qi] &= residual_mask(pred)
                    if not mask[qi].any():
                        break
            if mask.any():
                yield seg, mask


class BitmapUnion(PhysicalOp):
    """OR-merge of per-conjunct candidate bitmaps — the DNF execution
    operator.  A disjunctive query's plan carries one sub-plan per DNF
    conjunct (``plan.subplans``); each conjunct is evaluated with the
    conjunctive machinery (cached index-probe bitmaps, row-restricted
    residual evaluation) and the per-conjunct ``(n_rows,)`` masks are
    OR-merged into the query's row of the shared ``(nq, n_rows)`` batch
    bitmap.  Conjunctive plans grouped into the same batch pass through
    as single-conjunct unions, so mixed batches still share one segment
    sweep."""
    name = "BitmapUnion"

    @staticmethod
    def _conjunct_mask(ctx, seg, sub, stats, residual_mask) -> np.ndarray:
        m = np.ones(seg.n_rows, bool)
        for pred in sub.indexed:
            pm, blocks = ctx.pred_mask(seg, pred, use_index=True)
            stats.blocks_read += blocks
            m &= pm
            if not m.any():
                return m
        for pred in sub.residual:
            rows = np.nonzero(m)[0]
            if not len(rows):
                break
            stats.rows_scanned += len(rows)
            m &= residual_mask(pred, rows)
        return m

    def _batches(self, ctx):
        for seg in ctx.segments:
            if seg.n_rows == 0:
                continue
            # residual literals evaluated row-restricted but at most once
            # per (segment, literal, row) across ALL queries and conjuncts
            # in the batch: `done` tracks which rows a literal has been
            # evaluated on, `vals` which of those passed
            evaluated: Dict[Tuple, Tuple[np.ndarray, np.ndarray]] = {}

            def residual_mask(pred, rows: np.ndarray) -> np.ndarray:
                key = pred_cache_key(pred)
                hit = evaluated.get(key)
                if hit is None:
                    hit = (np.zeros(seg.n_rows, bool),
                           np.zeros(seg.n_rows, bool))
                    evaluated[key] = hit
                done, vals_mask = hit
                todo = rows[~done[rows]]
                if len(todo):
                    vals = {c: seg.columns[c][todo]
                            for c in q.expr_cols(pred)}
                    vals_mask[todo[eval_predicate_rows(vals, pred)]] = True
                    done[todo] = True
                return vals_mask

            mask = np.zeros((ctx.nq, seg.n_rows), bool)
            for qi, plan in enumerate(ctx.plans):
                if not ctx.allowed(qi, seg):
                    continue
                m = np.zeros(seg.n_rows, bool)
                for sub in (plan.subplans or [plan]):
                    m |= self._conjunct_mask(ctx, seg, sub, ctx.stats[qi],
                                             residual_mask)
                    if m.all():
                        break
                mask[qi] = m
            if mask.any():
                yield seg, mask


class RankScore(PhysicalOp):
    """Exact rank scores for surviving candidates.  The batch's query
    vectors are stacked into one ``l2_distances(Q, X)`` call per segment
    over the union of candidate rows."""
    name = "RankScore"

    def collect(self, ctx: PipelineContext) -> List[List[Candidates]]:
        with obs_trace.span("operator:" + self.name) as sp:
            return self._collect(ctx, sp)

    def _collect(self, ctx: PipelineContext, sp) -> List[List[Candidates]]:
        out: List[List[Candidates]] = [[] for _ in range(ctx.nq)]
        rank_lists = [qq.ranks for qq in ctx.queries]
        rank_cols = {r.col for r in rank_lists[0]}
        for seg, mask in self.children[0].batches(ctx):
            union = mask.any(axis=0)
            rows = np.nonzero(union)[0]
            if not len(rows):
                continue
            vals = {c: seg.columns[c][rows] for c in rank_cols}
            # logical rank-column bytes per candidate row (text columns
            # hold object refs, not streamable bytes — skip them)
            row_bytes = sum(v.nbytes // max(1, len(rows))
                            for v in vals.values() if v.dtype != object)
            scores = batched_rank_scores(vals, rank_lists)
            for qi, plan in enumerate(ctx.plans):
                sel = mask[qi][rows]
                if not sel.any():
                    continue
                if not plan.indexed and not plan.residual \
                        and not plan.subplans:
                    blocks = seg.n_blocks * len(rank_lists[qi])
                    ctx.stats[qi].blocks_read += blocks
                    if sp.live:
                        sp.add("blocks", blocks)
                qrows = rows[sel]
                ctx.stats[qi].rows_scanned += len(qrows)
                ctx.stats[qi].bytes_scanned += len(qrows) * row_bytes
                if sp.live:
                    sp.add("rows", len(qrows))
                    sp.add("bytes", len(qrows) * row_bytes)
                out[qi].append(Candidates(
                    np.full(len(qrows), seg.seg_id, np.int64),
                    qrows.astype(np.int64), scores[qi][sel]))
        return out


class FusedScanTopK(PhysicalOp):
    """Fused masked scan -> top-k over the packed cross-segment
    superbatch (kernels/fused_scan.py).  Drains the source's per-segment
    bitmaps, packs every surviving segment's rank column (plus bitmaps,
    pks and row-provenance maps) into ONE bucket-padded matrix, and makes
    a single kernel dispatch for the whole query batch — only ``(nq, k)``
    distances + row ids return to the host, instead of per-segment
    ``(nq, n)`` matrices.

    Sound only under the planner's ``_fusable`` gate: unique pks (the
    device-side cut precedes visibility resolution and the memtable
    overlay, so no candidate may be shadowed) and exactly one
    positive-weight vector/spatial rank term (a monotone transform of the
    kernel's squared-L2 order, so the device (distance, pk) tie-break
    equals the host merge's lexsort by (score, pk))."""
    name = "FusedScanTopK"

    def _gather(self, ctx: PipelineContext):
        """Drain the source into (segments, packed column, batch bitmap,
        stacked query matrix) — shared by the exact and quantized scans."""
        from repro.core import segment as seg_lib
        r0 = ctx.queries[0].ranks[0]
        segs, masks = [], []
        for seg, mask in self.children[0].batches(ctx):
            segs.append(seg)
            masks.append(mask)
        if not segs:
            return None
        packed = seg_lib.pack_segments(segs, r0.col)
        mask_all = np.concatenate(masks, axis=1)
        Q = np.stack([np.asarray(
            t.q if isinstance(t, q.VectorRank) else t.point, np.float32)
            for t in (qq.ranks[0] for qq in ctx.queries)])
        return segs, packed, mask_all, Q

    def _emit(self, ctx: PipelineContext, segs, packed, mask_all,
              d2, rows, scan_row_bytes: int,
              rerank_rows: Optional[List[int]] = None
              ) -> List[List[Candidates]]:
        """Turn kernel (d2, rows) output into per-query candidates and
        charge stats.  ``scan_row_bytes`` is the logical rank-column bytes
        the candidate-generation scan streams per mask-passing row (4*d
        exact, m quantized) — ``bytes_scanned`` measures the scan phase
        only; the exact re-rank's full-precision gather is reported
        separately as ``rerank_rows`` (x 4*d bytes, derivable)."""
        out: List[List[Candidates]] = [[] for _ in range(ctx.nq)]
        unfiltered_blocks = sum(s.n_blocks for s in segs)
        sp = obs_trace.current_span()
        for qi, (qq, plan) in enumerate(zip(ctx.queries, ctx.plans)):
            # stats parity with the staged RankScore operator: candidate
            # rows ranked, and full scan blocks charged to filterless plans
            n_cand = int(mask_all[qi].sum())
            ctx.stats[qi].rows_scanned += n_cand
            ctx.stats[qi].bytes_scanned += n_cand * scan_row_bytes
            if sp is not None:
                sp.add("rows", n_cand)
                sp.add("bytes", n_cand * scan_row_bytes)
            if rerank_rows is not None:
                ctx.stats[qi].rerank_rows += rerank_rows[qi]
            if not plan.indexed and not plan.residual and not plan.subplans:
                blocks = unfiltered_blocks * len(qq.ranks)
                ctx.stats[qi].blocks_read += blocks
                if sp is not None:
                    sp.add("blocks", blocks)
            keep = rows[qi] >= 0
            rr = rows[qi][keep]
            if not len(rr):
                continue
            w = np.float32(qq.ranks[0].weight)
            scores = w * np.sqrt(np.maximum(d2[qi][keep], 0)
                                 ).astype(np.float32)
            out[qi].append(Candidates(packed.sids[rr], packed.rows[rr],
                                      scores))
        return out

    def collect(self, ctx: PipelineContext) -> List[List[Candidates]]:
        with obs_trace.span("operator:" + self.name):
            return self._collect(ctx)

    def _collect(self, ctx: PipelineContext) -> List[List[Candidates]]:
        g = self._gather(ctx)
        if g is None:
            return [[] for _ in range(ctx.nq)]
        segs, packed, mask_all, Q = g
        k = max(qq.k for qq in ctx.queries)
        d2, rows = kops.fused_scan_topk(Q, packed.x, mask_all,
                                        packed.pks, k)
        return self._emit(ctx, segs, packed, mask_all, d2, rows,
                          scan_row_bytes=packed.x.shape[1]
                          * packed.x.dtype.itemsize)


class QuantizedScanTopK(FusedScanTopK):
    """Quantized dispatch: PQ-ADC candidate generation over the packed
    code matrix (``kernels/quantized_scan.py`` — m bytes/row instead of
    4*d) keeping k' = refine*k survivors per query, then an exact re-rank
    of the survivors through the ordinary fused scan with the survivor
    bitmap.  The re-rank reuses ``kops.fused_scan_topk`` verbatim, so the
    final (score, pk) results carry the exact path's arithmetic and
    tie-break comparator — whenever the survivors cover the true top-k
    (refine high enough), results are bitwise identical to the exact
    dispatch.  Admissible only under the planner's ``_quantized_params``
    gate (explicit recall_target, all-segment PQ residence); a pack-time
    codebook mismatch falls back to the exact fused scan."""
    name = "QuantizedScanTopK"

    def _collect(self, ctx: PipelineContext) -> List[List[Candidates]]:
        from repro.core import segment as seg_lib
        g = self._gather(ctx)
        if g is None:
            return [[] for _ in range(ctx.nq)]
        segs, packed, mask_all, Q = g
        k = max(qq.k for qq in ctx.queries)
        fp_bytes = packed.x.shape[1] * packed.x.dtype.itemsize
        pc = seg_lib.pack_quantized(segs, ctx.queries[0].ranks[0].col)
        if pc is None:
            # quantized residence fell behind (mixed codebooks / missing
            # codes): exact fused scan, correctness before bandwidth
            d2, rows = kops.fused_scan_topk(Q, packed.x, mask_all,
                                            packed.pks, k)
            return self._emit(ctx, segs, packed, mask_all, d2, rows,
                              scan_row_bytes=fp_bytes)
        refine = max((getattr(p, "refine", 0) for p in ctx.plans),
                     default=0) or 4
        kprime = min(kops.fs_kernel.KMAX, refine * k)
        adc_d, crows = kops.quantized_scan_topk(
            Q, pc.codes, pc.codebooks, mask_all, packed.pks, kprime)
        # survivor bitmap for the exact re-rank (per query)
        rmask = np.zeros_like(mask_all)
        rerank_rows: List[int] = []
        for qi in range(ctx.nq):
            rr = crows[qi][crows[qi] >= 0]
            rmask[qi, rr] = True
            rerank_rows.append(len(rr))
        d2, rows = kops.fused_scan_topk(Q, packed.x, rmask, packed.pks, k)
        return self._emit(ctx, segs, packed, mask_all, d2, rows,
                          scan_row_bytes=pc.codes.shape[1],
                          rerank_rows=rerank_rows)


class GraphSearchTopK(FusedScanTopK):
    """Graph dispatch: batched beam search over the stitched per-segment
    CSR proximity graphs (``kernels/graph_search.py``) generates
    candidates by traversal — only the rows the frontier touches are ever
    gathered, no column stream — then an exact re-rank of the beam
    survivors through the ordinary fused scan with the survivor bitmap.
    The re-rank reuses ``kops.fused_scan_topk`` verbatim, so the final
    (score, pk) results carry the exact path's arithmetic and tie-break
    comparator — whenever the beam covers the true top-k (beam wide
    enough for the recall target), results are bitwise identical to the
    exact dispatch.  Admissible only under the planner's
    ``_graph_params`` gate (explicit recall_target, all-segment graph
    residence); a pack-time missing graph falls back to the exact fused
    scan, never to wrong answers.

    Stats reflect the traversal: ``rows_scanned`` / ``bytes_scanned``
    charge the rows the beam actually gathered (the visited-bitmap
    popcount the kernel returns), not the mask-passing row count the
    streaming dispatches charge."""
    name = "GraphSearchTopK"

    def _collect(self, ctx: PipelineContext) -> List[List[Candidates]]:
        from repro.core.index import graph as graph_lib
        g = self._gather(ctx)
        if g is None:
            return [[] for _ in range(ctx.nq)]
        segs, packed, mask_all, Q = g
        k = max(qq.k for qq in ctx.queries)
        fp_bytes = packed.x.shape[1] * packed.x.dtype.itemsize
        pg = graph_lib.pack_graphs(segs, ctx.queries[0].ranks[0].col)
        if pg is None:
            # graph residence fell behind (a segment without a built
            # graph): exact fused scan, correctness before traversal
            d2, rows = kops.fused_scan_topk(Q, packed.x, mask_all,
                                            packed.pks, k)
            return self._emit(ctx, segs, packed, mask_all, d2, rows,
                              scan_row_bytes=fp_bytes)
        beam = max((getattr(p, "graph_beam", 0) for p in ctx.plans),
                   default=0) or 32
        hops = max((getattr(p, "graph_hops", 0) for p in ctx.plans),
                   default=0) or 8
        beam = min(beam, int(kops.fs_kernel.KMAX))
        _, brows, gathered = kops.graph_search_topk(
            Q, packed.x, pg.neighbors, pg.entries, mask_all, packed.pks,
            beam, hops)
        # survivor bitmap for the exact re-rank (per query)
        rmask = np.zeros_like(mask_all)
        rerank_rows: List[int] = []
        for qi in range(ctx.nq):
            rr = brows[qi][brows[qi] >= 0]
            rmask[qi, rr] = True
            rerank_rows.append(len(rr))
        d2, rows = kops.fused_scan_topk(Q, packed.x, rmask, packed.pks, k)
        out: List[List[Candidates]] = [[] for _ in range(ctx.nq)]
        sp = obs_trace.current_span()
        for qi, (qq, plan) in enumerate(zip(ctx.queries, ctx.plans)):
            n_gath = int(gathered[qi])
            ctx.stats[qi].rows_scanned += n_gath
            ctx.stats[qi].bytes_scanned += n_gath * fp_bytes
            ctx.stats[qi].rerank_rows += rerank_rows[qi]
            if sp is not None:
                sp.add("rows", n_gath)
                sp.add("bytes", n_gath * fp_bytes)
            if not plan.indexed and not plan.residual and not plan.subplans:
                blocks = -(-n_gath // BLOCK_ROWS) * len(qq.ranks)
                ctx.stats[qi].blocks_read += blocks
                if sp is not None:
                    sp.add("blocks", blocks)
            keep = rows[qi] >= 0
            rr = rows[qi][keep]
            if not len(rr):
                continue
            w = np.float32(qq.ranks[0].weight)
            scores = w * np.sqrt(np.maximum(d2[qi][keep], 0)
                                 ).astype(np.float32)
            out[qi].append(Candidates(packed.sids[rr], packed.rows[rr],
                                      scores))
        return out


class VisibilityResolve(PhysicalOp):
    """Drop candidates shadowed by a newer version of their pk anywhere in
    the store (shared lexsort winner set — core/visibility.py)."""
    name = "VisibilityResolve"

    def apply(self, ctx: PipelineContext,
              cands: List[Candidates]) -> List[Candidates]:
        with obs_trace.span("operator:" + self.name) as sp:
            vis = ctx.visibility
            if vis is None:                   # unique-pk fast path
                out = cands
            else:
                out = []
                for c in cands:
                    keep = vis.visible_mask(c.sids, c.rows)
                    out.append(Candidates(c.sids[keep], c.rows[keep],
                                          c.scores[keep]))
            if sp.live:
                sp.set(out_rows=sum(len(c.scores) for c in out))
            return out


class MemtableOverlay(PhysicalOp):
    """Brute-force scan of the RAM write buffer: newest visible version
    per pk, the query's filters applied, exact rank scores."""
    name = "MemtableOverlay"

    def apply(self, ctx: PipelineContext,
              cands: List[Candidates]) -> List[Candidates]:
        with obs_trace.span("operator:" + self.name) as sp:
            out = self._apply(ctx, cands)
            if sp.live:
                sp.set(out_rows=sum(len(c.scores) for c in out))
            return out

    def _apply(self, ctx: PipelineContext,
               cands: List[Candidates]) -> List[Candidates]:
        pk, _, tomb, cols = ctx.memtable_arrays()
        if not len(pk):
            return cands
        base = vis_lib.memtable_visible(pk, tomb)
        out = []
        for qi, (qq, c) in enumerate(zip(ctx.queries, cands)):
            keep = base & ctx.memtable_expr_mask(qq.where)
            rows = np.nonzero(keep)[0]
            if not len(rows):
                out.append(c)
                continue
            if qq.ranks:
                vals = {r.col: cols[r.col][rows] for r in qq.ranks}
                scores = combined_scores(vals, qq.ranks)
            else:
                scores = np.zeros(len(rows), np.float32)
            mt_c = Candidates(np.full(len(rows), -1, np.int64),
                              rows.astype(np.int64),
                              scores.astype(np.float32))
            out.append(Candidates.concat([c, mt_c]))
        return out


class TopKMerge(PhysicalOp):
    """Per-query merge of scored candidates: order by (score, pk), cut to
    k, materialize only the returned rows."""
    name = "TopKMerge"

    def finish(self, ctx: PipelineContext,
               cands: List[Candidates]) -> List[List[ResultRow]]:
        with obs_trace.span("operator:" + self.name) as sp:
            out = [materialize(ctx, qq, c, k=qq.k)
                   for qq, c in zip(ctx.queries, cands)]
            if sp.live:
                sp.set(out_rows=sum(len(r) for r in out))
            return out


class NRAMerge(PhysicalOp):
    """No-random-access aggregation over per-modality sorted streams
    (paper Algorithm 1) — executed by core.nra over the merged ``Next()``
    iterators; appears here as the plan's EXPLAIN node."""
    name = "NRAMerge"


class EmptyResult(PhysicalOp):
    """The filter expression normalized to FALSE: nothing to scan."""
    name = "EmptyResult"


class ShardFanout(PhysicalOp):
    """Scatter one query batch to every shard's independent pipeline
    (rows are hash-partitioned by pk across shards — core/shards).  The
    children are the per-shard operator subtrees, each costed against
    that shard's own catalog; execution runs them over each shard's
    segments, memtable and visibility state in full."""
    name = "ShardFanout"


class CrossShardTopKMerge(PhysicalOp):
    """Device-side merge of the per-shard top-k candidate lists into the
    global top-k (``kernels/topk_merge.py::batched_topk_merge``, ordered
    by the host comparator (score, pk)).  Shards partition pks, so the
    merge of per-shard top-ks IS the exact global top-k; the host never
    handles more than shards * k rows per query."""
    name = "CrossShardTopKMerge"


class ShardConcat(PhysicalOp):
    """Shard-wise concatenation of filter/scan results: shards hold
    disjoint pk sets, so concatenating and re-sorting by the result
    comparator (score, pk) reproduces the single-store output exactly."""
    name = "ShardConcat"


# ---------------------------------------------------------------------------
# materialization
# ---------------------------------------------------------------------------

def candidate_pks(ctx: PipelineContext, c: Candidates) -> np.ndarray:
    pks = np.empty(len(c.sids), np.int64)
    seg_by_id = {s.seg_id: s for s in ctx.segments}
    for sid in np.unique(c.sids):
        sel = c.sids == sid
        if sid < 0:
            mt_pk, _, _, _ = ctx.memtable_arrays()
            pks[sel] = mt_pk[c.rows[sel]]
        else:
            pks[sel] = seg_by_id[sid].pk[c.rows[sel]]
    return pks


def materialize(ctx: PipelineContext, query, c: Candidates,
                k: Optional[int] = None) -> List[ResultRow]:
    """Sort candidates by (score, pk), optionally cut to k, and gather the
    selected columns for the surviving rows only."""
    pks = candidate_pks(ctx, c)
    order = np.lexsort((pks, c.scores))
    if k is not None:
        order = order[:k]
    select = query.select or [col.name for col in ctx.store.schema.columns]
    seg_by_id = {s.seg_id: s for s in ctx.segments}
    out: List[ResultRow] = []
    for t in order:
        sid, row = int(c.sids[t]), int(c.rows[t])
        if sid < 0:
            _, _, _, cols = ctx.memtable_arrays()
            values = {name: cols[name][row] for name in select}
        else:
            seg = seg_by_id[sid]
            values = {name: seg.columns[name][row] for name in select}
        out.append(ResultRow(pk=int(pks[t]), score=float(c.scores[t]),
                             values=values))
    return out


# ---------------------------------------------------------------------------
# pipeline drivers
# ---------------------------------------------------------------------------

def collect_rows(ctx: PipelineContext, source: PhysicalOp
                 ) -> List[Candidates]:
    """Drain a bitmap-producing operator into per-query candidates with
    zero scores (filter-query path)."""
    out: List[List[Candidates]] = [[] for _ in range(ctx.nq)]
    for seg, mask in source.batches(ctx):
        for qi in range(ctx.nq):
            rows = np.nonzero(mask[qi])[0]
            if len(rows):
                out[qi].append(Candidates(
                    np.full(len(rows), seg.seg_id, np.int64),
                    rows.astype(np.int64),
                    np.zeros(len(rows), np.float32)))
    return [Candidates.concat(parts) for parts in out]


def run_scan_group(store, catalog, queries, plans, stats,
                   pred_cache: Optional[Dict] = None
                   ) -> List[List[ResultRow]]:
    """Execute a batch of scan-based queries (full_scan, index_intersect,
    full_scan_nn, prefilter_nn) in ONE shared pass over the segments."""
    ctx = PipelineContext(store, catalog, queries, plans, stats, pred_cache)
    is_nn = bool(queries[0].ranks)
    if any(p.kind in ("union", "union_nn") for p in plans):
        # DNF plans in the batch: the union source evaluates every plan
        # (conjunctive ones as single-conjunct unions) in one sweep
        source: PhysicalOp = BitmapUnion()
    else:
        source = IndexProbe() if any(p.indexed for p in plans) \
            else SegmentScan()
        if any(p.residual for p in plans):
            source = FilterBitmap([source])
    if is_nn:
        # planner-chosen dispatch: graph beam-search + exact re-rank,
        # quantized ADC + exact re-rank, fused packed kernel (one launch
        # per batch), or staged per-segment RankScore; the executor
        # groups by the (fused, quantized, graph) flags so a group is
        # always homogeneous
        if all(getattr(p, "graph", False) for p in plans):
            ranker = GraphSearchTopK
        elif all(getattr(p, "quantized", False) for p in plans):
            ranker = QuantizedScanTopK
        elif all(getattr(p, "fused", False) for p in plans):
            ranker = FusedScanTopK
        else:
            ranker = RankScore
        parts = ranker([source]).collect(ctx)
        cands = [Candidates.concat(p) for p in parts]
    else:
        cands = collect_rows(ctx, source)
    cands = VisibilityResolve().apply(ctx, cands)
    cands = MemtableOverlay().apply(ctx, cands)
    if is_nn:
        return TopKMerge().finish(ctx, cands)
    return [materialize(ctx, qq, c) for qq, c in zip(ctx.queries, cands)]


def finish_candidates(ctx: PipelineContext, cands: List[Candidates]
                      ) -> List[List[ResultRow]]:
    """Visibility + memtable overlay + top-k for externally-produced
    candidates (post-filter probes, NRA winner sets)."""
    cands = VisibilityResolve().apply(ctx, cands)
    cands = MemtableOverlay().apply(ctx, cands)
    return TopKMerge().finish(ctx, cands)


# ---------------------------------------------------------------------------
# EXPLAIN tree construction
# ---------------------------------------------------------------------------

def _pred_detail(preds) -> str:
    def one(p):
        if isinstance(p, q.Not):
            return "Not(" + one(p.child) + ")"
        if isinstance(p, (q.And, q.Or)):
            return f"{type(p).__name__}[{len(p.children)}]"
        return type(p).__name__ + ":" + str(getattr(p, "col", "?"))
    return ",".join(one(p) for p in preds)


def build_tree(plan, catalog=None) -> PhysicalOp:
    """Operator tree for a plan — the EXPLAIN structure.  With a catalog,
    nodes carry cost estimates in block-read units; without one (manual
    plans in tests) costs render as 0."""
    have = catalog is not None
    n_segs = len(catalog.store.segments) if have else 0
    total_blocks = catalog.total_blocks if have else 0.0
    mt_rows = catalog.store.memtable_rows if have else 0

    def conj_passing(pl_) -> float:
        if not have:
            return 0.0
        return conjunct_passing(catalog,
                                list(pl_.indexed) + list(pl_.residual))

    passing = conj_passing(plan)
    if plan.subplans:                     # DNF: rows passing ANY conjunct
        passing = min(sum(conj_passing(sp) for sp in plan.subplans),
                      float(catalog.total_rows) if have else 0.0)

    def source(pl_=plan) -> PhysicalOp:
        if pl_.indexed:
            est = sum(catalog.index_probe_blocks(p) for p in pl_.indexed) \
                if have else 0.0
            probe_rows = conjunct_passing(catalog, list(pl_.indexed)) \
                if have else 0.0
            return IndexProbe(detail=_pred_detail(pl_.indexed),
                              est_cost=est, est_rows=probe_rows)
        return SegmentScan(detail=f"{n_segs} segments",
                           est_cost=total_blocks * C_FILTER_BLOCK,
                           est_rows=float(catalog.total_rows)
                           if have else 0.0)

    def with_residual(node: PhysicalOp, pl_=plan) -> PhysicalOp:
        if not pl_.residual:
            return node
        est = conj_passing(pl_) * C_ROW_RESIDUAL * len(pl_.residual)
        return FilterBitmap([node], detail=_pred_detail(pl_.residual),
                            est_cost=est, est_rows=conj_passing(pl_))

    def finishers(node: PhysicalOp, with_topk: bool) -> PhysicalOp:
        node = VisibilityResolve([node], detail="lexsort winners")
        node = MemtableOverlay([node], detail=f"{mt_rows} rows",
                               est_cost=mt_rows / BLOCK_ROWS)
        if with_topk:
            node = TopKMerge([node], detail=f"k={plan.k}",
                             est_cost=C_MERGE * n_segs,
                             est_rows=float(plan.k))
        return node

    def ranker(node: PhysicalOp) -> PhysicalOp:
        """RankScore (staged per-segment kernels), FusedScanTopK (one
        packed launch), QuantizedScanTopK (ADC scan + exact re-rank), or
        GraphSearchTopK (CSR beam search + exact re-rank) per the plan's
        dispatch choice."""
        est = (passing / BLOCK_ROWS) * C_VECTOR_BLOCK * \
            max(1, len(plan.ranks))
        if getattr(plan, "graph", False):
            from repro.core.optimizer.cost import C_GATHER_ROW, C_HOP
            gathered = plan.graph_beam * plan.graph_r * plan.graph_hops / 2
            return GraphSearchTopK(
                [node],
                detail=(f"beam search R={plan.graph_r} "
                        f"beam={plan.graph_beam} hops={plan.graph_hops} "
                        f"-> exact re-rank k={plan.k}"),
                est_cost=(plan.graph_hops * C_HOP
                          + gathered * C_GATHER_ROW
                          + plan.graph_beam * C_RERANK_ROW),
                est_rows=gathered)
        if getattr(plan, "quantized", False):
            d = plan.ranks[0].q.shape[0] if plan.ranks else 1
            ratio = plan.pq_m / max(1.0, 4.0 * d)
            return QuantizedScanTopK(
                [node],
                detail=(f"adc pq m={plan.pq_m} refine={plan.refine} "
                        f"-> exact re-rank k={plan.k}"),
                est_cost=est * ratio + plan.refine * plan.k * C_RERANK_ROW,
                est_rows=passing)
        if plan.fused:
            return FusedScanTopK(
                [node],
                detail=(f"packed {n_segs} segments, k={plan.k}, "
                        f"1 launch (est_launches=1 vs {max(1, n_segs)} "
                        "staged)"),
                est_cost=est, est_rows=passing)
        return RankScore(
            [node], detail=f"{len(plan.ranks)} modalities (batched)",
            est_cost=est, est_rows=passing)

    kind = plan.kind
    if kind == "empty":
        return EmptyResult(detail=plan.note or "unsatisfiable filter")
    if kind in ("union", "union_nn"):
        # one child subtree per DNF conjunct, each with its own costs
        kids = [with_residual(source(sp), sp) for sp in plan.subplans]
        node = BitmapUnion(kids,
                           detail=f"{len(kids)} conjuncts (OR-merge)",
                           est_cost=C_MERGE * n_segs * max(1, len(kids)),
                           est_rows=passing)
        if kind == "union_nn":
            node = ranker(node)
        return finishers(node, with_topk=(kind == "union_nn"))
    if kind in ("full_scan", "index_intersect"):
        return finishers(with_residual(source()), with_topk=False)
    if kind in ("full_scan_nn", "prefilter_nn"):
        node = ranker(with_residual(source()))
        return finishers(node, with_topk=True)
    if kind == "postfilter_nn":
        r = plan.ranks[0] if plan.ranks else None
        probe = IndexProbe(
            detail=f"topk probe:{getattr(r, 'col', '?')}",
            est_cost=catalog.index_probe_blocks(
                q.VectorRange(r.col, r.q, float("inf"))) * C_VECTOR_BLOCK
            if (have and r is not None) else 0.0)
        return finishers(with_residual(probe), with_topk=True)
    if kind == "nra":
        leaves = [IndexProbe(
            detail=f"sorted access:{getattr(r, 'col', '?')}",
            est_cost=0.0) for r in plan.ranks]
        node = NRAMerge(leaves,
                        detail=f"{len(plan.ranks)} modalities",
                        est_cost=C_MERGE * n_segs * max(1, len(plan.ranks)))
        return finishers(node, with_topk=True)
    # unknown kinds (baseline strategies): render the generic scan shape
    node = with_residual(source())
    if plan.ranks:
        node = RankScore([node], detail=f"{len(plan.ranks)} modalities")
    return finishers(node, with_topk=bool(plan.ranks))
