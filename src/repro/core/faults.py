"""Fault-injection harness for crash-recovery testing.

A ``FaultInjector`` is armed with one named crash point (see
``CRASH_POINTS``); durability code calls ``faults.crash("...")`` at each
point and the injector raises ``InjectedCrash`` when its armed point is
reached.  After the first fire the injector is *poisoned*: every later
``crash()`` call raises too, so a store that "crashed" in one thread
cannot keep publishing durable state from another (the background flush
worker dies at its next crash point instead of finishing the flush the
simulated process crash should have interrupted).

Stores hold a ``faults`` attribute defaulting to the shared no-op
``NO_FAULTS`` injector; tests wire a fresh armed injector into one store
(``LSMStore.set_faults``) and drive writes until it fires, then abandon
the instance and recover from disk — the file system is left exactly as
a process kill at that point would leave it (including deliberately torn
WAL records and half-written segment files at the write-side points).
"""
from __future__ import annotations

from typing import Optional

# every named crash point the durability layer exposes, in write-path
# order — the crash-recovery matrix in tests/test_durability.py kills at
# each one
CRASH_POINTS = (
    "wal.append",              # torn record: half the bytes hit the log
    "wal.commit",              # record written, fdatasync never runs
    "flush.segment-file",      # torn segment temp file mid-write
    "flush.before-publish",    # segment durable, manifest still old
    "manifest.publish",        # manifest temp written, rename never runs
    "manifest.after-rename",   # new manifest live, dir fsync/GC skipped
    "compact.before-publish",  # merged segment durable, manifest old
    "compact.after-publish",   # manifest swapped, inputs not yet deleted
)


class InjectedCrash(RuntimeError):
    """Raised at an armed crash point; simulates the process dying."""


class FaultInjector:
    """Single-shot crash-point trigger.

    ``arm(point, after=N)`` fires on the N+1-th time ``point`` is
    reached.  ``fired`` records the point that actually fired (tests in
    background-flush mode poll it, because the crash raises on the
    worker thread, not under the writer's ``put``)."""

    def __init__(self) -> None:
        self._point: Optional[str] = None
        self._countdown = 0
        self.fired: Optional[str] = None

    def arm(self, point: str, after: int = 0) -> "FaultInjector":
        if point not in CRASH_POINTS:
            raise ValueError(f"unknown crash point {point!r}")
        self._point = point
        self._countdown = int(after)
        return self

    @property
    def crashed(self) -> bool:
        return self.fired is not None

    def should_crash(self, point: str) -> bool:
        """True when ``point`` must crash now (poisoned or armed with an
        exhausted countdown).  Does not raise — the WAL uses it to tear
        a record mid-write before raising itself."""
        if self.fired is not None:
            return True
        if point != self._point:
            return False
        if self._countdown > 0:
            self._countdown -= 1
            return False
        return True

    def crash(self, point: str) -> None:
        """Raise ``InjectedCrash`` when armed for ``point`` (or already
        poisoned); otherwise a no-op on the hot path."""
        if self.should_crash(point):
            self.fired = self.fired or point
            raise InjectedCrash(f"injected crash at {point}")


# shared disarmed injector: ``should_crash`` is always False, so the
# production path pays one attribute load + compare per crash point
NO_FAULTS = FaultInjector()
