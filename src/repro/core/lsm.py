"""Partitioned LSM store: memtable + immutable segments + size-tiered
compaction, with the unified secondary index framework built at flush /
compaction time (paper §3-§4).

Write path:  put/delete forward whole *columnar* batches to the chunked
memtable (O(#columns) per batch); the FlushScheduler seals full memtables
and turns them into level-0 Segments off the write critical path, building
all declared secondary indexes *with* the segment (the paper's central
ingestion claim vs global in-memory vector indexes).  Compaction *merges*
the per-segment indexes of the input tier (posting remap / sorted-run
merge / Z-order re-sort / centroid reuse) instead of rebuilding them.

Read path:   point gets via memtables (active + sealed) -> zone-map-pruned
segments (newest seqno wins); query execution lives in core.executor /
core.nra driven by the optimizer.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import memtable as mt
from repro.core import segment as seg_lib
from repro.core.flush import FlushScheduler
from repro.core.types import Column, ColumnType, Schema


@dataclasses.dataclass
class LSMConfig:
    flush_rows: int = 4096
    flush_bytes: int = 0          # optional byte threshold (0 = rows only)
    fanout: int = 4               # size-tiered: merge when a tier has this many
    max_levels: int = 6
    build_indexes: bool = True
    merge_indexes: bool = True    # compaction merges indexes vs rebuilds
    pipeline: bool = False        # decouple seal from flush/compaction
    max_sealed: int = 4           # write-stall threshold (pipelined modes)
    background: bool = False      # drain on a worker thread (benchmarks)
    quantize_vectors: bool = True  # PQ residence tier for vector columns
    pq_m: int = 8                  # subquantizers (halved until d % m == 0)


class LSMStore:
    def __init__(self, schema: Schema, cfg: Optional[LSMConfig] = None,
                 index_factory: Optional[Callable[[Column], Any]] = None,
                 memtable_factory: Optional[Callable[[Schema], Any]] = None):
        from repro.core.index import (GlobalIndexSet,
                                      default_index_factory)  # lazy: no cycle
        self.schema = schema
        self.cfg = cfg or LSMConfig()
        self._memtable_factory = memtable_factory or mt.MemTable
        self.memtable = self._memtable_factory(schema)
        self.sealed: List[Any] = []      # full memtables awaiting flush
        self.segments: List[seg_lib.Segment] = []
        self._seqno = 0
        self._index_factory = index_factory or default_index_factory
        self.global_index = GlobalIndexSet(schema)
        # quantized residence: col -> (book_id, codebooks); trained once
        # on the first flush, reused for every later flush so all of this
        # store's segments share one book (packable cross-segment)
        self._pq_books: Dict[str, Tuple[int, np.ndarray]] = {}
        # fast path: when every pk was written once and nothing deleted,
        # visibility resolution is the identity (skipped in NRA/executor)
        self.unique_pks = True
        self._seen_max_pk = -1
        self.metrics = {"flushes": 0, "compactions": 0, "puts": 0,
                        "deletes": 0, "noop_deletes": 0, "seals": 0,
                        "stalls": 0, "flush_s": 0.0, "compact_s": 0.0,
                        "index_build_s": 0.0, "index_merge_s": 0.0,
                        "index_rebuild_s": 0.0, "index_merges": 0,
                        "index_rebuilds": 0, "vis_extends": 0}
        self._on_delta: List[Callable] = []   # continuous-query hooks
        self._mt_epoch = 0                    # bumps on any memtable change
        self._mt_cache = None                 # (epoch, concat scan arrays)
        # store lock: every mutation of shared state (segments, sealed,
        # memtable, metrics, global_index, caches, PQ books) happens under
        # it.  Re-entrant so visibility helpers called from a publish
        # window can re-take it.  Expensive work (segment build, index
        # construction, PQ training) runs OUTSIDE; only the publish is
        # locked.  Lock order: never hold _lock while waiting on the
        # scheduler's condition variable.
        self._lock = threading.RLock()
        self.scheduler = FlushScheduler(self)

    # ------------------------------------------------------------------ write
    def put(self, pks: Sequence[int], batch: Dict[str, Any]) -> None:
        """Ingest one columnar batch: O(#columns) array appends into the
        active memtable; flush/compaction/indexing happen off this path
        via the scheduler.  Empty batches are a complete no-op (no delta
        hooks, no metrics)."""
        pks = np.asarray(pks, np.int64)
        if len(pks) == 0:
            return
        cbatch = batch
        with self._lock:
            self._track_unique(pks)
            self._seqno = self.memtable.put_batch(pks, batch, self._seqno)
            self._mt_epoch += 1
            self._mt_cache = None
            self.metrics["puts"] += len(pks)
            if self._on_delta and isinstance(self.memtable, mt.MemTable):
                # hand hooks the memtable's canonical numpy chunk
                # (zero-copy, already validated) — never per-row dicts
                cbatch = {name: chunks[-1] for name, chunks
                          in self.memtable._col_chunks.items()}
        # hooks and backpressure run unlocked: on_write may wait on the
        # scheduler's condition variable, which the worker needs _lock-free
        if self._on_delta:
            self._notify_delta(pks, cbatch, deleted=False)
        self.scheduler.on_write()

    def delete(self, pks: Sequence[int]) -> None:
        """Tombstone the given pks.  Deletes of never-written keys are
        no-ops: they write nothing and keep the ``unique_pks`` fast path
        (visibility resolution stays skippable)."""
        pks = np.asarray(pks, np.int64)
        if len(pks) == 0:
            return
        with self._lock:
            exists = self._contains_any_version(pks)
            if not exists.any():
                self.metrics["noop_deletes"] += len(pks)
                return
            live = pks[exists]
            self.unique_pks = False
            self._seqno = self.memtable.put_batch(live, {}, self._seqno,
                                                  tombstone=True)
            self._mt_epoch += 1
            self._mt_cache = None
            self.metrics["deletes"] += len(live)
            self.metrics["noop_deletes"] += int(len(pks) - len(live))
        self._notify_delta(live, None, deleted=True)
        self.scheduler.on_write()

    def _track_unique(self, pks: np.ndarray) -> None:
        if self.unique_pks:
            if int(pks.min()) <= self._seen_max_pk:
                self.unique_pks = False
            elif len(pks) > 1 and not (np.diff(pks) > 0).all() and \
                    len(np.unique(pks)) != len(pks):
                self.unique_pks = False
        self._seen_max_pk = max(self._seen_max_pk, int(pks.max()))

    def _contains_any_version(self, pks: np.ndarray) -> np.ndarray:
        """Bool mask: does any version (including tombstones) of each pk
        exist in the store?  Vectorized over segments; memtables checked
        via their O(1) key maps."""
        out = np.zeros(len(pks), bool)
        if self._seen_max_pk < 0:
            return out
        cand = np.nonzero(pks <= self._seen_max_pk)[0]
        if not len(cand):
            return out
        for m in (self.memtable, *self.sealed):
            if len(m):
                latest = m._latest
                for i in cand:
                    if int(pks[i]) in latest:
                        out[i] = True
        rest = cand[~out[cand]]
        for seg in self.segments:
            if not len(rest):
                break
            if seg.n_rows == 0:
                continue
            pos = np.minimum(np.searchsorted(seg.pk, pks[rest]),
                             seg.n_rows - 1)
            hit = seg.pk[pos] == pks[rest]
            out[rest[hit]] = True
            rest = rest[~hit]
        return out

    def on_delta(self, fn: Callable) -> None:
        """Register a hook called with ``(pks, batch, deleted)`` on writes
        — ``pks`` an int64 array and ``batch`` a columnar dict of numpy
        arrays (None for deletes).  Drives incremental view maintenance
        and ASYNC continuous queries."""
        self._on_delta.append(fn)

    def _notify_delta(self, pks, batch, deleted: bool) -> None:
        for fn in self._on_delta:
            fn(pks, batch, deleted)

    # ------------------------------------------------- flush / compaction
    def seal(self) -> bool:
        """Move the active memtable onto the flush queue (O(1) swap)."""
        with self._lock:
            if not len(self.memtable):
                return False
            self.sealed.append(self.memtable)
            self.memtable = self._memtable_factory(self.schema)
            self._mt_epoch += 1
            self._mt_cache = None
            self.metrics["seals"] += 1
            return True

    def flush(self) -> Optional[seg_lib.Segment]:
        """Seal the active memtable and drain all queued work; returns
        the segment the active memtable became (None if it was empty)."""
        sealed_now = self.seal()
        segs = self.scheduler.drain()
        return segs[-1] if (segs and sealed_now) else None

    def drain(self) -> List[seg_lib.Segment]:
        """Deterministically process all queued flushes + compactions
        (pipelined mode); returns the segments flushed."""
        return self.scheduler.drain()

    def _flush_sealed(self) -> seg_lib.Segment:
        """Turn the oldest sealed memtable into a level-0 segment with
        its indexes, then extend the visibility cache incrementally (a
        flush relocates versions without changing any pk's winner)."""
        from repro.core import visibility as vis_lib
        with self._lock:
            mtab = self.sealed[0]
        t0 = time.perf_counter()
        # build outside the lock: the sealed memtable is immutable (only
        # the active one takes writes) and the segment is private until
        # published, so index construction never blocks writers/readers
        pk, seqno, tomb, cols = mtab.scan_arrays()
        seg = seg_lib.Segment(self.schema, pk, seqno, tomb, cols, level=0)
        self._build_indexes(seg)
        self._quantize_segment(seg)
        with self._lock:
            # atomic publish: readers see (old segments + sealed) or
            # (new segment, sealed popped) — never the torn middle
            pre_key = (self._seqno, tuple(s.seg_id for s in self.segments))
            self.segments.append(seg)
            self.sealed.pop(0)
            self._mt_epoch += 1
            self._mt_cache = None
            self.global_index.on_new_segment(seg)
            if vis_lib.extend_cache_on_flush(self, pre_key, seg, len(pk)):
                self.metrics["vis_extends"] += 1
            seg.sort_order = None      # one-shot; don't retain 8B/row
            self.metrics["flushes"] += 1
            self.metrics["flush_s"] += time.perf_counter() - t0
        return seg

    def _build_indexes(self, seg: seg_lib.Segment) -> None:
        """Per-segment index construction at SST-build time (paper §4)."""
        if not self.cfg.build_indexes:
            return
        t0 = time.perf_counter()
        for col in self.schema.indexed_columns:
            idx = self._index_factory(col)
            if idx is not None:
                idx.build(seg, col)
                seg.indexes[col.name] = idx
        with self._lock:
            self.metrics["index_build_s"] += time.perf_counter() - t0

    # ------------------------------------------------ quantized residence
    def _vector_columns(self):
        return [c for c in self.schema.columns
                if c.ctype == ColumnType.VECTOR]

    def _quantize_segment(self, seg: seg_lib.Segment) -> None:
        """Encode-at-flush: PQ codes for every vector column, stored
        alongside the fp32 column (the quantized residence tier the fused
        quantized scan streams).  Codebooks come from the store-level
        cache — only the very first flush of a column trains."""
        if not self.cfg.quantize_vectors:
            return
        t0 = time.perf_counter()
        for col in self._vector_columns():
            self._encode_quantized(seg, col.name)
        with self._lock:
            self.metrics["quantize_s"] = \
                self.metrics.get("quantize_s", 0.0) \
                + (time.perf_counter() - t0)

    def _encode_quantized(self, seg: seg_lib.Segment, name: str) -> None:
        from repro.core import quantize as qz
        vecs = np.asarray(seg.columns[name], np.float32)
        if not len(vecs):
            return
        cached = self._pq_books.get(name)
        if cached is None:
            qc = qz.quantize_column(vecs, m=self.cfg.pq_m)
            with self._lock:
                self._pq_books[name] = (qc.book_id, qc.codebooks)
        else:
            bid, books = cached
            qc = qz.QuantizedColumn(qz.encode(vecs, books), books, bid)
        seg.quantized[name] = qc
        seg.content_gen += 1      # invalidate packed-code cache entries

    def _merge_quantized(self, tier, merged, row_maps) -> None:
        """Compaction maintenance for the quantized tier: donate the
        largest part's codebooks and copy its codes through the row maps
        (``quantize.merge_quantized`` — assignment pass at most, never a
        retrain).  Parts without codes force a plain re-encode from the
        store's cached books."""
        from repro.core import quantize as qz
        t0 = time.perf_counter()
        for col in self._vector_columns():
            parts = [s.quantized.get(col.name) for s in tier]
            if all(p is not None for p in parts) and any(
                    len(p.codes) for p in parts):
                merged.quantized[col.name] = qz.merge_quantized(
                    parts, merged.columns[col.name], row_maps)
                merged.content_gen += 1
            else:
                self._encode_quantized(merged, col.name)
        with self._lock:
            self.metrics["quantize_s"] = \
                self.metrics.get("quantize_s", 0.0) \
                + (time.perf_counter() - t0)

    def _compactable_level(self) -> Optional[int]:
        """Lowest level whose tier reached the size-tiered fanout."""
        counts: Dict[int, int] = {}
        for s in self.segments:
            counts[s.level] = counts.get(s.level, 0) + 1
        for level in range(self.cfg.max_levels):
            if counts.get(level, 0) >= self.cfg.fanout:
                return level
        return None

    def _compact_level(self, level: int) -> seg_lib.Segment:
        """Merge one full tier into a level+1 segment, *merging* the
        per-segment indexes through the compaction row maps instead of
        rebuilding them (paper §4's compaction-aware maintenance)."""
        with self._lock:
            tier = [s for s in self.segments if s.level == level]
            bottom = level + 1 >= self.cfg.max_levels or not any(
                s.level > level for s in self.segments)
        t0 = time.perf_counter()
        # merge + index maintenance outside the lock: inputs are immutable
        # segments, the output is private until published below
        merged, row_maps = seg_lib.merge_segments(
            self.schema, tier, level + 1, drop_tombstones=bottom,
            return_maps=True)
        merged.sort_order = None       # identity by construction; drop it
        if self.cfg.build_indexes:
            self._merge_or_rebuild_indexes(tier, merged, row_maps)
        if self.cfg.quantize_vectors:
            self._merge_quantized(tier, merged, row_maps)
        with self._lock:
            # single-assignment swap so concurrent readers iterating
            # self.segments never observe a half-replaced tier
            keep = [s for s in self.segments if s not in tier]
            keep.append(merged)
            self.segments = keep
            for s in tier:
                self.global_index.on_drop_segment(s.seg_id)
            self.global_index.on_new_segment(merged)
            self.metrics["compactions"] += 1
            self.metrics["compact_s"] += time.perf_counter() - t0
        return merged

    def _merge_or_rebuild_indexes(self, tier, merged, row_maps) -> None:
        """Index maintenance at compaction: structural merge when every
        input segment has a compatible built index, fresh rebuild
        otherwise; both paths are timed separately in ``metrics`` so the
        merge-vs-rebuild win is measurable."""
        for col in self.schema.indexed_columns:
            idx = self._index_factory(col)
            if idx is None:
                continue
            parts = [s.indexes.get(col.name) for s in tier]
            mergeable = self.cfg.merge_indexes and all(
                p is not None and type(p) is type(idx) for p in parts)
            t0 = time.perf_counter()
            if mergeable:
                idx.merge(parts, merged, col, row_maps)
                with self._lock:
                    self.metrics["index_merge_s"] += \
                        time.perf_counter() - t0
                    self.metrics["index_merges"] += 1
            else:
                idx.build(merged, col)
                with self._lock:
                    self.metrics["index_rebuild_s"] += \
                        time.perf_counter() - t0
                    self.metrics["index_rebuilds"] += 1
            merged.indexes[col.name] = idx

    # ------------------------------------------------------------------- read
    def get(self, key: int) -> Optional[Dict[str, Any]]:
        best = None
        # memtables newest-first: active, then sealed youngest->oldest
        for m in (self.memtable, *reversed(self.sealed)):
            best = m.get(key)
            if best is not None:
                break
        if best is None:
            # newest-first: segments are appended in time order
            for seg in reversed(self.segments):
                if not seg.may_contain(key):
                    continue
                i = seg.get(key)
                if i is not None:
                    r = seg.row(i)
                    if best is None or r["_seqno"] > best["_seqno"]:
                        best = r
        if best is None or best["_tombstone"]:
            return None
        return best

    @property
    def n_rows(self) -> int:
        return sum(s.n_rows for s in self.segments) + self.memtable_rows

    @property
    def memtable_rows(self) -> int:
        """Rows buffered in RAM (active + sealed awaiting flush)."""
        return len(self.memtable) + sum(len(m) for m in self.sealed)

    def all_segments(self) -> List[seg_lib.Segment]:
        return list(self.segments)

    def memtable_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                       Dict[str, np.ndarray]]:
        """Columnar view over ALL RAM-resident rows (sealed memtables
        oldest-first, then the active one) — the read paths' single
        window onto unflushed data, cached per write epoch."""
        with self._lock:
            if self._mt_cache is None or \
                    self._mt_cache[0] != self._mt_epoch:
                parts = [m.scan_arrays()
                         for m in (*self.sealed, self.memtable)]
                self._mt_cache = (
                    self._mt_epoch,
                    mt.concat_memtable_arrays(parts, self.schema))
            return self._mt_cache[1]

    # visible-version resolution across segments (newest seqno per pk wins)
    def resolve_visible(self, per_segment_rows: Dict[int, np.ndarray]
                        ) -> Dict[int, np.ndarray]:
        """Given {seg_id: row_indices}, drop rows shadowed by newer versions
        of the same pk elsewhere (or by memtable / tombstones).  Delegates
        to the shared vectorized resolver in ``core.visibility``."""
        from repro.core import visibility
        return visibility.visibility_index(self).resolve(per_segment_rows)
