"""Partitioned LSM store: memtable + immutable segments + size-tiered
compaction, with the unified secondary index framework built at flush /
compaction time (paper §3-§4).

Write path:  put/delete -> memtable (O(1)); at ``flush_rows`` the memtable
becomes a level-0 Segment and all declared secondary indexes are built
*with* the segment (never on the ingest critical path — the paper's
central ingestion claim vs global in-memory vector indexes).

Read path:   point gets via memtable -> zone-map-pruned segments (newest
seqno wins); query execution lives in core.executor / core.nra driven by
the optimizer.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core import memtable as mt
from repro.core import segment as seg_lib
from repro.core.types import Column, ColumnType, IndexKind, Schema


@dataclasses.dataclass
class LSMConfig:
    flush_rows: int = 4096
    fanout: int = 4               # size-tiered: merge when a tier has this many
    max_levels: int = 6
    build_indexes: bool = True


class LSMStore:
    def __init__(self, schema: Schema, cfg: Optional[LSMConfig] = None,
                 index_factory: Optional[Callable[[Column], Any]] = None):
        from repro.core.index import (GlobalIndexSet,
                                      default_index_factory)  # lazy: no cycle
        self.schema = schema
        self.cfg = cfg or LSMConfig()
        self.memtable = mt.MemTable(schema)
        self.segments: List[seg_lib.Segment] = []
        self._seqno = 0
        self._index_factory = index_factory or default_index_factory
        self.global_index = GlobalIndexSet(schema)
        # fast path: when every pk was written once and nothing deleted,
        # visibility resolution is the identity (skipped in NRA/executor)
        self.unique_pks = True
        self._seen_max_pk = -1
        self.metrics = {"flushes": 0, "compactions": 0, "puts": 0,
                        "deletes": 0, "flush_s": 0.0, "compact_s": 0.0,
                        "index_build_s": 0.0}
        self._on_delta: List[Callable] = []   # continuous-query hooks

    # ------------------------------------------------------------------ write
    def put(self, pks: Sequence[int], batch: Dict[str, Any]) -> None:
        lo = min(pks) if len(pks) else 0
        if lo <= self._seen_max_pk:
            self.unique_pks = False
        if len(pks):
            self._seen_max_pk = max(self._seen_max_pk, max(pks))
        self._seqno = self.memtable.put_batch(pks, batch, self._seqno)
        self.metrics["puts"] += len(pks)
        self._notify_delta(pks, batch, deleted=False)
        self._maybe_flush()

    def delete(self, pks: Sequence[int]) -> None:
        self.unique_pks = False
        self._seqno = self.memtable.put_batch(pks, {}, self._seqno,
                                              tombstone=True)
        self.metrics["deletes"] += len(pks)
        self._notify_delta(pks, None, deleted=True)
        self._maybe_flush()

    def on_delta(self, fn: Callable) -> None:
        """Register a hook called with (pks, batch|None, deleted) on writes
        — drives incremental view maintenance and ASYNC queries."""
        self._on_delta.append(fn)

    def _notify_delta(self, pks, batch, deleted: bool) -> None:
        for fn in self._on_delta:
            fn(pks, batch, deleted)

    def _maybe_flush(self) -> None:
        if len(self.memtable) >= self.cfg.flush_rows:
            self.flush()

    def flush(self) -> Optional[seg_lib.Segment]:
        if not len(self.memtable):
            return None
        t0 = time.perf_counter()
        pk, seqno, tomb, cols = self.memtable.scan_arrays()
        seg = seg_lib.Segment(self.schema, pk, seqno, tomb, cols, level=0)
        self._build_indexes(seg)
        self.segments.append(seg)
        self.global_index.on_new_segment(seg)
        self.memtable = mt.MemTable(self.schema)
        self.metrics["flushes"] += 1
        self.metrics["flush_s"] += time.perf_counter() - t0
        self._maybe_compact()
        return seg

    def _build_indexes(self, seg: seg_lib.Segment) -> None:
        """Per-segment index construction at SST-build time (paper §4)."""
        if not self.cfg.build_indexes:
            return
        t0 = time.perf_counter()
        for col in self.schema.indexed_columns:
            idx = self._index_factory(col)
            if idx is not None:
                idx.build(seg, col)
                seg.indexes[col.name] = idx
        self.metrics["index_build_s"] += time.perf_counter() - t0

    def _maybe_compact(self) -> None:
        """Size-tiered compaction: when ``fanout`` segments accumulate at a
        level, merge them into one segment at level+1 (rebuilding the
        per-segment indexes for the merged run)."""
        for level in range(self.cfg.max_levels):
            tier = [s for s in self.segments if s.level == level]
            if len(tier) < self.cfg.fanout:
                continue
            t0 = time.perf_counter()
            bottom = level + 1 >= self.cfg.max_levels or not any(
                s.level > level for s in self.segments)
            merged = seg_lib.merge_segments(self.schema, tier, level + 1,
                                            drop_tombstones=bottom)
            self._build_indexes(merged)
            self.segments = [s for s in self.segments if s not in tier]
            self.segments.append(merged)
            for s in tier:
                self.global_index.on_drop_segment(s.seg_id)
            self.global_index.on_new_segment(merged)
            self.metrics["compactions"] += 1
            self.metrics["compact_s"] += time.perf_counter() - t0

    # ------------------------------------------------------------------- read
    def get(self, key: int) -> Optional[Dict[str, Any]]:
        row = self.memtable.get(key)
        best = row
        if best is None:
            # newest-first: segments are appended in time order
            for seg in reversed(self.segments):
                if not seg.may_contain(key):
                    continue
                i = seg.get(key)
                if i is not None:
                    r = seg.row(i)
                    if best is None or r["_seqno"] > best["_seqno"]:
                        best = r
        if best is None or best["_tombstone"]:
            return None
        return best

    @property
    def n_rows(self) -> int:
        return sum(s.n_rows for s in self.segments) + len(self.memtable)

    def all_segments(self) -> List[seg_lib.Segment]:
        return list(self.segments)

    def memtable_arrays(self):
        return self.memtable.scan_arrays()

    # visible-version resolution across segments (newest seqno per pk wins)
    def resolve_visible(self, per_segment_rows: Dict[int, np.ndarray]
                        ) -> Dict[int, np.ndarray]:
        """Given {seg_id: row_indices}, drop rows shadowed by newer versions
        of the same pk elsewhere (or by memtable / tombstones).  Delegates
        to the shared vectorized resolver in ``core.visibility``."""
        from repro.core import visibility
        return visibility.visibility_index(self).resolve(per_segment_rows)
