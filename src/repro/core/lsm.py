"""Partitioned LSM store: memtable + immutable segments + size-tiered
compaction, with the unified secondary index framework built at flush /
compaction time (paper §3-§4).

Write path:  put/delete forward whole *columnar* batches to the chunked
memtable (O(#columns) per batch); the FlushScheduler seals full memtables
and turns them into level-0 Segments off the write critical path, building
all declared secondary indexes *with* the segment (the paper's central
ingestion claim vs global in-memory vector indexes).  Compaction *merges*
the per-segment indexes of the input tier (posting remap / sorted-run
merge / Z-order re-sort / centroid reuse) instead of rebuilding them.

Read path:   point gets via memtables (active + sealed) -> zone-map-pruned
segments (newest seqno wins); query execution lives in core.executor /
core.nra driven by the optimizer.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import manifest as manifest_lib
from repro.core import memtable as mt
from repro.core import segment as seg_lib
from repro.core import wal as wal_lib
from repro.core.faults import NO_FAULTS, FaultInjector
from repro.core.flush import FlushScheduler
from repro.core.types import Column, ColumnType, Schema, validate_batch
from repro.obs import REGISTRY
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class LSMConfig:
    flush_rows: int = 4096
    flush_bytes: int = 0          # optional byte threshold (0 = rows only)
    fanout: int = 4               # size-tiered: merge when a tier has this many
    max_levels: int = 6
    build_indexes: bool = True
    merge_indexes: bool = True    # compaction merges indexes vs rebuilds
    pipeline: bool = False        # decouple seal from flush/compaction
    max_sealed: int = 4           # write-stall threshold (pipelined modes)
    background: bool = False      # drain on a worker thread (benchmarks)
    quantize_vectors: bool = True  # PQ residence tier for vector columns
    pq_m: int = 8                  # subquantizers (halved until d % m == 0)
    # durability (None = process-resident store, the pre-durability mode)
    path: Optional[str] = None    # store directory: WAL + segments + manifest
    wal_group_records: int = 8    # group-commit every N records ...
    wal_group_bytes: int = 1 << 20  # ... or on a record this large


class LSMStore:
    def __init__(self, schema: Schema, cfg: Optional[LSMConfig] = None,
                 index_factory: Optional[Callable[[Column], Any]] = None,
                 memtable_factory: Optional[Callable[[Schema], Any]] = None):
        from repro.core.index import (GlobalIndexSet,
                                      default_index_factory)  # lazy: no cycle
        self.schema = schema
        self.cfg = cfg or LSMConfig()
        self._memtable_factory = memtable_factory or mt.MemTable
        self.memtable = self._memtable_factory(schema)
        self.sealed: List[Any] = []      # full memtables awaiting flush
        self.segments: List[seg_lib.Segment] = []
        self._seqno = 0
        self._index_factory = index_factory or default_index_factory
        self.global_index = GlobalIndexSet(schema)
        # quantized residence: col -> (book_id, codebooks); trained once
        # on the first flush, reused for every later flush so all of this
        # store's segments share one book (packable cross-segment)
        self._pq_books: Dict[str, Tuple[int, np.ndarray]] = {}
        # fast path: when every pk was written once and nothing deleted,
        # visibility resolution is the identity (skipped in NRA/executor)
        self.unique_pks = True
        self._seen_max_pk = -1
        self.metrics = {"flushes": 0, "compactions": 0, "puts": 0,
                        "deletes": 0, "noop_deletes": 0, "seals": 0,
                        "stalls": 0, "flush_s": 0.0, "compact_s": 0.0,
                        "index_build_s": 0.0, "index_merge_s": 0.0,
                        "index_rebuild_s": 0.0, "index_merges": 0,
                        "index_rebuilds": 0, "vis_extends": 0}
        self._on_delta: List[Callable] = []   # continuous-query hooks
        self._mt_epoch = 0                    # bumps on any memtable change
        self._mt_cache = None                 # (epoch, concat scan arrays)
        # store lock: every mutation of shared state (segments, sealed,
        # memtable, metrics, global_index, caches, PQ books) happens under
        # it.  Re-entrant so visibility helpers called from a publish
        # window can re-take it.  Expensive work (segment build, index
        # construction, PQ training) runs OUTSIDE; only the publish is
        # locked.  Lock order: never hold _lock while waiting on the
        # scheduler's condition variable.
        self._lock = threading.RLock()
        # durability: storage dir + WAL attach (and recovery) BEFORE the
        # scheduler exists, so a background worker never observes a
        # half-recovered store
        self.faults: FaultInjector = NO_FAULTS
        self.storage: Optional[manifest_lib.StoreDir] = None
        self.wal: Optional[wal_lib.WriteAheadLog] = None
        self._flushed_seqno = -1   # manifest frontier: max seqno durable
        #                            in segments (monotone; WAL GC bound)
        self._closed = False
        if self.cfg.path:
            self._attach_storage(self.cfg.path)
        self.scheduler = FlushScheduler(self)

    def set_faults(self, faults: FaultInjector) -> None:
        """Wire a fault injector into every crash point this store (and
        its WAL) passes through — test-only."""
        self.faults = faults
        if self.wal is not None:
            self.wal.faults = faults

    # ------------------------------------------------- durability / recovery
    def _attach_storage(self, path: str) -> None:
        """Recovery: load the latest manifest (segments with their
        indexes and PQ codes), GC orphan files from crashed flushes,
        then replay every WAL row past the durable frontier into a
        fresh memtable — a handful of vectorized ``put_batch`` calls
        with the original seqnos."""
        self.storage = manifest_lib.StoreDir(path)
        state = self.storage.load_latest()
        tracked_to = 0
        if state is not None:
            tracked_to = self._load_state(state)
        self.storage.gc_orphans(
            [f"seg-{s.seg_id:08d}.npz" for s in self.segments])
        self.wal = wal_lib.WriteAheadLog(
            self.storage.wal_dir, self.cfg.wal_group_records,
            self.cfg.wal_group_bytes, faults=self.faults)
        # materialize before applying: sealing below rotates the WAL,
        # which must not race the replay iterator's file walk
        records = list(self.wal.replay())
        for rec in records:
            self._apply_wal_record(rec, tracked_to)
            # re-run the live write path's seal decision after each
            # record so recovery converges to the exact memtable/segment
            # layout an uncrashed store fed the same batches would have
            # (the memtable must never sit above the flush threshold —
            # plans assume that invariant, and result parity with an
            # uncrashed twin depends on the layout matching)
            if len(self.memtable) >= self.cfg.flush_rows or (
                    self.cfg.flush_bytes > 0
                    and self.memtable.approx_bytes >= self.cfg.flush_bytes):
                self.seal()
        while self.sealed:
            self._flush_sealed()
        level = self._compactable_level()
        while level is not None:
            self._compact_level(level)
            level = self._compactable_level()
        # every replayed row is already on disk: acknowledged again
        self.wal.durable_seqno = self._seqno - 1

    def _load_state(self, state: Dict[str, Any]) -> int:
        """Rebuild the segment set from one manifest generation; returns
        the manifest's ``next_seqno`` (the boundary above which WAL rows
        were never reflected in the persisted unique-pk tracking)."""
        from repro.core import quantize as qz
        if state["schema"] != manifest_lib.schema_to_json(self.schema):
            raise ValueError("schema mismatch with on-disk manifest")
        for ent in state["segments"]:
            seg = seg_lib.load_segment(
                self.schema,
                os.path.join(self.storage.segments_dir, ent["file"]),
                self._index_factory)
            self.segments.append(seg)
            self.global_index.on_new_segment(seg)
        # re-key loaded PQ codes: one fresh shared book id per column, so
        # pack_quantized's same-book gate spans loaded + future segments
        for col in self._vector_columns():
            loaded = [s.quantized[col.name] for s in self.segments
                      if col.name in s.quantized]
            if loaded:
                bid = qz.fresh_book_id()
                for qc in loaded:
                    qc.book_id = bid
                self._pq_books[col.name] = (bid, loaded[0].codebooks)
        self._flushed_seqno = int(state["frontier"])
        self._seqno = self._flushed_seqno + 1
        self.unique_pks = bool(state["unique_pks"])
        self._seen_max_pk = int(state["seen_max_pk"])
        return int(state["next_seqno"])

    def _apply_wal_record(self, rec: wal_lib.WalRecord,
                          tracked_to: int) -> None:
        """Re-apply one logged batch: keep the contiguous suffix of rows
        past the durable frontier, with their original seqnos."""
        last = rec.seqno_start + rec.n_rows - 1
        if last <= self._flushed_seqno:
            return
        skip = max(0, self._flushed_seqno + 1 - rec.seqno_start)
        pks = rec.pks[skip:]
        start = rec.seqno_start + skip
        if rec.rtype == wal_lib.REC_DELETE:
            if last >= tracked_to:
                self.unique_pks = False
            self._seqno = self.memtable.put_batch(pks, {}, start,
                                                  tombstone=True)
            self.metrics["deletes"] += len(pks)
        else:
            tskip = max(0, tracked_to - start)
            if tskip < len(pks):
                self._track_unique(pks[tskip:])
            batch = {k: v[skip:] for k, v in rec.batch.items()}
            self._seqno = self.memtable.put_batch(pks, batch, start)
            self.metrics["puts"] += len(pks)
        self._mt_epoch += 1
        self._mt_cache = None

    def _durable_state(self) -> Dict[str, Any]:
        """Manifest payload for the current segment set (caller holds
        ``_lock``).  The frontier is monotone: compaction may drop the
        row carrying the previous max seqno, but WAL GC already trusted
        it, so it never moves backwards."""
        new_max = max((int(s.seqno.max()) for s in self.segments
                       if s.n_rows), default=-1)
        return {"schema": manifest_lib.schema_to_json(self.schema),
                "segments": [manifest_lib.segment_entry(s)
                             for s in self.segments],
                "frontier": int(max(self._flushed_seqno, new_max)),
                "next_seqno": int(self._seqno),
                "unique_pks": bool(self.unique_pks),
                "seen_max_pk": int(self._seen_max_pk)}

    def _publish_manifest(self) -> None:
        """Atomically commit the segment set (caller holds ``_lock``),
        then drop WAL files fully covered by the new frontier."""
        state = self._durable_state()
        self._flushed_seqno = state["frontier"]
        self.storage.publish(state, self.faults)
        if self.wal is not None:
            self.wal.gc(self._flushed_seqno)

    @property
    def durable_seqno(self) -> int:
        """Highest seqno the store acknowledges as crash-durable:
        group-committed in the WAL or captured in a published segment.
        In-memory stores acknowledge everything (nothing survives)."""
        if self.wal is None:
            return self._seqno - 1
        return max(self.wal.durable_seqno, self._flushed_seqno)

    def close(self) -> None:
        """Idempotent shutdown: stop the background flush worker (it
        drains queued work first), then group-commit and seal the WAL."""
        if self._closed:
            return
        self._closed = True
        self.scheduler.close()
        with self._lock:
            if self.wal is not None:
                self.wal.close()

    def snapshot(self, path: str) -> None:
        """Write a self-contained copy of the store to ``path``: flush
        everything (so the WAL side is empty), save each segment file,
        publish a manifest.  ``Database.restore(path)`` — or any store
        configured with ``path=...`` — opens it."""
        self.flush()
        sd = manifest_lib.StoreDir(path)
        with self._lock:
            segs = list(self.segments)
            state = self._durable_state()
        for s in segs:
            seg_lib.save_segment(s, sd.segment_path(s.seg_id))
        sd.publish(state)

    # ------------------------------------------------------------------ write
    def put(self, pks: Sequence[int], batch: Dict[str, Any]) -> None:
        """Ingest one columnar batch: O(#columns) array appends into the
        active memtable; flush/compaction/indexing happen off this path
        via the scheduler.  Empty batches are a complete no-op (no delta
        hooks, no metrics)."""
        pks = np.asarray(pks, np.int64)
        if len(pks) == 0:
            return
        if self.wal is not None:
            # canonicalize outside the lock so the WAL logs exactly the
            # arrays the memtable stores (replay re-applies them as-is)
            validate_batch(self.schema, batch, len(pks))
            batch = {c.name: mt.as_column_array(c, batch[c.name], len(pks))
                     for c in self.schema.columns}
        cbatch = batch
        with self._lock:
            if self.wal is not None:
                self.wal.append(pks, batch, self._seqno)
            self._track_unique(pks)
            self._seqno = self.memtable.put_batch(pks, batch, self._seqno)
            self._mt_epoch += 1
            self._mt_cache = None
            self.metrics["puts"] += len(pks)
            REGISTRY.inc("lsm.puts", len(pks))
            if self._on_delta and isinstance(self.memtable, mt.MemTable):
                # hand hooks the memtable's canonical numpy chunk
                # (zero-copy, already validated) — never per-row dicts
                cbatch = {name: chunks[-1] for name, chunks
                          in self.memtable._col_chunks.items()}
        # hooks and backpressure run unlocked: on_write may wait on the
        # scheduler's condition variable, which the worker needs _lock-free
        if self._on_delta:
            self._notify_delta(pks, cbatch, deleted=False)
        self.scheduler.on_write()

    def delete(self, pks: Sequence[int]) -> None:
        """Tombstone the given pks.  Deletes of never-written keys are
        no-ops: they write nothing and keep the ``unique_pks`` fast path
        (visibility resolution stays skippable)."""
        pks = np.asarray(pks, np.int64)
        if len(pks) == 0:
            return
        with self._lock:
            exists = self._contains_any_version(pks)
            if not exists.any():
                self.metrics["noop_deletes"] += len(pks)
                return
            live = pks[exists]
            if self.wal is not None:
                self.wal.append(live, {}, self._seqno, tombstone=True)
            self.unique_pks = False
            self._seqno = self.memtable.put_batch(live, {}, self._seqno,
                                                  tombstone=True)
            self._mt_epoch += 1
            self._mt_cache = None
            self.metrics["deletes"] += len(live)
            self.metrics["noop_deletes"] += int(len(pks) - len(live))
            REGISTRY.inc("lsm.deletes", len(live))
        self._notify_delta(live, None, deleted=True)
        self.scheduler.on_write()

    def _track_unique(self, pks: np.ndarray) -> None:
        if self.unique_pks:
            if int(pks.min()) <= self._seen_max_pk:
                self.unique_pks = False
            elif len(pks) > 1 and not (np.diff(pks) > 0).all() and \
                    len(np.unique(pks)) != len(pks):
                self.unique_pks = False
        self._seen_max_pk = max(self._seen_max_pk, int(pks.max()))

    def _contains_any_version(self, pks: np.ndarray) -> np.ndarray:
        """Bool mask: does any version (including tombstones) of each pk
        exist in the store?  Vectorized over segments; memtables checked
        via their O(1) key maps."""
        out = np.zeros(len(pks), bool)
        if self._seen_max_pk < 0:
            return out
        cand = np.nonzero(pks <= self._seen_max_pk)[0]
        if not len(cand):
            return out
        for m in (self.memtable, *self.sealed):
            if len(m):
                latest = m._latest
                for i in cand:
                    if int(pks[i]) in latest:
                        out[i] = True
        rest = cand[~out[cand]]
        for seg in self.segments:
            if not len(rest):
                break
            if seg.n_rows == 0:
                continue
            pos = np.minimum(np.searchsorted(seg.pk, pks[rest]),
                             seg.n_rows - 1)
            hit = seg.pk[pos] == pks[rest]
            out[rest[hit]] = True
            rest = rest[~hit]
        return out

    def on_delta(self, fn: Callable) -> None:
        """Register a hook called with ``(pks, batch, deleted)`` on writes
        — ``pks`` an int64 array and ``batch`` a columnar dict of numpy
        arrays (None for deletes).  Drives incremental view maintenance
        and ASYNC continuous queries."""
        self._on_delta.append(fn)

    def _notify_delta(self, pks, batch, deleted: bool) -> None:
        for fn in self._on_delta:
            fn(pks, batch, deleted)

    # ------------------------------------------------- flush / compaction
    def seal(self) -> bool:
        """Move the active memtable onto the flush queue (O(1) swap)."""
        with self._lock:
            if not len(self.memtable):
                return False
            self.sealed.append(self.memtable)
            self.memtable = self._memtable_factory(self.schema)
            self._mt_epoch += 1
            self._mt_cache = None
            self.metrics["seals"] += 1
            REGISTRY.inc("lsm.seals")
            if self.wal is not None:
                # group-commit everything the sealed memtable holds and
                # start a fresh file: WAL files align with flush units,
                # so GC can drop whole files once a publish covers them
                self.wal.rotate(self._seqno)
            return True

    def flush(self) -> Optional[seg_lib.Segment]:
        """Seal the active memtable and drain all queued work; returns
        the segment the active memtable became (None if it was empty)."""
        sealed_now = self.seal()
        segs = self.scheduler.drain()
        return segs[-1] if (segs and sealed_now) else None

    def drain(self) -> List[seg_lib.Segment]:
        """Deterministically process all queued flushes + compactions
        (pipelined mode); returns the segments flushed."""
        return self.scheduler.drain()

    def _flush_sealed(self) -> seg_lib.Segment:
        """Turn the oldest sealed memtable into a level-0 segment with
        its indexes, then extend the visibility cache incrementally (a
        flush relocates versions without changing any pk's winner)."""
        from repro.core import visibility as vis_lib
        with self._lock:
            mtab = self.sealed[0]
        t0 = time.perf_counter()
        with obs_trace.span("flush") as fsp:
            # build outside the lock: the sealed memtable is immutable
            # (only the active one takes writes) and the segment is
            # private until published, so index construction never
            # blocks writers/readers
            pk, seqno, tomb, cols = mtab.scan_arrays()
            seg = seg_lib.Segment(self.schema, pk, seqno, tomb, cols,
                                  level=0)
            self._build_indexes(seg)
            self._quantize_segment(seg)
            if self.storage is not None:
                # the file must be durable BEFORE the manifest names it
                # (durability/fsync-before-publish): save fsyncs + renames
                seg_lib.save_segment(
                    seg, self.storage.segment_path(seg.seg_id),
                    self.faults)
                self.faults.crash("flush.before-publish")
            with self._lock:
                # atomic publish: readers see (old segments + sealed) or
                # (new segment, sealed popped) — never the torn middle
                pre_key = (self._seqno,
                           tuple(s.seg_id for s in self.segments))
                self.segments.append(seg)
                self.sealed.pop(0)
                self._mt_epoch += 1
                self._mt_cache = None
                self.global_index.on_new_segment(seg)
                if vis_lib.extend_cache_on_flush(self, pre_key, seg,
                                                 len(pk)):
                    self.metrics["vis_extends"] += 1
                seg.sort_order = None  # one-shot; don't retain 8B/row
                self.metrics["flushes"] += 1
                dt = time.perf_counter() - t0
                self.metrics["flush_s"] += dt
                if self.storage is not None:
                    self._publish_manifest()
            REGISTRY.observe("lsm.flush_s", dt)
            REGISTRY.inc("lsm.flushes")
            if fsp.live:
                fsp.set(rows=len(pk), seg_id=seg.seg_id)
        return seg

    def _build_indexes(self, seg: seg_lib.Segment) -> None:
        """Per-segment index construction at SST-build time (paper §4)."""
        if not self.cfg.build_indexes:
            return
        t0 = time.perf_counter()
        for col in self.schema.indexed_columns:
            idx = self._index_factory(col)
            if idx is not None:
                idx.build(seg, col)
                seg.indexes[col.name] = idx
        with self._lock:
            self.metrics["index_build_s"] += time.perf_counter() - t0

    # ------------------------------------------------ quantized residence
    def _vector_columns(self):
        return [c for c in self.schema.columns
                if c.ctype == ColumnType.VECTOR]

    def _quantize_segment(self, seg: seg_lib.Segment) -> None:
        """Encode-at-flush: PQ codes for every vector column, stored
        alongside the fp32 column (the quantized residence tier the fused
        quantized scan streams).  Codebooks come from the store-level
        cache — only the very first flush of a column trains."""
        if not self.cfg.quantize_vectors:
            return
        t0 = time.perf_counter()
        for col in self._vector_columns():
            self._encode_quantized(seg, col.name)
        with self._lock:
            self.metrics["quantize_s"] = \
                self.metrics.get("quantize_s", 0.0) \
                + (time.perf_counter() - t0)

    def _encode_quantized(self, seg: seg_lib.Segment, name: str) -> None:
        from repro.core import quantize as qz
        vecs = np.asarray(seg.columns[name], np.float32)
        if not len(vecs):
            return
        cached = self._pq_books.get(name)
        if cached is None:
            qc = qz.quantize_column(vecs, m=self.cfg.pq_m)
            with self._lock:
                self._pq_books[name] = (qc.book_id, qc.codebooks)
        else:
            bid, books = cached
            qc = qz.QuantizedColumn(qz.encode(vecs, books), books, bid)
        seg.quantized[name] = qc
        seg.content_gen += 1      # invalidate packed-code cache entries

    def _merge_quantized(self, tier, merged, row_maps) -> None:
        """Compaction maintenance for the quantized tier: donate the
        largest part's codebooks and copy its codes through the row maps
        (``quantize.merge_quantized`` — assignment pass at most, never a
        retrain).  Parts without codes force a plain re-encode from the
        store's cached books."""
        from repro.core import quantize as qz
        t0 = time.perf_counter()
        for col in self._vector_columns():
            parts = [s.quantized.get(col.name) for s in tier]
            if all(p is not None for p in parts) and any(
                    len(p.codes) for p in parts):
                merged.quantized[col.name] = qz.merge_quantized(
                    parts, merged.columns[col.name], row_maps)
                merged.content_gen += 1
            else:
                self._encode_quantized(merged, col.name)
        with self._lock:
            self.metrics["quantize_s"] = \
                self.metrics.get("quantize_s", 0.0) \
                + (time.perf_counter() - t0)

    def _compactable_level(self) -> Optional[int]:
        """Lowest level whose tier reached the size-tiered fanout."""
        counts: Dict[int, int] = {}
        for s in self.segments:
            counts[s.level] = counts.get(s.level, 0) + 1
        for level in range(self.cfg.max_levels):
            if counts.get(level, 0) >= self.cfg.fanout:
                return level
        return None

    def _compact_level(self, level: int) -> seg_lib.Segment:
        """Merge one full tier into a level+1 segment, *merging* the
        per-segment indexes through the compaction row maps instead of
        rebuilding them (paper §4's compaction-aware maintenance)."""
        with self._lock:
            tier = [s for s in self.segments if s.level == level]
            bottom = level + 1 >= self.cfg.max_levels or not any(
                s.level > level for s in self.segments)
        t0 = time.perf_counter()
        with obs_trace.span("compact", level=level,
                            n_inputs=len(tier)) as csp:
            # merge + index maintenance outside the lock: inputs are
            # immutable segments, the output is private until published
            merged, row_maps = seg_lib.merge_segments(
                self.schema, tier, level + 1, drop_tombstones=bottom,
                return_maps=True)
            merged.sort_order = None   # identity by construction; drop it
            if self.cfg.build_indexes:
                self._merge_or_rebuild_indexes(tier, merged, row_maps)
            if self.cfg.quantize_vectors:
                self._merge_quantized(tier, merged, row_maps)
            if self.storage is not None:
                seg_lib.save_segment(
                    merged, self.storage.segment_path(merged.seg_id),
                    self.faults)
                self.faults.crash("compact.before-publish")
            with self._lock:
                # single-assignment swap so concurrent readers iterating
                # self.segments never observe a half-replaced tier
                keep = [s for s in self.segments if s not in tier]
                keep.append(merged)
                self.segments = keep
                for s in tier:
                    self.global_index.on_drop_segment(s.seg_id)
                self.global_index.on_new_segment(merged)
                self.metrics["compactions"] += 1
                dt = time.perf_counter() - t0
                self.metrics["compact_s"] += dt
                if self.storage is not None:
                    self._publish_manifest()
                    self.faults.crash("compact.after-publish")
                    # the swap is durable: the inputs are garbage now
                    for s in tier:
                        try:
                            os.remove(
                                self.storage.segment_path(s.seg_id))
                        except OSError:
                            pass
            REGISTRY.observe("lsm.compact_s", dt)
            REGISTRY.inc("lsm.compactions")
            if csp.live:
                csp.set(out_rows=merged.n_rows)
        return merged

    def _merge_or_rebuild_indexes(self, tier, merged, row_maps) -> None:
        """Index maintenance at compaction: structural merge when every
        input segment has a compatible built index, fresh rebuild
        otherwise; both paths are timed separately in ``metrics`` so the
        merge-vs-rebuild win is measurable."""
        for col in self.schema.indexed_columns:
            idx = self._index_factory(col)
            if idx is None:
                continue
            parts = [s.indexes.get(col.name) for s in tier]
            mergeable = self.cfg.merge_indexes and all(
                p is not None and type(p) is type(idx) for p in parts)
            t0 = time.perf_counter()
            if mergeable:
                idx.merge(parts, merged, col, row_maps)
                with self._lock:
                    self.metrics["index_merge_s"] += \
                        time.perf_counter() - t0
                    self.metrics["index_merges"] += 1
            else:
                idx.build(merged, col)
                with self._lock:
                    self.metrics["index_rebuild_s"] += \
                        time.perf_counter() - t0
                    self.metrics["index_rebuilds"] += 1
            merged.indexes[col.name] = idx

    # ------------------------------------------------------------------- read
    def get(self, key: int) -> Optional[Dict[str, Any]]:
        best = None
        # memtables newest-first: active, then sealed youngest->oldest
        for m in (self.memtable, *reversed(self.sealed)):
            best = m.get(key)
            if best is not None:
                break
        if best is None:
            # newest-first: segments are appended in time order
            for seg in reversed(self.segments):
                if not seg.may_contain(key):
                    continue
                i = seg.get(key)
                if i is not None:
                    r = seg.row(i)
                    if best is None or r["_seqno"] > best["_seqno"]:
                        best = r
        if best is None or best["_tombstone"]:
            return None
        return best

    @property
    def n_rows(self) -> int:
        return sum(s.n_rows for s in self.segments) + self.memtable_rows

    @property
    def memtable_rows(self) -> int:
        """Rows buffered in RAM (active + sealed awaiting flush)."""
        return len(self.memtable) + sum(len(m) for m in self.sealed)

    def all_segments(self) -> List[seg_lib.Segment]:
        return list(self.segments)

    def memtable_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                       Dict[str, np.ndarray]]:
        """Columnar view over ALL RAM-resident rows (sealed memtables
        oldest-first, then the active one) — the read paths' single
        window onto unflushed data, cached per write epoch."""
        with self._lock:
            if self._mt_cache is None or \
                    self._mt_cache[0] != self._mt_epoch:
                parts = [m.scan_arrays()
                         for m in (*self.sealed, self.memtable)]
                self._mt_cache = (
                    self._mt_epoch,
                    mt.concat_memtable_arrays(parts, self.schema))
            return self._mt_cache[1]

    # visible-version resolution across segments (newest seqno per pk wins)
    def resolve_visible(self, per_segment_rows: Dict[int, np.ndarray]
                        ) -> Dict[int, np.ndarray]:
        """Given {seg_id: row_indices}, drop rows shadowed by newer versions
        of the same pk elsewhere (or by memtable / tombstones).  Delegates
        to the shared vectorized resolver in ``core.visibility``."""
        from repro.core import visibility
        return visibility.visibility_index(self).resolve(per_segment_rows)
