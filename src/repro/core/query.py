"""Typed query AST mirroring the paper's four query types (§2.2).

Leaf filter predicates (hybrid search):
  Range(col, lo, hi)          — relational range / equality
  GeoWithin(col, rect)        — ST_Contains(col, @region)
  TextContains(col, term)     — content LIKE '%kw%' via inverted index
  VectorRange(col, q, thresh) — L2_Distance(col, q) < thresh

Boolean combinators compose leaves into a filter *expression tree*:
  And(a, b, ...) | Or(a, b, ...) | Not(a)

The planner normalizes expressions to DNF (``to_dnf``): a disjunction of
conjuncts, each conjunct a tuple of *literals* (a leaf predicate or a
``Not``-wrapped leaf).  Each conjunct is planned with the per-subset index
enumeration; conjunct bitmaps are OR-merged by the ``BitmapUnion``
physical operator.

Rank terms (hybrid NN, weighted sum — Algorithm 1's  s(o) = Σ λ_j d_j(o)):
  VectorRank(col, q, weight)
  SpatialRank(col, point, weight)
  TextRank(col, terms, weight)

HybridQuery(where, ranks, k): ranks empty => Type-1 hybrid search;
ranks non-empty => Type-2 hybrid NN. Continuous wrappers (Type 3/4) live
in core.continuous; the user-facing facade lives in core.api.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np


# ---------------------------------------------------------------------------
# leaf filter predicates
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Range:
    col: str
    lo: float
    hi: float                      # inclusive bounds


@dataclasses.dataclass(frozen=True)
class GeoWithin:
    col: str
    rect: Tuple[float, float, float, float]   # (xmin, ymin, xmax, ymax)


@dataclasses.dataclass(frozen=True)
class TextContains:
    col: str
    term: str


@dataclasses.dataclass(frozen=True, eq=False)
class VectorRange:
    """L2 distance below a threshold."""
    col: str
    q: np.ndarray
    thresh: float

    def __post_init__(self):
        object.__setattr__(self, "q", np.asarray(self.q, np.float32))
        object.__setattr__(self, "thresh", float(self.thresh))

    def __eq__(self, other):
        return (isinstance(other, VectorRange) and self.col == other.col
                and self.thresh == other.thresh
                and self.q.shape == other.q.shape
                and self.q.tobytes() == other.q.tobytes())

    def __hash__(self):
        return hash((self.col, self.q.tobytes(), self.thresh))

    def __repr__(self):
        return f"VectorRange({self.col}, dim={self.q.shape}, <{self.thresh})"


Predicate = Union[Range, GeoWithin, TextContains, VectorRange]


# ---------------------------------------------------------------------------
# boolean combinators
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, init=False)
class And:
    children: Tuple["BoolExpr", ...]

    def __init__(self, *children):
        if len(children) == 1 and isinstance(children[0], (list, tuple)):
            children = tuple(children[0])
        object.__setattr__(self, "children", tuple(children))

    def __repr__(self):
        return "And(" + ", ".join(repr(c) for c in self.children) + ")"


@dataclasses.dataclass(frozen=True, init=False)
class Or:
    children: Tuple["BoolExpr", ...]

    def __init__(self, *children):
        if len(children) == 1 and isinstance(children[0], (list, tuple)):
            children = tuple(children[0])
        object.__setattr__(self, "children", tuple(children))

    def __repr__(self):
        return "Or(" + ", ".join(repr(c) for c in self.children) + ")"


@dataclasses.dataclass(frozen=True)
class Not:
    child: "BoolExpr"


BoolExpr = Union[Predicate, And, Or, Not]

# a literal is what DNF conjuncts are made of: a leaf or a negated leaf
Literal = Union[Predicate, Not]


def is_leaf(expr) -> bool:
    return isinstance(expr, (Range, GeoWithin, TextContains, VectorRange))


def is_literal(expr) -> bool:
    return is_leaf(expr) or (isinstance(expr, Not) and is_leaf(expr.child))


def leaf_predicates(expr) -> List[Predicate]:
    """Every leaf predicate in the expression, negation stripped."""
    if expr is None:
        return []
    if is_leaf(expr):
        return [expr]
    if isinstance(expr, Not):
        return leaf_predicates(expr.child)
    if isinstance(expr, (And, Or)):
        out: List[Predicate] = []
        for c in expr.children:
            out.extend(leaf_predicates(c))
        return out
    raise TypeError(f"unknown filter expression {expr!r}")


def expr_cols(expr) -> List[str]:
    """Columns referenced by the expression (deduped, stable order)."""
    return list(dict.fromkeys(p.col for p in leaf_predicates(expr)))


def conjunction_literals(expr) -> List[Literal]:
    """Flatten a pure conjunction into its literal list.

    Accepts None (-> []), a single literal, or (nested) ``And`` of
    literals.  Raises ``ValueError`` for expressions containing ``Or`` or
    non-leaf negation — callers needing those must plan via ``to_dnf``.
    """
    if expr is None:
        return []
    if is_literal(expr):
        return [expr]
    if isinstance(expr, And):
        out: List[Literal] = []
        for c in expr.children:
            out.extend(conjunction_literals(c))
        return out
    raise ValueError(f"not a pure conjunction: {expr!r}")


# ---------------------------------------------------------------------------
# DNF normalization
# ---------------------------------------------------------------------------

def _nnf(expr, negate: bool):
    """Negation normal form: push Not down to the leaves (De Morgan)."""
    if is_leaf(expr):
        return Not(expr) if negate else expr
    if isinstance(expr, Not):
        return _nnf(expr.child, not negate)
    if isinstance(expr, And):
        kids = tuple(_nnf(c, negate) for c in expr.children)
        return Or(kids) if negate else And(kids)
    if isinstance(expr, Or):
        kids = tuple(_nnf(c, negate) for c in expr.children)
        return And(kids) if negate else Or(kids)
    raise TypeError(f"unknown filter expression {expr!r}")


def _distribute(expr) -> List[Tuple[Literal, ...]]:
    """NNF expression -> list of conjuncts (AND distributed over OR)."""
    if is_literal(expr):
        return [(expr,)]
    if isinstance(expr, Or):
        out: List[Tuple[Literal, ...]] = []
        for c in expr.children:
            out.extend(_distribute(c))
        return out
    if isinstance(expr, And):
        acc: List[Tuple[Literal, ...]] = [()]
        for c in expr.children:
            acc = [a + b for a in acc for b in _distribute(c)]
        return acc
    raise TypeError(f"unknown filter expression {expr!r}")


def _complement(lit: Literal) -> Literal:
    return lit.child if isinstance(lit, Not) else Not(lit)


def to_dnf(expr) -> List[Tuple[Literal, ...]]:
    """Normalize a filter expression to disjunctive normal form.

    Returns a list of conjuncts; each conjunct is a tuple of literals
    (leaf predicates, possibly ``Not``-wrapped).  The degenerate values
    follow the boolean algebra: ``None`` (no filter — always true)
    returns ``[()]``, the single empty conjunct; an unsatisfiable
    expression returns ``[]``, the empty disjunction (always false) —
    the two MUST stay distinct or a contradictory WHERE would match
    every row.  The result is simplified: duplicate literals within a
    conjunct are dropped, contradictory conjuncts (p AND NOT p) removed,
    duplicate conjuncts deduped, and absorbed conjuncts (supersets of
    another conjunct) pruned — making normalization idempotent.
    """
    if expr is None:
        return [()]
    conjuncts = []
    for raw in _distribute(_nnf(expr, negate=False)):
        lits = tuple(dict.fromkeys(raw))          # dedup, stable order
        if any(_complement(lt) in lits for lt in lits):
            continue                              # p AND NOT p: always false
        conjuncts.append(lits)
    # dedup + absorption: a conjunct strictly containing another conjunct's
    # literal set matches a subset of its rows and can be dropped
    sets = [frozenset(c) for c in conjuncts]
    keep: List[Tuple[Literal, ...]] = []
    seen = set()
    for i, c in enumerate(conjuncts):
        if sets[i] in seen:
            continue
        if any(sets[j] < sets[i] for j in range(len(conjuncts)) if j != i):
            continue
        seen.add(sets[i])
        keep.append(c)
    return keep


def from_dnf(conjuncts: Sequence[Sequence[Literal]]):
    """Inverse of ``to_dnf``: rebuild an expression from conjunct lists.
    ``[()]`` (always true) maps back to None; ``[]`` (always false) has
    no expression form and raises."""
    if not conjuncts:
        raise ValueError("empty DNF (always false) has no expression form")
    terms = []
    for c in conjuncts:
        c = tuple(c)
        if not c:
            return None                # TRUE conjunct absorbs everything
        terms.append(c[0] if len(c) == 1 else And(c))
    return terms[0] if len(terms) == 1 else Or(tuple(terms))


# ---------------------------------------------------------------------------
# rank terms
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class VectorRank:
    col: str
    q: np.ndarray
    weight: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "q", np.asarray(self.q, np.float32))
        object.__setattr__(self, "weight", float(self.weight))

    def __eq__(self, other):
        return (isinstance(other, VectorRank) and self.col == other.col
                and self.weight == other.weight
                and self.q.shape == other.q.shape
                and self.q.tobytes() == other.q.tobytes())

    def __hash__(self):
        return hash((self.col, self.q.tobytes(), self.weight))

    def __repr__(self):
        return f"VectorRank({self.col}, dim={self.q.shape}, w={self.weight})"


@dataclasses.dataclass(frozen=True)
class SpatialRank:
    col: str
    point: Tuple[float, float]
    weight: float = 1.0


@dataclasses.dataclass(frozen=True)
class TextRank:
    col: str
    terms: Tuple[str, ...]
    weight: float = 1.0


RankTerm = Union[VectorRank, SpatialRank, TextRank]


# ---------------------------------------------------------------------------
# query
# ---------------------------------------------------------------------------

class HybridQuery:
    """One declarative hybrid query: ``where`` is a boolean filter
    expression tree (or None), ``ranks`` the weighted rank terms.

    The legacy ``filters=[p1, p2]`` keyword is kept as a compat shim
    (list => implicit ``And``) and emits a ``DeprecationWarning``.
    """

    def __init__(self, where: Optional[BoolExpr] = None,
                 ranks: Sequence[RankTerm] = (), k: int = 10,
                 select: Optional[Sequence[str]] = None,
                 filters: Optional[Sequence[Predicate]] = None,
                 recall_target: Optional[float] = None):
        if isinstance(where, (list, tuple)):       # implicit conjunction
            where = None if not where else \
                where[0] if len(where) == 1 else And(tuple(where))
        if filters is not None:
            warnings.warn(
                "HybridQuery(filters=[...]) is deprecated; pass "
                "where=And(...) (or a single predicate) instead",
                DeprecationWarning, stacklevel=2)
            if where is not None:
                raise ValueError("pass either where= or filters=, not both")
            filters = list(filters)
            where = None if not filters else \
                filters[0] if len(filters) == 1 else And(tuple(filters))
        self.where = where
        self.ranks: List[RankTerm] = list(ranks)
        self.k = int(k)
        self.select = select
        # NN recall/latency knob: None (default) demands exact results;
        # a target < 1.0 lets the planner choose the quantized dispatch
        # (PQ-ADC candidate generation + exact re-rank of refine*k rows)
        if recall_target is not None:
            recall_target = float(recall_target)
            if not 0.0 < recall_target <= 1.0:
                raise ValueError(
                    f"recall_target must be in (0, 1], got {recall_target}")
        self.recall_target = recall_target

    @property
    def is_nn(self) -> bool:
        return bool(self.ranks)

    @property
    def filters(self) -> List[Literal]:
        """Flat literal list when ``where`` is a pure conjunction (the
        shape every pre-expression-tree caller assumed).  Raises
        ``ValueError`` for disjunctive expressions — those execute through
        DNF plans, never a flat AND loop."""
        return conjunction_literals(self.where)

    def __repr__(self):
        return (f"HybridQuery(where={self.where!r}, ranks={self.ranks!r}, "
                f"k={self.k})")


# ---------------------------------------------------------------------------
# continuous query declarations (Type 3 / Type 4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SyncQuery:
    """Re-execute every ``interval_s`` seconds (SYNC 60 seconds)."""
    query: HybridQuery
    interval_s: float
    name: str = ""


@dataclasses.dataclass
class AsyncQuery:
    """Re-execute when underlying data changes (ASYNC)."""
    query: HybridQuery
    name: str = ""
