"""Typed query AST mirroring the paper's four query types (§2.2).

Filter predicates (hybrid search):
  Range(col, lo, hi)          — relational range / equality
  GeoWithin(col, rect)        — ST_Contains(col, @region)
  TextContains(col, term)     — content LIKE '%kw%' via inverted index
  VectorRange(col, q, thresh) — L2_Distance(col, q) < thresh

Rank terms (hybrid NN, weighted sum — Algorithm 1's  s(o) = Σ λ_j d_j(o)):
  VectorRank(col, q, weight)
  SpatialRank(col, point, weight)
  TextRank(col, terms, weight)

HybridQuery(filters, ranks, k): ranks empty => Type-1 hybrid search;
ranks non-empty => Type-2 hybrid NN. Continuous wrappers (Type 3/4) live
in core.continuous.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# filter predicates
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Range:
    col: str
    lo: float
    hi: float                      # inclusive bounds


@dataclasses.dataclass(frozen=True)
class GeoWithin:
    col: str
    rect: Tuple[float, float, float, float]   # (xmin, ymin, xmax, ymax)


@dataclasses.dataclass(frozen=True)
class TextContains:
    col: str
    term: str


class VectorRange:
    """L2 distance below a threshold (frozen-by-convention)."""

    def __init__(self, col: str, q, thresh: float):
        self.col = col
        self.q = np.asarray(q, np.float32)
        self.thresh = float(thresh)

    def __repr__(self):
        return f"VectorRange({self.col}, dim={self.q.shape}, <{self.thresh})"


Predicate = object   # Range | GeoWithin | TextContains | VectorRange


# ---------------------------------------------------------------------------
# rank terms
# ---------------------------------------------------------------------------

class VectorRank:
    def __init__(self, col: str, q, weight: float = 1.0):
        self.col = col
        self.q = np.asarray(q, np.float32)
        self.weight = float(weight)


@dataclasses.dataclass(frozen=True)
class SpatialRank:
    col: str
    point: Tuple[float, float]
    weight: float = 1.0


@dataclasses.dataclass(frozen=True)
class TextRank:
    col: str
    terms: Tuple[str, ...]
    weight: float = 1.0


RankTerm = object    # VectorRank | SpatialRank | TextRank


@dataclasses.dataclass
class HybridQuery:
    filters: List[Predicate] = dataclasses.field(default_factory=list)
    ranks: List[RankTerm] = dataclasses.field(default_factory=list)
    k: int = 10
    select: Optional[Sequence[str]] = None

    @property
    def is_nn(self) -> bool:
        return bool(self.ranks)


# ---------------------------------------------------------------------------
# continuous query declarations (Type 3 / Type 4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SyncQuery:
    """Re-execute every ``interval_s`` seconds (SYNC 60 seconds)."""
    query: HybridQuery
    interval_s: float
    name: str = ""


@dataclasses.dataclass
class AsyncQuery:
    """Re-execute when underlying data changes (ASYNC)."""
    query: HybridQuery
    name: str = ""
