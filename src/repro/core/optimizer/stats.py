"""Unified statistics catalog (paper §5: "detailed statistics and
selectivity estimates for all secondary indexes (vector, spatial, text) in
a unified catalog").

Store-wide estimates are row-weighted aggregates of per-segment index
statistics; rank-modality distance bounds (D_max) feed the NRA upper
bounds and cost estimates.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core import query as q
from repro.core.types import BLOCK_ROWS


class Catalog:
    def __init__(self, store):
        self.store = store

    # ------------------------------------------------------- selectivity
    def selectivity(self, predicate) -> float:
        """Row-weighted average of per-segment index selectivity.  A
        negated literal's selectivity is the complement of its leaf's."""
        if isinstance(predicate, q.Not):
            return min(1.0, max(0.0,
                                1.0 - self.selectivity(predicate.child)))
        col = getattr(predicate, "col", None)
        total, acc = 0, 0.0
        for seg in self.store.segments:
            idx = seg.indexes.get(col)
            n = seg.n_rows
            total += n
            if idx is not None:
                acc += idx.selectivity(seg, predicate) * n
            else:
                acc += self._fallback_selectivity(seg, predicate) * n
        if total == 0:
            return 1.0
        return min(1.0, max(0.0, acc / total))

    def _fallback_selectivity(self, seg, predicate) -> float:
        if isinstance(predicate, q.Range):
            vals = np.asarray(seg.columns[predicate.col], np.float64)
            if len(vals) == 0:
                return 0.0
            lo, hi = float(vals.min()), float(vals.max())
            if hi <= lo:
                return 1.0
            frac = (min(predicate.hi, hi) - max(predicate.lo, lo)) / (hi - lo)
            return max(0.0, min(1.0, frac))
        return 0.5

    # --------------------------------------------------- distance bounds
    def dist_bound(self, rank) -> float:
        """Finite upper bound on the rank term's distance (for NRA UB)."""
        if isinstance(rank, q.TextRank):
            return 1.0                                   # 1/(1+score) <= 1
        if isinstance(rank, q.SpatialRank):
            diag = 0.0
            for seg in self.store.segments:
                idx = seg.indexes.get(rank.col)
                bb = getattr(idx, "bbox", None)
                if bb:
                    diag = max(diag, math.hypot(bb[2] - bb[0], bb[3] - bb[1]))
            px, py = rank.point
            return diag + abs(px) + abs(py) + 1.0
        # vector: (max ||v|| + ||q||)^2 via per-segment max norms
        qn = float(np.linalg.norm(np.asarray(rank.q, np.float32)))
        vmax = 0.0
        for seg in self.store.segments:
            vecs = seg.columns.get(rank.col)
            if vecs is not None and len(vecs):
                idx = seg.indexes.get(rank.col)
                cents = getattr(idx, "centroids", None)
                if cents is not None and len(cents):
                    vmax = max(vmax, float(
                        np.sqrt((cents ** 2).sum(1)).max()) * 2.0)
                else:
                    vmax = max(vmax, float(
                        np.sqrt((np.asarray(vecs[:64]) ** 2).sum(1)).max())
                        * 2.0)
        return (vmax + qn) ** 2 + 1.0

    # ------------------------------------------------------- cardinality
    @property
    def total_rows(self) -> int:
        return self.store.n_rows

    @property
    def total_blocks(self) -> float:
        return sum(s.n_blocks for s in self.store.segments) + \
            max(1, self.store.memtable_rows / BLOCK_ROWS)

    def index_probe_blocks(self, predicate) -> float:
        """Blocks touched probing the predicate's index across (global-
        index-pruned) segments."""
        col = getattr(predicate, "col", None)
        pruned = self.store.global_index.prune(self.store.segments, predicate)
        blocks = 0.0
        for seg in pruned:
            idx = seg.indexes.get(col)
            blocks += idx.probe_cost_blocks(seg, predicate) if idx is not None \
                else seg.n_blocks
        return blocks

    def has_index(self, col: str) -> bool:
        return any(col in seg.indexes for seg in self.store.segments) or \
            not self.store.segments
