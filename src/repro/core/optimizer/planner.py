"""Cost-based plan enumeration for hybrid queries (paper §5).

Filter expressions are normalized to DNF first (``query.where`` may be an
arbitrary And/Or/Not tree over the four leaf predicates).  A single
conjunct plans exactly as before; a disjunction plans every conjunct
independently via the per-subset index enumeration and OR-merges the
per-conjunct bitmaps with the ``BitmapUnion`` operator inside the shared
scan pipeline (plan kinds ``union`` / ``union_nn``).

Hybrid search (Type 1, one conjunct): enumerate every subset of
index-supported literals as the probe set (bitmap intersection), remaining
literals as residuals; compare against a full scan; pick min cost. This is
exactly the "optimal combination of index access paths" claim — single-
index pre-filter and post-filter plans are special cases.

Hybrid NN (Type 2, one conjunct): candidate plans are NRA (Algorithm 1
over unified sorted iterators), pre-filtered exact scan, post-filtered
vector index probe (single vector rank only), and full-scan ranking.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence

from repro.analysis.plan_validator import maybe_validate
from repro.core import query as q
from repro.core.optimizer import cost as cost_lib
from repro.core.optimizer.stats import Catalog
from repro.core.types import BLOCK_ROWS
from repro.kernels import fused_scan as fs_kernel

# global kill switch for the fused kernel path (benchmarks/tests compare
# against the staged per-segment fallback by flipping this)
FUSED_ENABLED = True


@dataclasses.dataclass
class Plan:
    kind: str                      # full_scan | index_intersect |
    #                                prefilter_nn | postfilter_nn | nra |
    #                                full_scan_nn | union | union_nn
    indexed: List = dataclasses.field(default_factory=list)
    residual: List = dataclasses.field(default_factory=list)
    ranks: List = dataclasses.field(default_factory=list)
    k: int = 0
    cost: float = 0.0
    note: str = ""
    subplans: List["Plan"] = dataclasses.field(default_factory=list)
    #                                one search-shaped plan per DNF conjunct
    fused: bool = False            # scan-NN dispatch: fused packed kernel
    #                                (one launch) vs staged per-segment
    quantized: bool = False        # scan-NN dispatch: PQ-ADC candidate
    #                                generation + exact re-rank (recall-
    #                                bounded; only with a recall_target)
    pq_m: int = 0                  # subquantizers of the quantized dispatch
    refine: int = 0                # exact re-rank factor (k' = refine*k)
    graph: bool = False            # scan-NN dispatch: CSR beam-search
    #                                candidate generation + exact re-rank
    #                                (recall-bounded; only with a
    #                                recall_target and per-segment graphs)
    graph_r: int = 0               # CSR out-degree of the probed graphs
    graph_beam: int = 0            # beam width (survivors re-ranked)
    graph_hops: int = 0            # fixed frontier-expansion count
    root: object = None            # operator tree (operators.PhysicalOp)

    def operator_tree(self, catalog=None):
        """The plan's physical-operator tree; built lazily (without cost
        estimates) for hand-constructed plans."""
        if self.root is None:
            from repro.core import operators as ops_lib
            self.root = ops_lib.build_tree(self, catalog)
        return self.root

    _describe_cache: Optional[str] = dataclasses.field(
        default=None, repr=False, compare=False)

    def describe(self) -> str:
        """EXPLAIN: one summary line followed by the operator tree with
        per-operator cost estimates (block-read units).  Rendered once
        per plan object — a plan is immutable after planning, and the
        executor stamps this string into every query's ``ExecStats``
        (N shards re-describing the same plan would otherwise re-render
        the tree N times)."""
        if self._describe_cache is not None:
            return self._describe_cache
        from repro.core.operators import _pred_detail
        if self.graph:
            disp = (f" dispatch=graph(R={self.graph_r}, "
                    f"beam={self.graph_beam}, hops={self.graph_hops})")
        elif self.quantized:
            disp = (f" dispatch=quantized(pq m={self.pq_m}, "
                    f"refine={self.refine})")
        elif self.fused:
            disp = " dispatch=fused"
        else:
            disp = ""
        if self.subplans:
            head = (f"{self.kind}(conjuncts={len(self.subplans)} "
                    f"ranks={len(self.ranks)} cost={self.cost:.1f}{disp})")
        else:
            ix = _pred_detail(self.indexed)
            rs = _pred_detail(self.residual)
            head = (f"{self.kind}(indexed=[{ix}] residual=[{rs}] "
                    f"ranks={len(self.ranks)} cost={self.cost:.1f}{disp})")
        self._describe_cache = head + "\n" + self.operator_tree().explain(1)
        return self._describe_cache


def _index_supported(catalog: Catalog, p) -> bool:
    # negated literals are residual-only: a NOT probe would complement a
    # bitmap whose cost/selectivity bookkeeping assumes positive matches
    if isinstance(p, q.Not):
        return False
    col = getattr(p, "col", None)
    return col is not None and catalog.has_index(col)


def _plan_conjunct(catalog: Catalog, literals: Sequence) -> Plan:
    """Best search-shaped plan (full_scan | index_intersect) for one
    conjunction of literals — the per-subset index enumeration."""
    filters = list(literals)
    supported = [p for p in filters if _index_supported(catalog, p)]
    best = Plan(kind="full_scan", residual=filters,
                cost=cost_lib.full_scan_cost(catalog, filters).total,
                note="fallback")
    # every non-empty subset of supported predicates as the probe set
    for r in range(1, len(supported) + 1):
        for subset in itertools.combinations(supported, r):
            residual = [p for p in filters if p not in subset]
            c = cost_lib.intersect_cost(catalog, list(subset), residual)
            if c.total < best.cost:
                best = Plan(kind="index_intersect", indexed=list(subset),
                            residual=residual, cost=c.total)
    return best


def _empty_plan(query: q.HybridQuery) -> Plan:
    """DNF normalized to FALSE (e.g. ``And(p, Not(p))``): no row can
    match — distinct from the no-filter case, which scans everything."""
    return Plan(kind="empty", ranks=list(query.ranks), k=query.k,
                cost=0.0, note="unsatisfiable filter (DNF = false)")


def plan_hybrid_search(catalog: Catalog, query: q.HybridQuery) -> Plan:
    conjuncts = q.to_dnf(query.where)
    if not conjuncts:
        return _empty_plan(query)
    if len(conjuncts) > 1:
        return plan_union(catalog, query, conjuncts)
    return _plan_conjunct(catalog, conjuncts[0])


def plan_hybrid_nn(catalog: Catalog, query: q.HybridQuery) -> Plan:
    conjuncts = q.to_dnf(query.where)
    if not conjuncts:
        return _empty_plan(query)
    if len(conjuncts) > 1:
        return plan_union(catalog, query, conjuncts)
    filters = list(conjuncts[0])
    ranks = list(query.ranks)
    k = query.k
    candidates: List[Plan] = []

    # full-scan ranking (always valid)
    fc = cost_lib.full_scan_cost(catalog, filters + ranks)
    candidates.append(Plan(kind="full_scan_nn", residual=filters,
                           ranks=ranks, k=k, cost=fc.total))

    # NRA over sorted iterators — needs an index per rank modality
    if ranks and all(_index_supported(catalog, r) for r in ranks):
        nc = cost_lib.nra_cost(catalog, ranks, filters, k)
        candidates.append(Plan(kind="nra", residual=filters, ranks=ranks,
                               k=k, cost=nc.total))

    # pre-filter: best filter sub-plan, then exact ranking of survivors
    if filters:
        fplan = _plan_conjunct(catalog, filters)
        fcost = cost_lib.PlanCost(blocks=fplan.cost, candidates=0)
        pc = cost_lib.prefilter_nn_cost(catalog, filters, ranks, fcost)
        candidates.append(Plan(kind="prefilter_nn", indexed=fplan.indexed,
                               residual=fplan.residual, ranks=ranks, k=k,
                               cost=pc.total))

    # post-filter: single vector rank via IVF probe, filters applied after
    vec_ranks = [r for r in ranks if isinstance(r, q.VectorRank)]
    if len(ranks) == 1 and len(vec_ranks) == 1 and \
            _index_supported(catalog, vec_ranks[0]):
        oc = cost_lib.postfilter_nn_cost(catalog, vec_ranks[0], filters, k)
        candidates.append(Plan(kind="postfilter_nn", residual=filters,
                               ranks=ranks, k=k, cost=oc.total))

    return min(candidates, key=lambda p: p.cost)


def plan_union(catalog: Catalog, query: q.HybridQuery,
               conjuncts: Optional[List] = None) -> Plan:
    """Disjunctive plan: one search-shaped sub-plan per DNF conjunct,
    OR-merged by ``BitmapUnion``; NN queries rank the merged bitmap
    (prefilter shape), so batching and EXPLAIN work unchanged."""
    if conjuncts is None:
        conjuncts = q.to_dnf(query.where)
    subs = [_plan_conjunct(catalog, list(c)) for c in conjuncts]
    total = sum(s.cost for s in subs)
    ranks = list(query.ranks)
    if not ranks:
        return Plan(kind="union", subplans=subs, cost=total,
                    note=f"{len(subs)} conjuncts")
    # rows passing ANY conjunct get exact-ranked (union selectivity bound)
    passing = min(float(catalog.total_rows),
                  sum(cost_lib.conjunct_passing(catalog, list(c))
                      for c in conjuncts))
    rank_blocks = (passing / BLOCK_ROWS) * cost_lib.C_VECTOR_BLOCK * \
        max(1, len(ranks))
    return Plan(kind="union_nn", subplans=subs, ranks=ranks, k=query.k,
                cost=total + rank_blocks + passing * cost_lib.C_ROW_RESIDUAL,
                note=f"{len(subs)} conjuncts")


def _fusable(catalog: Catalog, query: q.HybridQuery) -> bool:
    """Can this query take the fused packed scan->top-k kernel path?

    The fused kernel cuts to k ON DEVICE, before visibility resolution,
    so it is only sound when no candidate can be shadowed by a newer
    version (``unique_pks``).  It ranks by a single monotone distance —
    one positive-weight vector/spatial term — and tie-breaks by pk in
    int32 registers."""
    if not FUSED_ENABLED or len(query.ranks) != 1:
        return False
    r = query.ranks[0]
    if not isinstance(r, (q.VectorRank, q.SpatialRank)) or not r.weight > 0:
        return False
    if not 0 < query.k <= fs_kernel.KMAX:
        return False
    store = catalog.store
    if not store.unique_pks or not store.segments:
        return False
    return max(s.pk_max for s in store.segments) < int(fs_kernel.SENTINEL)


def _quantized_params(catalog: Catalog, query: q.HybridQuery):
    """(pq_m, refine) when the quantized dispatch is admissible for this
    query, else None.  Requires an explicit per-query ``recall_target``
    below 1.0 (the default contract stays exact), a single vector rank
    whose column carries PQ codes on EVERY visible segment (same m), and
    room for the k' = refine*k survivor set in the kernel's top-k
    registers.  Codebook identity across segments is re-checked at pack
    time (``pack_quantized``) — a mixed-book store falls back to the
    exact scan at execution, never to wrong answers."""
    rt = getattr(query, "recall_target", None)
    if rt is None or rt >= 1.0:
        return None
    r = query.ranks[0]
    if not isinstance(r, q.VectorRank):
        return None
    qcols = [s.quantized.get(r.col) if hasattr(s, "quantized") else None
             for s in catalog.store.segments]
    if not qcols or any(c is None or not len(c.codes) for c in qcols):
        return None
    ms = {c.m for c in qcols}
    if len(ms) != 1:
        return None
    # looser targets need fewer survivors re-ranked; the refine ladder is
    # deliberately coarse — recall is monotone in refine and the exact
    # re-rank makes every tier sound, just not equally cheap
    refine = 4 if rt <= 0.95 else (8 if rt <= 0.99 else 16)
    while refine >= 2 and refine * query.k > fs_kernel.KMAX:
        refine //= 2
    if refine < 2:
        return None
    return ms.pop(), refine


def _graph_params(catalog: Catalog, query: q.HybridQuery):
    """(r_degree, beam, hops) when the graph dispatch is admissible for
    this query, else None.  Requires an explicit per-query
    ``recall_target`` below 1.0 (the default contract stays exact) and a
    single vector rank whose column carries a built proximity graph on
    EVERY visible segment — a segment without a graph would silently
    fall back to scanning, voiding the cost advantage (execution still
    checks at pack time and falls back to the exact scan, never to wrong
    answers).  The beam ladder widens with the target: tighter recall
    needs more survivors re-ranked, and the fixed hop count grows so the
    traversal converges before the cut."""
    rt = getattr(query, "recall_target", None)
    if rt is None or rt >= 1.0:
        return None
    r = query.ranks[0]
    if not isinstance(r, q.VectorRank):
        return None
    segs = [s for s in catalog.store.segments if s.n_rows]
    if not segs:
        return None
    idxs = [s.indexes.get(r.col) for s in segs]
    if any(ix is None or getattr(ix, "kind", None) != "graph"
           or getattr(ix, "neighbors", None) is None for ix in idxs):
        return None
    r_deg = max(int(ix.R) for ix in idxs)
    base = 2 if rt <= 0.9 else (4 if rt <= 0.95 else 8)
    beam = min(int(fs_kernel.KMAX), max(32, base * query.k))
    if beam < query.k:
        return None
    hops = 8 if rt <= 0.95 else 10
    return r_deg, beam, hops


def _choose_dispatch(catalog: Catalog, plan: Plan,
                     query: q.HybridQuery) -> Plan:
    """Physical dispatch choice for scan-shaped NN plans: fused packed
    kernel (one launch, (nq, k) back to host) vs staged per-segment
    kernels (one launch per segment, full distance rows back).  Both
    costs are charged ON TOP of the already-chosen logical plan so the
    kind selection above is undisturbed; EXPLAIN surfaces the choice."""
    if plan.kind not in ("full_scan_nn", "prefilter_nn", "union_nn"):
        return plan
    if plan.subplans:
        passing = min(float(catalog.total_rows),
                      sum(cost_lib.conjunct_passing(
                          catalog, list(sp.indexed) + list(sp.residual))
                          for sp in plan.subplans))
    else:
        passing = cost_lib.conjunct_passing(
            catalog, list(plan.indexed) + list(plan.residual))
    staged = cost_lib.staged_dispatch_cost(catalog, passing)
    if not _fusable(catalog, query):
        plan.cost += staged
        return plan
    fused = cost_lib.fused_dispatch_cost(catalog, passing, query.k)
    gp = _graph_params(catalog, query)
    qp = _quantized_params(catalog, query)
    quant = None
    if qp is not None:
        pq_m, refine = qp
        d = query.ranks[0].q.shape[0]
        quant = cost_lib.quantized_dispatch_cost(
            catalog, passing, query.k, refine,
            code_ratio=pq_m / (4.0 * d))
    if gp is not None:
        r_deg, beam, hops = gp
        graph = cost_lib.graph_dispatch_cost(
            catalog, passing, query.k, beam, hops, r_deg)
        if graph <= fused and graph < staged and \
                (quant is None or graph <= quant):
            plan.graph = True
            plan.graph_r = r_deg
            plan.graph_beam = beam
            plan.graph_hops = hops
            plan.cost += graph
            return plan
    if quant is not None and quant <= fused and quant < staged:
        plan.quantized = True
        plan.pq_m = pq_m
        plan.refine = refine
        plan.cost += quant
        return plan
    if fused < staged:
        plan.fused = True
        plan.cost += fused
    else:
        plan.cost += staged
    return plan


def plan_shared_scan(catalog: Catalog, query: q.HybridQuery) -> Plan:
    """Batch-aware physical choice: when many structurally-identical exact
    NN queries execute together, one shared segment sweep with batched
    distance kernels beats N independent sorted-access (NRA) walks — the
    per-segment scan and the ``l2_distances(Q, X)`` call are paid once for
    the whole batch.  Returns the scan-shaped plan for one member."""
    conjuncts = q.to_dnf(query.where)
    if not conjuncts:
        return _empty_plan(query)
    if len(conjuncts) > 1:
        return _choose_dispatch(catalog,
                                plan_union(catalog, query, conjuncts),
                                query)
    filters = list(conjuncts[0])
    if filters:
        fplan = _plan_conjunct(catalog, filters)
        c = cost_lib.prefilter_nn_cost(
            catalog, filters, list(query.ranks),
            cost_lib.PlanCost(blocks=fplan.cost, candidates=0))
        chosen = Plan(kind="prefilter_nn", indexed=fplan.indexed,
                      residual=fplan.residual, ranks=list(query.ranks),
                      k=query.k, cost=c.total, note="batched shared scan")
    else:
        c = cost_lib.full_scan_cost(catalog, list(query.ranks))
        chosen = Plan(kind="full_scan_nn", ranks=list(query.ranks),
                      k=query.k, cost=c.total, note="batched shared scan")
    return maybe_validate(_choose_dispatch(catalog, chosen, query))


def plan(catalog: Catalog, query: q.HybridQuery) -> Plan:
    if query.is_nn:
        chosen = _choose_dispatch(catalog, plan_hybrid_nn(catalog, query),
                                  query)
        if not (chosen.quantized or chosen.graph) and \
                getattr(query, "recall_target", None) is not None:
            # the logical-kind choice above compares exact-scan costs, so
            # an index walk (nra/postfilter) can shadow the quantized or
            # graph scan even though those dispatches touch a fraction
            # of the bytes; re-price the scan shape with its recall-
            # bounded dispatch and switch when that wins
            alt = plan_shared_scan(catalog, query)
            if (alt.quantized or alt.graph) and alt.cost < chosen.cost:
                chosen = alt
    else:
        chosen = plan_hybrid_search(catalog, query)
    chosen.operator_tree(catalog)      # attach EXPLAIN tree with estimates
    return maybe_validate(chosen)
