"""Cost model for hybrid query plans (paper §5: "a robust cost model that
accounts for index access cost within the LSM layout, expected candidate
set size, and residual predicate evaluation overhead").

Units: 1.0 = one block read (HBM->VMEM tile fetch). Kernel compute per
block is folded into per-block constants (distance scans cost more per
block than bitmap filters — MXU vs VPU work).
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.core import query as q
from repro.core.types import BLOCK_ROWS

# per-block kernel cost multipliers (relative to a plain block read)
C_FILTER_BLOCK = 1.2       # bitmap_filter kernel over one block
C_VECTOR_BLOCK = 3.0       # ivf_scan distance kernel over one block
C_ROW_RESIDUAL = 1.0 / BLOCK_ROWS   # fetch+eval one row's residual preds
C_MERGE = 0.5              # per-segment top-k merge overhead

# kernel dispatch model (fused vs staged read-path choice)
C_LAUNCH = 5.0             # fixed overhead per kernel dispatch
C_FUSED_BLOCK = 3.4        # fused scan + on-chip top-k merge per block
#                            (C_VECTOR_BLOCK plus the sort network)
C_D2H_ROW = 1.0 / BLOCK_ROWS   # ship one row of distances device->host

# quantized dispatch model (PQ-ADC candidate generation + exact re-rank)
C_RERANK_ROW = C_VECTOR_BLOCK / BLOCK_ROWS  # gather + exact-score 1 row

# graph dispatch model (CSR beam-search candidate generation + re-rank)
C_HOP = 2.0                # one frontier expansion: neighbor gather +
#                            distance batch + sort-network beam prune
C_GATHER_ROW = 2.0 / BLOCK_ROWS  # random-access row gather — pricier
#                            per row than a streamed block read


@dataclasses.dataclass
class PlanCost:
    blocks: float            # estimated block-read units
    candidates: float        # expected candidate rows materialized

    @property
    def total(self) -> float:
        return self.blocks + self.candidates * C_ROW_RESIDUAL


def full_scan_cost(catalog, filters: List) -> PlanCost:
    nb = catalog.total_blocks
    per_block = C_FILTER_BLOCK * max(1, len(filters))
    for f in filters:
        leaf = f.child if isinstance(f, q.Not) else f
        if isinstance(leaf, q.VectorRange):
            per_block += C_VECTOR_BLOCK
    return PlanCost(blocks=nb * per_block, candidates=0.0)


def conjunct_passing(catalog, literals: List) -> float:
    """Expected rows satisfying a conjunction of literals (independence
    assumption — the same estimate the subset enumeration uses)."""
    sel = 1.0
    for p in literals:
        sel *= catalog.selectivity(p)
    return sel * catalog.total_rows


def intersect_cost(catalog, indexed: List, residual: List) -> PlanCost:
    probe = sum(catalog.index_probe_blocks(p) for p in indexed)
    sel = 1.0
    for p in indexed:
        sel *= catalog.selectivity(p)
    cand = sel * catalog.total_rows
    res_cost = cand * C_ROW_RESIDUAL * max(1, len(residual))
    return PlanCost(blocks=probe + res_cost, candidates=cand)


def prefilter_nn_cost(catalog, filters: List, ranks: List,
                      filter_cost: PlanCost) -> PlanCost:
    sel = 1.0
    for p in filters:
        sel *= catalog.selectivity(p)
    passing = sel * catalog.total_rows
    # exact rank scan over passing rows (gathered into blocks)
    rank_blocks = (passing / BLOCK_ROWS) * C_VECTOR_BLOCK * max(1, len(ranks))
    return PlanCost(blocks=filter_cost.blocks + rank_blocks,
                    candidates=passing)


def postfilter_nn_cost(catalog, vector_rank, filters: List, k: int
                       ) -> PlanCost:
    sel = 1.0
    for p in filters:
        sel *= catalog.selectivity(p)
    sel = max(sel, 1e-6)
    inflation = min(catalog.total_rows, k / sel) / max(k, 1)
    probe = catalog.index_probe_blocks(
        q.VectorRange(vector_rank.col, vector_rank.q, float("inf")))
    probe *= max(1.0, inflation / 4.0)      # deeper probes for low sel
    cand = min(catalog.total_rows, k * inflation)
    return PlanCost(blocks=probe * C_VECTOR_BLOCK,
                    candidates=cand * max(1, len(filters)))


def staged_dispatch_cost(catalog, passing_rows: float) -> float:
    """Dispatch + device->host overhead of the staged NN scan path: one
    distance-kernel launch per segment, and the full per-candidate
    distance matrix shipped back for the host top-k cut."""
    n_segs = max(1, len(catalog.store.segments))
    return C_LAUNCH * n_segs + passing_rows * C_D2H_ROW


def fused_dispatch_cost(catalog, passing_rows: float, k: int) -> float:
    """Dispatch + device->host overhead of the fused packed path: ONE
    launch for the whole batch, only (k) rows shipped back, plus the
    on-chip top-k maintenance surcharge over the scanned blocks."""
    merge_extra = (passing_rows / BLOCK_ROWS) * (C_FUSED_BLOCK
                                                 - C_VECTOR_BLOCK)
    return C_LAUNCH + k * C_D2H_ROW + merge_extra


def quantized_dispatch_cost(catalog, passing_rows: float, k: int,
                            refine: int, code_ratio: float) -> float:
    """Dispatch surcharge of the quantized read path, charged (like the
    other ``*_dispatch_cost`` terms) ON TOP of a logical plan that
    already paid ``C_VECTOR_BLOCK`` per scanned block for a full-
    precision scan.  ``code_ratio`` = code bytes per row / fp32 bytes per
    row (m / 4d): the ADC candidate-generation scan streams only that
    fraction of the bytes, so the dominant term is NEGATIVE — the
    bandwidth saving over the logical plan's assumed exact scan.  Against
    it: two launches (ADC scan + re-rank), the on-chip top-k' surcharge
    on the (smaller) scanned bytes, the exact re-rank of refine*k
    surviving rows, and k result rows shipped back."""
    blocks = passing_rows / BLOCK_ROWS
    scan_savings = blocks * C_VECTOR_BLOCK * (1.0 - code_ratio)
    merge_extra = blocks * code_ratio * (C_FUSED_BLOCK - C_VECTOR_BLOCK)
    rerank = C_LAUNCH + refine * k * C_RERANK_ROW
    return C_LAUNCH + k * C_D2H_ROW + merge_extra + rerank - scan_savings


def graph_dispatch_cost(catalog, passing_rows: float, k: int, beam: int,
                        hops: int, r_degree: int) -> float:
    """Dispatch surcharge of the graph read path, charged (like the other
    ``*_dispatch_cost`` terms) ON TOP of a logical plan that already paid
    ``C_VECTOR_BLOCK`` per scanned block for a full-precision scan.  The
    beam search never streams the column: it gathers only the rows the
    traversal touches, so the dominant term is NEGATIVE — the whole scan
    the logical plan assumed.  Against it: per-hop frontier expansion,
    the gathered rows, the exact re-rank of the beam survivors, and k
    result rows shipped back.

    The gather estimate discounts the naive ``beam * R * hops``: the
    visited bitmap dedups re-expansions, so after the opening fan-out
    (~``beam * R / 4`` rows survive the prune) each hop contributes only
    about half a beam of fresh rows.  Traversal cost is deliberately
    mask-INDEPENDENT — the kernel's beam routes through predicate-
    failing rows (dual accumulators), so a filter changes what is
    admitted, not what is gathered.  Selectivity still decides the
    dispatch: the scan savings shrink with the passing-row count, so
    below the point where a pre-filtered exact scan touches fewer rows
    than the fixed traversal, the graph prices itself out."""
    blocks = passing_rows / BLOCK_ROWS
    scan_savings = blocks * C_VECTOR_BLOCK
    gathered = min(float(catalog.total_rows),
                   beam * (r_degree / 4.0 + hops / 2.0))
    probe = hops * C_HOP + gathered * C_GATHER_ROW
    rerank = C_LAUNCH + beam * C_RERANK_ROW
    return C_LAUNCH + k * C_D2H_ROW + probe + rerank - scan_savings


def nra_cost(catalog, ranks: List, filters: List, k: int) -> PlanCost:
    """NRA touches an estimated depth per modality before bounds close;
    heuristic depth grows with modality count and k."""
    n = max(catalog.total_rows, 1)
    ell = len(ranks)
    depth_frac = min(1.0, (k * 8.0 * ell) / n)
    blocks = 0.0
    for r in ranks:
        per_modality = (n * depth_frac) / BLOCK_ROWS
        mult = C_VECTOR_BLOCK if isinstance(r, q.VectorRank) else C_FILTER_BLOCK
        blocks += per_modality * mult + C_MERGE * len(catalog.store.segments)
    cand = n * depth_frac * ell
    if filters:
        cand *= 1.2
    return PlanCost(blocks=blocks, candidates=cand)
