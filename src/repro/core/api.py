"""Unified public API facade (paper §2.2: one declarative surface for all
four query types).

ARCADE exposes its engine through a single SQL layer; this module is the
repro's equivalent — a session object that owns the store, executor, and
continuous engine, so callers never hand-wire the three:

    from repro.core.api import (Database, Or, Not, Range, TextContains,
                                VectorRank)

    db = Database(schema)
    t = db.table()
    t.put(pks, batch)

    rows = (t.query()
             .where(Or(Range("time", 0, 5),
                       Not(TextContains("body", "spam"))))
             .rank(VectorRank("emb", qvec))
             .limit(10)
             .all())

    print(t.query().where(...).explain())        # BitmapUnion cost tree
    results = db.execute_many([builder1, builder2, ...])
    sub = t.query().where(...).subscribe(interval_s=60.0)   # Type 3
    sub2 = t.query().where(...).subscribe(on_change=True)   # Type 4
    db.advance(now=60.0)                          # virtual-clock tick
    sub.latest

Filter expressions are arbitrary And/Or/Not trees over the leaf
predicates; the planner normalizes them to DNF and OR-merges per-conjunct
bitmaps with ``BitmapUnion`` (see core/optimizer/planner.py).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core import manifest as manifest_lib
from repro.core import query as q
from repro.core.continuous import ContinuousEngine
from repro.core.executor import Executor
from repro.core.lsm import LSMConfig, LSMStore
from repro.core.operators import ExecStats, ResultRow
from repro.core.optimizer import planner as planner_lib  # noqa: F401
from repro.core.shards import (ShardedContinuousEngine, ShardedExecutor,
                               ShardRouter)
# re-exported so `from repro.core.api import ...` is a one-stop import
from repro.core.query import (And, AsyncQuery, GeoWithin,  # noqa: F401
                              HybridQuery, Not, Or, Range, SpatialRank,
                              SyncQuery, TextContains, TextRank,
                              VectorRange, VectorRank)
from repro.core.types import (Column, ColumnType, IndexKind,  # noqa: F401
                              Schema)
from repro.obs import REGISTRY, SLOW_LOG
from repro.obs import analyze as obs_analyze

__all__ = [
    "Database", "Table", "QueryBuilder", "Subscription",
    "And", "Or", "Not", "Range", "GeoWithin", "TextContains", "VectorRange",
    "VectorRank", "SpatialRank", "TextRank", "HybridQuery",
    "Column", "ColumnType", "IndexKind", "Schema", "LSMConfig",
    "ShardRouter", "ShardedExecutor", "ShardedContinuousEngine",
]

DEFAULT_TABLE = "default"


class Subscription:
    """Stream handle for a registered continuous query (Type 3/4).

    ``poll(now)`` advances the table's virtual clock and returns this
    subscription's fresh result if it ran at that tick (else None);
    ``latest`` is the most recent result."""

    def __init__(self, table: "Table", rid: int, decl):
        self.table = table
        self.rid = rid
        self.decl = decl
        self.active = True

    @property
    def latest(self) -> Optional[List[ResultRow]]:
        reg = self.table._engine.registered.get(self.rid) \
            if self.table._engine else None
        return reg.last_result if reg else None

    def poll(self, now: float) -> Optional[List[ResultRow]]:
        return self.table.advance(now).get(self.rid)

    def cancel(self) -> None:
        if self.active and self.table._engine:
            self.table._engine.registered.pop(self.rid, None)
        self.active = False


class QueryBuilder:
    """Fluent builder for one hybrid query against a table.

    ``where`` calls AND-combine; pass ``Or``/``Not`` trees for anything
    richer.  Terminal methods: ``all()``, ``execute()``, ``explain()``,
    ``subscribe()``, ``build()``."""

    def __init__(self, table: "Table"):
        self._table = table
        self._where: Optional[q.BoolExpr] = None
        self._ranks: List[q.RankTerm] = []
        self._k = 10
        self._select: Optional[Sequence[str]] = None
        self._recall_target: Optional[float] = None

    # ------------------------------------------------------------ clauses
    def where(self, *exprs: q.BoolExpr) -> "QueryBuilder":
        for e in exprs:
            self._where = e if self._where is None else \
                q.And((self._where, e))
        return self

    def rank(self, *terms: q.RankTerm,
             recall_target: Optional[float] = None) -> "QueryBuilder":
        """Add rank terms.  ``recall_target`` (in (0, 1]) opts the query
        into approximate dispatch: the planner prices the candidate
        generators it has built for the rank column — the quantized ADC
        stream over PQ codes, or a beam search over the per-segment
        proximity graphs — against the exact scan, and exact-re-ranks
        whichever candidate set wins, so scores stay full-precision
        either way.  Leaving it unset (or 1.0) keeps the exact read
        path."""
        self._ranks.extend(terms)
        if recall_target is not None:
            self._recall_target = float(recall_target)
        return self

    def limit(self, k: int) -> "QueryBuilder":
        self._k = int(k)
        return self

    def select(self, *cols: str) -> "QueryBuilder":
        self._select = list(cols)
        return self

    # ---------------------------------------------------------- terminals
    def build(self) -> q.HybridQuery:
        return q.HybridQuery(where=self._where, ranks=list(self._ranks),
                             k=self._k, select=self._select,
                             recall_target=self._recall_target)

    def plan(self):
        """The table's plan for this query: a ``Plan`` on single-store
        tables, a ``ShardedPlan`` (fan-out + merge) on sharded ones."""
        return self._table.executor.plan(self.build())

    def explain(self, analyze: bool = False
                ) -> Union[str, obs_analyze.Analyzed]:
        """EXPLAIN text: plan summary + operator tree with cost
        estimates (``BitmapUnion`` with per-conjunct costs for OR;
        ``ShardFanout(n=N)`` with per-shard subtrees when sharded).

        ``analyze=True`` is EXPLAIN ANALYZE: the query executes under
        forced tracing and every operator node is annotated with actual
        time / rows / bytes plus estimated-vs-actual row drift.  Returns
        an ``Analyzed`` (prints as the annotated tree; carries the
        results, stats, and span tree)."""
        if analyze:
            return self._table.executor.explain_analyze(self.build())
        return self.plan().describe()

    def execute(self) -> Tuple[List[ResultRow], ExecStats]:
        return self._table.executor.execute(self.build())

    def all(self) -> List[ResultRow]:
        return self.execute()[0]

    def subscribe(self, interval_s: Optional[float] = None,
                  on_change: bool = False, name: str = "") -> Subscription:
        return self._table.subscribe(self.build(), interval_s=interval_s,
                                     on_change=on_change, name=name)


class Table:
    """One table: writes, queries, and continuous subscriptions, with the
    executor and continuous engine owned internally.  Backed by a single
    ``LSMStore`` by default; ``shards=N`` (N > 1) swaps in the sharded
    serving subsystem — a hash-partitioned ``ShardRouter`` store and the
    scatter-gather ``ShardedExecutor`` — behind the same surface."""

    def __init__(self, name: str, schema: Optional[Schema] = None,
                 cfg: Optional[LSMConfig] = None, *,
                 store: Optional[Union[LSMStore, ShardRouter]] = None,
                 shards: int = 1,
                 continuous_mode: str = "views",
                 view_budget_bytes: float = 64 * 2**20):
        if (schema is None) == (store is None):
            raise ValueError("pass exactly one of schema= or store=")
        if store is not None and shards > 1:
            raise ValueError("pass shards= only with schema= (adopted "
                             "stores keep their own partitioning)")
        self.name = name
        if store is not None:
            self.store = store
        elif shards > 1:
            self.store = ShardRouter(schema, cfg, n_shards=shards)
        else:
            self.store = LSMStore(schema, cfg)
        self.executor = ShardedExecutor(self.store) \
            if isinstance(self.store, ShardRouter) else Executor(self.store)
        self.continuous_mode = continuous_mode
        self.view_budget_bytes = view_budget_bytes
        self._engine: Optional[Union[ContinuousEngine,
                                     ShardedContinuousEngine]] = None

    # -------------------------------------------------------------- write
    def put(self, pks: Sequence[int], batch: Dict[str, Any]) -> None:
        """Ingest one columnar batch: dict of numpy arrays, forwarded to
        the store whole — the write path never materializes rows."""
        self.store.put(pks, batch)

    # ``insert`` is the SQL-flavored alias; both forward batches as-is
    insert = put

    def delete(self, pks: Sequence[int]) -> None:
        self.store.delete(pks)

    def flush(self) -> None:
        self.store.flush()

    def drain(self) -> None:
        """Deterministically finish queued flush/compaction work (only
        meaningful with ``LSMConfig(pipeline=True)``)."""
        self.store.drain()

    def close(self) -> None:
        """Stop background flush workers and seal the WAL (durable
        tables); idempotent, and a no-op beyond worker shutdown for
        process-resident tables."""
        self.store.close()

    # --------------------------------------------------------------- read
    def get(self, pk: int) -> Optional[Dict[str, Any]]:
        return self.store.get(pk)

    def query(self) -> QueryBuilder:
        return QueryBuilder(self)

    def execute(self, query: q.HybridQuery
                ) -> Tuple[List[ResultRow], ExecStats]:
        return self.executor.execute(query)

    def execute_many(self, queries: Sequence[Union[q.HybridQuery,
                                                   QueryBuilder]]
                     ) -> List[Tuple[List[ResultRow], ExecStats]]:
        built = [qq.build() if isinstance(qq, QueryBuilder) else qq
                 for qq in queries]
        return self.executor.execute_many(built)

    def explain(self, query: q.HybridQuery, analyze: bool = False
                ) -> Union[str, obs_analyze.Analyzed]:
        if analyze:
            return self.executor.explain_analyze(query)
        return self.executor.plan(query).describe()

    def metrics(self) -> Dict[str, Any]:
        """This table's engine-level metrics: the store's counter dict
        (per-shard dicts keyed by shard id when sharded), plus the
        sharded executor's and continuous engine's counters when they
        exist."""
        out: Dict[str, Any] = {"store": dict(self.store.metrics)}
        if isinstance(self.store, ShardRouter):
            out["shards"] = {i: dict(sh.metrics)
                             for i, sh in enumerate(self.store.shards)}
        if isinstance(self.executor, ShardedExecutor):
            out["executor"] = dict(self.executor.metrics)
        if self._engine is not None:
            out["continuous"] = dict(self._engine.metrics)
        return out

    # --------------------------------------------------------- continuous
    @property
    def engine(self) -> Union[ContinuousEngine, ShardedContinuousEngine]:
        if self._engine is None:
            if isinstance(self.store, ShardRouter):
                # per-shard deltas aggregate into one scheduling state;
                # due queries re-execute via scatter-gather (views do not
                # span shards yet)
                self._engine = ShardedContinuousEngine(
                    self.store, executor=self.executor)
            else:
                self._engine = ContinuousEngine(
                    self.store, mode=self.continuous_mode,
                    view_budget_bytes=self.view_budget_bytes)
        return self._engine

    @property
    def n_shards(self) -> int:
        return self.store.n_shards \
            if isinstance(self.store, ShardRouter) else 1

    def subscribe(self, query: q.HybridQuery,
                  interval_s: Optional[float] = None,
                  on_change: bool = False, name: str = "") -> Subscription:
        """Register a continuous query: ``interval_s`` => SYNC (Type 3),
        ``on_change=True`` => ASYNC (Type 4)."""
        if interval_s is not None and on_change:
            raise ValueError("pass interval_s= OR on_change=True, not both")
        if interval_s is not None:
            decl: Union[q.SyncQuery, q.AsyncQuery] = \
                q.SyncQuery(query, interval_s=float(interval_s), name=name)
        elif on_change:
            decl = q.AsyncQuery(query, name=name)
        else:
            raise ValueError("subscribe() needs interval_s= (SYNC) or "
                             "on_change=True (ASYNC)")
        rid = self.engine.register(decl)
        return Subscription(self, rid, decl)

    def advance(self, now: float) -> Dict[int, List[ResultRow]]:
        """Run everything due at virtual time ``now`` (no-op when nothing
        is subscribed)."""
        if self._engine is None:
            return {}
        return self._engine.advance(now)

    @property
    def n_rows(self) -> int:
        return self.store.n_rows

    @property
    def schema(self) -> Schema:
        return self.store.schema


class Database:
    """Session facade: tables + batched cross-query execution + the
    continuous virtual clock.  ``Database(schema)`` creates a default
    table; ``create_table`` adds named ones.  ``Database(schema,
    shards=N)`` makes the default table a hash-partitioned N-shard LSM
    with transparent scatter-gather execution (core/shards).

    ``Database(schema, path=dir)`` makes the database durable: every
    table gets its own store directory under ``dir/tables/<name>`` (WAL
    + segments + manifest, per shard), and the table catalog — schemas,
    shard counts, store configs — is published atomically to
    ``dir/db.json``.  Reopening is ``Database(path=dir)`` with no
    schema: the catalog rebuilds every table and each store replays its
    manifest + WAL.  ``close()`` (or the context-manager form) seals the
    WALs; ``snapshot(dir)``/``Database.restore(dir)`` round-trip a
    consistent on-disk copy."""

    def __init__(self, schema: Optional[Schema] = None,
                 cfg: Optional[LSMConfig] = None, *,
                 path: Optional[str] = None,
                 shards: int = 1,
                 continuous_mode: str = "views",
                 view_budget_bytes: float = 64 * 2**20):
        self.continuous_mode = continuous_mode
        self.view_budget_bytes = view_budget_bytes
        self.default_shards = int(shards)
        self.path = path
        self._closed = False
        self._tables: Dict[str, Table] = {}
        catalog = os.path.join(path, "db.json") if path else None
        if catalog and os.path.exists(catalog):
            if schema is not None:
                raise ValueError(
                    f"{path!r} already holds a database; reopen it with "
                    "Database(path=...) alone (no schema)")
            self._open_catalog(catalog)
        elif schema is not None:
            self.create_table(DEFAULT_TABLE, schema, cfg)
        elif path is not None:
            raise FileNotFoundError(
                f"no database at {path!r} (missing db.json); pass schema= "
                "to create one")

    # ------------------------------------------------------------- catalog
    def _table_cfg(self, name: str, cfg: Optional[LSMConfig]) -> \
            Optional[LSMConfig]:
        """Thread this database's directory into a table's store config:
        each table owns ``<path>/tables/<name>`` (shards subdivide it)."""
        if self.path is None:
            return cfg
        return dataclasses.replace(
            cfg or LSMConfig(),
            path=os.path.join(self.path, "tables", name))

    def _write_catalog(self) -> None:
        """Publish the table catalog atomically (write-temp, fsync,
        rename) — a crash between ``create_table`` calls leaves the
        previous catalog intact."""
        cat: Dict[str, Any] = {"version": 1, "tables": {}}
        for name, t in self._tables.items():
            if t.store.cfg.path is None:
                continue            # adopted in-memory store: not durable
            cfg_json = dataclasses.asdict(t.store.cfg)
            cfg_json.pop("path", None)   # derived from the db directory
            cat["tables"][name] = {
                "schema": manifest_lib.schema_to_json(t.schema),
                "shards": t.n_shards,
                "cfg": cfg_json,
            }
        manifest_lib.atomic_write_json(
            os.path.join(self.path, "db.json"), cat)

    def _open_catalog(self, catalog: str) -> None:
        with open(catalog, "r", encoding="utf-8") as f:
            cat = json.load(f)
        fields = {f.name for f in dataclasses.fields(LSMConfig)}
        for name, entry in cat["tables"].items():
            cfg = LSMConfig(**{k: v for k, v in entry["cfg"].items()
                               if k in fields})
            self._tables[name] = Table(
                name, manifest_lib.schema_from_json(entry["schema"]),
                self._table_cfg(name, cfg),
                shards=int(entry.get("shards", 1)),
                continuous_mode=self.continuous_mode,
                view_budget_bytes=self.view_budget_bytes)

    # -------------------------------------------------------------- tables
    def create_table(self, name: str, schema: Schema,
                     cfg: Optional[LSMConfig] = None,
                     shards: Optional[int] = None) -> Table:
        if name in self._tables:
            raise ValueError(f"table {name!r} already exists")
        self._tables[name] = Table(
            name, schema, self._table_cfg(name, cfg),
            shards=self.default_shards if shards is None else int(shards),
            continuous_mode=self.continuous_mode,
            view_budget_bytes=self.view_budget_bytes)
        if self.path is not None:
            self._write_catalog()
        return self._tables[name]

    def adopt_store(self, name: str,
                    store: Union[LSMStore, ShardRouter]) -> Table:
        """Wrap an already-built ``LSMStore`` (or ``ShardRouter``) —
        workload builders, benchmarks — as a table of this database."""
        if name in self._tables:
            raise ValueError(f"table {name!r} already exists")
        self._tables[name] = Table(
            name, store=store, continuous_mode=self.continuous_mode,
            view_budget_bytes=self.view_budget_bytes)
        return self._tables[name]

    def table(self, name: str = DEFAULT_TABLE) -> Table:
        if name not in self._tables:
            raise KeyError(f"no table {name!r}; create_table() first "
                           f"(have: {sorted(self._tables)})")
        return self._tables[name]

    @property
    def tables(self) -> Dict[str, Table]:
        return dict(self._tables)

    # ----------------------------------------------------------- execution
    def execute_many(self, queries: Sequence[Union[q.HybridQuery,
                                                   QueryBuilder]]
                     ) -> List[Tuple[List[ResultRow], ExecStats]]:
        """Execute a batch in one shared-scan pass per table.  Builders
        carry their table; bare ``HybridQuery`` objects run against the
        default table.  Results come back in input order."""
        resolved: List[Tuple[Table, q.HybridQuery]] = []
        for item in queries:
            if isinstance(item, QueryBuilder):
                resolved.append((item._table, item.build()))
            else:
                name = DEFAULT_TABLE if DEFAULT_TABLE in self._tables or \
                    len(self._tables) != 1 else next(iter(self._tables))
                resolved.append((self.table(name), item))
        by_table: Dict[str, List[int]] = {}
        for i, (t, _) in enumerate(resolved):
            by_table.setdefault(t.name, []).append(i)
        out: List = [None] * len(resolved)
        for name, idxs in by_table.items():
            t = resolved[idxs[0]][0]
            res = t.executor.execute_many([resolved[i][1] for i in idxs])
            for i, r in zip(idxs, res):
                out[i] = r
        return out

    # ----------------------------------------------------------- continuous
    def advance(self, now: float) -> Dict[str, Dict[int, List[ResultRow]]]:
        """Tick every table's continuous engine at virtual time ``now``."""
        return {name: t.advance(now) for name, t in self._tables.items()
                if t._engine is not None}

    # -------------------------------------------------------- observability
    def metrics(self) -> Dict[str, Any]:
        """Merged observability view: the process-wide registry snapshot
        (counters / gauges / histograms with p50-p99) under
        ``"registry"`` plus each table's engine-level dicts under
        ``"tables"`` (per-shard dicts keyed by shard id when
        sharded)."""
        return {"registry": REGISTRY.snapshot(),
                "tables": {name: t.metrics()
                           for name, t in self._tables.items()}}

    def metrics_text(self) -> str:
        """The registry in Prometheus text exposition format (histogram
        ``_bucket``/``_sum``/``_count`` series plus ``_p50``/``_p95``/
        ``_p99`` gauges) — paste into a scrape endpoint as-is."""
        return REGISTRY.prometheus_text()

    def slow_queries(self) -> List[Dict[str, Any]]:
        """Entries captured by the slow-query log (enable with
        ``obs.SLOW_LOG.configure(threshold_s)``), newest last."""
        return SLOW_LOG.snapshot()

    # ----------------------------------------------------------- durability
    def close(self) -> None:
        """Close every table: stop background flush workers, seal and
        fsync the WALs.  Idempotent; the database object stays readable
        for already-materialized state but accepts no more writes on
        durable tables (their WALs are closed)."""
        if self._closed:
            return
        self._closed = True
        for t in self._tables.values():
            t.close()

    def __enter__(self) -> "Database":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def snapshot(self, path: str) -> None:
        """Write a consistent copy of every table to ``path``: flush all
        pending rows, save segments + manifests per store, then publish
        the catalog.  The snapshot is itself a database directory —
        ``Database.restore(path)`` (or ``Database(path=path)``) opens
        it."""
        cat: Dict[str, Any] = {"version": 1, "tables": {}}
        for name, t in self._tables.items():
            t.store.snapshot(os.path.join(path, "tables", name))
            cfg_json = dataclasses.asdict(t.store.cfg)
            cfg_json.pop("path", None)
            cat["tables"][name] = {
                "schema": manifest_lib.schema_to_json(t.schema),
                "shards": t.n_shards,
                "cfg": cfg_json,
            }
        manifest_lib.atomic_write_json(os.path.join(path, "db.json"), cat)

    @classmethod
    def restore(cls, path: str, **kwargs: Any) -> "Database":
        """Open the database at ``path`` (a live directory or a
        ``snapshot()`` output): rebuild every table from the catalog,
        load manifests, replay WALs.  The restored database continues
        journaling into the same directory."""
        if not os.path.exists(os.path.join(path, "db.json")):
            raise FileNotFoundError(f"no database catalog at {path!r}")
        return cls(path=path, **kwargs)
