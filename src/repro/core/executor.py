"""Physical plan execution over the LSM store (paper §5).

Shared machinery: per-segment predicate bitmaps (index-backed when
available, kernel fallback otherwise), exact rank-distance evaluation,
newest-version visibility resolution, and memtable overlay (the memtable
is always scanned brute-force — it is small and RAM-resident).
Counters (blocks_read, rows_scanned) validate the cost model in
benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import query as q
from repro.core.index.text import tokenize
from repro.core.optimizer import planner as planner_lib
from repro.core.optimizer.stats import Catalog
from repro.core.types import BLOCK_ROWS, ColumnType
from repro.kernels import ops as kops


@dataclasses.dataclass
class ExecStats:
    blocks_read: float = 0.0
    rows_scanned: int = 0
    plan: str = ""


@dataclasses.dataclass
class ResultRow:
    pk: int
    score: float
    values: Dict[str, Any]


# ---------------------------------------------------------------------------
# predicate evaluation
# ---------------------------------------------------------------------------

def eval_predicate_seg(seg, pred, stats: ExecStats,
                       use_index: bool = True) -> np.ndarray:
    """Bool mask over segment rows for one predicate."""
    idx = seg.indexes.get(getattr(pred, "col", None)) if use_index else None
    if idx is not None:
        try:
            mask = idx.bitmap(seg, pred)
            stats.blocks_read += idx.probe_cost_blocks(seg, pred)
            return mask
        except NotImplementedError:
            pass
    # kernel fallback (full column scan)
    stats.blocks_read += seg.n_blocks
    if isinstance(pred, q.Range):
        col = np.asarray(seg.columns[pred.col], np.float32)[:, None]
        return kops.range_bitmap(col, np.asarray([[pred.lo, pred.hi]]))
    if isinstance(pred, q.GeoWithin):
        return kops.rect_filter(np.asarray(seg.columns[pred.col],
                                           np.float32), pred.rect)
    if isinstance(pred, q.TextContains):
        term = pred.term.lower()
        return np.asarray([term in tokenize(t)
                           for t in seg.columns[pred.col]], bool)
    if isinstance(pred, q.VectorRange):
        d = np.sqrt(np.maximum(kops.l2_distances(
            pred.q[None, :], np.asarray(seg.columns[pred.col],
                                        np.float32))[0], 0))
        return d < pred.thresh
    raise TypeError(f"unknown predicate {pred!r}")


def eval_predicate_rows(row_values: Dict[str, np.ndarray], pred) -> np.ndarray:
    """Predicate over materialized rows (memtable / residual eval)."""
    if isinstance(pred, q.Range):
        v = np.asarray(row_values[pred.col], np.float64)
        return (v >= pred.lo) & (v <= pred.hi)
    if isinstance(pred, q.GeoWithin):
        return kops.rect_filter(np.asarray(row_values[pred.col],
                                           np.float32), pred.rect)
    if isinstance(pred, q.TextContains):
        term = pred.term.lower()
        return np.asarray([term in tokenize(t)
                           for t in row_values[pred.col]], bool)
    if isinstance(pred, q.VectorRange):
        vecs = np.asarray(row_values[pred.col], np.float32)
        if len(vecs) == 0:
            return np.zeros((0,), bool)
        d = np.sqrt(np.maximum(
            kops.l2_distances(pred.q[None, :], vecs)[0], 0))
        return d < pred.thresh
    raise TypeError(f"unknown predicate {pred!r}")


# ---------------------------------------------------------------------------
# rank-distance evaluation (exact)
# ---------------------------------------------------------------------------

def rank_distances(values: Dict[str, np.ndarray], rank, seg=None,
                   rows: Optional[np.ndarray] = None) -> np.ndarray:
    if isinstance(rank, q.VectorRank):
        vecs = np.asarray(values[rank.col], np.float32)
        if len(vecs) == 0:
            return np.zeros((0,), np.float32)
        return np.sqrt(np.maximum(
            kops.l2_distances(rank.q[None, :], vecs)[0], 0))
    if isinstance(rank, q.SpatialRank):
        pts = np.asarray(values[rank.col], np.float32)
        p = np.asarray(rank.point, np.float32)
        if len(pts) == 0:
            return np.zeros((0,), np.float32)
        return np.sqrt(((pts - p) ** 2).sum(axis=1))
    if isinstance(rank, q.TextRank):
        out = np.empty(len(values[rank.col]), np.float32)
        qterms = [t.lower() for t in rank.terms]
        for i, text in enumerate(values[rank.col]):
            toks = tokenize(text)
            score = sum(toks.count(t) for t in qterms) / (len(toks) + 1.0)
            out[i] = 1.0 / (1.0 + score * 10.0)
        return out
    raise TypeError(f"unknown rank {rank!r}")


def combined_scores(values: Dict[str, np.ndarray], ranks) -> np.ndarray:
    n = len(next(iter(values.values()))) if values else 0
    total = np.zeros(n, np.float32)
    for r in ranks:
        total += r.weight * rank_distances(values, r)
    return total


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------

class Executor:
    def __init__(self, store):
        self.store = store
        self.catalog = Catalog(store)

    # ------------------------------------------------------------- public
    def execute(self, query: q.HybridQuery,
                plan: Optional[planner_lib.Plan] = None
                ) -> Tuple[List[ResultRow], ExecStats]:
        plan = plan or planner_lib.plan(self.catalog, query)
        stats = ExecStats(plan=plan.describe())
        if plan.kind in ("full_scan", "index_intersect"):
            rows = self._exec_filter(query, plan, stats)
            return rows, stats
        return self._exec_nn(query, plan, stats), stats

    # ----------------------------------------------------- filter queries
    def _segment_mask(self, seg, indexed, residual, stats) -> np.ndarray:
        mask = np.ones(seg.n_rows, bool)
        for pred in indexed:
            mask &= eval_predicate_seg(seg, pred, stats, use_index=True)
            if not mask.any():
                return mask
        if residual.__len__() and mask.any():
            rows = np.nonzero(mask)[0]
            vals = {c: seg.columns[c][rows] for c in seg.columns}
            stats.rows_scanned += len(rows)
            keep = np.ones(len(rows), bool)
            for pred in residual:
                keep &= eval_predicate_rows(vals, pred)
            mask = np.zeros(seg.n_rows, bool)
            mask[rows[keep]] = True
        return mask

    def _exec_filter(self, query, plan, stats) -> List[ResultRow]:
        per_seg: Dict[int, np.ndarray] = {}
        all_preds = plan.indexed + plan.residual
        for seg in self._pruned_segments(plan.indexed or plan.residual):
            mask = self._segment_mask(seg, plan.indexed, plan.residual,
                                      stats)
            rows = np.nonzero(mask)[0]
            if len(rows):
                per_seg[seg.seg_id] = rows
        visible = per_seg if self.store.unique_pks else \
            self.store.resolve_visible(per_seg)
        out: List[ResultRow] = []
        seg_by_id = {s.seg_id: s for s in self.store.segments}
        for sid, rows in visible.items():
            seg = seg_by_id[sid]
            for i in rows:
                out.append(self._row_result(seg, int(i), query, 0.0))
        out.extend(self._memtable_filter(query, all_preds))
        return out

    def _memtable_filter(self, query, preds) -> List[ResultRow]:
        mt = self.store.memtable
        if not len(mt):
            return []
        pk, seqno, tomb, cols = mt.scan_arrays()
        # newest version per pk, non-tombstone
        keep = self._memtable_visible(pk, tomb)
        mask = keep.copy()
        for pred in preds:
            sub = eval_predicate_rows(cols, pred)
            mask &= sub
        out = []
        for i in np.nonzero(mask)[0]:
            values = {c: cols[c][i] for c in cols}
            out.append(ResultRow(pk=int(pk[i]), score=0.0, values=values))
        return out

    @staticmethod
    def _memtable_visible(pk, tomb) -> np.ndarray:
        latest: Dict[int, int] = {}
        for i, key in enumerate(pk):
            latest[int(key)] = i
        keep = np.zeros(len(pk), bool)
        for key, i in latest.items():
            if not tomb[i]:
                keep[i] = True
        return keep

    def _pruned_segments(self, preds):
        segs = self.store.segments
        for p in preds:
            segs = self.store.global_index.prune(segs, p)
        return segs

    # --------------------------------------------------------- NN queries
    def _exec_nn(self, query, plan, stats) -> List[ResultRow]:
        if plan.kind == "nra":
            from repro.core.nra import nra_topk
            return nra_topk(self.store, self.catalog, query, stats)
        if plan.kind == "postfilter_nn":
            return self._postfilter_nn(query, plan, stats)
        # prefilter / full-scan: filter then exact-rank survivors
        return self._prefilter_nn(query, plan, stats)

    def _prefilter_nn(self, query, plan, stats) -> List[ResultRow]:
        cand: List[Tuple[float, Any, Any]] = []
        for seg in self.store.segments:
            if plan.indexed or plan.residual:
                mask = self._segment_mask(seg, plan.indexed, plan.residual,
                                          stats)
                rows = np.nonzero(mask)[0]
            else:
                rows = np.arange(seg.n_rows)
                stats.blocks_read += seg.n_blocks * len(query.ranks)
            if not len(rows):
                continue
            vals = {c: seg.columns[c][rows] for c in seg.columns}
            stats.rows_scanned += len(rows)
            scores = combined_scores(vals, query.ranks)
            for s, i in zip(scores, rows):
                cand.append((float(s), seg.seg_id, int(i)))
        return self._finish_nn(query, cand, stats)

    def _postfilter_nn(self, query, plan, stats) -> List[ResultRow]:
        rank = query.ranks[0]
        k = query.k
        inflate = 4
        seen_enough = False
        best: List[Tuple[float, Any, Any]] = []
        while not seen_enough:
            best = []
            for seg in self.store.segments:
                idx = seg.indexes.get(rank.col)
                if idx is None:
                    continue
                d, rows, br = idx.search(
                    np.asarray(rank.q, np.float32), k * inflate)
                stats.blocks_read += br
                if not len(rows):
                    continue
                vals = {c: seg.columns[c][rows] for c in seg.columns}
                keep = np.ones(len(rows), bool)
                for pred in query.filters:
                    keep &= eval_predicate_rows(vals, pred)
                stats.rows_scanned += len(rows)
                for dd, rr in zip(d[keep], rows[keep]):
                    best.append((float(dd) * rank.weight, seg.seg_id,
                                 int(rr)))
            seen_enough = len(best) >= k or inflate >= 64
            inflate *= 4
        return self._finish_nn(query, best, stats)

    def _finish_nn(self, query, cand, stats) -> List[ResultRow]:
        """Visibility-resolve, merge memtable, return top-k."""
        per_seg: Dict[int, List[int]] = {}
        score_of: Dict[Tuple[int, int], float] = {}
        for s, sid, i in cand:
            per_seg.setdefault(sid, []).append(i)
            score_of[(sid, i)] = s
        per_seg_arr = {sid: np.asarray(rows)
                       for sid, rows in per_seg.items()}
        visible = per_seg_arr if self.store.unique_pks else \
            self.store.resolve_visible(per_seg_arr)
        seg_by_id = {s.seg_id: s for s in self.store.segments}
        pool: List[ResultRow] = []
        for sid, rows in visible.items():
            seg = seg_by_id[sid]
            for i in rows:
                pool.append(self._row_result(seg, int(i), query,
                                             score_of[(sid, int(i))]))
        # memtable overlay: exact scores, filters applied
        mt = self.store.memtable
        if len(mt):
            pk, seqno, tomb, cols = mt.scan_arrays()
            keep = self._memtable_visible(pk, tomb)
            for pred in query.filters:
                keep &= eval_predicate_rows(cols, pred)
            rows = np.nonzero(keep)[0]
            if len(rows):
                vals = {c: cols[c][rows] for c in cols}
                scores = combined_scores(vals, query.ranks)
                for s, i in zip(scores, rows):
                    pool.append(ResultRow(
                        pk=int(pk[i]), score=float(s),
                        values={c: cols[c][i] for c in cols}))
        pool.sort(key=lambda r: (r.score, r.pk))
        return pool[:query.k]

    # -------------------------------------------------------------- utils
    def _row_result(self, seg, i: int, query, score: float) -> ResultRow:
        cols = query.select or [c.name for c in self.store.schema.columns]
        values = {c: seg.columns[c][i] for c in cols}
        return ResultRow(pk=int(seg.pk[i]), score=score, values=values)
