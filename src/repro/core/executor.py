"""Physical plan execution over the LSM store (paper §5).

The executor is a thin driver over the composable operator pipeline in
``core.operators``: plans become operator trees, operators pass columnar
batches, and visibility is resolved by the shared lexsort winner set in
``core.visibility``.

``execute_many`` is the primary entry point: a batch of concurrent
queries shares per-segment scans (each predicate bitmap computed once per
batch) and stacks its query vectors into single batched
``l2_distances(Q, X)`` kernel calls.  ``execute`` is the batch-of-one
special case.  Counters (blocks_read, rows_scanned) validate the cost
model in benchmarks.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import operators as ops
from repro.core import query as q
from repro.core.operators import (Candidates, ExecStats,  # noqa: F401
                                  PipelineContext, ResultRow,
                                  combined_scores, eval_expr_rows,
                                  eval_predicate_rows, eval_predicate_seg,
                                  rank_distances)
from repro.core.optimizer import planner as planner_lib
from repro.core.optimizer.stats import Catalog
from repro.kernels import ops as kops
from repro.obs import REGISTRY, SLOW_LOG
from repro.obs import analyze as obs_analyze
from repro.obs import trace as obs_trace


def _charge_kernel_stats(stats_list, before) -> None:
    """Attribute the kernel-dispatch delta since ``before`` (a
    ``kops.stats_snapshot()``) to every query in the executed unit —
    the same full-delta sharing policy blocks_read uses for cached
    bitmaps, so per-query stats stay comparable across batch sizes."""
    launches, byts, misses = kops.stats_snapshot()
    for st in stats_list:
        st.kernel_launches += launches - before[0]
        st.bytes_to_host += byts - before[1]
        st.jit_shape_misses += misses - before[2]


# a group of this many structurally-identical exact NN queries is executed
# as one shared segment sweep even when the per-query optimum is NRA
MIN_SHARED_SCAN_BATCH = 4


class Executor:
    def __init__(self, store):
        self.store = store
        self.catalog = Catalog(store)

    # ------------------------------------------------------------- public
    def plan(self, query: q.HybridQuery) -> planner_lib.Plan:
        """Plan one query against this executor's catalog (the facade's
        EXPLAIN entry point; ShardedExecutor overrides it with the
        fan-out plan)."""
        return planner_lib.plan(self.catalog, query)

    def execute(self, query: q.HybridQuery,
                plan: Optional[planner_lib.Plan] = None
                ) -> Tuple[List[ResultRow], ExecStats]:
        return self.execute_many([query], plans=[plan])[0]

    def explain_analyze(self, query: q.HybridQuery,
                        plan: Optional[planner_lib.Plan] = None
                        ) -> obs_analyze.Analyzed:
        """EXPLAIN ANALYZE: execute the query under forced tracing and
        annotate the plan's operator tree with actual rows / bytes /
        time per node plus estimated-vs-actual row drift.  Results are
        bitwise-identical to a plain ``execute`` — tracing observes the
        pipeline, it never changes dispatch or arithmetic."""
        plan = plan if plan is not None else self.plan(query)
        with obs_trace.force_tracing():
            with obs_trace.span("analyze") as root:
                ((results, stats),) = self.execute_many([query],
                                                        plans=[plan])
        actuals = obs_analyze.actuals_from(root)
        head = plan.describe().splitlines()[0]
        # fresh tree against the live catalog (never the plan's cached
        # root, which may have been built without cost estimates)
        tree = ops.build_tree(plan, self.catalog)
        text = head + " (analyzed)\n" + tree.explain(
            1, annotate=obs_analyze.make_annotator(actuals))
        return obs_analyze.Analyzed(text=text, results=results,
                                    stats=stats, span=root,
                                    actuals=actuals)

    def _observe_query(self, n_queries: int, elapsed_s: float,
                       out, sp) -> None:
        """Facade-level telemetry for one ``execute_many`` call: the
        query-latency histogram, throughput counters, and the slow-query
        log (plan + span tree when tracing was on)."""
        REGISTRY.observe("query.latency_s", elapsed_s)
        REGISTRY.inc("query.count", n_queries)
        kops.flush_registry_counters()
        if SLOW_LOG.threshold_s is not None and out:
            SLOW_LOG.maybe_record(
                elapsed_s, out[0][1].plan,
                span=sp if getattr(sp, "live", False) else None,
                n_queries=n_queries)

    def execute_many(self, queries: List[q.HybridQuery],
                     plans: Optional[List[Optional[planner_lib.Plan]]] = None
                     ) -> List[Tuple[List[ResultRow], ExecStats]]:
        t0 = time.perf_counter()
        with obs_trace.span("query", n=len(queries)) as sp:
            out = self._execute_many(queries, plans)
        self._observe_query(len(queries), time.perf_counter() - t0,
                            out, sp)
        return out

    def _execute_many(self, queries: List[q.HybridQuery],
                      plans: Optional[
                          List[Optional[planner_lib.Plan]]] = None
                      ) -> List[Tuple[List[ResultRow], ExecStats]]:
        """Execute a batch of queries with shared per-segment scans.

        Queries whose plans are scan-based (full_scan, index_intersect,
        full_scan_nn, prefilter_nn, and the DNF union / union_nn kinds)
        and — for NN queries — share a rank signature are grouped into one
        pipeline pass; the rest (nra, postfilter_nn) run individually but
        still share the batch-level predicate-bitmap cache.
        """
        given = list(plans) if plans is not None else [None] * len(queries)

        # subclasses customizing dispatch (the benchmark baseline
        # strategies) measure THEIR design point: run them query by query,
        # with no cross-query sharing.  An execute() override owns its own
        # planning, so it gets only the caller-given plan (and must not
        # delegate back to execute_many).
        if type(self).execute is not Executor.execute:
            return [self.execute(qq, p) for qq, p in zip(queries, given)]

        plans = [p or planner_lib.plan(self.catalog, qq)
                 for p, qq in zip(given, queries)]

        if (type(self)._exec_nn is not Executor._exec_nn
                or type(self)._exec_filter is not Executor._exec_filter):
            out = []
            for qq, plan in zip(queries, plans):
                st = ExecStats(plan=plan.describe())
                before = kops.stats_snapshot()
                res = self._exec_nn(qq, plan, st) if qq.is_nn \
                    else self._exec_filter(qq, plan, st)
                _charge_kernel_stats([st], before)
                out.append((res, st))
            return out

        results: List[Optional[List[ResultRow]]] = [None] * len(queries)

        groups: Dict[tuple, List[int]] = {}
        solo: List[int] = []
        empty: List[int] = []
        for i, (qq, plan) in enumerate(zip(queries, plans)):
            if plan.kind == "empty":
                empty.append(i)
            elif plan.kind in ("full_scan", "index_intersect",
                               "full_scan_nn", "prefilter_nn",
                               "union", "union_nn"):
                # a group must share rank structure (NN members stack
                # their query vectors into one kernel call) AND dispatch
                # mode (fused vs staged take different operators)
                key = ("nn", ops.rank_signature(qq.ranks), plan.fused,
                       getattr(plan, "quantized", False),
                       getattr(plan, "graph", False)) \
                    if qq.ranks else ("filter",)
                groups.setdefault(key, []).append(i)
            elif plan.kind == "nra" and given[i] is None:
                # planner-chosen NRA may be re-planned batch-aware below
                groups.setdefault(
                    ("nra", ops.rank_signature(qq.ranks)), []).append(i)
            else:
                solo.append(i)

        # batch-aware re-planning: enough structurally-identical exact NN
        # queries make one shared scan cheaper than N sorted-access walks
        for key in [k for k in groups if k[0] == "nra"]:
            idxs = groups.pop(key)
            if len(idxs) >= MIN_SHARED_SCAN_BATCH:
                for i in idxs:
                    plans[i] = planner_lib.plan_shared_scan(
                        self.catalog, queries[i])
                    groups.setdefault(
                        ("nn", key[1], plans[i].fused,
                         getattr(plans[i], "quantized", False),
                         getattr(plans[i], "graph", False)),
                        []).append(i)
            else:
                solo.extend(idxs)

        stats = [ExecStats(plan=p.describe()) for p in plans]
        pred_cache: Dict = {}
        for i in empty:
            results[i] = []
        for i in solo:
            before = kops.stats_snapshot()
            results[i] = self._exec_nn(queries[i], plans[i], stats[i],
                                       pred_cache)
            _charge_kernel_stats([stats[i]], before)
        for idxs in groups.values():
            before = kops.stats_snapshot()
            group_res = ops.run_scan_group(
                self.store, self.catalog,
                [queries[i] for i in idxs], [plans[i] for i in idxs],
                [stats[i] for i in idxs], pred_cache)
            _charge_kernel_stats([stats[i] for i in idxs], before)
            for i, res in zip(idxs, group_res):
                results[i] = res
        return list(zip(results, stats))

    # ----------------------------------------------------- plan dispatch
    def _exec_filter(self, query, plan, stats,
                     pred_cache: Optional[Dict] = None) -> List[ResultRow]:
        if plan.kind == "empty":
            return []
        return ops.run_scan_group(self.store, self.catalog, [query], [plan],
                                  [stats], pred_cache)[0]

    def _exec_nn(self, query, plan, stats,
                 pred_cache: Optional[Dict] = None) -> List[ResultRow]:
        if plan.kind == "empty":
            return []
        if plan.kind in ("full_scan", "index_intersect", "union"):
            return self._exec_filter(query, plan, stats, pred_cache)
        if plan.kind == "nra":
            from repro.core.nra import nra_topk
            with obs_trace.span("operator:NRAMerge"):
                return nra_topk(self.store, self.catalog, query, stats)
        if plan.kind == "postfilter_nn":
            return self._postfilter_nn(query, plan, stats, pred_cache)
        # prefilter / full-scan: filter then exact-rank survivors
        return self._prefilter_nn(query, plan, stats, pred_cache)

    def _prefilter_nn(self, query, plan, stats,
                      pred_cache: Optional[Dict] = None) -> List[ResultRow]:
        return ops.run_scan_group(self.store, self.catalog, [query], [plan],
                                  [stats], pred_cache)[0]

    def _postfilter_nn(self, query, plan, stats,
                       pred_cache: Optional[Dict] = None) -> List[ResultRow]:
        """Vector-index top-k probe, filters applied after; the probe depth
        inflates until k survivors remain (or the probe saturates)."""
        rank = query.ranks[0]
        k = query.k
        inflate = 4
        cand = Candidates.empty()
        with obs_trace.span("operator:IndexProbe", probe=rank.col) as sp:
            while True:
                parts: List[Candidates] = []
                n_survivors = 0
                for seg in self.store.segments:
                    idx = seg.indexes.get(rank.col)
                    if idx is None:
                        continue
                    d, rows, br = idx.search(
                        np.asarray(rank.q, np.float32), k * inflate)
                    stats.blocks_read += br
                    if sp.live:
                        sp.add("blocks", br)
                    if not len(rows):
                        continue
                    vals = {c: seg.columns[c][rows] for c in seg.columns}
                    keep = eval_expr_rows(vals, query.where)
                    stats.rows_scanned += len(rows)
                    if sp.live:
                        sp.add("rows", len(rows))
                    n_survivors += int(keep.sum())
                    parts.append(Candidates(
                        np.full(int(keep.sum()), seg.seg_id, np.int64),
                        rows[keep].astype(np.int64),
                        (d[keep] * rank.weight).astype(np.float32)))
                cand = Candidates.concat(parts)
                if n_survivors >= k or inflate >= 64:
                    break
                inflate *= 4
            if sp.live:
                sp.set(out_rows=len(cand.scores))
        ctx = PipelineContext(self.store, self.catalog, [query], [plan],
                              [stats], pred_cache)
        return ops.finish_candidates(ctx, [cand])[0]
