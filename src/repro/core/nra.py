"""Hybrid NN query processing — paper Algorithm 1 (NRA-style aggregation).

Every rank modality exposes the unified sorted ``Next()`` interface
(paper §5: "a standardized Next() interface for all supported
modalities"); per-segment streams are heap-merged into one global stream
per modality. Bounds per object o:

  LB(o) = Σ_seen λ_j d_j(o) + Σ_unseen λ_j bottom_j      (true score >= LB)
  UB(o) = Σ_seen λ_j d_j(o) + Σ_unseen λ_j D_max_j       (true score <= UB)

where bottom_j is the largest distance modality j has yielded so far and
D_max_j a finite domain bound from the catalog. Stop when the k-th
smallest UB among buffered objects is <= the LB of every other object and
of any completely-unseen object (Σ λ_j bottom_j).

TPU adaptation (DESIGN.md §8.1): streams yield *blocks*; bound updates are
vectorized over each block; the stop test runs once per round. Yielded
distances only grow, so block granularity preserves bound correctness.

Final scores are refined by random access (exact distances for the winner
set) — a TA-style refinement the storage layout makes cheap, giving exact
scores for the returned k (the paper returns "sorted by LB").
"""
from __future__ import annotations

import contextlib
from typing import Dict, List, Optional

import numpy as np

from repro.core import query as q
from repro.core import visibility as vis_lib
from repro.core.executor import ExecStats  # noqa: F401 (type only)
from repro.core.index.base import MergedSortedAccess


def _per_segment_lookup(masks: Dict[int, np.ndarray], sids: np.ndarray,
                        rows: np.ndarray) -> np.ndarray:
    """Vectorized masks[sid][row] gather, grouped by segment."""
    keep = np.empty(len(sids), bool)
    for sid in np.unique(sids):
        sel = sids == sid
        keep[sel] = masks[int(sid)][rows[sel]]
    return keep


def _modality_stream(segments, rank, stats) -> Optional[MergedSortedAccess]:
    streams = []
    for seg in segments:
        idx = seg.indexes.get(rank.col)
        if idx is None or seg.n_rows == 0:
            return None
        if isinstance(rank, q.VectorRank):
            it = idx.iterator(seg, rank.q)
        elif isinstance(rank, q.SpatialRank):
            it = idx.iterator(seg, np.asarray(rank.point, np.float32))
        elif isinstance(rank, q.TextRank):
            it = idx.iterator(seg, list(rank.terms))
        else:
            return None
        streams.append((seg.seg_id, it))

    def key_fn(sid, rows):
        return np.stack([np.full_like(rows, sid), rows], axis=1)

    return MergedSortedAccess(streams, key_fn=key_fn)


def nra_topk(store, catalog, query: q.HybridQuery, stats) -> List:
    from repro.core import executor as ex

    ranks = list(query.ranks)
    ell = len(ranks)
    weights = np.asarray([r.weight for r in ranks], np.float32)
    dmax = np.asarray([catalog.dist_bound(r) for r in ranks], np.float32)
    k = query.k
    # snapshot under the store lock: the whole NRA walk (sorted-access
    # streams, filter bitmaps, refinement) runs against one segment list
    # even while a background flush republishes mid-walk
    lock = getattr(store, "_lock", None)
    with lock if lock is not None else contextlib.nullcontext():
        segments = list(store.segments)
        vis = None if store.unique_pks else vis_lib.visibility_index(store)
    seg_by_id = {s.seg_id: s for s in segments}

    streams = [_modality_stream(segments, r, stats) for r in ranks]
    if any(s is None for s in streams):
        # missing index: planner should not have chosen NRA; full-scan
        from repro.core.optimizer import planner as pl
        plan = pl.Plan(kind="full_scan_nn", residual=query.filters,
                       ranks=ranks, k=k)
        return ex.Executor(store)._prefilter_nn(query, plan, stats)

    # filter bitmaps per segment (pre-computed once)
    masks: Dict[int, np.ndarray] = {}
    if query.filters:
        dummy = ex.ExecStats()
        for seg in segments:
            m = np.ones(seg.n_rows, bool)
            for pred in query.filters:
                m &= ex.eval_predicate_seg(seg, pred, dummy)
            masks[seg.seg_id] = m
        stats.blocks_read += dummy.blocks_read

    # --- growable candidate table (block-vectorized bookkeeping) --------
    # encoded key = sid << 32 | row; keymap: enc -> table row
    keymap: Dict[int, int] = {}
    cap = 1024
    dmat = np.full((cap, ell), np.nan, np.float32)
    enc_arr = np.zeros(cap, np.int64)
    n_seen = 0
    bottoms = np.zeros(ell, np.float32)
    exhausted = np.zeros(ell, bool)

    ROUND_ROWS = 256   # drain this many rows per modality per round:
    #                    the merged stream certifies small prefixes, so
    #                    multiple pulls amortize the per-round bound check

    while True:
        progressed = False
        for j, st in enumerate(streams):
            if exhausted[j]:
                continue
            parts_d, parts_k, got = [], [], 0
            while got < ROUND_ROWS:
                blk = st.next_block()
                if blk is None:
                    exhausted[j] = True
                    bottoms[j] = dmax[j]
                    break
                parts_d.append(blk[0])
                parts_k.append(blk[1])
                got += len(blk[0])
            if not parts_d:
                continue
            dists = np.concatenate(parts_d)
            keys = np.concatenate(parts_k)
            progressed = True
            bottoms[j] = max(bottoms[j], float(dists[-1]))
            sids = keys[:, 0].astype(np.int64)
            rows = keys[:, 1].astype(np.int64)
            if query.filters:
                keep = _per_segment_lookup(masks, sids, rows)
                sids, rows, dists = sids[keep], rows[keep], dists[keep]
            if vis is not None and len(sids):
                keep = vis.visible_mask(sids, rows)
                sids, rows, dists = sids[keep], rows[keep], dists[keep]
            if not len(sids):
                continue
            encs = (sids << 32) | rows
            idxs = np.empty(len(encs), np.int64)
            for t, e in enumerate(encs.tolist()):     # one dict op per row
                i = keymap.get(e)
                if i is None:
                    i = n_seen
                    keymap[e] = i
                    n_seen += 1
                    if n_seen > cap:
                        cap *= 2
                        dmat = np.concatenate(
                            [dmat, np.full((cap - len(dmat), ell), np.nan,
                                           np.float32)])
                        enc_arr = np.concatenate(
                            [enc_arr, np.zeros(cap - len(enc_arr),
                                               np.int64)])
                    enc_arr[i] = e
                idxs[t] = i
            cur = dmat[idxs, j]
            dmat[idxs, j] = np.where(np.isnan(cur), dists,
                                     np.minimum(cur, dists))
        if n_seen == 0:
            if not progressed:
                return []
            continue

        # vectorized bound check once per round over the live table
        live = dmat[:n_seen]
        mask = ~np.isnan(live)
        lbs = np.sum(np.where(mask, weights * live, weights * bottoms),
                     axis=1)
        ubs = np.sum(np.where(mask, weights * live, weights * dmax), axis=1)
        if n_seen >= k:
            top_idx = np.argpartition(ubs, k - 1)[:k]
            kth_ub = float(np.max(ubs[top_idx]))
            others_lb = np.inf
            if n_seen > k:
                rest_mask = np.ones(n_seen, bool)
                rest_mask[top_idx] = False
                others_lb = float(np.min(lbs[rest_mask]))
            unseen_lb = float(np.sum(weights * bottoms))
            if kth_ub <= others_lb and kth_ub <= unseen_lb:
                winners = [(int(enc_arr[i]) >> 32,
                            int(enc_arr[i]) & 0xFFFFFFFF) for i in top_idx]
                break
        if not progressed:
            # everything exhausted: all candidates fully seen — rank by
            # (score, key) so equal scores break deterministically
            order = np.lexsort((enc_arr[:n_seen], ubs))[:k]
            winners = [(int(enc_arr[i]) >> 32,
                        int(enc_arr[i]) & 0xFFFFFFFF) for i in order]
            break

    # --- random-access refinement: exact scores for the winner set, then
    # the shared finishing pipeline (visibility + memtable overlay + topk)
    from repro.core import operators as ops_lib

    parts = []
    for sid, row in winners:
        seg = seg_by_id[sid]
        vals = {r.col: seg.columns[r.col][np.asarray([row])] for r in ranks}
        score = float(ex.combined_scores(vals, ranks)[0])
        stats.rows_scanned += 1
        parts.append(ops_lib.Candidates(
            np.asarray([sid], np.int64), np.asarray([row], np.int64),
            np.asarray([score], np.float32)))
    cand = ops_lib.Candidates.concat(parts)
    from repro.core.optimizer import planner as pl
    plan = pl.Plan(kind="nra", residual=query.filters, ranks=ranks, k=k)
    ctx = ops_lib.PipelineContext(store, catalog, [query], [plan], [stats])
    return ops_lib.finish_candidates(ctx, [cand])[0]
