"""Distributed query execution: shard_map scatter-gather over the mesh.

ARCADE's data plane at scale: segments are partitioned across the
``data`` mesh axis (each data-parallel group owns a disjoint shard of the
LSM keyspace); a vector query fans out, every shard answers a local
top-k from its own posting blocks (ivf_scan semantics), and the global
top-k is combined with an all-gather + merge — the TPU-native analog of
the paper's per-SST iterators + top-level merging iterator, one level up.

``distributed_topk`` is pure jnp and jit/shard_map-lowered, so the same
code path is exercised by tests on 1 device and by the dry-run on the
16x16 / 2x16x16 production meshes (launch/dryrun_arcade.py).

The ENGINE-integrated form of this idea — hash-partitioned LSM shards
behind the planner, visibility and fused-kernel pipeline, with the
device-side cross-shard merge — lives in ``core/shards``; this module
remains the mesh-level shard_map demo the dry-run drives.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P


def local_topk(q: jnp.ndarray, vecs: jnp.ndarray, k: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact local top-k: q (d,), vecs (n, d) -> (k dists, k indices).
    Distances are squared L2 (monotone for merging; sqrt at the edge).

    ``k`` may exceed the shard's row count (small shards must not break
    the scatter-gather path): ``lax.top_k`` runs at the clamped size and
    the result is padded to k with (+inf, -1) sentinel slots, which the
    global merge orders last and callers filter with ``idx >= 0``."""
    qf = q.astype(jnp.float32)
    vf = vecs.astype(jnp.float32)
    d = (jnp.sum(qf * qf) - 2.0 * (vf @ qf)
         + jnp.sum(vf * vf, axis=-1))
    n = vf.shape[0]
    kk = min(k, n)
    neg_d, idx = jax.lax.top_k(-d, kk)
    if kk < k:
        neg_d = jnp.concatenate(
            [neg_d, jnp.full((k - kk,), -jnp.inf, neg_d.dtype)])
        idx = jnp.concatenate(
            [idx, jnp.full((k - kk,), -1, idx.dtype)])
    return -neg_d, idx


def make_distributed_topk(mesh: Mesh, k: int, shard_axis: str = "data"):
    """Builds a jit'd scatter-gather top-k over ``shard_axis``.

    vecs: (n_global, d) sharded on dim 0; ids: (n_global,) matching.
    Every shard computes a local top-k, then the (tiny) per-shard results
    are all-gathered and merged — collective payload is O(shards * k),
    never O(n).
    """
    from jax.experimental.shard_map import shard_map


    def _shardfn(q, vecs, ids):
        d, idx = local_topk(q, vecs, k)             # local candidates
        # padded slots (k > local rows) carry idx=-1: gather a -1 id so
        # they survive the merge as identifiable sentinels, never as a
        # bogus row 0 hit
        local_ids = jnp.where(idx >= 0, ids[jnp.maximum(idx, 0)], -1)
        # gather per-shard winners: (n_shards, k)
        all_d = jax.lax.all_gather(d, shard_axis)
        all_i = jax.lax.all_gather(local_ids, shard_axis)
        flat_d = all_d.reshape(-1)
        flat_i = all_i.reshape(-1)
        neg, pos = jax.lax.top_k(-flat_d, k)
        return -neg, flat_i[pos]

    fn = shard_map(
        _shardfn, mesh=mesh,
        in_specs=(P(), P(shard_axis, None), P(shard_axis)),
        out_specs=(P(), P()),
        check_rep=False)
    return jax.jit(fn)


def make_distributed_hybrid_score(mesh: Mesh, k: int,
                                  shard_axis: str = "data"):
    """Weighted multi-modal scatter-gather: vector + spatial distances
    combined on-shard (Algorithm 1's scoring, dense refinement form),
    then global top-k merge."""
    from jax.experimental.shard_map import shard_map

    def _shardfn(qv, qp, w, vecs, pts, ids, mask):
        qf = qv.astype(jnp.float32)
        vf = vecs.astype(jnp.float32)
        d_v = jnp.sqrt(jnp.maximum(
            jnp.sum(qf * qf) - 2.0 * (vf @ qf) + jnp.sum(vf * vf, -1), 0.0))
        d_s = jnp.sqrt(jnp.sum((pts.astype(jnp.float32)
                                - qp.astype(jnp.float32)) ** 2, -1))
        score = w[0] * d_v + w[1] * d_s
        score = jnp.where(mask, score, jnp.inf)
        kk = min(k, vf.shape[0])       # k may exceed the shard row count
        neg, idx = jax.lax.top_k(-score, kk)
        local_ids = ids[idx]
        if kk < k:
            neg = jnp.concatenate(
                [neg, jnp.full((k - kk,), -jnp.inf, neg.dtype)])
            local_ids = jnp.concatenate(
                [local_ids, jnp.full((k - kk,), -1, local_ids.dtype)])
        all_s = jax.lax.all_gather(-neg, shard_axis).reshape(-1)
        all_i = jax.lax.all_gather(local_ids, shard_axis).reshape(-1)
        neg2, pos = jax.lax.top_k(-all_s, k)
        return -neg2, all_i[pos]

    fn = shard_map(
        _shardfn, mesh=mesh,
        in_specs=(P(), P(), P(), P(shard_axis, None), P(shard_axis, None),
                  P(shard_axis), P(shard_axis)),
        out_specs=(P(), P()),
        check_rep=False)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# host-side convenience: run a distributed query over an LSM store
# ---------------------------------------------------------------------------

def store_shards(store, n_shards: int):
    """Partition the store's rows into n_shards (by pk hash), padded to a
    common length — the layout the data axis owns in production.

    Fully vectorized: one stable argsort by shard id and a single sliced
    scatter place every row (no per-row Python loop).  RAM-resident rows
    come along via the sealed-aware ``store.memtable_arrays()`` — the
    active memtable AND memtables queued for flush — so recently-ingested
    rows are not silently dropped from the distributed scan, and
    visibility is resolved before packing: per pk only the newest-seqno
    version survives, and pks whose newest version is a tombstone are
    excluded entirely (a memtable delete shadows the flushed row)."""
    vecs, pts, ids, seqs, tombs = [], [], [], [], []
    col_v = next(c.name for c in store.schema.columns
                 if c.ctype.value == "vector")
    col_p = [c.name for c in store.schema.columns
             if c.ctype.value == "spatial"]
    for seg in store.segments:
        vecs.append(np.asarray(seg.columns[col_v], np.float32))
        if col_p:
            pts.append(np.asarray(seg.columns[col_p[0]], np.float32))
        ids.append(seg.pk)
        seqs.append(seg.seqno)
        tombs.append(seg.tombstone)
    if store.memtable_rows:
        mt_pk, mt_seq, mt_tomb, mt_cols = store.memtable_arrays()
        vecs.append(np.asarray(mt_cols[col_v], np.float32))
        if col_p:
            pts.append(np.asarray(mt_cols[col_p[0]], np.float32))
        ids.append(mt_pk)
        seqs.append(mt_seq)
        tombs.append(mt_tomb)
    if not vecs:
        raise ValueError("empty store")
    vecs = np.concatenate(vecs)
    ids = np.concatenate(ids)
    seqs = np.concatenate(seqs)
    tombs = np.concatenate(tombs)
    pts = np.concatenate(pts) if pts else np.zeros((len(ids), 2), np.float32)
    # visibility: newest seqno per pk wins; tombstone winners drop the pk
    order = np.lexsort((seqs, ids))
    run_end = np.append(ids[order][1:] != ids[order][:-1], True)
    winners = order[run_end]
    winners = winners[~tombs[winners]]
    vecs, pts, ids = vecs[winners], pts[winners], ids[winners]
    shard_of = (ids % n_shards).astype(np.int64)
    counts = np.bincount(shard_of, minlength=n_shards)
    per = int(counts.max()) if len(ids) else 1
    # slot of each row: shard base + rank within its shard, computed from
    # the stable shard sort (rows stay in store order within a shard)
    order = np.argsort(shard_of, kind="stable")
    within = np.arange(len(ids)) - np.repeat(
        np.cumsum(counts) - counts, counts)
    slots = shard_of[order] * per + within
    V = np.zeros((n_shards * per, vecs.shape[1]), np.float32)
    Pt = np.zeros((n_shards * per, 2), np.float32)
    I = np.full(n_shards * per, -1, np.int64)
    M = np.zeros(n_shards * per, bool)
    V[slots] = vecs[order]
    Pt[slots] = pts[order]
    I[slots] = ids[order]
    M[slots] = True
    return V, Pt, I, M
