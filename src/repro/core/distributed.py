"""Distributed query execution: shard_map scatter-gather over the mesh.

ARCADE's data plane at scale: segments are partitioned across the
``data`` mesh axis (each data-parallel group owns a disjoint shard of the
LSM keyspace); a vector query fans out, every shard answers a local
top-k from its own posting blocks (ivf_scan semantics), and the global
top-k is combined with an all-gather + merge — the TPU-native analog of
the paper's per-SST iterators + top-level merging iterator, one level up.

``distributed_topk`` is pure jnp and jit/shard_map-lowered, so the same
code path is exercised by tests on 1 device and by the dry-run on the
16x16 / 2x16x16 production meshes (launch/dryrun_arcade.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def local_topk(q: jnp.ndarray, vecs: jnp.ndarray, k: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact local top-k: q (d,), vecs (n, d) -> (k dists, k indices).
    Distances are squared L2 (monotone for merging; sqrt at the edge)."""
    qf = q.astype(jnp.float32)
    vf = vecs.astype(jnp.float32)
    d = (jnp.sum(qf * qf) - 2.0 * (vf @ qf)
         + jnp.sum(vf * vf, axis=-1))
    neg_d, idx = jax.lax.top_k(-d, k)
    return -neg_d, idx


def make_distributed_topk(mesh: Mesh, k: int, shard_axis: str = "data"):
    """Builds a jit'd scatter-gather top-k over ``shard_axis``.

    vecs: (n_global, d) sharded on dim 0; ids: (n_global,) matching.
    Every shard computes a local top-k, then the (tiny) per-shard results
    are all-gathered and merged — collective payload is O(shards * k),
    never O(n).
    """
    from jax.experimental.shard_map import shard_map

    n_shards = mesh.shape[shard_axis]

    def _shardfn(q, vecs, ids):
        d, idx = local_topk(q, vecs, k)             # local candidates
        local_ids = ids[idx]
        # gather per-shard winners: (n_shards, k)
        all_d = jax.lax.all_gather(d, shard_axis)
        all_i = jax.lax.all_gather(local_ids, shard_axis)
        flat_d = all_d.reshape(-1)
        flat_i = all_i.reshape(-1)
        neg, pos = jax.lax.top_k(-flat_d, k)
        return -neg, flat_i[pos]

    fn = shard_map(
        _shardfn, mesh=mesh,
        in_specs=(P(), P(shard_axis, None), P(shard_axis)),
        out_specs=(P(), P()),
        check_rep=False)
    return jax.jit(fn)


def make_distributed_hybrid_score(mesh: Mesh, k: int,
                                  shard_axis: str = "data"):
    """Weighted multi-modal scatter-gather: vector + spatial distances
    combined on-shard (Algorithm 1's scoring, dense refinement form),
    then global top-k merge."""
    from jax.experimental.shard_map import shard_map

    def _shardfn(qv, qp, w, vecs, pts, ids, mask):
        qf = qv.astype(jnp.float32)
        vf = vecs.astype(jnp.float32)
        d_v = jnp.sqrt(jnp.maximum(
            jnp.sum(qf * qf) - 2.0 * (vf @ qf) + jnp.sum(vf * vf, -1), 0.0))
        d_s = jnp.sqrt(jnp.sum((pts.astype(jnp.float32)
                                - qp.astype(jnp.float32)) ** 2, -1))
        score = w[0] * d_v + w[1] * d_s
        score = jnp.where(mask, score, jnp.inf)
        neg, idx = jax.lax.top_k(-score, k)
        all_s = jax.lax.all_gather(-neg, shard_axis).reshape(-1)
        all_i = jax.lax.all_gather(ids[idx], shard_axis).reshape(-1)
        neg2, pos = jax.lax.top_k(-all_s, k)
        return -neg2, all_i[pos]

    fn = shard_map(
        _shardfn, mesh=mesh,
        in_specs=(P(), P(), P(), P(shard_axis, None), P(shard_axis, None),
                  P(shard_axis), P(shard_axis)),
        out_specs=(P(), P()),
        check_rep=False)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# host-side convenience: run a distributed query over an LSM store
# ---------------------------------------------------------------------------

def store_shards(store, n_shards: int):
    """Partition the store's rows into n_shards (by pk hash), padded to a
    common length — the layout the data axis owns in production."""
    vecs, pts, ids = [], [], []
    col_v = next(c.name for c in store.schema.columns
                 if c.ctype.value == "vector")
    col_p = [c.name for c in store.schema.columns
             if c.ctype.value == "spatial"]
    for seg in store.segments:
        vecs.append(np.asarray(seg.columns[col_v], np.float32))
        if col_p:
            pts.append(np.asarray(seg.columns[col_p[0]], np.float32))
        ids.append(seg.pk)
    if not vecs:
        raise ValueError("empty store")
    vecs = np.concatenate(vecs)
    ids = np.concatenate(ids)
    pts = np.concatenate(pts) if pts else np.zeros((len(ids), 2), np.float32)
    shard_of = ids % n_shards
    per = int(np.max(np.bincount(shard_of.astype(int),
                                 minlength=n_shards))) if len(ids) else 1
    V = np.zeros((n_shards, per, vecs.shape[1]), np.float32)
    Pt = np.zeros((n_shards, per, 2), np.float32)
    I = np.full((n_shards, per), -1, np.int64)
    M = np.zeros((n_shards, per), bool)
    fill = np.zeros(n_shards, int)
    for i in range(len(ids)):
        s = int(shard_of[i])
        j = fill[s]
        V[s, j] = vecs[i]
        Pt[s, j] = pts[i]
        I[s, j] = ids[i]
        M[s, j] = True
        fill[s] += 1
    return (V.reshape(n_shards * per, -1), Pt.reshape(n_shards * per, 2),
            I.reshape(-1), M.reshape(-1))
