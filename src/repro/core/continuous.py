"""Continuous query processing: SYNC (fixed interval) and ASYNC
(data-change-triggered) execution over the incremental-view framework
(paper §2.2 Types 3-4, §6).

The scheduler runs on a virtual clock (test-friendly; the serving driver
maps it to wall time). Three engines, matching the paper's §7.5 setups:

  * "none"   — ARCADE   : re-execute from base tables every time;
  * "fcache" — ARCADE+F : full-result cache, invalidated when a delta
                hits the query's predicate region (prior-work baseline);
  * "views"  — ARCADE+S : incremental materialized views + rewriting
                (the paper's contribution).
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Dict, List, Optional, Tuple


from repro.core import query as q
from repro.core.executor import Executor
from repro.core.views import rewrite as rw_lib
from repro.core.views.maintenance import ViewMaintainer
from repro.core.views.selection import build_candidates, knapsack_select
from repro.obs import REGISTRY
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class Registered:
    decl: object                   # SyncQuery | AsyncQuery
    next_due: float = 0.0
    dirty: bool = True
    rewrite: Optional[rw_lib.Rewrite] = None
    runs: int = 0
    view_hits: int = 0
    last_result: Optional[List] = None


class _FullResultCache:
    """ARCADE+F baseline: cache complete results per query; a delta that
    may affect the query invalidates its entry."""

    def __init__(self):
        self.entries: Dict[int, List] = {}

    def invalidate_on_delta(self, registered, batch, deleted) -> None:
        from repro.core.executor import eval_predicate_rows
        for rid, res in list(self.entries.items()):
            reg = registered.get(rid)
            if reg is None:
                continue
            query = reg.decl.query
            if deleted or batch is None:
                self.entries.pop(rid, None)
                continue
            # conservative: a delta row touching ANY leaf predicate's
            # region may move rows in OR out of the result (an update that
            # fails the full expression can still evict its old version),
            # so leaves are tested individually, never the combined tree
            leaves = q.leaf_predicates(query.where)
            affected = not leaves
            for pred in leaves:
                try:
                    if eval_predicate_rows(batch, pred).any():
                        affected = True
                        break
                except Exception:
                    affected = True
                    break
            if affected or query.is_nn:
                self.entries.pop(rid, None)


class ContinuousEngine:
    def __init__(self, store, mode: str = "views",
                 view_budget_bytes: float = 64 * 2**20):
        assert mode in ("none", "fcache", "views")
        self.store = store
        self.mode = mode
        self.executor = Executor(store)
        self.registered: Dict[int, Registered] = {}
        self._next_id = 0
        self.view_budget = view_budget_bytes
        self.maintainer = ViewMaintainer(store) if mode == "views" else None
        self.fcache = _FullResultCache() if mode == "fcache" else None
        self.metrics = {"executions": 0, "view_hits": 0, "cache_hits": 0,
                        "exec_time_s": 0.0}
        if mode == "fcache":
            store.on_delta(self._fcache_delta)
        store.on_delta(self._mark_async_dirty)

    # --------------------------------------------------------- registration
    def register(self, decl) -> int:
        rid = self._next_id
        self._next_id += 1
        reg = Registered(decl=decl)
        if isinstance(decl, q.SyncQuery):
            reg.next_due = 0.0
        self.registered[rid] = reg
        if self.mode == "views":
            self._reselect_views()
            # static rewrite at registration time (paper §6)
            reg.rewrite = rw_lib.match(self.maintainer.views, decl.query)
        return rid

    def _reselect_views(self) -> None:
        queries = [r.decl.query for r in self.registered.values()]
        cands = build_candidates(self.store, queries)
        chosen = knapsack_select(cands, self.view_budget)
        self.maintainer.install([c.view for c in chosen])
        # re-bind static rewrites for all registered queries
        for reg in self.registered.values():
            reg.rewrite = rw_lib.match(self.maintainer.views,
                                       reg.decl.query)

    # --------------------------------------------------------------- deltas
    def _fcache_delta(self, pks, batch, deleted) -> None:
        if self.fcache is not None:
            self.fcache.invalidate_on_delta(self.registered, batch, deleted)

    def _mark_async_dirty(self, pks, batch, deleted) -> None:
        for reg in self.registered.values():
            if isinstance(reg.decl, q.AsyncQuery):
                reg.dirty = True

    # ------------------------------------------------------------ execution
    def _run_one(self, rid: int, reg: Registered) -> List:
        t0 = _time.perf_counter()
        query = reg.decl.query
        if self.mode == "fcache" and rid in self.fcache.entries:
            self.metrics["cache_hits"] += 1
            res = self.fcache.entries[rid]
        elif self.mode == "views" and reg.rewrite is not None \
                and reg.rewrite.any:
            res, st, used = rw_lib.execute_with_views(
                self.executor, query, reg.rewrite)
            if used:
                reg.view_hits += 1
                self.metrics["view_hits"] += 1
        else:
            res, _ = self.executor.execute(query)
        self._finish_run(rid, reg, res, t0)
        return res

    def _finish_run(self, rid: int, reg: Registered, res: List,
                    t0: float) -> None:
        if self.mode == "fcache":
            self.fcache.entries[rid] = res
        reg.runs += 1
        reg.last_result = res
        self.metrics["executions"] += 1
        self.metrics["exec_time_s"] += _time.perf_counter() - t0

    def _can_batch(self, rid: int, reg: Registered) -> bool:
        """Due queries with no cache/view shortcut go through the shared
        batched scan in ``execute_many``."""
        if self.mode == "fcache" and rid in self.fcache.entries:
            return False
        if self.mode == "views" and reg.rewrite is not None \
                and reg.rewrite.any:
            return False
        return True

    def advance(self, now: float) -> Dict[int, List]:
        """Run everything due at virtual time ``now``; returns results.

        All due queries without a cache/view shortcut execute in ONE
        ``execute_many`` batch, amortizing per-segment scans and stacking
        their query vectors into batched kernel calls.
        """
        adv0 = _time.perf_counter()
        due = []
        for rid, reg in self.registered.items():
            if isinstance(reg.decl, q.SyncQuery):
                if now >= reg.next_due:
                    due.append((rid, reg))
                    reg.next_due = now + reg.decl.interval_s
            else:   # ASYNC: only when data changed
                if reg.dirty:
                    due.append((rid, reg))
                    reg.dirty = False
        out: Dict[int, List] = {}
        with obs_trace.span("advance", due=len(due)):
            batched = [(rid, reg) for rid, reg in due
                       if self._can_batch(rid, reg)]
            for rid, reg in due:
                if not self._can_batch(rid, reg):
                    out[rid] = self._run_one(rid, reg)
            if batched:
                t0 = _time.perf_counter()
                many = self.executor.execute_many(
                    [reg.decl.query for _, reg in batched])
                for (rid, reg), (res, _) in zip(batched, many):
                    out[rid] = res
                    self._finish_run(rid, reg, res, t0)
                    t0 = _time.perf_counter()
        REGISTRY.observe("continuous.advance_s",
                         _time.perf_counter() - adv0)
        REGISTRY.inc("continuous.advances")
        return out

    def snapshot_query(self, query: q.HybridQuery) -> Tuple[List, bool]:
        """One-shot query; in views mode, dynamic runtime matching."""
        if self.mode == "views":
            rw = rw_lib.match(self.maintainer.views, query)
            res, st, used = rw_lib.execute_with_views(self.executor, query,
                                                      rw)
            if used:
                self.metrics["view_hits"] += 1
            return res, used
        res, _ = self.executor.execute(query)
        return res, False
