"""Per-segment vector IVF / PQ-IVF index with block-level access (paper §4).

Structure mirrors Figure 2:
  level 1 — index metadata: centroid table (n_centroids, dim) +
            centroid -> posting-list block ranges;
  level 2 — posting-list blocks: (vector, row-id) pairs grouped by
            centroid, padded to BLOCK_ROWS multiples (the read unit).

Query path (3 steps, per the paper): load centroid metadata -> score
centroids (MXU matmul kernel) -> read only the n_probe nearest centroids'
posting blocks -> exact distances (Pallas ivf_scan kernel) -> top-k. Only
the selected blocks are touched: that is the block-granular I/O claim vs
fully-memory-resident per-segment indexes (SingleStore-V).

The PQ variant stores uint8 codes; distances via ADC (one-hot x LUT matmul
kernel), with exact re-ranking of the top candidates.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np

from repro.core.index.base import SecondaryIndex, SortedAccess
from repro.core.types import BLOCK_ROWS
from repro.kernels import ops as kops


def kmeans(x: np.ndarray, k: int, iters: int = 8, seed: int = 0
           ) -> np.ndarray:
    """Lightweight k-means for centroid tables (float32, L2)."""
    n = len(x)
    rng = np.random.default_rng(seed)
    k = min(k, n)
    cents = x[rng.choice(n, size=k, replace=False)].astype(np.float32)
    for _ in range(iters):
        assign = kops.assign_nearest(x, cents)
        for j in range(k):
            m = assign == j
            if m.any():
                cents[j] = x[m].mean(axis=0)
    return cents


class IVFIndex(SecondaryIndex):
    kind = "ivf"

    def __init__(self, n_probe: int = 4, use_pq: bool = False,
                 pq_m: int = 8, refine: int = 4):
        self.n_probe = n_probe
        self.use_pq = use_pq
        self.pq_m = pq_m
        self.refine = refine            # PQ: exact-rerank factor (x k)
        # built state
        self.centroids: Optional[np.ndarray] = None
        self.post_rows: Optional[np.ndarray] = None     # row ids, grouped
        self.post_vecs: Optional[np.ndarray] = None     # vectors, grouped
        self.post_offsets: Optional[np.ndarray] = None  # centroid -> range
        self.codes: Optional[np.ndarray] = None         # PQ codes (n, m)
        self.codebooks: Optional[np.ndarray] = None     # (m, 256, dsub)
        self.blocks_total = 0

    # ------------------------------------------------------------- build
    def build(self, segment, column) -> None:
        vecs = np.asarray(segment.columns[column.name], np.float32)
        n = len(vecs)
        if n == 0:
            self.centroids = np.zeros((1, column.dim), np.float32)
            self.post_rows = np.zeros((0,), np.int64)
            self.post_vecs = np.zeros((0, column.dim), np.float32)
            self.post_offsets = np.zeros((2,), np.int64)
            return
        k = max(1, int(math.sqrt(n)))
        self.centroids = kmeans(vecs, k)
        self._group(vecs, kops.assign_nearest(vecs, self.centroids))
        if self.use_pq:
            self._build_pq(vecs)

    def _group(self, vecs: np.ndarray, assign: np.ndarray) -> None:
        """Group vectors into posting lists by centroid assignment and
        compute the per-centroid radii (triangle-inequality lower bound
        d(q, v) >= d(q, c) - radius(c) for sorted NRA-exact access) — all
        vectorized, no per-centroid kernel loop."""
        n = len(vecs)
        order = np.argsort(assign, kind="stable")
        self.post_rows = order.astype(np.int64)
        self.post_vecs = vecs[order]
        counts = np.bincount(assign, minlength=len(self.centroids))
        self.post_offsets = np.zeros(len(self.centroids) + 1, np.int64)
        np.cumsum(counts, out=self.post_offsets[1:])
        self.blocks_total = (n + BLOCK_ROWS - 1) // BLOCK_ROWS
        diff = self.post_vecs - self.centroids[assign[order]]
        d = np.sqrt(np.maximum((diff * diff).sum(axis=1), 0.0))
        self.radii = np.zeros(len(self.centroids), np.float32)
        nonempty = counts > 0
        if nonempty.any():
            starts = self.post_offsets[:-1][nonempty]
            self.radii[nonempty] = np.maximum.reduceat(d, starts)

    def merge(self, parts, merged_seg, column, row_maps) -> None:
        """Compaction-aware merge (paper §4): reuse the parts' centroid
        tables (their union) instead of re-running k-means, and reassign
        only the surviving rows in one vectorized ``assign_nearest`` —
        index maintenance cost at compaction is a single assignment pass,
        not a full rebuild."""
        vecs = np.asarray(merged_seg.columns[column.name], np.float32)
        n = len(vecs)
        if n == 0:
            self.centroids = np.zeros((1, column.dim), np.float32)
            self.post_rows = np.zeros((0,), np.int64)
            self.post_vecs = np.zeros((0, column.dim), np.float32)
            self.post_offsets = np.zeros((2,), np.int64)
            self.radii = np.zeros(1, np.float32)
            return
        usable = [p for p in parts
                  if getattr(p, "centroids", None) is not None
                  and len(p.centroids)]
        if not usable:
            self.build(merged_seg, column)
            return
        # keep the centroid table at the rebuild-equivalent size so the
        # n_probe/#lists ratio (and with it recall) is unchanged: each
        # part contributes its highest-occupancy centroids, proportional
        # to its share of the surviving rows
        target_k = max(1, int(math.sqrt(n)))
        total = sum(len(p.post_rows) for p in usable) or 1
        kept_c, kept_n = [], []
        for p in usable:
            quota = max(1, round(target_k * len(p.post_rows) / total))
            counts = np.diff(p.post_offsets)
            top = np.sort(np.argsort(counts)[::-1][:quota])
            kept_c.append(p.centroids[top])
            kept_n.append(counts[top])
        cents = np.concatenate(kept_c).astype(np.float32)
        if len(cents) > target_k:
            # quotas round up, so trim the lowest-occupancy centroids
            # globally — never a positional tail (that would erase one
            # part's whole contribution)
            occ = np.concatenate(kept_n)
            cents = cents[np.sort(np.argsort(occ)[::-1][:target_k])]
        self.centroids = cents
        self._group(vecs, kops.assign_nearest(vecs, self.centroids))
        if self.use_pq:
            donor = max((p for p in parts
                         if getattr(p, "codebooks", None) is not None),
                        key=lambda p: len(p.post_rows), default=None)
            if donor is None:
                self._build_pq(vecs)
            else:
                self._reencode_pq(vecs, donor.codebooks)

    def _reencode_pq(self, vecs: np.ndarray, codebooks: np.ndarray) -> None:
        """PQ codebook reuse: keep a donor part's codebooks and re-encode
        the merged vectors (one assignment per subspace, no k-means)."""
        m, _, dsub = codebooks.shape
        self.pq_m = m
        self.codebooks = codebooks
        codes = [kops.assign_nearest(vecs[:, j * dsub:(j + 1) * dsub],
                                     codebooks[j]) for j in range(m)]
        codes = np.stack(codes, axis=1).astype(np.uint8)
        self.codes = codes[self.post_rows]

    def _build_pq(self, vecs: np.ndarray) -> None:
        n, d = vecs.shape
        m = self.pq_m
        while d % m:
            m //= 2
        self.pq_m = m
        dsub = d // m
        n_codes = min(256, max(2, n))
        books, codes = [], []
        for j in range(m):
            sub = vecs[:, j * dsub:(j + 1) * dsub]
            cb = kmeans(sub, n_codes, seed=j)
            if len(cb) < 256:
                cb = np.pad(cb, ((0, 256 - len(cb)), (0, 0)),
                            constant_values=1e30)
            books.append(cb)
            codes.append(kops.assign_nearest(sub, cb[:n_codes]))
        self.codebooks = np.stack(books).astype(np.float32)   # (m,256,dsub)
        codes = np.stack(codes, axis=1).astype(np.uint8)       # (n, m)
        self.codes = codes[self.post_rows]                     # grouped order

    # ------------------------------------------------------- persistence
    def to_arrays(self):
        out = {"centroids": np.asarray(self.centroids, np.float32),
               "post_rows": np.asarray(self.post_rows, np.int64),
               "post_vecs": np.asarray(self.post_vecs, np.float32),
               "post_offsets": np.asarray(self.post_offsets, np.int64),
               "radii": np.asarray(
                   getattr(self, "radii",
                           np.zeros(len(self.centroids), np.float32)),
                   np.float32),
               "blocks_total": np.asarray([self.blocks_total], np.int64)}
        if self.codes is not None:
            out["codes"] = np.asarray(self.codes, np.uint8)
            out["codebooks"] = np.asarray(self.codebooks, np.float32)
        return out

    def from_arrays(self, arrays, segment, column) -> None:
        self.centroids = np.asarray(arrays["centroids"], np.float32)
        self.post_rows = np.asarray(arrays["post_rows"], np.int64)
        self.post_vecs = np.asarray(arrays["post_vecs"], np.float32)
        self.post_offsets = np.asarray(arrays["post_offsets"], np.int64)
        self.radii = np.asarray(arrays["radii"], np.float32)
        self.blocks_total = int(arrays["blocks_total"][0])
        if "codes" in arrays:
            self.codes = np.asarray(arrays["codes"], np.uint8)
            self.codebooks = np.asarray(arrays["codebooks"], np.float32)
            self.use_pq = True
            self.pq_m = int(self.codebooks.shape[0])

    # ------------------------------------------------------------- query
    def _probe_order(self, q: np.ndarray) -> np.ndarray:
        cd = kops.l2_distances(q[None, :], self.centroids)[0]
        return np.argsort(cd)

    @staticmethod
    def _euclid(d2: np.ndarray) -> np.ndarray:
        return np.sqrt(np.maximum(d2, 0.0))

    def search(self, q: np.ndarray, k: int, n_probe: Optional[int] = None
               ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Top-k (dists, row_ids, blocks_read) via n_probe posting lists."""
        q = np.asarray(q, np.float32)
        n_probe = n_probe or self.n_probe
        probe = self._probe_order(q)[:n_probe]
        cand_slices = [slice(int(self.post_offsets[c]),
                             int(self.post_offsets[c + 1])) for c in probe]
        rows = np.concatenate([self.post_rows[s] for s in cand_slices]) \
            if cand_slices else np.zeros((0,), np.int64)
        if len(rows) == 0:
            return np.zeros((0,)), rows, 0
        blocks_read = sum((s.stop - s.start + BLOCK_ROWS - 1) // BLOCK_ROWS
                          for s in cand_slices)
        if self.use_pq:
            codes = np.concatenate([self.codes[s] for s in cand_slices])
            d_adc = kops.pq_adc_distances(q, codes, self.codebooks)
            keep = min(len(rows), max(k * self.refine, k))
            top = np.argpartition(d_adc, keep - 1)[:keep]
            vecs = np.concatenate([self.post_vecs[s] for s in cand_slices])
            d_exact = self._euclid(kops.l2_distances(q[None, :],
                                                     vecs[top])[0])
            # (score, row) comparator: pk order within a segment
            order = np.lexsort((rows[top], d_exact))[:k]
            return d_exact[order], rows[top][order], blocks_read
        vecs = np.concatenate([self.post_vecs[s] for s in cand_slices])
        d, idx = kops.block_topk(q, vecs, min(k, len(rows)))
        return self._euclid(d), rows[idx], blocks_read

    def bitmap(self, segment, predicate) -> np.ndarray:
        """VectorRange: dist(col, q) < thresh — probe lists, exact check."""
        q = np.asarray(predicate.q, np.float32)
        # distance filters need high recall: probe ~half the lists
        mask = np.zeros(segment.n_rows, bool)
        if predicate.thresh <= 0:          # admits nothing: skip the probe
            return mask
        t2 = float(predicate.thresh) ** 2
        n_probe = max(self.n_probe, len(self.centroids) // 2)
        probe = self._probe_order(q)[:n_probe]
        for c in probe:
            s = slice(int(self.post_offsets[c]), int(self.post_offsets[c + 1]))
            if s.stop == s.start:
                continue
            # compare squared distances against thresh^2: same admitted
            # rows, one less full-posting-list sqrt pass
            d2 = kops.l2_distances(q[None, :], self.post_vecs[s])[0]
            hit = d2 < t2
            mask[self.post_rows[s][hit]] = True
        return mask

    def iterator(self, segment, query) -> SortedAccess:
        return IVFSortedAccess(self, np.asarray(query, np.float32))

    # --------------------------------------------------------- optimizer
    def selectivity(self, segment, predicate) -> float:
        """Sample centroid distances as a proxy for the distance filter."""
        if segment.n_rows == 0:
            return 0.0
        q = np.asarray(predicate.q, np.float32)
        cd = self._euclid(kops.l2_distances(q[None, :], self.centroids)[0])
        frac = float(np.mean(cd < predicate.thresh * 1.5))
        return min(1.0, max(1.0 / segment.n_rows, frac))

    def probe_cost_blocks(self, segment, predicate) -> float:
        """Blocks touched by an n_probe-deep probe.  Priced from the
        ACTUAL trained list sizes, not n_rows/n_lists: k-means on skewed
        data leaves some posting lists holding most of the rows, and the
        probe order follows query-centroid distance — worst case it
        lands on the heaviest lists, so the conservative estimate sums
        the n_probe LARGEST lists."""
        if self.post_offsets is None:       # not trained yet: balanced guess
            n_lists = len(self.centroids) if self.centroids is not None else 1
            per_list = max(1.0, segment.n_rows / max(1, n_lists) / BLOCK_ROWS)
            return 1.0 + self.n_probe * per_list
        sizes = np.diff(self.post_offsets).astype(np.float64)
        if not len(sizes):
            return 1.0
        top = np.sort(sizes)[::-1][:self.n_probe]
        # every probed list costs at least one block fetch
        return 1.0 + float(np.maximum(top / BLOCK_ROWS, 1.0).sum())


class IVFSortedAccess(SortedAccess):
    """Rigorously sorted access for NRA: posting lists are expanded in
    centroid-distance order; a buffered row is emitted only once its exact
    distance is <= the triangle-inequality lower bound of every unexpanded
    list (max(0, d(q, c) - radius(c))) — so the emitted stream is globally
    non-decreasing and the NRA bound bookkeeping is exact."""

    def __init__(self, index: IVFIndex, q: np.ndarray, block: int = 256):
        self.idx = index
        self.q = q
        cd2 = kops.l2_distances(q[None, :], index.centroids)[0]
        cd = IVFIndex._euclid(cd2)
        self.order = np.argsort(cd)
        radii = getattr(index, "radii", np.zeros(len(cd), np.float32))
        lbs = np.maximum(cd - radii, 0.0)
        # frontier bound after expanding the first i lists (in cd order)
        lbs_ord = lbs[self.order]
        self._suffix_lb = np.concatenate([
            np.minimum.accumulate(lbs_ord[::-1])[::-1], [np.inf]])
        self.next_list = 0
        self.block = block
        self.buf_d = np.zeros((0,), np.float32)
        self.buf_r = np.zeros((0,), np.int64)
        self.blocks_read = 0

    def _frontier(self) -> float:
        """Lower bound of anything still unexpanded."""
        return float(self._suffix_lb[self.next_list])

    def _expand(self) -> bool:
        if self.next_list >= len(self.order):
            return False
        c = int(self.order[self.next_list])
        self.next_list += 1
        s = slice(int(self.idx.post_offsets[c]),
                  int(self.idx.post_offsets[c + 1]))
        if s.stop > s.start:
            d = IVFIndex._euclid(
                kops.l2_distances(self.q[None, :], self.idx.post_vecs[s])[0])
            self.blocks_read += (s.stop - s.start + BLOCK_ROWS - 1) \
                // BLOCK_ROWS
            self.buf_d = np.concatenate([self.buf_d, d])
            self.buf_r = np.concatenate([self.buf_r, self.idx.post_rows[s]])
            o = np.argsort(self.buf_d)
            self.buf_d, self.buf_r = self.buf_d[o], self.buf_r[o]
        return True

    def next_block(self):
        # expand until at least `block` buffered rows are certified
        # (distance <= frontier bound) or nothing remains to expand
        while True:
            certified = int(np.searchsorted(self.buf_d, self._frontier(),
                                            side="right"))
            if certified >= self.block or not self._expand():
                break
        certified = int(np.searchsorted(self.buf_d, self._frontier(),
                                        side="right"))
        n = min(max(certified, 0), len(self.buf_d))
        if n == 0:
            n = min(self.block, len(self.buf_d))  # all expanded: flush
        if n == 0:
            return None
        out = (self.buf_d[:n], self.buf_r[:n])
        self.buf_d, self.buf_r = self.buf_d[n:], self.buf_r[n:]
        return out
