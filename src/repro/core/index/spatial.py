"""Z-order spatial index (paper §2.1 SPATIAL_INDEX_TYPE 'local'/'hybrid').

Per-segment component: rows sorted by 32-bit Morton code (16 bits per
axis over the segment's bounding box), with per-block zone maps (bbox per
block). Range queries prune blocks by bbox overlap then exact-filter via
the bitmap kernel; distance iterators implement incremental nearest
neighbour (Hjaltason & Samet) over block bounding boxes — a correct
globally-sorted stream for NRA.

'hybrid' adds the global level: the store-wide fence map from segment
bboxes handled by core.index.global_index.
"""
from __future__ import annotations

import heapq
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.core.index.base import SecondaryIndex, SortedAccess
from repro.core.types import BLOCK_ROWS
from repro.kernels import ops as kops


def morton_codes(xy: np.ndarray, bbox: Tuple[float, float, float, float]
                 ) -> np.ndarray:
    """Interleave 16-bit quantized x/y into 32-bit Morton codes."""
    xmin, ymin, xmax, ymax = bbox
    sx = (xmax - xmin) or 1.0
    sy = (ymax - ymin) or 1.0
    qx = np.clip(((xy[:, 0] - xmin) / sx * 65535), 0, 65535).astype(np.uint32)
    qy = np.clip(((xy[:, 1] - ymin) / sy * 65535), 0, 65535).astype(np.uint32)

    def spread(v):
        v = (v | (v << 8)) & np.uint32(0x00FF00FF)
        v = (v | (v << 4)) & np.uint32(0x0F0F0F0F)
        v = (v | (v << 2)) & np.uint32(0x33333333)
        v = (v | (v << 1)) & np.uint32(0x55555555)
        return v

    return (spread(qx) | (spread(qy) << np.uint32(1))).astype(np.uint32)


def _block_bboxes(points: np.ndarray) -> np.ndarray:
    """Per-block (xmin, ymin, xmax, ymax) zone maps, vectorized via
    ``reduceat`` over BLOCK_ROWS strides (no per-block Python loop)."""
    n = len(points)
    if n == 0:
        return np.zeros((0, 4), np.float32)
    starts = np.arange(0, n, BLOCK_ROWS)
    mins = np.minimum.reduceat(points, starts, axis=0)
    maxs = np.maximum.reduceat(points, starts, axis=0)
    return np.concatenate([mins, maxs], axis=1).astype(np.float32)


class ZOrderIndex(SecondaryIndex):
    kind = "zorder"

    def __init__(self):
        self.rows: Optional[np.ndarray] = None      # row ids in z order
        self.points: Optional[np.ndarray] = None    # (n, 2) in z order
        self.block_bbox: Optional[np.ndarray] = None  # (nb, 4)
        self.bbox = (0.0, 0.0, 1.0, 1.0)

    def build(self, segment, column) -> None:
        pts = np.asarray(segment.columns[column.name], np.float32)
        if len(pts) == 0:
            self.rows = np.zeros((0,), np.int64)
            self.points = pts.reshape(0, 2)
            self.block_bbox = np.zeros((0, 4), np.float32)
            return
        self.bbox = (float(pts[:, 0].min()), float(pts[:, 1].min()),
                     float(pts[:, 0].max()), float(pts[:, 1].max()))
        z = morton_codes(pts, self.bbox)
        order = np.argsort(z, kind="stable")
        self.rows = order.astype(np.int64)
        self.points = pts[order]
        self.block_bbox = _block_bboxes(self.points)

    def merge(self, parts, merged_seg, column, row_maps) -> None:
        """Z-order array merge: gather the surviving (row, point) pairs
        from the parts' already-materialized z-ordered arrays, re-quantize
        under the union bounding box, and re-sort the codes — the raw
        column is never re-read and the zone maps rebuild via reduceat."""
        pts_list, rows_list = [], []
        for part, rmap in zip(parts, row_maps):
            if part.rows is None or not len(part.rows):
                continue
            new_rows = rmap[part.rows]
            keep = new_rows >= 0
            pts_list.append(part.points[keep])
            rows_list.append(new_rows[keep])
        if not pts_list:
            self.rows = np.zeros((0,), np.int64)
            self.points = np.zeros((0, 2), np.float32)
            self.block_bbox = np.zeros((0, 4), np.float32)
            return
        pts = np.concatenate(pts_list)
        rows = np.concatenate(rows_list)
        self.bbox = (float(pts[:, 0].min()), float(pts[:, 1].min()),
                     float(pts[:, 0].max()), float(pts[:, 1].max()))
        z = morton_codes(pts, self.bbox)
        order = np.argsort(z, kind="stable")
        self.rows = rows[order].astype(np.int64)
        self.points = pts[order]
        self.block_bbox = _block_bboxes(self.points)

    def to_arrays(self):
        return {"rows": np.asarray(self.rows, np.int64),
                "points": np.asarray(self.points, np.float32),
                "block_bbox": np.asarray(self.block_bbox, np.float32),
                "bbox": np.asarray(self.bbox, np.float64)}

    def from_arrays(self, arrays, segment, column) -> None:
        self.rows = np.asarray(arrays["rows"], np.int64)
        self.points = np.asarray(arrays["points"], np.float32)
        self.block_bbox = np.asarray(arrays["block_bbox"], np.float32)
        self.bbox = tuple(float(v) for v in arrays["bbox"])

    # --------------------------------------------------------------- range
    def _overlapping_blocks(self, rect) -> np.ndarray:
        if self.block_bbox is None or len(self.block_bbox) == 0:
            return np.zeros((0,), np.int64)
        xmin, ymin, xmax, ymax = rect
        bb = self.block_bbox
        hit = ~((bb[:, 2] < xmin) | (bb[:, 0] > xmax)
                | (bb[:, 3] < ymin) | (bb[:, 1] > ymax))
        return np.nonzero(hit)[0]

    def bitmap(self, segment, predicate) -> np.ndarray:
        mask = np.zeros(segment.n_rows, bool)
        blocks = self._overlapping_blocks(predicate.rect)
        self.last_blocks_read = len(blocks)
        for b in blocks:
            sl = slice(b * BLOCK_ROWS, min((b + 1) * BLOCK_ROWS,
                                           len(self.points)))
            inside = kops.rect_filter(self.points[sl], predicate.rect)
            mask[self.rows[sl][inside]] = True
        return mask

    def selectivity(self, segment, predicate) -> float:
        if segment.n_rows == 0:
            return 0.0
        xmin, ymin, xmax, ymax = predicate.rect
        bxmin, bymin, bxmax, bymax = self.bbox
        area_q = max(0.0, min(xmax, bxmax) - max(xmin, bxmin)) * \
            max(0.0, min(ymax, bymax) - max(ymin, bymin))
        area_b = max((bxmax - bxmin) * (bymax - bymin), 1e-12)
        return min(1.0, area_q / area_b)

    def probe_cost_blocks(self, segment, predicate) -> float:
        return max(1.0, len(self._overlapping_blocks(predicate.rect)))

    # ------------------------------------------------------------ distance
    def iterator(self, segment, query) -> "ZOrderSortedAccess":
        return ZOrderSortedAccess(self, np.asarray(query, np.float32))


def _bbox_min_dist(p: np.ndarray, bb: np.ndarray) -> np.ndarray:
    dx = np.maximum(np.maximum(bb[:, 0] - p[0], p[0] - bb[:, 2]), 0.0)
    dy = np.maximum(np.maximum(bb[:, 1] - p[1], p[1] - bb[:, 3]), 0.0)
    return np.sqrt(dx * dx + dy * dy)


class ZOrderSortedAccess(SortedAccess):
    """Exact incremental-NN: a heap over (block lower bound | row exact
    distance); a row is emitted only once its distance is <= every
    remaining block's lower bound => globally sorted output."""

    def __init__(self, index: ZOrderIndex, point: np.ndarray,
                 block_out: int = 256):
        self.idx = index
        self.p = point
        self.block_out = block_out
        self.blocks_read = 0
        self._heap: List[Tuple[float, int, str, Any]] = []
        self._count = 0
        if index.block_bbox is not None and len(index.block_bbox):
            lbs = _bbox_min_dist(point, index.block_bbox)
            for b, lb in enumerate(lbs):
                self._push(float(lb), "block", b)

    def _push(self, d, kind, payload):
        self._count += 1
        heapq.heappush(self._heap, (d, self._count, kind, payload))

    def next_block(self):
        out_d, out_r = [], []
        while self._heap and len(out_d) < self.block_out:
            d, _, kind, payload = heapq.heappop(self._heap)
            if kind == "row":
                out_d.append(d)
                out_r.append(payload)
                continue
            b = payload
            sl = slice(b * BLOCK_ROWS, min((b + 1) * BLOCK_ROWS,
                                           len(self.idx.points)))
            self.blocks_read += 1
            pts = self.idx.points[sl]
            dist = np.sqrt(((pts - self.p) ** 2).sum(axis=1))
            for dd, rr in zip(dist, self.idx.rows[sl]):
                self._push(float(dd), "row", int(rr))
        if not out_d:
            return None
        return np.asarray(out_d, np.float32), np.asarray(out_r, np.int64)
