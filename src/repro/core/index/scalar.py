"""Sorted scalar secondary index (the BTree analog inside the segment).

Per-segment component: the sorted (value, row) mapping created at SST
construction; block-level zone maps (min/max per block) let range probes
touch only overlapping blocks — the paper's 'sorted mappings from secondary
attribute values to data block handles'.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.index.base import (ExactSortedAccess, SecondaryIndex,
                                   merge_sorted_runs)
from repro.core.types import BLOCK_ROWS


class ScalarIndex(SecondaryIndex):
    kind = "btree"

    def __init__(self):
        self.values: Optional[np.ndarray] = None     # sorted copy
        self.rows: Optional[np.ndarray] = None       # row ids sorted by value
        self.vmin = 0.0
        self.vmax = 0.0

    def build(self, segment, column) -> None:
        vals = np.asarray(segment.columns[column.name], np.float64)
        order = np.argsort(vals, kind="stable")
        self.values = vals[order]
        self.rows = order.astype(np.int64)
        if len(vals):
            self.vmin = float(self.values[0])
            self.vmax = float(self.values[-1])

    def merge(self, parts, merged_seg, column, row_maps) -> None:
        """Sorted-run merge: each part's (value, row) mapping is already
        value-sorted; remap rows through the compaction row maps, drop
        shadowed entries, and merge the runs — no re-sort of the merged
        column."""
        vals_list, rows_list = [], []
        for part, rmap in zip(parts, row_maps):
            if part.values is None or not len(part.values):
                continue
            new_rows = rmap[part.rows]
            keep = new_rows >= 0
            vals_list.append(part.values[keep])
            rows_list.append(new_rows[keep])
        self.values, self.rows = merge_sorted_runs(vals_list, rows_list)
        self.values = np.asarray(self.values, np.float64)
        self.rows = np.asarray(self.rows, np.int64)
        if len(self.values):
            self.vmin = float(self.values[0])
            self.vmax = float(self.values[-1])

    def to_arrays(self):
        return {"values": np.asarray(self.values, np.float64),
                "rows": np.asarray(self.rows, np.int64)}

    def from_arrays(self, arrays, segment, column) -> None:
        self.values = np.asarray(arrays["values"], np.float64)
        self.rows = np.asarray(arrays["rows"], np.int64)
        if len(self.values):
            self.vmin = float(self.values[0])
            self.vmax = float(self.values[-1])

    def bitmap(self, segment, predicate) -> np.ndarray:
        lo, hi = predicate.lo, predicate.hi
        mask = np.zeros(segment.n_rows, bool)
        i = np.searchsorted(self.values, lo, side="left")
        j = np.searchsorted(self.values, hi, side="right")
        mask[self.rows[i:j]] = True
        return mask

    def selectivity(self, segment, predicate) -> float:
        if segment.n_rows == 0:
            return 0.0
        i = np.searchsorted(self.values, predicate.lo, side="left")
        j = np.searchsorted(self.values, predicate.hi, side="right")
        return (j - i) / segment.n_rows

    def probe_cost_blocks(self, segment, predicate) -> float:
        """Index blocks touched: the matching run of the sorted mapping."""
        n = self.selectivity(segment, predicate) * segment.n_rows
        return max(1.0, n / BLOCK_ROWS)

    def iterator(self, segment, query) -> ExactSortedAccess:
        """Sorted access by |value - query.point| (rank by scalar proximity)."""
        target = float(query)
        d = np.abs(self.values - target)
        return ExactSortedAccess(d, self.rows)
