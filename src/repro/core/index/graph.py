"""Per-segment Vamana-style graph index: flat-array CSR over the vector
column (paper §4's secondary-index framework; HMGI's production answer
for high-recall integrated search).

Layout is device-shaped from the start: ``neighbors`` is a dense int32
``(n, R)`` matrix with fixed out-degree R and -1 padding — exactly what
``kernels/graph_search.py`` gathers — plus a medoid entry point.  Build
is the standard incremental loop: greedy beam search from the medoid for
candidates, robust prune (alpha-relaxed) down to R, bidirectional edges
with overflow re-prune.  All squared distances; no sqrt anywhere.

Compaction MERGES graphs instead of rebuilding (the codebook-donation
rule from ``core/quantize.py`` applied to adjacency): the largest part
donates its CSR, remapped through the compaction row maps (-1 for edges
to dropped rows), and only rows the donor does not cover — foreign
parts' rows — are stitched in by bounded re-insertion.  ``reinserted``
/ ``donated_rows`` counters let tests prove the bound.

``pack_graphs`` stacks the per-segment CSRs into packed row space for
the one-launch cross-segment kernel, seeding every segment's medoid.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.index.base import ExactSortedAccess, SecondaryIndex
from repro.core.types import BLOCK_ROWS

DEFAULT_R = 16          # fixed out-degree (CSR row width)
DEFAULT_BUILD_BEAM = 32  # greedy-search working-set size at build time
PRUNE_ALPHA = 1.2        # robust-prune relaxation (squared: alpha^2)
N_ENTRIES = 16           # farthest-point-sampled seeds per segment


class GraphIndex(SecondaryIndex):
    """Vamana-style CSR graph over one segment's vector column."""

    kind = "graph"

    def __init__(self, r_degree: int = DEFAULT_R,
                 build_beam: int = DEFAULT_BUILD_BEAM, seed: int = 0):
        self.R = int(r_degree)
        self.build_beam = int(build_beam)
        self.seed = int(seed)
        self.neighbors: Optional[np.ndarray] = None   # (n, R) int32, -1 pad
        self.medoid = 0
        self.entries = np.zeros(0, np.int64)          # FPS seed rows
        self.vecs: Optional[np.ndarray] = None        # segment column ref
        # build-vs-merge accounting (tests assert the re-insertion bound)
        self.inserted_rows = 0
        self.donated_rows = 0

    # ------------------------------------------------------------ build
    def build(self, segment, column) -> None:
        """Vamana build: start from a random R-regular graph (an expander
        — navigable everywhere before any geometry exists), then refine
        every node in two passes, alpha=1.0 then alpha-relaxed.  Pure
        incremental insertion from a single medoid entry is NOT enough:
        on clustered data the build-time search gets stuck in the entry
        point's cluster, plants wrong-cluster edges, and the finished
        graph cannot descend into most clusters at all."""
        vecs = np.asarray(segment.columns[column.name], np.float32)
        self._init_arrays(vecs)
        n = len(vecs)
        if not n:
            return
        rng = np.random.default_rng(self.seed + n)
        if n > 1:
            init = rng.integers(0, n - 1, size=(n, self.R))
            init += init >= np.arange(n)[:, None]      # no self-loops
            self.neighbors[:] = init.astype(np.int32)
        self._built[:] = True
        self.inserted_rows = n
        self._set_medoid()
        self._pick_entries(np.arange(n))
        for alpha in (1.0, PRUNE_ALPHA):
            for i in rng.permutation(n):
                self._refine(int(i), alpha)
        self._ensure_reachable()

    def _init_arrays(self, vecs: np.ndarray) -> None:
        self.vecs = vecs
        self.neighbors = np.full((len(vecs), self.R), -1, np.int32)
        self.medoid = 0
        self.inserted_rows = 0
        self.donated_rows = 0
        self._built = np.zeros(len(vecs), bool)

    def _set_medoid(self) -> None:
        """Entry point = row nearest the column mean (squared L2)."""
        if self.vecs is None or not len(self.vecs):
            return
        mean = self.vecs.mean(axis=0)
        diff = self.vecs - mean
        self.medoid = int(np.argmin((diff * diff).sum(axis=1)))

    def _pick_entries(self, rows: np.ndarray) -> None:
        """Farthest-point-sample N_ENTRIES seed rows (starting nearest
        the medoid).  A single medoid entry is a navigability trap on
        clustered columns — greedy routing cannot always cross cluster
        gaps — while FPS provably lands a seed in every well-separated
        cluster, so the beam opens inside the right one."""
        rows = np.asarray(rows, np.int64)
        if not len(rows):
            self.entries = np.asarray([self.medoid], np.int64)
            return
        sub = self.vecs[rows]
        d = ((sub - self.vecs[self.medoid]) ** 2).sum(axis=1)
        chosen = [int(np.argmin(d))]
        dmin = ((sub - sub[chosen[0]]) ** 2).sum(axis=1)
        while len(chosen) < min(N_ENTRIES, len(rows)):
            nxt = int(np.argmax(dmin))
            chosen.append(nxt)
            dmin = np.minimum(dmin, ((sub - sub[nxt]) ** 2).sum(axis=1))
        self.entries = np.unique(rows[chosen])

    def _seed_rows(self) -> np.ndarray:
        """Seed set for a beam search: the FPS entries restricted to
        built rows, falling back to the medoid mid-insertion."""
        ent = self.entries
        if len(ent):
            ent = ent[self._built[ent]]
            if len(ent):
                return ent
        return np.asarray([self.medoid], np.int64)

    def _greedy(self, qv: np.ndarray, entry, L: int):
        """Best-first search over built rows, seeded with one or many
        entry rows; returns every visited row id with its squared
        distance, sorted ascending by (d2, id)."""
        vecs, nbrs = self.vecs, self.neighbors
        ent = np.unique(np.atleast_1d(np.asarray(entry, np.int64)))
        visited = np.zeros(len(vecs), bool)
        visited[ent] = True
        diff = vecs[ent] - qv
        cand_i = ent
        cand_d = (diff * diff).sum(axis=1).astype(np.float32)
        order = np.lexsort((cand_i, cand_d))
        cand_i, cand_d = cand_i[order], cand_d[order]
        expanded = np.zeros(len(vecs), bool)
        while True:
            head = cand_i[:L]
            todo = head[~expanded[head]]
            if not len(todo):
                break
            u = int(todo[0])
            expanded[u] = True
            nb = nbrs[u]
            nb = nb[nb >= 0]
            nb = nb[~visited[nb]]
            if len(nb):
                visited[nb] = True
                diff = vecs[nb] - qv
                d = (diff * diff).sum(axis=1).astype(np.float32)
                cand_i = np.concatenate([cand_i, nb])
                cand_d = np.concatenate([cand_d, d])
                order = np.lexsort((cand_i, cand_d))
                cand_i, cand_d = cand_i[order], cand_d[order]
        return cand_i, cand_d

    def _robust_prune(self, cand_i: np.ndarray, cand_d: np.ndarray,
                      alpha: float = PRUNE_ALPHA) -> np.ndarray:
        """Vamana robust prune: keep the nearest candidate, drop every
        other candidate it alpha-dominates, repeat up to R survivors.
        Inputs sorted ascending by distance; squared form throughout."""
        a2 = alpha * alpha
        out = []
        ids, d = cand_i, cand_d
        while len(ids) and len(out) < self.R:
            c = int(ids[0])
            out.append(c)
            diff = self.vecs[ids] - self.vecs[c]
            dc = (diff * diff).sum(axis=1)
            keep = a2 * dc > d
            keep[0] = False
            ids, d = ids[keep], d[keep]
        return np.asarray(out, np.int64)

    def _refine(self, i: int, alpha: float) -> None:
        """One Vamana refinement step: greedy-search candidates UNION the
        node's current out-edges -> robust prune -> bidirectional edges
        with overflow re-prune (all at the pass's alpha)."""
        cand_i, cand_d = self._greedy(self.vecs[i], self._seed_rows(),
                                      self.build_beam)
        cur = self.neighbors[i].astype(np.int64)
        cur = cur[cur >= 0]
        if len(cur):
            diff = self.vecs[cur] - self.vecs[i]
            cur_d = (diff * diff).sum(axis=1).astype(np.float32)
            cand_i = np.concatenate([cand_i, cur])
            cand_d = np.concatenate([cand_d, cur_d])
        sel = cand_i != i
        cand_i, cand_d = cand_i[sel], cand_d[sel]
        cand_i, first = np.unique(cand_i, return_index=True)
        cand_d = cand_d[first]
        order = np.lexsort((cand_i, cand_d))
        sel = self._robust_prune(cand_i[order], cand_d[order], alpha)
        self.neighbors[i] = -1
        self.neighbors[i, :len(sel)] = sel
        for j in sel:
            self._backlink(int(j), i, alpha)

    def _backlink(self, j: int, i: int, alpha: float) -> None:
        """Add edge j->i, re-pruning j's list when it overflows."""
        row = self.neighbors[j]
        if i in row:
            return
        free = np.nonzero(row < 0)[0]
        if len(free):
            row[free[0]] = i
            return
        cand = np.concatenate([row.astype(np.int64), [i]])
        diff = self.vecs[cand] - self.vecs[j]
        d = (diff * diff).sum(axis=1).astype(np.float32)
        order = np.lexsort((cand, d))
        pruned = self._robust_prune(cand[order], d[order], alpha)
        self.neighbors[j] = -1
        self.neighbors[j, :len(pruned)] = pruned

    def _insert(self, i: int) -> None:
        """Bounded insertion: greedy-search candidates -> robust prune ->
        bidirectional edges with overflow re-prune."""
        self.inserted_rows += 1
        if not self._built.any():
            self._built[i] = True
            self.medoid = i
            return
        cand_i, cand_d = self._greedy(self.vecs[i], self._seed_rows(),
                                      self.build_beam)
        sel = cand_i != i
        sel &= self._built[cand_i]
        sel = self._robust_prune(cand_i[sel], cand_d[sel])
        self.neighbors[i, :len(sel)] = sel
        self._built[i] = True
        for j in sel:
            self._backlink(int(j), i, PRUNE_ALPHA)

    # ------------------------------------------------------------ merge
    def merge(self, parts: Sequence["GraphIndex"], merged_seg, column,
              row_maps: Sequence[np.ndarray]) -> None:
        """Donation merge (mirrors ``quantize.merge_quantized``): the
        part with the most surviving rows donates its CSR, remapped
        through the compaction row maps; every other row is stitched in
        by the same bounded insertion build uses.  Never a from-scratch
        rebuild."""
        vecs = np.asarray(merged_seg.columns[column.name], np.float32)
        usable = all(p is not None and p.neighbors is not None
                     for p in parts)
        if not usable or not len(vecs):
            self.build(merged_seg, column)
            return
        survivors = [int((rmap >= 0).sum()) for rmap in row_maps]
        donor_i = int(np.argmax(survivors))
        donor, dmap = parts[donor_i], row_maps[donor_i]
        self.R = donor.R
        self._init_arrays(vecs)
        alive = dmap >= 0
        src = np.nonzero(alive)[0]
        if len(src):
            dst = dmap[src]
            nbr = donor.neighbors[src].astype(np.int64)
            valid = nbr >= 0
            safe = np.where(valid, nbr, 0)
            mapped = np.where(valid, dmap[safe], -1)
            mapped = np.where(mapped >= 0, mapped, -1)
            self.neighbors[dst] = mapped.astype(np.int32)
            self._built[dst] = True
            self.donated_rows = len(src)
            dm = dmap[donor.medoid]
            self.medoid = int(dm) if dm >= 0 else int(dst[0])
            # seed insertion searches from FPS entries over donor rows
            self._pick_entries(np.nonzero(self._built)[0])
        # foreign + new rows: everything the donor's map does not cover
        foreign = np.nonzero(~self._built)[0]
        rng = np.random.default_rng(self.seed + len(vecs))
        for i in rng.permutation(foreign):
            self._insert(int(i))
        self._set_medoid()
        self._pick_entries(np.arange(len(vecs)))
        self._ensure_reachable()

    def _reachable(self) -> np.ndarray:
        """Rows reachable from the seed set via out-edges (BFS)."""
        reach = np.zeros(len(self.vecs), bool)
        seeds = np.unique(np.concatenate(
            [[self.medoid], np.asarray(self.entries, np.int64)]))
        reach[seeds] = True
        frontier = seeds
        while len(frontier):
            nb = self.neighbors[frontier].ravel()
            nb = nb[nb >= 0]
            nb = np.unique(nb)
            nb = nb[~reach[nb]]
            reach[nb] = True
            frontier = nb
        return reach

    def _ensure_reachable(self, max_rounds: int = 16) -> None:
        """Repair connectivity: robust-prune drops backward edges freely,
        so a few rows end up with no in-edge path from the medoid and are
        invisible to every beam search.  Each round grafts every stranded
        row onto a near reachable host — a free out-degree slot when the
        host has one, otherwise evicting a neighbor only if that neighbor
        keeps at least two other in-edges (so a graft cannot strand
        someone else).  Rounds repeat until the BFS covers the graph."""
        n = len(self.vecs)
        if not n:
            return
        for _ in range(max_rounds):
            reach = self._reachable()
            miss = np.nonzero(~reach)[0]
            if not len(miss):
                return
            hosts = np.nonzero(reach)[0]
            flat = self.neighbors.ravel()
            indeg = np.bincount(flat[flat >= 0], minlength=n)
            for lo in range(0, len(miss), 256):
                chunk = miss[lo:lo + 256]
                diff = self.vecs[chunk][:, None, :] - \
                    self.vecs[hosts][None, :, :]
                d2 = (diff * diff).sum(axis=2)
                # analysis: allow[parity/raw-score-sort] host candidate
                # shortlist for edge grafting, not a rank ordering — ties
                # pick an arbitrary equally-near host, never a result row
                near = np.argsort(d2, axis=1)[:, :8]
                for mi, m in enumerate(chunk):
                    for hj in near[mi]:
                        row = self.neighbors[int(hosts[hj])]
                        free = np.nonzero(row < 0)[0]
                        if len(free):
                            row[free[0]] = int(m)
                            indeg[m] += 1
                            break
                        # full host: evict the most-redundant neighbor
                        safe = np.where(indeg[row] >= 3, indeg[row], -1)
                        if safe.max() < 0:
                            continue
                        slot = int(np.argmax(safe))
                        indeg[row[slot]] -= 1
                        row[slot] = int(m)
                        indeg[m] += 1
                        break

    # ------------------------------------------------------- persistence
    def to_arrays(self):
        """The CSR survives as-is; ``vecs`` is a reference into the
        segment column and is re-pointed at load, never duplicated."""
        return {"neighbors": np.asarray(self.neighbors, np.int32),
                "entries": np.asarray(self.entries, np.int64),
                "meta": np.asarray([self.medoid, self.R], np.int64)}

    def from_arrays(self, arrays, segment, column) -> None:
        self.neighbors = np.asarray(arrays["neighbors"], np.int32)
        self.entries = np.asarray(arrays["entries"], np.int64)
        self.medoid = int(arrays["meta"][0])
        self.R = int(arrays["meta"][1])
        self.vecs = np.asarray(segment.columns[column.name], np.float32)
        self._built = np.ones(len(self.vecs), bool)
        self.inserted_rows = 0
        self.donated_rows = len(self.vecs)

    # ------------------------------------------------------------ reads
    def search(self, q: np.ndarray, k: int, beam: Optional[int] = None):
        """Host-side greedy beam search -> (sqrt dists, rows, blocks)."""
        if self.neighbors is None or self.vecs is None \
                or not len(self.vecs):
            return (np.zeros(0, np.float32), np.zeros(0, np.int64), 0.0)
        L = max(int(beam or self.build_beam), k)
        cand_i, cand_d = self._greedy(np.asarray(q, np.float32),
                                      self._seed_rows(), L)
        cand_i, cand_d = cand_i[:k], cand_d[:k]
        blocks = 1.0 + len(np.unique(cand_i // BLOCK_ROWS))
        return (np.sqrt(np.maximum(cand_d, 0), dtype=np.float32),
                cand_i, blocks)

    def iterator(self, segment, qv):
        """Exact sorted access (NRA fallback): the graph orders its own
        beam, but NRA's bound bookkeeping needs globally sorted access —
        serve it exactly from the column."""
        diff = self.vecs - np.asarray(qv, np.float32)
        d = np.sqrt(np.maximum((diff * diff).sum(axis=1), 0),
                    dtype=np.float32)
        return ExactSortedAccess(d, np.arange(len(self.vecs),
                                              dtype=np.int64))

    def probe_cost_blocks(self, segment, predicate) -> float:
        gathered = min(segment.n_rows,
                       4 * self.build_beam * max(1, self.R))
        return 1.0 + gathered / BLOCK_ROWS


@dataclasses.dataclass
class PackedGraph:
    """Cross-segment CSR stack in packed row space (row-aligned with
    ``segment.pack_segments``): neighbor ids shifted by each segment's
    packed offset (-1 padding survives), every segment's medoid and FPS
    entry rows all seeds."""
    neighbors: np.ndarray    # (N, R) int32, -1 padded
    entries: np.ndarray      # (E,) int32 packed-space seed rows
    r_degree: int


def pack_graphs(segments: Sequence, col: str) -> Optional[PackedGraph]:
    """Stack per-segment graphs for the one-launch kernel; None when any
    non-empty segment lacks a built graph index (callers fall back to
    the exact fused scan)."""
    idxs, ns = [], []
    for s in segments:
        idx = s.indexes.get(col)
        if s.n_rows and (getattr(idx, "kind", None) != "graph"
                         or idx.neighbors is None):
            return None
        idxs.append(idx)
        ns.append(s.n_rows)
    if not ns or not sum(ns):
        return None
    r_deg = max((idx.R for idx, n in zip(idxs, ns) if n), default=1)
    offsets = np.cumsum([0] + ns)
    nbr = np.full((int(offsets[-1]), r_deg), -1, np.int32)
    entries = []
    for idx, n, off in zip(idxs, ns, offsets[:-1]):
        if not n:
            continue
        part = idx.neighbors
        shifted = np.where(part >= 0, part + np.int32(off), -1)
        nbr[off:off + n, :part.shape[1]] = shifted
        seeds = np.unique(np.concatenate(
            [[idx.medoid], np.asarray(idx.entries, np.int64)]))
        entries.extend(int(e) + int(off) for e in seeds)
    return PackedGraph(nbr, np.asarray(entries, np.int32), int(r_deg))
