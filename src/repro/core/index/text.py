"""Per-segment inverted text index ("Text IVF" — paper §4: "implemented in
a similar manner by replacing centroids with the corpus terms").

Level 1: term dictionary (term -> posting range); level 2: posting blocks
of (row, tf) pairs. contains() gives a bitmap; the BM25-ish iterator gives
sorted access for NRA text-relevance ranking (distance = 1 / (1 + score)
so smaller = more relevant, matching the ascending-distance contract).
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.index.base import ExactSortedAccess, SecondaryIndex
from repro.core.types import BLOCK_ROWS
from repro.core.wal import pack_object_array, unpack_object_array

_TOKEN_RE = re.compile(r"[a-z0-9]+")


def tokenize(text: str) -> List[str]:
    return _TOKEN_RE.findall(str(text).lower())


class InvertedTextIndex(SecondaryIndex):
    kind = "inverted"

    def __init__(self, k1: float = 1.2, b: float = 0.75):
        self.k1 = k1
        self.b = b
        self.postings: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        self.doc_len: Optional[np.ndarray] = None
        self.avg_len = 1.0
        self.n_docs = 0

    def build(self, segment, column) -> None:
        texts = segment.columns[column.name]
        self.n_docs = len(texts)
        lens = np.zeros(self.n_docs, np.float32)
        acc: Dict[str, Dict[int, int]] = {}
        for i, t in enumerate(texts):
            toks = tokenize(t)
            lens[i] = len(toks)
            for tok in toks:
                acc.setdefault(tok, {})
                acc[tok][i] = acc[tok].get(i, 0) + 1
        self.doc_len = lens
        self.avg_len = float(lens.mean()) if self.n_docs else 1.0
        for term, hits in acc.items():
            rows = np.fromiter(hits.keys(), np.int64, len(hits))
            tfs = np.fromiter(hits.values(), np.float32, len(hits))
            order = np.argsort(rows)
            self.postings[term] = (rows[order], tfs[order])

    def merge(self, parts, merged_seg, column, row_maps) -> None:
        """Posting-list merge: remap each part's postings through the
        compaction row maps (shadowed docs fall out as -1), concatenate
        per term, and re-sort by row id.  No re-tokenization — the cost
        is O(vocabulary + postings), not O(corpus tokens)."""
        self.n_docs = merged_seg.n_rows
        doc_len = np.zeros(self.n_docs, np.float32)
        acc: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
        for part, rmap in zip(parts, row_maps):
            if part.doc_len is not None and len(part.doc_len):
                survived = rmap >= 0
                # every merged row comes from exactly one part (the
                # winning version), so this scatter never collides
                doc_len[rmap[survived]] = part.doc_len[survived]
            for term, (rows, tfs) in part.postings.items():
                new_rows = rmap[rows]
                keep = new_rows >= 0
                if keep.any():
                    acc.setdefault(term, []).append(
                        (new_rows[keep], tfs[keep]))
        self.doc_len = doc_len
        self.avg_len = float(doc_len.mean()) if self.n_docs else 1.0
        self.postings = {}
        for term, chunks in acc.items():
            if len(chunks) == 1:
                rows, tfs = chunks[0]
            else:
                rows = np.concatenate([c[0] for c in chunks])
                tfs = np.concatenate([c[1] for c in chunks])
            order = np.argsort(rows)
            self.postings[term] = (rows[order], tfs[order])

    # -------------------------------------------------------- persistence
    def to_arrays(self):
        """Dictionary + postings flattened to flat arrays: sorted terms
        as an offsets+utf8 blob, per-term posting ranges, concatenated
        (row, tf) pairs."""
        terms = sorted(self.postings)
        term_offsets, term_blob = pack_object_array(
            np.asarray(terms, object))
        rows = [self.postings[t][0] for t in terms]
        tfs = [self.postings[t][1] for t in terms]
        post_offsets = np.zeros(len(terms) + 1, np.int64)
        np.cumsum([len(r) for r in rows], out=post_offsets[1:])
        return {
            "term_blob": term_blob,
            "term_offsets": term_offsets,
            "post_offsets": post_offsets,
            "post_rows": np.concatenate(rows).astype(np.int64)
            if rows else np.zeros(0, np.int64),
            "post_tfs": np.concatenate(tfs).astype(np.float32)
            if tfs else np.zeros(0, np.float32),
            "doc_len": np.asarray(
                self.doc_len if self.doc_len is not None else [],
                np.float32),
            "meta": np.asarray([self.avg_len, float(self.n_docs)],
                               np.float64),
        }

    def from_arrays(self, arrays, segment, column) -> None:
        terms = unpack_object_array(
            np.asarray(arrays["term_offsets"], np.int64),
            np.asarray(arrays["term_blob"], np.uint8), as_str=True)
        off = np.asarray(arrays["post_offsets"], np.int64)
        rows = np.asarray(arrays["post_rows"], np.int64)
        tfs = np.asarray(arrays["post_tfs"], np.float32)
        self.postings = {
            str(t): (rows[off[i]:off[i + 1]], tfs[off[i]:off[i + 1]])
            for i, t in enumerate(terms)}
        self.doc_len = np.asarray(arrays["doc_len"], np.float32)
        self.avg_len = float(arrays["meta"][0])
        self.n_docs = int(arrays["meta"][1])

    # ------------------------------------------------------------- access
    def bitmap(self, segment, predicate) -> np.ndarray:
        mask = np.zeros(segment.n_rows, bool)
        entry = self.postings.get(predicate.term.lower())
        if entry is not None:
            mask[entry[0]] = True
        return mask

    def _bm25(self, terms) -> Tuple[np.ndarray, np.ndarray]:
        scores: Dict[int, float] = {}
        for term in terms:
            entry = self.postings.get(term.lower())
            if entry is None:
                continue
            rows, tfs = entry
            df = len(rows)
            idf = math.log(1 + (self.n_docs - df + 0.5) / (df + 0.5))
            dl = self.doc_len[rows]
            tf_norm = tfs * (self.k1 + 1) / (
                tfs + self.k1 * (1 - self.b + self.b * dl / self.avg_len))
            for r, s in zip(rows, idf * tf_norm):
                scores[int(r)] = scores.get(int(r), 0.0) + float(s)
        if not scores:
            return np.zeros((0,), np.float32), np.zeros((0,), np.int64)
        rows = np.fromiter(scores.keys(), np.int64, len(scores))
        vals = np.fromiter(scores.values(), np.float32, len(scores))
        return vals, rows

    def iterator(self, segment, query) -> ExactSortedAccess:
        """Sorted access for NRA in EXACTLY the ``TextRank`` metric:
        d = 1 / (1 + 10 * Σ_t tf(t, doc) / (len(doc) + 1)), computed from
        the posting tfs and stored doc lengths with the same float64
        arithmetic ``rank_distances`` uses, then cast to float32 — so the
        distances NRA books as bounds ARE the distances refinement
        scores with.  (A BM25-ordered stream certifies bounds in a
        different metric and silently breaks the NRA winner-set
        guarantee.)  Rows matching no query term sit at the metric's
        ceiling 1.0 and are never yielded; stream exhaustion raises the
        modality bottom to dmax = 1.0, which is their exact distance."""
        terms = query if isinstance(query, (list, tuple)) else [query]
        tf_sum: Dict[int, float] = {}
        for term in terms:                  # duplicates count twice, as in
            entry = self.postings.get(str(term).lower())  # rank_distances
            if entry is None:
                continue
            for r, tf in zip(*entry):
                tf_sum[int(r)] = tf_sum.get(int(r), 0.0) + float(tf)
        if not tf_sum:
            return ExactSortedAccess(np.zeros((0,), np.float32),
                                     np.zeros((0,), np.int64))
        rows = np.fromiter(tf_sum.keys(), np.int64, len(tf_sum))
        tfs = np.fromiter(tf_sum.values(), np.float64, len(tf_sum))
        score = tfs / (self.doc_len[rows].astype(np.float64) + 1.0)
        dist = (1.0 / (1.0 + score * 10.0)).astype(np.float32)
        return ExactSortedAccess(dist, rows)

    # ---------------------------------------------------------- optimizer
    def selectivity(self, segment, predicate) -> float:
        if segment.n_rows == 0:
            return 0.0
        entry = self.postings.get(predicate.term.lower())
        return (len(entry[0]) / segment.n_rows) if entry is not None else 0.0

    def probe_cost_blocks(self, segment, predicate) -> float:
        entry = self.postings.get(predicate.term.lower())
        n = len(entry[0]) if entry is not None else 0
        return 1.0 + n / BLOCK_ROWS           # dictionary + posting blocks
