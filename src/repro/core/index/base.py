"""Unified secondary index interface (paper §4, Challenge #1).

Every modality index — vector IVF, spatial Z-order, text inverted, scalar
btree — implements the same contract:

  build(segment, column)            index construction at SST-build time
  bitmap(segment, predicate)        -> bool mask over segment rows
  iterator(segment, query)          -> SortedAccess yielding (dist, rows)
                                       blocks in ascending distance order
  stats()                           -> selectivity inputs for the optimizer

The standardized sorted ``Next()`` access is what enables the NRA
aggregation across modalities (paper Algorithm 1): ARCADE's key interface
unification.
"""
from __future__ import annotations

import abc
import heapq
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


class SortedAccess(abc.ABC):
    """Sorted access stream over one segment: blocks of (distance, row_id)
    pairs in globally ascending distance order.

    TPU adaptation: ``next_block`` yields a *block* at a time (vectorized
    bound updates in the NRA loop) rather than one row; bound semantics are
    preserved because every yielded distance is >= all previously yielded
    distances (see DESIGN.md §8.1).
    """

    @abc.abstractmethod
    def next_block(self) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Returns (distances ascending, row_ids) or None when exhausted."""

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            blk = self.next_block()
            if blk is None:
                return
            yield blk


class ExactSortedAccess(SortedAccess):
    """Sorted access over fully-materialized (dist, row) arrays."""

    def __init__(self, dists: np.ndarray, rows: np.ndarray,
                 block: int = 128):
        # (score, row) comparator: deterministic tie order for NRA's
        # sorted-access streams regardless of producer ordering
        order = np.lexsort((np.asarray(rows), np.asarray(dists)))
        self._d = np.asarray(dists)[order]
        self._r = np.asarray(rows)[order]
        self._i = 0
        self._block = block

    def next_block(self):
        if self._i >= len(self._d):
            return None
        j = min(self._i + self._block, len(self._d))
        out = (self._d[self._i:j], self._r[self._i:j])
        self._i = j
        return out


class MergedSortedAccess(SortedAccess):
    """Heap-merge of per-segment sorted streams into one global stream —
    the paper's 'top-level merging iterator using a priority queue'.
    Yields (dists, global_keys) where keys are (seg_id, row) encoded by
    ``key_fn``.

    The merged stream must be *globally* non-decreasing (NRA's bound
    bookkeeping relies on it), so when a block is popped only the prefix
    not exceeding the next-smallest stream head is emitted; the remainder
    is pushed back keyed by its new first element.
    """

    def __init__(self, streams: List[Tuple[int, SortedAccess]],
                 key_fn=None):
        self._heap: List[Tuple[float, int, int, np.ndarray, np.ndarray]] = []
        self._streams = dict(streams)
        self._key_fn = key_fn or (lambda sid, rows: rows)
        self._counter = 0
        for sid, st in streams:
            self._pull(sid)

    def _pull(self, sid: int):
        blk = self._streams[sid].next_block()
        if blk is not None:
            d, r = blk
            self._push_buf(sid, d, r)

    def _push_buf(self, sid: int, d: np.ndarray, r: np.ndarray):
        if len(d):
            self._counter += 1
            heapq.heappush(self._heap,
                           (float(d[0]), self._counter, sid, d, r))

    def next_block(self):
        if not self._heap:
            return None
        _, _, sid, d, r = heapq.heappop(self._heap)
        bound = self._heap[0][0] if self._heap else np.inf
        cut = int(np.searchsorted(d, bound, side="right"))
        cut = max(cut, 1)                  # d[0] <= bound by heap order
        rest_d, rest_r = d[cut:], r[cut:]
        if len(rest_d):
            self._push_buf(sid, rest_d, rest_r)
        else:
            self._pull(sid)
        return d[:cut], self._key_fn(sid, r[:cut])


def merge_sorted_runs(vals_list: List[np.ndarray],
                      rows_list: List[np.ndarray]
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized k-way merge of pre-sorted (value, row) runs.

    Each run is merged in via searchsorted rank arithmetic — the final
    position of ``a[i]`` is ``i + #{b < a[i]}`` — which is O(n log m)
    with no Python-level per-element loop (the sorted-run analog of the
    LSM merge itself; used by the mergeable scalar index).
    """
    pairs = [(v, r) for v, r in zip(vals_list, rows_list) if len(v)]
    if not pairs:
        return np.zeros(0, np.float64), np.zeros(0, np.int64)
    av, ar = pairs[0]
    for bv, br in pairs[1:]:
        pa = np.searchsorted(bv, av, side="left") + np.arange(len(av))
        pb = np.searchsorted(av, bv, side="right") + np.arange(len(bv))
        ov = np.empty(len(av) + len(bv), av.dtype)
        orr = np.empty(len(ar) + len(br), ar.dtype)
        ov[pa], ov[pb] = av, bv
        orr[pa], orr[pb] = ar, br
        av, ar = ov, orr
    return av, ar


class SecondaryIndex(abc.ABC):
    kind: str = "abstract"

    @abc.abstractmethod
    def build(self, segment, column) -> None:
        ...

    def merge(self, parts: List["SecondaryIndex"], merged_seg, column,
              row_maps: List[np.ndarray]) -> None:
        """Compaction-aware construction (paper §4): populate this index
        for ``merged_seg`` from the source segments' already-built
        indexes instead of rebuilding from raw columns.

        ``parts`` are the source indexes (one per merged segment, same
        order as the merge) and ``row_maps[i]`` maps source segment i's
        row ids to merged rows (-1 = dropped by the merge).  The default
        falls back to a fresh ``build`` — subclasses override with a
        cheaper structural merge (posting-list remap, sorted-run merge,
        Z-order re-sort, centroid reuse).
        """
        self.build(merged_seg, column)

    # persistence -----------------------------------------------------------
    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Serialize the *built* state to named numpy arrays — the
        segment file's pickle-free on-disk contract (core/segment.py
        stores them under ``idx.<column>.<key>``). Hyperparameters
        (n_probe, R, ...) are NOT persisted: they are serving policy and
        come from the index factory at load time."""
        raise NotImplementedError(f"{self.kind} is not persistable")

    def from_arrays(self, arrays: Dict[str, np.ndarray],
                    segment, column) -> None:
        """Restore built state from ``to_arrays`` output onto a
        factory-fresh instance. ``segment`` supplies the raw columns
        for indexes that keep references into them (the graph's vecs)."""
        raise NotImplementedError(f"{self.kind} is not persistable")

    def bitmap(self, segment, predicate) -> np.ndarray:
        raise NotImplementedError(f"{self.kind} has no bitmap access")

    def iterator(self, segment, query) -> SortedAccess:
        raise NotImplementedError(f"{self.kind} has no sorted access")

    # optimizer hooks --------------------------------------------------------
    def selectivity(self, segment, predicate) -> float:
        """Estimated fraction of rows passing ``predicate``."""
        return 1.0

    def probe_cost_blocks(self, segment, predicate) -> float:
        """Estimated #blocks touched to answer ``predicate`` via this index."""
        return segment.n_blocks
