"""Global (in-RAM) index level: maps value ranges -> per-segment index
blocks (paper §4: "The global index, organized as a multi-level tree, maps
secondary value ranges to SST index blocks. This design enables efficient
SST file pruning and direct query routing").

One GlobalIndex per indexed column; entries are per-segment summaries
(zone maps: scalar min/max, spatial bbox, vector centroid cloud radius,
text term Bloom-ish set). ``prune`` returns only the segments whose
summary intersects the predicate — segments never touched never cost I/O.
"""
from __future__ import annotations

from typing import Any, Dict, List

import numpy as np

from repro.core.types import ColumnType


class GlobalIndex:
    def __init__(self, column):
        self.column = column
        # seg_id -> summary
        self.summaries: Dict[int, Any] = {}

    # ---------------------------------------------------------- maintain
    def add_segment(self, segment) -> None:
        name = self.column.name
        ct = self.column.ctype
        if ct == ColumnType.SCALAR:
            v = np.asarray(segment.columns[name], np.float64)
            self.summaries[segment.seg_id] = (float(v.min()), float(v.max())) \
                if len(v) else (np.inf, -np.inf)
        elif ct == ColumnType.SPATIAL:
            p = np.asarray(segment.columns[name], np.float32)
            self.summaries[segment.seg_id] = (
                (float(p[:, 0].min()), float(p[:, 1].min()),
                 float(p[:, 0].max()), float(p[:, 1].max()))
                if len(p) else (np.inf, np.inf, -np.inf, -np.inf))
        elif ct == ColumnType.VECTOR:
            idx = segment.indexes.get(name)
            cents = getattr(idx, "centroids", None)
            self.summaries[segment.seg_id] = cents
        elif ct == ColumnType.TEXT:
            idx = segment.indexes.get(name)
            terms = set(getattr(idx, "postings", {}).keys())
            self.summaries[segment.seg_id] = terms

    def drop_segment(self, seg_id: int) -> None:
        self.summaries.pop(seg_id, None)

    # ------------------------------------------------------------- prune
    def prune(self, segments, predicate) -> List:
        """Segments possibly containing matches for ``predicate``."""
        from repro.core import query as q
        out = []
        for seg in segments:
            s = self.summaries.get(seg.seg_id)
            if s is None:
                out.append(seg)          # no summary: cannot prune
                continue
            if isinstance(predicate, q.Range):
                lo, hi = s
                if not (predicate.hi < lo or predicate.lo > hi):
                    out.append(seg)
            elif isinstance(predicate, q.GeoWithin):
                xmin, ymin, xmax, ymax = s
                qx0, qy0, qx1, qy1 = predicate.rect
                if not (qx1 < xmin or qx0 > xmax or qy1 < ymin or qy0 > ymax):
                    out.append(seg)
            elif isinstance(predicate, q.TextContains):
                if predicate.term.lower() in s:
                    out.append(seg)
            elif isinstance(predicate, q.VectorRange):
                cents = s
                if cents is None or len(cents) == 0:
                    out.append(seg)
                    continue
                d2 = ((cents - predicate.q[None, :]) ** 2).sum(1)
                # conservative: centroid within thresh + cloud slack;
                # compared in squared form — no sqrt on the prune path
                lim = predicate.thresh * 2.0 + 1.0
                if float(d2.min()) <= lim * lim:
                    out.append(seg)
            else:
                out.append(seg)
        return out


class GlobalIndexSet:
    """All global indexes of a store; kept in sync on flush/compaction."""

    def __init__(self, schema):
        self.schema = schema
        self.by_col: Dict[str, GlobalIndex] = {
            c.name: GlobalIndex(c) for c in schema.indexed_columns}

    def on_new_segment(self, segment) -> None:
        for gi in self.by_col.values():
            gi.add_segment(segment)

    def on_drop_segment(self, seg_id: int) -> None:
        for gi in self.by_col.values():
            gi.drop_segment(seg_id)

    def prune(self, segments, predicate) -> List:
        col = getattr(predicate, "col", None)
        gi = self.by_col.get(col)
        if gi is None:
            return list(segments)
        return gi.prune(segments, predicate)
