"""Unified secondary index framework (paper §4)."""
from __future__ import annotations

from repro.core.index.base import (  # noqa: F401
    ExactSortedAccess, MergedSortedAccess, SecondaryIndex, SortedAccess)
from repro.core.index.global_index import GlobalIndex, GlobalIndexSet  # noqa: F401
from repro.core.index.graph import GraphIndex, PackedGraph, pack_graphs  # noqa: F401
from repro.core.index.ivf import IVFIndex
from repro.core.index.scalar import ScalarIndex
from repro.core.index.spatial import ZOrderIndex
from repro.core.index.text import InvertedTextIndex
from repro.core.types import Column, IndexKind


def default_index_factory(column: Column):
    """Map a column's declared index kind to its implementation."""
    k = column.index
    if k == IndexKind.BTREE:
        return ScalarIndex()
    if k == IndexKind.IVF:
        return IVFIndex()
    if k == IndexKind.PQIVF:
        return IVFIndex(use_pq=True)
    if k == IndexKind.GRAPH:
        return GraphIndex()
    if k == IndexKind.ZORDER:
        return ZOrderIndex()
    if k == IndexKind.INVERTED:
        return InvertedTextIndex()
    return None
