"""Per-store manifest: the atomic commit point for durable state.

A store directory looks like::

    <root>/
      manifest-<gen>.json      # generation-numbered store state
      segments/seg-<id>.npz    # columnar segment files (core/segment.py)
      wal/wal-<seqno>.log      # rotated WAL files (core/wal.py)

The manifest is the only coordination point: a segment file exists
*durably* the moment the manifest that references it is renamed into
place. Publish protocol (classic write-temp/fsync/rename, matching
Arc's segment registration in SNIPPETS.md):

    1. write ``manifest-<gen+1>.json.tmp``, flush + fsync the file
    2. ``os.replace`` tmp -> ``manifest-<gen+1>.json``  (atomic)
    3. fsync the directory (the rename itself becomes durable)
    4. delete generations older than the previous one

A crash anywhere before step 2 leaves the previous manifest intact and
at most a tmp/orphan segment file behind; a crash between 2 and 3 can
lose the *new* generation on some filesystems but never the old one.
Recovery loads the highest parseable generation and garbage-collects
segment files it does not reference (orphans from crashed flushes).

State carried per generation: schema, segment list (file, level,
row count, max seqno), the durable seqno frontier (max seqno captured
in any flushed segment — WAL replay starts past it), writer counters
(next seqno / unique-pk stats) and the PQ codebook assignment per
column so quantized residence survives restart.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Tuple

from repro.core.faults import NO_FAULTS, FaultInjector
from repro.core.types import Column, ColumnType, IndexKind, Schema

MANIFEST_PREFIX = "manifest-"
SEGMENTS_DIR = "segments"
WAL_DIR = "wal"
FORMAT_VERSION = 1


def schema_to_json(schema: Schema) -> List[Dict[str, Any]]:
    return [{"name": c.name, "ctype": c.ctype.name, "dim": c.dim,
             "index": c.index.name,
             "spatial_index_type": c.spatial_index_type}
            for c in schema.columns]


def schema_from_json(cols: List[Dict[str, Any]]) -> Schema:
    return Schema([Column(c["name"], ColumnType[c["ctype"]],
                          dim=c["dim"], index=IndexKind[c["index"]],
                          spatial_index_type=c["spatial_index_type"])
                   for c in cols])


def fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_json(path: str, obj: Any) -> None:
    """Durable small-file write: temp + fsync + atomic rename + dir
    fsync. Used for the facade's db.json as well as manifests."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(os.path.dirname(path) or ".")


class StoreDir:
    """Layout + manifest publish/load for one store's directory."""

    def __init__(self, root: str):
        self.root = root
        self.segments_dir = os.path.join(root, SEGMENTS_DIR)
        self.wal_dir = os.path.join(root, WAL_DIR)
        os.makedirs(self.segments_dir, exist_ok=True)
        os.makedirs(self.wal_dir, exist_ok=True)
        self.generation = self._latest_generation()

    # ------------------------------------------------------------ paths
    def segment_path(self, seg_id: int) -> str:
        return os.path.join(self.segments_dir, f"seg-{seg_id:08d}.npz")

    def _manifest_path(self, gen: int) -> str:
        return os.path.join(self.root, f"{MANIFEST_PREFIX}{gen:08d}.json")

    def _generations(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith(MANIFEST_PREFIX) and name.endswith(".json"):
                out.append(int(name[len(MANIFEST_PREFIX):-5]))
        return sorted(out)

    def _latest_generation(self) -> int:
        gens = self._generations()
        return gens[-1] if gens else 0

    # ---------------------------------------------------------- publish
    def publish(self, state: Dict[str, Any],
                faults: FaultInjector = NO_FAULTS) -> int:
        """Atomically commit ``state`` as the next generation; returns
        the new generation number. Crash points bracket the rename so
        the recovery matrix can land on either side of the commit."""
        gen = self.generation + 1
        state = dict(state, version=FORMAT_VERSION, generation=gen)
        final = self._manifest_path(gen)
        tmp = final + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f, indent=1, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        faults.crash("manifest.publish")
        os.replace(tmp, final)
        faults.crash("manifest.after-rename")
        fsync_dir(self.root)
        self.generation = gen
        # keep the previous generation as a safety net, drop the rest
        for old in self._generations():
            if old < gen - 1:
                try:
                    os.remove(self._manifest_path(old))
                except OSError:
                    pass
        return gen

    # ------------------------------------------------------------- load
    def load_latest(self) -> Optional[Dict[str, Any]]:
        """Highest parseable generation (a crash between fsync(file) and
        dir-fsync can leave a truncated or missing newest file — fall
        back one generation rather than fail)."""
        for gen in reversed(self._generations()):
            try:
                with open(self._manifest_path(gen)) as f:
                    state = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            self.generation = gen
            return state
        return None

    # --------------------------------------------------------------- gc
    def gc_orphans(self, live_files: List[str]) -> List[str]:
        """Remove segment files (and stale tmps) not referenced by the
        loaded manifest — debris from flushes/compactions that crashed
        before their publish. Returns removed names."""
        live = set(live_files)
        removed = []
        for name in sorted(os.listdir(self.segments_dir)):
            if name in live:
                continue
            try:
                os.remove(os.path.join(self.segments_dir, name))
                removed.append(name)
            except OSError:
                pass
        for name in os.listdir(self.root):
            if name.endswith(".json.tmp"):
                try:
                    os.remove(os.path.join(self.root, name))
                except OSError:
                    pass
        return removed


def segment_entry(seg) -> Dict[str, Any]:
    """Manifest record for one flushed segment."""
    return {"file": f"seg-{seg.seg_id:08d}.npz", "seg_id": int(seg.seg_id),
            "level": int(seg.level), "n_rows": int(seg.n_rows),
            "max_seqno": int(seg.seqno.max()) if seg.n_rows else -1}
