"""Write buffer (memtable) — host-side append store, the skip-list analog.

Writes are O(1) appends with a monotonically increasing seqno; the LSM
store flushes the memtable to an immutable Segment (and builds its
per-segment indexes) once ``flush_rows`` is reached. Reads over the
memtable are brute-force — it is small and RAM-resident by construction,
exactly like RocksDB's write buffer.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.types import ColumnType, Schema, validate_batch


class MemTable:
    def __init__(self, schema: Schema):
        self.schema = schema
        self._pk: List[int] = []
        self._seqno: List[int] = []
        self._tomb: List[bool] = []
        self._cols: Dict[str, List[Any]] = {c.name: [] for c in schema.columns}
        # newest row index per key for O(1) point reads
        self._latest: Dict[int, int] = {}
        # scan_arrays() memo — every read path materializes the same
        # columnar view; cleared on write (flush swaps the instance)
        self._scan_cache = None

    def __len__(self) -> int:
        return len(self._pk)

    @property
    def approx_bytes(self) -> int:
        n = len(self._pk)
        per_row = 16
        for c in self.schema.columns:
            if c.ctype == ColumnType.VECTOR:
                per_row += 4 * c.dim
            elif c.ctype == ColumnType.SPATIAL:
                per_row += 8
            else:
                per_row += 24
        return n * per_row

    def put_batch(self, pks, batch: Dict[str, Any], seqno_start: int,
                  tombstone: bool = False) -> int:
        """Append rows; returns the next unused seqno."""
        n = validate_batch(self.schema, batch) if not tombstone else len(pks)
        self._scan_cache = None
        seq = seqno_start
        for i in range(len(pks)):
            self._latest[int(pks[i])] = len(self._pk)
            self._pk.append(int(pks[i]))
            self._seqno.append(seq)
            self._tomb.append(tombstone)
            for c in self.schema.columns:
                if tombstone:
                    self._cols[c.name].append(_null_for(c))
                else:
                    self._cols[c.name].append(batch[c.name][i])
            seq += 1
        return seq

    def get(self, key: int) -> Optional[Dict[str, Any]]:
        i = self._latest.get(int(key))
        if i is None:
            return None
        row = {"_pk": self._pk[i], "_seqno": self._seqno[i],
               "_tombstone": self._tomb[i]}
        for name, vals in self._cols.items():
            row[name] = vals[i]
        return row

    def scan_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   Dict[str, np.ndarray]]:
        """Materialize as columnar arrays (for flush or brute-force read).
        Memoized until the next write; callers must not mutate."""
        if self._scan_cache is not None:
            return self._scan_cache
        pk = np.asarray(self._pk, np.int64)
        seqno = np.asarray(self._seqno, np.int64)
        tomb = np.asarray(self._tomb, bool)
        cols = {}
        for c in self.schema.columns:
            vals = self._cols[c.name]
            if c.ctype == ColumnType.VECTOR:
                cols[c.name] = np.asarray(vals, np.float32).reshape(
                    len(vals), c.dim) if vals else np.zeros((0, c.dim),
                                                            np.float32)
            elif c.ctype == ColumnType.SPATIAL:
                cols[c.name] = np.asarray(vals, np.float32).reshape(
                    len(vals), 2) if vals else np.zeros((0, 2), np.float32)
            elif c.ctype == ColumnType.SCALAR:
                cols[c.name] = np.asarray(vals, np.float64)
            else:
                cols[c.name] = np.asarray(vals, object)
        self._scan_cache = (pk, seqno, tomb, cols)
        return self._scan_cache


def _null_for(c):
    if c.ctype == ColumnType.VECTOR:
        return np.zeros((c.dim,), np.float32)
    if c.ctype == ColumnType.SPATIAL:
        return np.zeros((2,), np.float32)
    if c.ctype == ColumnType.SCALAR:
        return 0.0
    return ""
