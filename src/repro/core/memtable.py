"""Write buffer (memtable) — columnar append store, the skip-list analog.

Storage is *chunked columnar*: every ``put_batch`` appends whole numpy
arrays (one chunk per batch) instead of looping rows/columns in Python,
so the write critical path is O(#columns) array conversions per batch —
never O(rows).  ``scan_arrays`` concatenates the chunks once and memoizes
the result; point reads binary-search the chunk offsets.

The LSM store seals the memtable (hands it to the flush scheduler) once
``flush_rows`` / ``flush_bytes`` is reached; reads over the memtable are
brute-force — it is small and RAM-resident by construction, exactly like
RocksDB's write buffer.
"""
from __future__ import annotations

import bisect
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.types import Column, ColumnType, Schema, validate_batch

# fixed per-row overhead: pk (8) + seqno (8) + tombstone flag
_ROW_OVERHEAD = 17
# per-string object overhead on top of the payload
_STR_OVERHEAD = 16


def as_column_array(c: Column, values, n: Optional[int] = None
                    ) -> np.ndarray:
    """Canonical numpy representation of one column of a batch."""
    if c.ctype == ColumnType.VECTOR:
        arr = np.asarray(values, np.float32)
        return arr.reshape(len(arr), c.dim) if arr.size else \
            np.zeros((n or 0, c.dim), np.float32)
    if c.ctype == ColumnType.SPATIAL:
        arr = np.asarray(values, np.float32)
        return arr.reshape(len(arr), 2) if arr.size else \
            np.zeros((n or 0, 2), np.float32)
    if c.ctype == ColumnType.SCALAR:
        return np.asarray(values, np.float64)
    arr = np.asarray(values, object)           # TEXT / BLOB
    return arr


def _null_chunk(c: Column, n: int) -> np.ndarray:
    if c.ctype == ColumnType.VECTOR:
        return np.zeros((n, c.dim), np.float32)
    if c.ctype == ColumnType.SPATIAL:
        return np.zeros((n, 2), np.float32)
    if c.ctype == ColumnType.SCALAR:
        return np.zeros(n, np.float64)
    return np.full(n, "", object)


def _empty_columns(schema: Schema) -> Dict[str, np.ndarray]:
    return {c.name: _null_chunk(c, 0) for c in schema.columns}


def _var_chunk_bytes(arr: np.ndarray) -> int:
    """Actual payload size of one TEXT/BLOB chunk (by content, not a
    flat per-row constant — flush-by-bytes depends on this)."""
    return int(sum(len(v) if isinstance(v, (str, bytes)) else
                   len(str(v)) for v in arr)) + _STR_OVERHEAD * len(arr)


class MemTable:
    def __init__(self, schema: Schema):
        self.schema = schema
        self._pk_chunks: List[np.ndarray] = []
        self._seq_chunks: List[np.ndarray] = []
        self._tomb_chunks: List[np.ndarray] = []
        self._col_chunks: Dict[str, List[np.ndarray]] = \
            {c.name: [] for c in schema.columns}
        self._starts: List[int] = [0]      # chunk start offsets (+ total)
        # newest row index per key for O(1) point reads
        self._latest: Dict[int, int] = {}
        self._bytes = 0                    # fixed-width payload (eager)
        # TEXT/BLOB payloads are summed lazily in ``approx_bytes`` (the
        # O(rows) len() walk must never run on the write critical path)
        self._var_cols = [c.name for c in schema.columns
                          if c.ctype in (ColumnType.TEXT, ColumnType.BLOB)]
        self._var_bytes = 0
        self._var_counted: Dict[str, int] = {n: 0 for n in self._var_cols}
        # scan_arrays() memo — every read path materializes the same
        # columnar view; cleared on write (flush swaps the instance)
        self._scan_cache = None

    def __len__(self) -> int:
        return self._starts[-1]

    @property
    def approx_bytes(self) -> int:
        # catch up on variable-width chunks appended since the last call
        for name in self._var_cols:
            chunks = self._col_chunks[name]
            for ci in range(self._var_counted[name], len(chunks)):
                self._var_bytes += _var_chunk_bytes(chunks[ci])
            self._var_counted[name] = len(chunks)
        return self._bytes + self._var_bytes + _ROW_OVERHEAD * len(self)

    def put_batch(self, pks, batch: Dict[str, Any], seqno_start: int,
                  tombstone: bool = False) -> int:
        """Append a columnar batch as one chunk; returns the next unused
        seqno.  O(#columns) array appends — no per-row loop."""
        n = validate_batch(self.schema, batch) if not tombstone else len(pks)
        if n == 0:
            return seqno_start
        self._scan_cache = None
        pk = np.asarray(pks, np.int64)
        base = self._starts[-1]
        self._pk_chunks.append(pk)
        self._seq_chunks.append(
            np.arange(seqno_start, seqno_start + n, dtype=np.int64))
        self._tomb_chunks.append(np.full(n, tombstone, bool))
        for c in self.schema.columns:
            arr = _null_chunk(c, n) if tombstone else \
                as_column_array(c, batch[c.name], n)
            self._col_chunks[c.name].append(arr)
            if c.ctype not in (ColumnType.TEXT, ColumnType.BLOB):
                self._bytes += int(arr.nbytes)      # O(1), no row walk
        self._starts.append(base + n)
        # one C-level dict update: pk -> newest global row index
        self._latest.update(zip(pk.tolist(), range(base, base + n)))
        return seqno_start + n

    def _locate(self, i: int) -> Tuple[int, int]:
        """Global row index -> (chunk id, offset within chunk)."""
        ci = bisect.bisect_right(self._starts, i) - 1
        return ci, i - self._starts[ci]

    def get(self, key: int) -> Optional[Dict[str, Any]]:
        i = self._latest.get(int(key))
        if i is None:
            return None
        ci, off = self._locate(i)
        row = {"_pk": int(self._pk_chunks[ci][off]),
               "_seqno": int(self._seq_chunks[ci][off]),
               "_tombstone": bool(self._tomb_chunks[ci][off])}
        for name, chunks in self._col_chunks.items():
            row[name] = chunks[ci][off]
        return row

    def scan_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   Dict[str, np.ndarray]]:
        """Materialize as columnar arrays (for flush or brute-force read):
        one concatenation per column.  Memoized until the next write;
        callers must not mutate."""
        if self._scan_cache is not None:
            return self._scan_cache
        if not self._pk_chunks:
            empty = (np.zeros(0, np.int64), np.zeros(0, np.int64),
                     np.zeros(0, bool), _empty_columns(self.schema))
            self._scan_cache = empty
            return empty
        pk = np.concatenate(self._pk_chunks)
        seqno = np.concatenate(self._seq_chunks)
        tomb = np.concatenate(self._tomb_chunks)
        cols = {name: np.concatenate(chunks)
                for name, chunks in self._col_chunks.items()}
        self._scan_cache = (pk, seqno, tomb, cols)
        return self._scan_cache


def concat_memtable_arrays(parts: List[Tuple[np.ndarray, np.ndarray,
                                             np.ndarray,
                                             Dict[str, np.ndarray]]],
                           schema: Schema):
    """Stack several memtables' scan_arrays into one logical view (sealed
    memtables awaiting flush + the active one, oldest first)."""
    parts = [p for p in parts if len(p[0])]
    if not parts:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, bool), _empty_columns(schema))
    if len(parts) == 1:
        return parts[0]
    pk = np.concatenate([p[0] for p in parts])
    seqno = np.concatenate([p[1] for p in parts])
    tomb = np.concatenate([p[2] for p in parts])
    cols = {c.name: np.concatenate([p[3][c.name] for p in parts])
            for c in schema.columns}
    return pk, seqno, tomb, cols
