"""Quantized residence tier: PQ codes stored alongside every vector rank
column (paper §4's IVF+PQ pairing, promoted from index detail to a
storage-level property).

At flush time each vector column is PQ-encoded (m subquantizers, uint8
codes) next to the full-precision column; the fused quantized scan
(``kernels/quantized_scan.py``) streams the code matrix — m bytes/row
instead of 4*d — for candidate generation, then the survivors are
re-ranked exactly against the fp32 column.

Codebook lifecycle mirrors ``IVFIndex.merge``'s donation rule:

  * the store trains codebooks ONCE per column (first flush) and reuses
    them for every later flush, so cross-segment packing sees a single
    shared book (``book_id``) and LUTs are computed once per query;
  * at compaction the largest part donates its codebooks; donor rows keep
    their codes verbatim through the compaction row maps and only rows
    from foreign-book parts are re-encoded (one assignment pass — never a
    k-means retrain).

Everything here is plain numpy on purpose: flush/compaction run on the
ingest path and must not touch the kernel-dispatch accounting
(``kernels.ops.stats_snapshot``) that read-path tests and benchmarks
meter.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence

import numpy as np

DEFAULT_PQ_M = 8
# training is sampled + few-iter: codebooks only steer candidate
# generation, the exact re-rank restores full precision
TRAIN_SAMPLE = 1024
TRAIN_ITERS = 4
# pad value for unused codeword slots: large enough never to win an
# assignment, small enough that its squared LUT entry stays finite in
# fp32 (1e15**2 = 1e30 << fp32 max) — inf LUT entries would turn the
# one-hot matmul's 0*inf lanes into NaN
PAD_CENTROID = np.float32(1e15)

_book_ids = itertools.count(1)


def fresh_book_id() -> int:
    """Allocate a new shared-codebook identity (recovery re-keys loaded
    segments' codes with one of these per column, since saved book ids
    from a dead process mean nothing here)."""
    return next(_book_ids)


@dataclasses.dataclass
class QuantizedColumn:
    """PQ residence for one segment column, in segment row order."""
    codes: np.ndarray       # (n, m) uint8
    codebooks: np.ndarray   # (m, 256, dsub) fp32, padded with PAD_CENTROID
    book_id: int            # shared-codebook identity (packability gate)

    @property
    def m(self) -> int:
        return int(self.codes.shape[1])

    def decode(self) -> np.ndarray:
        """Reconstruct (n, d) fp32 from codes — test/debug helper."""
        n, m = self.codes.shape
        dsub = self.codebooks.shape[2]
        out = np.empty((n, m * dsub), np.float32)
        for j in range(m):
            out[:, j * dsub:(j + 1) * dsub] = \
                self.codebooks[j][self.codes[:, j].astype(np.int64)]
        return out


def subquantizers(d: int, m: int = DEFAULT_PQ_M) -> int:
    """Largest m' <= m with d % m' == 0 (same halving rule as IVF PQ)."""
    m = min(m, d)
    while m > 1 and d % m:
        m //= 2
    return max(1, m)


def _kmeans256(x: np.ndarray, seed: int) -> np.ndarray:
    """(256, dsub) codebook for one subspace: sampled gemm k-means,
    unused slots padded with PAD_CENTROID."""
    n, dsub = x.shape
    rng = np.random.default_rng(seed)
    k = min(256, n)
    cents = x[rng.choice(n, size=k, replace=False)].astype(np.float32)
    for _ in range(TRAIN_ITERS):
        assign = _assign(x, cents)
        for j in range(k):
            sel = assign == j
            if sel.any():
                cents[j] = x[sel].mean(axis=0)
    if k < 256:
        cents = np.pad(cents, ((0, 256 - k), (0, 0)),
                       constant_values=PAD_CENTROID)
    return cents


def _assign(x: np.ndarray, cents: np.ndarray) -> np.ndarray:
    """Nearest-centroid assignment via the expansion form, chunked so the
    (chunk, k) distance matrix stays small."""
    cn = (cents.astype(np.float32) ** 2).sum(axis=1)[None, :]
    out = np.empty(len(x), np.int64)
    for lo in range(0, len(x), 16384):
        c = np.asarray(x[lo:lo + 16384], np.float32)
        d2 = (c * c).sum(axis=1)[:, None] - 2.0 * (c @ cents.T) + cn
        out[lo:lo + 16384] = np.argmin(d2, axis=1)
    return out


def train_codebooks(vecs: np.ndarray, m: int, seed: int = 0) -> np.ndarray:
    """(m, 256, dsub) codebooks from a sample of the first flush."""
    vecs = np.asarray(vecs, np.float32)
    n, d = vecs.shape
    dsub = d // m
    if n > TRAIN_SAMPLE:
        rng = np.random.default_rng(seed)
        vecs = vecs[rng.choice(n, size=TRAIN_SAMPLE, replace=False)]
    books = np.empty((m, 256, dsub), np.float32)
    for j in range(m):
        books[j] = _kmeans256(vecs[:, j * dsub:(j + 1) * dsub], seed + j)
    return books


def encode(vecs: np.ndarray, codebooks: np.ndarray) -> np.ndarray:
    """(n, m) uint8 codes: per-subspace nearest codeword."""
    vecs = np.asarray(vecs, np.float32)
    n = len(vecs)
    m, _, dsub = codebooks.shape
    codes = np.empty((n, m), np.uint8)
    for j in range(m):
        codes[:, j] = _assign(vecs[:, j * dsub:(j + 1) * dsub],
                              codebooks[j])
    return codes


def quantize_column(vecs: np.ndarray,
                    codebooks: Optional[np.ndarray] = None,
                    book_id: Optional[int] = None,
                    m: int = DEFAULT_PQ_M,
                    seed: int = 0) -> QuantizedColumn:
    """Encode one segment column; trains fresh codebooks (new book_id)
    only when none are supplied."""
    vecs = np.asarray(vecs, np.float32)
    if codebooks is None:
        codebooks = train_codebooks(vecs, subquantizers(vecs.shape[1], m),
                                    seed=seed)
        book_id = next(_book_ids)
    assert book_id is not None
    return QuantizedColumn(encode(vecs, codebooks), codebooks, book_id)


def merge_quantized(parts: Sequence[QuantizedColumn],
                    merged_vecs: np.ndarray,
                    row_maps: List[np.ndarray]) -> QuantizedColumn:
    """Compaction merge with codebook donation (no retrain, ever).

    The largest part donates its codebooks; every part sharing the
    donor's book copies its codes verbatim through the compaction row
    maps, and only rows from foreign-book parts get a single re-encode
    assignment pass against the donated books.
    """
    donor_i = max(range(len(parts)), key=lambda i: len(parts[i].codes))
    donor = parts[donor_i]
    merged_vecs = np.asarray(merged_vecs, np.float32)
    n_out = len(merged_vecs)
    codes = np.zeros((n_out, donor.m), np.uint8)
    filled = np.zeros(n_out, bool)
    for part, rmap in zip(parts, row_maps):
        if part.book_id != donor.book_id or part.m != donor.m:
            continue
        live = rmap >= 0
        codes[rmap[live]] = part.codes[live]
        filled[rmap[live]] = True
    rest = ~filled
    if rest.any():
        codes[rest] = encode(merged_vecs[rest], donor.codebooks)
    return QuantizedColumn(codes, donor.codebooks, donor.book_id)
