"""Fault-tolerant sharded checkpointing with elastic restore.

Design (scales to 1000+ nodes):
  * step-atomic: write to ``step_N.tmp/`` then rename — a crash mid-write
    never corrupts the latest good checkpoint;
  * sharded: each host writes only the leaves (or leaf-shards) it owns —
    here single-process, the layout is per-leaf ``.npy`` plus a manifest
    (step, config name, mesh shape, tree structure, data-pipeline state);
  * elastic: ``restore`` only needs the manifest + leaf files; the caller
    re-shards onto whatever mesh the restarted job has (device_put with new
    shardings), so a job can restart on a different topology after node
    loss;
  * retention: keep the last K checkpoints, delete older ones.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

MANIFEST = "manifest.json"


def _leaf_paths(tree: Pytree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, state: Pytree,
         extra: Optional[Dict] = None, keep: int = 3) -> str:
    """Atomically save ``state`` for ``step``. Returns the final dir."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _leaf_paths(state)
    index = {}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":   # np.save can't round-trip ml_dtypes
            np.save(os.path.join(tmp, fname), arr.view(np.uint16))
        else:
            np.save(os.path.join(tmp, fname), arr)
        index[key] = {"file": fname, "shape": list(arr.shape),
                      "dtype": dtype_name}
    manifest = {"step": step, "leaves": index, "extra": extra or {}}
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Pytree, step: Optional[int] = None,
            shardings: Optional[Pytree] = None) -> Tuple[Pytree, Dict]:
    """Restore into the structure of ``like``; optionally device_put with
    ``shardings`` (elastic restore onto a new mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    index = manifest["leaves"]
    keys = [k for k, _ in _leaf_paths(like)]
    missing = [k for k in keys if k not in index]
    if missing:
        raise ValueError(f"checkpoint missing leaves: {missing[:5]}...")
    arrays = []
    for k, leaf in _leaf_paths(like):
        arr = np.load(os.path.join(d, index[k]["file"]))
        if index[k]["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        want = tuple(np.shape(leaf)) if hasattr(leaf, "shape") else None
        if want is not None and tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {k}: ckpt {arr.shape} "
                             f"vs state {want}")
        arrays.append(arr)
    treedef = jax.tree.structure(like)
    state = jax.tree.unflatten(treedef, arrays)
    if shardings is not None:
        state = jax.tree.map(
            lambda a, s: jax.device_put(a, s), state, shardings)
    else:
        state = jax.tree.map(jnp.asarray, state)
    return state, manifest["extra"]
