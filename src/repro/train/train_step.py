"""Train step: loss + grad + optimizer update, with microbatching
(gradient accumulation), remat policy (set per-config), and donated buffers.

The step is a pure function; the launcher jits it with in/out shardings
derived from the logical axes (repro.sharding.partition).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model
from repro.train import optimizer as opt_lib

Pytree = Any


def make_train_state(key, cfg: ModelConfig, opt_cfg: opt_lib.OptConfig):
    params, axes = model.init_params(key, cfg)
    opt_state = opt_lib.opt_init(params, opt_cfg)
    return {"params": params, "opt": opt_state}, axes


def train_state_shapes(cfg: ModelConfig, opt_cfg: opt_lib.OptConfig):
    return jax.eval_shape(
        lambda k: make_train_state(k, cfg, opt_cfg)[0], jax.random.PRNGKey(0))


def _is_axes(a):
    return isinstance(a, tuple)


def state_axes(cfg: ModelConfig, opt_cfg: opt_lib.OptConfig) -> Pytree:
    """Logical axes for the full train state (params + optimizer moments).

    AdamW moments share the param axes; Adafactor's factored rows/cols drop
    the last / second-to-last axis respectively.
    """
    p_axes = model.param_axes(cfg)
    p_shapes = model.param_shapes(cfg)
    if opt_cfg.name == "adafactor":
        def v_axes(a, s):
            if len(s.shape) >= 2:
                return {"row": tuple(a[:-1]),
                        "col": tuple(a[:-2]) + (a[-1],)}
            return {"v": tuple(a)}

        v = jax.tree.map(v_axes, p_axes, p_shapes, is_leaf=_is_axes)
        opt_axes = {"v": v, "step": ()}
    else:
        opt_axes = {"mu": p_axes, "nu": p_axes, "step": ()}
        if opt_cfg.compress_grads:
            opt_axes["err"] = p_axes
    return {"params": p_axes, "opt": opt_axes}


def _loss_for_grad(params, cfg, batch):
    loss, metrics = model.loss_fn(params, cfg, batch)
    return loss, metrics


def train_step(state: Pytree, batch: Dict[str, jnp.ndarray],
               cfg: ModelConfig, opt_cfg: opt_lib.OptConfig,
               num_microbatches: int = 1,
               grad_axes: Optional[Pytree] = None) -> Tuple[Pytree, Dict]:
    """One optimizer step. batch["tokens"]: (global_batch, seq).

    ``grad_axes``: logical-axes pytree matching params. When set, each
    microbatch's gradients are sharding-constrained to the parameter
    layout *before* accumulation, so GSPMD lowers the per-microbatch
    cross-data reduction as a reduce-scatter (1/data_parallelism the
    bytes of the unsharded all-reduce it otherwise emits — measured 16x
    on the yi-34b train cell, EXPERIMENTS.md §Perf A1).
    """
    from repro.sharding.partition import constrain

    params = state["params"]
    grad_fn = jax.value_and_grad(_loss_for_grad, has_aux=True)

    def _constrain_grads(g):
        if grad_axes is None:
            return g
        # map with the axes tree first: is_leaf stops at axes tuples
        return jax.tree.map(
            lambda a, leaf: constrain(leaf, a), grad_axes, g,
            is_leaf=lambda a: isinstance(a, tuple))

    if num_microbatches <= 1:
        (loss, metrics), grads = grad_fn(params, cfg, batch)
        grads = _constrain_grads(grads)
    else:
        # gradient accumulation over microbatches via scan (constant HLO)
        def resh(x):
            b = x.shape[0]
            assert b % num_microbatches == 0, (b, num_microbatches)
            return x.reshape(num_microbatches, b // num_microbatches,
                             *x.shape[1:])

        micro = jax.tree.map(resh, batch)
        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def acc_fn(carry, mb):
            g_acc, loss_acc = carry
            (loss, _), g = grad_fn(params, cfg, mb)
            g = _constrain_grads(g)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, loss_acc + loss), None

        (grads, loss), _ = jax.lax.scan(
            acc_fn, (zero_grads, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / num_microbatches, grads)
        loss = loss / num_microbatches
        metrics = {"loss": loss}

    new_params, new_opt = opt_lib.opt_update(grads, state["opt"], params,
                                             opt_cfg)
    metrics = dict(metrics)
    metrics["grad_norm"] = opt_lib.global_norm(grads)
    return {"params": new_params, "opt": new_opt}, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: opt_lib.OptConfig,
                    num_microbatches: int = 1):
    return functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                             num_microbatches=num_microbatches)
