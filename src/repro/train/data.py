"""Deterministic sharded data pipeline.

Synthetic token streams (zipfian unigram mix + markov bigram structure so
loss decreases measurably), deterministically sharded by (host, step):
every host derives its shard from (seed, step, host_id) — no coordination
traffic, and a restarted/elastically-rescaled job replays exactly from the
checkpointed cursor. This is the standard straggler-free input design for
1000+ node jobs (no central dispenser to fall behind).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0


class SyntheticLM:
    """Markov-chain token stream: next ~ 0.7 * bigram(prev) + 0.3 * zipf."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # sparse bigram: each token has 4 likely successors
        self.succ = rng.integers(0, v, size=(v, 4))
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.zipf = p / p.sum()
        self.step = 0

    @property
    def host_batch(self) -> int:
        b, n = self.cfg.global_batch, self.cfg.num_hosts
        assert b % n == 0, (b, n)
        return b // n

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for (step, host) — replayable after restart."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_id, 0xA5CADE))
        b, s = self.host_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.choice(cfg.vocab_size, size=b, p=self.zipf)
        use_bigram = rng.random((b, s)) < 0.7
        zipf_draw = rng.choice(cfg.vocab_size, size=(b, s), p=self.zipf)
        succ_pick = rng.integers(0, 4, size=(b, s))
        for t in range(s):
            big = self.succ[toks[:, t], succ_pick[:, t]]
            toks[:, t + 1] = np.where(use_bigram[:, t], big, zipf_draw[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(self.step)
            self.step += 1

    # ---- checkpointable cursor ----
    def state_dict(self) -> Dict:
        return {"step": self.step}

    def load_state_dict(self, s: Dict) -> None:
        self.step = int(s["step"])


def text_to_tokens(text: str, vocab_size: int, seq_len: int) -> np.ndarray:
    """Toy hashing tokenizer for examples (byte-pair-free, deterministic)."""
    words = text.lower().split()
    ids = [(hash(w) % (vocab_size - 2)) + 2 for w in words][:seq_len]
    ids = ids + [0] * (seq_len - len(ids))
    return np.asarray(ids, np.int32)
