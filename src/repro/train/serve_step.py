"""Serving steps: prefill (full forward) and decode (one token against a
KV/state cache), plus a batched request loop used by the serving driver and
the ARCADE embedding path.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model

Pytree = Any


def prefill_step(params: Pytree, cfg: ModelConfig, tokens: jnp.ndarray,
                 memory: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence forward -> logits (B, S, V)."""
    return model.forward(params, cfg, tokens, memory)


def decode_step(params: Pytree, cfg: ModelConfig, token: jnp.ndarray,
                cache: Pytree, pos,
                memory: Optional[jnp.ndarray] = None):
    """One new token given a cache of depth seq_len -> (logits, cache)."""
    return model.decode_step(params, cfg, token, cache, pos, memory=memory)


def embed_step(params: Pytree, cfg: ModelConfig,
               tokens: jnp.ndarray) -> jnp.ndarray:
    """Batched embedding requests (the ARCADE ingestion/query vector path)."""
    return model.encode(params, cfg, tokens)


def serve_hybrid_queries(params: Pytree, cfg: ModelConfig,
                         tokens: jnp.ndarray, executor,
                         make_query) -> list:
    """Serve a batch of hybrid queries end to end: embed all query token
    sequences in one ``embed_step`` call, build a HybridQuery per request
    via ``make_query(vector)``, and answer the whole batch with one
    shared-scan ``Executor.execute_many`` pass (per-segment scans and
    distance kernels are amortized across the request batch).

    Returns ``[(results, stats), ...]`` aligned with the token batch.
    """
    import numpy as np
    qvecs = np.asarray(_embed_jitted(params, cfg, tokens), np.float32)
    queries = [make_query(qv) for qv in qvecs]
    return executor.execute_many(queries)


# jitted embed for the serving path (ModelConfig is hashable -> static)
_embed_jitted = jax.jit(embed_step, static_argnums=(1,))


def greedy_generate(params: Pytree, cfg: ModelConfig, prompt: jnp.ndarray,
                    max_new: int, max_seq: int,
                    memory: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Greedy decode loop (host-side driver for examples; jits each step)."""
    b, p_len = prompt.shape
    cache, _ = model.init_cache(cfg, b, max_seq)
    step = jax.jit(functools.partial(decode_step, cfg=cfg),
                   static_argnames=())

    # feed the prompt one token at a time (simple, exercises the cache path)
    for i in range(p_len - 1):
        _, cache = step(params, token=prompt[:, i:i + 1], cache=cache,
                        pos=jnp.int32(i), memory=memory)
    pos = p_len - 1
    tok = prompt[:, pos:pos + 1]
    gen = []
    for i in range(max_new):
        logits, cache = step(params, token=tok, cache=cache,
                             pos=jnp.int32(pos + i), memory=memory)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        gen.append(tok)
    return jnp.concatenate(gen, axis=1)
