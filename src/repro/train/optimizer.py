"""Optimizers in pure JAX: AdamW (bf16 params + fp32 moments) and Adafactor
(factored second moment — the production choice for the 671B config, whose
Adam states exceed the v5e HBM envelope; EXPERIMENTS.md §Dry-run).

Also: int8 gradient compression with error feedback, an optional
distributed-optimization trick for cross-pod all-reduces (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"           # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # adafactor
    decay_rate: float = 0.8
    # gradient compression (int8 + error feedback) for cross-pod all-reduce
    compress_grads: bool = False


def lr_schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Pytree, max_norm: float) -> Tuple[Pytree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), tree), norm


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback
# ---------------------------------------------------------------------------

def compress_int8(g: jnp.ndarray, err: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Quantize g+err to int8 with a per-tensor scale; returns (q, scale,
    new_err). The all-reduce then moves 1/4 the bytes of fp32 (1/2 of bf16)."""
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g - deq


def apply_grad_compression(grads: Pytree, err: Pytree) -> Tuple[Pytree, Pytree]:
    """Simulate compressed all-reduce: quantize -> dequantize, carrying the
    quantization error into the next step (error feedback)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    outs, new_errs = [], []
    for g, e in zip(flat_g, flat_e):
        q, scale, ne = compress_int8(g, e)
        outs.append(q.astype(jnp.float32) * scale)
        new_errs.append(ne)
    return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(treedef, new_errs)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params: Pytree, cfg: OptConfig) -> Pytree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compress_grads:
        state["err"] = jax.tree.map(zeros, params)
    return state


def adamw_update(grads: Pytree, state: Pytree, params: Pytree,
                 cfg: OptConfig) -> Tuple[Pytree, Pytree]:
    step = state["step"] + 1
    if cfg.compress_grads:
        grads, new_err = apply_grad_compression(grads, state["err"])
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu / c1
        nu_hat = nu / c2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    if cfg.compress_grads:
        new_state["err"] = new_err
    return new_params, new_state


# ---------------------------------------------------------------------------
# Adafactor (factored second moment)
# ---------------------------------------------------------------------------

def adafactor_init(params: Pytree, cfg: OptConfig) -> Pytree:
    def factored(p):
        if p.ndim >= 2:
            row = jnp.zeros(p.shape[:-1], jnp.float32)
            col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return {"row": row, "col": col}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "v": jax.tree.map(factored, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(grads: Pytree, state: Pytree, params: Pytree,
                     cfg: OptConfig) -> Tuple[Pytree, Pytree]:
    step = state["step"] + 1
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay_rate)

    def upd(p, g, v):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            row = beta2 * v["row"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            col = beta2 * v["col"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            row_mean = jnp.mean(row, axis=-1, keepdims=True)
            vhat = (row / jnp.maximum(row_mean, 1e-30))[..., None] * col[..., None, :]
            new_v = {"row": row, "col": col}
        else:
            vhat = beta2 * v["v"] + (1 - beta2) * g2
            new_v = {"v": vhat}
        update = g / jnp.sqrt(vhat + 1e-30)
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), new_v

    out = jax.tree.map(upd, params, grads, state["v"],
                       is_leaf=lambda x: isinstance(x, dict) and
                       ("row" in x or "v" in x))
    # out leaves are tuples at the positions of params leaves
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"v": new_v, "step": step}


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def opt_init(params: Pytree, cfg: OptConfig) -> Pytree:
    return adafactor_init(params, cfg) if cfg.name == "adafactor" \
        else adamw_init(params, cfg)


def opt_update(grads: Pytree, state: Pytree, params: Pytree,
               cfg: OptConfig) -> Tuple[Pytree, Pytree]:
    return adafactor_update(grads, state, params, cfg) \
        if cfg.name == "adafactor" else adamw_update(grads, state, params, cfg)
