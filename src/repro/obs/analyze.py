"""EXPLAIN ANALYZE: annotate a plan's operator tree with actuals.

The executors run the query under ``trace.force_tracing()`` inside a
private root span; every operator records a span named
``operator:<NodeName>`` whose attributes mirror its ``ExecStats``
charges exactly (``rows``/``bytes``/``blocks``) plus the rows it
produced (``out_rows``).  This module aggregates those spans by operator
name and renders the EXPLAIN tree with an ``(actual time=... rows=...
drift=...)`` suffix per node — ``drift`` is the actual/estimated row
ratio where the planner attached an ``est_rows`` estimate.

Keying by *name* is sound because one query's pipeline instantiates each
operator once (per-conjunct probe nodes under ``BitmapUnion`` execute
inside the union's single span); sharded plans disambiguate repeated
subtrees by nesting operator spans under per-shard ``shard`` spans and
switching the actuals table at each ``Shard`` EXPLAIN node.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from .trace import Span

SPAN_PREFIX = "operator:"

_ZERO = {"count": 0, "time_s": 0.0, "rows": 0, "bytes": 0,
         "blocks": 0.0, "out_rows": 0}


def actuals_from(root: Span) -> Dict[str, Dict[str, Any]]:
    """Aggregate ``operator:*`` spans under ``root`` by operator name."""
    out: Dict[str, Dict[str, Any]] = {}
    for sp in root.walk():
        if not sp.name.startswith(SPAN_PREFIX):
            continue
        d = out.setdefault(sp.name[len(SPAN_PREFIX):], dict(_ZERO))
        d["count"] += 1
        d["time_s"] += sp.dur
        for key in ("rows", "bytes", "blocks", "out_rows"):
            d[key] += sp.attrs.get(key, 0)
    return out


def shard_actuals(root: Span) -> Dict[int, Dict[str, Dict[str, Any]]]:
    """Per-shard actuals tables, keyed by the ``shard=i`` span attr.
    Each table carries a synthetic ``Shard`` entry holding the whole
    shard span's duration (annotates the ``Shard`` EXPLAIN node)."""
    out: Dict[int, Dict[str, Dict[str, Any]]] = {}
    for sp in root.walk():
        if sp.name != "shard":
            continue
        table = actuals_from(sp)
        entry = dict(_ZERO)
        entry.update(count=1, time_s=sp.dur)
        table["Shard"] = entry
        out[int(sp.attrs.get("shard", len(out)))] = table
    return out


def fmt_bytes(n: int) -> str:
    if n < 10_000:
        return f"{n}B"
    if n < 10_000_000:
        return f"{n / 1024:.1f}KB"
    return f"{n / (1024 * 1024):.1f}MB"


def make_annotator(actuals: Dict[str, Dict[str, Any]],
                   per_shard: Optional[
                       Dict[int, Dict[str, Dict[str, Any]]]] = None
                   ) -> Callable:
    """Annotation callback for ``PhysicalOp.explain(annotate=...)``.

    EXPLAIN renders depth-first, so a stateful cursor can switch the
    actuals table as it enters each ``Shard`` subtree: the i-th Shard
    node it meets reads shard i's table (``_ShardSubplan`` details are
    built in shard order)."""
    state = {"table": actuals, "next_shard": 0}

    def annotate(node) -> str:
        if node.name == "Shard" and per_shard is not None:
            state["table"] = per_shard.get(state["next_shard"], {})
            state["next_shard"] += 1
        a = state["table"].get(node.name)
        if a is None:
            return " (actual -)"
        parts = [f"time={a['time_s'] * 1e3:.3f}ms"]
        rows = a["out_rows"] or a["rows"]
        parts.append(f"rows={rows}")
        if a["bytes"]:
            parts.append(f"bytes={fmt_bytes(a['bytes'])}")
        if a["blocks"]:
            parts.append(f"blocks={a['blocks']:.0f}")
        est = float(getattr(node, "est_rows", 0.0) or 0.0)
        parts.append(f"drift={rows / est:.2f}x" if est > 0 else "drift=-")
        return " (actual " + " ".join(parts) + ")"

    return annotate


@dataclasses.dataclass
class Analyzed:
    """EXPLAIN ANALYZE output: the annotated tree plus the execution's
    results / stats / span tree (results are bitwise-identical to a
    normal ``execute`` — analyze only observes)."""
    text: str
    results: List
    stats: Any
    span: Span
    actuals: Dict[str, Dict[str, Any]]
    per_shard: Optional[Dict[int, Dict[str, Dict[str, Any]]]] = None

    def __str__(self) -> str:
        return self.text
