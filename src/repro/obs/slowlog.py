"""Threshold-based slow-query log.

When a query's wall latency crosses the configured threshold, the
executor records an entry holding the latency, the plan description,
and — when tracing was on for that query — the captured span tree.
Retention is a bounded ring buffer; the threshold defaults to ``None``
(disabled) so the hot path is a single comparison against ``None``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

from .trace import Span


class SlowQueryLog:
    def __init__(self, maxlen: int = 128):
        self._lock = threading.Lock()
        self.threshold_s: Optional[float] = None
        self.entries: deque = deque(maxlen=maxlen)

    def configure(self, threshold_s: Optional[float],
                  maxlen: Optional[int] = None) -> None:
        with self._lock:
            self.threshold_s = threshold_s
            if maxlen is not None:
                self.entries = deque(self.entries, maxlen=maxlen)

    def maybe_record(self, latency_s: float, plan: str,
                     span: Optional[Span] = None,
                     **extra: Any) -> bool:
        """Record iff enabled and over threshold; returns True if kept."""
        thr = self.threshold_s
        if thr is None or latency_s < thr:
            return False
        entry = {"ts": time.time(), "latency_s": latency_s, "plan": plan,
                 "span_tree": span.tree() if span is not None else None}
        entry.update(extra)
        with self._lock:
            self.entries.append(entry)
        return True

    def snapshot(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self.entries)

    def clear(self) -> None:
        with self._lock:
            self.entries.clear()


SLOW_LOG = SlowQueryLog()
