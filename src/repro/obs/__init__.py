"""Unified observability layer: span tracer, metrics registry, slow-query
log, and EXPLAIN ANALYZE (ISSUE 10).

    from repro import obs

    obs.set_tracing(True)            # spans (default off, <=2% when off)
    obs.REGISTRY.snapshot()          # counters / gauges / histograms
    obs.TRACER.chrome_trace()        # Perfetto-loadable trace JSON
    obs.SLOW_LOG.configure(0.05)     # log queries slower than 50ms
"""
from .analyze import (Analyzed, actuals_from, make_annotator,  # noqa: F401
                      shard_actuals)
from .metrics import (DEFAULT_LATENCY_BUCKETS, REGISTRY,  # noqa: F401
                      Counter, Gauge, Histogram, MetricsRegistry)
from .slowlog import SLOW_LOG, SlowQueryLog  # noqa: F401
from .trace import (NULL_SPAN, TRACER, Span, Tracer,  # noqa: F401
                    current_span, enabled, force_tracing, record_span,
                    set_tracing, span)
