"""Process-wide metrics registry: counters, gauges, and fixed-bucket
latency histograms with p50/p95/p99, exposed as Prometheus text.

One namespace absorbs the telemetry that used to live in scattered
per-store dicts and thread-local kernel counters:

    lsm.flush_s / lsm.compact_s     flush + compaction durations
    lsm.puts / lsm.flushes / ...    write-path counters
    wal.fsync_s / wal.commits       group-commit fsync latency
    query.latency_s / query.count   read-path latency distribution
    kernels.launches / ...          kernel-dispatch totals (all threads)
    continuous.advance_s            continuous-engine tick latency

The per-store ``metrics`` dicts remain (tests and benchmarks read them);
source sites record into both.  Registry updates are lock-guarded and
cheap (sub-microsecond) — instrumented paths run with metrics always on;
only TRACING defaults off.
"""
from __future__ import annotations

import bisect
import re
import threading
from typing import Any, Dict, List, Optional, Sequence, Union

# log-spaced latency buckets: 10us .. 60s upper bounds (seconds)
DEFAULT_LATENCY_BUCKETS = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4,
    1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class Counter:
    """Monotonic counter (float-valued so duration totals fit too)."""

    kind = "counter"

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value: Union[int, float] = 0

    def inc(self, n: Union[int, float] = 1) -> None:
        with self._lock:
            self.value += n

    def snapshot(self) -> Dict[str, Any]:
        return {"type": self.kind, "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value: float = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def snapshot(self) -> Dict[str, Any]:
        return {"type": self.kind, "value": self.value}


class Histogram:
    """Fixed-bucket histogram with cumulative-count percentiles.

    Buckets are ascending upper bounds; observations above the last
    bound land in the +Inf bucket.  ``percentile`` interpolates within
    the winning bucket and clamps to the observed min/max, so p50 on a
    handful of samples stays inside the sampled range."""

    kind = "histogram"

    def __init__(self, buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        self._lock = threading.Lock()
        self.bounds: List[float] = sorted(float(b) for b in buckets)
        self.counts: List[int] = [0] * (len(self.bounds) + 1)  # + overflow
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def percentile(self, q: float) -> float:
        """q in [0, 1]; 0.0 when the histogram is empty."""
        with self._lock:
            if self.count == 0:
                return 0.0
            target = q * self.count
            cum = 0
            for i, c in enumerate(self.counts):
                cum += c
                if cum >= target and c > 0:
                    hi = self.bounds[i] if i < len(self.bounds) else self.max
                    lo = self.bounds[i - 1] if i > 0 else 0.0
                    frac = 1.0 - (cum - target) / c
                    est = lo + (hi - lo) * frac
                    return min(max(est, self.min), self.max)
            return self.max

    @property
    def p50(self) -> float:
        return self.percentile(0.50)

    @property
    def p95(self) -> float:
        return self.percentile(0.95)

    @property
    def p99(self) -> float:
        return self.percentile(0.99)

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            counts = list(self.counts)
            count, total = self.count, self.sum
        out = {"type": self.kind, "count": count, "sum": total,
               "buckets": {str(b): c
                           for b, c in zip(self.bounds, counts)},
               "inf": counts[-1]}
        if count:
            out.update(p50=self.p50, p95=self.p95, p99=self.p99,
                       min=self.min, max=self.max)
        return out


_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str, prefix: str = "repro_") -> str:
    return prefix + _NAME_RE.sub("_", name)


class MetricsRegistry:
    """Name -> metric, with get-or-create accessors and exporters."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, Union[Counter, Gauge, Histogram]] = {}
        # bumped on reset() so hot paths holding cached metric object
        # refs (kernel dispatch) know to re-fetch
        self.generation = 0

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = self._metrics[name] = cls(*args)
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {m.kind}, "
                            f"not {cls.__name__.lower()}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        return self._get(name, Histogram, buckets)

    # --------------------------------------------------------- conveniences
    def inc(self, name: str, n: Union[int, float] = 1) -> None:
        self.counter(name).inc(n)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def get(self, name: str) -> Optional[Union[Counter, Gauge, Histogram]]:
        return self._metrics.get(name)

    def reset(self) -> None:
        """Drop every metric (benchmark isolation / tests)."""
        with self._lock:
            self._metrics.clear()
            self.generation += 1

    # ------------------------------------------------------------- exports
    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            items = sorted(self._metrics.items())
        return {name: m.snapshot() for name, m in items}

    def prometheus_text(self) -> str:
        """Prometheus text exposition format.  Histograms export the
        standard ``_bucket``/``_sum``/``_count`` series plus derived
        ``_p50``/``_p95``/``_p99`` gauges (the SLO-gate numbers the
        ROADMAP's serving front door wants at a glance)."""
        with self._lock:
            items = sorted(self._metrics.items())
        lines: List[str] = []
        for name, m in items:
            pn = _prom_name(name)
            if isinstance(m, Histogram):
                lines.append(f"# TYPE {pn} histogram")
                cum = 0
                with m._lock:
                    counts = list(m.counts)
                    count, total = m.count, m.sum
                for b, c in zip(m.bounds, counts):
                    cum += c
                    lines.append(f'{pn}_bucket{{le="{b:g}"}} {cum}')
                lines.append(f'{pn}_bucket{{le="+Inf"}} {count}')
                lines.append(f"{pn}_sum {total:.9g}")
                lines.append(f"{pn}_count {count}")
                for q, v in (("p50", m.p50), ("p95", m.p95),
                             ("p99", m.p99)):
                    lines.append(f"# TYPE {pn}_{q} gauge")
                    lines.append(f"{pn}_{q} {v:.9g}")
            else:
                lines.append(f"# TYPE {pn} {m.kind}")
                lines.append(f"{pn} {m.value:.9g}"
                             if isinstance(m.value, float)
                             else f"{pn} {m.value}")
        return "\n".join(lines) + "\n"


REGISTRY = MetricsRegistry()
