"""Low-overhead span tracer: a contextvar-parented span tree per
execution flow, with ring-buffer retention of finished root spans.

Design constraints (ISSUE 10 tentpole):

  * tracing defaults OFF and the disabled path must stay within the
    benchmarked <=2% overhead budget — ``span()`` is one module-global
    check plus a shared no-op context manager, no allocation;
  * spans nest by contextvar, so operator spans land under their query
    span on the query thread while a background flush worker's spans
    root independently (contextvars are per-thread by construction);
  * finished ROOT spans are retained in a bounded deque; exports are
    Chrome trace-event JSON (load in Perfetto / chrome://tracing) and a
    human-readable indented tree.

Call sites open spans with ``with span("flush") as sp:`` — the
with-statement guarantees the span closes on exceptions (machine-checked
by the ``obs/span-closed`` analysis rule).  ``sp.set(rows=...)``
attaches attributes; on the disabled path ``sp`` is the no-op singleton
and ``set`` discards everything.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import threading
import time
from collections import deque
from typing import Any, Dict, Iterator, List, Optional

_EPOCH = time.perf_counter()


class Span:
    """One finished (or in-flight) span: name, start offset from the
    tracer epoch, duration, attributes, children."""

    __slots__ = ("name", "t0", "dur", "attrs", "children")
    live = True

    def __init__(self, name: str, attrs: Optional[Dict[str, Any]] = None):
        self.name = name
        self.t0 = 0.0
        self.dur = 0.0
        self.attrs: Dict[str, Any] = attrs if attrs is not None else {}
        self.children: List["Span"] = []

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def add(self, key: str, delta: Any) -> None:
        """Accumulate a numeric attribute (kernel-launch style counts)."""
        self.attrs[key] = self.attrs.get(key, 0) + delta

    # ---------------------------------------------------------- traversal
    def walk(self) -> Iterator["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def tree(self, indent: int = 0) -> str:
        """Human-readable dump: name, duration, attrs, nested children."""
        pad = "  " * indent
        at = ""
        if self.attrs:
            at = " {" + ", ".join(f"{k}={v}"
                                  for k, v in sorted(self.attrs.items())) \
                + "}"
        lines = [f"{pad}{self.name} {self.dur * 1e3:.3f}ms{at}"]
        for c in self.children:
            lines.append(c.tree(indent + 1))
        return "\n".join(lines)


class _NullSpan:
    """Shared no-op span for the disabled path: ``with span(...)`` costs
    two trivial method calls and ``sp.set(...)`` discards its kwargs."""

    __slots__ = ()
    live = False
    name = ""
    dur = 0.0
    attrs: Dict[str, Any] = {}
    children: List[Span] = []

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def add(self, key: str, delta: Any) -> None:
        pass


NULL_SPAN = _NullSpan()

_current: contextvars.ContextVar[Optional[Span]] = \
    contextvars.ContextVar("repro_obs_span", default=None)


class Tracer:
    """Process-wide retention of finished root spans (bounded)."""

    def __init__(self, maxlen: int = 256):
        self._lock = threading.Lock()
        self.roots: deque = deque(maxlen=maxlen)

    def retain(self, root: Span) -> None:
        with self._lock:
            self.roots.append(root)

    def clear(self) -> None:
        with self._lock:
            self.roots.clear()

    def snapshot(self) -> List[Span]:
        with self._lock:
            return list(self.roots)

    # ------------------------------------------------------------ exports
    def chrome_trace(self) -> str:
        """Chrome trace-event JSON ("X" complete events, microseconds) —
        loadable in Perfetto / chrome://tracing."""
        events = []
        for root in self.snapshot():
            for sp in root.walk():
                events.append({
                    "name": sp.name, "ph": "X", "pid": 0, "tid": 0,
                    "ts": round(sp.t0 * 1e6, 3),
                    "dur": round(sp.dur * 1e6, 3),
                    "args": {k: (v if isinstance(v, (int, float, str, bool))
                                 else repr(v))
                             for k, v in sp.attrs.items()},
                })
        return json.dumps({"traceEvents": events,
                           "displayTimeUnit": "ms"})

    def tree(self) -> str:
        return "\n".join(root.tree() for root in self.snapshot())


TRACER = Tracer()

_enabled = False


def enabled() -> bool:
    return _enabled


def set_tracing(on: bool) -> None:
    """Flip the process-wide tracing switch (default off)."""
    global _enabled
    _enabled = bool(on)


@contextlib.contextmanager
def force_tracing() -> Iterator[None]:
    """Enable tracing for a block and restore the prior state — the
    EXPLAIN ANALYZE path uses this so one query traces regardless of the
    global default."""
    global _enabled
    prev = _enabled
    _enabled = True
    try:
        yield
    finally:
        _enabled = prev


class _SpanCtx:
    """Live context manager returned by ``span()`` when tracing is on."""

    __slots__ = ("node", "token")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.node = Span(name, attrs)
        self.token: Optional[contextvars.Token] = None

    def __enter__(self) -> Span:
        self.token = _current.set(self.node)
        self.node.t0 = time.perf_counter() - _EPOCH
        return self.node

    def __exit__(self, *exc: Any) -> bool:
        node = self.node
        node.dur = time.perf_counter() - _EPOCH - node.t0
        _current.reset(self.token)
        parent = _current.get()
        if parent is None:
            TRACER.retain(node)
        else:
            parent.children.append(node)
        return False


def span(name: str, **attrs: Any):
    """Open a span: ``with span("operator:FusedScanTopK") as sp: ...``.
    A shared no-op when tracing is disabled."""
    if not _enabled:
        return NULL_SPAN
    return _SpanCtx(name, attrs)


def current_span() -> Optional[Span]:
    """The innermost open span on this flow (None when untraced)."""
    if not _enabled:
        return None
    return _current.get()


def record_span(name: str, duration: float, **attrs: Any) -> Optional[Span]:
    """Attach an already-measured span (generator drains accumulate time
    across ``next()`` windows, then record once at exhaustion)."""
    if not _enabled:
        return None
    node = Span(name, attrs)
    node.t0 = time.perf_counter() - _EPOCH - duration
    node.dur = duration
    parent = _current.get()
    if parent is None:
        TRACER.retain(node)
    else:
        parent.children.append(node)
    return node
