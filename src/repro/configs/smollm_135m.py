"""smollm-135m — small llama-arch GQA. [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="smollm-135m", family="dense",
    num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
    d_ff=1536, vocab_size=49152, head_dim=64, remat="full",
    tie_embeddings=True,
)

REDUCED = FULL.replace(
    name="smollm-135m-reduced",
    num_layers=4, d_model=96, num_heads=3, num_kv_heads=1,
    d_ff=192, vocab_size=512, head_dim=32,
)
