"""phi3-medium-14b — dense RoPE SwiGLU GQA. [arXiv:2404.14219; unverified]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="phi3-medium-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
    d_ff=17920, vocab_size=100352, head_dim=128,
    rope_theta=10000.0, remat="full",
)

REDUCED = FULL.replace(
    name="phi3-medium-14b-reduced",
    num_layers=4, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=16, remat="none",
)
