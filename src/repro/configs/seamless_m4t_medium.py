"""seamless-m4t-medium — encoder-decoder multimodal backbone.
[arXiv:2308.11596; hf]

The audio frontend is a STUB per the assignment: input_specs() supplies
precomputed frame embeddings (batch, frames, d_model) for the encoder;
the decoder is a standard transformer with cross-attention. Decode shapes
lower the decoder serve_step against a cached encoder memory.
"""
from repro.configs.base import EncDecConfig, ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64, remat="full",
    encdec=EncDecConfig(encoder_layers=12, frontend_len_ratio=0.25),
)

REDUCED = FULL.replace(
    name="seamless-m4t-medium-reduced",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, head_dim=32,
    encdec=EncDecConfig(encoder_layers=2, frontend_len_ratio=0.25),
)
