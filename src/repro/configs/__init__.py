"""Config registry: ``get_config(arch_id)`` / ``list_archs()``."""
from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    MLAConfig, ModelConfig, MoEConfig, SHAPES, ShapeConfig, SSMConfig,
    XLSTMConfig, cell_supported, get_shape)

from repro.configs import (arcade_embedder, deepseek_moe_16b,
                           deepseek_v3_671b, llama32_vision_90b,
                           phi3_medium_14b, qwen3_4b, seamless_m4t_medium,
                           smollm_135m, xlstm_125m, yi_34b, zamba2_7b)

_REGISTRY = {
    "yi-34b": yi_34b,
    "phi3-medium-14b": phi3_medium_14b,
    "smollm-135m": smollm_135m,
    "qwen3-4b": qwen3_4b,
    "xlstm-125m": xlstm_125m,
    "zamba2-7b": zamba2_7b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "llama-3.2-vision-90b": llama32_vision_90b,
    "arcade-embedder": arcade_embedder,
}

ASSIGNED_ARCHS = tuple(k for k in _REGISTRY if k != "arcade-embedder")


def list_archs():
    return list(_REGISTRY)


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {list(_REGISTRY)}")
    mod = _REGISTRY[name]
    return mod.REDUCED if reduced else mod.FULL
