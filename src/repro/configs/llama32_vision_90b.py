"""llama-3.2-vision-90b — dense GQA with cross-attn image layers every 5th
layer. [hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision tower is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings (batch, num_image_tokens, d_model). 100 layers
= 20 super-blocks of (4 self-attn + 1 cross-attn) lowered as a scan.
"""
from repro.configs.base import ModelConfig, VisionConfig

FULL = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    rope_theta=500_000.0, remat="full",
    vision=VisionConfig(cross_attn_every=5, num_image_tokens=2048),
)

REDUCED = FULL.replace(
    name="llama-3.2-vision-90b-reduced",
    num_layers=5, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=16, remat="none",
    vision=VisionConfig(cross_attn_every=5, num_image_tokens=16),
)
