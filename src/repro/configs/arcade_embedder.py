"""arcade-embedder — the paper-native config: a small dense encoder that
produces the 128-d text embeddings used by the TRACY benchmark (paper §7.1
generates 128-dim embeddings from Tweet content / POI descriptions).

Mean-pooled causal LM trunk + 128-d projection head; this is the model that
examples/serve_hybrid.py serves to embed queries and ingested rows.
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="arcade-embedder", family="dense",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=1408, vocab_size=32000, head_dim=64,
)

REDUCED = FULL.replace(
    name="arcade-embedder-reduced",
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=32,
)

EMBED_DIM = 128
