"""deepseek-v3-671b — MLA + fine-grained MoE (1 shared + 256 routed top-8) + MTP.
[arXiv:2412.19437; hf]

d_ff=2048 is the per-expert width; the first 3 layers are dense with
d_ff=18432 (the published config). Adafactor by default: Adam m/v for 671B
params exceed the 256-chip v5e HBM envelope (EXPERIMENTS.md §Dry-run).
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=2048, vocab_size=129280,
    attn_type="mla", optimizer="adafactor", remat="full", mtp_depth=1,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, num_shared_experts=1, top_k=8,
                  expert_d_ff=2048, first_dense_layers=3, dense_d_ff=18432,
                  capacity_factor=1.25, group_size=1024),
)

REDUCED = FULL.replace(
    name="deepseek-v3-671b-reduced",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=64, vocab_size=512, remat="none", mtp_depth=1,
    mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=8, num_shared_experts=1, top_k=2,
                  expert_d_ff=64, first_dense_layers=1, dense_d_ff=256,
                  capacity_factor=2.0, group_size=64),
)
